//! E2E validation driver (DESIGN.md "End-to-end validation"): pretrains the
//! default serving model (`xl-256a`, the DiT-XL/2-256 analog) to
//! convergence on SynthBlobs-10, trains lazy gates at two ratios, then
//! serves batched requests and reports the paper's headline comparison —
//! ours-at-ratio-r vs DDIM-at-(1−r)·steps at equal compute — with quality
//! metrics, lazy accounting, latency and throughput. The run is recorded
//! in EXPERIMENTS.md.
//!
//! Run (after `make artifacts` — needs the xl-256a config exported):
//!     cargo run --release --example train_and_eval
//! Env knobs: LAZYDIT_PRETRAIN_STEPS, LAZYDIT_GATE_STEPS, LAZYDIT_NEVAL.

use lazydit::bench::quality::{eval_labels, stack_images, FeatureExtractor,
                              MetricContext};
use lazydit::config::{ServeConfig, SkipPolicy, TrainConfig};
use lazydit::coordinator::engine::{generate_batch, Engine, EngineOptions};
use lazydit::model::checkpoint::{gates_path, theta_path, Checkpoint};
use lazydit::model::runner::ModelRunner;
use lazydit::runtime::engine_rt::Runtime;
use lazydit::runtime::manifest::Manifest;
use lazydit::train::lazytrain::{lazy_train, LazyTrainOptions};
use lazydit::train::pretrain::pretrain;
use std::path::PathBuf;
use std::rc::Rc;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    lazydit::util::logging::init();
    let config = std::env::var("LAZYDIT_CONFIG").unwrap_or("xl-256a".into());
    let artifacts = PathBuf::from("artifacts");
    let ckpt = PathBuf::from("runs/e2e");
    let manifest = Manifest::load(&artifacts)?;
    let cfg = manifest.config(&config)?.clone();
    let rt = Rc::new(Runtime::cpu()?);

    // ---- phase 1: pretrain the base model (few hundred steps, log curve)
    let theta = match Checkpoint::load(&theta_path(&ckpt, &config)) {
        Ok(ck) => {
            println!("reusing pretrained θ");
            ck.vec("theta")?.clone()
        }
        Err(_) => {
            let steps = env_usize("LAZYDIT_PRETRAIN_STEPS", 1200);
            println!("== phase 1: pretraining {config} for {steps} steps ==");
            let tc = TrainConfig {
                config_name: config.clone(),
                steps,
                lr: 2e-3,
                ..Default::default()
            };
            let rep = pretrain(&rt, &cfg, &tc, &ckpt)?;
            // loss curve (every 10%)
            println!("loss curve (step, loss):");
            let stride = (rep.losses.len() / 10).max(1);
            for (i, l) in rep.losses.iter().enumerate().step_by(stride) {
                println!("  {i:>6}  {l:.4}");
            }
            println!("final tail loss {:.4} ({:.1}s)", rep.tail_loss, rep.wall_s);
            assert!(rep.tail_loss < rep.first_loss,
                    "pretraining must reduce the loss");
            Checkpoint::load(&theta_path(&ckpt, &config))?.vec("theta")?.clone()
        }
    };

    // ---- phase 2: lazy learning at 30% and 50% targets
    let gate_steps = env_usize("LAZYDIT_GATE_STEPS", 400);
    let mut gammas = Vec::new();
    for ratio in [30usize, 50] {
        let tag = format!("e2e-r{ratio}");
        let gamma = match Checkpoint::load(&gates_path(&ckpt, &config, &tag)) {
            Ok(ck) => ck.vec("gamma")?.clone(),
            Err(_) => {
                println!("== phase 2: lazy learning target {ratio}% \
                          ({gate_steps} steps) ==");
                let tc = TrainConfig {
                    config_name: config.clone(),
                    steps: gate_steps,
                    lr: 5e-3,
                    ..Default::default()
                };
                let opts = LazyTrainOptions {
                    serve_steps: 20,
                    target_attn: Some(ratio as f64 / 100.0),
                    target_ffn: Some(ratio as f64 / 100.0),
                    tag: tag.clone(),
                    ..Default::default()
                };
                let rep = lazy_train(&rt, &cfg, &tc, &opts, &theta, &ckpt)?;
                println!("  skip frac attn/ffn {:.2}/{:.2}, dloss {:.4}, \
                          {:.1}s", rep.final_frac_attn, rep.final_frac_ffn,
                          rep.final_dloss, rep.wall_s);
                Checkpoint::load(&gates_path(&ckpt, &config, &tag))?
                    .vec("gamma")?.clone()
            }
        };
        gammas.push((ratio, gamma));
    }

    // ---- phase 3: serve + evaluate — the paper's headline comparison
    println!("== phase 3: serving comparison ==");
    let extractor = FeatureExtractor::new(&rt, &cfg, manifest.feature_dim)?;
    let n_real = env_usize("LAZYDIT_NREAL", 512);
    let metrics = MetricContext::build(&extractor, cfg.model.img_size, n_real,
                                       0xE2E, 8)?;
    println!("IS-classifier accuracy on real data: {:.3}",
             metrics.clf_accuracy);
    let n_eval = env_usize("LAZYDIT_NEVAL", 96);
    let serve = ServeConfig {
        config_name: config.clone(),
        max_batch: 16,
        policy: SkipPolicy::Mean,
        ..Default::default()
    };

    struct Row {
        name: String,
        steps: usize,
        lazy: f64,
        fid: f64,
        is: f64,
        imgs_per_s: f64,
        gmacs: f64,
    }
    let mut rows: Vec<Row> = Vec::new();

    let mut eval_engine = |name: String, mut engine: Engine, steps: usize,
                           gates_on: bool| -> anyhow::Result<Row> {
        let labels = eval_labels(n_eval, cfg.model.num_classes);
        let t0 = std::time::Instant::now();
        let res = generate_batch(&mut engine, &labels, steps, 0x5EED, 1.5)?;
        let wall = t0.elapsed().as_secs_f64();
        let imgs = stack_images(&res)?;
        let q = metrics.evaluate(&extractor, &imgs)?;
        let lazy: f64 =
            res.iter().map(|r| r.lazy_ratio).sum::<f64>() / res.len() as f64;
        let macs = lazydit::tmacs::run_macs(&cfg.model, steps, lazy, true,
                                            gates_on);
        Ok(Row {
            name,
            steps,
            lazy,
            fid: q.fid,
            is: q.is,
            imgs_per_s: n_eval as f64 / wall,
            gmacs: lazydit::tmacs::as_gmacs(macs),
        })
    };

    // DDIM at full and reduced steps
    for steps in [20usize, 14, 10] {
        let runner = ModelRunner::with_disabled_gates(rt.clone(), cfg.clone(),
                                                      &theta)?;
        let engine = Engine::from_parts(runner, serve.clone(), EngineOptions {
            disable_gates: true,
            ..Default::default()
        });
        rows.push(eval_engine(format!("DDIM-{steps}"), engine, steps, false)?);
    }
    // ours at 20 steps with the two gate sets
    for (ratio, gamma) in &gammas {
        let runner = ModelRunner::new(rt.clone(), cfg.clone(), &theta, gamma)?;
        let engine = Engine::from_parts(runner, serve.clone(),
                                        EngineOptions::default());
        rows.push(eval_engine(format!("Ours-20@{ratio}%"), engine, 20, true)?);
    }

    println!("\n{:<14} {:>5} {:>7} {:>9} {:>8} {:>9} {:>10}",
             "method", "steps", "lazy%", "FID-a", "IS-a", "img/s", "GMACs/img");
    for r in &rows {
        println!("{:<14} {:>5} {:>6.1}% {:>9.3} {:>8.3} {:>9.2} {:>10.3}",
                 r.name, r.steps, 100.0 * r.lazy, r.fid, r.is, r.imgs_per_s,
                 r.gmacs);
    }

    // headline check: ours@50% should beat DDIM at matched compute (10 steps)
    let ddim10 = rows.iter().find(|r| r.name == "DDIM-10").unwrap();
    let ours50 = rows.iter().find(|r| r.name.starts_with("Ours-20@50")).unwrap();
    println!(
        "\nheadline: Ours-20@50% FID {:.3} vs DDIM-10 FID {:.3}  → {}",
        ours50.fid,
        ddim10.fid,
        if ours50.fid < ddim10.fid { "REPRODUCED (ours wins at equal compute)" }
        else { "NOT reproduced on this run" }
    );
    Ok(())
}
