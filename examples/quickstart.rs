//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Loads the `nano` artifacts, pretrains a tiny DiT for a few steps,
//! trains lazy gates, then generates a handful of images both ways
//! (DDIM vs lazy) and prints the lazy-ratio accounting.
//!
//! Run (after `make artifacts`):
//!     cargo run --release --example quickstart

use lazydit::config::{LazyScope, ServeConfig, SkipPolicy, TrainConfig};
use lazydit::coordinator::engine::{generate_batch, Engine, EngineOptions};
use lazydit::model::checkpoint::Checkpoint;
use lazydit::model::runner::ModelRunner;
use lazydit::runtime::engine_rt::Runtime;
use lazydit::runtime::manifest::Manifest;
use lazydit::train::lazytrain::{lazy_train, LazyTrainOptions};
use lazydit::train::pretrain::pretrain;
use std::path::PathBuf;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    lazydit::util::logging::init();
    let artifacts = PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)?;
    let cfg = manifest.config("nano")?.clone();
    let rt = Rc::new(Runtime::cpu()?);
    let ckpt = PathBuf::from("runs/quickstart");

    // 1. pretrain the base DiT on SynthBlobs-10 (AOT pretrain_step graph)
    println!("== pretraining (tiny, ~seconds) ==");
    let tc = TrainConfig {
        config_name: "nano".into(),
        steps: 120,
        lr: 3e-3,
        ..Default::default()
    };
    let rep = pretrain(&rt, &cfg, &tc, &ckpt)?;
    println!("loss {:.4} → {:.4}", rep.first_loss, rep.tail_loss);
    let theta = Checkpoint::load(&lazydit::model::checkpoint::theta_path(&ckpt, "nano"))?
        .vec("theta")?
        .clone();

    // 2. lazy learning (paper Sec. 3.3): gates trained toward 50% laziness
    println!("== lazy learning ==");
    let ltc = TrainConfig {
        config_name: "nano".into(),
        steps: 120,
        lr: 1e-2,
        ..Default::default()
    };
    let opts = LazyTrainOptions {
        serve_steps: 10,
        tag: "quickstart".into(),
        ..Default::default()
    };
    let lrep = lazy_train(&rt, &cfg, &ltc, &opts, &theta, &ckpt)?;
    println!(
        "train-time skip frac: attn {:.2} ffn {:.2}",
        lrep.final_frac_attn, lrep.final_frac_ffn
    );
    let gamma = Checkpoint::load(&lazydit::model::checkpoint::gates_path(
        &ckpt, "nano", "quickstart"))?
        .vec("gamma")?
        .clone();

    // 3. generate: DDIM baseline vs lazy engine
    let serve = ServeConfig {
        config_name: "nano".into(),
        max_batch: 8,
        policy: SkipPolicy::Mean,
        scope: LazyScope::Both,
        ..Default::default()
    };
    let labels = vec![0, 1, 2, 3];

    println!("== DDIM baseline (10 steps) ==");
    let runner = ModelRunner::with_disabled_gates(rt.clone(), cfg.clone(), &theta)?;
    let mut ddim = Engine::from_parts(runner, serve.clone(), EngineOptions {
        disable_gates: true,
        ..Default::default()
    });
    let t0 = std::time::Instant::now();
    let res = generate_batch(&mut ddim, &labels, 10, 7, 1.5)?;
    println!("{} images in {:.2}s, lazy ratio {:.0}%", res.len(),
             t0.elapsed().as_secs_f64(),
             100.0 * ddim.layer_stats.overall_ratio());

    println!("== LazyDiT (10 steps, learned gates) ==");
    let runner = ModelRunner::new(rt, cfg, &theta, &gamma)?;
    let mut lazy = Engine::from_parts(runner, serve, EngineOptions::default());
    let t0 = std::time::Instant::now();
    let res = generate_batch(&mut lazy, &labels, 10, 7, 1.5)?;
    println!("{} images in {:.2}s, lazy ratio {:.1}%", res.len(),
             t0.elapsed().as_secs_f64(),
             100.0 * lazy.layer_stats.overall_ratio());
    println!("{}", lazy.layer_stats.render_fig4());

    // 4. dump a PNG grid
    let images = lazydit::bench::quality::stack_images(&res)?;
    let out = PathBuf::from("runs/quickstart/samples.png");
    lazydit::io::png::write_grid(&out, &images, 2, 16)?;
    println!("wrote {}", out.display());
    Ok(())
}
