//! Mobile-profile example (paper Table 3 context): single-stream serving —
//! one request in flight, CFG lanes only — comparing DDIM step-reduction
//! against lazy skipping at matched compute, reporting per-image latency.
//!
//! Run (after `make artifacts` and a pretrain of nano or xl-256a):
//!     cargo run --release --example mobile_profile

use lazydit::config::{ServeConfig, SkipPolicy, TrainConfig};
use lazydit::coordinator::engine::{generate_batch, Engine, EngineOptions};
use lazydit::model::checkpoint::Checkpoint;
use lazydit::model::runner::ModelRunner;
use lazydit::runtime::engine_rt::Runtime;
use lazydit::runtime::manifest::Manifest;
use lazydit::train::lazytrain::{lazy_train, LazyTrainOptions};
use lazydit::train::pretrain::pretrain;
use std::path::PathBuf;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    lazydit::util::logging::init();
    let artifacts = PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)?;
    let cfg = manifest.config("nano")?.clone();
    let rt = Rc::new(Runtime::cpu()?);
    let ckpt = PathBuf::from("runs/mobile_profile");

    let theta = match Checkpoint::load(
        &lazydit::model::checkpoint::theta_path(&ckpt, "nano")) {
        Ok(ck) => ck.vec("theta")?.clone(),
        Err(_) => {
            let tc = TrainConfig { config_name: "nano".into(), steps: 150,
                                   lr: 3e-3, ..Default::default() };
            pretrain(&rt, &cfg, &tc, &ckpt)?;
            Checkpoint::load(&lazydit::model::checkpoint::theta_path(&ckpt, "nano"))?
                .vec("theta")?.clone()
        }
    };
    let gamma = match Checkpoint::load(
        &lazydit::model::checkpoint::gates_path(&ckpt, "nano", "mobile")) {
        Ok(ck) => ck.vec("gamma")?.clone(),
        Err(_) => {
            let tc = TrainConfig { config_name: "nano".into(), steps: 150,
                                   lr: 1e-2, ..Default::default() };
            let opts = LazyTrainOptions { serve_steps: 20, tag: "mobile".into(),
                                          ..Default::default() };
            lazy_train(&rt, &cfg, &tc, &opts, &theta, &ckpt)?;
            Checkpoint::load(&lazydit::model::checkpoint::gates_path(
                &ckpt, "nano", "mobile"))?.vec("gamma")?.clone()
        }
    };

    // single-stream: max_batch = 2 ⇒ exactly one CFG request per round
    let serve = ServeConfig {
        config_name: "nano".into(),
        max_batch: 2,
        policy: SkipPolicy::Mean,
        ..Default::default()
    };
    let n = 8;
    let labels: Vec<usize> = (0..n).map(|i| i % 10).collect();

    println!("{:<28} {:>6} {:>8} {:>12} {:>10}",
             "setting", "steps", "lazy%", "s/img", "GMACs/img");
    let mut base = None;
    for (name, steps, lazy) in [("DDIM", 20usize, false),
                                ("DDIM", 10, false),
                                ("LazyDiT mean-policy", 20, true)] {
        let runner = if lazy {
            ModelRunner::new(rt.clone(), cfg.clone(), &theta, &gamma)?
        } else {
            ModelRunner::with_disabled_gates(rt.clone(), cfg.clone(), &theta)?
        };
        let mut engine = Engine::from_parts(runner, serve.clone(),
            EngineOptions { disable_gates: !lazy, ..Default::default() });
        let t0 = std::time::Instant::now();
        let res = generate_batch(&mut engine, &labels, steps, 3, 1.5)?;
        let per_img = t0.elapsed().as_secs_f64() / n as f64;
        let ratio: f64 = res.iter().map(|r| r.lazy_ratio).sum::<f64>()
            / res.len() as f64;
        let macs = lazydit::tmacs::run_macs(&cfg.model, steps, ratio, true, lazy);
        if base.is_none() {
            base = Some(per_img);
        }
        println!("{:<28} {:>6} {:>7.1}% {:>11.4}s {:>10.3}", name, steps,
                 100.0 * ratio, per_img,
                 lazydit::tmacs::as_gmacs(macs));
    }
    println!("\nsingle-stream latency tracks compute: the lazy engine's \
              per-image time sits between DDIM-20 and DDIM-10 in proportion \
              to its achieved skip ratio (paper Table 3's shape).");
    Ok(())
}
