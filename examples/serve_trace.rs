//! Serving-workload example: replay a Poisson-arrival request trace through
//! the continuous-batching engine (open-loop), reporting throughput,
//! latency percentiles, and shed count — the workload the paper's serving
//! story targets (Tables 3/6 context).
//!
//! Run (after `make artifacts`):
//!     cargo run --release --example serve_trace

use lazydit::config::{ServeConfig, SkipPolicy, TrainConfig};
use lazydit::coordinator::engine::{Engine, EngineOptions};
use lazydit::coordinator::request::Request;
use lazydit::data::workload::WorkloadSpec;
use lazydit::metrics::stats::{mean, quantile};
use lazydit::model::checkpoint::Checkpoint;
use lazydit::model::runner::ModelRunner;
use lazydit::runtime::engine_rt::Runtime;
use lazydit::runtime::manifest::Manifest;
use lazydit::train::pretrain::pretrain;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    lazydit::util::logging::init();
    let artifacts = PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)?;
    let cfg = manifest.config("nano")?.clone();
    let rt = Rc::new(Runtime::cpu()?);
    let ckpt = PathBuf::from("runs/serve_trace");

    // a quick base model (serving mechanics demo, not a quality run)
    let theta = match Checkpoint::load(
        &lazydit::model::checkpoint::theta_path(&ckpt, "nano")) {
        Ok(ck) => ck.vec("theta")?.clone(),
        Err(_) => {
            let tc = TrainConfig { config_name: "nano".into(), steps: 80,
                                   lr: 3e-3, ..Default::default() };
            pretrain(&rt, &cfg, &tc, &ckpt)?;
            Checkpoint::load(&lazydit::model::checkpoint::theta_path(&ckpt, "nano"))?
                .vec("theta")?.clone()
        }
    };

    let runner = ModelRunner::with_disabled_gates(rt, cfg, &theta)?;
    let mut engine = Engine::from_parts(
        runner,
        ServeConfig { config_name: "nano".into(), max_batch: 8,
                      policy: SkipPolicy::Never, queue_cap: 32,
                      ..Default::default() },
        EngineOptions { disable_gates: true, ..Default::default() },
    );

    // open-loop trace: 48 requests, Poisson arrivals, mixed step counts
    let spec = WorkloadSpec {
        requests: 48,
        rate: 12.0, // req/s
        steps_choices: vec![6, 10, 14],
        num_classes: 10,
        seed: 42,
        slo_mix: Vec::new(), // single engine: no tiers to route to
    };
    let trace = spec.generate();
    println!("replaying {} requests (Poisson {} req/s, steps in {:?})",
             trace.events.len(), spec.rate, spec.steps_choices);

    let t0 = Instant::now();
    let mut pending = trace.events.as_slice();
    let mut done = Vec::new();
    let mut shed = 0usize;
    while !pending.is_empty() || engine.active_count() > 0 {
        let now = t0.elapsed().as_secs_f64();
        // admit arrivals whose time has come, subject to the queue bound
        while let Some(ev) = pending.first() {
            if ev.at > now {
                break;
            }
            if engine.active_count() >= engine.serve.queue_cap {
                shed += 1; // admission control: reject at capacity
            } else {
                let mut req = Request::new(0, ev.class_label, ev.steps, ev.seed);
                req.cfg_scale = 1.5;
                engine.submit(req);
            }
            pending = &pending[1..];
        }
        if engine.active_count() == 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
            continue;
        }
        done.extend(engine.step_round()?);
    }
    let wall = t0.elapsed().as_secs_f64();
    let lat: Vec<f64> = done.iter().map(|r| r.latency.as_secs_f64()).collect();
    println!("completed {} ({} shed) in {wall:.2}s → {:.2} img/s", done.len(),
             shed, done.len() as f64 / wall);
    println!("latency: mean {:.3}s  p50 {:.3}s  p95 {:.3}s  p99 {:.3}s",
             mean(&lat), quantile(&lat, 0.5), quantile(&lat, 0.95),
             quantile(&lat, 0.99));
    println!("engine rounds ran one denoise step each; requests at different \
              timesteps shared batches (continuous batching).");
    Ok(())
}
