//! Output writers: PNG encoder (sample grids, Figures 1/3/7), CSV dumps,
//! and aligned markdown table printing for the paper-table harnesses.

pub mod png;
pub mod table;

pub use table::TableWriter;
