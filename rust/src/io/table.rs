//! Aligned table printer + CSV writer for the paper-table harnesses.

use anyhow::Result;
use std::path::Path;

/// Collects rows and renders a monospace table (and CSV).
#[derive(Debug, Clone)]
pub struct TableWriter {
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TableWriter {
    pub fn new(title: &str, headers: &[&str]) -> TableWriter {
        TableWriter {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Insert a horizontal separator (rendered as a dashed line).
    pub fn hline(&mut self) {
        self.rows.push(vec!["---".to_string(); self.headers.len()]);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = format!("\n== {} ==\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            if r.iter().all(|c| c == "---") {
                out.push_str(&sep);
            } else {
                out.push_str(&fmt_row(r));
            }
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut s = String::new();
        s.push_str(&self.headers.join(","));
        s.push('\n');
        for r in &self.rows {
            if r.iter().all(|c| c == "---") {
                continue;
            }
            let esc: Vec<String> = r
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            s.push_str(&esc.join(","));
            s.push('\n');
        }
        std::fs::write(path, s)?;
        Ok(())
    }
}

/// f64 formatting helpers for table cells.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn f4(v: f64) -> String {
    format!("{v:.4}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TableWriter::new("T", &["method", "fid"]);
        t.row(vec!["DDIM".into(), "2.34".into()]);
        t.row(vec!["Ours".into(), "2.37".into()]);
        let s = t.render();
        assert!(s.contains("method"));
        assert!(s.contains("DDIM"));
        // all data lines equal width
        let lines: Vec<&str> = s.lines().filter(|l| l.contains('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("lazydit_table_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        let mut t = TableWriter::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "a,b\n1,\"x,y\"\n");
    }

    #[test]
    #[should_panic]
    fn wrong_column_count_panics() {
        let mut t = TableWriter::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
