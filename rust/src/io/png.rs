//! Minimal PNG encoder substrate (no image crates offline): 8-bit RGB,
//! stored-deflate zlib blocks, hand-rolled CRC32 and Adler-32.
//! Enough to dump the sample grids of Figures 1/3/7.

use crate::tensor::Tensor;
use anyhow::{bail, Result};
use std::path::Path;

/// CRC32 (IEEE, reflected) — PNG chunk checksums.
fn crc32(data: &[u8]) -> u32 {
    // small table-less implementation; fine for our file sizes
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

fn adler32(data: &[u8]) -> u32 {
    let mut a = 1u32;
    let mut b = 0u32;
    for &byte in data {
        a = (a + byte as u32) % 65521;
        b = (b + a) % 65521;
    }
    (b << 16) | a
}

fn chunk(out: &mut Vec<u8>, kind: &[u8; 4], body: &[u8]) {
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    out.extend_from_slice(kind);
    out.extend_from_slice(body);
    let mut crc_input = Vec::with_capacity(4 + body.len());
    crc_input.extend_from_slice(kind);
    crc_input.extend_from_slice(body);
    out.extend_from_slice(&crc32(&crc_input).to_be_bytes());
}

/// zlib container with stored (uncompressed) deflate blocks.
fn zlib_stored(raw: &[u8]) -> Vec<u8> {
    let mut out = vec![0x78, 0x01]; // CMF/FLG (no compression preset)
    for (i, block) in raw.chunks(65535).enumerate() {
        let last = (i + 1) * 65535 >= raw.len();
        out.push(if last { 1 } else { 0 });
        out.extend_from_slice(&(block.len() as u16).to_le_bytes());
        out.extend_from_slice(&(!(block.len() as u16)).to_le_bytes());
        out.extend_from_slice(block);
    }
    out.extend_from_slice(&adler32(raw).to_be_bytes());
    out
}

/// Encode an RGB8 image (row-major, 3 bytes/pixel) to PNG bytes.
pub fn encode_rgb(width: usize, height: usize, pixels: &[u8]) -> Result<Vec<u8>> {
    if pixels.len() != width * height * 3 {
        bail!("pixel buffer size mismatch");
    }
    let mut out = Vec::new();
    out.extend_from_slice(b"\x89PNG\r\n\x1a\n");
    let mut ihdr = Vec::new();
    ihdr.extend_from_slice(&(width as u32).to_be_bytes());
    ihdr.extend_from_slice(&(height as u32).to_be_bytes());
    ihdr.extend_from_slice(&[8, 2, 0, 0, 0]); // 8-bit, RGB, deflate, none, none
    chunk(&mut out, b"IHDR", &ihdr);
    // raw scanlines with filter byte 0
    let mut raw = Vec::with_capacity(height * (1 + width * 3));
    for y in 0..height {
        raw.push(0);
        raw.extend_from_slice(&pixels[y * width * 3..(y + 1) * width * 3]);
    }
    chunk(&mut out, b"IDAT", &zlib_stored(&raw));
    chunk(&mut out, b"IEND", &[]);
    Ok(out)
}

/// Convert one [3, S, S] image in [-1, 1] to RGB8 row-major.
pub fn tensor_image_to_rgb(img: &[f32], s: usize) -> Vec<u8> {
    let mut px = vec![0u8; s * s * 3];
    for y in 0..s {
        for x in 0..s {
            for c in 0..3 {
                let v = img[c * s * s + y * s + x];
                px[(y * s + x) * 3 + c] = (((v + 1.0) * 0.5).clamp(0.0, 1.0) * 255.0) as u8;
            }
        }
    }
    px
}

/// Write a grid of [B, 3, S, S] images (cols × rows, zero-padded) with
/// `scale`-pixel upsampling (nearest) so 8×8 toys are visible.
pub fn write_grid(path: &Path, imgs: &Tensor, cols: usize, scale: usize) -> Result<()> {
    let shape = imgs.shape();
    if shape.len() != 4 || shape[1] != 3 {
        bail!("expected [B,3,S,S], got {:?}", shape);
    }
    let (b, s) = (shape[0], shape[2]);
    let rows = b.div_ceil(cols);
    let cell = s * scale;
    let (w, h) = (cols * cell, rows * cell);
    let mut px = vec![0u8; w * h * 3];
    for i in 0..b {
        let rgb = tensor_image_to_rgb(imgs.row(i), s);
        let (gy, gx) = (i / cols, i % cols);
        for y in 0..cell {
            for x in 0..cell {
                let src = ((y / scale) * s + (x / scale)) * 3;
                let dst = ((gy * cell + y) * w + gx * cell + x) * 3;
                px[dst..dst + 3].copy_from_slice(&rgb[src..src + 3]);
            }
        }
    }
    let bytes = encode_rgb(w, h, &px)?;
    std::fs::write(path, bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        // CRC32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn adler32_known_vector() {
        // Adler32("Wikipedia") = 0x11E60398
        assert_eq!(adler32(b"Wikipedia"), 0x11E6_0398);
    }

    #[test]
    fn encodes_valid_signature_and_chunks() {
        let px = vec![255u8; 4 * 4 * 3];
        let png = encode_rgb(4, 4, &px).unwrap();
        assert_eq!(&png[..8], b"\x89PNG\r\n\x1a\n");
        assert_eq!(&png[12..16], b"IHDR");
        assert_eq!(&png[png.len() - 8..png.len() - 4], b"IEND");
    }

    #[test]
    fn rejects_bad_buffer() {
        assert!(encode_rgb(4, 4, &[0u8; 5]).is_err());
    }

    #[test]
    fn tensor_to_rgb_range() {
        let img = vec![-1.0f32, 1.0, 0.0, 0.5, -1.0, 1.0, 0.0, 0.5, -1.0, 1.0, 0.0, 0.5];
        let rgb = tensor_image_to_rgb(&img, 2);
        assert_eq!(rgb.len(), 12);
        assert_eq!(rgb[0], 0); // -1 -> 0
        // channel layout interleaved per pixel
        assert!(rgb.iter().all(|&v| v <= 255));
    }

    #[test]
    fn grid_writes_file() {
        let dir = std::env::temp_dir().join("lazydit_png_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("grid.png");
        let imgs = Tensor::from_vec(&[2, 3, 2, 2], vec![0.5; 24]).unwrap();
        write_grid(&p, &imgs, 2, 4).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..4], b"\x89PNG");
    }
}
