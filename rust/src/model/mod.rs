//! Model-side L3: flat parameter store + weight slicing, checkpoint I/O,
//! and the lazy block runner (the per-step module loop that realises the
//! paper's skip-or-run decisions as *elided executable invocations*).

pub mod params;
pub mod checkpoint;
pub mod runner;

pub use params::{GateWeights, WeightSet};
pub use runner::{ModelRunner, StepOutcome, StepStats};
