//! The lazy block runner — the serving hot path.
//!
//! One denoise step = embed → (per block: modgate → decide → [module|cache]
//! → apply) ×2 → final. The decision is made HERE, on the host, *before*
//! the module executable is invoked: a skip elides the whole MHSA/FFN
//! executable call, which is how the paper's laziness becomes wall-clock
//! time (DESIGN.md §2 "per-module executables").

use crate::config::{LazyScope, SkipPolicy};
use crate::model::params::{GateWeights, WeightSet};
use crate::runtime::engine_rt::{Executable, Runtime};
use crate::runtime::manifest::ManifestConfig;
use crate::runtime::value::HostValue;
use crate::tensor::pool::TensorPool;
use crate::tensor::Tensor;
use anyhow::{bail, Result};
use std::rc::Rc;

/// Per-module batch cache: the previous step's module outputs Y_{l,t-1},
/// held in *dual representation* — the host tensor plus a memoized XLA
/// literal of it, built lazily and invalidated only when a fresh module
/// output (or a migrated row) is written. A skipped module therefore
/// hands `apply` a pre-built literal with zero tensor clones and zero
/// host→literal conversions in the steady state (docs/PERF.md).
///
/// Invariant: `lits[k]`, when present, is byte-identical to a conversion
/// of `values[k]` — every mutation of slot `k` goes through a method
/// that either drops or replaces the memo.
pub struct BatchCaches {
    /// [2L] tensors of [B, N, D]; index 2l+m (m: attn=0, ffn=1).
    values: Vec<Tensor>,
    /// Row validity: values[k].row(i) meaningful iff valid[k][i].
    /// Flipping a validity bit never touches the tensor, so it does not
    /// invalidate the literal memo.
    pub valid: Vec<Vec<bool>>,
    /// Memoized literal per slot (None = stale or never built).
    lits: Vec<Option<xla::Literal>>,
    /// Arena the slot tensors were drawn from and return to.
    pool: Rc<TensorPool>,
    /// Host→literal conversions performed (the zero-copy test hook:
    /// flat across steady-state skip steps).
    conversions: u64,
    /// Memo hits: literals served without a conversion.
    lit_hits: u64,
}

impl std::fmt::Debug for BatchCaches {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BatchCaches")
            .field("slots", &self.values.len())
            .field("valid", &self.valid)
            .field("conversions", &self.conversions)
            .field("lit_hits", &self.lit_hits)
            .finish()
    }
}

impl BatchCaches {
    /// A cold cache backed by its own private arena (tests, profiling).
    /// Serving paths share the runner's arena via [`Self::with_pool`].
    pub fn empty(depth: usize, b: usize, n: usize, d: usize) -> BatchCaches {
        Self::with_pool(Rc::new(TensorPool::new()), depth, b, n, d)
    }

    /// A cold cache whose `[B, N, D]` slots are acquired from `pool`
    /// (and return to it via [`Self::release_into_pool`] / slot swaps).
    pub fn with_pool(pool: Rc<TensorPool>, depth: usize, b: usize, n: usize,
                     d: usize) -> BatchCaches {
        BatchCaches {
            values: (0..2 * depth).map(|_| pool.acquire(&[b, n, d])).collect(),
            valid: vec![vec![false; b]; 2 * depth],
            lits: (0..2 * depth).map(|_| None).collect(),
            pool,
            conversions: 0,
            lit_hits: 0,
        }
    }

    /// Number of module slots (2·depth).
    pub fn slots(&self) -> usize {
        self.values.len()
    }

    /// Read access to a slot's host tensor.
    pub fn value(&self, k: usize) -> &Tensor {
        &self.values[k]
    }

    /// Overwrite one row of slot `k` (cache migration on batch-membership
    /// change). Drops the slot's literal memo — the tensor diverged.
    pub fn write_row(&mut self, k: usize, row: usize, src: &[f32]) {
        self.values[k].row_mut(row).copy_from_slice(src);
        self.lits[k] = None;
    }

    /// Install a fresh module output for slot `k`: the tensor is *moved*
    /// in (no clone), the literal the run path already built for `apply`
    /// becomes the memo, and the displaced tensor's buffer returns to
    /// the arena.
    pub fn store_fresh(&mut self, k: usize, f: Tensor, lit: xla::Literal) {
        let old = std::mem::replace(&mut self.values[k], f);
        self.pool.release(old);
        self.lits[k] = Some(lit);
    }

    /// The slot's literal: served from the memo when the tensor hasn't
    /// changed since the last call, converted (and memoized) otherwise.
    pub fn literal(&mut self, k: usize) -> Result<&xla::Literal> {
        if self.lits[k].is_none() {
            self.conversions += 1;
            self.lits[k] = Some(HostValue::f32_literal(&self.values[k])?);
        } else {
            self.lit_hits += 1;
        }
        Ok(self.lits[k].as_ref().expect("just filled"))
    }

    /// Migrate rows from another cache set (the engine's bucket-change
    /// repack): per slot, gather `src`'s rows named by `idx`
    /// (`usize::MAX` ⇒ zeroed padding) into this cache's tensor via
    /// [`Tensor::gather_rows_into`] — reusing the destination buffer —
    /// carry the validity bits along, and drop the literal memos.
    pub fn gather_from(&mut self, src: &BatchCaches, idx: &[usize]) {
        for k in 0..self.values.len() {
            src.values[k].gather_rows_into(idx, &mut self.values[k]);
            self.lits[k] = None;
            for (r, &i) in idx.iter().enumerate() {
                self.valid[k][r] = i != usize::MAX && src.valid[k][i];
            }
        }
    }

    /// Mark every slot's `row` invalid (a request left the batch). The
    /// tensors are untouched, so literal memos stay valid.
    pub fn clear_row(&mut self, row: usize) {
        for v in self.valid.iter_mut() {
            v[row] = false;
        }
    }

    /// Host→literal conversions performed so far (test hook).
    pub fn conversions(&self) -> u64 {
        self.conversions
    }

    /// Literal requests served from the memo (test hook).
    pub fn literal_hits(&self) -> u64 {
        self.lit_hits
    }

    /// Return every slot buffer to the arena (bucket change / drain).
    pub fn release_into_pool(self) {
        let BatchCaches { values, pool, .. } = self;
        for v in values {
            pool.release(v);
        }
    }
}

/// Outcome of one denoise step over a batch.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    /// Guided-model noise prediction [B, C, H, W] (pre-CFG combination).
    pub eps: Tensor,
    /// Gate values s per module per row: [2L][B].
    pub s_vals: Vec<Vec<f32>>,
    /// Whether each module invocation was skipped: [2L].
    pub skipped: Vec<bool>,
    /// Per module slot [2L]: the gates *wanted* to skip but a cold
    /// (cache-invalid) live row forced the whole batch to run — the
    /// laziness lost to all-or-nothing batch coupling when a fresh
    /// request joins (observable via `STATS` as `cold_denied`).
    pub skip_denied_cold: Vec<bool>,
}

/// Aggregated laziness accounting (the paper's Γ, per scope).
#[derive(Debug, Clone, Default)]
pub struct StepStats {
    pub modules_total: usize,
    pub modules_skipped: usize,
    pub attn_total: usize,
    pub attn_skipped: usize,
    pub ffn_total: usize,
    pub ffn_skipped: usize,
    /// Module invocations whose skip was denied by a cold row only.
    pub modules_denied_cold: usize,
    /// Cold-row denials on MHSA slots.
    pub attn_denied_cold: usize,
    /// Cold-row denials on FFN slots.
    pub ffn_denied_cold: usize,
}

impl StepStats {
    pub fn lazy_ratio(&self) -> f64 {
        self.modules_skipped as f64 / self.modules_total.max(1) as f64
    }

    pub fn absorb(&mut self, outcome: &StepOutcome) {
        for (k, &sk) in outcome.skipped.iter().enumerate() {
            self.modules_total += 1;
            let is_attn = k % 2 == 0;
            if is_attn {
                self.attn_total += 1;
            } else {
                self.ffn_total += 1;
            }
            if sk {
                self.modules_skipped += 1;
                if is_attn {
                    self.attn_skipped += 1;
                } else {
                    self.ffn_skipped += 1;
                }
            }
            if outcome.skip_denied_cold.get(k).copied().unwrap_or(false) {
                self.modules_denied_cold += 1;
                if is_attn {
                    self.attn_denied_cold += 1;
                } else {
                    self.ffn_denied_cold += 1;
                }
            }
        }
    }
}

/// Decision controls for one step.
#[derive(Debug, Clone, Copy)]
pub struct DecisionCfg {
    pub policy: SkipPolicy,
    pub scope: LazyScope,
    pub threshold: f32,
}

/// Compiled executables for one bucket size.
struct BucketExes {
    bucket: usize,
    embed: Rc<Executable>,
    modgate: Rc<Executable>,
    attn: Rc<Executable>,
    ffn: Rc<Executable>,
    apply: Rc<Executable>,
    final_: Rc<Executable>,
}

/// Weight tensors pre-converted to XLA literals ONCE at load — the §Perf
/// optimization that removes per-call host→literal conversion of every
/// weight matrix from the hot path (EXPERIMENTS.md §Perf).
struct LitWeights {
    embed: Vec<xla::Literal>,
    /// [depth][module] -> modgate args (w_sh, b_sh, w_sc, b_sc).
    modulate: Vec<[Vec<xla::Literal>; 2]>,
    attn: Vec<Vec<xla::Literal>>,
    ffn: Vec<Vec<xla::Literal>>,
    /// [depth][module] -> (w_al, b_al).
    apply: Vec<[Vec<xla::Literal>; 2]>,
    final_: Vec<xla::Literal>,
    /// [depth][module] -> (w_g, b_g).
    gates: Vec<[(xla::Literal, xla::Literal); 2]>,
}

fn lits(vals: &[HostValue]) -> Result<Vec<xla::Literal>> {
    vals.iter().map(|v| v.to_literal()).collect()
}

/// The runner's arena, sized to the acquire-side demand: a batch
/// rebuild draws the 2L cache slots of one size class (plus a `z` and
/// a couple of transients in other classes). The hot loop's release
/// flux is one-way, so anything beyond this would park dead buffers.
fn arena_for(cfg: &ManifestConfig) -> TensorPool {
    TensorPool::with_capacity(2 * cfg.model.depth + 2)
}

impl LitWeights {
    fn build(w: &WeightSet, g: &GateWeights) -> Result<LitWeights> {
        let pair2 = |arr: &[Vec<HostValue>; 2]| -> Result<[Vec<xla::Literal>; 2]> {
            Ok([lits(&arr[0])?, lits(&arr[1])?])
        };
        Ok(LitWeights {
            embed: lits(&w.embed)?,
            modulate: w.modulate.iter().map(pair2).collect::<Result<_>>()?,
            attn: w.attn.iter().map(|v| lits(v)).collect::<Result<_>>()?,
            ffn: w.ffn.iter().map(|v| lits(v)).collect::<Result<_>>()?,
            apply: w.apply.iter().map(pair2).collect::<Result<_>>()?,
            final_: lits(&w.final_)?,
            gates: g
                .gates
                .iter()
                .map(|pair| {
                    Ok([
                        (pair[0].0.to_literal()?, pair[0].1.to_literal()?),
                        (pair[1].0.to_literal()?, pair[1].1.to_literal()?),
                    ])
                })
                .collect::<Result<_>>()?,
        })
    }
}

/// The model runner: weights + gate weights + per-bucket executables +
/// the buffer arena the step loop recycles transients through.
pub struct ModelRunner {
    rt: Rc<Runtime>,
    pub cfg: ManifestConfig,
    pub weights: WeightSet,
    pub gates: GateWeights,
    lit: LitWeights,
    buckets: Vec<BucketExes>,
    /// Per-runner (hence per-replica) buffer arena: the step loop's
    /// transient `[B, N, D]` tensors and the engine's batch caches all
    /// draw from and return to it, so the steady state allocates
    /// nothing (docs/PERF.md).
    pool: Rc<TensorPool>,
}

impl ModelRunner {
    pub fn new(rt: Rc<Runtime>, cfg: ManifestConfig, theta: &[f32],
               gamma: &[f32]) -> Result<ModelRunner> {
        let weights = WeightSet::from_flat(&cfg, theta)?;
        let gates = GateWeights::from_flat(&cfg, gamma)?;
        let lit = LitWeights::build(&weights, &gates)?;
        let pool = Rc::new(arena_for(&cfg));
        Ok(ModelRunner { rt, cfg, weights, gates, lit, buckets: Vec::new(),
                         pool })
    }

    /// Same runner with laziness disabled (DDIM baseline path).
    pub fn with_disabled_gates(rt: Rc<Runtime>, cfg: ManifestConfig,
                               theta: &[f32]) -> Result<ModelRunner> {
        let weights = WeightSet::from_flat(&cfg, theta)?;
        let gates = GateWeights::disabled(&cfg);
        let lit = LitWeights::build(&weights, &gates)?;
        let pool = Rc::new(arena_for(&cfg));
        Ok(ModelRunner { rt, cfg, weights, gates, lit, buckets: Vec::new(),
                         pool })
    }

    /// The runner's buffer arena — engines share it with their batch
    /// caches so cache slots and step transients recycle into each other.
    pub fn pool(&self) -> &Rc<TensorPool> {
        &self.pool
    }

    /// Replace gate weights (penalty sweeps re-use compiled executables).
    pub fn set_gates(&mut self, gamma: &[f32]) -> Result<()> {
        self.gates = GateWeights::from_flat(&self.cfg, gamma)?;
        self.lit = LitWeights::build(&self.weights, &self.gates)?;
        Ok(())
    }

    fn bucket_exes(&mut self, b: usize) -> Result<usize> {
        if let Some(i) = self.buckets.iter().position(|be| be.bucket == b) {
            return Ok(i);
        }
        if !self.cfg.buckets.contains(&b) {
            bail!("bucket {b} not exported (have {:?})", self.cfg.buckets);
        }
        let load = |name: String| self.rt.load(&self.cfg, &name);
        let be = BucketExes {
            bucket: b,
            embed: load(format!("embed_b{b}"))?,
            modgate: load(format!("modgate_b{b}"))?,
            attn: load(format!("attn_b{b}"))?,
            ffn: load(format!("ffn_b{b}"))?,
            apply: load(format!("apply_b{b}"))?,
            final_: load(format!("final_b{b}"))?,
        };
        self.buckets.push(be);
        Ok(self.buckets.len() - 1)
    }

    /// Pre-compile all executables of a bucket (startup, not hot path).
    pub fn warmup(&mut self, bucket: usize) -> Result<()> {
        self.bucket_exes(bucket)?;
        Ok(())
    }

    /// One denoise step over a padded batch.
    ///
    /// * `z`: [B, C, H, W] latents (B == bucket size, padded rows zeros)
    /// * `t`: [B] float timesteps, `y`: [B] labels (null for uncond rows)
    /// * `live`: [B] — padding rows are false and excluded from decisions
    /// * `caches`: previous-step module outputs, updated in place
    #[allow(clippy::too_many_arguments)]
    pub fn step(&mut self, bucket: usize, z: &Tensor, t: &[f32], y: &[i32],
                live: &[bool], caches: &mut BatchCaches,
                dec: DecisionCfg) -> Result<StepOutcome> {
        self.step_with_forced(bucket, z, t, y, live, caches, dec, None)
    }

    /// `step` with an optional forced skip mask per module slot [2L] — the
    /// input-independent (Learn2Cache-analog) baseline path. A forced skip
    /// is still subject to cache availability.
    #[allow(clippy::too_many_arguments)]
    pub fn step_with_forced(&mut self, bucket: usize, z: &Tensor, t: &[f32],
                            y: &[i32], live: &[bool],
                            caches: &mut BatchCaches, dec: DecisionCfg,
                            forced: Option<&[bool]>) -> Result<StepOutcome> {
        let bi = self.bucket_exes(bucket)?;
        let depth = self.cfg.model.depth;
        let b = bucket;
        debug_assert_eq!(z.shape()[0], b);
        debug_assert_eq!(t.len(), b);

        // dynamic inputs: converted once per step, borrowed in place
        // (weights are pre-built literals — see LitWeights)
        let t_lit = HostValue::F32(Tensor::from_vec(&[b], t.to_vec())?)
            .to_literal()?;
        let y_lit = HostValue::I32 { shape: vec![b], data: y.to_vec() }
            .to_literal()?;
        let z_lit = HostValue::f32_literal(z)?;

        // ---- embed
        let mut embed_args: Vec<&xla::Literal> = vec![&z_lit, &t_lit, &y_lit];
        embed_args.extend(self.lit.embed.iter());
        let mut out = self.buckets[bi].embed.call_lit(&embed_args)?;
        let c = out.pop().unwrap().as_f32()?;
        let mut x = out.pop().unwrap().as_f32()?;
        let c_lit = HostValue::f32_literal(&c)?;
        self.pool.release(c); // only the literal is needed downstream

        let mut s_vals: Vec<Vec<f32>> = Vec::with_capacity(2 * depth);
        let mut skipped: Vec<bool> = Vec::with_capacity(2 * depth);
        let mut skip_denied_cold: Vec<bool> = Vec::with_capacity(2 * depth);

        for l in 0..depth {
            for mi in 0..2usize {
                let k = 2 * l + mi;
                let x_lit = HostValue::f32_literal(&x)?;
                // ---- fused LN + modulate + gate
                let mut mg_args: Vec<&xla::Literal> = vec![&x_lit, &c_lit];
                mg_args.extend(self.lit.modulate[l][mi].iter());
                let (gw, gb) = &self.lit.gates[l][mi];
                mg_args.push(gw);
                mg_args.push(gb);
                let mut mg_out = self.buckets[bi].modgate.call_lit(&mg_args)?;
                let s = mg_out.pop().unwrap().as_f32()?;
                let zmod = mg_out.pop().unwrap().as_f32()?;

                // ---- decision (reads the gate tensor in place — no
                // per-module copy of s just to reduce over it)
                let in_scope = if mi == 0 {
                    dec.scope.covers_attn()
                } else {
                    dec.scope.covers_ffn()
                };
                let cache_ok = live
                    .iter()
                    .enumerate()
                    .filter(|(_, &lv)| lv)
                    .all(|(i, _)| caches.valid[k][i]);
                let would_skip = match forced {
                    Some(mask) => mask[k],
                    None => in_scope
                        && decide(dec.policy, dec.threshold, s.data(), live),
                };
                let blend = dec.policy == SkipPolicy::Blend;
                let skip_now = would_skip && cache_ok && !blend;
                skipped.push(skip_now);
                // laziness lost to all-or-nothing batch coupling: the
                // gates said skip, a cold live row said run
                skip_denied_cold.push(would_skip && !cache_ok && !blend);

                if skip_now {
                    // ---- SKIP: reuse Y_{l,t-1}; the module executable
                    // is never invoked, and the cache flows to `apply`
                    // below as its memoized literal — zero clones, zero
                    // conversions (the latency win, now allocation-free)
                    self.pool.release(zmod);
                } else {
                    // ---- RUN the module
                    let zmod_lit = HostValue::f32_literal(&zmod)?;
                    let mut m_args: Vec<&xla::Literal> = vec![&zmod_lit];
                    let (exe, warr) = if mi == 0 {
                        (&self.buckets[bi].attn, &self.lit.attn[l])
                    } else {
                        (&self.buckets[bi].ffn, &self.lit.ffn[l])
                    };
                    m_args.extend(warr.iter());
                    let mut m_out = exe.call_lit(&m_args)?;
                    let mut f = m_out.pop().unwrap().as_f32()?;
                    if blend && in_scope {
                        // training-faithful blending with the cache
                        blend_rows(&mut f, caches.value(k), &caches.valid[k],
                                   s.data());
                    }
                    // the run path needs the literal for `apply` anyway;
                    // move both the tensor and the literal into the
                    // cache so the next step's skip is free
                    let f_lit = HostValue::f32_literal(&f)?;
                    caches.store_fresh(k, f, f_lit);
                    for (i, &lv) in live.iter().enumerate() {
                        if lv {
                            caches.valid[k][i] = true;
                        }
                    }
                    self.pool.release(zmod);
                }
                // the gate vector is moved (not copied) into the outcome
                s_vals.push(s.into_vec());

                // ---- apply: x + alpha(c) ∘ f  (always runs; paper keeps
                // scale/shift/residual on skip steps). `f` arrives as the
                // cache slot's literal on both paths.
                let f_lit = caches.literal(k)?;
                let mut ap_args: Vec<&xla::Literal> = vec![&x_lit, &c_lit];
                ap_args.extend(self.lit.apply[l][mi].iter());
                ap_args.push(f_lit);
                let mut ap_out = self.buckets[bi].apply.call_lit(&ap_args)?;
                let new_x = ap_out.pop().unwrap().as_f32()?;
                self.pool.release(std::mem::replace(&mut x, new_x));
            }
        }

        // ---- final
        let x_lit = HostValue::f32_literal(&x)?;
        let mut fin_args: Vec<&xla::Literal> = vec![&x_lit, &c_lit];
        fin_args.extend(self.lit.final_.iter());
        let mut fin_out = self.buckets[bi].final_.call_lit(&fin_args)?;
        let eps = fin_out.pop().unwrap().as_f32()?;
        self.pool.release(x);

        Ok(StepOutcome { eps, s_vals, skipped, skip_denied_cold })
    }
}

/// Aggregate per-row gate values into one skip decision (DESIGN.md §7).
/// Allocation-free: it runs 2L times per step on every replica, so the
/// reduction streams over the live rows instead of collecting them.
/// No live rows ⇒ never skip, under every policy.
pub fn decide(policy: SkipPolicy, threshold: f32, s: &[f32], live: &[bool]) -> bool {
    debug_assert_eq!(s.len(), live.len());
    let live_rows = || s.iter().zip(live).filter(|(_, &lv)| lv).map(|(&v, _)| v);
    match policy {
        SkipPolicy::Never => false,
        SkipPolicy::Blend => false, // handled in runner (always runs)
        SkipPolicy::Mean => {
            let (mut sum, mut n) = (0.0f32, 0usize);
            for v in live_rows() {
                sum += v;
                n += 1;
            }
            n > 0 && sum / n as f32 > threshold
        }
        SkipPolicy::Majority => {
            let (mut above, mut n) = (0usize, 0usize);
            for v in live_rows() {
                if v > threshold {
                    above += 1;
                }
                n += 1;
            }
            2 * above > n // n == 0 ⇒ false
        }
        SkipPolicy::All => {
            let mut n = 0usize;
            for v in live_rows() {
                if v <= threshold {
                    return false;
                }
                n += 1;
            }
            n > 0
        }
        SkipPolicy::Any => live_rows().any(|v| v > threshold),
    }
}

/// Row-wise training blend: f_i ← (1−s_i)·f_i + s_i·cache_i (valid rows).
fn blend_rows(f: &mut Tensor, cache: &Tensor, valid: &[bool], s: &[f32]) {
    let r = f.row_len();
    for i in 0..f.dim0() {
        if !valid[i] {
            continue;
        }
        let w = s[i];
        let crow = cache.row(i);
        let frow = &mut f.row_mut(i)[..r];
        for (fv, cv) in frow.iter_mut().zip(crow) {
            *fv = (1.0 - w) * *fv + w * cv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decide_truth_table() {
        let live = vec![true, true, true];
        let s = vec![0.9, 0.9, 0.1];
        assert!(decide(SkipPolicy::Mean, 0.5, &s, &live)); // mean .63
        assert!(decide(SkipPolicy::Majority, 0.5, &s, &live)); // 2/3
        assert!(!decide(SkipPolicy::All, 0.5, &s, &live));
        assert!(decide(SkipPolicy::Any, 0.5, &s, &live));
        assert!(!decide(SkipPolicy::Never, 0.5, &s, &live));
    }

    #[test]
    fn decide_ignores_dead_rows() {
        let live = vec![true, false, false];
        let s = vec![0.1, 0.99, 0.99];
        assert!(!decide(SkipPolicy::Mean, 0.5, &s, &live));
        assert!(!decide(SkipPolicy::Any, 0.5, &s, &live));
    }

    #[test]
    fn decide_empty_live_never_skips() {
        assert!(!decide(SkipPolicy::Any, 0.5, &[0.9], &[false]));
    }

    #[test]
    fn blend_rows_math() {
        let mut f = Tensor::from_vec(&[2, 2], vec![1., 1., 2., 2.]).unwrap();
        let cache = Tensor::from_vec(&[2, 2], vec![3., 3., 4., 4.]).unwrap();
        blend_rows(&mut f, &cache, &[true, false], &[0.5, 0.5]);
        assert_eq!(f.row(0), &[2., 2.]); // blended
        assert_eq!(f.row(1), &[2., 2.]); // invalid cache: untouched
    }

    #[test]
    fn stats_accounting() {
        let outcome = StepOutcome {
            eps: Tensor::zeros(&[1]),
            s_vals: vec![vec![0.9], vec![0.1], vec![0.9], vec![0.2]],
            skipped: vec![true, false, true, false],
            skip_denied_cold: vec![false, true, false, false],
        };
        let mut st = StepStats::default();
        st.absorb(&outcome);
        assert_eq!(st.modules_total, 4);
        assert_eq!(st.modules_skipped, 2);
        assert_eq!(st.attn_skipped, 2);
        assert_eq!(st.ffn_skipped, 0);
        assert_eq!(st.modules_denied_cold, 1);
        assert_eq!(st.attn_denied_cold, 0);
        assert_eq!(st.ffn_denied_cold, 1);
        assert!((st.lazy_ratio() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn literal_cache_write_then_skip_reuses() {
        // the tentpole invariant: consecutive literal() calls without a
        // tensor write perform exactly one conversion (steady-state
        // skips are conversion-free)
        let mut c = BatchCaches::empty(1, 2, 2, 2);
        assert_eq!(c.conversions(), 0);
        c.literal(0).unwrap();
        assert_eq!((c.conversions(), c.literal_hits()), (1, 0));
        c.literal(0).unwrap();
        c.literal(0).unwrap();
        assert_eq!((c.conversions(), c.literal_hits()), (1, 2));
        // other slots have their own memo
        c.literal(1).unwrap();
        assert_eq!(c.conversions(), 2);
    }

    #[test]
    fn literal_cache_write_invalidates() {
        let mut c = BatchCaches::empty(1, 2, 1, 2);
        c.literal(0).unwrap();
        // a row write (cache migration) drops the memo...
        c.write_row(0, 1, &[5.0, 6.0]);
        let lit = c.literal(0).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![0., 0., 5., 6.]);
        assert_eq!(c.conversions(), 2, "stale memo must not be served");
        // ...and the rebuilt memo is served from then on
        c.literal(0).unwrap();
        assert_eq!(c.conversions(), 2);
    }

    #[test]
    fn store_fresh_memoizes_without_converting() {
        let mut c = BatchCaches::empty(1, 1, 1, 2);
        let f = Tensor::from_vec(&[1, 1, 2], vec![3.0, 4.0]).unwrap();
        let lit = crate::runtime::value::HostValue::f32_literal(&f).unwrap();
        c.store_fresh(0, f, lit);
        // the run path's literal becomes the memo: the following skip
        // performs zero conversions
        let got = c.literal(0).unwrap();
        assert_eq!(got.to_vec::<f32>().unwrap(), vec![3.0, 4.0]);
        assert_eq!((c.conversions(), c.literal_hits()), (0, 1));
        assert_eq!(c.value(0).data(), &[3.0, 4.0]);
    }

    #[test]
    fn literal_memo_tracks_tensor_exactly() {
        use crate::util::propcheck::propcheck;
        // coherence property: after any interleaving of row writes,
        // fresh stores, and literal reads, literal(k) always equals a
        // from-scratch conversion of value(k)
        propcheck(60, |g| {
            let b = g.usize_in(1, 4);
            let nd = g.usize_in(1, 6);
            let mut c = BatchCaches::empty(1, b, 1, nd);
            for _ in 0..g.usize_in(1, 12) {
                match g.usize_in(0, 2) {
                    0 => {
                        let row = g.usize_in(0, b - 1);
                        let src = g.vec_f32(nd, -2.0, 2.0);
                        c.write_row(0, row, &src);
                    }
                    1 => {
                        let data = g.vec_f32(b * nd, -2.0, 2.0);
                        let f = Tensor::from_vec(&[b, 1, nd], data).unwrap();
                        let lit =
                            crate::runtime::value::HostValue::f32_literal(&f)
                                .unwrap();
                        c.store_fresh(0, f, lit);
                    }
                    _ => {
                        c.literal(0).unwrap();
                    }
                }
                let expect = c.value(0).data().to_vec();
                let got = c.literal(0).unwrap().to_vec::<f32>().unwrap();
                assert_eq!(got, expect, "memo diverged from tensor");
            }
        });
    }

    #[test]
    fn clear_row_keeps_memo() {
        let mut c = BatchCaches::empty(2, 2, 1, 2);
        c.literal(1).unwrap();
        c.valid[1][0] = true;
        c.clear_row(0);
        assert!(!c.valid[1][0]);
        c.literal(1).unwrap();
        assert_eq!(c.conversions(), 1, "validity flips are memo-neutral");
    }
}
