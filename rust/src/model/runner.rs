//! The lazy block runner — the serving hot path.
//!
//! One denoise step = embed → (per block: modgate → decide → [module|cache]
//! → apply) ×2 → final. The decision is made HERE, on the host, *before*
//! the module executable is invoked: a skip elides the whole MHSA/FFN
//! executable call, which is how the paper's laziness becomes wall-clock
//! time (DESIGN.md §2 "per-module executables").
//!
//! The decision is **row-granular** (the paper's gates are per-sample):
//! every live batch row decides its own skip from its own gate value,
//! and a slot whose rows disagree splits into a compacted run-rows
//! sub-batch (executed at the nearest compiled bucket width, scattered
//! back into the cache slot) while skip-rows are served straight from
//! their cached bytes. The uniform cases keep the PR 4 fast paths:
//! all-skip passes the memoized cache literal to `apply` with zero
//! clones and zero conversions; all-run is the plain full-batch
//! invocation. CFG lane pairs always land in the same partition
//! ([`plan_rows`]). The legacy all-or-nothing batch-consensus gate
//! survives as `DecisionCfg::row_granular = false` (the coupled
//! baseline the `cold_churn` bench compares against).

use crate::config::{LazyScope, SkipPolicy};
use crate::model::params::{GateWeights, WeightSet};
use crate::runtime::engine_rt::{Executable, Runtime};
use crate::runtime::manifest::ManifestConfig;
use crate::runtime::value::HostValue;
use crate::tensor::pool::TensorPool;
use crate::tensor::Tensor;
use anyhow::{bail, Result};
use std::rc::Rc;

/// Per-module batch cache: the previous step's module outputs Y_{l,t-1},
/// held in *dual representation* — the host tensor plus a memoized XLA
/// literal of it, built lazily and invalidated only when a fresh module
/// output (or a migrated row) is written. A skipped module therefore
/// hands `apply` a pre-built literal with zero tensor clones and zero
/// host→literal conversions in the steady state (docs/PERF.md).
///
/// Invariant: `lits[k]`, when present, is byte-identical to a conversion
/// of `values[k]` — every mutation of slot `k` goes through a method
/// that either drops or replaces the memo.
pub struct BatchCaches {
    /// [2L] tensors of [B, N, D]; index 2l+m (m: attn=0, ffn=1).
    values: Vec<Tensor>,
    /// Row validity: values[k].row(i) meaningful iff valid[k][i].
    /// Flipping a validity bit never touches the tensor, so it does not
    /// invalidate the literal memo.
    pub valid: Vec<Vec<bool>>,
    /// Memoized literal per slot (None = stale or never built).
    lits: Vec<Option<xla::Literal>>,
    /// Arena the slot tensors were drawn from and return to.
    pool: Rc<TensorPool>,
    /// Host→literal conversions performed (the zero-copy test hook:
    /// flat across steady-state skip steps).
    conversions: u64,
    /// Memo hits: literals served without a conversion.
    lit_hits: u64,
}

impl std::fmt::Debug for BatchCaches {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BatchCaches")
            .field("slots", &self.values.len())
            .field("valid", &self.valid)
            .field("conversions", &self.conversions)
            .field("lit_hits", &self.lit_hits)
            .finish()
    }
}

impl BatchCaches {
    /// A cold cache backed by its own private arena (tests, profiling).
    /// Serving paths share the runner's arena via [`Self::with_pool`].
    pub fn empty(depth: usize, b: usize, n: usize, d: usize) -> BatchCaches {
        Self::with_pool(Rc::new(TensorPool::new()), depth, b, n, d)
    }

    /// A cold cache whose `[B, N, D]` slots are acquired from `pool`
    /// (and return to it via [`Self::release_into_pool`] / slot swaps).
    pub fn with_pool(pool: Rc<TensorPool>, depth: usize, b: usize, n: usize,
                     d: usize) -> BatchCaches {
        BatchCaches {
            values: (0..2 * depth).map(|_| pool.acquire(&[b, n, d])).collect(),
            valid: vec![vec![false; b]; 2 * depth],
            lits: (0..2 * depth).map(|_| None).collect(),
            pool,
            conversions: 0,
            lit_hits: 0,
        }
    }

    /// Number of module slots (2·depth).
    pub fn slots(&self) -> usize {
        self.values.len()
    }

    /// Read access to a slot's host tensor.
    pub fn value(&self, k: usize) -> &Tensor {
        &self.values[k]
    }

    /// Overwrite one row of slot `k` (cache migration on batch-membership
    /// change). Drops the slot's literal memo — the tensor diverged.
    pub fn write_row(&mut self, k: usize, row: usize, src: &[f32]) {
        self.values[k].row_mut(row).copy_from_slice(src);
        self.lits[k] = None;
    }

    /// Install a fresh module output for slot `k`: the tensor is *moved*
    /// in (no clone), the literal the run path already built for `apply`
    /// becomes the memo, and the displaced tensor's buffer returns to
    /// the arena.
    pub fn store_fresh(&mut self, k: usize, f: Tensor, lit: xla::Literal) {
        let old = std::mem::replace(&mut self.values[k], f);
        self.pool.release(old);
        self.lits[k] = Some(lit);
    }

    /// The slot's literal: served from the memo when the tensor hasn't
    /// changed since the last call, converted (and memoized) otherwise.
    pub fn literal(&mut self, k: usize) -> Result<&xla::Literal> {
        if self.lits[k].is_none() {
            self.conversions += 1;
            self.lits[k] = Some(HostValue::f32_literal(&self.values[k])?);
        } else {
            self.lit_hits += 1;
        }
        Ok(self.lits[k].as_ref().expect("just filled"))
    }

    /// Partial-run install (the row-granular skip path): overwrite the
    /// rows named by `idx` (sub-batch row `j` → batch row `idx[j]`;
    /// `usize::MAX` ⇒ sub-batch padding, dropped) with fresh module
    /// outputs, drop the slot's literal memo (the tensor diverged), and
    /// raise the overwritten rows' validity. Skip-rows keep their cached
    /// bytes and validity untouched.
    pub fn scatter_fresh(&mut self, k: usize, sub: &Tensor, idx: &[usize]) {
        self.values[k].scatter_rows_from(sub, idx);
        self.lits[k] = None;
        for &i in idx {
            if i != usize::MAX {
                self.valid[k][i] = true;
            }
        }
    }

    /// Migrate rows from another cache set (the engine's bucket-change
    /// repack): per slot, gather `src`'s rows named by `idx`
    /// (`usize::MAX` ⇒ zeroed padding) into this cache's tensor via
    /// [`Tensor::gather_rows_into`] — reusing the destination buffer —
    /// carry the validity bits along, and drop the literal memos.
    pub fn gather_from(&mut self, src: &BatchCaches, idx: &[usize]) {
        for k in 0..self.values.len() {
            src.values[k].gather_rows_into(idx, &mut self.values[k]);
            self.lits[k] = None;
            for (r, &i) in idx.iter().enumerate() {
                self.valid[k][r] = i != usize::MAX && src.valid[k][i];
            }
        }
    }

    /// Mark every slot's `row` invalid (a request left the batch). The
    /// tensors are untouched, so literal memos stay valid.
    pub fn clear_row(&mut self, row: usize) {
        for v in self.valid.iter_mut() {
            v[row] = false;
        }
    }

    /// Host→literal conversions performed so far (test hook).
    pub fn conversions(&self) -> u64 {
        self.conversions
    }

    /// Literal requests served from the memo (test hook).
    pub fn literal_hits(&self) -> u64 {
        self.lit_hits
    }

    /// Return every slot buffer to the arena (bucket change / drain).
    pub fn release_into_pool(self) {
        let BatchCaches { values, pool, .. } = self;
        for v in values {
            pool.release(v);
        }
    }
}

/// Outcome of one denoise step over a batch.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    /// Guided-model noise prediction [B, C, H, W] (pre-CFG combination).
    pub eps: Tensor,
    /// Gate values s per module per row: [2L][B].
    pub s_vals: Vec<Vec<f32>>,
    /// Whether the module invocation was elided *entirely* (every live
    /// row served from cache): [2L]. A partial (mixed) slot still ran
    /// the executable — on its compacted run-rows sub-batch — so it
    /// reports `false` here; per-row truth is in [`Self::row_skipped`].
    pub skipped: Vec<bool>,
    /// Per slot [2L]: bitmask of batch rows served from the cache (bit
    /// `i` = row `i` skipped). Rows ≥ 64 fall back to the coupled gate
    /// (see [`Self::row_skipped`]).
    pub row_skips: Vec<u64>,
    /// Per slot [2L]: live rows the module executable actually ran.
    pub rows_run: Vec<u32>,
    /// Per slot [2L]: live rows served straight from the cache.
    pub rows_skipped: Vec<u32>,
    /// Per slot [2L]: rows whose wanted skip was denied by a cold cache
    /// (their own, or their CFG partner's — pairs run together).
    pub rows_denied_cold: Vec<u32>,
    /// Per slot [2L]: skip-rows the legacy all-or-nothing gate would
    /// NOT have skipped on the same inputs (the exact counterfactual —
    /// see [`RowPlan::rows_recovered`]).
    pub rows_recovered: Vec<u32>,
    /// Per module slot [2L]: at least one row's wanted skip was denied
    /// by a cold (cache-invalid) row. Under the legacy coupled gate
    /// this is the whole-batch denial PR 4 surfaced as `cold_denied`;
    /// under row-granular gating only the cold row itself (plus its CFG
    /// partner) runs, so the count measures inherent cold work, not
    /// coupling waste.
    pub skip_denied_cold: Vec<bool>,
}

impl StepOutcome {
    /// Was batch row `row` served from the cache for slot `k`? Rows
    /// past the 64-bit mask fall back to the module-level bool — those
    /// buckets run the coupled gate, whose mask is uniform by
    /// construction.
    pub fn row_skipped(&self, k: usize, row: usize) -> bool {
        if row < 64 {
            (self.row_skips[k] >> row) & 1 == 1
        } else {
            self.skipped[k]
        }
    }
}

/// Aggregated laziness accounting (the paper's Γ, per scope).
#[derive(Debug, Clone, Default)]
pub struct StepStats {
    pub modules_total: usize,
    pub modules_skipped: usize,
    pub attn_total: usize,
    pub attn_skipped: usize,
    pub ffn_total: usize,
    pub ffn_skipped: usize,
    /// Module invocations whose skip was denied by a cold row only.
    pub modules_denied_cold: usize,
    /// Cold-row denials on MHSA slots.
    pub attn_denied_cold: usize,
    /// Cold-row denials on FFN slots.
    pub ffn_denied_cold: usize,
    /// Row-weighted work: live rows the executables actually ran.
    pub rows_run: u64,
    /// Row-weighted laziness: live rows served straight from the cache.
    pub rows_skipped: u64,
    /// Rows skipped while their module still ran for other rows — the
    /// work recovered by row-granular gating.
    pub rows_recovered: u64,
}

impl StepStats {
    pub fn lazy_ratio(&self) -> f64 {
        self.modules_skipped as f64 / self.modules_total.max(1) as f64
    }

    /// Row-weighted lazy ratio (falls back to the module-weighted ratio
    /// when no row accounting has been absorbed).
    pub fn row_lazy_ratio(&self) -> f64 {
        let total = self.rows_run + self.rows_skipped;
        if total == 0 {
            return self.lazy_ratio();
        }
        self.rows_skipped as f64 / total as f64
    }

    pub fn absorb(&mut self, outcome: &StepOutcome) {
        for (k, &sk) in outcome.skipped.iter().enumerate() {
            self.modules_total += 1;
            let is_attn = k % 2 == 0;
            if is_attn {
                self.attn_total += 1;
            } else {
                self.ffn_total += 1;
            }
            if sk {
                self.modules_skipped += 1;
                if is_attn {
                    self.attn_skipped += 1;
                } else {
                    self.ffn_skipped += 1;
                }
            }
            if outcome.skip_denied_cold.get(k).copied().unwrap_or(false) {
                self.modules_denied_cold += 1;
                if is_attn {
                    self.attn_denied_cold += 1;
                } else {
                    self.ffn_denied_cold += 1;
                }
            }
            self.rows_run +=
                outcome.rows_run.get(k).copied().unwrap_or(0) as u64;
            self.rows_skipped +=
                outcome.rows_skipped.get(k).copied().unwrap_or(0) as u64;
            self.rows_recovered +=
                outcome.rows_recovered.get(k).copied().unwrap_or(0) as u64;
        }
    }
}

/// Decision controls for one step.
#[derive(Debug, Clone, Copy)]
pub struct DecisionCfg {
    pub policy: SkipPolicy,
    pub scope: LazyScope,
    pub threshold: f32,
    /// Row-granular gating (the default): every live row decides its
    /// own skips from its own gate value, the module runs on a
    /// compacted run-rows sub-batch, and skip-rows are served from the
    /// cache. `false` restores the legacy all-or-nothing
    /// batch-consensus gate (the coupled baseline — one cold row forces
    /// the whole batch to run).
    pub row_granular: bool,
}

/// Outcome of the per-row gate for one module slot (see [`plan_rows`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RowPlan {
    /// Every live row is served from the cache — no module invocation
    /// at all (the uniform-skip fast path: pre-built literal, zero
    /// clones, zero conversions).
    pub all_skip: bool,
    /// No live row skips (the uniform-run fast path).
    pub all_run: bool,
    /// Live rows the module must run.
    pub rows_run: u32,
    /// Live rows served from the cache.
    pub rows_skipped: u32,
    /// Rows that wanted to skip but run because a cache — their own or
    /// their CFG partner's — is cold.
    pub rows_denied_cold: u32,
    /// Skip-rows the coupled batch-consensus gate would NOT have
    /// skipped on the same inputs — the exact counterfactual, not just
    /// "skips of a mixed slot": a Mean/Majority/Any consensus can skip
    /// a batch whose rows disagree, and those skips are not recovery.
    pub rows_recovered: u32,
}

impl RowPlan {
    /// Neither uniform case: the slot splits into run/skip sub-batches.
    pub fn mixed(&self) -> bool {
        !self.all_skip && !self.all_run
    }
}

/// Per-row gate + cache plan for one module slot: fills `mask[i] = true`
/// iff batch row `i` is served from the cache this step, and returns the
/// partition summary.
///
/// Row-granular mode (`dec.row_granular`): each live row wants to skip
/// iff its own gate value exceeds the threshold (the paper's per-sample
/// gate — `Mean`/`Majority`/`All`/`Any` all reduce to the same
/// per-row test over a singleton; they keep their distinct batch
/// semantics only in coupled mode). A row skips iff it wants to AND its
/// cache row is valid. **CFG-pair invariant:** the cond/uncond lanes of
/// one request (marked by `pairs[i]` = rows `i`,`i+1` are one pair)
/// decide jointly — both skip or both run — so per-request accounting
/// and the batcher's adjacency invariant stay intact.
///
/// Coupled mode reproduces the legacy batch-consensus gate bit-exactly:
/// one decision for the whole batch ([`decide`]), denied outright when
/// any live row's cache is cold.
///
/// A `forced` mask row (the Learn2Cache-analog static schedule)
/// overrides the *gates* in both modes, but cache validity still
/// applies per row — a forced-skip slot with one cold row splits in
/// row-granular mode (only the cold rows run), and is denied outright
/// in coupled mode. `Blend` never skips (the runner blends on the run
/// path).
/// The legacy batch-consensus inputs for one slot: does the consensus
/// (or the forced bit) want the skip, and is every live row's cache
/// warm? One implementation shared by the coupled branch and the
/// row-granular `rows_recovered` counterfactual — the advertised
/// "exact counterfactual" must never drift from the real coupled gate.
fn coupled_gate(dec: DecisionCfg, in_scope: bool, forced: Option<bool>,
                s: &[f32], live: &[bool], valid: &[bool]) -> (bool, bool) {
    let would = match forced {
        Some(f) => f,
        None => in_scope && decide(dec.policy, dec.threshold, s, live),
    };
    let cache_ok = live
        .iter()
        .enumerate()
        .filter(|(_, &lv)| lv)
        .all(|(i, _)| valid[i]);
    (would, cache_ok)
}

#[allow(clippy::too_many_arguments)]
pub fn plan_rows(dec: DecisionCfg, in_scope: bool, forced: Option<bool>,
                 s: &[f32], live: &[bool], pairs: &[bool], valid: &[bool],
                 mask: &mut Vec<bool>) -> RowPlan {
    let n = live.len();
    mask.clear();
    mask.resize(n, false);
    let blend = dec.policy == SkipPolicy::Blend;

    if !dec.row_granular {
        // legacy batch consensus (PR 4 semantics, kept bit-exact)
        let (would, cache_ok) =
            coupled_gate(dec, in_scope, forced, s, live, valid);
        let skip_now = would && cache_ok && !blend;
        let live_n = live.iter().filter(|&&lv| lv).count() as u32;
        if skip_now {
            for (i, &lv) in live.iter().enumerate() {
                mask[i] = lv;
            }
        }
        return RowPlan {
            all_skip: skip_now,
            all_run: !skip_now,
            rows_run: if skip_now { 0 } else { live_n },
            rows_skipped: if skip_now { live_n } else { 0 },
            rows_denied_cold: if would && !cache_ok && !blend {
                live_n
            } else {
                0
            },
            rows_recovered: 0, // the coupled gate cannot out-skip itself
        };
    }

    let (mut rows_run, mut rows_skipped, mut denied) = (0u32, 0u32, 0u32);
    let row_wants = |i: usize| -> bool {
        if blend {
            return false;
        }
        match forced {
            Some(f) => f,
            None => {
                in_scope
                    && !matches!(dec.policy,
                                 SkipPolicy::Never | SkipPolicy::Blend)
                    && s[i] > dec.threshold
            }
        }
    };
    let mut i = 0usize;
    while i < n {
        // CFG lanes are adjacent (batcher invariant); a pair spans two
        // rows and decides jointly
        let span = if pairs.get(i).copied().unwrap_or(false) && i + 1 < n {
            2
        } else {
            1
        };
        if live[i] {
            let want = (i..i + span).all(|r| row_wants(r));
            let ok = (i..i + span).all(|r| valid[r]);
            let skip = want && ok;
            for r in i..i + span {
                mask[r] = skip;
                if skip {
                    rows_skipped += 1;
                } else {
                    rows_run += 1;
                    if want {
                        denied += 1;
                    }
                }
            }
        }
        i += span;
    }
    // the coupled counterfactual, for recovered-work accounting: would
    // the legacy batch-consensus gate have skipped this whole slot?
    // (e.g. a Mean consensus can skip a batch whose rows disagree — the
    // per-row gate's skips there are fidelity, not recovered work)
    let coupled_would = {
        let (would, cache_ok) =
            coupled_gate(dec, in_scope, forced, s, live, valid);
        would && cache_ok && !blend
    };
    RowPlan {
        all_skip: rows_run == 0 && rows_skipped > 0,
        all_run: rows_skipped == 0,
        rows_run,
        rows_skipped,
        rows_denied_cold: denied,
        rows_recovered: if coupled_would { 0 } else { rows_skipped },
    }
}

/// The run/skip split of one partial module invocation: which batch
/// rows must run — compacted into a padded sub-batch at the nearest
/// compiled bucket width — and which are served straight from the
/// cache. One instance lives on the runner and is re-planned in place
/// every mixed slot (index lists recycled, no allocation in the steady
/// state); the compacted tensors themselves recycle through the
/// runner's [`TensorPool`].
#[derive(Debug, Default, Clone)]
pub struct RowPartition {
    /// Compiled bucket width of the run sub-batch (≥ the run-row count,
    /// never wider than the full batch's bucket).
    pub bucket: usize,
    /// Batch row of each sub-batch row, padded with `usize::MAX` to
    /// `bucket`. Compaction is its own inverse, so this one map drives
    /// both the gather (batch → sub-batch) and the scatter back
    /// ([`Tensor::gather_rows_into`] / [`Tensor::scatter_rows_from`]).
    pub run_idx: Vec<usize>,
    /// Batch rows served from the cache (diagnostics and tests).
    pub skip_idx: Vec<usize>,
}

impl RowPartition {
    /// Re-plan in place from a skip mask: run-rows are the live rows
    /// whose mask bit is false; the sub-batch width is the smallest
    /// compiled bucket that holds them. `cur_bucket` (the full batch's
    /// width) is itself compiled, so a width always exists.
    pub fn plan(&mut self, mask: &[bool], live: &[bool], buckets: &[usize],
                cur_bucket: usize) {
        self.run_idx.clear();
        self.skip_idx.clear();
        for (i, &lv) in live.iter().enumerate() {
            if !lv {
                continue;
            }
            if mask[i] {
                self.skip_idx.push(i);
            } else {
                self.run_idx.push(i);
            }
        }
        let need = self.run_idx.len();
        self.bucket = buckets
            .iter()
            .copied()
            .filter(|&w| w >= need && w <= cur_bucket)
            .min()
            .unwrap_or(cur_bucket);
        self.run_idx.resize(self.bucket, usize::MAX);
    }
}

/// Compiled executables for one bucket size.
struct BucketExes {
    bucket: usize,
    embed: Rc<Executable>,
    modgate: Rc<Executable>,
    attn: Rc<Executable>,
    ffn: Rc<Executable>,
    apply: Rc<Executable>,
    final_: Rc<Executable>,
}

/// Weight tensors pre-converted to XLA literals ONCE at load — the §Perf
/// optimization that removes per-call host→literal conversion of every
/// weight matrix from the hot path (EXPERIMENTS.md §Perf).
struct LitWeights {
    embed: Vec<xla::Literal>,
    /// [depth][module] -> modgate args (w_sh, b_sh, w_sc, b_sc).
    modulate: Vec<[Vec<xla::Literal>; 2]>,
    attn: Vec<Vec<xla::Literal>>,
    ffn: Vec<Vec<xla::Literal>>,
    /// [depth][module] -> (w_al, b_al).
    apply: Vec<[Vec<xla::Literal>; 2]>,
    final_: Vec<xla::Literal>,
    /// [depth][module] -> (w_g, b_g).
    gates: Vec<[(xla::Literal, xla::Literal); 2]>,
}

fn lits(vals: &[HostValue]) -> Result<Vec<xla::Literal>> {
    vals.iter().map(|v| v.to_literal()).collect()
}

/// The runner's arena, sized to the acquire-side demand: a batch
/// rebuild draws the 2L cache slots of one size class (plus a `z` and
/// a couple of transients in other classes). The hot loop's release
/// flux is one-way, so anything beyond this would park dead buffers.
fn arena_for(cfg: &ManifestConfig) -> TensorPool {
    TensorPool::with_capacity(2 * cfg.model.depth + 2)
}

impl LitWeights {
    fn build(w: &WeightSet, g: &GateWeights) -> Result<LitWeights> {
        let pair2 = |arr: &[Vec<HostValue>; 2]| -> Result<[Vec<xla::Literal>; 2]> {
            Ok([lits(&arr[0])?, lits(&arr[1])?])
        };
        Ok(LitWeights {
            embed: lits(&w.embed)?,
            modulate: w.modulate.iter().map(pair2).collect::<Result<_>>()?,
            attn: w.attn.iter().map(|v| lits(v)).collect::<Result<_>>()?,
            ffn: w.ffn.iter().map(|v| lits(v)).collect::<Result<_>>()?,
            apply: w.apply.iter().map(pair2).collect::<Result<_>>()?,
            final_: lits(&w.final_)?,
            gates: g
                .gates
                .iter()
                .map(|pair| {
                    Ok([
                        (pair[0].0.to_literal()?, pair[0].1.to_literal()?),
                        (pair[1].0.to_literal()?, pair[1].1.to_literal()?),
                    ])
                })
                .collect::<Result<_>>()?,
        })
    }
}

/// The model runner: weights + gate weights + per-bucket executables +
/// the buffer arena the step loop recycles transients through.
pub struct ModelRunner {
    rt: Rc<Runtime>,
    pub cfg: ManifestConfig,
    pub weights: WeightSet,
    pub gates: GateWeights,
    lit: LitWeights,
    buckets: Vec<BucketExes>,
    /// Per-runner (hence per-replica) buffer arena: the step loop's
    /// transient `[B, N, D]` tensors and the engine's batch caches all
    /// draw from and return to it, so the steady state allocates
    /// nothing (docs/PERF.md).
    pool: Rc<TensorPool>,
    /// Reusable per-slot skip mask filled by [`plan_rows`] — grown once,
    /// then recycled every module slot (allocation-free hot path).
    gate_mask: Vec<bool>,
    /// Reusable run/skip partition plan for mixed slots (index lists
    /// recycled in place; compacted tensors recycle through `pool`).
    partition: RowPartition,
    /// Bucket widths the partial path may compact a run sub-batch to.
    /// Defaults to the full compiled set; SLO-tiered engines restrict
    /// it to their round-bucket set
    /// ([`Self::restrict_partial_buckets`]) so a tier's executable
    /// footprint stays bounded the way PR 3 intended.
    partial_buckets: Vec<usize>,
    /// Telemetry sink for per-module run/skip spans (disabled by
    /// default: zero clock reads, zero allocations on the step path).
    tracer: crate::obs::Tracer,
}

impl ModelRunner {
    pub fn new(rt: Rc<Runtime>, cfg: ManifestConfig, theta: &[f32],
               gamma: &[f32]) -> Result<ModelRunner> {
        let weights = WeightSet::from_flat(&cfg, theta)?;
        let gates = GateWeights::from_flat(&cfg, gamma)?;
        let lit = LitWeights::build(&weights, &gates)?;
        let pool = Rc::new(arena_for(&cfg));
        let partial_buckets = cfg.buckets.clone();
        Ok(ModelRunner { rt, cfg, weights, gates, lit, buckets: Vec::new(),
                         pool, gate_mask: Vec::new(),
                         partition: RowPartition::default(),
                         partial_buckets,
                         tracer: crate::obs::Tracer::disabled() })
    }

    /// Same runner with laziness disabled (DDIM baseline path).
    pub fn with_disabled_gates(rt: Rc<Runtime>, cfg: ManifestConfig,
                               theta: &[f32]) -> Result<ModelRunner> {
        let weights = WeightSet::from_flat(&cfg, theta)?;
        let gates = GateWeights::disabled(&cfg);
        let lit = LitWeights::build(&weights, &gates)?;
        let pool = Rc::new(arena_for(&cfg));
        let partial_buckets = cfg.buckets.clone();
        Ok(ModelRunner { rt, cfg, weights, gates, lit, buckets: Vec::new(),
                         pool, gate_mask: Vec::new(),
                         partition: RowPartition::default(),
                         partial_buckets,
                         tracer: crate::obs::Tracer::disabled() })
    }

    /// Hand the runner a telemetry tracer: every module slot records a
    /// run/skip span with its gate value and row split (see
    /// [`crate::obs`]). Costs two clock reads and one ring write per
    /// slot when enabled; a single branch when not.
    pub fn install_tracer(&mut self, tracer: crate::obs::Tracer) {
        self.tracer = tracer;
    }

    /// Restrict the widths the partial (run-rows sub-batch) path may
    /// compile to — an SLO-tiered engine passes its round-bucket set so
    /// a mixed slot never lazily loads executables outside the tier's
    /// footprint. Unknown widths are ignored (every partial bucket must
    /// be compiled); an empty intersection keeps the full compiled set.
    pub fn restrict_partial_buckets(&mut self, buckets: &[usize]) {
        let restricted: Vec<usize> = self
            .cfg
            .buckets
            .iter()
            .copied()
            .filter(|b| buckets.contains(b))
            .collect();
        if !restricted.is_empty() {
            self.partial_buckets = restricted;
        }
    }

    /// The runner's buffer arena — engines share it with their batch
    /// caches so cache slots and step transients recycle into each other.
    pub fn pool(&self) -> &Rc<TensorPool> {
        &self.pool
    }

    /// Replace gate weights (penalty sweeps re-use compiled executables).
    pub fn set_gates(&mut self, gamma: &[f32]) -> Result<()> {
        self.gates = GateWeights::from_flat(&self.cfg, gamma)?;
        self.lit = LitWeights::build(&self.weights, &self.gates)?;
        Ok(())
    }

    fn bucket_exes(&mut self, b: usize) -> Result<usize> {
        if let Some(i) = self.buckets.iter().position(|be| be.bucket == b) {
            return Ok(i);
        }
        if !self.cfg.buckets.contains(&b) {
            bail!("bucket {b} not exported (have {:?})", self.cfg.buckets);
        }
        let load = |name: String| self.rt.load(&self.cfg, &name);
        let be = BucketExes {
            bucket: b,
            embed: load(format!("embed_b{b}"))?,
            modgate: load(format!("modgate_b{b}"))?,
            attn: load(format!("attn_b{b}"))?,
            ffn: load(format!("ffn_b{b}"))?,
            apply: load(format!("apply_b{b}"))?,
            final_: load(format!("final_b{b}"))?,
        };
        self.buckets.push(be);
        Ok(self.buckets.len() - 1)
    }

    /// Pre-compile all executables of a bucket (startup, not hot path).
    pub fn warmup(&mut self, bucket: usize) -> Result<()> {
        self.bucket_exes(bucket)?;
        Ok(())
    }

    /// One denoise step over a padded batch.
    ///
    /// * `z`: [B, C, H, W] latents (B == bucket size, padded rows zeros)
    /// * `t`: [B] float timesteps, `y`: [B] labels (null for uncond rows)
    /// * `live`: [B] — padding rows are false and excluded from decisions
    /// * `pairs`: [B] — `pairs[i]` marks rows `i`,`i+1` as one request's
    ///   CFG lane pair (they skip or run together)
    /// * `caches`: previous-step module outputs, updated in place
    #[allow(clippy::too_many_arguments)]
    pub fn step(&mut self, bucket: usize, z: &Tensor, t: &[f32], y: &[i32],
                live: &[bool], pairs: &[bool], caches: &mut BatchCaches,
                dec: DecisionCfg) -> Result<StepOutcome> {
        self.step_with_forced(bucket, z, t, y, live, pairs, caches, dec,
                              None)
    }

    /// `step` with an optional forced skip mask per module slot [2L] — the
    /// input-independent (Learn2Cache-analog) baseline path. A forced skip
    /// is still subject to cache availability.
    #[allow(clippy::too_many_arguments)]
    pub fn step_with_forced(&mut self, bucket: usize, z: &Tensor, t: &[f32],
                            y: &[i32], live: &[bool], pairs: &[bool],
                            caches: &mut BatchCaches, dec: DecisionCfg,
                            forced: Option<&[bool]>) -> Result<StepOutcome> {
        let bi = self.bucket_exes(bucket)?;
        let depth = self.cfg.model.depth;
        let b = bucket;
        debug_assert_eq!(z.shape()[0], b);
        debug_assert_eq!(t.len(), b);
        // the per-row mask rides StepOutcome as a 64-bit bitmask; wider
        // buckets (unrealistically large) fall back to the coupled gate
        let mut dec = dec;
        if b > 64 {
            dec.row_granular = false;
        }

        // dynamic inputs: converted once per step, borrowed in place
        // (weights are pre-built literals — see LitWeights)
        let t_lit = HostValue::F32(Tensor::from_vec(&[b], t.to_vec())?)
            .to_literal()?;
        let y_lit = HostValue::I32 { shape: vec![b], data: y.to_vec() }
            .to_literal()?;
        let z_lit = HostValue::f32_literal(z)?;

        // ---- embed
        let mut embed_args: Vec<&xla::Literal> = vec![&z_lit, &t_lit, &y_lit];
        embed_args.extend(self.lit.embed.iter());
        let mut out = self.buckets[bi].embed.call_lit(&embed_args)?;
        let c = out.pop().unwrap().as_f32()?;
        let mut x = out.pop().unwrap().as_f32()?;
        let c_lit = HostValue::f32_literal(&c)?;
        self.pool.release(c); // only the literal is needed downstream

        let mut s_vals: Vec<Vec<f32>> = Vec::with_capacity(2 * depth);
        let mut skipped: Vec<bool> = Vec::with_capacity(2 * depth);
        let mut skip_denied_cold: Vec<bool> = Vec::with_capacity(2 * depth);
        let mut row_skips: Vec<u64> = Vec::with_capacity(2 * depth);
        let mut rows_run: Vec<u32> = Vec::with_capacity(2 * depth);
        let mut rows_skipped: Vec<u32> = Vec::with_capacity(2 * depth);
        let mut rows_denied: Vec<u32> = Vec::with_capacity(2 * depth);
        let mut rows_recovered: Vec<u32> = Vec::with_capacity(2 * depth);

        for l in 0..depth {
            for mi in 0..2usize {
                let k = 2 * l + mi;
                // 0 without touching the clock when tracing is off
                let slot_start = self.tracer.now_us();
                let x_lit = HostValue::f32_literal(&x)?;
                // ---- fused LN + modulate + gate
                let mut mg_args: Vec<&xla::Literal> = vec![&x_lit, &c_lit];
                mg_args.extend(self.lit.modulate[l][mi].iter());
                let (gw, gb) = &self.lit.gates[l][mi];
                mg_args.push(gw);
                mg_args.push(gb);
                let mut mg_out = self.buckets[bi].modgate.call_lit(&mg_args)?;
                let s = mg_out.pop().unwrap().as_f32()?;
                let zmod = mg_out.pop().unwrap().as_f32()?;

                // ---- decision (reads the gate tensor in place — no
                // per-module copy of s just to reduce over it): a
                // per-row skip mask, uniform fast paths kept
                let in_scope = if mi == 0 {
                    dec.scope.covers_attn()
                } else {
                    dec.scope.covers_ffn()
                };
                let blend = dec.policy == SkipPolicy::Blend;
                let forced_k = forced.map(|mask| mask[k]);
                let plan = plan_rows(dec, in_scope, forced_k, s.data(),
                                     live, pairs, &caches.valid[k],
                                     &mut self.gate_mask);
                skipped.push(plan.all_skip);
                // laziness lost to a cold cache: the gates said skip,
                // a cold row said run (the whole batch under the
                // coupled gate; just that row and its CFG partner under
                // row granularity)
                skip_denied_cold.push(plan.rows_denied_cold > 0);
                let mut bits = 0u64;
                for (i, &m) in self.gate_mask.iter().take(64).enumerate() {
                    if m {
                        bits |= 1 << i;
                    }
                }
                row_skips.push(bits);
                rows_run.push(plan.rows_run);
                rows_skipped.push(plan.rows_skipped);
                rows_denied.push(plan.rows_denied_cold);
                rows_recovered.push(plan.rows_recovered);

                if plan.all_skip {
                    // ---- SKIP (uniform): reuse Y_{l,t-1}; the module
                    // executable is never invoked, and the cache flows
                    // to `apply` below as its memoized literal — zero
                    // clones, zero conversions (the latency win)
                    self.pool.release(zmod);
                } else if plan.all_run {
                    // ---- RUN (uniform): the whole batch through the
                    // module executable
                    let zmod_lit = HostValue::f32_literal(&zmod)?;
                    let mut m_args: Vec<&xla::Literal> = vec![&zmod_lit];
                    let (exe, warr) = if mi == 0 {
                        (&self.buckets[bi].attn, &self.lit.attn[l])
                    } else {
                        (&self.buckets[bi].ffn, &self.lit.ffn[l])
                    };
                    m_args.extend(warr.iter());
                    let mut m_out = exe.call_lit(&m_args)?;
                    let mut f = m_out.pop().unwrap().as_f32()?;
                    if blend && in_scope {
                        // training-faithful blending with the cache
                        blend_rows(&mut f, caches.value(k), &caches.valid[k],
                                   s.data());
                    }
                    // the run path needs the literal for `apply` anyway;
                    // move both the tensor and the literal into the
                    // cache so the next step's skip is free
                    let f_lit = HostValue::f32_literal(&f)?;
                    caches.store_fresh(k, f, f_lit);
                    for (i, &lv) in live.iter().enumerate() {
                        if lv {
                            caches.valid[k][i] = true;
                        }
                    }
                    self.pool.release(zmod);
                } else {
                    // ---- PARTIAL: compact the run-rows into a
                    // sub-batch at the nearest compiled bucket width,
                    // invoke the module there, scatter the fresh rows
                    // back into the cache slot; skip-rows are served
                    // straight from their cached bytes (the laziness
                    // the all-or-nothing gate used to deny)
                    let mut part = std::mem::take(&mut self.partition);
                    part.plan(&self.gate_mask, live, &self.partial_buckets,
                              b);
                    let sbi = self.bucket_exes(part.bucket)?;
                    let mut zshape = zmod.shape().to_vec();
                    zshape[0] = part.bucket;
                    // no-zero acquire: the gather writes every row
                    // (run-rows copied, padding rows are its memset),
                    // so zeroing first would touch each byte twice
                    let mut zsub = self.pool.acquire_for_overwrite(&zshape);
                    zmod.gather_rows_into(&part.run_idx, &mut zsub);
                    let zsub_lit = HostValue::f32_literal(&zsub)?;
                    let mut m_args: Vec<&xla::Literal> = vec![&zsub_lit];
                    let (exe, warr) = if mi == 0 {
                        (&self.buckets[sbi].attn, &self.lit.attn[l])
                    } else {
                        (&self.buckets[sbi].ffn, &self.lit.ffn[l])
                    };
                    m_args.extend(warr.iter());
                    let mut m_out = exe.call_lit(&m_args)?;
                    let fsub = m_out.pop().unwrap().as_f32()?;
                    caches.scatter_fresh(k, &fsub, &part.run_idx);
                    self.pool.release(fsub);
                    self.pool.release(zsub);
                    self.pool.release(zmod);
                    self.partition = part;
                }
                if self.tracer.is_enabled() {
                    // live-row mean gate value rides the packed arg;
                    // this O(B) pass runs only when tracing is on
                    let (mut sum, mut n) = (0.0f64, 0u32);
                    for (i, &lv) in live.iter().enumerate() {
                        if lv {
                            sum += s.data()[i] as f64;
                            n += 1;
                        }
                    }
                    let gate = if n > 0 { sum / n as f64 } else { 0.0 };
                    self.tracer.record_at(crate::obs::TraceEvent {
                        kind: if plan.all_skip {
                            crate::obs::EventKind::ModuleSkip
                        } else {
                            crate::obs::EventKind::ModuleRun
                        },
                        ts_us: slot_start,
                        dur_us: self.tracer.now_us()
                            .saturating_sub(slot_start),
                        kind_id: k as u64,
                        arg: crate::obs::ring::pack_module_arg(
                            gate, plan.rows_run, plan.rows_skipped),
                    });
                }
                // the gate vector is moved (not copied) into the outcome
                s_vals.push(s.into_vec());

                // ---- apply: x + alpha(c) ∘ f  (always runs; paper keeps
                // scale/shift/residual on skip steps). `f` arrives as the
                // cache slot's literal on both paths.
                let f_lit = caches.literal(k)?;
                let mut ap_args: Vec<&xla::Literal> = vec![&x_lit, &c_lit];
                ap_args.extend(self.lit.apply[l][mi].iter());
                ap_args.push(f_lit);
                let mut ap_out = self.buckets[bi].apply.call_lit(&ap_args)?;
                let new_x = ap_out.pop().unwrap().as_f32()?;
                self.pool.release(std::mem::replace(&mut x, new_x));
            }
        }

        // ---- final
        let x_lit = HostValue::f32_literal(&x)?;
        let mut fin_args: Vec<&xla::Literal> = vec![&x_lit, &c_lit];
        fin_args.extend(self.lit.final_.iter());
        let mut fin_out = self.buckets[bi].final_.call_lit(&fin_args)?;
        let eps = fin_out.pop().unwrap().as_f32()?;
        self.pool.release(x);

        Ok(StepOutcome {
            eps,
            s_vals,
            skipped,
            row_skips,
            rows_run,
            rows_skipped,
            rows_denied_cold: rows_denied,
            rows_recovered,
            skip_denied_cold,
        })
    }
}

/// Aggregate per-row gate values into one skip decision (DESIGN.md §7).
/// Allocation-free: it runs 2L times per step on every replica, so the
/// reduction streams over the live rows instead of collecting them.
/// No live rows ⇒ never skip, under every policy.
pub fn decide(policy: SkipPolicy, threshold: f32, s: &[f32], live: &[bool]) -> bool {
    debug_assert_eq!(s.len(), live.len());
    let live_rows = || s.iter().zip(live).filter(|(_, &lv)| lv).map(|(&v, _)| v);
    match policy {
        SkipPolicy::Never => false,
        SkipPolicy::Blend => false, // handled in runner (always runs)
        SkipPolicy::Mean => {
            let (mut sum, mut n) = (0.0f32, 0usize);
            for v in live_rows() {
                sum += v;
                n += 1;
            }
            n > 0 && sum / n as f32 > threshold
        }
        SkipPolicy::Majority => {
            let (mut above, mut n) = (0usize, 0usize);
            for v in live_rows() {
                if v > threshold {
                    above += 1;
                }
                n += 1;
            }
            2 * above > n // n == 0 ⇒ false
        }
        SkipPolicy::All => {
            let mut n = 0usize;
            for v in live_rows() {
                if v <= threshold {
                    return false;
                }
                n += 1;
            }
            n > 0
        }
        SkipPolicy::Any => live_rows().any(|v| v > threshold),
    }
}

/// Row-wise training blend: f_i ← (1−s_i)·f_i + s_i·cache_i (valid rows).
fn blend_rows(f: &mut Tensor, cache: &Tensor, valid: &[bool], s: &[f32]) {
    let r = f.row_len();
    for i in 0..f.dim0() {
        if !valid[i] {
            continue;
        }
        let w = s[i];
        let crow = cache.row(i);
        let frow = &mut f.row_mut(i)[..r];
        for (fv, cv) in frow.iter_mut().zip(crow) {
            *fv = (1.0 - w) * *fv + w * cv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decide_truth_table() {
        let live = vec![true, true, true];
        let s = vec![0.9, 0.9, 0.1];
        assert!(decide(SkipPolicy::Mean, 0.5, &s, &live)); // mean .63
        assert!(decide(SkipPolicy::Majority, 0.5, &s, &live)); // 2/3
        assert!(!decide(SkipPolicy::All, 0.5, &s, &live));
        assert!(decide(SkipPolicy::Any, 0.5, &s, &live));
        assert!(!decide(SkipPolicy::Never, 0.5, &s, &live));
    }

    #[test]
    fn decide_ignores_dead_rows() {
        let live = vec![true, false, false];
        let s = vec![0.1, 0.99, 0.99];
        assert!(!decide(SkipPolicy::Mean, 0.5, &s, &live));
        assert!(!decide(SkipPolicy::Any, 0.5, &s, &live));
    }

    #[test]
    fn decide_empty_live_never_skips() {
        assert!(!decide(SkipPolicy::Any, 0.5, &[0.9], &[false]));
    }

    #[test]
    fn blend_rows_math() {
        let mut f = Tensor::from_vec(&[2, 2], vec![1., 1., 2., 2.]).unwrap();
        let cache = Tensor::from_vec(&[2, 2], vec![3., 3., 4., 4.]).unwrap();
        blend_rows(&mut f, &cache, &[true, false], &[0.5, 0.5]);
        assert_eq!(f.row(0), &[2., 2.]); // blended
        assert_eq!(f.row(1), &[2., 2.]); // invalid cache: untouched
    }

    #[test]
    fn stats_accounting() {
        let outcome = StepOutcome {
            eps: Tensor::zeros(&[1]),
            s_vals: vec![vec![0.9], vec![0.1], vec![0.9], vec![0.2]],
            skipped: vec![true, false, true, false],
            row_skips: vec![1, 0, 1, 2],
            rows_run: vec![0, 1, 0, 1],
            rows_skipped: vec![1, 0, 1, 1],
            rows_denied_cold: vec![0, 1, 0, 0],
            rows_recovered: vec![0, 0, 0, 1],
            skip_denied_cold: vec![false, true, false, false],
        };
        let mut st = StepStats::default();
        st.absorb(&outcome);
        assert_eq!(st.modules_total, 4);
        assert_eq!(st.modules_skipped, 2);
        assert_eq!(st.attn_skipped, 2);
        assert_eq!(st.ffn_skipped, 0);
        assert_eq!(st.modules_denied_cold, 1);
        assert_eq!(st.attn_denied_cold, 0);
        assert_eq!(st.ffn_denied_cold, 1);
        assert!((st.lazy_ratio() - 0.5).abs() < 1e-9);
        // row-weighted: 3 skipped of 5 rows, one only row granularity
        // could recover
        assert_eq!((st.rows_run, st.rows_skipped, st.rows_recovered),
                   (2, 3, 1));
        assert!((st.row_lazy_ratio() - 0.6).abs() < 1e-9);
        // per-row bit reads: slot 3 skipped row 1, ran row 0
        assert!(!outcome.row_skipped(3, 0));
        assert!(outcome.row_skipped(3, 1));
    }

    fn dec(policy: SkipPolicy, row_granular: bool) -> DecisionCfg {
        DecisionCfg {
            policy,
            scope: LazyScope::Both,
            threshold: 0.5,
            row_granular,
        }
    }

    #[test]
    fn plan_rows_per_row_threshold() {
        // rows 0/2 above threshold, row 1 below; all caches warm
        let live = [true, true, true, false];
        let pairs = [false; 4];
        let valid = [true, true, true, false];
        let s = [0.9, 0.1, 0.8, 0.0];
        let mut mask = Vec::new();
        let p = plan_rows(dec(SkipPolicy::Mean, true), true, None, &s, &live,
                          &pairs, &valid, &mut mask);
        assert_eq!(mask, vec![true, false, true, false]);
        assert!(p.mixed());
        assert_eq!((p.rows_run, p.rows_skipped, p.rows_denied_cold),
                   (1, 2, 0));
        // recovered is the exact coupled counterfactual: a Mean
        // consensus (batch mean 0.6 > 0.5) would have skipped this
        // whole warm batch, so these 2 skips are fidelity, not recovery…
        assert_eq!(p.rows_recovered, 0);
        // …while an All consensus (row 1 at 0.1) would have run it, so
        // the same per-row mask counts both skips as recovered
        let p = plan_rows(dec(SkipPolicy::All, true), true, None, &s, &live,
                          &pairs, &valid, &mut mask);
        assert_eq!(mask, vec![true, false, true, false]);
        assert_eq!(p.rows_recovered, 2);
    }

    #[test]
    fn plan_rows_cold_row_runs_alone() {
        // every gate wants to skip, but row 1 is cold: only row 1 runs
        // (and is counted denied); its neighbors keep their skips — the
        // laziness the coupled gate loses
        let live = [true, true, true];
        let pairs = [false; 3];
        let valid = [true, false, true];
        let s = [0.9, 0.9, 0.9];
        let mut mask = Vec::new();
        let p = plan_rows(dec(SkipPolicy::Mean, true), true, None, &s, &live,
                          &pairs, &valid, &mut mask);
        assert_eq!(mask, vec![true, false, true]);
        assert_eq!((p.rows_run, p.rows_skipped, p.rows_denied_cold),
                   (1, 2, 1));
        assert_eq!(p.rows_recovered, 2,
                   "the cold row would have denied the coupled gate, so \
                    both warm skips are recovered work");
        // the coupled gate denies the whole batch on the same inputs
        let pc = plan_rows(dec(SkipPolicy::Mean, false), true, None, &s,
                           &live, &pairs, &valid, &mut mask);
        assert!(pc.all_run);
        assert_eq!((pc.rows_run, pc.rows_skipped, pc.rows_denied_cold),
                   (3, 0, 3));
    }

    #[test]
    fn plan_rows_couples_cfg_pairs() {
        // rows 0-1 are one CFG pair: row 1's low gate (or cold cache)
        // drags row 0 into the run partition with it
        let live = [true, true, true];
        let pairs = [true, false, false];
        let mut mask = Vec::new();
        let p = plan_rows(dec(SkipPolicy::Mean, true), true, None,
                          &[0.9, 0.1, 0.9], &live, &pairs,
                          &[true, true, true], &mut mask);
        assert_eq!(mask, vec![false, false, true], "gate disagreement");
        assert_eq!(p.rows_denied_cold, 0, "gate disagreement is not cold");
        let p = plan_rows(dec(SkipPolicy::Mean, true), true, None,
                          &[0.9, 0.9, 0.9], &live, &pairs,
                          &[true, false, true], &mut mask);
        assert_eq!(mask, vec![false, false, true], "partner cold");
        assert_eq!(p.rows_denied_cold, 2,
                   "both pair rows denied by the one cold cache");
        // agreeing warm pair skips together
        let p = plan_rows(dec(SkipPolicy::Mean, true), true, None,
                          &[0.9, 0.9, 0.1], &live, &pairs,
                          &[true, true, true], &mut mask);
        assert_eq!(mask, vec![true, true, false]);
        assert!(p.mixed());
    }

    #[test]
    fn plan_rows_uniform_masks_match_consensus() {
        use crate::util::propcheck::propcheck;
        // the bit-identity property: whenever the per-row gate lands on
        // a uniform mask (all live rows skip, or none do), the coupled
        // batch-consensus gate must produce the exact same mask and
        // partition counts — row granularity only ever *adds* behavior
        // on mixed masks
        propcheck(300, |g| {
            let n = g.usize_in(1, 8);
            let mut live: Vec<bool> = (0..n).map(|_| g.bool()).collect();
            live[0] = true; // the planner never sees an all-dead batch
            let valid: Vec<bool> = (0..n).map(|_| g.bool()).collect();
            let s: Vec<f32> = (0..n)
                .map(|_| if g.bool() { 0.9 } else { 0.1 })
                .collect();
            let pairs = vec![false; n];
            let policy = match g.usize_in(0, 3) {
                0 => SkipPolicy::Mean,
                1 => SkipPolicy::Majority,
                2 => SkipPolicy::All,
                _ => SkipPolicy::Any,
            };
            let forced = match g.usize_in(0, 2) {
                0 => None,
                1 => Some(false),
                _ => Some(true),
            };
            let mut mrow = Vec::new();
            let mut mcon = Vec::new();
            let p = plan_rows(dec(policy, true), true, forced, &s, &live,
                              &pairs, &valid, &mut mrow);
            if p.mixed() {
                return; // only uniform masks carry the identity claim
            }
            let c = plan_rows(dec(policy, false), true, forced, &s, &live,
                              &pairs, &valid, &mut mcon);
            assert_eq!(mrow, mcon, "uniform mask diverged from consensus \
                                    (policy {policy:?})");
            assert_eq!((p.rows_run, p.rows_skipped),
                       (c.rows_run, c.rows_skipped));
            assert_eq!(p.all_skip, c.all_skip);
        });
    }

    #[test]
    fn plan_rows_never_and_blend_run_everything() {
        let live = [true, true];
        let pairs = [false, false];
        let valid = [true, true];
        let mut mask = Vec::new();
        for policy in [SkipPolicy::Never, SkipPolicy::Blend] {
            for rg in [true, false] {
                let p = plan_rows(dec(policy, rg), true, None, &[0.9, 0.9],
                                  &live, &pairs, &valid, &mut mask);
                assert!(p.all_run, "{policy:?} rg={rg}");
                assert_eq!(mask, vec![false, false]);
                assert_eq!(p.rows_denied_cold, 0);
            }
        }
    }

    #[test]
    fn row_partition_plans_nearest_bucket() {
        let buckets = [1usize, 2, 4, 8];
        let mut part = RowPartition::default();
        // 3 run rows in an 8-wide batch → compacted to bucket 4
        let mask = [true, false, false, true, false, false, false, false];
        let live = [true, true, true, true, true, false, false, false];
        part.plan(&mask, &live, &buckets, 8);
        assert_eq!(part.bucket, 4);
        assert_eq!(part.run_idx, vec![1, 2, 4, usize::MAX]);
        assert_eq!(part.skip_idx, vec![0, 3]);
        // exact fit keeps the exact width; replanning reuses the lists
        let mask = [true, true, false, false, true, false, false, false];
        part.plan(&mask, &live, &buckets, 8);
        assert_eq!(part.bucket, 2);
        assert_eq!(part.run_idx, vec![2, 3]);
        assert_eq!(part.skip_idx, vec![0, 1, 4]);
        // never wider than the current bucket even if the set has more
        part.plan(&[false, false], &[true, true], &buckets, 2);
        assert_eq!(part.bucket, 2);
        assert_eq!(part.run_idx, vec![0, 1]);
    }

    #[test]
    fn scatter_fresh_overwrites_run_rows_only() {
        let mut c = BatchCaches::empty(1, 4, 1, 2);
        // warm every row with known bytes, memoize the literal
        let f = Tensor::from_vec(&[4, 1, 2],
                                 vec![1., 1., 2., 2., 3., 3., 4., 4.])
            .unwrap();
        let lit = HostValue::f32_literal(&f).unwrap();
        c.store_fresh(0, f, lit);
        c.valid[0] = vec![true, false, true, false];
        assert_eq!(c.conversions(), 0);
        // partial run over rows 1 and 3 (sub-batch padded to width 4)
        let sub = Tensor::from_vec(&[4, 1, 2],
                                   vec![9., 9., 8., 8., 0., 0., 0., 0.])
            .unwrap();
        c.scatter_fresh(0, &sub, &[1, 3, usize::MAX, usize::MAX]);
        assert_eq!(c.value(0).data(),
                   &[1., 1., 9., 9., 3., 3., 8., 8.]);
        assert_eq!(c.valid[0], vec![true, true, true, true],
                   "run rows rise to valid, skip rows stay valid");
        // the memo was dropped (tensor diverged) and rebuilds correctly
        let got = c.literal(0).unwrap().to_vec::<f32>().unwrap();
        assert_eq!(got, vec![1., 1., 9., 9., 3., 3., 8., 8.]);
        assert_eq!(c.conversions(), 1, "scatter must drop the stale memo");
    }

    #[test]
    fn literal_cache_write_then_skip_reuses() {
        // the tentpole invariant: consecutive literal() calls without a
        // tensor write perform exactly one conversion (steady-state
        // skips are conversion-free)
        let mut c = BatchCaches::empty(1, 2, 2, 2);
        assert_eq!(c.conversions(), 0);
        c.literal(0).unwrap();
        assert_eq!((c.conversions(), c.literal_hits()), (1, 0));
        c.literal(0).unwrap();
        c.literal(0).unwrap();
        assert_eq!((c.conversions(), c.literal_hits()), (1, 2));
        // other slots have their own memo
        c.literal(1).unwrap();
        assert_eq!(c.conversions(), 2);
    }

    #[test]
    fn literal_cache_write_invalidates() {
        let mut c = BatchCaches::empty(1, 2, 1, 2);
        c.literal(0).unwrap();
        // a row write (cache migration) drops the memo...
        c.write_row(0, 1, &[5.0, 6.0]);
        let lit = c.literal(0).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![0., 0., 5., 6.]);
        assert_eq!(c.conversions(), 2, "stale memo must not be served");
        // ...and the rebuilt memo is served from then on
        c.literal(0).unwrap();
        assert_eq!(c.conversions(), 2);
    }

    #[test]
    fn store_fresh_memoizes_without_converting() {
        let mut c = BatchCaches::empty(1, 1, 1, 2);
        let f = Tensor::from_vec(&[1, 1, 2], vec![3.0, 4.0]).unwrap();
        let lit = crate::runtime::value::HostValue::f32_literal(&f).unwrap();
        c.store_fresh(0, f, lit);
        // the run path's literal becomes the memo: the following skip
        // performs zero conversions
        let got = c.literal(0).unwrap();
        assert_eq!(got.to_vec::<f32>().unwrap(), vec![3.0, 4.0]);
        assert_eq!((c.conversions(), c.literal_hits()), (0, 1));
        assert_eq!(c.value(0).data(), &[3.0, 4.0]);
    }

    #[test]
    fn literal_memo_tracks_tensor_exactly() {
        use crate::util::propcheck::propcheck;
        // coherence property: after any interleaving of row writes,
        // fresh stores, and literal reads, literal(k) always equals a
        // from-scratch conversion of value(k)
        propcheck(60, |g| {
            let b = g.usize_in(1, 4);
            let nd = g.usize_in(1, 6);
            let mut c = BatchCaches::empty(1, b, 1, nd);
            for _ in 0..g.usize_in(1, 12) {
                match g.usize_in(0, 2) {
                    0 => {
                        let row = g.usize_in(0, b - 1);
                        let src = g.vec_f32(nd, -2.0, 2.0);
                        c.write_row(0, row, &src);
                    }
                    1 => {
                        let data = g.vec_f32(b * nd, -2.0, 2.0);
                        let f = Tensor::from_vec(&[b, 1, nd], data).unwrap();
                        let lit =
                            crate::runtime::value::HostValue::f32_literal(&f)
                                .unwrap();
                        c.store_fresh(0, f, lit);
                    }
                    _ => {
                        c.literal(0).unwrap();
                    }
                }
                let expect = c.value(0).data().to_vec();
                let got = c.literal(0).unwrap().to_vec::<f32>().unwrap();
                assert_eq!(got, expect, "memo diverged from tensor");
            }
        });
    }

    #[test]
    fn clear_row_keeps_memo() {
        let mut c = BatchCaches::empty(2, 2, 1, 2);
        c.literal(1).unwrap();
        c.valid[1][0] = true;
        c.clear_row(0);
        assert!(!c.valid[1][0]);
        c.literal(1).unwrap();
        assert_eq!(c.conversions(), 1, "validity flips are memo-neutral");
    }
}
