//! The lazy block runner — the serving hot path.
//!
//! One denoise step = embed → (per block: modgate → decide → [module|cache]
//! → apply) ×2 → final. The decision is made HERE, on the host, *before*
//! the module executable is invoked: a skip elides the whole MHSA/FFN
//! executable call, which is how the paper's laziness becomes wall-clock
//! time (DESIGN.md §2 "per-module executables").

use crate::config::{LazyScope, SkipPolicy};
use crate::model::params::{GateWeights, WeightSet};
use crate::runtime::engine_rt::{Executable, Runtime};
use crate::runtime::manifest::ManifestConfig;
use crate::runtime::value::HostValue;
use crate::tensor::Tensor;
use anyhow::{bail, Result};
use std::rc::Rc;

/// Per-module batch cache: the previous step's module outputs Y_{l,t-1}.
#[derive(Debug, Clone)]
pub struct BatchCaches {
    /// [2L] tensors of [B, N, D]; index 2l+m (m: attn=0, ffn=1).
    pub values: Vec<Tensor>,
    /// Row validity: values[k].row(i) meaningful iff valid[k][i].
    pub valid: Vec<Vec<bool>>,
}

impl BatchCaches {
    pub fn empty(depth: usize, b: usize, n: usize, d: usize) -> BatchCaches {
        BatchCaches {
            values: (0..2 * depth).map(|_| Tensor::zeros(&[b, n, d])).collect(),
            valid: vec![vec![false; b]; 2 * depth],
        }
    }
}

/// Outcome of one denoise step over a batch.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    /// Guided-model noise prediction [B, C, H, W] (pre-CFG combination).
    pub eps: Tensor,
    /// Gate values s per module per row: [2L][B].
    pub s_vals: Vec<Vec<f32>>,
    /// Whether each module invocation was skipped: [2L].
    pub skipped: Vec<bool>,
}

/// Aggregated laziness accounting (the paper's Γ, per scope).
#[derive(Debug, Clone, Default)]
pub struct StepStats {
    pub modules_total: usize,
    pub modules_skipped: usize,
    pub attn_total: usize,
    pub attn_skipped: usize,
    pub ffn_total: usize,
    pub ffn_skipped: usize,
}

impl StepStats {
    pub fn lazy_ratio(&self) -> f64 {
        self.modules_skipped as f64 / self.modules_total.max(1) as f64
    }

    pub fn absorb(&mut self, outcome: &StepOutcome) {
        for (k, &sk) in outcome.skipped.iter().enumerate() {
            self.modules_total += 1;
            let is_attn = k % 2 == 0;
            if is_attn {
                self.attn_total += 1;
            } else {
                self.ffn_total += 1;
            }
            if sk {
                self.modules_skipped += 1;
                if is_attn {
                    self.attn_skipped += 1;
                } else {
                    self.ffn_skipped += 1;
                }
            }
        }
    }
}

/// Decision controls for one step.
#[derive(Debug, Clone, Copy)]
pub struct DecisionCfg {
    pub policy: SkipPolicy,
    pub scope: LazyScope,
    pub threshold: f32,
}

/// Compiled executables for one bucket size.
struct BucketExes {
    bucket: usize,
    embed: Rc<Executable>,
    modgate: Rc<Executable>,
    attn: Rc<Executable>,
    ffn: Rc<Executable>,
    apply: Rc<Executable>,
    final_: Rc<Executable>,
}

/// Weight tensors pre-converted to XLA literals ONCE at load — the §Perf
/// optimization that removes per-call host→literal conversion of every
/// weight matrix from the hot path (EXPERIMENTS.md §Perf).
struct LitWeights {
    embed: Vec<xla::Literal>,
    /// [depth][module] -> modgate args (w_sh, b_sh, w_sc, b_sc).
    modulate: Vec<[Vec<xla::Literal>; 2]>,
    attn: Vec<Vec<xla::Literal>>,
    ffn: Vec<Vec<xla::Literal>>,
    /// [depth][module] -> (w_al, b_al).
    apply: Vec<[Vec<xla::Literal>; 2]>,
    final_: Vec<xla::Literal>,
    /// [depth][module] -> (w_g, b_g).
    gates: Vec<[(xla::Literal, xla::Literal); 2]>,
}

fn lits(vals: &[HostValue]) -> Result<Vec<xla::Literal>> {
    vals.iter().map(|v| v.to_literal()).collect()
}

impl LitWeights {
    fn build(w: &WeightSet, g: &GateWeights) -> Result<LitWeights> {
        let pair2 = |arr: &[Vec<HostValue>; 2]| -> Result<[Vec<xla::Literal>; 2]> {
            Ok([lits(&arr[0])?, lits(&arr[1])?])
        };
        Ok(LitWeights {
            embed: lits(&w.embed)?,
            modulate: w.modulate.iter().map(pair2).collect::<Result<_>>()?,
            attn: w.attn.iter().map(|v| lits(v)).collect::<Result<_>>()?,
            ffn: w.ffn.iter().map(|v| lits(v)).collect::<Result<_>>()?,
            apply: w.apply.iter().map(pair2).collect::<Result<_>>()?,
            final_: lits(&w.final_)?,
            gates: g
                .gates
                .iter()
                .map(|pair| {
                    Ok([
                        (pair[0].0.to_literal()?, pair[0].1.to_literal()?),
                        (pair[1].0.to_literal()?, pair[1].1.to_literal()?),
                    ])
                })
                .collect::<Result<_>>()?,
        })
    }
}

/// The model runner: weights + gate weights + per-bucket executables.
pub struct ModelRunner {
    rt: Rc<Runtime>,
    pub cfg: ManifestConfig,
    pub weights: WeightSet,
    pub gates: GateWeights,
    lit: LitWeights,
    buckets: Vec<BucketExes>,
}

impl ModelRunner {
    pub fn new(rt: Rc<Runtime>, cfg: ManifestConfig, theta: &[f32],
               gamma: &[f32]) -> Result<ModelRunner> {
        let weights = WeightSet::from_flat(&cfg, theta)?;
        let gates = GateWeights::from_flat(&cfg, gamma)?;
        let lit = LitWeights::build(&weights, &gates)?;
        Ok(ModelRunner { rt, cfg, weights, gates, lit, buckets: Vec::new() })
    }

    /// Same runner with laziness disabled (DDIM baseline path).
    pub fn with_disabled_gates(rt: Rc<Runtime>, cfg: ManifestConfig,
                               theta: &[f32]) -> Result<ModelRunner> {
        let weights = WeightSet::from_flat(&cfg, theta)?;
        let gates = GateWeights::disabled(&cfg);
        let lit = LitWeights::build(&weights, &gates)?;
        Ok(ModelRunner { rt, cfg, weights, gates, lit, buckets: Vec::new() })
    }

    /// Replace gate weights (penalty sweeps re-use compiled executables).
    pub fn set_gates(&mut self, gamma: &[f32]) -> Result<()> {
        self.gates = GateWeights::from_flat(&self.cfg, gamma)?;
        self.lit = LitWeights::build(&self.weights, &self.gates)?;
        Ok(())
    }

    fn bucket_exes(&mut self, b: usize) -> Result<usize> {
        if let Some(i) = self.buckets.iter().position(|be| be.bucket == b) {
            return Ok(i);
        }
        if !self.cfg.buckets.contains(&b) {
            bail!("bucket {b} not exported (have {:?})", self.cfg.buckets);
        }
        let load = |name: String| self.rt.load(&self.cfg, &name);
        let be = BucketExes {
            bucket: b,
            embed: load(format!("embed_b{b}"))?,
            modgate: load(format!("modgate_b{b}"))?,
            attn: load(format!("attn_b{b}"))?,
            ffn: load(format!("ffn_b{b}"))?,
            apply: load(format!("apply_b{b}"))?,
            final_: load(format!("final_b{b}"))?,
        };
        self.buckets.push(be);
        Ok(self.buckets.len() - 1)
    }

    /// Pre-compile all executables of a bucket (startup, not hot path).
    pub fn warmup(&mut self, bucket: usize) -> Result<()> {
        self.bucket_exes(bucket)?;
        Ok(())
    }

    /// One denoise step over a padded batch.
    ///
    /// * `z`: [B, C, H, W] latents (B == bucket size, padded rows zeros)
    /// * `t`: [B] float timesteps, `y`: [B] labels (null for uncond rows)
    /// * `live`: [B] — padding rows are false and excluded from decisions
    /// * `caches`: previous-step module outputs, updated in place
    #[allow(clippy::too_many_arguments)]
    pub fn step(&mut self, bucket: usize, z: &Tensor, t: &[f32], y: &[i32],
                live: &[bool], caches: &mut BatchCaches,
                dec: DecisionCfg) -> Result<StepOutcome> {
        self.step_with_forced(bucket, z, t, y, live, caches, dec, None)
    }

    /// `step` with an optional forced skip mask per module slot [2L] — the
    /// input-independent (Learn2Cache-analog) baseline path. A forced skip
    /// is still subject to cache availability.
    #[allow(clippy::too_many_arguments)]
    pub fn step_with_forced(&mut self, bucket: usize, z: &Tensor, t: &[f32],
                            y: &[i32], live: &[bool],
                            caches: &mut BatchCaches, dec: DecisionCfg,
                            forced: Option<&[bool]>) -> Result<StepOutcome> {
        let bi = self.bucket_exes(bucket)?;
        let depth = self.cfg.model.depth;
        let b = bucket;
        debug_assert_eq!(z.shape()[0], b);
        debug_assert_eq!(t.len(), b);

        // dynamic inputs: converted once per step (weights are pre-built
        // literals — see LitWeights)
        let t_lit = HostValue::F32(Tensor::from_vec(&[b], t.to_vec())?)
            .to_literal()?;
        let y_lit = HostValue::I32 { shape: vec![b], data: y.to_vec() }
            .to_literal()?;
        let z_lit = HostValue::F32(z.clone()).to_literal()?;

        // ---- embed
        let mut embed_args: Vec<&xla::Literal> = vec![&z_lit, &t_lit, &y_lit];
        embed_args.extend(self.lit.embed.iter());
        let mut out = self.buckets[bi].embed.call_lit(&embed_args)?;
        let c = out.pop().unwrap().as_f32()?;
        let mut x = out.pop().unwrap().as_f32()?;
        let c_lit = HostValue::F32(c).to_literal()?;

        let mut s_vals: Vec<Vec<f32>> = Vec::with_capacity(2 * depth);
        let mut skipped: Vec<bool> = Vec::with_capacity(2 * depth);

        for l in 0..depth {
            for mi in 0..2usize {
                let k = 2 * l + mi;
                let x_lit = HostValue::F32(x.clone()).to_literal()?;
                // ---- fused LN + modulate + gate
                let mut mg_args: Vec<&xla::Literal> = vec![&x_lit, &c_lit];
                mg_args.extend(self.lit.modulate[l][mi].iter());
                let (gw, gb) = &self.lit.gates[l][mi];
                mg_args.push(gw);
                mg_args.push(gb);
                let mut mg_out = self.buckets[bi].modgate.call_lit(&mg_args)?;
                let s = mg_out.pop().unwrap().as_f32()?;
                let zmod = mg_out.pop().unwrap().as_f32()?;
                let s_rows: Vec<f32> = s.data().to_vec();

                // ---- decision
                let in_scope = if mi == 0 {
                    dec.scope.covers_attn()
                } else {
                    dec.scope.covers_ffn()
                };
                let cache_ok = live
                    .iter()
                    .enumerate()
                    .filter(|(_, &lv)| lv)
                    .all(|(i, _)| caches.valid[k][i]);
                let want_skip = match forced {
                    Some(mask) => mask[k] && cache_ok,
                    None => in_scope
                        && cache_ok
                        && decide(dec.policy, dec.threshold, &s_rows, live),
                };

                let f = if want_skip && dec.policy != SkipPolicy::Blend {
                    // ---- SKIP: reuse Y_{l,t-1}; the module executable is
                    // never invoked — this is the latency win.
                    caches.values[k].clone()
                } else {
                    // ---- RUN the module
                    let zmod_lit = HostValue::F32(zmod).to_literal()?;
                    let mut m_args: Vec<&xla::Literal> = vec![&zmod_lit];
                    let (exe, warr) = if mi == 0 {
                        (&self.buckets[bi].attn, &self.lit.attn[l])
                    } else {
                        (&self.buckets[bi].ffn, &self.lit.ffn[l])
                    };
                    m_args.extend(warr.iter());
                    let mut m_out = exe.call_lit(&m_args)?;
                    let mut f = m_out.pop().unwrap().as_f32()?;
                    if dec.policy == SkipPolicy::Blend && in_scope {
                        // training-faithful blending with the cache
                        blend_rows(&mut f, &caches.values[k], &caches.valid[k],
                                   &s_rows);
                    }
                    // update cache with the fresh (possibly blended) output
                    caches.values[k] = f.clone();
                    for (i, &lv) in live.iter().enumerate() {
                        if lv {
                            caches.valid[k][i] = true;
                        }
                    }
                    f
                };
                skipped.push(want_skip && dec.policy != SkipPolicy::Blend);
                s_vals.push(s_rows);

                // ---- apply: x + alpha(c) ∘ f  (always runs; paper keeps
                // scale/shift/residual on skip steps)
                let f_lit = HostValue::F32(f).to_literal()?;
                let mut ap_args: Vec<&xla::Literal> = vec![&x_lit, &c_lit];
                ap_args.extend(self.lit.apply[l][mi].iter());
                ap_args.push(&f_lit);
                let mut ap_out = self.buckets[bi].apply.call_lit(&ap_args)?;
                x = ap_out.pop().unwrap().as_f32()?;
            }
        }

        // ---- final
        let x_lit = HostValue::F32(x).to_literal()?;
        let mut fin_args: Vec<&xla::Literal> = vec![&x_lit, &c_lit];
        fin_args.extend(self.lit.final_.iter());
        let mut fin_out = self.buckets[bi].final_.call_lit(&fin_args)?;
        let eps = fin_out.pop().unwrap().as_f32()?;

        Ok(StepOutcome { eps, s_vals, skipped })
    }
}

/// Aggregate per-row gate values into one skip decision (DESIGN.md §7).
pub fn decide(policy: SkipPolicy, threshold: f32, s: &[f32], live: &[bool]) -> bool {
    let rows: Vec<f32> = s
        .iter()
        .zip(live)
        .filter(|(_, &lv)| lv)
        .map(|(&v, _)| v)
        .collect();
    if rows.is_empty() {
        return false;
    }
    match policy {
        SkipPolicy::Never => false,
        SkipPolicy::Blend => false, // handled in runner (always runs)
        SkipPolicy::Mean => {
            rows.iter().sum::<f32>() / rows.len() as f32 > threshold
        }
        SkipPolicy::Majority => {
            let n = rows.iter().filter(|&&v| v > threshold).count();
            2 * n > rows.len()
        }
        SkipPolicy::All => rows.iter().all(|&v| v > threshold),
        SkipPolicy::Any => rows.iter().any(|&v| v > threshold),
    }
}

/// Row-wise training blend: f_i ← (1−s_i)·f_i + s_i·cache_i (valid rows).
fn blend_rows(f: &mut Tensor, cache: &Tensor, valid: &[bool], s: &[f32]) {
    let r = f.row_len();
    for i in 0..f.dim0() {
        if !valid[i] {
            continue;
        }
        let w = s[i];
        let crow = cache.row(i);
        let frow = &mut f.row_mut(i)[..r];
        for (fv, cv) in frow.iter_mut().zip(crow) {
            *fv = (1.0 - w) * *fv + w * cv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decide_truth_table() {
        let live = vec![true, true, true];
        let s = vec![0.9, 0.9, 0.1];
        assert!(decide(SkipPolicy::Mean, 0.5, &s, &live)); // mean .63
        assert!(decide(SkipPolicy::Majority, 0.5, &s, &live)); // 2/3
        assert!(!decide(SkipPolicy::All, 0.5, &s, &live));
        assert!(decide(SkipPolicy::Any, 0.5, &s, &live));
        assert!(!decide(SkipPolicy::Never, 0.5, &s, &live));
    }

    #[test]
    fn decide_ignores_dead_rows() {
        let live = vec![true, false, false];
        let s = vec![0.1, 0.99, 0.99];
        assert!(!decide(SkipPolicy::Mean, 0.5, &s, &live));
        assert!(!decide(SkipPolicy::Any, 0.5, &s, &live));
    }

    #[test]
    fn decide_empty_live_never_skips() {
        assert!(!decide(SkipPolicy::Any, 0.5, &[0.9], &[false]));
    }

    #[test]
    fn blend_rows_math() {
        let mut f = Tensor::from_vec(&[2, 2], vec![1., 1., 2., 2.]).unwrap();
        let cache = Tensor::from_vec(&[2, 2], vec![3., 3., 4., 4.]).unwrap();
        blend_rows(&mut f, &cache, &[true, false], &[0.5, 0.5]);
        assert_eq!(f.row(0), &[2., 2.]); // blended
        assert_eq!(f.row(1), &[2., 2.]); // invalid cache: untouched
    }

    #[test]
    fn stats_accounting() {
        let outcome = StepOutcome {
            eps: Tensor::zeros(&[1]),
            s_vals: vec![vec![0.9], vec![0.1], vec![0.9], vec![0.2]],
            skipped: vec![true, false, true, false],
        };
        let mut st = StepStats::default();
        st.absorb(&outcome);
        assert_eq!(st.modules_total, 4);
        assert_eq!(st.modules_skipped, 2);
        assert_eq!(st.attn_skipped, 2);
        assert_eq!(st.ffn_skipped, 0);
        assert!((st.lazy_ratio() - 0.5).abs() < 1e-9);
    }
}
