//! Weight slicing: the flat θ / γ vectors (single contiguous buffers, the
//! training interface) sliced into the per-graph argument tensors of the
//! serving executables, following the manifest offset table.

use crate::runtime::manifest::{ManifestConfig, ParamMeta};
use crate::runtime::value::HostValue;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

fn slice_param(flat: &[f32], p: &ParamMeta) -> Tensor {
    let data = flat[p.offset..p.offset + p.size].to_vec();
    let shape = if p.shape.is_empty() { vec![1] } else { p.shape.clone() };
    Tensor::from_vec(&shape, data).expect("manifest shape consistent")
}

/// All base-parameter argument tensors, pre-sliced once at load time so the
/// hot path never re-slices θ.
#[derive(Debug, Clone)]
pub struct WeightSet {
    /// embed graph args in order (w_patch..y_table).
    pub embed: Vec<HostValue>,
    /// per block, per module (attn=0, ffn=1): modgate w_sh,b_sh,w_sc,b_sc.
    pub modulate: Vec<[Vec<HostValue>; 2]>,
    /// per block: attn graph args (w_qkv,b_qkv,w_o,b_o).
    pub attn: Vec<Vec<HostValue>>,
    /// per block: ffn graph args (w1,b1,w2,b2).
    pub ffn: Vec<Vec<HostValue>>,
    /// per block, per module: apply args (w_al, b_al).
    pub apply: Vec<[Vec<HostValue>; 2]>,
    /// final graph args (w_sh,b_sh,w_sc,b_sc,w_out,b_out).
    pub final_: Vec<HostValue>,
}

impl WeightSet {
    pub fn from_flat(cfg: &ManifestConfig, theta: &[f32]) -> Result<WeightSet> {
        if theta.len() != cfg.theta_len() {
            bail!(
                "theta length {} != manifest {} — checkpoint/config mismatch",
                theta.len(),
                cfg.theta_len()
            );
        }
        let g = |name: &str| -> Result<HostValue> {
            Ok(HostValue::F32(slice_param(theta, cfg.param(name)?)))
        };
        let embed = vec![
            g("embed.patch.w")?, g("embed.patch.b")?,
            g("embed.t.w1")?, g("embed.t.b1")?,
            g("embed.t.w2")?, g("embed.t.b2")?,
            g("embed.y.table")?,
        ];
        let mut modulate = Vec::new();
        let mut attn = Vec::new();
        let mut ffn = Vec::new();
        let mut apply = Vec::new();
        for l in 0..cfg.model.depth {
            let m = |mod_: &str, suf: &str| g(&format!("block{l}.{mod_}.{suf}"));
            modulate.push([
                vec![m("attn", "w_shift")?, m("attn", "b_shift")?,
                     m("attn", "w_scale")?, m("attn", "b_scale")?],
                vec![m("ffn", "w_shift")?, m("ffn", "b_shift")?,
                     m("ffn", "w_scale")?, m("ffn", "b_scale")?],
            ]);
            attn.push(vec![
                m("attn", "w_qkv")?, m("attn", "b_qkv")?,
                m("attn", "w_o")?, m("attn", "b_o")?,
            ]);
            ffn.push(vec![
                m("ffn", "w1")?, m("ffn", "b1")?,
                m("ffn", "w2")?, m("ffn", "b2")?,
            ]);
            apply.push([
                vec![m("attn", "w_alpha")?, m("attn", "b_alpha")?],
                vec![m("ffn", "w_alpha")?, m("ffn", "b_alpha")?],
            ]);
        }
        let final_ = vec![
            g("final.w_shift")?, g("final.b_shift")?,
            g("final.w_scale")?, g("final.b_scale")?,
            g("final.w_out")?, g("final.b_out")?,
        ];
        Ok(WeightSet { embed, modulate, attn, ffn, apply, final_ })
    }
}

/// Lazy-gate weights per (layer, module), sliced from flat γ.
#[derive(Debug, Clone)]
pub struct GateWeights {
    /// [depth][module: attn=0, ffn=1] -> (w [D], b [1]).
    pub gates: Vec<[(HostValue, HostValue); 2]>,
}

impl GateWeights {
    pub fn from_flat(cfg: &ManifestConfig, gamma: &[f32]) -> Result<GateWeights> {
        if gamma.len() != cfg.gamma_len() {
            bail!(
                "gamma length {} != manifest {} — gate checkpoint mismatch",
                gamma.len(),
                cfg.gamma_len()
            );
        }
        let mut gates = Vec::new();
        for l in 0..cfg.model.depth {
            let mut pair = Vec::new();
            for mod_ in ["attn", "ffn"] {
                let w = slice_param(gamma, cfg.gate(&format!("gate{l}.{mod_}.w"))?);
                let b = slice_param(gamma, cfg.gate(&format!("gate{l}.{mod_}.b"))?);
                pair.push((HostValue::F32(w), HostValue::F32(b)));
            }
            let b = pair.pop().unwrap();
            let a = pair.pop().unwrap();
            gates.push([a, b]);
        }
        Ok(GateWeights { gates })
    }

    /// The "never lazy" gate set: w=0, b=-10 ⇒ s ≈ 4.5e-5 (always run).
    /// Used for the DDIM baseline so the identical code path executes.
    pub fn disabled(cfg: &ManifestConfig) -> GateWeights {
        let mut gamma = vec![0.0f32; cfg.gamma_len()];
        for gmeta in &cfg.gates {
            if gmeta.name.ends_with(".b") {
                gamma[gmeta.offset] = -10.0;
            }
        }
        GateWeights::from_flat(cfg, &gamma).expect("consistent")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::path::Path;

    fn manifest_cfg() -> ManifestConfig {
        // mirror of the nano manifest, hand-rolled (offsets like python's)
        let j = Json::parse(
            r#"{"configs": {"nano": {
            "paper_analog": "t",
            "model": {"img_size": 8, "channels": 3, "patch": 2, "dim": 4,
                      "depth": 1, "heads": 2, "num_classes": 2,
                      "mlp_ratio": 2, "freq_dim": 4},
            "diffusion": {"timesteps": 10, "beta_start": 1e-4, "beta_end": 0.02},
            "params": [
              {"name": "embed.patch.w", "shape": [12, 4], "offset": 0, "size": 48},
              {"name": "embed.patch.b", "shape": [4], "offset": 48, "size": 4},
              {"name": "embed.t.w1", "shape": [4, 4], "offset": 52, "size": 16},
              {"name": "embed.t.b1", "shape": [4], "offset": 68, "size": 4},
              {"name": "embed.t.w2", "shape": [4, 4], "offset": 72, "size": 16},
              {"name": "embed.t.b2", "shape": [4], "offset": 88, "size": 4},
              {"name": "embed.y.table", "shape": [3, 4], "offset": 92, "size": 12},
              {"name": "block0.attn.w_shift", "shape": [4, 4], "offset": 104, "size": 16},
              {"name": "block0.attn.b_shift", "shape": [4], "offset": 120, "size": 4},
              {"name": "block0.attn.w_scale", "shape": [4, 4], "offset": 124, "size": 16},
              {"name": "block0.attn.b_scale", "shape": [4], "offset": 140, "size": 4},
              {"name": "block0.attn.w_alpha", "shape": [4, 4], "offset": 144, "size": 16},
              {"name": "block0.attn.b_alpha", "shape": [4], "offset": 160, "size": 4},
              {"name": "block0.ffn.w_shift", "shape": [4, 4], "offset": 164, "size": 16},
              {"name": "block0.ffn.b_shift", "shape": [4], "offset": 180, "size": 4},
              {"name": "block0.ffn.w_scale", "shape": [4, 4], "offset": 184, "size": 16},
              {"name": "block0.ffn.b_scale", "shape": [4], "offset": 200, "size": 4},
              {"name": "block0.ffn.w_alpha", "shape": [4, 4], "offset": 204, "size": 16},
              {"name": "block0.ffn.b_alpha", "shape": [4], "offset": 220, "size": 4},
              {"name": "block0.attn.w_qkv", "shape": [4, 12], "offset": 224, "size": 48},
              {"name": "block0.attn.b_qkv", "shape": [12], "offset": 272, "size": 12},
              {"name": "block0.attn.w_o", "shape": [4, 4], "offset": 284, "size": 16},
              {"name": "block0.attn.b_o", "shape": [4], "offset": 300, "size": 4},
              {"name": "block0.ffn.w1", "shape": [4, 8], "offset": 304, "size": 32},
              {"name": "block0.ffn.b1", "shape": [8], "offset": 336, "size": 8},
              {"name": "block0.ffn.w2", "shape": [8, 4], "offset": 344, "size": 32},
              {"name": "block0.ffn.b2", "shape": [4], "offset": 376, "size": 4},
              {"name": "final.w_shift", "shape": [4, 4], "offset": 380, "size": 16},
              {"name": "final.b_shift", "shape": [4], "offset": 396, "size": 4},
              {"name": "final.w_scale", "shape": [4, 4], "offset": 400, "size": 16},
              {"name": "final.b_scale", "shape": [4], "offset": 416, "size": 4},
              {"name": "final.w_out", "shape": [4, 12], "offset": 420, "size": 48},
              {"name": "final.b_out", "shape": [12], "offset": 468, "size": 12}
            ],
            "gates": [
              {"name": "gate0.attn.w", "shape": [4], "offset": 0, "size": 4},
              {"name": "gate0.attn.b", "shape": [], "offset": 4, "size": 1},
              {"name": "gate0.ffn.w", "shape": [4], "offset": 5, "size": 4},
              {"name": "gate0.ffn.b", "shape": [], "offset": 9, "size": 1}
            ],
            "buckets": [1], "train_batch": 2, "graphs": {}
        }}, "feature_dim": 64}"#,
        )
        .unwrap();
        let m = crate::runtime::manifest::Manifest::from_json(Path::new("/tmp"), &j).unwrap();
        m.config("nano").unwrap().clone()
    }

    #[test]
    fn slices_all_weights() {
        let cfg = manifest_cfg();
        let theta: Vec<f32> = (0..cfg.theta_len()).map(|i| i as f32).collect();
        let w = WeightSet::from_flat(&cfg, &theta).unwrap();
        assert_eq!(w.embed.len(), 7);
        assert_eq!(w.modulate.len(), 1);
        assert_eq!(w.attn[0].len(), 4);
        // offsets respected: patch.b starts at 48
        assert_eq!(w.embed[1].as_f32_ref().unwrap().data()[0], 48.0);
        // w_qkv at offset 224
        assert_eq!(w.attn[0][0].as_f32_ref().unwrap().data()[0], 224.0);
    }

    #[test]
    fn rejects_wrong_length() {
        let cfg = manifest_cfg();
        assert!(WeightSet::from_flat(&cfg, &[0.0; 3]).is_err());
        assert!(GateWeights::from_flat(&cfg, &[0.0; 3]).is_err());
    }

    #[test]
    fn gate_slicing_and_disabled() {
        let cfg = manifest_cfg();
        let gamma: Vec<f32> = (0..cfg.gamma_len()).map(|i| i as f32 * 0.1).collect();
        let g = GateWeights::from_flat(&cfg, &gamma).unwrap();
        assert_eq!(g.gates.len(), 1);
        // scalar bias arrives as shape [1]
        assert_eq!(g.gates[0][0].1.as_f32_ref().unwrap().shape(), &[1]);
        let d = GateWeights::disabled(&cfg);
        assert_eq!(d.gates[0][0].1.as_f32_ref().unwrap().data()[0], -10.0);
        assert_eq!(d.gates[0][0].0.as_f32_ref().unwrap().data(), &[0.0; 4]);
    }
}
