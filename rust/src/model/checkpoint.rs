//! `.ldck` checkpoint format: named f32 vectors in one binary file.
//!
//! Layout (little-endian):
//!   magic   b"LDCK"
//!   version u32 (=1)
//!   count   u32
//!   entry*  { name_len u16, name utf-8, ndim u16, dims u32*, data f32* }
//!
//! Used for θ (base model), γ (gates), and optimizer state (m, v, step).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"LDCK";

/// An in-memory checkpoint: ordered name → (shape, data).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    pub entries: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl Checkpoint {
    pub fn new() -> Checkpoint {
        Checkpoint::default()
    }

    pub fn insert(&mut self, name: &str, shape: &[usize], data: Vec<f32>) {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        self.entries.insert(name.to_string(), (shape.to_vec(), data));
    }

    pub fn insert_scalar(&mut self, name: &str, v: f32) {
        self.insert(name, &[], vec![v]);
    }

    pub fn vec(&self, name: &str) -> Result<&Vec<f32>> {
        Ok(&self
            .entries
            .get(name)
            .with_context(|| format!("checkpoint missing '{name}'"))?
            .1)
    }

    pub fn scalar(&self, name: &str) -> Result<f32> {
        let v = self.vec(name)?;
        if v.len() != 1 {
            bail!("'{name}' is not a scalar");
        }
        Ok(v[0])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&1u32.to_le_bytes())?;
        w.write_all(&(self.entries.len() as u32).to_le_bytes())?;
        for (name, (shape, data)) in &self.entries {
            let nb = name.as_bytes();
            if nb.len() > u16::MAX as usize {
                bail!("name too long");
            }
            w.write_all(&(nb.len() as u16).to_le_bytes())?;
            w.write_all(nb)?;
            w.write_all(&(shape.len() as u16).to_le_bytes())?;
            for &d in shape {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            for &v in data {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("opening checkpoint {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not an .ldck checkpoint", path.display());
        }
        let version = read_u32(&mut r)?;
        if version != 1 {
            bail!("unsupported checkpoint version {version}");
        }
        let count = read_u32(&mut r)? as usize;
        let mut out = Checkpoint::new();
        for _ in 0..count {
            let name_len = read_u16(&mut r)? as usize;
            let mut nb = vec![0u8; name_len];
            r.read_exact(&mut nb)?;
            let name = String::from_utf8(nb).context("checkpoint name utf8")?;
            let ndim = read_u16(&mut r)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut r)? as usize);
            }
            let n: usize = shape.iter().product();
            let mut bytes = vec![0u8; n * 4];
            r.read_exact(&mut bytes)?;
            let data = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            out.entries.insert(name, (shape, data));
        }
        Ok(out)
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

/// Standard checkpoint paths under the run directory.
pub fn theta_path(dir: &Path, config: &str) -> std::path::PathBuf {
    dir.join(format!("{config}.theta.ldck"))
}

pub fn gates_path(dir: &Path, config: &str, tag: &str) -> std::path::PathBuf {
    dir.join(format!("{config}.gates.{tag}.ldck"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("lazydit_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut c = Checkpoint::new();
        c.insert("theta", &[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        c.insert_scalar("step", 42.0);
        let p = tmp("rt.ldck");
        c.save(&p).unwrap();
        let d = Checkpoint::load(&p).unwrap();
        assert_eq!(c, d);
        assert_eq!(d.scalar("step").unwrap(), 42.0);
        assert_eq!(d.vec("theta").unwrap().len(), 6);
    }

    #[test]
    fn missing_entry_errors() {
        let c = Checkpoint::new();
        assert!(c.vec("nope").is_err());
    }

    #[test]
    fn rejects_non_checkpoint() {
        let p = tmp("garbage.ldck");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn empty_roundtrip() {
        let c = Checkpoint::new();
        let p = tmp("empty.ldck");
        c.save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap(), c);
    }
}
