//! Configuration types. `ModelConfig`/`DiffusionConfig` are parsed from
//! `artifacts/manifest.json` (single source of truth = python/compile/
//! configs.py); serve/train/bench configs are CLI- or JSON-loadable.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Architecture hyper-parameters of one exported model config.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub paper_analog: String,
    pub img_size: usize,
    pub channels: usize,
    pub patch: usize,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub num_classes: usize,
    pub mlp_ratio: usize,
    pub freq_dim: usize,
}

impl ModelConfig {
    pub fn tokens(&self) -> usize {
        let side = self.img_size / self.patch;
        side * side
    }

    pub fn patch_dim(&self) -> usize {
        self.patch * self.patch * self.channels
    }

    pub fn hidden(&self) -> usize {
        self.dim * self.mlp_ratio
    }

    pub fn img_elems(&self) -> usize {
        self.channels * self.img_size * self.img_size
    }

    /// The CFG null-label id.
    pub fn null_label(&self) -> usize {
        self.num_classes
    }

    pub fn from_json(name: &str, j: &Json) -> Result<ModelConfig> {
        let m = j.req("model")?;
        let g = |k: &str| -> Result<usize> {
            m.req(k)?
                .as_usize()
                .with_context(|| format!("model.{k} not a number"))
        };
        Ok(ModelConfig {
            name: name.to_string(),
            paper_analog: j
                .get("paper_analog")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            img_size: g("img_size")?,
            channels: g("channels")?,
            patch: g("patch")?,
            dim: g("dim")?,
            depth: g("depth")?,
            heads: g("heads")?,
            num_classes: g("num_classes")?,
            mlp_ratio: g("mlp_ratio")?,
            freq_dim: g("freq_dim")?,
        })
    }
}

/// Diffusion-process constants (must match python/compile/diffusion.py).
#[derive(Debug, Clone, PartialEq)]
pub struct DiffusionConfig {
    pub timesteps: usize,
    pub beta_start: f32,
    pub beta_end: f32,
}

impl DiffusionConfig {
    pub fn from_json(j: &Json) -> Result<DiffusionConfig> {
        let d = j.req("diffusion")?;
        Ok(DiffusionConfig {
            timesteps: d.req("timesteps")?.as_usize().context("timesteps")?,
            beta_start: d.req("beta_start")?.as_f64().context("beta_start")? as f32,
            beta_end: d.req("beta_end")?.as_f64().context("beta_end")? as f32,
        })
    }
}

/// How the coordinator aggregates per-row gate decisions when a batch
/// shares one module invocation (DESIGN.md §7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SkipPolicy {
    /// Skip iff the mean gate value over live rows exceeds 0.5.
    Mean,
    /// Skip iff a strict majority of live rows wants to skip.
    Majority,
    /// Skip iff every live row wants to skip (conservative).
    All,
    /// Skip iff any live row wants to skip (aggressive).
    Any,
    /// Never skip — the DDIM baseline path.
    Never,
    /// Training-faithful: always run the module, blend with cache by s.
    Blend,
}

impl SkipPolicy {
    pub fn parse(s: &str) -> Result<SkipPolicy> {
        Ok(match s {
            "mean" => SkipPolicy::Mean,
            "majority" => SkipPolicy::Majority,
            "all" => SkipPolicy::All,
            "any" => SkipPolicy::Any,
            "never" => SkipPolicy::Never,
            "blend" => SkipPolicy::Blend,
            _ => bail!("unknown skip policy '{s}' (mean|majority|all|any|never|blend)"),
        })
    }

    /// Stable lowercase label (inverse of `parse`); used for the pool
    /// A/B report so a variant rename can't silently change the wire.
    pub fn name(&self) -> &'static str {
        match self {
            SkipPolicy::Mean => "mean",
            SkipPolicy::Majority => "majority",
            SkipPolicy::All => "all",
            SkipPolicy::Any => "any",
            SkipPolicy::Never => "never",
            SkipPolicy::Blend => "blend",
        }
    }
}

/// Which modules laziness applies to (paper Fig. 6 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LazyScope {
    Both,
    AttnOnly,
    FfnOnly,
    None,
}

impl LazyScope {
    pub fn parse(s: &str) -> Result<LazyScope> {
        Ok(match s {
            "both" => LazyScope::Both,
            "attn" => LazyScope::AttnOnly,
            "ffn" => LazyScope::FfnOnly,
            "none" => LazyScope::None,
            _ => bail!("unknown lazy scope '{s}' (both|attn|ffn|none)"),
        })
    }

    pub fn covers_attn(&self) -> bool {
        matches!(self, LazyScope::Both | LazyScope::AttnOnly)
    }

    pub fn covers_ffn(&self) -> bool {
        matches!(self, LazyScope::Both | LazyScope::FfnOnly)
    }
}

/// How the replica-pool router picks a replica for a new request
/// (coordinator::pool::router).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Rotate across replicas regardless of load.
    RoundRobin,
    /// Join-shortest-queue: fewest admitted-but-unfinished requests.
    Jsq,
    /// Lazy-aware: fewest queued remaining denoise steps, discounted by
    /// the replica's observed lazy ratio Γ (a lazier replica clears its
    /// backlog faster, so its effective backlog is smaller).
    Lazy,
}

impl RoutePolicy {
    pub fn parse(s: &str) -> Result<RoutePolicy> {
        Ok(match s {
            "rr" | "round-robin" => RoutePolicy::RoundRobin,
            "jsq" => RoutePolicy::Jsq,
            "lazy" => RoutePolicy::Lazy,
            _ => bail!("unknown route policy '{s}' (rr|jsq|lazy)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "rr",
            RoutePolicy::Jsq => "jsq",
            RoutePolicy::Lazy => "lazy",
        }
    }
}

/// Serving-engine configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub config_name: String,
    pub max_batch: usize,
    pub queue_cap: usize,
    pub cfg_scale: f32,
    pub policy: SkipPolicy,
    pub scope: LazyScope,
    pub threads: usize,
    /// Gate threshold (paper uses 0.5).
    pub threshold: f32,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            config_name: "xl-256a".into(),
            max_batch: 8,
            queue_cap: 256,
            cfg_scale: 1.5,
            policy: SkipPolicy::Mean,
            scope: LazyScope::Both,
            threads: 1,
            threshold: 0.5,
        }
    }
}

/// Training-driver configuration (pretrain and lazy-learning phases).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub config_name: String,
    pub steps: usize,
    pub batch: usize,
    pub lr: f32,
    pub seed: u64,
    /// CFG label-dropout probability during pretraining.
    pub label_dropout: f32,
    /// Lazy-learning penalties ρ_attn / ρ_ffn (paper Eq. 5).
    pub rho_attn: f32,
    pub rho_ffn: f32,
    /// Gap between t and t_prev for cache construction, as a fraction of
    /// T/steps for the sampling grid the gates will serve.
    pub cache_stride: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            config_name: "xl-256a".into(),
            steps: 500,
            batch: 32,
            lr: 1e-4,
            seed: 0,
            label_dropout: 0.1,
            rho_attn: 1e-3,
            rho_ffn: 1e-3,
            cache_stride: 20,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(
            r#"{
            "paper_analog": "DiT-XL/2 256",
            "model": {"img_size": 8, "channels": 3, "patch": 2, "dim": 96,
                      "depth": 6, "heads": 6, "num_classes": 10,
                      "mlp_ratio": 4, "freq_dim": 128, "tokens": 16,
                      "patch_dim": 12},
            "diffusion": {"timesteps": 1000, "beta_start": 1e-4,
                          "beta_end": 0.02}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_model_config() {
        let j = sample_json();
        let c = ModelConfig::from_json("xl-256a", &j).unwrap();
        assert_eq!(c.dim, 96);
        assert_eq!(c.tokens(), 16);
        assert_eq!(c.patch_dim(), 12);
        assert_eq!(c.hidden(), 384);
        assert_eq!(c.null_label(), 10);
    }

    #[test]
    fn parses_diffusion_config() {
        let j = sample_json();
        let d = DiffusionConfig::from_json(&j).unwrap();
        assert_eq!(d.timesteps, 1000);
        assert!((d.beta_end - 0.02).abs() < 1e-9);
    }

    #[test]
    fn missing_key_errors() {
        let j = Json::parse(r#"{"model": {"img_size": 8}}"#).unwrap();
        assert!(ModelConfig::from_json("x", &j).is_err());
    }

    #[test]
    fn policy_parse() {
        assert_eq!(SkipPolicy::parse("mean").unwrap(), SkipPolicy::Mean);
        assert_eq!(SkipPolicy::parse("blend").unwrap(), SkipPolicy::Blend);
        assert!(SkipPolicy::parse("bogus").is_err());
    }

    #[test]
    fn policy_name_roundtrips_through_parse() {
        for p in [
            SkipPolicy::Mean,
            SkipPolicy::Majority,
            SkipPolicy::All,
            SkipPolicy::Any,
            SkipPolicy::Never,
            SkipPolicy::Blend,
        ] {
            assert_eq!(SkipPolicy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn route_parse() {
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(
            RoutePolicy::parse("round-robin").unwrap(),
            RoutePolicy::RoundRobin
        );
        assert_eq!(RoutePolicy::parse("jsq").unwrap(), RoutePolicy::Jsq);
        assert_eq!(RoutePolicy::parse("lazy").unwrap(), RoutePolicy::Lazy);
        assert!(RoutePolicy::parse("hash").is_err());
        assert_eq!(RoutePolicy::Lazy.name(), "lazy");
    }

    #[test]
    fn scope_covers() {
        assert!(LazyScope::Both.covers_attn() && LazyScope::Both.covers_ffn());
        assert!(LazyScope::AttnOnly.covers_attn() && !LazyScope::AttnOnly.covers_ffn());
        assert!(!LazyScope::None.covers_attn());
    }
}
