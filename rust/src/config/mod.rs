//! Configuration types. `ModelConfig`/`DiffusionConfig` are parsed from
//! `artifacts/manifest.json` (single source of truth = python/compile/
//! configs.py); serve/train/bench configs are CLI- or JSON-loadable.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Architecture hyper-parameters of one exported model config.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub paper_analog: String,
    pub img_size: usize,
    pub channels: usize,
    pub patch: usize,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub num_classes: usize,
    pub mlp_ratio: usize,
    pub freq_dim: usize,
}

impl ModelConfig {
    pub fn tokens(&self) -> usize {
        let side = self.img_size / self.patch;
        side * side
    }

    pub fn patch_dim(&self) -> usize {
        self.patch * self.patch * self.channels
    }

    pub fn hidden(&self) -> usize {
        self.dim * self.mlp_ratio
    }

    pub fn img_elems(&self) -> usize {
        self.channels * self.img_size * self.img_size
    }

    /// The CFG null-label id.
    pub fn null_label(&self) -> usize {
        self.num_classes
    }

    pub fn from_json(name: &str, j: &Json) -> Result<ModelConfig> {
        let m = j.req("model")?;
        let g = |k: &str| -> Result<usize> {
            m.req(k)?
                .as_usize()
                .with_context(|| format!("model.{k} not a number"))
        };
        Ok(ModelConfig {
            name: name.to_string(),
            paper_analog: j
                .get("paper_analog")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            img_size: g("img_size")?,
            channels: g("channels")?,
            patch: g("patch")?,
            dim: g("dim")?,
            depth: g("depth")?,
            heads: g("heads")?,
            num_classes: g("num_classes")?,
            mlp_ratio: g("mlp_ratio")?,
            freq_dim: g("freq_dim")?,
        })
    }
}

/// Diffusion-process constants (must match python/compile/diffusion.py).
#[derive(Debug, Clone, PartialEq)]
pub struct DiffusionConfig {
    pub timesteps: usize,
    pub beta_start: f32,
    pub beta_end: f32,
}

impl DiffusionConfig {
    pub fn from_json(j: &Json) -> Result<DiffusionConfig> {
        let d = j.req("diffusion")?;
        Ok(DiffusionConfig {
            timesteps: d.req("timesteps")?.as_usize().context("timesteps")?,
            beta_start: d.req("beta_start")?.as_f64().context("beta_start")? as f32,
            beta_end: d.req("beta_end")?.as_f64().context("beta_end")? as f32,
        })
    }
}

/// How the coordinator aggregates per-row gate decisions when a batch
/// shares one module invocation (DESIGN.md §7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SkipPolicy {
    /// Skip iff the mean gate value over live rows exceeds 0.5.
    Mean,
    /// Skip iff a strict majority of live rows wants to skip.
    Majority,
    /// Skip iff every live row wants to skip (conservative).
    All,
    /// Skip iff any live row wants to skip (aggressive).
    Any,
    /// Never skip — the DDIM baseline path.
    Never,
    /// Training-faithful: always run the module, blend with cache by s.
    Blend,
}

impl SkipPolicy {
    pub fn parse(s: &str) -> Result<SkipPolicy> {
        Ok(match s {
            "mean" => SkipPolicy::Mean,
            "majority" => SkipPolicy::Majority,
            "all" => SkipPolicy::All,
            "any" => SkipPolicy::Any,
            "never" => SkipPolicy::Never,
            "blend" => SkipPolicy::Blend,
            _ => bail!("unknown skip policy '{s}' (mean|majority|all|any|never|blend)"),
        })
    }

    /// Stable lowercase label (inverse of `parse`); used for the pool
    /// A/B report so a variant rename can't silently change the wire.
    pub fn name(&self) -> &'static str {
        match self {
            SkipPolicy::Mean => "mean",
            SkipPolicy::Majority => "majority",
            SkipPolicy::All => "all",
            SkipPolicy::Any => "any",
            SkipPolicy::Never => "never",
            SkipPolicy::Blend => "blend",
        }
    }
}

/// Which modules laziness applies to (paper Fig. 6 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LazyScope {
    Both,
    AttnOnly,
    FfnOnly,
    None,
}

impl LazyScope {
    pub fn parse(s: &str) -> Result<LazyScope> {
        Ok(match s {
            "both" => LazyScope::Both,
            "attn" => LazyScope::AttnOnly,
            "ffn" => LazyScope::FfnOnly,
            "none" => LazyScope::None,
            _ => bail!("unknown lazy scope '{s}' (both|attn|ffn|none)"),
        })
    }

    pub fn covers_attn(&self) -> bool {
        matches!(self, LazyScope::Both | LazyScope::AttnOnly)
    }

    pub fn covers_ffn(&self) -> bool {
        matches!(self, LazyScope::Both | LazyScope::FfnOnly)
    }
}

/// Request service-level-objective class, carried on the wire
/// (`"slo"` field, optional) and used by the replica-pool router for
/// tier-aware placement (coordinator::pool::router).
///
/// LazyDiT makes per-request cost dynamic — a replica's effective
/// throughput depends on its observed lazy ratio Γ — so one batch/bucket
/// configuration cannot serve both a latency budget and bulk throughput
/// well. The pool therefore provisions replicas per tier and routes each
/// request to the tier whose configuration matches its objective.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Slo {
    /// Minimize completion latency: prefer small-batch replicas with the
    /// lowest lazy-discounted backlog `pending_steps · (1 − Γ)`.
    Latency,
    /// Maximize throughput: prefer large-bucket replicas that amortize
    /// each model invocation over many lanes.
    Throughput,
    /// No stated objective (the wire default): runs on any replica under
    /// the pool's configured route policy.
    #[default]
    Besteffort,
}

impl Slo {
    /// Number of SLO classes (per-tier counter arrays are `[T; COUNT]`).
    pub const COUNT: usize = 3;

    /// Every class, in `index()` order.
    pub const ALL: [Slo; Slo::COUNT] =
        [Slo::Latency, Slo::Throughput, Slo::Besteffort];

    /// Parse a wire/CLI spelling (`latency`/`lat`, `throughput`/`thr`,
    /// `besteffort`/`be`).
    pub fn parse(s: &str) -> Result<Slo> {
        Ok(match s.trim() {
            "latency" | "lat" => Slo::Latency,
            "throughput" | "thr" => Slo::Throughput,
            "besteffort" | "be" => Slo::Besteffort,
            _ => bail!(
                "unknown SLO class '{s}' (latency|throughput|besteffort)"
            ),
        })
    }

    /// Canonical wire spelling.
    pub fn name(&self) -> &'static str {
        match self {
            Slo::Latency => "latency",
            Slo::Throughput => "throughput",
            Slo::Besteffort => "besteffort",
        }
    }

    /// Stable index for per-tier counter arrays (`ALL[index()] == self`).
    pub fn index(&self) -> usize {
        match self {
            Slo::Latency => 0,
            Slo::Throughput => 1,
            Slo::Besteffort => 2,
        }
    }

    /// Can a replica provisioned for tier `self` honor a request of
    /// class `req`? Best-effort replicas serve everything and
    /// best-effort requests run anywhere; otherwise the classes must
    /// match — a B1 latency replica must not strand its headroom on a
    /// bulk job, and a deep-batch throughput replica cannot honor a
    /// latency budget. Enforced both at dispatch (candidate generation)
    /// and at steal time (a thief never pulls a job its own tier cannot
    /// honor).
    pub fn serves(&self, req: Slo) -> bool {
        *self == Slo::Besteffort || req == Slo::Besteffort || *self == req
    }
}

/// How the replica-pool router picks a replica for a new request
/// (coordinator::pool::router).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Rotate across replicas regardless of load.
    RoundRobin,
    /// Join-shortest-queue: fewest admitted-but-unfinished requests.
    Jsq,
    /// Lazy-aware: fewest queued remaining denoise steps, discounted by
    /// the replica's observed lazy ratio Γ (a lazier replica clears its
    /// backlog faster, so its effective backlog is smaller).
    Lazy,
}

impl RoutePolicy {
    pub fn parse(s: &str) -> Result<RoutePolicy> {
        Ok(match s {
            "rr" | "round-robin" => RoutePolicy::RoundRobin,
            "jsq" => RoutePolicy::Jsq,
            "lazy" => RoutePolicy::Lazy,
            _ => bail!("unknown route policy '{s}' (rr|jsq|lazy)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "rr",
            RoutePolicy::Jsq => "jsq",
            RoutePolicy::Lazy => "lazy",
        }
    }
}

/// Serving-engine configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub config_name: String,
    pub max_batch: usize,
    pub queue_cap: usize,
    pub cfg_scale: f32,
    pub policy: SkipPolicy,
    pub scope: LazyScope,
    pub threads: usize,
    /// Gate threshold (paper uses 0.5).
    pub threshold: f32,
    /// Row-granular lazy gating (the default): each live batch row
    /// decides its own skips and mixed slots run a compacted run-rows
    /// sub-batch while skip-rows are served from cache. `false`
    /// restores the legacy all-or-nothing batch-consensus gate
    /// (`serve --coupled-gate`), kept for A/B against the coupled
    /// baseline.
    pub row_granular: bool,
    /// Per-replica bucket-set restriction (SLO-tiered pools): the
    /// engine plans rounds only against compiled buckets that are also
    /// in this set. `None` (the default) uses the full compiled set.
    /// A restriction can only narrow — every bucket size is backed by
    /// an AOT-compiled executable, so unknown sizes are ignored.
    pub bucket_override: Option<Vec<usize>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            config_name: "xl-256a".into(),
            max_batch: 8,
            queue_cap: 256,
            cfg_scale: 1.5,
            policy: SkipPolicy::Mean,
            scope: LazyScope::Both,
            threads: 1,
            threshold: 0.5,
            row_granular: true,
            bucket_override: None,
        }
    }
}

/// Training-driver configuration (pretrain and lazy-learning phases).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub config_name: String,
    pub steps: usize,
    pub batch: usize,
    pub lr: f32,
    pub seed: u64,
    /// CFG label-dropout probability during pretraining.
    pub label_dropout: f32,
    /// Lazy-learning penalties ρ_attn / ρ_ffn (paper Eq. 5).
    pub rho_attn: f32,
    pub rho_ffn: f32,
    /// Gap between t and t_prev for cache construction, as a fraction of
    /// T/steps for the sampling grid the gates will serve.
    pub cache_stride: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            config_name: "xl-256a".into(),
            steps: 500,
            batch: 32,
            lr: 1e-4,
            seed: 0,
            label_dropout: 0.1,
            rho_attn: 1e-3,
            rho_ffn: 1e-3,
            cache_stride: 20,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(
            r#"{
            "paper_analog": "DiT-XL/2 256",
            "model": {"img_size": 8, "channels": 3, "patch": 2, "dim": 96,
                      "depth": 6, "heads": 6, "num_classes": 10,
                      "mlp_ratio": 4, "freq_dim": 128, "tokens": 16,
                      "patch_dim": 12},
            "diffusion": {"timesteps": 1000, "beta_start": 1e-4,
                          "beta_end": 0.02}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_model_config() {
        let j = sample_json();
        let c = ModelConfig::from_json("xl-256a", &j).unwrap();
        assert_eq!(c.dim, 96);
        assert_eq!(c.tokens(), 16);
        assert_eq!(c.patch_dim(), 12);
        assert_eq!(c.hidden(), 384);
        assert_eq!(c.null_label(), 10);
    }

    #[test]
    fn parses_diffusion_config() {
        let j = sample_json();
        let d = DiffusionConfig::from_json(&j).unwrap();
        assert_eq!(d.timesteps, 1000);
        assert!((d.beta_end - 0.02).abs() < 1e-9);
    }

    #[test]
    fn missing_key_errors() {
        let j = Json::parse(r#"{"model": {"img_size": 8}}"#).unwrap();
        assert!(ModelConfig::from_json("x", &j).is_err());
    }

    #[test]
    fn policy_parse() {
        assert_eq!(SkipPolicy::parse("mean").unwrap(), SkipPolicy::Mean);
        assert_eq!(SkipPolicy::parse("blend").unwrap(), SkipPolicy::Blend);
        assert!(SkipPolicy::parse("bogus").is_err());
    }

    #[test]
    fn policy_name_roundtrips_through_parse() {
        for p in [
            SkipPolicy::Mean,
            SkipPolicy::Majority,
            SkipPolicy::All,
            SkipPolicy::Any,
            SkipPolicy::Never,
            SkipPolicy::Blend,
        ] {
            assert_eq!(SkipPolicy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn route_parse() {
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(
            RoutePolicy::parse("round-robin").unwrap(),
            RoutePolicy::RoundRobin
        );
        assert_eq!(RoutePolicy::parse("jsq").unwrap(), RoutePolicy::Jsq);
        assert_eq!(RoutePolicy::parse("lazy").unwrap(), RoutePolicy::Lazy);
        assert!(RoutePolicy::parse("hash").is_err());
        assert_eq!(RoutePolicy::Lazy.name(), "lazy");
    }

    #[test]
    fn slo_parse_roundtrip_and_index() {
        for slo in Slo::ALL {
            assert_eq!(Slo::parse(slo.name()).unwrap(), slo);
            assert_eq!(Slo::ALL[slo.index()], slo);
        }
        assert_eq!(Slo::parse("lat").unwrap(), Slo::Latency);
        assert_eq!(Slo::parse("thr").unwrap(), Slo::Throughput);
        assert_eq!(Slo::parse("be").unwrap(), Slo::Besteffort);
        assert_eq!(Slo::parse(" latency ").unwrap(), Slo::Latency);
        assert!(Slo::parse("gold").is_err());
        assert!(Slo::parse("").is_err());
        assert_eq!(Slo::default(), Slo::Besteffort, "wire default");
    }

    #[test]
    fn slo_compatibility_matrix() {
        // best-effort replicas serve everything; best-effort requests run
        // anywhere; latency and throughput never cross
        for req in Slo::ALL {
            assert!(Slo::Besteffort.serves(req));
        }
        for tier in Slo::ALL {
            assert!(tier.serves(Slo::Besteffort));
        }
        assert!(Slo::Latency.serves(Slo::Latency));
        assert!(Slo::Throughput.serves(Slo::Throughput));
        assert!(!Slo::Latency.serves(Slo::Throughput),
                "a B1 latency replica must not take bulk jobs");
        assert!(!Slo::Throughput.serves(Slo::Latency),
                "a deep-batch replica cannot honor a latency budget");
    }

    #[test]
    fn scope_covers() {
        assert!(LazyScope::Both.covers_attn() && LazyScope::Both.covers_ffn());
        assert!(LazyScope::AttnOnly.covers_attn() && !LazyScope::AttnOnly.covers_ffn());
        assert!(!LazyScope::None.covers_attn());
    }
}
