//! Lazy-learning driver (paper Sec. 3.3 + "Penalty Regulation").
//!
//! θ stays frozen; the gate vector γ is trained for ~500 steps with the
//! combined diffusion + lazy loss. Caches for the training forward come
//! from a gate-free forward at the *preceding sampling-grid timestep*
//! (t_prev > t on the DDIM grid the gates will serve), matching inference.
//!
//! ρ regulation: the paper sweeps ρ ∈ [1e-7, 1e-2] by hand; we expose both
//! a fixed-ρ mode (Fig. 5 sweeps) and an adaptive controller that
//! multiplicatively adjusts ρ every `adjust_every` steps to steer the
//! train-time skip fraction toward `target_ratio` (Tables 1/2/5).

use crate::config::{LazyScope, TrainConfig};
use crate::data::synth::SynthBlobs;
use crate::model::checkpoint::{gates_path, Checkpoint};
use crate::runtime::engine_rt::Runtime;
use crate::runtime::manifest::ManifestConfig;
use crate::runtime::value::HostValue;
use crate::sampler::schedule::Schedule;
use crate::tensor::Tensor;
use crate::util::prng::Rng;
use anyhow::{Context, Result};
use std::path::Path;
use std::rc::Rc;

/// Options specific to the lazy-learning phase.
#[derive(Debug, Clone)]
pub struct LazyTrainOptions {
    /// Sampling grid (number of DDIM steps) the gates will serve.
    pub serve_steps: usize,
    /// Adaptive targets for the per-module skip fraction; None = fixed ρ
    /// for that module. Separate targets support the paper's Fig. 5
    /// "Lazy Strategy" ablation (fix one module, sweep the other).
    pub target_attn: Option<f64>,
    pub target_ffn: Option<f64>,
    /// Which modules get laziness (Fig. 5 "Individual Laziness").
    pub scope: LazyScope,
    /// Checkpoint tag, e.g. "s20-r50".
    pub tag: String,
    pub adjust_every: usize,
}

impl Default for LazyTrainOptions {
    fn default() -> Self {
        LazyTrainOptions {
            serve_steps: 20,
            target_attn: Some(0.5),
            target_ffn: Some(0.5),
            scope: LazyScope::Both,
            tag: "default".into(),
            adjust_every: 10,
        }
    }
}

/// Summary of a lazy-learning run.
#[derive(Debug, Clone)]
pub struct LazyTrainReport {
    pub steps: usize,
    pub final_rho_attn: f32,
    pub final_rho_ffn: f32,
    pub final_frac_attn: f32,
    pub final_frac_ffn: f32,
    pub final_dloss: f32,
    pub mean_s_attn: f32,
    pub mean_s_ffn: f32,
    pub wall_s: f64,
}

/// γ init: w = 0, b = bias (sigmoid(bias) starting gate value).
pub fn init_gamma(cfg: &ManifestConfig, bias: f32) -> Vec<f32> {
    let mut gamma = vec![0.0f32; cfg.gamma_len()];
    for g in &cfg.gates {
        if g.name.ends_with(".b") {
            gamma[g.offset] = bias;
        }
    }
    gamma
}

/// Train gates; saves γ to `<ckpt>/<config>.gates.<tag>.ldck`.
#[allow(clippy::too_many_arguments)]
pub fn lazy_train(rt: &Rc<Runtime>, cfg: &ManifestConfig, tc: &TrainConfig,
                  opts: &LazyTrainOptions, theta: &[f32], ckpt_dir: &Path)
                  -> Result<LazyTrainReport> {
    let start = std::time::Instant::now();
    let m = &cfg.model;
    let b = cfg.train_batch;
    let ds = SynthBlobs::new(m.img_size);
    let mut rng = Rng::new(tc.seed ^ 0x1A2_7781);

    let mut gamma = init_gamma(cfg, -2.0);
    let glen = gamma.len();
    let mut mvec = vec![0.0f32; glen];
    let mut vvec = vec![0.0f32; glen];

    let step_exe = rt.load(cfg, "train_step")?;
    let schedule = Schedule::linear(cfg.diffusion.timesteps,
                                    cfg.diffusion.beta_start,
                                    cfg.diffusion.beta_end);
    // the serving DDIM grid, descending; consecutive grid entries define
    // (t_prev, t) pairs exactly as inference will see them
    let grid = schedule.ddim_timesteps(opts.serve_steps);
    let img = m.img_elems();

    let (mut rho_a, mut rho_f) = match opts.scope {
        LazyScope::Both => (tc.rho_attn, tc.rho_ffn),
        LazyScope::AttnOnly => (tc.rho_attn, 0.0),
        LazyScope::FfnOnly => (0.0, tc.rho_ffn),
        LazyScope::None => (0.0, 0.0),
    };

    let (mut dl, mut sa, mut sf, mut fa, mut ff) =
        (0f32, 0f32, 0f32, 0f32, 0f32);
    let theta_t = Tensor::from_vec(&[theta.len()], theta.to_vec())?;

    for step in 0..tc.steps {
        let (x0, mut labels) = ds.sample_batch(&mut rng, b);
        for l in labels.iter_mut() {
            if rng.uniform() < tc.label_dropout {
                *l = m.null_label();
            }
        }
        let y: Vec<i32> = labels.iter().map(|&l| l as i32).collect();
        // sample a position ≥1 in the serving grid: t = grid[i] with the
        // noisier predecessor t_prev = grid[i-1]
        let mut t = Vec::with_capacity(b);
        let mut t_prev = Vec::with_capacity(b);
        for _ in 0..b {
            let i = 1 + rng.below(grid.len().saturating_sub(1).max(1));
            let i = i.min(grid.len() - 1);
            t.push(grid[i] as i32);
            t_prev.push(grid[i - 1] as i32);
        }
        let mut noise = vec![0.0f32; b * img];
        rng.fill_normal(&mut noise);

        let args = vec![
            HostValue::F32(theta_t.clone()),
            HostValue::F32(Tensor::from_vec(&[glen], gamma)?),
            HostValue::F32(Tensor::from_vec(&[glen], mvec)?),
            HostValue::F32(Tensor::from_vec(&[glen], vvec)?),
            HostValue::scalar_f32((step + 1) as f32),
            HostValue::F32(x0),
            HostValue::I32 { shape: vec![b], data: y },
            HostValue::I32 { shape: vec![b], data: t },
            HostValue::I32 { shape: vec![b], data: t_prev },
            HostValue::F32(Tensor::from_vec(
                &[b, m.channels, m.img_size, m.img_size], noise)?),
            HostValue::scalar_f32(tc.lr),
            HostValue::scalar_f32(rho_a),
            HostValue::scalar_f32(rho_f),
        ];
        let mut out = step_exe.call(&args)?;
        ff = out.pop().context("frac_ffn")?.as_f32()?.data()[0];
        fa = out.pop().context("frac_attn")?.as_f32()?.data()[0];
        sf = out.pop().context("s_ffn")?.as_f32()?.data()[0];
        sa = out.pop().context("s_attn")?.as_f32()?.data()[0];
        let _lazyloss = out.pop().context("lazyloss")?;
        dl = out.pop().context("dloss")?.as_f32()?.data()[0];
        vvec = out.pop().context("v")?.as_f32()?.into_vec();
        mvec = out.pop().context("m")?.as_f32()?.into_vec();
        gamma = out.pop().context("gamma")?.as_f32()?.into_vec();

        // ---- adaptive ρ controller (Penalty Regulation)
        if step % opts.adjust_every == opts.adjust_every - 1 {
            if let Some(target) = opts.target_attn {
                if opts.scope.covers_attn() {
                    rho_a = steer(rho_a, fa, target as f32);
                }
            }
            if let Some(target) = opts.target_ffn {
                if opts.scope.covers_ffn() {
                    rho_f = steer(rho_f, ff, target as f32);
                }
            }
        }
        if step % 100 == 0 {
            log::info!(
                "lazy[{}/{}] step {step}/{}: dloss {dl:.4} frac a/f \
                 {fa:.2}/{ff:.2} rho a/f {rho_a:.2e}/{rho_f:.2e}",
                m.name, opts.tag, tc.steps);
        }
    }

    let mut ck = Checkpoint::new();
    ck.insert("gamma", &[glen], gamma);
    ck.insert_scalar("serve_steps", opts.serve_steps as f32);
    ck.insert_scalar("frac_attn", fa);
    ck.insert_scalar("frac_ffn", ff);
    ck.save(&gates_path(ckpt_dir, &m.name, &opts.tag))?;

    Ok(LazyTrainReport {
        steps: tc.steps,
        final_rho_attn: rho_a,
        final_rho_ffn: rho_f,
        final_frac_attn: fa,
        final_frac_ffn: ff,
        final_dloss: dl,
        mean_s_attn: sa,
        mean_s_ffn: sf,
        wall_s: start.elapsed().as_secs_f64(),
    })
}

/// Multiplicative ρ steering: raise the laziness penalty while under
/// target, lower it while over; clamped to the paper's sweep range
/// [1e-7, 1e-2] (extended ceiling 1e-1 for tiny models).
fn steer(rho: f32, frac: f32, target: f32) -> f32 {
    let factor = if frac < target - 0.02 {
        1.5
    } else if frac > target + 0.02 {
        1.0 / 1.5
    } else {
        1.0
    };
    (rho * factor).clamp(1e-7, 1e-1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steer_direction() {
        // under target → increase penalty (push s up)
        assert!(steer(1e-3, 0.1, 0.5) > 1e-3);
        // over target → decrease
        assert!(steer(1e-3, 0.9, 0.5) < 1e-3);
        // within band → keep
        assert_eq!(steer(1e-3, 0.5, 0.5), 1e-3);
    }

    #[test]
    fn steer_clamped() {
        assert!(steer(1e-1, 0.0, 1.0) <= 1e-1);
        assert!(steer(1e-7, 1.0, 0.0) >= 1e-7);
    }
}
