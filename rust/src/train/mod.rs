//! Training drivers — both phases run ENTIRELY from Rust by executing the
//! AOT-lowered `init` / `pretrain_step` / `train_step` graphs, so the
//! binary remains self-contained after `make artifacts`:
//!
//! * [`pretrain`] — trains the base DiT on SynthBlobs-10 (the paper uses
//!   officially released ImageNet checkpoints; we have none — DESIGN.md §4).
//! * [`lazytrain`] — the paper's 500-step lazy learning: θ frozen, gates γ
//!   trained with diffusion + lazy loss, with an adaptive ρ controller
//!   steering toward a target lazy ratio ("Penalty Regulation").

pub mod pretrain;
pub mod lazytrain;

pub use lazytrain::{lazy_train, LazyTrainReport};
pub use pretrain::{pretrain, PretrainReport};
