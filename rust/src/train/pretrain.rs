//! Base-model pretraining driver (AOT `init` + `pretrain_step` graphs).

use crate::config::TrainConfig;
use crate::data::synth::SynthBlobs;
use crate::model::checkpoint::{theta_path, Checkpoint};
use crate::runtime::engine_rt::Runtime;
use crate::runtime::manifest::ManifestConfig;
use crate::runtime::value::HostValue;
use crate::tensor::Tensor;
use crate::util::prng::Rng;
use anyhow::{Context, Result};
use std::path::Path;
use std::rc::Rc;

/// Summary of a pretraining run.
#[derive(Debug, Clone)]
pub struct PretrainReport {
    pub steps: usize,
    pub first_loss: f32,
    pub last_loss: f32,
    /// Mean loss over the last 10% of steps.
    pub tail_loss: f32,
    pub losses: Vec<f32>,
    pub wall_s: f64,
}

/// Train the base DiT from scratch; saves θ to `<ckpt>/<config>.theta.ldck`.
pub fn pretrain(rt: &Rc<Runtime>, cfg: &ManifestConfig, tc: &TrainConfig,
                ckpt_dir: &Path) -> Result<PretrainReport> {
    let start = std::time::Instant::now();
    let m = &cfg.model;
    let b = cfg.train_batch;
    let ds = SynthBlobs::new(m.img_size);
    let mut rng = Rng::new(tc.seed ^ 0x7123_4567);

    // ---- init θ via the exported initializer
    let init = rt.load(cfg, "init")?;
    let key = HostValue::U32 { shape: vec![2], data: vec![tc.seed as u32, 0x5EED] };
    let mut out = init.call(&[key])?;
    let theta = out.pop().context("init output")?.as_f32()?;
    let p = theta.len();
    let mut theta = theta.into_vec();
    let mut mvec = vec![0.0f32; p];
    let mut vvec = vec![0.0f32; p];

    let step_exe = rt.load(cfg, "pretrain_step")?;
    let timesteps = cfg.diffusion.timesteps;
    let img = m.img_elems();

    let mut losses = Vec::with_capacity(tc.steps);
    for step in 0..tc.steps {
        // batch with CFG label dropout
        let (x0, mut labels) = ds.sample_batch(&mut rng, b);
        for l in labels.iter_mut() {
            if rng.uniform() < tc.label_dropout {
                *l = m.null_label();
            }
        }
        let y: Vec<i32> = labels.iter().map(|&l| l as i32).collect();
        let t: Vec<i32> = (0..b).map(|_| rng.below(timesteps) as i32).collect();
        let mut noise = vec![0.0f32; b * img];
        rng.fill_normal(&mut noise);

        let args = vec![
            HostValue::F32(Tensor::from_vec(&[p], theta)?),
            HostValue::F32(Tensor::from_vec(&[p], mvec)?),
            HostValue::F32(Tensor::from_vec(&[p], vvec)?),
            HostValue::scalar_f32((step + 1) as f32),
            HostValue::F32(x0),
            HostValue::I32 { shape: vec![b], data: y },
            HostValue::I32 { shape: vec![b], data: t },
            HostValue::F32(Tensor::from_vec(
                &[b, m.channels, m.img_size, m.img_size], noise)?),
            HostValue::scalar_f32(tc.lr),
        ];
        let mut out = step_exe.call(&args)?;
        let loss = out.pop().context("loss")?.as_f32()?.data()[0];
        vvec = out.pop().context("v")?.as_f32()?.into_vec();
        mvec = out.pop().context("m")?.as_f32()?.into_vec();
        theta = out.pop().context("theta")?.as_f32()?.into_vec();
        losses.push(loss);
        if step % 100 == 0 {
            log::info!("pretrain[{}] step {step}/{} loss {loss:.4}",
                       m.name, tc.steps);
        }
    }

    // ---- save
    let mut ck = Checkpoint::new();
    ck.insert("theta", &[p], theta);
    ck.insert_scalar("steps", tc.steps as f32);
    ck.save(&theta_path(ckpt_dir, &m.name))?;

    let tail_n = (losses.len() / 10).max(1);
    let tail = &losses[losses.len() - tail_n..];
    Ok(PretrainReport {
        steps: tc.steps,
        first_loss: *losses.first().unwrap_or(&0.0),
        last_loss: *losses.last().unwrap_or(&0.0),
        tail_loss: tail.iter().sum::<f32>() / tail_n as f32,
        losses,
        wall_s: start.elapsed().as_secs_f64(),
    })
}
