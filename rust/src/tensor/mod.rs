//! Host tensor substrate: contiguous row-major f32 arrays with the small
//! set of ops the L3 hot path needs (residuals, blends, gathers for the
//! continuous batcher, CFG combination). Heavy math lives in the AOT
//! executables; these ops are deliberately simple and allocation-aware.

use anyhow::{bail, Result};

pub mod pool;

/// A contiguous row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {n} elems, got {}", shape, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Leading-dimension size (batch).
    pub fn dim0(&self) -> usize {
        *self.shape.first().unwrap_or(&1)
    }

    /// Elements per leading-dim row.
    pub fn row_len(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.data.len() / self.shape[0].max(1)
        }
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let r = self.row_len();
        &self.data[i * r..(i + 1) * r]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let r = self.row_len();
        &mut self.data[i * r..(i + 1) * r]
    }

    /// Copy row `src` of `other` into row `dst` of self.
    pub fn copy_row_from(&mut self, dst: usize, other: &Tensor, src: usize) {
        debug_assert_eq!(self.row_len(), other.row_len());
        let r = self.row_len();
        self.data[dst * r..(dst + 1) * r]
            .copy_from_slice(&other.data[src * r..(src + 1) * r]);
    }

    /// Gather rows into a new tensor with leading dim = idx.len(),
    /// padding with zeros for indices == usize::MAX (bucket padding).
    /// Only padding rows are zero-filled; gathered rows are written
    /// exactly once (no full-output memset before the copy loop).
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        let r = self.row_len();
        let mut data = Vec::with_capacity(r * idx.len());
        for &i in idx {
            if i != usize::MAX {
                data.extend_from_slice(&self.data[i * r..(i + 1) * r]);
            } else {
                data.resize(data.len() + r, 0.0);
            }
        }
        Tensor { shape: new_shape0(&self.shape, idx.len()), data }
    }

    /// [`gather_rows`](Self::gather_rows) into an existing destination,
    /// reusing its buffer (the batcher's repack path: no allocation, and
    /// only padding rows pay a memset). `out` must already have leading
    /// dim `idx.len()` and matching row length.
    pub fn gather_rows_into(&self, idx: &[usize], out: &mut Tensor) {
        let r = self.row_len();
        debug_assert_eq!(out.row_len(), r);
        debug_assert_eq!(out.dim0(), idx.len());
        for (k, &i) in idx.iter().enumerate() {
            let dst = &mut out.data[k * r..(k + 1) * r];
            if i != usize::MAX {
                dst.copy_from_slice(&self.data[i * r..(i + 1) * r]);
            } else {
                dst.fill(0.0);
            }
        }
    }

    /// Scatter complement of [`gather_rows_into`](Self::gather_rows_into):
    /// overwrite rows of `self` with rows of `src`, where `idx[j]` names
    /// the destination row of `src` row `j` (`usize::MAX` ⇒ `src` row `j`
    /// is sub-batch padding and is dropped). Rows of `self` not named by
    /// `idx` are left untouched — the partial-run scatter of the
    /// row-granular skip path writes fresh module outputs over run-rows
    /// while skip-rows keep their cached bytes.
    pub fn scatter_rows_from(&mut self, src: &Tensor, idx: &[usize]) {
        let r = self.row_len();
        debug_assert_eq!(src.row_len(), r);
        debug_assert_eq!(src.dim0(), idx.len());
        for (j, &i) in idx.iter().enumerate() {
            if i != usize::MAX {
                self.data[i * r..(i + 1) * r]
                    .copy_from_slice(&src.data[j * r..(j + 1) * r]);
            }
        }
    }

    /// Reshape view (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?} mismatch", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    // ---------------- element-wise ----------------

    pub fn add_assign(&mut self, other: &Tensor) {
        debug_assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, k: f32) {
        for a in self.data.iter_mut() {
            *a *= k;
        }
    }

    /// self = a*x + b*y (shapes equal) — DDIM update helper.
    pub fn axpby_from(&mut self, a: f32, x: &Tensor, b: f32, y: &Tensor) {
        debug_assert_eq!(x.shape, y.shape);
        debug_assert_eq!(self.shape, x.shape);
        for i in 0..self.data.len() {
            self.data[i] = a * x.data[i] + b * y.data[i];
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        debug_assert_eq!(self.shape, other.shape);
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    // ---------------- reductions ----------------

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().sum::<f32>() / self.data.len() as f32
        }
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    /// Cosine similarity with another tensor (the paper's f(·,·), Eq. 3).
    pub fn cosine(&self, other: &Tensor) -> f32 {
        debug_assert_eq!(self.shape, other.shape);
        let dot: f32 = self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum();
        let na = self.l2_norm();
        let nb = other.l2_norm();
        if na == 0.0 || nb == 0.0 {
            0.0
        } else {
            dot / (na * nb)
        }
    }

    /// Mean squared error vs other.
    pub fn mse(&self, other: &Tensor) -> f32 {
        debug_assert_eq!(self.shape, other.shape);
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / n as f32
    }
}

fn new_shape0(shape: &[usize], d0: usize) -> Vec<usize> {
    let mut s = shape.to_vec();
    if s.is_empty() {
        s.push(d0);
    } else {
        s[0] = d0;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    #[test]
    fn rows_and_gather() {
        let t = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.row(1), &[3., 4.]);
        let g = t.gather_rows(&[2, 0, usize::MAX]);
        assert_eq!(g.shape(), &[3, 2]);
        assert_eq!(g.row(0), &[5., 6.]);
        assert_eq!(g.row(1), &[1., 2.]);
        assert_eq!(g.row(2), &[0., 0.]); // padding
    }

    #[test]
    fn gather_rows_into_reuses_destination() {
        let t = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        // destination pre-filled with garbage: gathered rows overwrite,
        // padding rows are the only memset
        let mut out = Tensor::from_vec(&[3, 2], vec![9.0; 6]).unwrap();
        t.gather_rows_into(&[1, usize::MAX, 0], &mut out);
        assert_eq!(out.row(0), &[3., 4.]);
        assert_eq!(out.row(1), &[0., 0.]);
        assert_eq!(out.row(2), &[1., 2.]);
        // agrees with the allocating variant on every index pattern
        let g = t.gather_rows(&[1, usize::MAX, 0]);
        assert_eq!(g, out);
    }

    #[test]
    fn scatter_rows_from_overwrites_only_named_rows() {
        let src = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.])
            .unwrap();
        let mut out = Tensor::from_vec(&[4, 2], vec![9.0; 8]).unwrap();
        // src row 0 → out row 2, src row 1 is padding, src row 2 → out row 0
        out.scatter_rows_from(&src, &[2, usize::MAX, 0]);
        assert_eq!(out.row(0), &[5., 6.]);
        assert_eq!(out.row(1), &[9., 9.], "unnamed row untouched");
        assert_eq!(out.row(2), &[1., 2.]);
        assert_eq!(out.row(3), &[9., 9.], "unnamed row untouched");
    }

    #[test]
    fn scatter_inverts_gather() {
        // the partition round-trip: gathering rows into a compacted
        // sub-batch and scattering them back through the same index map
        // reconstructs exactly the gathered rows, touching nothing else
        propcheck(100, |g| {
            let rows = g.usize_in(1, 8);
            let r = g.usize_in(1, 6);
            let data = g.vec_f32(rows * r, -3.0, 3.0);
            let t = Tensor::from_vec(&[rows, r], data).unwrap();
            // random selection with padding tail, like RowPartition
            let picks: Vec<usize> =
                (0..rows).filter(|_| g.bool()).collect();
            let width = g.usize_in(picks.len().max(1), picks.len() + 3);
            let mut idx = picks.clone();
            idx.resize(width, usize::MAX);
            let sub = t.gather_rows(&idx);
            let mut out =
                Tensor::from_vec(&[rows, r], g.vec_f32(rows * r, -3.0, 3.0))
                    .unwrap();
            let before = out.clone();
            out.scatter_rows_from(&sub, &idx);
            for row in 0..rows {
                if picks.contains(&row) {
                    assert_eq!(out.row(row), t.row(row),
                               "scattered row must carry the source bytes");
                } else {
                    assert_eq!(out.row(row), before.row(row),
                               "unselected row must be untouched");
                }
            }
        });
    }

    #[test]
    fn axpby() {
        let x = Tensor::from_vec(&[2], vec![1., 2.]).unwrap();
        let y = Tensor::from_vec(&[2], vec![10., 20.]).unwrap();
        let mut out = Tensor::zeros(&[2]);
        out.axpby_from(2.0, &x, 0.5, &y);
        assert_eq!(out.data(), &[7., 14.]);
    }

    #[test]
    fn cosine_properties() {
        propcheck(100, |g| {
            let n = g.usize_in(2, 64);
            let v = g.vec_normal(n);
            let t = Tensor::from_vec(&[n], v.clone()).unwrap();
            // self-similarity == 1
            let c = t.cosine(&t);
            assert!((c - 1.0).abs() < 1e-5, "self cosine {c}");
            // scale invariance
            let mut t2 = t.clone();
            t2.scale(3.5);
            assert!((t.cosine(&t2) - 1.0).abs() < 1e-4);
            // antipodal == -1
            let mut t3 = t.clone();
            t3.scale(-1.0);
            assert!((t.cosine(&t3) + 1.0).abs() < 1e-4);
        });
    }

    #[test]
    fn mse_zero_iff_equal() {
        let a = Tensor::from_vec(&[4], vec![1., 2., 3., 4.]).unwrap();
        assert_eq!(a.mse(&a), 0.0);
        let b = Tensor::from_vec(&[4], vec![1., 2., 3., 5.]).unwrap();
        assert!(a.mse(&b) > 0.0);
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::zeros(&[2, 3]);
        assert!(t.clone().reshape(&[3, 2]).is_ok());
        assert!(t.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 3]).is_err());
    }
}
