//! `TensorPool` — a per-replica buffer arena for the denoise hot path.
//!
//! The step loop used to churn one fresh `Vec<f32>` per module per step
//! (`x.clone()`, `f.clone()`, cache rebuilds): at `[B, N, D]` sizes that
//! is megabytes of malloc/free traffic per denoise step. The arena
//! recycles same-sized buffers instead: `acquire` pops a retained buffer
//! when one of the right element count exists and only heap-allocates
//! otherwise; `release` returns a tensor's storage for the next
//! acquirer.
//!
//! Ownership: each replica's engine owns exactly one arena (constructed
//! by its [`crate::model::runner::ModelRunner`], shared via `Rc` with
//! the engine's persistent batch state). The pool is single-threaded by
//! construction — replicas never share engines — so interior
//! mutability is `RefCell`/`Cell`, not locks.
//!
//! Accounting: `allocated` / `reused` / `released` counters are the
//! test hook behind the zero-copy acceptance check — a steady-state
//! denoise loop must show `allocated` flat while `reused` grows (see
//! docs/PERF.md).

use crate::tensor::Tensor;
use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;

/// Default per-class retention cap (see [`TensorPool::with_capacity`]).
/// The steady-state step loop *releases* far more often than it
/// *acquires* (acquires happen only on batch rebuilds), so an oversized
/// cap just hoards dead buffers — the cap must track the rebuild
/// demand, which is 2L cache slots per size class plus a transient or
/// two. Runners size it from their model depth; this default covers
/// tests and ad-hoc pools.
const DEFAULT_RETAINED_PER_CLASS: usize = 8;

/// Point-in-time arena counters (the allocation-counting test hook).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers created fresh on the heap (pool misses).
    pub allocated: u64,
    /// Buffers served from the free list (pool hits).
    pub reused: u64,
    /// Buffers returned to the pool.
    pub released: u64,
    /// Free buffers currently retained across all size classes.
    pub retained: usize,
}

/// A size-classed free list of `f32` buffers. See the module docs.
#[derive(Debug)]
pub struct TensorPool {
    /// Free buffers keyed by element count.
    free: RefCell<BTreeMap<usize, Vec<Vec<f32>>>>,
    /// Per-class retention bound: `release` drops beyond it.
    cap_per_class: usize,
    allocated: Cell<u64>,
    reused: Cell<u64>,
    released: Cell<u64>,
}

impl Default for TensorPool {
    fn default() -> TensorPool {
        TensorPool::with_capacity(DEFAULT_RETAINED_PER_CLASS)
    }
}

impl TensorPool {
    /// An empty arena with the default per-class retention cap.
    pub fn new() -> TensorPool {
        TensorPool::default()
    }

    /// An empty arena retaining at most `cap_per_class` free buffers
    /// per size class. Size it to the acquire-side demand — an engine's
    /// batch rebuild draws 2L cache slots of one class plus one `z` —
    /// because the hot loop's release flux is one-way (a bigger cap
    /// only parks dead memory, it never increases reuse).
    pub fn with_capacity(cap_per_class: usize) -> TensorPool {
        TensorPool {
            free: RefCell::new(BTreeMap::new()),
            cap_per_class: cap_per_class.max(1),
            allocated: Cell::new(0),
            reused: Cell::new(0),
            released: Cell::new(0),
        }
    }

    /// A zero-filled tensor of `shape`, recycling a retained buffer of
    /// the same element count when one exists. Reused buffers are
    /// re-zeroed (memset), so an acquired tensor is indistinguishable
    /// from `Tensor::zeros` — stale contents can never leak between
    /// occupants.
    pub fn acquire(&self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        let buf = self.free.borrow_mut().get_mut(&n).and_then(Vec::pop);
        match buf {
            Some(mut data) => {
                self.reused.set(self.reused.get() + 1);
                data.fill(0.0);
                Tensor::from_vec(shape, data).expect("pool size class")
            }
            None => {
                self.allocated.set(self.allocated.get() + 1);
                Tensor::zeros(shape)
            }
        }
    }

    /// Like [`acquire`](Self::acquire) but WITHOUT the re-zeroing
    /// memset on reuse — for destinations the caller immediately
    /// overwrites in full (e.g. a gather that writes every row and
    /// memsets its own padding), where zeroing first would just write
    /// every byte twice. A pool miss still hands out a zero-filled
    /// fresh buffer; only the reuse path may carry stale contents, so
    /// callers MUST write every element before reading any.
    pub fn acquire_for_overwrite(&self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        let buf = self.free.borrow_mut().get_mut(&n).and_then(Vec::pop);
        match buf {
            Some(data) => {
                self.reused.set(self.reused.get() + 1);
                Tensor::from_vec(shape, data).expect("pool size class")
            }
            None => {
                self.allocated.set(self.allocated.get() + 1);
                Tensor::zeros(shape)
            }
        }
    }

    /// Return a tensor's storage to the arena. Shape is forgotten —
    /// only the element count keys the free list — so a `[B, N, D]`
    /// cache slot and a flat scratch buffer of the same size recycle
    /// into each other.
    pub fn release(&self, t: Tensor) {
        let data = t.into_vec();
        if data.is_empty() {
            return;
        }
        let n = data.len();
        let mut free = self.free.borrow_mut();
        let class = free.entry(n).or_default();
        if class.len() < self.cap_per_class {
            class.push(data);
            self.released.set(self.released.get() + 1);
        }
    }

    /// Live counters (see [`PoolStats`]).
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            allocated: self.allocated.get(),
            reused: self.reused.get(),
            released: self.released.get(),
            retained: self.free.borrow().values().map(Vec::len).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    #[test]
    fn acquire_release_cycle_reuses() {
        let p = TensorPool::new();
        let a = p.acquire(&[2, 3]);
        assert_eq!(p.stats().allocated, 1);
        p.release(a);
        assert_eq!(p.stats().retained, 1);
        // same element count, different shape: still a hit
        let b = p.acquire(&[3, 2]);
        assert_eq!(b.shape(), &[3, 2]);
        let st = p.stats();
        assert_eq!((st.allocated, st.reused, st.retained), (1, 1, 0));
    }

    #[test]
    fn reused_buffers_are_rezeroed() {
        let p = TensorPool::new();
        let mut a = p.acquire(&[4]);
        a.data_mut().copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        p.release(a);
        let b = p.acquire(&[4]);
        assert_eq!(b.data(), &[0.0; 4], "stale contents must never leak");
    }

    #[test]
    fn acquire_for_overwrite_skips_the_rezero() {
        // the contract: reuse may carry stale contents (the caller
        // overwrites in full), a pool miss is still zero-filled, and
        // the hit/miss counters account it like any acquire
        let p = TensorPool::new();
        let fresh = p.acquire_for_overwrite(&[4]);
        assert_eq!(fresh.data(), &[0.0; 4], "pool miss is zero-filled");
        let mut a = p.acquire(&[4]);
        a.data_mut().copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        p.release(a);
        let b = p.acquire_for_overwrite(&[4]);
        assert_eq!(b.data(), &[1.0, 2.0, 3.0, 4.0],
                   "reuse skips the memset — caller must overwrite");
        let st = p.stats();
        assert_eq!((st.allocated, st.reused), (2, 1));
    }

    #[test]
    fn mismatched_sizes_do_not_cross() {
        let p = TensorPool::new();
        p.release(Tensor::zeros(&[4]));
        let t = p.acquire(&[5]);
        assert_eq!(t.len(), 5);
        let st = p.stats();
        assert_eq!((st.allocated, st.reused), (1, 0));
        assert_eq!(st.retained, 1, "the [4] buffer is still parked");
    }

    #[test]
    fn retention_is_bounded() {
        let p = TensorPool::new();
        for _ in 0..2 * DEFAULT_RETAINED_PER_CLASS {
            p.release(Tensor::zeros(&[8]));
        }
        assert_eq!(p.stats().retained, DEFAULT_RETAINED_PER_CLASS);
        // a sized pool binds to its own cap (and never below 1)
        let p = TensorPool::with_capacity(2);
        for _ in 0..5 {
            p.release(Tensor::zeros(&[4]));
        }
        assert_eq!(p.stats().retained, 2);
        assert_eq!(TensorPool::with_capacity(0).cap_per_class, 1);
    }

    #[test]
    fn empty_tensors_are_not_pooled() {
        let p = TensorPool::new();
        p.release(Tensor::zeros(&[0]));
        assert_eq!(p.stats().retained, 0);
        assert_eq!(p.stats().released, 0);
    }

    #[test]
    fn steady_state_allocates_nothing() {
        // the acceptance property at arena level: after warmup, a loop
        // of acquire/release pairs serves every request from the free
        // list — `allocated` stays flat
        propcheck(50, |g| {
            let p = TensorPool::new();
            let d0 = g.usize_in(1, 8);
            let d1 = g.usize_in(1, 16);
            let warm = p.acquire(&[d0, d1]);
            p.release(warm);
            let after_warmup = p.stats().allocated;
            for _ in 0..g.usize_in(2, 20) {
                let t = p.acquire(&[d0, d1]);
                p.release(t);
            }
            assert_eq!(p.stats().allocated, after_warmup,
                       "steady state must not allocate");
            assert!(p.stats().reused >= 1);
        });
    }
}
