//! Host-side values crossing the PJRT boundary.

use crate::tensor::Tensor;
use anyhow::{bail, Result};
use xla::Literal;

/// A typed host array destined for (or received from) an executable.
#[derive(Debug, Clone)]
pub enum HostValue {
    F32(Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
}

impl HostValue {
    pub fn scalar_f32(v: f32) -> HostValue {
        HostValue::F32(Tensor::scalar(v))
    }

    pub fn i32_vec(data: Vec<i32>) -> HostValue {
        let shape = vec![data.len()];
        HostValue::I32 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => t.shape(),
            HostValue::I32 { shape, .. } => shape,
            HostValue::U32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            HostValue::F32(_) => "float32",
            HostValue::I32 { .. } => "int32",
            HostValue::U32 { .. } => "uint32",
        }
    }

    /// Convert to an XLA literal (shape-preserving).
    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostValue::F32(t) => Literal::vec1(t.data()).reshape(&dims)?,
            HostValue::I32 { data, .. } => Literal::vec1(data).reshape(&dims)?,
            HostValue::U32 { data, .. } => Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Build an f32 literal from a *borrowed* tensor. Equivalent to
    /// `HostValue::F32(t.clone()).to_literal()` minus the clone — the
    /// hot-path variant: the step loop converts `x`/`f` once per module
    /// and must not pay an extra `[B, N, D]` copy just to wrap the
    /// tensor in an owned enum first.
    pub fn f32_literal(t: &Tensor) -> Result<Literal> {
        let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
        Ok(Literal::vec1(t.data()).reshape(&dims)?)
    }

    /// Convert an XLA literal back to a host value.
    pub fn from_literal(lit: &Literal) -> Result<HostValue> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        use xla::ElementType::*;
        match shape.ty() {
            F32 => Ok(HostValue::F32(Tensor::from_vec(&dims, lit.to_vec::<f32>()?)?)),
            S32 => Ok(HostValue::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            U32 => Ok(HostValue::U32 { shape: dims, data: lit.to_vec::<u32>()? }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    pub fn as_f32(self) -> Result<Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            other => bail!("expected f32 value, got {}", other.dtype()),
        }
    }

    pub fn as_f32_ref(&self) -> Result<&Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            other => bail!("expected f32 value, got {}", other.dtype()),
        }
    }
}

impl From<Tensor> for HostValue {
    fn from(t: Tensor) -> HostValue {
        HostValue::F32(t)
    }
}
