//! The executable registry: compile-on-first-use of `*.hlo.txt` graphs,
//! shape-checked execution, and buffer-resident weights for the hot path.

use crate::runtime::manifest::{GraphMeta, ManifestConfig};
use crate::runtime::value::HostValue;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, XlaComputation};

/// A compiled executable plus its manifest metadata.
pub struct Executable {
    pub meta: GraphMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host values; returns decomposed host outputs.
    /// Every graph is lowered with `return_tuple=True`, so the single
    /// result buffer is a tuple literal we decompose.
    pub fn call(&self, args: &[HostValue]) -> Result<Vec<HostValue>> {
        self.check_args(args)?;
        let literals: Vec<Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<Literal>(&literals)?;
        self.collect_outputs(result)
    }

    /// Execute with pre-converted literals — the hot path. Weight literals
    /// are built ONCE at model load (see model::params::WeightSet::lit),
    /// so per-step conversion cost is only the small dynamic tensors.
    pub fn call_lit(&self, args: &[&Literal]) -> Result<Vec<HostValue>> {
        let result = self.exe.execute::<&Literal>(args)?;
        self.collect_outputs(result)
    }

    /// Execute with a mix of device-resident buffers (weights) and host
    /// values — the optimized hot path (weights uploaded once at load,
    /// never re-converted per call).
    pub fn call_b(&self, args: &[ArgRef<'_>]) -> Result<Vec<HostValue>> {
        let client = self.exe.client();
        // owned temporaries for host args; refs mix them with weights
        let mut temps: Vec<Option<PjRtBuffer>> = Vec::with_capacity(args.len());
        for a in args {
            match a {
                ArgRef::Host(h) => {
                    let dims: Vec<usize> = h.shape().to_vec();
                    let buf = match h {
                        HostValue::F32(t) => {
                            client.buffer_from_host_buffer(t.data(), &dims, None)?
                        }
                        HostValue::I32 { data, .. } => {
                            client.buffer_from_host_buffer(data, &dims, None)?
                        }
                        HostValue::U32 { data, .. } => {
                            client.buffer_from_host_buffer(data, &dims, None)?
                        }
                    };
                    temps.push(Some(buf));
                }
                ArgRef::Device(_) => temps.push(None),
            }
        }
        let refs: Vec<&PjRtBuffer> = args
            .iter()
            .zip(&temps)
            .map(|(a, t)| match a {
                ArgRef::Host(_) => t.as_ref().unwrap(),
                ArgRef::Device(b) => *b,
            })
            .collect();
        let result = self.exe.execute_b::<&PjRtBuffer>(&refs)?;
        self.collect_outputs(result)
    }

    fn collect_outputs(
        &self,
        mut result: Vec<Vec<PjRtBuffer>>,
    ) -> Result<Vec<HostValue>> {
        if result.is_empty() || result[0].is_empty() {
            bail!("executable '{}' returned no outputs", self.meta.name);
        }
        let replica = result.remove(0);
        // xla_extension 0.5.1 PJRT CPU returns ONE tuple buffer for
        // return_tuple=True graphs; decompose via literal.
        if replica.len() == 1 && self.meta.outputs.len() > 1 {
            let lit = replica[0].to_literal_sync()?;
            let mut lit = lit;
            let parts = lit.decompose_tuple()?;
            return parts.iter().map(HostValue::from_literal).collect();
        }
        let mut out = Vec::with_capacity(replica.len());
        for buf in &replica {
            let mut lit = buf.to_literal_sync()?;
            // single-output tuple roots still need unwrapping
            match lit.decompose_tuple() {
                Ok(parts) if !parts.is_empty() => {
                    for p in &parts {
                        out.push(HostValue::from_literal(p)?);
                    }
                }
                _ => out.push(HostValue::from_literal(&lit)?),
            }
        }
        Ok(out)
    }

    fn check_args(&self, args: &[HostValue]) -> Result<()> {
        if args.len() != self.meta.inputs.len() {
            bail!(
                "graph '{}' expects {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                args.len()
            );
        }
        for (a, m) in args.iter().zip(&self.meta.inputs) {
            if a.shape() != m.shape.as_slice() {
                bail!(
                    "graph '{}' input '{}': shape {:?} != manifest {:?}",
                    self.meta.name,
                    m.name,
                    a.shape(),
                    m.shape
                );
            }
            if a.dtype() != m.dtype {
                bail!(
                    "graph '{}' input '{}': dtype {} != manifest {}",
                    self.meta.name,
                    m.name,
                    a.dtype(),
                    m.dtype
                );
            }
        }
        Ok(())
    }
}

/// Host-or-device argument for `call_b`.
pub enum ArgRef<'a> {
    Host(&'a HostValue),
    Device(&'a PjRtBuffer),
}

/// The per-process PJRT runtime: one CPU client + compiled-graph cache.
pub struct Runtime {
    client: PjRtClient,
    cache: RefCell<BTreeMap<String, Rc<Executable>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: RefCell::new(BTreeMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Load + compile a graph (cached per config + name — graph names like
    /// `apply_b1` repeat across configs with different shapes).
    pub fn load(&self, cfg: &ManifestConfig, name: &str) -> Result<Rc<Executable>> {
        let key = format!("{}/{name}", cfg.model.name);
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let meta = cfg.graph(name)?.clone();
        let proto = HloModuleProto::from_text_file(&meta.file).with_context(|| {
            format!("loading HLO text {} — run `make artifacts`?", meta.file.display())
        })?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling graph '{name}'"))?;
        let exec = Rc::new(Executable { meta, exe });
        self.cache.borrow_mut().insert(key, exec.clone());
        Ok(exec)
    }

    /// Upload a host tensor to a device-resident buffer (weights path).
    pub fn upload(&self, t: &Tensor) -> Result<PjRtBuffer> {
        Ok(self
            .client
            .buffer_from_host_buffer(t.data(), t.shape(), None)?)
    }

    /// Number of graphs compiled so far (startup metrics).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}
