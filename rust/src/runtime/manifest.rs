//! `artifacts/manifest.json` parsing — the shape/offset contract emitted by
//! `python/compile/aot.py` (single source of truth: python/compile/configs.py).

use crate::config::{DiffusionConfig, ModelConfig};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One tensor inside the flat θ or γ vector.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// One executable input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct ArgMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One exported graph.
#[derive(Debug, Clone)]
pub struct GraphMeta {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<ArgMeta>,
    pub outputs: Vec<ArgMeta>,
}

/// Everything exported for one model config.
#[derive(Debug, Clone)]
pub struct ManifestConfig {
    pub model: ModelConfig,
    pub diffusion: DiffusionConfig,
    pub params: Vec<ParamMeta>,
    pub gates: Vec<ParamMeta>,
    pub buckets: Vec<usize>,
    pub train_batch: usize,
    pub graphs: BTreeMap<String, GraphMeta>,
}

impl ManifestConfig {
    /// Total flat θ length.
    pub fn theta_len(&self) -> usize {
        self.params.last().map(|p| p.offset + p.size).unwrap_or(0)
    }

    /// Total flat γ length.
    pub fn gamma_len(&self) -> usize {
        self.gates.last().map(|p| p.offset + p.size).unwrap_or(0)
    }

    pub fn param(&self, name: &str) -> Result<&ParamMeta> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .with_context(|| format!("param '{name}' not in manifest"))
    }

    pub fn gate(&self, name: &str) -> Result<&ParamMeta> {
        self.gates
            .iter()
            .find(|p| p.name == name)
            .with_context(|| format!("gate '{name}' not in manifest"))
    }

    pub fn graph(&self, name: &str) -> Result<&GraphMeta> {
        self.graphs
            .get(name)
            .with_context(|| format!("graph '{name}' not in manifest"))
    }

    /// Smallest exported bucket that fits `n` rows.
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= n)
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub feature_dim: usize,
    pub configs: BTreeMap<String, ManifestConfig>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(artifacts_dir, &j)
    }

    pub fn from_json(root: &Path, j: &Json) -> Result<Manifest> {
        let mut configs = BTreeMap::new();
        let cj = j.req("configs")?.as_obj().context("configs not object")?;
        for (name, cfg_j) in cj {
            configs.insert(name.clone(), parse_config(root, name, cfg_j)?);
        }
        Ok(Manifest {
            root: root.to_path_buf(),
            feature_dim: j.req("feature_dim")?.as_usize().context("feature_dim")?,
            configs,
        })
    }

    pub fn config(&self, name: &str) -> Result<&ManifestConfig> {
        self.configs
            .get(name)
            .with_context(|| format!(
                "config '{name}' not exported (have: {:?}); re-run `make artifacts` \
                 with CONFIGS={name}",
                self.configs.keys().collect::<Vec<_>>()
            ))
    }
}

fn parse_params(j: &Json) -> Result<Vec<ParamMeta>> {
    j.as_arr()
        .context("params not array")?
        .iter()
        .map(|p| {
            Ok(ParamMeta {
                name: p.req("name")?.as_str().context("name")?.to_string(),
                shape: p.req("shape")?.as_shape().context("shape")?,
                offset: p.req("offset")?.as_usize().context("offset")?,
                size: p.req("size")?.as_usize().context("size")?,
            })
        })
        .collect()
}

fn parse_args(j: &Json) -> Result<Vec<ArgMeta>> {
    j.as_arr()
        .context("args not array")?
        .iter()
        .map(|a| {
            Ok(ArgMeta {
                name: a
                    .get("name")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string(),
                shape: a.req("shape")?.as_shape().context("shape")?,
                dtype: a.req("dtype")?.as_str().context("dtype")?.to_string(),
            })
        })
        .collect()
}

fn parse_config(root: &Path, name: &str, j: &Json) -> Result<ManifestConfig> {
    let model = ModelConfig::from_json(name, j)?;
    let diffusion = DiffusionConfig::from_json(j)?;
    let params = parse_params(j.req("params")?)?;
    let gates = parse_params(j.req("gates")?)?;
    let buckets = j
        .req("buckets")?
        .as_shape()
        .context("buckets")?;
    let train_batch = j.req("train_batch")?.as_usize().context("train_batch")?;
    let mut graphs = BTreeMap::new();
    for (gname, gj) in j.req("graphs")?.as_obj().context("graphs")? {
        graphs.insert(
            gname.clone(),
            GraphMeta {
                name: gname.clone(),
                file: root.join(gj.req("file")?.as_str().context("file")?),
                inputs: parse_args(gj.req("inputs")?)?,
                outputs: parse_args(gj.req("outputs")?)?,
            },
        );
    }
    Ok(ManifestConfig {
        model,
        diffusion,
        params,
        gates,
        buckets,
        train_batch,
        graphs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(
            r#"{
          "version": 1, "feature_dim": 64,
          "configs": {"nano": {
            "paper_analog": "(tests)",
            "model": {"img_size": 8, "channels": 3, "patch": 2, "dim": 32,
                      "depth": 2, "heads": 2, "num_classes": 10,
                      "mlp_ratio": 4, "freq_dim": 128},
            "diffusion": {"timesteps": 1000, "beta_start": 1e-4, "beta_end": 0.02},
            "params": [
               {"name": "embed.patch.w", "shape": [12, 32], "offset": 0, "size": 384},
               {"name": "embed.patch.b", "shape": [32], "offset": 384, "size": 32}],
            "gates": [{"name": "gate0.attn.w", "shape": [32], "offset": 0, "size": 32}],
            "buckets": [1, 2, 4],
            "train_batch": 8,
            "graphs": {"attn_b1": {"file": "nano/attn_b1.hlo.txt",
              "inputs": [{"name": "z", "shape": [1, 16, 32], "dtype": "float32"}],
              "outputs": [{"shape": [1, 16, 32], "dtype": "float32"}]}}
          }}}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_json(Path::new("/tmp/a"), &sample()).unwrap();
        let c = m.config("nano").unwrap();
        assert_eq!(c.model.dim, 32);
        assert_eq!(c.theta_len(), 416);
        assert_eq!(c.gamma_len(), 32);
        assert_eq!(c.buckets, vec![1, 2, 4]);
        let g = c.graph("attn_b1").unwrap();
        assert_eq!(g.inputs[0].shape, vec![1, 16, 32]);
        assert!(g.file.ends_with("nano/attn_b1.hlo.txt"));
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest::from_json(Path::new("/tmp/a"), &sample()).unwrap();
        let c = m.config("nano").unwrap();
        assert_eq!(c.bucket_for(1), Some(1));
        assert_eq!(c.bucket_for(3), Some(4));
        assert_eq!(c.bucket_for(4), Some(4));
        assert_eq!(c.bucket_for(5), None);
    }

    #[test]
    fn unknown_config_errors_helpfully() {
        let m = Manifest::from_json(Path::new("/tmp/a"), &sample()).unwrap();
        let err = m.config("xl-256a").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
