//! PJRT runtime: loads `artifacts/<config>/*.hlo.txt`, compiles them on the
//! CPU PJRT client, and executes them from the L3 hot path.
//!
//! Interchange is HLO **text** — jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §2).

pub mod manifest;
pub mod value;
pub mod engine_rt;

pub use engine_rt::{Executable, Runtime};
pub use manifest::{ArgMeta, GraphMeta, Manifest, ManifestConfig, ParamMeta};
pub use value::HostValue;
