//! Request types and per-request trajectory state.
//!
//! A trajectory's denoising state is a first-class portable value:
//! [`TrajectorySnapshot`] carries everything a request has accumulated
//! (params, step cursor, latent z, per-lane module caches, skip/seen
//! counters) in a versioned byte encoding, so a replica can evict a
//! running request at a step boundary and any compatible sibling can
//! resume it bit-identically. [`ActiveRequest`] is the engine-resident
//! form: the same portable state plus nothing else — wall-clock
//! admission is stamped in shared epoch microseconds (`obs::epoch`),
//! not an `Instant`, precisely so it survives migration.

use crate::config::Slo;
use crate::tensor::Tensor;
use crate::util::prng::Rng;
use anyhow::{bail, Result};

/// A generation request as admitted by the router.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub class_label: usize,
    pub steps: usize,
    pub seed: u64,
    /// CFG guidance scale; 1.0 disables the uncond lane.
    pub cfg_scale: f32,
    /// Service-level objective class (wire `"slo"` field; defaults to
    /// best-effort for legacy request lines). The pool router uses it
    /// for tier-aware placement.
    pub slo: Slo,
    /// Absolute completion deadline in shared-epoch microseconds
    /// (`obs::epoch`); 0 means "no deadline". Set from the wire
    /// `"deadline_ms"` field (relative, converted at parse time) or
    /// defaulted by the router from the skip calendar's predicted
    /// service time for latency-tier requests. Drives EDF queue
    /// ordering and shed-by-slack; like `id`/`slo` it never affects the
    /// output image and is excluded from [`RequestKey`].
    pub deadline_us: u64,
}

impl Request {
    pub fn new(id: u64, class_label: usize, steps: usize, seed: u64) -> Request {
        Request {
            id,
            class_label,
            steps,
            seed,
            cfg_scale: 1.5,
            slo: Slo::Besteffort,
            deadline_us: 0,
        }
    }

    /// Builder-style SLO tag (tests/benches).
    pub fn with_slo(mut self, slo: Slo) -> Request {
        self.slo = slo;
        self
    }

    /// Builder-style absolute deadline (tests/benches).
    pub fn with_deadline_us(mut self, deadline_us: u64) -> Request {
        self.deadline_us = deadline_us;
        self
    }

    /// Number of batch lanes this request occupies (CFG doubles).
    pub fn lanes(&self) -> usize {
        if self.cfg_scale > 1.0 {
            2
        } else {
            1
        }
    }

    /// This request's canonical content key (see [`RequestKey::of`]).
    /// `model_params` identifies the serving model/resolution the pool
    /// runs — two pools with different models must never share entries.
    pub fn key(&self, model_params: u64) -> RequestKey {
        RequestKey::of(self, model_params)
    }
}

/// Canonical content-addressable identity of a request: exactly the
/// fields that determine the finished output — class label, CFG scale
/// (by f32 *bits*, so 1.5 and 1.5000001 are distinct keys), step count,
/// seed, and the serving model/resolution (`model_params`). Wire
/// identity (`id`) and scheduling class (`slo`) are deliberately
/// excluded: they never change the image. Equal keys ⇒ bit-identical
/// outputs (propcheck-asserted against the SimEngine in
/// `pool/cache.rs`), which is what lets the exact-result cache return a
/// stored image with zero engine work.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RequestKey {
    /// Class label conditioning the sample.
    pub class_label: u64,
    /// `cfg_scale.to_bits()` — bit-exact, no float comparison hazards.
    pub cfg_bits: u32,
    /// Denoise step count.
    pub steps: u64,
    /// Init-noise seed.
    pub seed: u64,
    /// Serving model / resolution discriminator (e.g. the image element
    /// count): keys from different model configurations never collide.
    pub model_params: u64,
}

impl RequestKey {
    /// Derive the canonical key for `req` under a given model identity.
    pub fn of(req: &Request, model_params: u64) -> RequestKey {
        RequestKey {
            class_label: req.class_label as u64,
            cfg_bits: req.cfg_scale.to_bits(),
            steps: req.steps as u64,
            seed: req.seed,
            model_params,
        }
    }

    /// The near-hit family this key belongs to: everything but the
    /// seed. Two requests in the same family share a trajectory shape
    /// (label, CFG, schedule, model) and differ only in init noise —
    /// the warm-start donor store is keyed on this.
    pub fn family(&self) -> FamilyKey {
        FamilyKey {
            class_label: self.class_label,
            cfg_bits: self.cfg_bits,
            steps: self.steps,
            model_params: self.model_params,
        }
    }
}

/// Warm-start (near-hit) key: [`RequestKey`] minus the seed. Requests
/// in the same family may borrow a donor trajectory's early-step lane
/// caches even though their latents differ (Δ-DiT: trajectory
/// deviations concentrate in late steps).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FamilyKey {
    /// Class label conditioning the sample.
    pub class_label: u64,
    /// `cfg_scale.to_bits()` of every member request.
    pub cfg_bits: u32,
    /// Denoise step count of every member request.
    pub steps: u64,
    /// Serving model / resolution discriminator.
    pub model_params: u64,
}

/// Per-lane cache store: one [N*D] vector per (layer, module).
#[derive(Debug, Clone, PartialEq)]
pub struct LaneCaches {
    pub values: Vec<Vec<f32>>, // [2L][N*D]
    pub valid: Vec<bool>,      // [2L]
}

impl LaneCaches {
    pub fn empty(depth: usize, nd: usize) -> LaneCaches {
        LaneCaches {
            values: vec![vec![0.0; nd]; 2 * depth],
            valid: vec![false; 2 * depth],
        }
    }
}

/// In-flight trajectory state owned by the engine.
#[derive(Debug)]
pub struct ActiveRequest {
    pub req: Request,
    /// Current latent z_t, flat [C*H*W].
    pub z: Vec<f32>,
    /// DDIM timestep subset (descending) and cursor.
    pub timesteps: Vec<usize>,
    pub cursor: usize,
    /// Per-lane caches: [0]=cond, [1]=uncond (if CFG).
    pub caches: Vec<LaneCaches>,
    /// Per-(layer,module) skip counts for this request.
    pub skip_counts: Vec<u32>,
    pub modules_seen: Vec<u32>,
    /// Admission stamp in shared epoch microseconds (`obs::epoch_us`).
    /// Epoch-based (not an `Instant`) so the stamp travels with a
    /// snapshot and the finishing replica reports the full end-to-end
    /// latency, counted once, however many migrations happened.
    pub admitted_us: u64,
    pub steps_done: usize,
}

impl ActiveRequest {
    pub fn new(req: Request, timesteps: Vec<usize>, depth: usize, nd: usize,
               img_elems: usize) -> ActiveRequest {
        let mut rng = Rng::new(req.seed ^ 0xD1FF_051F);
        let mut z = vec![0.0f32; img_elems];
        rng.fill_normal(&mut z);
        let lanes = req.lanes();
        ActiveRequest {
            req,
            z,
            timesteps,
            cursor: 0,
            caches: (0..lanes).map(|_| LaneCaches::empty(depth, nd)).collect(),
            skip_counts: vec![0; 2 * depth],
            modules_seen: vec![0; 2 * depth],
            admitted_us: crate::obs::epoch_us(),
            steps_done: 0,
        }
    }

    /// Package this trajectory as a portable snapshot. The caller (the
    /// engine's evict path) must have flushed any batch-resident cache
    /// rows back into `caches` first — the snapshot is only as fresh as
    /// the lane stores it copies out.
    pub fn into_snapshot(self) -> TrajectorySnapshot {
        TrajectorySnapshot {
            req: self.req,
            timesteps: self.timesteps,
            cursor: self.cursor,
            z: self.z,
            caches: self.caches,
            skip_counts: self.skip_counts,
            modules_seen: self.modules_seen,
            admitted_us: self.admitted_us,
            steps_done: self.steps_done,
        }
    }

    /// Rebuild engine-resident state from a snapshot. Every field is
    /// restored verbatim — in particular `z` is **never** re-sampled,
    /// so a resumed trajectory continues bit-identically from its
    /// eviction boundary.
    pub fn from_snapshot(snap: TrajectorySnapshot) -> ActiveRequest {
        ActiveRequest {
            req: snap.req,
            z: snap.z,
            timesteps: snap.timesteps,
            cursor: snap.cursor,
            caches: snap.caches,
            skip_counts: snap.skip_counts,
            modules_seen: snap.modules_seen,
            admitted_us: snap.admitted_us,
            steps_done: snap.steps_done,
        }
    }

    pub fn done(&self) -> bool {
        self.cursor >= self.timesteps.len()
    }

    /// Current timestep, or None when finished.
    pub fn current_t(&self) -> Option<usize> {
        self.timesteps.get(self.cursor).copied()
    }

    /// Next (lower) timestep, or -1 at the boundary.
    pub fn next_t(&self) -> isize {
        self.timesteps
            .get(self.cursor + 1)
            .map(|&t| t as isize)
            .unwrap_or(-1)
    }

    /// The paper's per-request lazy ratio Γ.
    pub fn lazy_ratio(&self) -> f64 {
        let seen: u32 = self.modules_seen.iter().sum();
        let skipped: u32 = self.skip_counts.iter().sum();
        skipped as f64 / seen.max(1) as f64
    }
}

/// Magic prefix of an encoded [`TrajectorySnapshot`].
const SNAP_MAGIC: [u8; 4] = *b"LZTS";
/// Current snapshot encoding version. Bump on any layout change; the
/// decoder rejects every version it does not know. v2 added the
/// request's `deadline_us` (8 bytes immediately after the slo byte).
const SNAP_VERSION: u8 = 2;
/// Decode-time ceiling on any single length field (elements). The
/// largest real field is z at C·H·W or a lane store at 2L·N·D — far
/// below this; a corrupt length must fail fast instead of attempting a
/// multi-GB allocation.
const SNAP_MAX_LEN: usize = 1 << 28;

/// A portable, self-contained image of an in-flight trajectory: the
/// request params plus everything accumulated since admission (step
/// cursor, latent z, per-lane module caches, skip/seen counters, the
/// epoch-µs admission stamp). [`crate::coordinator::pool::PoolEngine`]
/// implementations produce one at a step boundary (`evict_to_snapshot`)
/// and consume one (`admit_snapshot`); the pool layer moves them
/// between replicas for stealing, drain-by-migration, and crash
/// resume. Resuming from a snapshot is bit-identical to never having
/// been interrupted.
#[derive(Debug, Clone, PartialEq)]
pub struct TrajectorySnapshot {
    /// The admitted request (pool-unique id, params, SLO tag).
    pub req: Request,
    /// DDIM timestep subset (descending), as planned at admission.
    pub timesteps: Vec<usize>,
    /// Steps already denoised; the resume point.
    pub cursor: usize,
    /// Latent z_t at the eviction boundary, flat [C*H*W].
    pub z: Vec<f32>,
    /// Per-lane module caches ([0]=cond, [1]=uncond when CFG), flushed
    /// from batch residency at eviction.
    pub caches: Vec<LaneCaches>,
    /// Per-(layer,module) skip counts so far, [2L].
    pub skip_counts: Vec<u32>,
    /// Per-(layer,module) invocation counts so far, [2L].
    pub modules_seen: Vec<u32>,
    /// Admission stamp in shared epoch microseconds.
    pub admitted_us: u64,
    /// Denoising steps completed (mirrors `cursor` on the engine path).
    pub steps_done: usize,
}

impl TrajectorySnapshot {
    /// Steps still to denoise — the unit of backlog/gauge accounting.
    pub fn pending_steps(&self) -> usize {
        self.timesteps.len().saturating_sub(self.cursor)
    }

    /// Batch lanes the trajectory occupies (CFG doubles).
    pub fn lanes(&self) -> usize {
        self.req.lanes()
    }

    /// Trim this snapshot to its warm-start donor form: the lane caches
    /// (the only state a joiner ever borrows) plus the request params,
    /// schedule, and cursor that identify and bound them. The latent is
    /// dropped and the counters/stamps zeroed — a donor is read for its
    /// early-step cache rows, never resumed as a trajectory, so keeping
    /// `z` would only bloat the donor store.
    pub fn donor_trim(&self) -> TrajectorySnapshot {
        let mut req = self.req.clone();
        req.id = 0;
        TrajectorySnapshot {
            req,
            timesteps: self.timesteps.clone(),
            cursor: self.cursor,
            z: Vec::new(),
            caches: self.caches.clone(),
            skip_counts: vec![0; self.skip_counts.len()],
            modules_seen: vec![0; self.modules_seen.len()],
            admitted_us: 0,
            steps_done: self.cursor,
        }
    }

    /// Serialize to the versioned byte encoding: `b"LZTS"` + version
    /// byte, then little-endian length-prefixed fields in declaration
    /// order. [`Self::decode`] inverts this exactly.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + 4 * self.z.len());
        out.extend_from_slice(&SNAP_MAGIC);
        out.push(SNAP_VERSION);
        out.extend_from_slice(&self.req.id.to_le_bytes());
        out.extend_from_slice(&(self.req.class_label as u64).to_le_bytes());
        out.extend_from_slice(&(self.req.steps as u64).to_le_bytes());
        out.extend_from_slice(&self.req.seed.to_le_bytes());
        out.extend_from_slice(&self.req.cfg_scale.to_le_bytes());
        out.push(self.req.slo.index() as u8);
        out.extend_from_slice(&self.req.deadline_us.to_le_bytes());
        out.extend_from_slice(&self.admitted_us.to_le_bytes());
        out.extend_from_slice(&(self.cursor as u64).to_le_bytes());
        out.extend_from_slice(&(self.steps_done as u64).to_le_bytes());
        put_len(&mut out, self.timesteps.len());
        for &t in &self.timesteps {
            out.extend_from_slice(&(t as u32).to_le_bytes());
        }
        put_len(&mut out, self.z.len());
        for &v in &self.z {
            out.extend_from_slice(&v.to_le_bytes());
        }
        put_len(&mut out, self.caches.len());
        for lane in &self.caches {
            put_len(&mut out, lane.values.len());
            for slot in &lane.values {
                put_len(&mut out, slot.len());
                for &v in slot {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            put_len(&mut out, lane.valid.len());
            for &b in &lane.valid {
                out.push(b as u8);
            }
        }
        put_len(&mut out, self.skip_counts.len());
        for &c in &self.skip_counts {
            out.extend_from_slice(&c.to_le_bytes());
        }
        put_len(&mut out, self.modules_seen.len());
        for &c in &self.modules_seen {
            out.extend_from_slice(&c.to_le_bytes());
        }
        out
    }

    /// Decode an encoded snapshot, rejecting bad magic, unknown
    /// versions, truncation, trailing garbage, and inconsistent
    /// structure (per-lane `values`/`valid` length mismatch, cursor
    /// past the schedule).
    pub fn decode(bytes: &[u8]) -> Result<TrajectorySnapshot> {
        let mut r = SnapReader { buf: bytes, pos: 0 };
        let magic = r.take(4)?;
        if magic != SNAP_MAGIC {
            bail!("snapshot: bad magic {magic:?}");
        }
        let version = r.u8()?;
        if version != SNAP_VERSION {
            bail!("snapshot: unsupported version {version} \
                   (this build reads v{SNAP_VERSION})");
        }
        let id = r.u64()?;
        let class_label = r.u64()? as usize;
        let steps = r.u64()? as usize;
        let seed = r.u64()?;
        let cfg_scale = r.f32()?;
        let slo_idx = r.u8()? as usize;
        let Some(&slo) = Slo::ALL.get(slo_idx) else {
            bail!("snapshot: bad slo index {slo_idx}");
        };
        let deadline_us = r.u64()?;
        let admitted_us = r.u64()?;
        let cursor = r.u64()? as usize;
        let steps_done = r.u64()? as usize;
        let nt = r.len()?;
        let mut timesteps = Vec::with_capacity(nt);
        for _ in 0..nt {
            timesteps.push(r.u32()? as usize);
        }
        let nz = r.len()?;
        let mut z = Vec::with_capacity(nz);
        for _ in 0..nz {
            z.push(r.f32()?);
        }
        let lanes = r.len()?;
        let mut caches = Vec::with_capacity(lanes);
        for _ in 0..lanes {
            let nslots = r.len()?;
            let mut values = Vec::with_capacity(nslots);
            for _ in 0..nslots {
                let nd = r.len()?;
                let mut slot = Vec::with_capacity(nd);
                for _ in 0..nd {
                    slot.push(r.f32()?);
                }
                values.push(slot);
            }
            let nvalid = r.len()?;
            if nvalid != nslots {
                bail!("snapshot: lane valid len {nvalid} != values len \
                       {nslots}");
            }
            let mut valid = Vec::with_capacity(nvalid);
            for _ in 0..nvalid {
                valid.push(r.u8()? != 0);
            }
            caches.push(LaneCaches { values, valid });
        }
        let nsk = r.len()?;
        let mut skip_counts = Vec::with_capacity(nsk);
        for _ in 0..nsk {
            skip_counts.push(r.u32()?);
        }
        let nms = r.len()?;
        let mut modules_seen = Vec::with_capacity(nms);
        for _ in 0..nms {
            modules_seen.push(r.u32()?);
        }
        if r.pos != bytes.len() {
            bail!("snapshot: {} trailing bytes", bytes.len() - r.pos);
        }
        if cursor > timesteps.len() {
            bail!("snapshot: cursor {cursor} past schedule of {}",
                  timesteps.len());
        }
        if skip_counts.len() != modules_seen.len() {
            bail!("snapshot: skip/seen counter shapes differ");
        }
        Ok(TrajectorySnapshot {
            req: Request {
                id,
                class_label,
                steps,
                seed,
                cfg_scale,
                slo,
                deadline_us,
            },
            timesteps,
            cursor,
            z,
            caches,
            skip_counts,
            modules_seen,
            admitted_us,
            steps_done,
        })
    }
}

fn put_len(out: &mut Vec<u8>, n: usize) {
    out.extend_from_slice(&(n as u32).to_le_bytes());
}

/// Bounds-checked little-endian reader over an encoded snapshot.
struct SnapReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> SnapReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        let Some(end) = end else {
            bail!("snapshot: truncated at byte {} (want {n} more)", self.pos);
        };
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn len(&mut self) -> Result<usize> {
        let n = self.u32()? as usize;
        if n > SNAP_MAX_LEN {
            bail!("snapshot: length field {n} over cap {SNAP_MAX_LEN}");
        }
        Ok(n)
    }
}

/// Completed request: final image + accounting.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub class_label: usize,
    pub steps: usize,
    /// SLO class the request carried (echoed on the wire; per-tier
    /// completion accounting in the pool).
    pub slo: Slo,
    /// Final sample [C, H, W] flattened.
    pub image: Tensor,
    pub lazy_ratio: f64,
    pub attn_lazy_ratio: f64,
    pub ffn_lazy_ratio: f64,
    pub latency: std::time::Duration,
    /// Per-(layer,module) skip fractions, [2L].
    pub per_module_skip: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requests_default_to_besteffort_slo() {
        let r = Request::new(1, 0, 10, 0);
        assert_eq!(r.slo, Slo::Besteffort);
        let r = r.with_slo(Slo::Latency);
        assert_eq!(r.slo, Slo::Latency);
    }

    #[test]
    fn lanes_follow_cfg() {
        let mut r = Request::new(1, 0, 10, 0);
        assert_eq!(r.lanes(), 2);
        r.cfg_scale = 1.0;
        assert_eq!(r.lanes(), 1);
    }

    #[test]
    fn trajectory_state() {
        let req = Request::new(1, 3, 4, 7);
        let ar = ActiveRequest::new(req, vec![999, 749, 499, 249], 2, 16 * 32, 192);
        assert!(!ar.done());
        assert_eq!(ar.current_t(), Some(999));
        assert_eq!(ar.next_t(), 749);
        assert_eq!(ar.caches.len(), 2);
        assert_eq!(ar.caches[0].values.len(), 4);
        assert_eq!(ar.z.len(), 192);
        // z is standard-normal-ish, not all zeros
        assert!(ar.z.iter().any(|&v| v.abs() > 0.1));
    }

    #[test]
    fn noise_deterministic_by_seed() {
        let a = ActiveRequest::new(Request::new(1, 0, 2, 42), vec![999, 499], 1, 4, 12);
        let b = ActiveRequest::new(Request::new(2, 5, 2, 42), vec![999, 499], 1, 4, 12);
        assert_eq!(a.z, b.z, "same seed, same init noise");
        let c = ActiveRequest::new(Request::new(3, 0, 2, 43), vec![999, 499], 1, 4, 12);
        assert_ne!(a.z, c.z);
    }

    #[test]
    fn boundary_next_t() {
        let mut ar = ActiveRequest::new(Request::new(1, 0, 1, 0), vec![999], 1, 4, 12);
        assert_eq!(ar.next_t(), -1);
        ar.cursor = 1;
        assert!(ar.done());
        assert_eq!(ar.current_t(), None);
    }

    /// A mid-trajectory snapshot with every field populated non-trivially
    /// (CFG pair → 2 lanes, mixed validity, nonzero counters).
    fn sample_snapshot() -> TrajectorySnapshot {
        let mut req = Request::new(41, 7, 4, 0xBEEF).with_slo(Slo::Latency);
        req.cfg_scale = 2.0;
        let mut ar = ActiveRequest::new(req, vec![999, 749, 499, 249], 2, 8, 12);
        ar.cursor = 2;
        ar.steps_done = 2;
        ar.skip_counts = vec![1, 0, 3, 2];
        ar.modules_seen = vec![2, 2, 4, 4];
        for (lane, lc) in ar.caches.iter_mut().enumerate() {
            for (k, slot) in lc.values.iter_mut().enumerate() {
                for (i, v) in slot.iter_mut().enumerate() {
                    *v = (lane * 100 + k * 10 + i) as f32 + 0.25;
                }
                lc.valid[k] = k % 2 == lane % 2;
            }
        }
        ar.into_snapshot()
    }

    #[test]
    fn snapshot_roundtrips_through_active_request() {
        let snap = sample_snapshot();
        let ar = ActiveRequest::from_snapshot(snap.clone());
        assert_eq!(ar.cursor, 2);
        assert_eq!(ar.admitted_us, snap.admitted_us);
        let back = ar.into_snapshot();
        assert_eq!(back, snap, "resident form must preserve every field");
    }

    #[test]
    fn snapshot_encoding_roundtrips_bit_exactly() {
        let snap = sample_snapshot();
        let bytes = snap.encode();
        let back = TrajectorySnapshot::decode(&bytes).expect("decode");
        assert_eq!(back, snap);
        // f32 payloads round-trip by bits, not by approximate value
        assert_eq!(back.z.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   snap.z.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
        assert_eq!(back.pending_steps(), 2);
        assert_eq!(back.lanes(), 2);
    }

    #[test]
    fn snapshot_decode_rejects_bad_inputs() {
        let good = sample_snapshot().encode();
        // bad magic
        let mut b = good.clone();
        b[0] = b'X';
        assert!(TrajectorySnapshot::decode(&b).is_err(), "bad magic");
        // unknown version
        let mut b = good.clone();
        b[4] = 99;
        assert!(TrajectorySnapshot::decode(&b).is_err(), "unknown version");
        // truncation at every prefix length must error, never panic
        for cut in 0..good.len() {
            assert!(TrajectorySnapshot::decode(&good[..cut]).is_err(),
                    "truncated at {cut} must be rejected");
        }
        // trailing garbage
        let mut b = good.clone();
        b.push(0);
        assert!(TrajectorySnapshot::decode(&b).is_err(), "trailing bytes");
        // corrupt slo index
        let mut b = good.clone();
        // slo byte sits right after magic+version+id+label+steps+seed+cfg
        let slo_off = 4 + 1 + 8 + 8 + 8 + 8 + 4;
        b[slo_off] = 7;
        assert!(TrajectorySnapshot::decode(&b).is_err(), "bad slo index");
        // absurd length field fails fast instead of allocating
        let mut b = good;
        // slo byte, then deadline_us + admitted_us + cursor + steps_done
        let ts_len_off = slo_off + 1 + 8 + 8 + 8 + 8;
        b[ts_len_off..ts_len_off + 4]
            .copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(TrajectorySnapshot::decode(&b).is_err(), "huge length");
    }

    #[test]
    fn request_key_covers_every_output_field_and_nothing_else() {
        let mut r = Request::new(7, 3, 12, 99);
        r.cfg_scale = 1.5;
        let k = r.key(48);
        // non-output fields must NOT perturb the key: a cached result
        // is valid for any wire id / SLO class
        let mut r2 = r.clone();
        r2.id = 1234;
        r2.slo = Slo::Latency;
        assert_eq!(r2.key(48), k);
        // every output-affecting field must perturb it
        let mut p = r.clone();
        p.class_label = 4;
        assert_ne!(p.key(48), k, "label");
        let mut p = r.clone();
        p.cfg_scale = 2.0;
        assert_ne!(p.key(48), k, "cfg");
        let mut p = r.clone();
        p.steps = 13;
        assert_ne!(p.key(48), k, "steps");
        let mut p = r.clone();
        p.seed = 100;
        assert_ne!(p.key(48), k, "seed");
        assert_ne!(r.key(64), k, "resolution/model params");
        // the family key forgets exactly the seed
        let mut p = r.clone();
        p.seed = 100;
        assert_eq!(p.key(48).family(), k.family());
        let mut p = r.clone();
        p.class_label = 4;
        assert_ne!(p.key(48).family(), k.family());
    }

    #[test]
    fn donor_trim_keeps_caches_drops_latent() {
        let snap = sample_snapshot();
        let donor = snap.donor_trim();
        assert_eq!(donor.caches, snap.caches, "lane caches survive");
        assert_eq!(donor.cursor, snap.cursor);
        assert_eq!(donor.timesteps, snap.timesteps);
        assert!(donor.z.is_empty(), "latent dropped");
        assert_eq!(donor.req.id, 0, "wire identity dropped");
        assert_eq!(donor.req.seed, snap.req.seed, "donor seed retained \
                    (a near hit must differ in seed to warm-start)");
        assert!(donor.skip_counts.iter().all(|&c| c == 0));
        assert_eq!(donor.admitted_us, 0);
        // the trimmed form stays codec-portable
        let back = TrajectorySnapshot::decode(&donor.encode()).unwrap();
        assert_eq!(back, donor);
    }

    /// A randomly-shaped, fully-populated valid snapshot (generalizes
    /// `sample_snapshot` for the codec fuzz property).
    fn gen_snapshot(g: &mut crate::util::propcheck::Gen) -> TrajectorySnapshot {
        let steps = g.usize_in(1, 5);
        let mut req = Request::new(g.u64() % 1000, g.usize_in(0, 9), steps,
                                   g.u64());
        req.cfg_scale = if g.bool() { 2.0 } else { 1.0 };
        let depth = g.usize_in(1, 3);
        let nd = g.usize_in(1, 6);
        let img = g.usize_in(0, 10);
        let timesteps: Vec<usize> =
            (0..steps).rev().map(|i| i * 250 + 1).collect();
        let mut ar = ActiveRequest::new(req, timesteps, depth, nd, img);
        ar.cursor = g.usize_in(0, steps);
        ar.steps_done = ar.cursor;
        for k in 0..2 * depth {
            ar.skip_counts[k] = g.usize_in(0, 9) as u32;
            ar.modules_seen[k] = ar.skip_counts[k] + g.usize_in(0, 9) as u32;
        }
        for lc in ar.caches.iter_mut() {
            for (k, slot) in lc.values.iter_mut().enumerate() {
                let vals = g.vec_f32(slot.len(), -4.0, 4.0);
                slot.copy_from_slice(&vals);
                lc.valid[k] = g.bool();
            }
        }
        ar.into_snapshot()
    }

    /// The fuzz invariant for one mutated byte string: decode must not
    /// panic (a panic fails the test), and any *accepted* mutation must
    /// decode to a snapshot whose own encode/decode cycle is stable —
    /// no silent drift to a third snapshot. A mutation that left the
    /// bytes untouched must decode to exactly the original.
    fn check_mutation(mutated: &[u8], good: &[u8],
                      original: &TrajectorySnapshot) {
        let Ok(decoded) = TrajectorySnapshot::decode(mutated) else {
            return; // rejected: exactly what corruption should get
        };
        let re = decoded.encode();
        let again = TrajectorySnapshot::decode(&re)
            .expect("re-encoding an accepted snapshot must decode");
        crate::prop_assert!(again.encode() == re,
                            "accepted mutation round-trips unstably");
        if mutated == good {
            crate::prop_assert!(decoded == *original,
                                "identity mutation changed the snapshot");
        }
    }

    #[test]
    fn codec_survives_generated_mutations() {
        use crate::util::propcheck::propcheck;
        propcheck(150, |g| {
            let snap = gen_snapshot(g);
            let good = snap.encode();
            // truncation at a random cut is always rejected
            let cut = g.usize_in(0, good.len() - 1);
            crate::prop_assert!(
                TrajectorySnapshot::decode(&good[..cut]).is_err(),
                "truncation at {cut}/{} accepted", good.len());
            // a single random bit flip
            let mut m = good.clone();
            let byte = g.usize_in(0, m.len() - 1);
            m[byte] ^= 1 << g.usize_in(0, 7);
            check_mutation(&m, &good, &snap);
            // a length-prefix lie: stomp 4 random-aligned bytes with a
            // random word (covers absurd lengths and internal
            // inconsistencies)
            let mut m = good.clone();
            let off = g.usize_in(0, m.len().saturating_sub(4));
            let lie = (g.u64() as u32).to_le_bytes();
            m[off..off + 4].copy_from_slice(&lie);
            check_mutation(&m, &good, &snap);
            // appending garbage is always rejected (no trailing bytes)
            let mut m = good.clone();
            m.push(g.u64() as u8);
            crate::prop_assert!(TrajectorySnapshot::decode(&m).is_err(),
                                "trailing byte accepted");
        });
    }

    #[test]
    fn snapshot_tolerates_empty_payloads() {
        // simulator snapshots carry no z / caches — the encoding must
        // round-trip the degenerate shape too
        let req = Request::new(9, 1, 3, 5);
        let snap = TrajectorySnapshot {
            req,
            timesteps: vec![999, 499, 99],
            cursor: 1,
            z: vec![],
            caches: vec![],
            skip_counts: vec![],
            modules_seen: vec![],
            admitted_us: 12345,
            steps_done: 1,
        };
        let back = TrajectorySnapshot::decode(&snap.encode()).unwrap();
        assert_eq!(back, snap);
        assert_eq!(back.pending_steps(), 2);
    }
}
