//! Request types and per-request trajectory state.

use crate::config::Slo;
use crate::tensor::Tensor;
use crate::util::prng::Rng;
use std::time::Instant;

/// A generation request as admitted by the router.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub class_label: usize,
    pub steps: usize,
    pub seed: u64,
    /// CFG guidance scale; 1.0 disables the uncond lane.
    pub cfg_scale: f32,
    /// Service-level objective class (wire `"slo"` field; defaults to
    /// best-effort for legacy request lines). The pool router uses it
    /// for tier-aware placement.
    pub slo: Slo,
}

impl Request {
    pub fn new(id: u64, class_label: usize, steps: usize, seed: u64) -> Request {
        Request {
            id,
            class_label,
            steps,
            seed,
            cfg_scale: 1.5,
            slo: Slo::Besteffort,
        }
    }

    /// Builder-style SLO tag (tests/benches).
    pub fn with_slo(mut self, slo: Slo) -> Request {
        self.slo = slo;
        self
    }

    /// Number of batch lanes this request occupies (CFG doubles).
    pub fn lanes(&self) -> usize {
        if self.cfg_scale > 1.0 {
            2
        } else {
            1
        }
    }
}

/// Per-lane cache store: one [N*D] vector per (layer, module).
#[derive(Debug, Clone)]
pub struct LaneCaches {
    pub values: Vec<Vec<f32>>, // [2L][N*D]
    pub valid: Vec<bool>,      // [2L]
}

impl LaneCaches {
    pub fn empty(depth: usize, nd: usize) -> LaneCaches {
        LaneCaches {
            values: vec![vec![0.0; nd]; 2 * depth],
            valid: vec![false; 2 * depth],
        }
    }
}

/// In-flight trajectory state owned by the engine.
#[derive(Debug)]
pub struct ActiveRequest {
    pub req: Request,
    /// Current latent z_t, flat [C*H*W].
    pub z: Vec<f32>,
    /// DDIM timestep subset (descending) and cursor.
    pub timesteps: Vec<usize>,
    pub cursor: usize,
    /// Per-lane caches: [0]=cond, [1]=uncond (if CFG).
    pub caches: Vec<LaneCaches>,
    /// Per-(layer,module) skip counts for this request.
    pub skip_counts: Vec<u32>,
    pub modules_seen: Vec<u32>,
    pub started: Instant,
    pub steps_done: usize,
}

impl ActiveRequest {
    pub fn new(req: Request, timesteps: Vec<usize>, depth: usize, nd: usize,
               img_elems: usize) -> ActiveRequest {
        let mut rng = Rng::new(req.seed ^ 0xD1FF_051F);
        let mut z = vec![0.0f32; img_elems];
        rng.fill_normal(&mut z);
        let lanes = req.lanes();
        ActiveRequest {
            req,
            z,
            timesteps,
            cursor: 0,
            caches: (0..lanes).map(|_| LaneCaches::empty(depth, nd)).collect(),
            skip_counts: vec![0; 2 * depth],
            modules_seen: vec![0; 2 * depth],
            started: Instant::now(),
            steps_done: 0,
        }
    }

    pub fn done(&self) -> bool {
        self.cursor >= self.timesteps.len()
    }

    /// Current timestep, or None when finished.
    pub fn current_t(&self) -> Option<usize> {
        self.timesteps.get(self.cursor).copied()
    }

    /// Next (lower) timestep, or -1 at the boundary.
    pub fn next_t(&self) -> isize {
        self.timesteps
            .get(self.cursor + 1)
            .map(|&t| t as isize)
            .unwrap_or(-1)
    }

    /// The paper's per-request lazy ratio Γ.
    pub fn lazy_ratio(&self) -> f64 {
        let seen: u32 = self.modules_seen.iter().sum();
        let skipped: u32 = self.skip_counts.iter().sum();
        skipped as f64 / seen.max(1) as f64
    }
}

/// Completed request: final image + accounting.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub class_label: usize,
    pub steps: usize,
    /// SLO class the request carried (echoed on the wire; per-tier
    /// completion accounting in the pool).
    pub slo: Slo,
    /// Final sample [C, H, W] flattened.
    pub image: Tensor,
    pub lazy_ratio: f64,
    pub attn_lazy_ratio: f64,
    pub ffn_lazy_ratio: f64,
    pub latency: std::time::Duration,
    /// Per-(layer,module) skip fractions, [2L].
    pub per_module_skip: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requests_default_to_besteffort_slo() {
        let r = Request::new(1, 0, 10, 0);
        assert_eq!(r.slo, Slo::Besteffort);
        let r = r.with_slo(Slo::Latency);
        assert_eq!(r.slo, Slo::Latency);
    }

    #[test]
    fn lanes_follow_cfg() {
        let mut r = Request::new(1, 0, 10, 0);
        assert_eq!(r.lanes(), 2);
        r.cfg_scale = 1.0;
        assert_eq!(r.lanes(), 1);
    }

    #[test]
    fn trajectory_state() {
        let req = Request::new(1, 3, 4, 7);
        let ar = ActiveRequest::new(req, vec![999, 749, 499, 249], 2, 16 * 32, 192);
        assert!(!ar.done());
        assert_eq!(ar.current_t(), Some(999));
        assert_eq!(ar.next_t(), 749);
        assert_eq!(ar.caches.len(), 2);
        assert_eq!(ar.caches[0].values.len(), 4);
        assert_eq!(ar.z.len(), 192);
        // z is standard-normal-ish, not all zeros
        assert!(ar.z.iter().any(|&v| v.abs() > 0.1));
    }

    #[test]
    fn noise_deterministic_by_seed() {
        let a = ActiveRequest::new(Request::new(1, 0, 2, 42), vec![999, 499], 1, 4, 12);
        let b = ActiveRequest::new(Request::new(2, 5, 2, 42), vec![999, 499], 1, 4, 12);
        assert_eq!(a.z, b.z, "same seed, same init noise");
        let c = ActiveRequest::new(Request::new(3, 0, 2, 43), vec![999, 499], 1, 4, 12);
        assert_ne!(a.z, c.z);
    }

    #[test]
    fn boundary_next_t() {
        let mut ar = ActiveRequest::new(Request::new(1, 0, 1, 0), vec![999], 1, 4, 12);
        assert_eq!(ar.next_t(), -1);
        ar.cursor = 1;
        assert!(ar.done());
        assert_eq!(ar.current_t(), None);
    }
}
