//! The denoise scheduler/engine: owns the active set, assembles batches
//! (continuous batching), drives the lazy block runner one step per round,
//! applies CFG + DDIM on the host, and retires finished requests.

use crate::config::ServeConfig;
use crate::coordinator::batcher::{plan_cap, plan_round, stabilize_plan,
                                  BatchPlan};
use crate::coordinator::request::{ActiveRequest, LaneCaches, Request,
                                  RequestResult, TrajectorySnapshot};
use crate::coordinator::stats::{LayerStats, ServeStats};
use crate::model::checkpoint::Checkpoint;
use crate::model::runner::{BatchCaches, DecisionCfg, ModelRunner, StepOutcome};
use crate::obs::ring::pack_pair;
use crate::obs::{EventKind, TraceEvent, Tracer};
use crate::runtime::engine_rt::Runtime;
use crate::runtime::manifest::Manifest;
use crate::sampler::cfg::combine_pair;
use crate::sampler::ddim::DdimSampler;
use crate::sampler::schedule::Schedule;
use crate::tensor::pool::TensorPool;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

/// Engine construction options beyond ServeConfig.
#[derive(Debug, Clone, Default)]
pub struct EngineOptions {
    /// Override gates with the disabled set (DDIM baseline).
    pub disable_gates: bool,
    /// Static per-(slot, step-index) skip schedule (Learn2Cache baseline);
    /// indexed [step_idx % len][slot].
    pub static_schedule: Option<Vec<Vec<bool>>>,
}

/// The serving engine (single-threaded over one PJRT client; concurrency
/// comes from batching, which is where diffusion serving wins anyway).
pub struct Engine {
    pub runner: ModelRunner,
    pub sampler: DdimSampler,
    pub serve: ServeConfig,
    pub options: EngineOptions,
    pub layer_stats: LayerStats,
    pub serve_stats: ServeStats,
    /// When present, accumulates consecutive-step module-output cosine
    /// similarities (the Learn2Cache-analog offline profiling pass).
    pub sim_profile: Option<crate::baselines::learn2cache::SimProfile>,
    active: Vec<ActiveRequest>,
    rr_cursor: usize,
    next_id: u64,
    /// Bucket set rounds are planned against, resolved once at
    /// construction: the tier's `ServeConfig::bucket_override`
    /// intersected with the compiled set (each bucket size is backed by
    /// an AOT-compiled executable, so a restriction can only narrow),
    /// or the full compiled set when there is no override or the
    /// intersection is empty.
    round_buckets: Vec<usize>,
    /// Persistent cross-round batch state: input tensors and module
    /// caches stay batch-resident between rounds, so unchanged slot
    /// membership costs zero cache copies per step (see [`sync_batch`]).
    batch: Option<BatchState>,
    /// This engine's buffer arena (shared with the runner's, so batch
    /// caches and step transients recycle into each other).
    pool: Rc<TensorPool>,
    /// Telemetry sink for batch-level span events (disabled by default;
    /// a traced pool replica installs one via
    /// [`crate::coordinator::pool::PoolEngine::install_tracer`], which
    /// also hands the runner a clone for per-module spans).
    tracer: Tracer,
    /// The configured gate threshold, kept so brownout gamma boosts are
    /// reversible: modules skip when their gate value *exceeds*
    /// `serve.threshold`, so a boost lowers the effective threshold and
    /// boost 0 must restore this exact value.
    base_threshold: f32,
    /// Per-step-index run/seen row counters — the calibration feed for
    /// `lazydit calibrate` and the pool's skip calendars
    /// ([`crate::coordinator::pool::PoolEngine::step_profile`]).
    step_profile: crate::coordinator::pool::calendar::StepProfile,
}

/// The engine's persistent batch: padded model inputs plus the
/// dual-representation caches, living across rounds. `rows[i]` names the
/// `(request id, lane)` occupying batch row `i` (None = padding); the
/// truth for a resident lane's caches is HERE, and its per-request
/// [`LaneCaches`] store is stale until the row is evicted or flushed.
struct BatchState {
    /// Padded batch width (an exported bucket size).
    bucket: usize,
    /// Row occupancy, `(request id, lane)` per row.
    rows: Vec<Option<(u64, usize)>>,
    /// Module output caches, batch-major, with memoized literals.
    caches: BatchCaches,
    /// Latent input rows `[B, C, H, W]` (refreshed every round — DDIM
    /// advances z on the host).
    z: Tensor,
    /// Per-row timesteps (refreshed every round).
    t: Vec<f32>,
    /// Per-row labels (cond label / null for uncond + padding).
    y: Vec<i32>,
}

impl BatchState {
    /// Clear every row a finished request occupied (no scatter-back:
    /// the trajectory is complete, its caches die with it).
    fn clear_request(&mut self, id: u64, null_y: i32) {
        for row in 0..self.bucket {
            if matches!(self.rows[row], Some((rid, _)) if rid == id) {
                self.rows[row] = None;
                self.caches.clear_row(row);
                self.z.row_mut(row).fill(0.0);
                self.t[row] = 0.0;
                self.y[row] = null_y;
            }
        }
    }
}

/// Copy one batch row's caches back into a lane store (row eviction /
/// flush). Only valid slots are copied; validity bits only ever rise,
/// matching the scatter semantics of the pre-resident engine.
fn scatter_row(caches: &BatchCaches, row: usize, lc: &mut LaneCaches) {
    for k in 0..caches.slots() {
        if caches.valid[k][row] {
            lc.valid[k] = true;
            lc.values[k].copy_from_slice(caches.value(k).row(row));
        }
    }
}

/// Reconcile the persistent batch with this round's (stabilized) plan.
///
/// Steady state — identical membership in identical rows — is a no-op:
/// zero cache copies, zero allocations, literal memos intact. Otherwise:
/// * bucket change: new state from the arena; rows present in both the
///   old and new occupancy migrate tensor-to-tensor via
///   `gather_rows_into` (one pass per slot, padding rows zeroed), rows
///   leaving scatter back to their lane stores;
/// * same bucket: two row-level passes — evict every mismatched row
///   (scatter to its lane store, so a later load of the same request
///   reads fresh data), then load incoming rows from their lane stores.
///
/// Returns `(rows_retained, rows_migrated)` for `ServeStats`.
#[allow(clippy::too_many_arguments)]
fn sync_batch(state: &mut Option<BatchState>, plan: &BatchPlan,
              active: &mut [ActiveRequest], pool: &Rc<TensorPool>,
              depth: usize, n: usize, d: usize, ztail: &[usize],
              null_y: i32) -> (u64, u64) {
    let b = plan.bucket;
    // desired occupancy, by row
    let mut desired: Vec<Option<(u64, usize)>> = vec![None; b];
    for (row, slot) in plan.lanes.iter().enumerate() {
        desired[row] = Some((active[slot.req_idx].req.id, slot.lane));
    }

    let mut carried = 0u64;
    let rebucket = !matches!(state, Some(s) if s.bucket == b);
    if rebucket {
        let mut zshape = vec![b];
        zshape.extend_from_slice(ztail);
        let mut fresh = BatchState {
            bucket: b,
            rows: vec![None; b],
            caches: BatchCaches::with_pool(pool.clone(), depth, b, n, d),
            z: pool.acquire(&zshape),
            t: vec![0.0; b],
            y: vec![null_y; b],
        };
        if let Some(old) = state.take() {
            // carryover map: new row -> old row holding the same lane
            let idx: Vec<usize> = desired
                .iter()
                .map(|&want| {
                    want.and_then(|key| {
                        old.rows.iter().position(|&o| o == Some(key))
                    })
                    .unwrap_or(usize::MAX)
                })
                .collect();
            fresh.caches.gather_from(&old.caches, &idx);
            for (r, &i) in idx.iter().enumerate() {
                if i != usize::MAX {
                    fresh.rows[r] = desired[r];
                    carried += 1;
                }
            }
            // rows leaving the batch entirely: back to their lane store
            for (orow, occ) in old.rows.iter().enumerate() {
                if let Some((id, lane)) = *occ {
                    if !idx.contains(&orow) {
                        if let Some(ar) =
                            active.iter_mut().find(|a| a.req.id == id)
                        {
                            scatter_row(&old.caches, orow,
                                        &mut ar.caches[lane]);
                        }
                    }
                }
            }
            old.caches.release_into_pool();
            pool.release(old.z);
        }
        *state = Some(fresh);
    }

    let state = state.as_mut().expect("just ensured");
    let (mut retained, mut migrated) = (0u64, 0u64);
    // pass 1: evict every mismatched occupied row BEFORE any load, so a
    // request moving between rows never reads its own stale lane store
    for row in 0..b {
        let want = desired[row];
        if state.rows[row] == want {
            if want.is_some() {
                retained += 1;
            }
            continue;
        }
        if let Some((id, lane)) = state.rows[row] {
            if let Some(ar) = active.iter_mut().find(|a| a.req.id == id) {
                scatter_row(&state.caches, row, &mut ar.caches[lane]);
            }
            state.caches.clear_row(row);
            state.rows[row] = None;
            migrated += 1;
            if want.is_none() {
                state.z.row_mut(row).fill(0.0);
                state.t[row] = 0.0;
                state.y[row] = null_y;
            }
        }
    }
    // pass 2: load incoming rows from their (now fresh) lane stores
    for row in 0..b {
        if state.rows[row].is_none() {
            if let Some((id, lane)) = desired[row] {
                let ar = active
                    .iter()
                    .find(|a| a.req.id == id)
                    .expect("planned request is active");
                let lc = &ar.caches[lane];
                for k in 0..state.caches.slots() {
                    state.caches.valid[k][row] = lc.valid[k];
                    if lc.valid[k] {
                        state.caches.write_row(k, row, &lc.values[k]);
                    }
                }
                state.rows[row] = Some((id, lane));
                migrated += 1;
            }
        }
    }
    // gather-carried rows matched in pass 1 but did pay a row copy
    (retained - carried, migrated + carried)
}

/// Detach one request from the engine at the current step boundary:
/// flush its batch-resident rows back into its lane stores (the same
/// scatter semantics as [`flush_batch`]), vacate the rows, and remove
/// it from the active set. The returned [`ActiveRequest`] is fully
/// self-contained — packaging it as a [`TrajectorySnapshot`] and
/// resuming anywhere is bit-identical to never having evicted (see the
/// `evicted_trajectory_resumes_bit_identically` propcheck). Free
/// function so tests can drive it against simulated batch states.
fn detach_request(state: &mut Option<BatchState>,
                  active: &mut Vec<ActiveRequest>, id: u64,
                  null_y: i32) -> Option<ActiveRequest> {
    let idx = active.iter().position(|a| a.req.id == id)?;
    if let Some(st) = state.as_mut() {
        for row in 0..st.bucket {
            if let Some((rid, lane)) = st.rows[row] {
                if rid == id {
                    scatter_row(&st.caches, row,
                                &mut active[idx].caches[lane]);
                }
            }
        }
        st.clear_request(id, null_y);
    }
    Some(active.remove(idx))
}

/// Scatter every resident row back to its lane store and drop the
/// persistent batch (profiling rounds diff the lane stores, so they
/// need them current; also releases the buffers to the arena).
fn flush_batch(state: &mut Option<BatchState>, active: &mut [ActiveRequest],
               pool: &Rc<TensorPool>) {
    let Some(st) = state.take() else { return };
    for row in 0..st.bucket {
        if let Some((id, lane)) = st.rows[row] {
            if let Some(ar) = active.iter_mut().find(|a| a.req.id == id) {
                scatter_row(&st.caches, row, &mut ar.caches[lane]);
            }
        }
    }
    st.caches.release_into_pool();
    pool.release(st.z);
}

/// Resolve the effective bucket set for `round_buckets` (see the field
/// docs); pure so both constructors share it.
fn effective_buckets(compiled: &[usize],
                     serve: &crate::config::ServeConfig) -> Vec<usize> {
    if let Some(ov) = &serve.bucket_override {
        let restricted: Vec<usize> =
            compiled.iter().copied().filter(|b| ov.contains(b)).collect();
        if !restricted.is_empty() {
            return restricted;
        }
    }
    compiled.to_vec()
}

impl Engine {
    /// Build an engine from artifacts + checkpoints.
    pub fn from_artifacts(artifacts: &Path, ckpt_dir: &Path, serve: ServeConfig,
                          options: EngineOptions, gates_tag: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts)?;
        let cfg = manifest.config(&serve.config_name)?.clone();
        let rt = Rc::new(Runtime::cpu()?);

        let theta_path =
            crate::model::checkpoint::theta_path(ckpt_dir, &serve.config_name);
        let theta_ck = Checkpoint::load(&theta_path).with_context(|| {
            format!("base checkpoint missing — run `lazydit pretrain --config {}`",
                    serve.config_name)
        })?;
        let theta = theta_ck.vec("theta")?.clone();

        let runner = if options.disable_gates {
            ModelRunner::with_disabled_gates(rt, cfg.clone(), &theta)?
        } else {
            let gpath = crate::model::checkpoint::gates_path(
                ckpt_dir, &serve.config_name, gates_tag);
            let gck = Checkpoint::load(&gpath).with_context(|| {
                format!("gate checkpoint '{gates_tag}' missing — run \
                         `lazydit lazy-train --config {}`", serve.config_name)
            })?;
            ModelRunner::new(Rc::new(Runtime::cpu()?), cfg.clone(), &theta,
                             gck.vec("gamma")?)?
        };

        let schedule = Schedule::linear(cfg.diffusion.timesteps,
                                        cfg.diffusion.beta_start,
                                        cfg.diffusion.beta_end);
        let depth = cfg.model.depth;
        let round_buckets = effective_buckets(&cfg.buckets, &serve);
        let mut runner = runner;
        // the partial (run-rows sub-batch) path may only compact to
        // widths inside this engine's round-bucket set — a tier-
        // restricted replica must not lazily load executables outside
        // its provisioned footprint
        runner.restrict_partial_buckets(&round_buckets);
        let pool = runner.pool().clone();
        let base_threshold = serve.threshold;
        Ok(Engine {
            runner,
            sampler: DdimSampler::new(schedule),
            serve,
            options,
            layer_stats: LayerStats::new(depth),
            serve_stats: ServeStats::default(),
            sim_profile: None,
            active: Vec::new(),
            rr_cursor: 0,
            next_id: 1,
            round_buckets,
            batch: None,
            pool,
            tracer: Tracer::disabled(),
            base_threshold,
            step_profile: crate::coordinator::pool::calendar::StepProfile::new(),
        })
    }

    /// Build an engine from in-memory parameters (tests, training loops).
    pub fn from_parts(mut runner: ModelRunner, serve: ServeConfig,
                      options: EngineOptions) -> Engine {
        let schedule = Schedule::linear(runner.cfg.diffusion.timesteps,
                                        runner.cfg.diffusion.beta_start,
                                        runner.cfg.diffusion.beta_end);
        let depth = runner.cfg.model.depth;
        let round_buckets = effective_buckets(&runner.cfg.buckets, &serve);
        // keep the partial path inside this engine's round-bucket set
        runner.restrict_partial_buckets(&round_buckets);
        let pool = runner.pool().clone();
        let base_threshold = serve.threshold;
        Engine {
            runner,
            sampler: DdimSampler::new(schedule),
            serve,
            options,
            layer_stats: LayerStats::new(depth),
            serve_stats: ServeStats::default(),
            sim_profile: None,
            active: Vec::new(),
            rr_cursor: 0,
            next_id: 1,
            round_buckets,
            batch: None,
            pool,
            tracer: Tracer::disabled(),
            base_threshold,
            step_profile: crate::coordinator::pool::calendar::StepProfile::new(),
        }
    }

    pub fn next_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Admit a request into the active set.
    pub fn submit(&mut self, mut req: Request) -> u64 {
        if req.id == 0 {
            req.id = self.next_id();
        }
        let id = req.id;
        // the protocol edge bounds steps (server::MAX_STEPS), but
        // programmatic callers can pass anything — clamp to this
        // engine's schedule instead of panicking a worker thread
        let max_steps = self.sampler.schedule.timesteps;
        let clamped = req.steps.clamp(1, max_steps);
        if clamped != req.steps {
            log::warn!("request {id}: steps {} clamped to {clamped} \
                        (schedule has {max_steps})", req.steps);
            req.steps = clamped;
        }
        // same guard for lanes: the pool router filters replicas that
        // cannot fit a request, but programmatic callers can submit a
        // 2-lane CFG request into an engine whose plannable cap is 1 —
        // plan_round could then never include it and step_round would
        // make no progress forever. Degrade to the cond-only lane
        // instead of wedging the engine. `plan_cap` is the same rule
        // plan_round packs against, so guard and planner cannot diverge.
        let lane_cap =
            plan_cap(&self.round_buckets, self.serve.max_batch).max(1);
        if req.lanes() > lane_cap {
            log::warn!("request {id}: {} lanes exceed this engine's \
                        plannable cap {lane_cap} — dropping the uncond \
                        lane (cfg_scale forced to 1.0)", req.lanes());
            req.cfg_scale = 1.0;
        }
        let m = &self.runner.cfg.model;
        let nd = m.tokens() * m.dim;
        let ts = self.sampler.schedule.ddim_timesteps(req.steps);
        self.active.push(ActiveRequest::new(req, ts, m.depth, nd,
                                            m.img_elems()));
        id
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Ids of every active trajectory, in admission order.
    pub fn active_ids(&self) -> Vec<u64> {
        self.active.iter().map(|a| a.req.id).collect()
    }

    /// Evict an active trajectory at the current step boundary into a
    /// portable snapshot: batch residency flushes to the lane stores
    /// first ([`detach_request`]), so the snapshot's caches are current
    /// and resuming it — here or on a sibling replica — is
    /// bit-identical to an uninterrupted run. `None` for unknown ids.
    pub fn evict_to_snapshot(&mut self, id: u64)
                             -> Option<TrajectorySnapshot> {
        let null_y = self.runner.cfg.model.null_label() as i32;
        let ar = detach_request(&mut self.batch, &mut self.active, id,
                                null_y)?;
        Some(ar.into_snapshot())
    }

    /// Admit a previously evicted trajectory, resuming at its cursor.
    /// Snapshot ids are pool-unique and kept; `next_id` advances past
    /// them so later fresh submissions cannot collide.
    pub fn admit_snapshot(&mut self, snap: TrajectorySnapshot) -> u64 {
        let id = snap.req.id;
        self.next_id = self.next_id.max(id.saturating_add(1));
        self.serve_stats.resumed += 1;
        self.serve_stats.resume_steps_saved += snap.cursor as u64;
        self.active.push(ActiveRequest::from_snapshot(snap));
        id
    }

    /// Admit a request warm-started from a same-family donor's lane
    /// caches (pool result-cache near hit). The donor is validated
    /// against the request *as admitted* (after the step/lane clamps):
    /// family fields must match and every donor lane must have this
    /// model's exact `[2L][N*D]` shape — any mismatch admits the
    /// request cold and returns 0 seeded rows, which is always safe.
    /// On success the donor's valid rows are copied into the joiner's
    /// lane stores and marked valid, so the cache gate sees warm rows
    /// at step 0 instead of denying its would-skips cold. Seeded rows
    /// are counted as `rows_warmed` in `LayerStats`.
    pub fn submit_warm(&mut self, req: Request, donor: &TrajectorySnapshot)
                       -> (u64, u64) {
        let id = self.submit(req);
        let Some(ar) = self.active.iter_mut().find(|a| a.req.id == id)
        else {
            return (id, 0);
        };
        let family_ok = donor.req.class_label == ar.req.class_label
            && donor.req.steps == ar.req.steps
            && donor.req.cfg_scale.to_bits() == ar.req.cfg_scale.to_bits();
        if !family_ok || donor.cursor == 0
            || donor.caches.len() != ar.caches.len()
        {
            return (id, 0);
        }
        let shape_ok = donor.caches.iter().zip(&ar.caches).all(|(d, own)| {
            d.values.len() == own.values.len()
                && d.valid.len() == own.valid.len()
                && d.values
                    .iter()
                    .zip(&own.values)
                    .all(|(dv, ov)| dv.len() == ov.len())
        });
        if !shape_ok {
            return (id, 0);
        }
        let mut rows = 0u64;
        let mut seeded_slots: Vec<u64> = vec![0; donor.caches[0].valid.len()];
        for (dl, ol) in donor.caches.iter().zip(ar.caches.iter_mut()) {
            for k in 0..dl.valid.len() {
                if dl.valid[k] {
                    ol.values[k].copy_from_slice(&dl.values[k]);
                    ol.valid[k] = true;
                    rows += 1;
                    seeded_slots[k] += 1;
                }
            }
        }
        for (k, &n) in seeded_slots.iter().enumerate() {
            if n > 0 {
                self.layer_stats.record_rows_warmed(k, n);
            }
        }
        (id, rows)
    }

    /// Copy an active trajectory's state as of the last completed step
    /// boundary without disturbing residency: resident rows are
    /// scattered into a *clone* of the lane stores, never the live
    /// ones. The crash-resume stash the pool worker refreshes between
    /// rounds.
    pub fn snapshot_request(&self, id: u64) -> Option<TrajectorySnapshot> {
        let ar = self.active.iter().find(|a| a.req.id == id)?;
        let mut caches = ar.caches.clone();
        if let Some(st) = &self.batch {
            for row in 0..st.bucket {
                if let Some((rid, lane)) = st.rows[row] {
                    if rid == id {
                        scatter_row(&st.caches, row, &mut caches[lane]);
                    }
                }
            }
        }
        Some(TrajectorySnapshot {
            req: ar.req.clone(),
            timesteps: ar.timesteps.clone(),
            cursor: ar.cursor,
            z: ar.z.clone(),
            caches,
            skip_counts: ar.skip_counts.clone(),
            modules_seen: ar.modules_seen.clone(),
            admitted_us: ar.admitted_us,
            steps_done: ar.steps_done,
        })
    }

    /// Remaining denoise steps across the active set — the replica pool's
    /// backlog unit for lazy-aware routing.
    pub fn pending_steps(&self) -> usize {
        self.active
            .iter()
            .map(|a| a.timesteps.len().saturating_sub(a.cursor))
            .sum()
    }

    /// Run one scheduling round (one denoise step for the selected batch).
    /// Returns finished requests.
    pub fn step_round(&mut self) -> Result<Vec<RequestResult>> {
        let lane_counts: Vec<usize> =
            self.active.iter().map(|a| a.req.lanes()).collect();
        let Some(mut plan) = plan_round(&lane_counts, self.rr_cursor,
                                         self.serve.max_batch,
                                         &self.round_buckets) else {
            return Ok(Vec::new());
        };
        // pin already-resident lanes to their rows so rotation churn in
        // plan order doesn't defeat the persistent batch (steady state
        // must be a row-for-row match)
        if let Some(state) = &self.batch {
            let active = &self.active;
            stabilize_plan(&mut plan, &state.rows,
                           |idx| active[idx].req.id);
        }
        self.rr_cursor = self.rr_cursor.wrapping_add(1);
        let outcome = self.run_plan(&plan)?;
        self.apply_outcome(&plan, outcome)?;
        Ok(self.retire_finished())
    }

    /// Closed-loop: run rounds until all active requests finish.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestResult>> {
        let start = Instant::now();
        let mut out = Vec::new();
        while !self.active.is_empty() {
            let finished = self.step_round()?;
            out.extend(finished);
        }
        self.serve_stats.wall_s += start.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Run one model step for a plan against the *persistent* batch:
    /// the repack ([`sync_batch`]) touches caches only for joins/leaves
    /// (steady state: zero copies), then the runner steps the resident
    /// tensors in place. Profiling rounds fall back to the scratch path
    /// (they diff the per-lane stores, which residency leaves stale).
    fn run_plan(&mut self, plan: &BatchPlan) -> Result<StepOutcome> {
        if self.sim_profile.is_some() {
            self.flush_batch_state();
            return self.run_plan_scratch(plan);
        }
        // copy out the scalar dims up front — cloning the whole
        // ModelConfig (heap Strings included) per step would put an
        // allocation right back on the path this exists to clear
        let (depth, n, d, img, null_y, ztail) = {
            let m = &self.runner.cfg.model;
            (m.depth, m.tokens(), m.dim, m.img_elems(),
             m.null_label() as i32, [m.channels, m.img_size, m.img_size])
        };
        let bb_start = self.tracer.now_us();
        let (retained, migrated) =
            sync_batch(&mut self.batch, plan, &mut self.active, &self.pool,
                       depth, n, d, &ztail, null_y);
        self.serve_stats.rows_retained += retained;
        self.serve_stats.rows_migrated += migrated;
        if self.tracer.is_enabled() {
            let now = self.tracer.now_us();
            self.tracer.record_at(TraceEvent {
                kind: EventKind::BatchBuild,
                ts_us: bb_start,
                dur_us: now.saturating_sub(bb_start),
                kind_id: plan.bucket as u64,
                arg: pack_pair(plan.lanes.len() as u32, plan.bucket as u32),
            });
            self.tracer.record_at(TraceEvent {
                kind: EventKind::Scatter,
                ts_us: now,
                dur_us: 0,
                kind_id: plan.bucket as u64,
                arg: pack_pair(retained as u32, migrated as u32),
            });
        }

        // refresh the dynamic inputs (DDIM advances z on the host and
        // the cursor advances t every step; caches need no refresh)
        {
            let state = self.batch.as_mut().expect("synced");
            for (row, slot) in plan.lanes.iter().enumerate() {
                let ar = &self.active[slot.req_idx];
                let ct = ar
                    .current_t()
                    .context("scheduled a finished request")?;
                state.z.row_mut(row).copy_from_slice(&ar.z[..img]);
                state.t[row] = ct as f32;
                state.y[row] = if slot.lane == 0 {
                    ar.req.class_label as i32
                } else {
                    null_y
                };
            }
        }

        let forced = self.forced_row(plan);
        let live = plan.live_mask();
        let pairs = plan.pair_mask();
        let dec = DecisionCfg {
            policy: self.serve.policy,
            scope: self.serve.scope,
            threshold: self.serve.threshold,
            row_granular: self.serve.row_granular,
        };
        let state = self.batch.as_mut().expect("synced");
        self.runner.step_with_forced(plan.bucket, &state.z, &state.t,
                                     &state.y, &live, &pairs,
                                     &mut state.caches, dec,
                                     forced.as_deref())
    }

    /// The Learn2Cache-analog static schedule's [2L] mask row for this
    /// round, when a schedule is configured: the first lane's cursor
    /// drives the row index, and only that row is cloned — never the
    /// whole schedule. Shared by the resident and scratch step paths so
    /// their row selection can never diverge.
    fn forced_row(&self, plan: &BatchPlan) -> Option<Vec<bool>> {
        self.options.static_schedule.as_ref().map(|sched| {
            let step_idx = plan
                .lanes
                .first()
                .map(|s| self.active[s.req_idx].cursor)
                .unwrap_or(0);
            sched[step_idx % sched.len()].clone()
        })
    }

    /// Scratch-batch path (similarity profiling): rebuild the batch from
    /// the per-lane stores every round, exactly the pre-resident engine,
    /// with buffers drawn from the arena instead of fresh allocations.
    fn run_plan_scratch(&mut self, plan: &BatchPlan) -> Result<StepOutcome> {
        let b = plan.bucket;
        let (depth, n, d, img, null_y, channels, img_size) = {
            let m = &self.runner.cfg.model;
            (m.depth, m.tokens(), m.dim, m.img_elems(),
             m.null_label() as i32, m.channels, m.img_size)
        };

        let mut z = self.pool.acquire(&[b, channels, img_size, img_size]);
        let mut t = vec![0.0f32; b];
        let mut y = vec![null_y; b];
        let mut caches =
            BatchCaches::with_pool(self.pool.clone(), depth, b, n, d);

        for (row, slot) in plan.lanes.iter().enumerate() {
            let ar = &self.active[slot.req_idx];
            let ct = ar
                .current_t()
                .context("scheduled a finished request")?;
            z.row_mut(row).copy_from_slice(&ar.z[..img]);
            t[row] = ct as f32;
            y[row] = if slot.lane == 0 {
                ar.req.class_label as i32
            } else {
                null_y
            };
            let lc = &ar.caches[slot.lane];
            for k in 0..2 * depth {
                caches.valid[k][row] = lc.valid[k];
                if lc.valid[k] {
                    caches.write_row(k, row, &lc.values[k]);
                }
            }
        }

        let forced = self.forced_row(plan);
        let live = plan.live_mask();
        let pairs = plan.pair_mask();
        let dec = DecisionCfg {
            policy: self.serve.policy,
            scope: self.serve.scope,
            threshold: self.serve.threshold,
            row_granular: self.serve.row_granular,
        };
        let outcome = self.runner.step_with_forced(
            plan.bucket, &z, &t, &y, &live, &pairs, &mut caches, dec,
            forced.as_deref())?;

        // similarity profiling (Learn2Cache-analog offline pass): cosine
        // between each lane's previous module output (still in the
        // per-lane store) and the fresh one (now in the batch caches).
        if self.sim_profile.is_some() {
            let mut records: Vec<(usize, usize, f64)> = Vec::new();
            for (row, slot) in plan.lanes.iter().enumerate() {
                let ar = &self.active[slot.req_idx];
                for k in 0..2 * depth {
                    // per-row: a partial slot produced fresh output only
                    // for its run-rows
                    if ar.caches[slot.lane].valid[k] && caches.valid[k][row]
                        && !outcome.row_skipped(k, row)
                    {
                        let cos = slice_cosine(&ar.caches[slot.lane].values[k],
                                               caches.value(k).row(row));
                        records.push((ar.cursor, k, cos));
                    }
                }
            }
            let prof = self.sim_profile.as_mut().unwrap();
            for (cursor, k, cos) in records {
                prof.record(cursor, k, cos);
            }
        }

        // scatter caches back to the owning lanes
        for (row, slot) in plan.lanes.iter().enumerate() {
            let ar = &mut self.active[slot.req_idx];
            scatter_row(&caches, row, &mut ar.caches[slot.lane]);
        }
        caches.release_into_pool();
        self.pool.release(z);
        Ok(outcome)
    }

    /// Scatter every resident row back to its lane store and release the
    /// persistent batch into the arena (profiling prologue).
    fn flush_batch_state(&mut self) {
        flush_batch(&mut self.batch, &mut self.active, &self.pool);
    }

    /// Fold a step outcome into per-request state: CFG combine, DDIM
    /// update, cursor advance, accounting.
    fn apply_outcome(&mut self, plan: &BatchPlan, outcome: StepOutcome)
                     -> Result<()> {
        let depth = self.runner.cfg.model.depth;
        // engine-level per-layer stats (one live mask for all 2L slots —
        // rebuilding it per slot would put 2L allocations back per step)
        let live = plan.live_mask();
        for k in 0..2 * depth {
            let mean_s = outcome.s_vals[k]
                .iter()
                .zip(live.iter())
                .filter(|(_, &lv)| lv)
                .map(|(&s, _)| s as f64)
                .sum::<f64>()
                / plan.lanes.len().max(1) as f64;
            self.layer_stats.record(k, outcome.skipped[k], mean_s);
            // row-weighted work: laziness accounted per row, not per
            // whole-module boolean — partial slots contribute both run
            // and skipped rows, and `rows_recovered` is the share only
            // row granularity could skip
            self.layer_stats.record_rows(
                k,
                outcome.rows_run[k] as u64,
                outcome.rows_skipped[k] as u64,
                outcome.rows_recovered[k] as u64,
            );
            if outcome.skip_denied_cold.get(k).copied().unwrap_or(false) {
                // the gates wanted a skip; a cold (freshly-joined) row
                // forced a run — the whole batch under the coupled
                // gate, just the cold row (and its CFG partner) under
                // row granularity (STATS `cold_denied`)
                self.layer_stats.record_cold_denied(k);
            }
            self.serve_stats.module_invocations += 1;
            if outcome.skipped[k] {
                self.serve_stats.module_skips += 1;
            }
        }

        // per-request: find each request's lane rows
        let mut row = 0usize;
        while row < plan.lanes.len() {
            let slot = plan.lanes[row];
            let ar = &mut self.active[slot.req_idx];
            let lanes = ar.req.lanes();
            let eps_req = if lanes == 2 {
                let cond =
                    Tensor::from_vec(&[outcome.eps.row_len()],
                                     outcome.eps.row(row).to_vec())?;
                let unc =
                    Tensor::from_vec(&[outcome.eps.row_len()],
                                     outcome.eps.row(row + 1).to_vec())?;
                combine_pair(&cond, &unc, ar.req.cfg_scale)
            } else {
                Tensor::from_vec(&[outcome.eps.row_len()],
                                 outcome.eps.row(row).to_vec())?
            };
            // DDIM update
            let t_cur = ar.current_t().context("finished in apply")? as isize;
            let t_next = ar.next_t();
            let mut zt = Tensor::from_vec(&[ar.z.len()], ar.z.clone())?;
            self.sampler.step(&mut zt, &eps_req, t_cur, t_next);
            ar.z.copy_from_slice(zt.data());
            // skip accounting (per request: a module counts once per
            // step, read from the request's own row — CFG lanes are
            // pair-coupled, so the first lane's bit speaks for both)
            let step = ar.cursor;
            let mut run_rows = 0u64;
            for k in 0..2 * depth {
                ar.modules_seen[k] += 1;
                if outcome.row_skipped(k, row) {
                    ar.skip_counts[k] += 1;
                } else {
                    run_rows += 1;
                }
            }
            self.step_profile.record(step, run_rows, 2 * depth as u64);
            ar.cursor += 1;
            ar.steps_done += 1;
            row += lanes;
        }
        Ok(())
    }

    fn retire_finished(&mut self) -> Vec<RequestResult> {
        let m = &self.runner.cfg.model;
        let shape = [m.channels, m.img_size, m.img_size];
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].done() {
                let ar = self.active.remove(i);
                let total_attn: u32 =
                    (0..m.depth).map(|l| ar.modules_seen[2 * l]).sum();
                let skip_attn: u32 =
                    (0..m.depth).map(|l| ar.skip_counts[2 * l]).sum();
                let total_ffn: u32 =
                    (0..m.depth).map(|l| ar.modules_seen[2 * l + 1]).sum();
                let skip_ffn: u32 =
                    (0..m.depth).map(|l| ar.skip_counts[2 * l + 1]).sum();
                // end-to-end latency from the epoch admission stamp:
                // survives migration, and the finishing replica
                // reports the full figure exactly once
                let latency = std::time::Duration::from_micros(
                    crate::obs::epoch_us().saturating_sub(ar.admitted_us));
                self.serve_stats.completed += 1;
                self.serve_stats.record_latency(latency.as_secs_f64());
                out.push(RequestResult {
                    id: ar.req.id,
                    class_label: ar.req.class_label,
                    steps: ar.req.steps,
                    slo: ar.req.slo,
                    image: Tensor::from_vec(&shape, ar.z).expect("shape"),
                    lazy_ratio: ar
                        .skip_counts
                        .iter()
                        .sum::<u32>() as f64
                        / ar.modules_seen.iter().sum::<u32>().max(1) as f64,
                    attn_lazy_ratio: skip_attn as f64 / total_attn.max(1) as f64,
                    ffn_lazy_ratio: skip_ffn as f64 / total_ffn.max(1) as f64,
                    latency,
                    per_module_skip: (0..2 * m.depth)
                        .map(|k| ar.skip_counts[k] as f64
                             / ar.modules_seen[k].max(1) as f64)
                        .collect(),
                });
            } else {
                i += 1;
            }
        }
        // a finished trajectory's resident rows die with it — no
        // scatter-back, just vacate the rows for the next joiner
        if let Some(state) = &mut self.batch {
            let null_y = self.runner.cfg.model.null_label() as i32;
            for r in &out {
                state.clear_request(r.id, null_y);
            }
        }
        out
    }
}

/// The real engine drives a pool replica through the same surface the
/// synthetic engine implements (coordinator::pool).
impl crate::coordinator::pool::PoolEngine for Engine {
    fn submit(&mut self, req: Request) -> u64 {
        Engine::submit(self, req)
    }

    fn active_count(&self) -> usize {
        Engine::active_count(self)
    }

    fn pending_steps(&self) -> usize {
        Engine::pending_steps(self)
    }

    fn step_round(&mut self) -> Result<Vec<RequestResult>> {
        Engine::step_round(self)
    }

    fn layer_stats(&self) -> &LayerStats {
        &self.layer_stats
    }

    fn serve_stats(&self) -> &crate::coordinator::stats::ServeStats {
        &self.serve_stats
    }

    fn policy_name(&self) -> String {
        self.serve.policy.name().to_string()
    }

    fn step_profile(&self)
                    -> Option<&crate::coordinator::pool::calendar::StepProfile> {
        Some(&self.step_profile)
    }

    fn arena_stats(&self) -> Option<crate::tensor::pool::PoolStats> {
        Some(self.pool.stats())
    }

    fn install_tracer(&mut self, tracer: Tracer) {
        // the runner gets a clone so per-module run/skip spans carry
        // real durations; the engine keeps its own for batch-level
        // events (both share one ring through the Arc)
        self.runner.install_tracer(tracer.clone());
        self.tracer = tracer;
    }

    fn active_ids(&self) -> Vec<u64> {
        Engine::active_ids(self)
    }

    fn evict_to_snapshot(&mut self, id: u64) -> Option<TrajectorySnapshot> {
        Engine::evict_to_snapshot(self, id)
    }

    fn admit_snapshot(&mut self, snap: TrajectorySnapshot) -> u64 {
        Engine::admit_snapshot(self, snap)
    }

    fn snapshot_request(&self, id: u64) -> Option<TrajectorySnapshot> {
        Engine::snapshot_request(self, id)
    }

    fn submit_warm(&mut self, req: Request, donor: &TrajectorySnapshot)
                   -> (u64, u64) {
        Engine::submit_warm(self, req, donor)
    }

    fn set_gamma_boost(&mut self, boost: u32) {
        // Modules skip when their gate value exceeds `serve.threshold`
        // (see `model::runner::decide`), so raising target laziness
        // means lowering the bar. Scale from the configured base — not
        // the current value — so repeated boosts don't compound and
        // boost 0 restores the tier's configured gate exactly.
        let scale = 1.0 - (boost.min(95) as f32) / 100.0;
        self.serve.threshold = self.base_threshold * scale;
    }
}

/// Cosine similarity between two equal-length slices.
fn slice_cosine(a: &[f32], b: &[f32]) -> f64 {
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        dot += (x * y) as f64;
        na += (x * x) as f64;
        nb += (y * y) as f64;
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

/// Convenience: generate a batch of images closed-loop and return results
/// sorted by id.
pub fn generate_batch(engine: &mut Engine, labels: &[usize], steps: usize,
                      seed: u64, cfg_scale: f32) -> Result<Vec<RequestResult>> {
    for (i, &lab) in labels.iter().enumerate() {
        let id = engine.next_id();
        let mut req = Request::new(id, lab, steps, seed.wrapping_add(i as u64));
        req.cfg_scale = cfg_scale;
        engine.submit(req);
    }
    let mut res = engine.run_to_completion()?;
    res.sort_by_key(|r| r.id);
    if res.len() != labels.len() {
        bail!("lost requests: {} of {}", res.len(), labels.len());
    }
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::coordinator::batcher::LaneSlot;
    use crate::runtime::value::HostValue;
    use crate::util::propcheck::propcheck;

    /// Test double for the runner's run path: install a fresh "module
    /// output" whose live rows carry occupant-derived values (so any
    /// row misplacement shows up as a value mismatch) and whose padding
    /// rows carry a per-round garbage sentinel (so padding leakage
    /// shows up too), then mark live rows valid — exactly the cache
    /// mutations `step_with_forced` performs on a run.
    fn sim_run(caches: &mut BatchCaches, k: usize, bucket: usize, nd: usize,
               plan: &BatchPlan, active: &[ActiveRequest], round: usize) {
        let mut data = vec![-7.0 - round as f32; bucket * nd];
        for (row, slot) in plan.lanes.iter().enumerate() {
            let id = active[slot.req_idx].req.id;
            let v = (id * 1000 + slot.lane as u64 * 100 + k as u64) as f32
                + round as f32 * 0.125;
            data[row * nd..(row + 1) * nd].fill(v);
        }
        let f = Tensor::from_vec(&[bucket, 1, nd], data).unwrap();
        let lit = HostValue::f32_literal(&f).unwrap();
        caches.store_fresh(k, f, lit);
        for row in 0..plan.lanes.len() {
            caches.valid[k][row] = true;
        }
    }

    /// Test double for the runner's PARTIAL path: compact the run rows
    /// (live && !mask) through a real [`RowPartition`], fill the
    /// sub-batch with the same occupant-derived values `sim_run` uses,
    /// and scatter it back via `scatter_fresh` — skip rows keep their
    /// cached bytes, exactly the row-granular cache mutations of
    /// `step_with_forced`.
    fn sim_run_partial(caches: &mut BatchCaches, k: usize, bucket: usize,
                       nd: usize, plan: &BatchPlan,
                       active: &[ActiveRequest], round: usize,
                       mask: &[bool]) {
        use crate::model::runner::RowPartition;
        let live = plan.live_mask();
        let mut part = RowPartition::default();
        part.plan(mask, &live, &[1, 2, 4, 8, 16], bucket);
        let mut data = vec![-7.0 - round as f32; part.bucket * nd];
        for (j, &row) in part.run_idx.iter().enumerate() {
            if row == usize::MAX {
                continue;
            }
            let slot = plan.lanes[row];
            let id = active[slot.req_idx].req.id;
            let v = (id * 1000 + slot.lane as u64 * 100 + k as u64) as f32
                + round as f32 * 0.125;
            data[j * nd..(j + 1) * nd].fill(v);
        }
        let sub = Tensor::from_vec(&[part.bucket, 1, nd], data).unwrap();
        caches.scatter_fresh(k, &sub, &part.run_idx);
    }

    fn mk_active(nreq: usize, steps: usize, depth: usize, nd: usize)
                 -> Vec<ActiveRequest> {
        (0..nreq)
            .map(|i| {
                let mut req = Request::new(1 + i as u64, i, steps, i as u64);
                req.cfg_scale = if i % 2 == 0 { 1.0 } else { 1.5 };
                ActiveRequest::new(req, vec![999; steps], depth, nd, 4)
            })
            .collect()
    }

    fn cache_ok(valid: &[bool], live: &[bool]) -> bool {
        live.iter()
            .enumerate()
            .filter(|(_, &lv)| lv)
            .all(|(i, _)| valid[i])
    }

    #[test]
    fn steady_state_rounds_are_zero_copy() {
        // the acceptance hook: identical membership round after round ⇒
        // all rows retained, nothing migrated, no arena allocations, no
        // host→literal conversions (store_fresh memoizes the run path's
        // literal; skips hit the memo)
        let (depth, nd, slots) = (2usize, 4usize, 4usize);
        let mut active = mk_active(2, 100, depth, nd);
        let pool = Rc::new(TensorPool::new());
        let mut state: Option<BatchState> = None;
        let plan = BatchPlan {
            bucket: 2,
            lanes: vec![LaneSlot { req_idx: 0, lane: 0 },
                        LaneSlot { req_idx: 1, lane: 0 }],
        };
        // warmup round: both rows join (cold), every module "runs"
        sync_batch(&mut state, &plan, &mut active, &pool, depth, 1, nd,
                   &[1, 2, 2], -1);
        for k in 0..slots {
            sim_run(&mut state.as_mut().unwrap().caches, k, 2, nd, &plan,
                    &active, 0);
        }
        let warm_allocs = pool.stats().allocated;
        let st = state.as_mut().unwrap();
        assert_eq!(st.caches.conversions(), 0,
                   "run path memoizes, never converts");
        // steady state: same plan, every module "skips" (reads the memo).
        // A disabled tracer rides along exactly as in run_plan — it must
        // stay free: no clock reads (now_us pins to 0) and no recording.
        let tracer = crate::obs::Tracer::disabled();
        for round in 1..6 {
            let mut p = plan.clone();
            stabilize_plan(&mut p, &state.as_ref().unwrap().rows,
                           |idx| active[idx].req.id);
            let bb_start = tracer.now_us();
            let (retained, migrated) =
                sync_batch(&mut state, &p, &mut active, &pool, depth, 1, nd,
                           &[1, 2, 2], -1);
            assert_eq!((retained, migrated), (2, 0), "round {round}");
            assert_eq!(bb_start, 0, "disabled tracer must not read clocks");
            tracer.record_at(crate::obs::TraceEvent {
                kind: crate::obs::EventKind::Scatter,
                ts_us: bb_start,
                dur_us: 0,
                kind_id: p.bucket as u64,
                arg: pack_pair(retained as u32, migrated as u32),
            });
            let st = state.as_mut().unwrap();
            for k in 0..slots {
                st.caches.literal(k).unwrap(); // the skip path's read
            }
        }
        assert!(tracer.ring().is_none(),
                "disabled tracer holds no ring, records nothing");
        let st = state.as_mut().unwrap();
        assert_eq!(st.caches.conversions(), 0,
                   "steady-state skips must perform zero conversions");
        assert_eq!(st.caches.literal_hits(), 5 * slots as u64);
        assert_eq!(pool.stats().allocated, warm_allocs,
                   "steady-state rounds must not allocate");
    }

    #[test]
    fn resident_repack_matches_scratch_rebuild() {
        // the bit-identity property behind unchanged eps/skipped: under
        // random batch-membership churn (joins, leaves, row shifts,
        // bucket changes) AND non-uniform row-granular gates (partial
        // run/skip splits, CFG pairs coupled), the pooled resident
        // caches hold exactly what a from-scratch per-round rebuild
        // (pooling off) would hold — same validity, same bytes — for
        // every live row, every round; and the flushed lane stores
        // agree at the end
        propcheck(40, |g| {
            let depth = g.usize_in(1, 3);
            let slots = 2 * depth;
            let nd = g.usize_in(1, 4);
            let nreq = g.usize_in(2, 5);
            let mut res_active = mk_active(nreq, 50, depth, nd);
            let mut ref_active = mk_active(nreq, 50, depth, nd);
            let pool = Rc::new(TensorPool::new());
            let mut state: Option<BatchState> = None;
            let rounds = g.usize_in(3, 8);
            for round in 0..rounds {
                // random membership: rotate + truncate the request set
                let mut sel: Vec<usize> = (0..nreq).collect();
                sel.rotate_left(g.usize_in(0, nreq - 1));
                sel.truncate(g.usize_in(1, nreq));
                let mut lanes = Vec::new();
                for &ri in &sel {
                    for lane in 0..res_active[ri].req.lanes() {
                        lanes.push(LaneSlot { req_idx: ri, lane });
                    }
                }
                let bucket = *[1usize, 2, 4, 8, 16]
                    .iter()
                    .find(|&&b| b >= lanes.len())
                    .unwrap();
                let mut plan = BatchPlan { bucket, lanes };
                if let Some(st) = &state {
                    let ids: Vec<u64> =
                        res_active.iter().map(|a| a.req.id).collect();
                    stabilize_plan(&mut plan, &st.rows, |idx| ids[idx]);
                }
                // resident path (pooling on)
                sync_batch(&mut state, &plan, &mut res_active, &pool, depth,
                           1, nd, &[1, 2, 2], -1);
                // reference path (pooling off): fresh scratch from the
                // reference lane stores, like the pre-resident engine
                let mut scratch = BatchCaches::empty(depth, bucket, 1, nd);
                for (row, slot) in plan.lanes.iter().enumerate() {
                    let lc = &ref_active[slot.req_idx].caches[slot.lane];
                    for k in 0..slots {
                        scratch.valid[k][row] = lc.valid[k];
                        if lc.valid[k] {
                            scratch.write_row(k, row, &lc.values[k]);
                        }
                    }
                }
                let live = plan.live_mask();
                let pairs = plan.pair_mask();
                let st = state.as_mut().unwrap();
                for k in 0..slots {
                    let ok_res = cache_ok(&st.caches.valid[k], &live);
                    let ok_ref = cache_ok(&scratch.valid[k], &live);
                    assert_eq!(ok_res, ok_ref,
                               "cache_ok diverged (round {round} slot {k})");
                    // row-granular gates, like the runner: random
                    // per-row gate values, CFG pairs coupled, validity
                    // consulted per row — both paths must plan the
                    // identical mask and end bit-identical whether the
                    // slot skips fully, runs fully, or splits
                    use crate::model::runner::plan_rows;
                    let s: Vec<f32> = (0..bucket)
                        .map(|_| if g.bool() { 0.9 } else { 0.1 })
                        .collect();
                    let dcfg = DecisionCfg {
                        policy: crate::config::SkipPolicy::Mean,
                        scope: crate::config::LazyScope::Both,
                        threshold: 0.5,
                        row_granular: true,
                    };
                    let mut mask_res = Vec::new();
                    let mut mask_ref = Vec::new();
                    let p_res = plan_rows(dcfg, true, None, &s, &live,
                                          &pairs, &st.caches.valid[k],
                                          &mut mask_res);
                    let p_ref = plan_rows(dcfg, true, None, &s, &live,
                                          &pairs, &scratch.valid[k],
                                          &mut mask_ref);
                    assert_eq!(mask_res, mask_ref,
                               "plans diverged (round {round} slot {k})");
                    assert_eq!(p_res, p_ref);
                    if p_res.all_skip {
                        // cache-served everywhere: no mutation at all
                    } else if p_res.all_run {
                        sim_run(&mut st.caches, k, bucket, nd, &plan,
                                &res_active, round);
                        sim_run(&mut scratch, k, bucket, nd, &plan,
                                &ref_active, round);
                    } else {
                        sim_run_partial(&mut st.caches, k, bucket, nd,
                                        &plan, &res_active, round,
                                        &mask_res);
                        sim_run_partial(&mut scratch, k, bucket, nd,
                                        &plan, &ref_active, round,
                                        &mask_ref);
                    }
                }
                // live rows must be bit-identical between the two paths
                for (row, _) in plan.lanes.iter().enumerate() {
                    for k in 0..slots {
                        assert_eq!(st.caches.valid[k][row],
                                   scratch.valid[k][row],
                                   "validity diverged r{round} k{k} row{row}");
                        if st.caches.valid[k][row] {
                            assert_eq!(st.caches.value(k).row(row),
                                       scratch.value(k).row(row),
                                       "bytes diverged r{round} k{k} row{row}");
                        }
                    }
                }
                // reference engine scatters back every round
                for (row, slot) in plan.lanes.iter().enumerate() {
                    scatter_row(&scratch, row,
                                &mut ref_active[slot.req_idx].caches
                                    [slot.lane]);
                }
            }
            // endgame: flushed resident lane stores == reference stores
            flush_batch(&mut state, &mut res_active, &pool);
            for (a, b) in res_active.iter().zip(&ref_active) {
                for lane in 0..a.caches.len() {
                    assert_eq!(a.caches[lane].valid, b.caches[lane].valid,
                               "flushed validity diverged (req {})", a.req.id);
                    for k in 0..slots {
                        if a.caches[lane].valid[k] {
                            assert_eq!(a.caches[lane].values[k],
                                       b.caches[lane].values[k],
                                       "flushed bytes diverged (req {})",
                                       a.req.id);
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn evicted_trajectory_resumes_bit_identically() {
        // the migration tentpole property: at a random step boundary,
        // every trajectory is detached (residency flushed), pushed
        // through the versioned byte encoding, and re-admitted into a
        // FRESH batch state — exactly what evict_to_snapshot →
        // encode → wire → decode → admit_snapshot does across two
        // replicas. Every live batch row and every flushed lane store
        // must stay bit-identical to the uninterrupted resident run,
        // CFG pairs included (mk_active alternates cfg 1.0/1.5), and
        // counters/z survive untouched.
        propcheck(30, |g| {
            use crate::model::runner::plan_rows;
            let depth = g.usize_in(1, 3);
            let slots = 2 * depth;
            let nd = g.usize_in(1, 4);
            let nreq = g.usize_in(2, 4);
            let rounds = g.usize_in(3, 7);
            let evict_round = g.usize_in(1, rounds - 1);
            let mut mig_active = mk_active(nreq, 50, depth, nd);
            let mut ref_active = mk_active(nreq, 50, depth, nd);
            let pool = Rc::new(TensorPool::new());
            let mut mig_state: Option<BatchState> = None;
            let mut ref_state: Option<BatchState> = None;
            for round in 0..rounds {
                if round == evict_round {
                    // boundary migration of the whole resident set,
                    // through the portable encoding, into a fresh
                    // engine-side state (as a sibling replica would)
                    let ids: Vec<u64> =
                        mig_active.iter().map(|a| a.req.id).collect();
                    let mut resumed = Vec::new();
                    for id in ids {
                        let ar = detach_request(&mut mig_state,
                                                &mut mig_active, id, -1)
                            .expect("active id detaches");
                        let bytes = ar.into_snapshot().encode();
                        let snap = TrajectorySnapshot::decode(&bytes)
                            .expect("own encoding decodes");
                        resumed.push(ActiveRequest::from_snapshot(snap));
                    }
                    assert!(mig_active.is_empty());
                    if let Some(st) = mig_state.take() {
                        st.caches.release_into_pool();
                        pool.release(st.z);
                    }
                    mig_active = resumed;
                }
                // identical plans on both sides: all requests in order
                let mut lanes = Vec::new();
                for (ri, a) in mig_active.iter().enumerate() {
                    for lane in 0..a.req.lanes() {
                        lanes.push(LaneSlot { req_idx: ri, lane });
                    }
                }
                let bucket = *[1usize, 2, 4, 8, 16]
                    .iter()
                    .find(|&&b| b >= lanes.len())
                    .unwrap();
                let plan = BatchPlan { bucket, lanes };
                sync_batch(&mut mig_state, &plan, &mut mig_active, &pool,
                           depth, 1, nd, &[1, 2, 2], -1);
                sync_batch(&mut ref_state, &plan, &mut ref_active, &pool,
                           depth, 1, nd, &[1, 2, 2], -1);
                let live = plan.live_mask();
                let pairs = plan.pair_mask();
                for k in 0..slots {
                    // one shared random gate draw per (round, slot) —
                    // both paths must plan the identical row mask
                    let s: Vec<f32> = (0..bucket)
                        .map(|_| if g.bool() { 0.9 } else { 0.1 })
                        .collect();
                    let dcfg = DecisionCfg {
                        policy: crate::config::SkipPolicy::Mean,
                        scope: crate::config::LazyScope::Both,
                        threshold: 0.5,
                        row_granular: true,
                    };
                    let mut mask_mig = Vec::new();
                    let mut mask_ref = Vec::new();
                    let p_mig = plan_rows(
                        dcfg, true, None, &s, &live, &pairs,
                        &mig_state.as_ref().unwrap().caches.valid[k],
                        &mut mask_mig);
                    let p_ref = plan_rows(
                        dcfg, true, None, &s, &live, &pairs,
                        &ref_state.as_ref().unwrap().caches.valid[k],
                        &mut mask_ref);
                    assert_eq!(mask_mig, mask_ref,
                               "plans diverged (round {round} slot {k})");
                    assert_eq!(p_mig, p_ref);
                    for (state, act) in [(&mut mig_state, &mig_active),
                                         (&mut ref_state, &ref_active)] {
                        let st = state.as_mut().unwrap();
                        if p_mig.all_skip {
                            // cache-served: no mutation
                        } else if p_mig.all_run {
                            sim_run(&mut st.caches, k, bucket, nd, &plan,
                                    act, round);
                        } else {
                            sim_run_partial(&mut st.caches, k, bucket, nd,
                                            &plan, act, round, &mask_mig);
                        }
                    }
                }
                let mst = mig_state.as_ref().unwrap();
                let rst = ref_state.as_ref().unwrap();
                for row in 0..plan.lanes.len() {
                    for k in 0..slots {
                        assert_eq!(mst.caches.valid[k][row],
                                   rst.caches.valid[k][row],
                                   "validity diverged r{round} k{k} \
                                    row{row}");
                        if mst.caches.valid[k][row] {
                            assert_eq!(mst.caches.value(k).row(row),
                                       rst.caches.value(k).row(row),
                                       "bytes diverged r{round} k{k} \
                                        row{row}");
                        }
                    }
                }
            }
            // endgame: flushed lane stores, z, and counters identical
            flush_batch(&mut mig_state, &mut mig_active, &pool);
            flush_batch(&mut ref_state, &mut ref_active, &pool);
            for (a, b) in mig_active.iter().zip(&ref_active) {
                assert_eq!(a.req.id, b.req.id, "order preserved");
                assert_eq!(a.cursor, b.cursor);
                assert_eq!(a.skip_counts, b.skip_counts);
                assert_eq!(a.modules_seen, b.modules_seen);
                assert_eq!(a.z, b.z, "latent must travel untouched");
                for lane in 0..a.caches.len() {
                    assert_eq!(a.caches[lane], b.caches[lane],
                               "flushed lane store diverged (req {})",
                               a.req.id);
                }
            }
        });
    }

    #[test]
    fn detach_vacates_rows_and_survivors_stay_resident() {
        let (depth, nd) = (1usize, 2usize);
        let mut active = mk_active(2, 10, depth, nd);
        let pool = Rc::new(TensorPool::new());
        let mut state: Option<BatchState> = None;
        let plan = BatchPlan {
            bucket: 2,
            lanes: vec![LaneSlot { req_idx: 0, lane: 0 },
                        LaneSlot { req_idx: 1, lane: 0 }],
        };
        sync_batch(&mut state, &plan, &mut active, &pool, depth, 1, nd,
                   &[1, 1, 2], -1);
        sim_run(&mut state.as_mut().unwrap().caches, 0, 2, nd, &plan,
                &active, 0);
        let id0 = active[0].req.id;
        let id1 = active[1].req.id;
        let row0: Vec<f32> = state.as_ref().unwrap()
            .caches.value(0).row(0).to_vec();
        let ar = detach_request(&mut state, &mut active, id0, -1)
            .expect("detach");
        // the evictee's freshly-run row flushed into its lane store
        assert!(ar.caches[0].valid[0]);
        assert_eq!(ar.caches[0].values[0], row0);
        let st = state.as_ref().unwrap();
        assert_eq!(st.rows[0], None, "evicted row vacated");
        assert_eq!(st.rows[1], Some((id1, 0)), "survivor untouched");
        assert!(st.caches.valid[0][1]);
        // unknown ids are a no-op
        assert!(detach_request(&mut state, &mut active, 999, -1).is_none());
        assert_eq!(active.len(), 1);
    }

    #[test]
    fn partial_path_on_uniform_mask_matches_full_run() {
        // execution-level bit identity on uniform masks: driving the
        // run through the partition machinery (compact → run → scatter)
        // with an all-run mask leaves every live row byte-identical to
        // the scalar full-run path (store_fresh), and validity agrees
        let (depth, nd) = (1usize, 3usize);
        let active = mk_active(2, 10, depth, nd);
        let plan = BatchPlan {
            bucket: 4,
            lanes: vec![LaneSlot { req_idx: 0, lane: 0 },
                        LaneSlot { req_idx: 1, lane: 0 }],
        };
        let mut full = BatchCaches::empty(depth, 4, 1, nd);
        let mut part = BatchCaches::empty(depth, 4, 1, nd);
        sim_run(&mut full, 0, 4, nd, &plan, &active, 3);
        sim_run_partial(&mut part, 0, 4, nd, &plan, &active, 3,
                        &[false, false, false, false]);
        for row in 0..plan.lanes.len() {
            assert_eq!(full.value(0).row(row), part.value(0).row(row),
                       "row {row} diverged");
            assert_eq!(full.valid[0][row], part.valid[0][row]);
            assert!(part.valid[0][row]);
        }
        // live padding rows: the partial path never touches them
        assert!(!part.valid[0][2] && !part.valid[0][3]);
    }

    #[test]
    fn retired_requests_vacate_their_rows() {
        let (depth, nd) = (1usize, 2usize);
        let mut active = mk_active(2, 10, depth, nd);
        let pool = Rc::new(TensorPool::new());
        let mut state: Option<BatchState> = None;
        let plan = BatchPlan {
            bucket: 2,
            lanes: vec![LaneSlot { req_idx: 0, lane: 0 },
                        LaneSlot { req_idx: 1, lane: 0 }],
        };
        sync_batch(&mut state, &plan, &mut active, &pool, depth, 1, nd,
                   &[1, 1, 2], -1);
        let st = state.as_mut().unwrap();
        st.caches.valid[0][0] = true;
        st.caches.valid[0][1] = true;
        st.clear_request(active[0].req.id, -1);
        assert_eq!(st.rows[0], None, "retired row vacated");
        assert!(!st.caches.valid[0][0]);
        assert_eq!(st.rows[1], Some((active[1].req.id, 0)),
                   "other occupant untouched");
        assert!(st.caches.valid[0][1]);
    }

    #[test]
    fn bucket_override_restricts_but_never_extends_or_empties() {
        let compiled = [1usize, 2, 4, 8, 16];
        let mut serve = ServeConfig::default();
        // no override: full compiled set
        assert_eq!(effective_buckets(&compiled, &serve), compiled.to_vec());
        // a tier restriction keeps only compiled members
        serve.bucket_override = Some(vec![1, 2, 4]);
        assert_eq!(effective_buckets(&compiled, &serve), vec![1, 2, 4]);
        // unknown sizes are ignored (each bucket is an AOT executable)
        serve.bucket_override = Some(vec![2, 3, 5, 8]);
        assert_eq!(effective_buckets(&compiled, &serve), vec![2, 8]);
        // an empty intersection falls back to the full compiled set
        serve.bucket_override = Some(vec![3, 5, 7]);
        assert_eq!(effective_buckets(&compiled, &serve), compiled.to_vec());
        serve.bucket_override = Some(Vec::new());
        assert_eq!(effective_buckets(&compiled, &serve), compiled.to_vec());
    }
}
