//! The denoise scheduler/engine: owns the active set, assembles batches
//! (continuous batching), drives the lazy block runner one step per round,
//! applies CFG + DDIM on the host, and retires finished requests.

use crate::config::ServeConfig;
use crate::coordinator::batcher::{plan_cap, plan_round, BatchPlan};
use crate::coordinator::request::{ActiveRequest, Request, RequestResult};
use crate::coordinator::stats::{LayerStats, ServeStats};
use crate::model::checkpoint::Checkpoint;
use crate::model::runner::{BatchCaches, DecisionCfg, ModelRunner, StepOutcome};
use crate::runtime::engine_rt::Runtime;
use crate::runtime::manifest::Manifest;
use crate::sampler::cfg::combine_pair;
use crate::sampler::ddim::DdimSampler;
use crate::sampler::schedule::Schedule;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

/// Engine construction options beyond ServeConfig.
#[derive(Debug, Clone, Default)]
pub struct EngineOptions {
    /// Override gates with the disabled set (DDIM baseline).
    pub disable_gates: bool,
    /// Static per-(slot, step-index) skip schedule (Learn2Cache baseline);
    /// indexed [step_idx % len][slot].
    pub static_schedule: Option<Vec<Vec<bool>>>,
}

/// The serving engine (single-threaded over one PJRT client; concurrency
/// comes from batching, which is where diffusion serving wins anyway).
pub struct Engine {
    pub runner: ModelRunner,
    pub sampler: DdimSampler,
    pub serve: ServeConfig,
    pub options: EngineOptions,
    pub layer_stats: LayerStats,
    pub serve_stats: ServeStats,
    /// When present, accumulates consecutive-step module-output cosine
    /// similarities (the Learn2Cache-analog offline profiling pass).
    pub sim_profile: Option<crate::baselines::learn2cache::SimProfile>,
    active: Vec<ActiveRequest>,
    rr_cursor: usize,
    next_id: u64,
    /// Bucket set rounds are planned against, resolved once at
    /// construction: the tier's `ServeConfig::bucket_override`
    /// intersected with the compiled set (each bucket size is backed by
    /// an AOT-compiled executable, so a restriction can only narrow),
    /// or the full compiled set when there is no override or the
    /// intersection is empty.
    round_buckets: Vec<usize>,
}

/// Resolve the effective bucket set for `round_buckets` (see the field
/// docs); pure so both constructors share it.
fn effective_buckets(compiled: &[usize],
                     serve: &crate::config::ServeConfig) -> Vec<usize> {
    if let Some(ov) = &serve.bucket_override {
        let restricted: Vec<usize> =
            compiled.iter().copied().filter(|b| ov.contains(b)).collect();
        if !restricted.is_empty() {
            return restricted;
        }
    }
    compiled.to_vec()
}

impl Engine {
    /// Build an engine from artifacts + checkpoints.
    pub fn from_artifacts(artifacts: &Path, ckpt_dir: &Path, serve: ServeConfig,
                          options: EngineOptions, gates_tag: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts)?;
        let cfg = manifest.config(&serve.config_name)?.clone();
        let rt = Rc::new(Runtime::cpu()?);

        let theta_path =
            crate::model::checkpoint::theta_path(ckpt_dir, &serve.config_name);
        let theta_ck = Checkpoint::load(&theta_path).with_context(|| {
            format!("base checkpoint missing — run `lazydit pretrain --config {}`",
                    serve.config_name)
        })?;
        let theta = theta_ck.vec("theta")?.clone();

        let runner = if options.disable_gates {
            ModelRunner::with_disabled_gates(rt, cfg.clone(), &theta)?
        } else {
            let gpath = crate::model::checkpoint::gates_path(
                ckpt_dir, &serve.config_name, gates_tag);
            let gck = Checkpoint::load(&gpath).with_context(|| {
                format!("gate checkpoint '{gates_tag}' missing — run \
                         `lazydit lazy-train --config {}`", serve.config_name)
            })?;
            ModelRunner::new(Rc::new(Runtime::cpu()?), cfg.clone(), &theta,
                             gck.vec("gamma")?)?
        };

        let schedule = Schedule::linear(cfg.diffusion.timesteps,
                                        cfg.diffusion.beta_start,
                                        cfg.diffusion.beta_end);
        let depth = cfg.model.depth;
        let round_buckets = effective_buckets(&cfg.buckets, &serve);
        Ok(Engine {
            runner,
            sampler: DdimSampler::new(schedule),
            serve,
            options,
            layer_stats: LayerStats::new(depth),
            serve_stats: ServeStats::default(),
            sim_profile: None,
            active: Vec::new(),
            rr_cursor: 0,
            next_id: 1,
            round_buckets,
        })
    }

    /// Build an engine from in-memory parameters (tests, training loops).
    pub fn from_parts(runner: ModelRunner, serve: ServeConfig,
                      options: EngineOptions) -> Engine {
        let schedule = Schedule::linear(runner.cfg.diffusion.timesteps,
                                        runner.cfg.diffusion.beta_start,
                                        runner.cfg.diffusion.beta_end);
        let depth = runner.cfg.model.depth;
        let round_buckets = effective_buckets(&runner.cfg.buckets, &serve);
        Engine {
            runner,
            sampler: DdimSampler::new(schedule),
            serve,
            options,
            layer_stats: LayerStats::new(depth),
            serve_stats: ServeStats::default(),
            sim_profile: None,
            active: Vec::new(),
            rr_cursor: 0,
            next_id: 1,
            round_buckets,
        }
    }

    pub fn next_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Admit a request into the active set.
    pub fn submit(&mut self, mut req: Request) -> u64 {
        if req.id == 0 {
            req.id = self.next_id();
        }
        let id = req.id;
        // the protocol edge bounds steps (server::MAX_STEPS), but
        // programmatic callers can pass anything — clamp to this
        // engine's schedule instead of panicking a worker thread
        let max_steps = self.sampler.schedule.timesteps;
        let clamped = req.steps.clamp(1, max_steps);
        if clamped != req.steps {
            log::warn!("request {id}: steps {} clamped to {clamped} \
                        (schedule has {max_steps})", req.steps);
            req.steps = clamped;
        }
        // same guard for lanes: the pool router filters replicas that
        // cannot fit a request, but programmatic callers can submit a
        // 2-lane CFG request into an engine whose plannable cap is 1 —
        // plan_round could then never include it and step_round would
        // make no progress forever. Degrade to the cond-only lane
        // instead of wedging the engine. `plan_cap` is the same rule
        // plan_round packs against, so guard and planner cannot diverge.
        let lane_cap =
            plan_cap(&self.round_buckets, self.serve.max_batch).max(1);
        if req.lanes() > lane_cap {
            log::warn!("request {id}: {} lanes exceed this engine's \
                        plannable cap {lane_cap} — dropping the uncond \
                        lane (cfg_scale forced to 1.0)", req.lanes());
            req.cfg_scale = 1.0;
        }
        let m = &self.runner.cfg.model;
        let nd = m.tokens() * m.dim;
        let ts = self.sampler.schedule.ddim_timesteps(req.steps);
        self.active.push(ActiveRequest::new(req, ts, m.depth, nd,
                                            m.img_elems()));
        id
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Remaining denoise steps across the active set — the replica pool's
    /// backlog unit for lazy-aware routing.
    pub fn pending_steps(&self) -> usize {
        self.active
            .iter()
            .map(|a| a.timesteps.len().saturating_sub(a.cursor))
            .sum()
    }

    /// Run one scheduling round (one denoise step for the selected batch).
    /// Returns finished requests.
    pub fn step_round(&mut self) -> Result<Vec<RequestResult>> {
        let lane_counts: Vec<usize> =
            self.active.iter().map(|a| a.req.lanes()).collect();
        let Some(plan) = plan_round(&lane_counts, self.rr_cursor,
                                     self.serve.max_batch,
                                     &self.round_buckets) else {
            return Ok(Vec::new());
        };
        self.rr_cursor = self.rr_cursor.wrapping_add(1);
        let outcome = self.run_plan(&plan)?;
        self.apply_outcome(&plan, outcome)?;
        Ok(self.retire_finished())
    }

    /// Closed-loop: run rounds until all active requests finish.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestResult>> {
        let start = Instant::now();
        let mut out = Vec::new();
        while !self.active.is_empty() {
            let finished = self.step_round()?;
            out.extend(finished);
        }
        self.serve_stats.wall_s += start.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Assemble the batch tensors for a plan and run one model step.
    fn run_plan(&mut self, plan: &BatchPlan) -> Result<StepOutcome> {
        let m = self.runner.cfg.model.clone();
        let b = plan.bucket;
        let depth = m.depth;
        let (n, d) = (m.tokens(), m.dim);
        let img = m.img_elems();

        let mut z = Tensor::zeros(&[b, m.channels, m.img_size, m.img_size]);
        let mut t = vec![0.0f32; b];
        let mut y = vec![m.null_label() as i32; b];
        let mut caches = BatchCaches::empty(depth, b, n, d);

        for (row, slot) in plan.lanes.iter().enumerate() {
            let ar = &self.active[slot.req_idx];
            let ct = ar
                .current_t()
                .context("scheduled a finished request")?;
            z.row_mut(row).copy_from_slice(&ar.z[..img]);
            t[row] = ct as f32;
            y[row] = if slot.lane == 0 {
                ar.req.class_label as i32
            } else {
                m.null_label() as i32
            };
            let lc = &ar.caches[slot.lane];
            for k in 0..2 * depth {
                caches.valid[k][row] = lc.valid[k];
                if lc.valid[k] {
                    caches.values[k].row_mut(row).copy_from_slice(&lc.values[k]);
                }
            }
        }

        let live = plan.live_mask();
        let dec = DecisionCfg {
            policy: self.serve.policy,
            scope: self.serve.scope,
            threshold: self.serve.threshold,
        };

        let outcome = if let Some(sched) = self.options.static_schedule.clone() {
            self.run_static(plan, &z, &t, &y, &live, &mut caches, dec, &sched)?
        } else {
            self.runner.step(plan.bucket, &z, &t, &y, &live, &mut caches, dec)?
        };

        // optional similarity profiling (Learn2Cache-analog offline pass):
        // cosine between each lane's previous module output (still in the
        // per-lane store) and the fresh one (now in the batch caches).
        if self.sim_profile.is_some() {
            let mut records: Vec<(usize, usize, f64)> = Vec::new();
            for (row, slot) in plan.lanes.iter().enumerate() {
                let ar = &self.active[slot.req_idx];
                for k in 0..2 * depth {
                    if ar.caches[slot.lane].valid[k] && caches.valid[k][row]
                        && !outcome.skipped[k]
                    {
                        let cos = slice_cosine(&ar.caches[slot.lane].values[k],
                                               caches.values[k].row(row));
                        records.push((ar.cursor, k, cos));
                    }
                }
            }
            let prof = self.sim_profile.as_mut().unwrap();
            for (cursor, k, cos) in records {
                prof.record(cursor, k, cos);
            }
        }

        // scatter caches back to the owning lanes
        for (row, slot) in plan.lanes.iter().enumerate() {
            let ar = &mut self.active[slot.req_idx];
            let lc = &mut ar.caches[slot.lane];
            for k in 0..2 * depth {
                if caches.valid[k][row] {
                    lc.valid[k] = true;
                    lc.values[k].copy_from_slice(caches.values[k].row(row));
                }
            }
        }
        Ok(outcome)
    }

    /// Learn2Cache-analog path: decisions come from a static per-step
    /// schedule instead of the gates (baselines::learn2cache).
    #[allow(clippy::too_many_arguments)]
    fn run_static(&mut self, plan: &BatchPlan, z: &Tensor, t: &[f32],
                  y: &[i32], live: &[bool], caches: &mut BatchCaches,
                  dec: DecisionCfg, sched: &[Vec<bool>]) -> Result<StepOutcome> {
        // step index of the first live request drives the schedule row
        let step_idx = plan
            .lanes
            .first()
            .map(|s| self.active[s.req_idx].cursor)
            .unwrap_or(0);
        let row = &sched[step_idx % sched.len()];
        // static schedules are expressed via scope+policy override:
        // emulate by temporarily forcing decisions through a gate-free
        // runner call with Never policy, then substituting the schedule.
        let outcome = self.runner.step_with_forced(
            plan.bucket, z, t, y, live, caches, dec, Some(row))?;
        Ok(outcome)
    }

    /// Fold a step outcome into per-request state: CFG combine, DDIM
    /// update, cursor advance, accounting.
    fn apply_outcome(&mut self, plan: &BatchPlan, outcome: StepOutcome)
                     -> Result<()> {
        let depth = self.runner.cfg.model.depth;
        // engine-level per-layer stats
        for k in 0..2 * depth {
            let mean_s = outcome.s_vals[k]
                .iter()
                .zip(plan.live_mask().iter())
                .filter(|(_, &lv)| lv)
                .map(|(&s, _)| s as f64)
                .sum::<f64>()
                / plan.lanes.len().max(1) as f64;
            self.layer_stats.record(k, outcome.skipped[k], mean_s);
            self.serve_stats.module_invocations += 1;
            if outcome.skipped[k] {
                self.serve_stats.module_skips += 1;
            }
        }

        // per-request: find each request's lane rows
        let mut row = 0usize;
        while row < plan.lanes.len() {
            let slot = plan.lanes[row];
            let ar = &mut self.active[slot.req_idx];
            let lanes = ar.req.lanes();
            let eps_req = if lanes == 2 {
                let cond =
                    Tensor::from_vec(&[outcome.eps.row_len()],
                                     outcome.eps.row(row).to_vec())?;
                let unc =
                    Tensor::from_vec(&[outcome.eps.row_len()],
                                     outcome.eps.row(row + 1).to_vec())?;
                combine_pair(&cond, &unc, ar.req.cfg_scale)
            } else {
                Tensor::from_vec(&[outcome.eps.row_len()],
                                 outcome.eps.row(row).to_vec())?
            };
            // DDIM update
            let t_cur = ar.current_t().context("finished in apply")? as isize;
            let t_next = ar.next_t();
            let mut zt = Tensor::from_vec(&[ar.z.len()], ar.z.clone())?;
            self.sampler.step(&mut zt, &eps_req, t_cur, t_next);
            ar.z.copy_from_slice(zt.data());
            // skip accounting (per request: a module counts once per step)
            for k in 0..2 * depth {
                ar.modules_seen[k] += 1;
                if outcome.skipped[k] {
                    ar.skip_counts[k] += 1;
                }
            }
            ar.cursor += 1;
            ar.steps_done += 1;
            row += lanes;
        }
        Ok(())
    }

    fn retire_finished(&mut self) -> Vec<RequestResult> {
        let m = &self.runner.cfg.model;
        let shape = [m.channels, m.img_size, m.img_size];
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].done() {
                let ar = self.active.remove(i);
                let total_attn: u32 =
                    (0..m.depth).map(|l| ar.modules_seen[2 * l]).sum();
                let skip_attn: u32 =
                    (0..m.depth).map(|l| ar.skip_counts[2 * l]).sum();
                let total_ffn: u32 =
                    (0..m.depth).map(|l| ar.modules_seen[2 * l + 1]).sum();
                let skip_ffn: u32 =
                    (0..m.depth).map(|l| ar.skip_counts[2 * l + 1]).sum();
                let latency = ar.started.elapsed();
                self.serve_stats.completed += 1;
                self.serve_stats.latencies_s.push(latency.as_secs_f64());
                out.push(RequestResult {
                    id: ar.req.id,
                    class_label: ar.req.class_label,
                    steps: ar.req.steps,
                    slo: ar.req.slo,
                    image: Tensor::from_vec(&shape, ar.z).expect("shape"),
                    lazy_ratio: ar
                        .skip_counts
                        .iter()
                        .sum::<u32>() as f64
                        / ar.modules_seen.iter().sum::<u32>().max(1) as f64,
                    attn_lazy_ratio: skip_attn as f64 / total_attn.max(1) as f64,
                    ffn_lazy_ratio: skip_ffn as f64 / total_ffn.max(1) as f64,
                    latency,
                    per_module_skip: (0..2 * m.depth)
                        .map(|k| ar.skip_counts[k] as f64
                             / ar.modules_seen[k].max(1) as f64)
                        .collect(),
                });
            } else {
                i += 1;
            }
        }
        out
    }
}

/// The real engine drives a pool replica through the same surface the
/// synthetic engine implements (coordinator::pool).
impl crate::coordinator::pool::PoolEngine for Engine {
    fn submit(&mut self, req: Request) -> u64 {
        Engine::submit(self, req)
    }

    fn active_count(&self) -> usize {
        Engine::active_count(self)
    }

    fn pending_steps(&self) -> usize {
        Engine::pending_steps(self)
    }

    fn step_round(&mut self) -> Result<Vec<RequestResult>> {
        Engine::step_round(self)
    }

    fn layer_stats(&self) -> &LayerStats {
        &self.layer_stats
    }

    fn serve_stats(&self) -> &crate::coordinator::stats::ServeStats {
        &self.serve_stats
    }

    fn policy_name(&self) -> String {
        self.serve.policy.name().to_string()
    }
}

/// Cosine similarity between two equal-length slices.
fn slice_cosine(a: &[f32], b: &[f32]) -> f64 {
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        dot += (x * y) as f64;
        na += (x * x) as f64;
        nb += (y * y) as f64;
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

/// Convenience: generate a batch of images closed-loop and return results
/// sorted by id.
pub fn generate_batch(engine: &mut Engine, labels: &[usize], steps: usize,
                      seed: u64, cfg_scale: f32) -> Result<Vec<RequestResult>> {
    for (i, &lab) in labels.iter().enumerate() {
        let id = engine.next_id();
        let mut req = Request::new(id, lab, steps, seed.wrapping_add(i as u64));
        req.cfg_scale = cfg_scale;
        engine.submit(req);
    }
    let mut res = engine.run_to_completion()?;
    res.sort_by_key(|r| r.id);
    if res.len() != labels.len() {
        bail!("lost requests: {} of {}", res.len(), labels.len());
    }
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;

    #[test]
    fn bucket_override_restricts_but_never_extends_or_empties() {
        let compiled = [1usize, 2, 4, 8, 16];
        let mut serve = ServeConfig::default();
        // no override: full compiled set
        assert_eq!(effective_buckets(&compiled, &serve), compiled.to_vec());
        // a tier restriction keeps only compiled members
        serve.bucket_override = Some(vec![1, 2, 4]);
        assert_eq!(effective_buckets(&compiled, &serve), vec![1, 2, 4]);
        // unknown sizes are ignored (each bucket is an AOT executable)
        serve.bucket_override = Some(vec![2, 3, 5, 8]);
        assert_eq!(effective_buckets(&compiled, &serve), vec![2, 8]);
        // an empty intersection falls back to the full compiled set
        serve.bucket_override = Some(vec![3, 5, 7]);
        assert_eq!(effective_buckets(&compiled, &serve), compiled.to_vec());
        serve.bucket_override = Some(Vec::new());
        assert_eq!(effective_buckets(&compiled, &serve), compiled.to_vec());
    }
}
