//! Continuous batcher: selects which in-flight requests join the next
//! model invocation and how their lanes map onto a padded bucket.
//!
//! Requests at *different* timesteps batch together (t is a per-row model
//! input) — diffusion's analogue of vLLM-style continuous batching. CFG
//! lanes of one request are kept adjacent (cond at slot i, uncond at i+1).

/// One lane in the assembled batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneSlot {
    /// Index into the engine's active-request vector.
    pub req_idx: usize,
    /// 0 = cond, 1 = uncond.
    pub lane: usize,
}

/// The plan for one engine round.
#[derive(Debug, Clone)]
pub struct BatchPlan {
    /// Bucket size used (>= lanes.len()).
    pub bucket: usize,
    /// Lane assignments; padded tail rows have no entry.
    pub lanes: Vec<LaneSlot>,
}

impl BatchPlan {
    pub fn live_mask(&self) -> Vec<bool> {
        let mut m = vec![false; self.bucket];
        for (i, _) in self.lanes.iter().enumerate() {
            m[i] = true;
        }
        m
    }

    /// `pair_mask()[i]` is true iff rows `i`, `i+1` are the cond/uncond
    /// lanes of one CFG request. The row-granular gate uses it to keep
    /// both lanes of a request in the same run/skip partition (they
    /// share a trajectory — skipping one lane but not the other would
    /// split a single sample's module accounting).
    pub fn pair_mask(&self) -> Vec<bool> {
        let mut m = vec![false; self.bucket];
        for (i, slot) in self.lanes.iter().enumerate() {
            if slot.lane == 0
                && self
                    .lanes
                    .get(i + 1)
                    .map_or(false,
                            |n| n.req_idx == slot.req_idx && n.lane == 1)
            {
                m[i] = true;
            }
        }
        m
    }
}

/// The widest plannable bucket under `max_lanes` — the lane cap
/// [`plan_round`] packs against (0 when no bucket qualifies). Shared
/// with `Engine::submit`'s anti-wedge lane guard, which must agree with
/// this rule exactly: a request admitted past a guard computed from a
/// *diverged* copy of this expression could never be planned, wedging
/// its worker in a no-progress spin.
pub fn plan_cap(buckets: &[usize], max_lanes: usize) -> usize {
    buckets
        .iter()
        .copied()
        .filter(|&b| b <= max_lanes.max(*buckets.first().unwrap_or(&1)))
        .max()
        .unwrap_or(0)
}

/// Select requests FIFO (by position) so that their total lanes fit the
/// largest bucket ≤ `max_lanes`, then pick the smallest exported bucket
/// that holds them. `lane_counts[i]` is lanes-per-request (1 or 2).
///
/// `start` rotates the FIFO origin so long queues make progress fairly
/// (round-robin across rounds).
pub fn plan_round(lane_counts: &[usize], start: usize, max_lanes: usize,
                  buckets: &[usize]) -> Option<BatchPlan> {
    let n = lane_counts.len();
    if n == 0 {
        return None;
    }
    let cap = plan_cap(buckets, max_lanes);
    if cap == 0 {
        return None;
    }
    let mut lanes = Vec::new();
    let mut used = 0usize;
    for k in 0..n {
        let i = (start + k) % n;
        let lc = lane_counts[i];
        if used + lc > cap {
            // keep scanning: a later 1-lane request may still fit
            continue;
        }
        for lane in 0..lc {
            lanes.push(LaneSlot { req_idx: i, lane });
        }
        used += lc;
        if used == cap {
            break;
        }
    }
    if lanes.is_empty() {
        return None;
    }
    // smallest bucket that fits
    let bucket = buckets
        .iter()
        .copied()
        .filter(|&b| b >= lanes.len())
        .min()?;
    Some(BatchPlan { bucket, lanes })
}

/// Reorder a plan's lanes so requests already resident in the engine's
/// persistent batch keep their rows. `plan_round`'s rotating FIFO origin
/// shifts the *order* of an otherwise-unchanged selection every round;
/// without this pass that order churn would evict and reload every row
/// each step, defeating the zero-copy steady state.
///
/// `resident` is the current row occupancy (`(request id, lane)` per
/// row); `id_of` maps a plan `req_idx` to its request id. The selected
/// request *set* is unchanged — only the order: requests present in
/// `resident` come first, in resident-row order, then new joiners in
/// plan order. CFG lane adjacency is preserved (lanes are rebuilt per
/// request), so `apply_outcome`'s row walk still holds.
pub fn stabilize_plan(plan: &mut BatchPlan,
                      resident: &[Option<(u64, usize)>],
                      id_of: impl Fn(usize) -> u64) {
    // resident request ids in row order (first occurrence)
    let mut prev_ids: Vec<u64> = Vec::new();
    for occ in resident.iter().flatten() {
        if !prev_ids.contains(&occ.0) {
            prev_ids.push(occ.0);
        }
    }
    // the plan's selection as (req_idx, lane count), in plan order
    let mut selected: Vec<(usize, usize)> = Vec::new();
    for slot in &plan.lanes {
        if slot.lane == 0 {
            selected.push((slot.req_idx, 1));
        } else {
            selected
                .last_mut()
                .expect("plan lanes open with lane 0")
                .1 += 1;
        }
    }
    // stable order: resident ∩ selected first (resident order), then
    // the new joiners in plan order
    let mut ordered: Vec<(usize, usize)> = Vec::with_capacity(selected.len());
    for &pid in &prev_ids {
        if let Some(pos) =
            selected.iter().position(|&(ri, _)| id_of(ri) == pid)
        {
            ordered.push(selected.remove(pos));
        }
    }
    ordered.extend(selected);
    plan.lanes.clear();
    for (ri, lanes) in ordered {
        for lane in 0..lanes {
            plan.lanes.push(LaneSlot { req_idx: ri, lane });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    const BUCKETS: &[usize] = &[1, 2, 4, 8, 16];

    #[test]
    fn empty_queue_no_plan() {
        assert!(plan_round(&[], 0, 8, BUCKETS).is_none());
    }

    #[test]
    fn plan_cap_is_widest_plannable_bucket() {
        assert_eq!(plan_cap(BUCKETS, 8), 8);
        assert_eq!(plan_cap(BUCKETS, 16), 16);
        assert_eq!(plan_cap(BUCKETS, 5), 4);
        // max_lanes below the smallest bucket still yields that bucket
        // (the first-bucket fudge plan_round relies on)
        assert_eq!(plan_cap(BUCKETS, 0), 1);
        assert_eq!(plan_cap(&[2, 4], 1), 2);
        assert_eq!(plan_cap(&[], 8), 0, "no buckets, no cap");
    }

    #[test]
    fn single_cfg_request_uses_bucket_2() {
        let p = plan_round(&[2], 0, 8, BUCKETS).unwrap();
        assert_eq!(p.bucket, 2);
        assert_eq!(p.lanes.len(), 2);
        assert_eq!(p.lanes[0], LaneSlot { req_idx: 0, lane: 0 });
        assert_eq!(p.lanes[1], LaneSlot { req_idx: 0, lane: 1 });
    }

    #[test]
    fn fills_up_to_max_lanes() {
        // 5 CFG requests (10 lanes), max 8 → 4 requests fit
        let p = plan_round(&[2, 2, 2, 2, 2], 0, 8, BUCKETS).unwrap();
        assert_eq!(p.bucket, 8);
        assert_eq!(p.lanes.len(), 8);
    }

    #[test]
    fn rotation_gives_fairness() {
        let p = plan_round(&[2, 2, 2], 1, 4, BUCKETS).unwrap();
        // starts from request 1
        assert_eq!(p.lanes[0].req_idx, 1);
        assert_eq!(p.lanes[2].req_idx, 2);
    }

    #[test]
    fn mixed_lane_counts_pack() {
        // [2, 1, 2, 1], cap 4: packs 2+1 then the 1-lane at the end
        let p = plan_round(&[2, 1, 2, 1], 0, 4, BUCKETS).unwrap();
        assert_eq!(p.lanes.len(), 4);
        let reqs: Vec<usize> = p.lanes.iter().map(|l| l.req_idx).collect();
        assert_eq!(reqs, vec![0, 0, 1, 3]);
    }

    #[test]
    fn pair_mask_marks_cfg_pairs_only() {
        // [2, 1, 2] lanes, cap 8: rows 0-1 pair, row 2 single, rows 3-4
        // pair, rest padding
        let p = plan_round(&[2, 1, 2], 0, 8, BUCKETS).unwrap();
        assert_eq!(p.lanes.len(), 5);
        let m = p.pair_mask();
        assert_eq!(m.len(), p.bucket);
        assert_eq!(&m[..5], &[true, false, false, true, false]);
        assert!(m[5..].iter().all(|&x| !x), "padding rows never pair");
        // a single-lane-only plan has no pairs anywhere
        let p = plan_round(&[1, 1, 1], 0, 4, BUCKETS).unwrap();
        assert!(p.pair_mask().iter().all(|&x| !x));
    }

    #[test]
    fn live_mask_matches_lanes() {
        let p = plan_round(&[2, 1], 0, 4, BUCKETS).unwrap();
        let m = p.live_mask();
        assert_eq!(m.len(), p.bucket);
        assert_eq!(m.iter().filter(|&&x| x).count(), 3);
    }

    #[test]
    fn long_queue_rotation_is_fair() {
        // 32 CFG requests, cap 8 → 4 requests per round; over 8 rotated
        // rounds every request must be scheduled exactly once — FIFO
        // rotation may not favor the head of the queue
        let lane_counts = vec![2usize; 32];
        let mut picks = vec![0usize; 32];
        for round in 0..8 {
            // the engine advances its cursor by 1 per round; requests per
            // round is 4, so emulate the same stride scaled by selections
            let start = (round * 4) % 32;
            let p = plan_round(&lane_counts, start, 8, BUCKETS).unwrap();
            assert_eq!(p.lanes.len(), 8);
            for l in &p.lanes {
                if l.lane == 0 {
                    picks[l.req_idx] += 1;
                }
            }
        }
        assert_eq!(picks.iter().sum::<usize>(), 32);
        let (mn, mx) = (picks.iter().min().unwrap(), picks.iter().max().unwrap());
        assert_eq!((mn, mx), (&1, &1), "unfair rotation: {picks:?}");
    }

    #[test]
    fn unit_stride_rotation_never_starves() {
        // the engine's actual stride is +1 per round; under that stride a
        // long queue must still cycle through everyone within n rounds
        // of slack even though consecutive rounds overlap heavily
        let lane_counts = vec![2usize; 24];
        let mut picks = vec![0usize; 24];
        for round in 0..24 {
            let p = plan_round(&lane_counts, round % 24, 4, BUCKETS).unwrap();
            for l in &p.lanes {
                if l.lane == 0 {
                    picks[l.req_idx] += 1;
                }
            }
        }
        assert!(picks.iter().all(|&c| c >= 1), "starved: {picks:?}");
    }

    #[test]
    fn cfg_lanes_adjacent_in_long_mixed_queue() {
        // worst-case interleaving of 1- and 2-lane requests: cond/uncond
        // of one request must always land at rows (i, i+1)
        let lane_counts: Vec<usize> =
            (0..40).map(|i| if i % 3 == 0 { 1 } else { 2 }).collect();
        for start in 0..lane_counts.len() {
            let Some(p) = plan_round(&lane_counts, start, 16, BUCKETS) else {
                panic!("no plan from start {start}");
            };
            let mut i = 0;
            while i < p.lanes.len() {
                let slot = p.lanes[i];
                assert_eq!(slot.lane, 0, "row {i} must open a request");
                if lane_counts[slot.req_idx] == 2 {
                    assert_eq!(
                        p.lanes[i + 1],
                        LaneSlot { req_idx: slot.req_idx, lane: 1 },
                        "uncond lane not adjacent at rows {i},{}", i + 1
                    );
                    i += 2;
                } else {
                    i += 1;
                }
            }
        }
    }

    #[test]
    fn live_mask_pads_every_exported_bucket() {
        // for each exported bucket size, force its selection with the
        // smallest lane count that exceeds the next-smaller bucket, then
        // check the mask: live rows first, padded tail all-false
        for (bi, &bucket) in BUCKETS.iter().enumerate() {
            let prev = if bi == 0 { 0 } else { BUCKETS[bi - 1] };
            let lanes = prev + 1;
            let lane_counts = vec![1usize; lanes];
            let p = plan_round(&lane_counts, 0, bucket, BUCKETS).unwrap();
            assert_eq!(p.bucket, bucket, "lanes {lanes} must pick bucket {bucket}");
            assert_eq!(p.lanes.len(), lanes);
            let m = p.live_mask();
            assert_eq!(m.len(), bucket);
            assert_eq!(m.iter().filter(|&&x| x).count(), lanes);
            for (i, &lv) in m.iter().enumerate() {
                assert_eq!(lv, i < lanes,
                           "bucket {bucket}: padding must be the all-false tail");
            }
        }
        // exact-fit case: no padding at all
        for &bucket in BUCKETS {
            let lane_counts = vec![1usize; bucket];
            let p = plan_round(&lane_counts, 0, bucket, BUCKETS).unwrap();
            assert_eq!(p.bucket, bucket);
            assert!(p.live_mask().iter().all(|&x| x));
        }
    }

    #[test]
    fn stabilize_neutralizes_rotation_churn() {
        // 3 single-lane requests with ids 10/11/12, all resident in
        // rows 0..3; whatever order rotation hands us, the stabilized
        // plan must reproduce the resident row order exactly
        let ids = [10u64, 11, 12];
        let resident: Vec<Option<(u64, usize)>> =
            vec![Some((10, 0)), Some((11, 0)), Some((12, 0)), None];
        for start in 0..3 {
            let mut p = plan_round(&[1, 1, 1], start, 4, BUCKETS).unwrap();
            stabilize_plan(&mut p, &resident, |ri| ids[ri]);
            let got: Vec<u64> =
                p.lanes.iter().map(|l| ids[l.req_idx]).collect();
            assert_eq!(got, vec![10, 11, 12], "start {start}");
        }
    }

    #[test]
    fn stabilize_keeps_cfg_lanes_adjacent_and_appends_joiners() {
        // resident: CFG request 20 at rows 0-1; selection adds request
        // 21 (CFG) — 20 keeps its rows, 21 joins after
        let ids = [21u64, 20];
        let resident: Vec<Option<(u64, usize)>> =
            vec![Some((20, 0)), Some((20, 1)), None, None];
        let mut p = plan_round(&[2, 2], 0, 4, BUCKETS).unwrap();
        // rotation put request index 0 (id 21) first
        assert_eq!(p.lanes[0].req_idx, 0);
        stabilize_plan(&mut p, &resident, |ri| ids[ri]);
        assert_eq!(p.lanes.len(), 4);
        assert_eq!((ids[p.lanes[0].req_idx], p.lanes[0].lane), (20, 0));
        assert_eq!((ids[p.lanes[1].req_idx], p.lanes[1].lane), (20, 1));
        assert_eq!((ids[p.lanes[2].req_idx], p.lanes[2].lane), (21, 0));
        assert_eq!((ids[p.lanes[3].req_idx], p.lanes[3].lane), (21, 1));
    }

    #[test]
    fn stabilize_preserves_selection_set() {
        // the pass may only reorder — never add, drop, or split lanes
        propcheck(200, |g| {
            let n = g.usize_in(1, 10);
            let lane_counts: Vec<usize> =
                (0..n).map(|_| g.usize_in(1, 2)).collect();
            let ids: Vec<u64> = (0..n).map(|i| 100 + i as u64).collect();
            let start = g.usize_in(0, n - 1);
            let Some(mut p) = plan_round(&lane_counts, start, 8, BUCKETS)
            else {
                return;
            };
            let before = p.clone();
            // random resident occupancy over a random subset
            let rb = g.usize_in(1, 8);
            let mut resident: Vec<Option<(u64, usize)>> = vec![None; rb];
            for row in 0..rb {
                if g.bool() {
                    let ri = g.usize_in(0, n - 1);
                    resident[row] = Some((ids[ri], 0));
                }
            }
            stabilize_plan(&mut p, &resident, |ri| ids[ri]);
            assert_eq!(p.lanes.len(), before.lanes.len());
            assert_eq!(p.bucket, before.bucket);
            let mut a: Vec<usize> =
                before.lanes.iter().map(|l| l.req_idx).collect();
            let mut b: Vec<usize> = p.lanes.iter().map(|l| l.req_idx).collect();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "selection set changed");
            // adjacency invariant survives
            let mut i = 0;
            while i < p.lanes.len() {
                let slot = p.lanes[i];
                assert_eq!(slot.lane, 0);
                if lane_counts[slot.req_idx] == 2 {
                    assert_eq!(p.lanes[i + 1],
                               LaneSlot { req_idx: slot.req_idx, lane: 1 });
                    i += 2;
                } else {
                    i += 1;
                }
            }
        });
    }

    #[test]
    fn prop_invariants() {
        propcheck(300, |g| {
            let n = g.usize_in(0, 12);
            let lane_counts: Vec<usize> =
                (0..n).map(|_| g.usize_in(1, 2)).collect();
            let start = if n == 0 { 0 } else { g.usize_in(0, n - 1) };
            let max_lanes = g.usize_in(1, 16);
            if let Some(p) = plan_round(&lane_counts, start, max_lanes, BUCKETS) {
                // bucket exported and fits
                assert!(BUCKETS.contains(&p.bucket));
                assert!(p.lanes.len() <= p.bucket);
                // never exceeds the cap bucket
                let cap = BUCKETS.iter().copied().filter(|&b| b <= max_lanes.max(1)).max().unwrap_or(1);
                assert!(p.lanes.len() <= cap.max(1));
                // CFG lanes adjacent and complete
                let mut i = 0;
                while i < p.lanes.len() {
                    let slot = p.lanes[i];
                    if lane_counts[slot.req_idx] == 2 {
                        assert_eq!(slot.lane, 0);
                        assert_eq!(p.lanes[i + 1].req_idx, slot.req_idx);
                        assert_eq!(p.lanes[i + 1].lane, 1);
                        i += 2;
                    } else {
                        assert_eq!(slot.lane, 0);
                        i += 1;
                    }
                }
                // no request appears twice
                let mut seen = std::collections::BTreeSet::new();
                for l in &p.lanes {
                    if l.lane == 0 {
                        assert!(seen.insert(l.req_idx), "request selected twice");
                    }
                }
            }
        });
    }

    #[test]
    fn prop_eventual_progress() {
        // every request is eventually selected under rotation
        propcheck(100, |g| {
            let n = g.usize_in(1, 10);
            let lane_counts: Vec<usize> = (0..n).map(|_| g.usize_in(1, 2)).collect();
            let mut served = vec![false; n];
            for round in 0..4 * n {
                if let Some(p) = plan_round(&lane_counts, round % n, 2, BUCKETS) {
                    for l in &p.lanes {
                        served[l.req_idx] = true;
                    }
                }
            }
            assert!(served.iter().all(|&s| s), "starvation: {served:?}");
        });
    }
}
