//! The serving coordinator — the paper's system contribution realised as a
//! diffusion-serving engine (DESIGN.md §7):
//!
//! * [`request`] — request types and per-request trajectory state;
//! * [`batcher`] — continuous batching with bucket padding (requests at
//!   *different* timesteps share one model invocation — t is per-row);
//! * [`engine`] — the denoise scheduler: gather caches → run the lazy
//!   block runner → CFG-combine → DDIM-update → scatter caches;
//! * [`stats`] — lazy-ratio Γ accounting, per-layer laziness (Fig. 4);
//! * [`pool`] — replica pool: N worker threads each owning an engine,
//!   with lazy-aware + SLO-tiered routing, work stealing, and pool-wide
//!   stats aggregation;
//! * [`server`] — TCP JSON-lines front-end with admission control and
//!   the `STATS` gauges verb, feeding either one engine or the replica
//!   pool's router.
//!
//! The architecture (sampler → model → coordinator → pool → wire) and
//! the request lifecycle are mapped end-to-end in docs/ARCHITECTURE.md.

pub mod request;
pub mod batcher;
pub mod engine;
pub mod pool;
pub mod stats;
pub mod server;

pub use engine::{Engine, EngineOptions};
pub use pool::{PoolEngine, PoolReport, Router};
pub use request::{Request, RequestResult};
pub use stats::LayerStats;
