//! Engine-level laziness accounting: aggregate Γ and the per-layer skip
//! distribution that regenerates Figure 4.

/// Per-(layer,module) skip statistics across all served requests.
#[derive(Debug, Clone, Default)]
pub struct LayerStats {
    /// [2L]: skips per module slot (2l = attn, 2l+1 = ffn).
    pub skips: Vec<u64>,
    /// [2L]: invocations per module slot.
    pub total: Vec<u64>,
    /// Sum of gate values per slot (for mean-s reporting).
    pub s_sum: Vec<f64>,
    /// [2L]: invocations whose skip was *denied by a cold row* — the
    /// gates wanted to reuse the cache but a freshly-joined (cache-
    /// invalid) row forced a run. Under the coupled gate that denial
    /// dragged the whole batch; under row-granular gating only the cold
    /// row (and its CFG partner) runs, so the counter now measures
    /// inherent cold work rather than coupling waste (surfaced via
    /// `STATS`).
    pub cold_denied: Vec<u64>,
    /// [2L]: live rows the module executable actually ran — the
    /// row-weighted work unit behind Γ (a partial invocation counts
    /// only its run-rows here).
    pub rows_run: Vec<u64>,
    /// [2L]: live rows served straight from the cache.
    pub rows_skipped: Vec<u64>,
    /// [2L]: rows served from cache that the all-or-nothing gate would
    /// NOT have skipped on the same inputs — row granularity's
    /// recovered work (exact counterfactual, per slot).
    pub rows_recovered: Vec<u64>,
    /// [2L]: rows whose skip was possible only because the request was
    /// warm-started from a donor trajectory's lane caches — cold-row
    /// denials the pool result cache converted into skips (surfaced via
    /// `STATS` as `rows_warmed`).
    pub rows_warmed: Vec<u64>,
}

impl LayerStats {
    pub fn new(depth: usize) -> LayerStats {
        LayerStats {
            skips: vec![0; 2 * depth],
            total: vec![0; 2 * depth],
            s_sum: vec![0.0; 2 * depth],
            cold_denied: vec![0; 2 * depth],
            rows_run: vec![0; 2 * depth],
            rows_skipped: vec![0; 2 * depth],
            rows_recovered: vec![0; 2 * depth],
            rows_warmed: vec![0; 2 * depth],
        }
    }

    pub fn depth(&self) -> usize {
        self.skips.len() / 2
    }

    pub fn record(&mut self, slot: usize, skipped: bool, mean_s: f64) {
        self.total[slot] += 1;
        self.s_sum[slot] += mean_s;
        if skipped {
            self.skips[slot] += 1;
        }
    }

    /// Count one cold-row skip denial on `slot` (see `cold_denied`).
    pub fn record_cold_denied(&mut self, slot: usize) {
        self.cold_denied[slot] += 1;
    }

    /// Row-weighted accounting for one module invocation on `slot`:
    /// `run` live rows executed, `skipped` rows served from cache, of
    /// which `recovered` were skippable only thanks to row granularity
    /// (the coupled batch gate would have run them).
    pub fn record_rows(&mut self, slot: usize, run: u64, skipped: u64,
                       recovered: u64) {
        self.rows_run[slot] += run;
        self.rows_skipped[slot] += skipped;
        self.rows_recovered[slot] += recovered;
    }

    /// Count `n` warm-start skips on `slot`: rows that would have been
    /// cold-denied but carried donor-seeded caches (see `rows_warmed`).
    pub fn record_rows_warmed(&mut self, slot: usize, n: u64) {
        self.rows_warmed[slot] += n;
    }

    /// Total cold-row denials across all slots (the `STATS` gauge).
    pub fn cold_denied_total(&self) -> u64 {
        self.cold_denied.iter().sum()
    }

    /// Total warm-start skips across all slots (the `STATS` gauge).
    pub fn rows_warmed_total(&self) -> u64 {
        self.rows_warmed.iter().sum()
    }

    /// Total live rows run across all slots.
    pub fn rows_run_total(&self) -> u64 {
        self.rows_run.iter().sum()
    }

    /// Total live rows served from cache across all slots.
    pub fn rows_skipped_total(&self) -> u64 {
        self.rows_skipped.iter().sum()
    }

    /// Total rows recovered by row-granular gating across all slots.
    pub fn rows_recovered_total(&self) -> u64 {
        self.rows_recovered.iter().sum()
    }

    /// Row-weighted lazy ratio Γ: skipped rows over live rows seen.
    /// Falls back to the module-weighted ratio when no row accounting
    /// exists (engines predating row stats, hand-built reports).
    pub fn row_overall_ratio(&self) -> f64 {
        let run = self.rows_run_total();
        let skipped = self.rows_skipped_total();
        if run + skipped == 0 {
            return self.overall_ratio();
        }
        skipped as f64 / (run + skipped) as f64
    }

    /// One slot's lazy ratio: row-weighted when row accounting exists
    /// for the slot (a partially-skipped invocation contributes
    /// fractionally), module-weighted otherwise. `.get` keeps merged
    /// stats safe when an older report carried no row vectors.
    fn slot_ratio(&self, k: usize) -> f64 {
        let run = self.rows_run.get(k).copied().unwrap_or(0);
        let skipped = self.rows_skipped.get(k).copied().unwrap_or(0);
        if run + skipped > 0 {
            skipped as f64 / (run + skipped) as f64
        } else {
            ratio(self.skips[k], self.total[k])
        }
    }

    /// Lazy ratio of the attn module at layer l (row-weighted when
    /// available, module-weighted otherwise).
    pub fn attn_ratio(&self, l: usize) -> f64 {
        self.slot_ratio(2 * l)
    }

    /// Lazy ratio of the ffn module at layer l (row-weighted when
    /// available).
    pub fn ffn_ratio(&self, l: usize) -> f64 {
        self.slot_ratio(2 * l + 1)
    }

    /// Module-weighted overall ratio (whole-invocation booleans); the
    /// row-weighted Γ is [`Self::row_overall_ratio`].
    pub fn overall_ratio(&self) -> f64 {
        ratio(self.skips.iter().sum(), self.total.iter().sum())
    }

    pub fn attn_overall(&self) -> f64 {
        self.module_overall(0)
    }

    pub fn ffn_overall(&self) -> f64 {
        self.module_overall(1)
    }

    /// Row-preferring overall ratio over one module kind (0 = attn,
    /// 1 = ffn).
    fn module_overall(&self, m: usize) -> f64 {
        let rs: u64 = (0..self.depth())
            .map(|l| self.rows_skipped.get(2 * l + m).copied().unwrap_or(0))
            .sum();
        let rr: u64 = (0..self.depth())
            .map(|l| self.rows_run.get(2 * l + m).copied().unwrap_or(0))
            .sum();
        if rr + rs > 0 {
            return rs as f64 / (rr + rs) as f64;
        }
        let s: u64 = (0..self.depth()).map(|l| self.skips[2 * l + m]).sum();
        let t: u64 = (0..self.depth()).map(|l| self.total[2 * l + m]).sum();
        ratio(s, t)
    }

    /// ASCII bar chart of per-layer laziness (Fig. 4 regeneration).
    pub fn render_fig4(&self) -> String {
        let mut out = String::from(
            "\nlayer-wise laziness (paper Fig. 4): ratio of skipped invocations\n",
        );
        for l in 0..self.depth() {
            let a = self.attn_ratio(l);
            let f = self.ffn_ratio(l);
            out.push_str(&format!(
                "  layer {l:>2}  MHSA {:>5.1}% |{:<20}|  FFN {:>5.1}% |{:<20}|\n",
                100.0 * a,
                "#".repeat((a * 20.0).round() as usize),
                100.0 * f,
                "#".repeat((f * 20.0).round() as usize),
            ));
        }
        out
    }
}

fn ratio(num: u64, den: u64) -> f64 {
    num as f64 / den.max(1) as f64
}

/// Serving-level latency/throughput aggregation.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub completed: usize,
    pub shed: usize,
    pub latencies_s: Vec<f64>,
    pub wall_s: f64,
    pub module_invocations: u64,
    pub module_skips: u64,
    /// Batch rows carried across consecutive rounds without any cache
    /// copy (the engine's persistent-slot repack; steady state is all
    /// retained).
    pub rows_retained: u64,
    /// Batch rows migrated (evicted/loaded) on membership change.
    pub rows_migrated: u64,
    /// Trajectories resumed from a [`TrajectorySnapshot`] on this
    /// engine (mid-flight migration, drain hand-off, or crash resume).
    ///
    /// [`TrajectorySnapshot`]: crate::coordinator::request::TrajectorySnapshot
    pub resumed: u64,
    /// Denoising steps that did **not** have to be re-run because a
    /// resumed trajectory arrived with its cursor (and caches) intact —
    /// the work migration saved vs. re-denoising from step 0.
    pub resume_steps_saved: u64,
    /// Log-bucketed latency histogram fed by [`Self::record_latency`] —
    /// the quantile source (no per-call sort), mergeable across
    /// replicas.
    pub hist: crate::obs::LatencyHist,
}

impl ServeStats {
    pub fn throughput(&self) -> f64 {
        self.completed as f64 / self.wall_s.max(1e-9)
    }

    /// Record one finished-request latency into both the exact sample
    /// vector (mean stays bit-compatible) and the histogram (quantiles).
    pub fn record_latency(&mut self, seconds: f64) {
        self.latencies_s.push(seconds);
        self.hist.record_secs(seconds);
    }

    pub fn mean_latency(&self) -> f64 {
        crate::metrics::stats::mean(&self.latencies_s)
    }

    /// p99 latency in seconds, from the histogram (O(buckets), no sort).
    /// Hand-built stats that never went through [`Self::record_latency`]
    /// fall back to the exact sorted quantile.
    pub fn p99_latency(&self) -> f64 {
        self.quantile_latency(0.99)
    }

    /// Any latency quantile in seconds (histogram-backed, same fallback
    /// as [`Self::p99_latency`]).
    pub fn quantile_latency(&self, q: f64) -> f64 {
        if self.hist.count() > 0 {
            self.hist.quantile_us(q) as f64 / 1e6
        } else {
            crate::metrics::stats::quantile(&self.latencies_s, q)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios() {
        let mut st = LayerStats::new(2);
        // layer 0 attn: 1 skip of 2; layer 1 ffn: 2 skips of 2
        st.record(0, true, 0.9);
        st.record(0, false, 0.3);
        st.record(3, true, 0.8);
        st.record(3, true, 0.9);
        assert!((st.attn_ratio(0) - 0.5).abs() < 1e-9);
        assert_eq!(st.ffn_ratio(1), 1.0);
        assert_eq!(st.attn_ratio(1), 0.0);
        assert!((st.overall_ratio() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn per_module_aggregates() {
        let mut st = LayerStats::new(1);
        st.record(0, true, 0.9);
        st.record(1, false, 0.1);
        assert_eq!(st.attn_overall(), 1.0);
        assert_eq!(st.ffn_overall(), 0.0);
    }

    #[test]
    fn fig4_renders() {
        let mut st = LayerStats::new(3);
        st.record(0, true, 0.9);
        st.record(1, false, 0.2);
        let s = st.render_fig4();
        assert!(s.contains("layer  0"));
        assert!(s.contains("MHSA"));
    }

    #[test]
    fn serve_stats_math() {
        let st = ServeStats {
            completed: 10,
            shed: 0,
            latencies_s: vec![1.0, 2.0, 3.0],
            wall_s: 5.0,
            module_invocations: 100,
            module_skips: 30,
            ..Default::default()
        };
        assert!((st.throughput() - 2.0).abs() < 1e-9);
        assert!((st.mean_latency() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn mean_is_bit_compatible_with_presort_era() {
        // the bit-compat shim: mean still comes off the exact sample
        // vector, so routing quantiles through the histogram changed
        // nothing about it — identical input, identical f64 out
        let mut st = ServeStats::default();
        for s in [0.0103, 0.0250, 0.0999, 1.5, 0.0042] {
            st.record_latency(s);
        }
        let old_mean =
            crate::metrics::stats::mean(&[0.0103, 0.0250, 0.0999, 1.5, 0.0042]);
        assert_eq!(st.mean_latency().to_bits(), old_mean.to_bits());
    }

    #[test]
    fn p99_reads_the_histogram_not_a_sort() {
        let mut st = ServeStats::default();
        for i in 1..=200u32 {
            st.record_latency(i as f64 * 1e-3); // 1ms .. 200ms
        }
        assert_eq!(st.hist.count(), 200);
        let p99 = st.p99_latency();
        // exact p99 is 0.198s; the histogram answers within its 12.5%
        // bucket error without touching (or sorting) latencies_s
        assert!((p99 - 0.198).abs() / 0.198 <= 0.125, "p99 {p99}");
        let p50 = st.quantile_latency(0.5);
        assert!((p50 - 0.100).abs() / 0.100 <= 0.125, "p50 {p50}");
    }

    #[test]
    fn hand_built_stats_fall_back_to_exact_quantile() {
        // struct-literal stats (merged pool reports from older paths)
        // never fed the histogram; p99 must still be truthful
        let st = ServeStats {
            latencies_s: vec![0.1, 0.2, 0.3, 0.4],
            ..Default::default()
        };
        assert_eq!(st.hist.count(), 0);
        assert!((st.p99_latency()
                 - crate::metrics::stats::quantile(&st.latencies_s, 0.99))
            .abs() < 1e-12);
    }

    #[test]
    fn row_weighted_gamma() {
        let mut st = LayerStats::new(1);
        // no rows recorded yet: falls back to module-weighted
        st.record(0, true, 0.9);
        st.record(0, false, 0.1);
        assert!((st.row_overall_ratio() - 0.5).abs() < 1e-12);
        // a partial invocation: 1 run row, 3 skipped (3 recovered),
        // then a uniform skip of 4 rows
        st.record_rows(0, 1, 3, 3);
        st.record_rows(1, 0, 4, 0);
        assert_eq!(st.rows_run_total(), 1);
        assert_eq!(st.rows_skipped_total(), 7);
        assert_eq!(st.rows_recovered_total(), 3);
        assert!((st.row_overall_ratio() - 7.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn cold_denied_counters() {
        let mut st = LayerStats::new(2);
        assert_eq!(st.cold_denied_total(), 0);
        st.record_cold_denied(1);
        st.record_cold_denied(1);
        st.record_cold_denied(3);
        assert_eq!(st.cold_denied, vec![0, 2, 0, 1]);
        assert_eq!(st.cold_denied_total(), 3);
    }
}
