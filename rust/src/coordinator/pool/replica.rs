//! One replica: a worker thread owning its engine, fed through a bounded
//! queue, observable through lock-free gauges.
//!
//! Lifecycle: `spawn` → jobs via `try_send` → `close` (queue refuses new
//! work, worker finishes queued + in-flight trajectories) → `join_report`
//! (final per-replica stats). Engine construction happens on the worker
//! thread because PJRT types are `!Send`/`!Sync`.

use crate::config::Slo;
use crate::coordinator::pool::cache::PoolCache;
use crate::coordinator::pool::steal::{Rebalancer, StealPeer};
use crate::coordinator::pool::{EngineFactory, PoolEngine, RespawnFactory};
use crate::coordinator::request::{Request, RequestKey, RequestResult,
                                  TrajectorySnapshot};
use crate::coordinator::stats::{LayerStats, ServeStats};
use crate::obs::ring::pack_pair;
use crate::obs::{EventKind, LatencyHist, TraceEvent, Tracer};
use crate::util::threadpool::{BoundedQueue, Popped};
use anyhow::{Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// What a queued job carries: a fresh request, or a mid-flight
/// trajectory evicted from another replica that resumes at its cursor.
pub enum JobPayload {
    /// A freshly routed request, denoised from step 0.
    Fresh(Request),
    /// A portable trajectory snapshot — admitted via
    /// [`PoolEngine::admit_snapshot`], it resumes at its step cursor
    /// with its lane caches and latent intact.
    Resumed(TrajectorySnapshot),
}

/// A routed unit of work plus its response channel.
pub struct PoolJob {
    /// What to run (pool-unique id already assigned in either variant).
    pub payload: JobPayload,
    /// Where the finished [`RequestResult`] goes.
    pub respond: mpsc::Sender<RequestResult>,
    /// Epoch-µs when the router enqueued the job (0 = untimed). Queue
    /// wait is measured from here at engine admission; the stamp rides
    /// along on steal migration, so the wait covers the job's whole
    /// queued life, not just its final queue.
    pub enqueued_us: u64,
    /// Predicted cost of the job's remaining work in milli-module-
    /// invocations, priced by the router's [`super::PoolCalendar`] at
    /// admission (0 = unpriced). Rides along on steal/migration so the
    /// per-replica `predicted_cost_milli` gauge transfers with the job.
    pub cost_milli: u64,
}

/// Effective deadline assigned to jobs whose request carries none:
/// their enqueue instant plus this slack. Far enough out that any real
/// deadline sorts ahead of every legacy job, while legacy jobs keep
/// their exact relative FIFO order among themselves (same offset ⇒
/// enqueue-order keys) — so a deadline-free workload under EDF is
/// byte-for-byte the old FIFO schedule, and a legacy job can never be
/// starved indefinitely by a stream of far-future deadlines.
pub const LEGACY_DEADLINE_US: u64 = 60_000_000;

impl PoolJob {
    /// A job for a freshly routed request.
    pub fn fresh(req: Request, respond: mpsc::Sender<RequestResult>,
                 enqueued_us: u64) -> PoolJob {
        PoolJob { payload: JobPayload::Fresh(req), respond, enqueued_us,
                  cost_milli: 0 }
    }

    /// A job resuming an evicted trajectory.
    pub fn resumed(snap: TrajectorySnapshot,
                   respond: mpsc::Sender<RequestResult>,
                   enqueued_us: u64) -> PoolJob {
        PoolJob { payload: JobPayload::Resumed(snap), respond, enqueued_us,
                  cost_milli: 0 }
    }

    /// A job resuming an evicted trajectory, queue-stamped at the
    /// trajectory's ORIGINAL admission instant — not "now". Every
    /// re-queue path (panic recovery, park-for-respawn, drain-by-
    /// migration, mid-trajectory relief) builds its job through here so
    /// the queue-wait span measured at the next engine admission covers
    /// the request's whole queued life since the router first admitted
    /// it, instead of restarting at each re-queue.
    pub fn resumed_restamped(snap: TrajectorySnapshot,
                             respond: mpsc::Sender<RequestResult>)
                             -> PoolJob {
        let enqueued_us = snap.admitted_us;
        PoolJob::resumed(snap, respond, enqueued_us)
    }

    /// The pool-unique request id.
    pub fn id(&self) -> u64 {
        match &self.payload {
            JobPayload::Fresh(r) => r.id,
            JobPayload::Resumed(s) => s.req.id,
        }
    }

    /// The request's SLO class (steal/placement eligibility).
    pub fn slo(&self) -> Slo {
        match &self.payload {
            JobPayload::Fresh(r) => r.slo,
            JobPayload::Resumed(s) => s.req.slo,
        }
    }

    /// Lanes the request occupies per round (2 under CFG) — the
    /// physical-fit half of the placement predicate.
    pub fn lanes(&self) -> usize {
        match &self.payload {
            JobPayload::Fresh(r) => r.lanes(),
            JobPayload::Resumed(s) => s.lanes(),
        }
    }

    /// Denoise steps still to run: the full schedule for a fresh
    /// request, the cursor remainder for a resumed one. This is the
    /// gauge unit every transfer (dispatch, steal, migration, forfeit)
    /// moves with the job.
    pub fn remaining_steps(&self) -> usize {
        match &self.payload {
            JobPayload::Fresh(r) => r.steps,
            JobPayload::Resumed(s) => s.pending_steps(),
        }
    }

    /// The request's absolute deadline (epoch-µs; 0 = none declared).
    pub fn deadline_us(&self) -> u64 {
        match &self.payload {
            JobPayload::Fresh(r) => r.deadline_us,
            JobPayload::Resumed(s) => s.req.deadline_us,
        }
    }

    /// The EDF sort key: the declared deadline, or — for deadline-free
    /// jobs — the enqueue stamp pushed out by [`LEGACY_DEADLINE_US`].
    /// Total over every job, so a mixed queue orders deterministically:
    /// real deadlines first (earliest wins), then legacy jobs in their
    /// original FIFO order.
    pub fn effective_deadline(&self) -> u64 {
        match self.deadline_us() {
            0 => self.enqueued_us.saturating_add(LEGACY_DEADLINE_US),
            d => d,
        }
    }
}

/// Per-replica provisioning: the SLO class a replica is tuned for and
/// its batcher shape. Replicas used to share one pool-wide
/// configuration; a heterogeneous pool provisions e.g. one B1
/// latency-tier replica next to N B8 throughput-tier replicas and lets
/// the router place each request on the tier that matches its budget
/// (`lazydit serve --replica-spec "lat:b1x1,thr:b8x3"`).
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaTier {
    /// SLO class this replica is provisioned to honor (see
    /// [`Slo::serves`] for the compatibility matrix).
    pub slo: Slo,
    /// Max lanes per engine round — the per-replica
    /// `ServeConfig::max_batch`. Also bounds in-engine admission when
    /// stealing is off (excess jobs wait in the input queue).
    pub max_batch: usize,
    /// Padded bucket sizes this replica plans rounds against (powers of
    /// two up to `max_batch` for tiered replicas; empty ⇒ the engine's
    /// compiled default set).
    pub buckets: Vec<usize>,
    /// In-engine admission bound while stealing is armed: everything
    /// beyond it stays in the queue, where it remains migratable.
    pub steal_window: usize,
    /// Order this replica's queue earliest-deadline-first instead of
    /// FIFO (default on). Deadline-free workloads are unaffected either
    /// way — [`PoolJob::effective_deadline`] keys legacy jobs by their
    /// enqueue order — so the flag exists for A/B measurement
    /// (the scaling bench's EDF-vs-FIFO arm), not as a safety valve.
    pub edf: bool,
}

impl Default for ReplicaTier {
    /// The legacy pool-wide behavior: best-effort class, `max_batch` 8.
    fn default() -> Self {
        ReplicaTier::new(Slo::Besteffort, 8)
    }
}

impl ReplicaTier {
    /// A tier provisioned for `slo` with the given batch width. The
    /// bucket set is the powers of two below `max_batch` plus
    /// `max_batch` itself (a non-power width must be a compiled bucket
    /// to be realizable on the real engine — `cmd_serve` validates
    /// this); the steal window tracks `max_batch` so the batcher stays
    /// full while the queue tail stays migratable.
    pub fn new(slo: Slo, max_batch: usize) -> ReplicaTier {
        let max_batch = max_batch.max(1);
        let mut buckets = Vec::new();
        let mut b = 1usize;
        while b < max_batch {
            buckets.push(b);
            b *= 2;
        }
        buckets.push(max_batch);
        ReplicaTier { slo, max_batch, buckets, steal_window: max_batch,
                      edf: true }
    }

    /// Can this replica honor a request of class `slo`? Enforced at
    /// dispatch (candidate generation) and steal time.
    pub fn can_serve(&self, slo: Slo) -> bool {
        self.slo.serves(slo)
    }

    /// The full admission predicate: SLO compatibility AND lane fit
    /// (delegates to [`tier_admits`]). Used by the router's servability
    /// classification and the steal eligibility check; the candidate
    /// filter uses [`GaugeSnapshot::admits`], which shares the same
    /// implementation — one source of truth, three call sites.
    pub fn admits(&self, slo: Slo, lanes: usize) -> bool {
        tier_admits(self.slo, self.max_batch, slo, lanes)
    }

    /// In-engine admission bound for this replica's worker: the steal
    /// window while stealing is armed (beyond it, jobs stay stealable),
    /// otherwise `max_batch`. Stealing workers read the bound through
    /// [`Rebalancer::effective_window`], which narrows it by one step
    /// while sibling backlogs are overdispersed.
    pub fn engine_window(&self, stealing: bool) -> usize {
        if stealing {
            self.steal_window.max(1)
        } else {
            self.max_batch.max(1)
        }
    }
}

/// The one admission predicate shared by dispatch candidate filtering,
/// the router's shed-reason classification, and steal eligibility: a
/// replica of tier class `tier_slo` with batch width `max_batch` can
/// run a request of class `slo` occupying `lanes` lanes. A request
/// wider than the batch could never be planned — admitting it would
/// wedge the worker in a no-progress spin — and SLO classes only mix
/// through best-effort ([`Slo::serves`]).
pub fn tier_admits(tier_slo: Slo, max_batch: usize, slo: Slo,
                   lanes: usize) -> bool {
    tier_slo.serves(slo) && max_batch >= lanes.max(1)
}

/// [`ReplicaGauges::breaker`] state: healthy, full dispatch.
pub const BREAKER_CLOSED: usize = 0;
/// [`ReplicaGauges::breaker`] state: out of the candidate rotation.
pub const BREAKER_OPEN: usize = 1;
/// [`ReplicaGauges::breaker`] state: back in rotation as a probe; the
/// supervisor closes it after a healthy interval, reopens it on fault.
pub const BREAKER_HALF_OPEN: usize = 2;

/// Human-readable breaker-state label for `STATS`/reports.
pub fn breaker_name(state: usize) -> &'static str {
    match state {
        BREAKER_OPEN => "open",
        BREAKER_HALF_OPEN => "half_open",
        _ => "closed",
    }
}

/// Live per-replica load/laziness gauges. The router reads these on every
/// dispatch; the worker updates them as rounds complete. All counters are
/// relaxed atomics — approximate-but-cheap is exactly what routing needs.
#[derive(Debug, Default)]
pub struct ReplicaGauges {
    /// Requests admitted (dispatched) and not yet completed.
    pub queued: AtomicUsize,
    /// Remaining denoise steps across queued + in-flight requests.
    /// Incremented by the router at dispatch, decremented by the worker
    /// as rounds consume steps.
    pub pending_steps: AtomicUsize,
    /// Requests completed by this replica.
    pub completed: AtomicU64,
    /// Requests completed per SLO class (`Slo::index()` order) — the
    /// per-tier live gauge behind the `STATS` wire verb and the
    /// tier-breakdown line of the pool report.
    pub completed_by_slo: [AtomicU64; Slo::COUNT],
    /// Requests this replica accepted but dropped without completing
    /// (engine failure, panic, refused queue backlog). The router's
    /// admission ledger needs these or dead replicas would pin
    /// "outstanding" work forever.
    pub forfeited: AtomicU64,
    /// Module invocations observed (engine layer-stats total).
    pub modules_seen: AtomicU64,
    /// Module invocations skipped (engine layer-stats skips).
    pub modules_skipped: AtomicU64,
    /// Module invocations whose skip was denied by a cold (freshly-
    /// joined, cache-invalid) row — under row-granular gating only the
    /// cold row itself runs, so this measures inherent cold work;
    /// surfaced live through the `STATS` wire verb.
    pub cold_denied: AtomicU64,
    /// Live rows the engine's executables actually ran (row-weighted
    /// work — partial invocations count only their run-rows).
    pub rows_run: AtomicU64,
    /// Live rows served straight from the cache.
    pub rows_skipped: AtomicU64,
    /// Skipped rows the coupled batch gate would not have skipped —
    /// work only row-granular gating could skip (`STATS`
    /// `rows_recovered`).
    pub rows_recovered: AtomicU64,
    /// Requests this replica admitted warm-started from a donor
    /// trajectory's lane caches (pool cache near hit — the engine
    /// actually seeded rows, not just a donor lookup).
    pub warm_hits: AtomicU64,
    /// Rows whose skip was possible only because the request was
    /// warm-started — cold denials the cache converted (`STATS`
    /// `rows_warmed`, mirrors the engine's layer-stats total).
    pub rows_warmed: AtomicU64,
    /// Jobs this replica pulled from a sibling's queue while idle.
    pub steals: AtomicU64,
    /// Jobs a sibling pulled out of this replica's queue.
    pub stolen: AtomicU64,
    /// Mid-flight trajectories this replica evicted and handed to a
    /// sibling (drain-by-migration, mid-trajectory relief, crash
    /// recovery). Queued-job steals count under `stolen`, not here.
    pub migrated_out: AtomicU64,
    /// Mid-flight trajectories this replica received as snapshots.
    pub migrated_in: AtomicU64,
    /// Trajectories this replica resumed from a snapshot (mirrors the
    /// engine's `ServeStats::resumed`, kept here so `STATS` can report
    /// it live without touching the `!Send` engine).
    pub resumed: AtomicU64,
    /// Denoise steps resuming saved versus re-denoising from step 0
    /// (Σ cursor over resumed snapshots).
    pub resume_steps_saved: AtomicU64,
    /// Raised to ask the worker to evict every resident at its next
    /// step boundary and hand them to compatible siblings (drain-by-
    /// migration: retag, pre-shutdown). The worker lowers it once the
    /// sweep ran; unplaceable residents resume locally — a drain never
    /// strands work.
    pub drain: AtomicBool,
    /// Live SLO re-tag: 0 = provisioned tier class applies, otherwise
    /// `Slo::index() + 1` of the class this replica now serves (tier
    /// autoscaling retags an idle throughput replica to latency without
    /// respawning it). Read through [`Self::live_slo`].
    pub slo_tag: AtomicUsize,
    /// Mid-trajectory relief request: 0 = none, otherwise `thief + 1`.
    /// A thief that found nothing queued but a dwarfing resident
    /// backlog here asks the victim to evict ONE resident at its next
    /// boundary and push it to the thief's queue.
    pub evict_to: AtomicUsize,
    /// Per-SLO-class latency histograms (log-bucketed, mergeable),
    /// fed at retire time — the per-tier p50/p95/p99 behind `STATS`.
    pub lat_hist_by_slo: [LatencyHist; Slo::COUNT],
    /// Set once the worker thread has exited (report posted). Read by
    /// the router so finished/dead replicas drop out of candidate
    /// generation instead of winning the cost order with snapshot 0.
    pub finished: AtomicBool,
    /// Worker-loop heartbeat: bumped at the top of every loop iteration.
    /// The supervisor's stall detector watches this counter — a busy
    /// replica whose heartbeat stops advancing is wedged, not slow.
    pub heartbeat: AtomicU64,
    /// Epoch-µs stamp of the last heartbeat (`STATS` liveness row).
    pub heartbeat_us: AtomicU64,
    /// Times the supervisor respawned this slot's worker. The gauges
    /// `Arc` survives incarnations, so the count accumulates across
    /// respawns and flows `STATS` → pool report → BENCH_serve.json.
    pub restarts: AtomicU64,
    /// Per-replica circuit breaker state: 0 closed (healthy), 1 open
    /// (the router stops dispatching here), 2 half-open (one probe
    /// stream allowed). Driven by the supervisor's state machine; read
    /// through [`GaugeSnapshot`] so candidate ordering stays pure.
    pub breaker: AtomicUsize,
    /// Times the breaker tripped open (flap accounting).
    pub breaker_trips: AtomicU64,
    /// A supervised worker died (panic, wedged engine, step error) and
    /// left its queue OPEN awaiting a respawned incarnation. Mutually
    /// exclusive with `finished`: a needs-respawn slot is down but not
    /// dead — the supervisor either revives it or, once the restart
    /// budget is spent, finishes it for good via
    /// [`ReplicaHandle::give_up`].
    pub needs_respawn: AtomicBool,
    /// Supervisor poison request: a supervised worker that sees this at
    /// a loop boundary parks its residents back into its own queue and
    /// exits for respawn — the cooperative escape hatch for a stall
    /// that eventually returns from `step_round`.
    pub poisoned: AtomicBool,
    /// Brownout stage-2 dial: percentage points of extra target
    /// laziness the worker applies to its engine
    /// ([`PoolEngine::set_gamma_boost`]) at the next loop boundary.
    pub gamma_boost: AtomicUsize,
    /// Predicted module invocations (milli-units) across this replica's
    /// *queued* jobs, priced by the router's calendar at dispatch.
    /// Incremented optimistically at dispatch, decremented at engine
    /// admission / forfeit, transferred with steals — the cost-weighted
    /// sibling of `queued`. Advisory: resumed/migrated jobs re-enter at
    /// cost 0, so the gauge may undercount but never leaks.
    pub predicted_cost_milli: AtomicU64,
    /// Requests that retired at or before their declared deadline.
    /// Deadline-free requests count in neither bucket.
    pub deadline_hits: AtomicU64,
    /// Requests that retired after their declared deadline (completed
    /// late — sheds never reach a worker and are not counted here).
    pub deadline_misses: AtomicU64,
}

impl ReplicaGauges {
    /// Observed lazy ratio Γ (0 until the first round completes).
    /// Row-weighted — skipped rows over live rows seen — so the
    /// router's and rebalancer's lazy-discounted backlog accounts
    /// partial skips honestly; falls back to the module-weighted ratio
    /// when no row accounting has been published yet.
    pub fn lazy_ratio(&self) -> f64 {
        let run = self.rows_run.load(Ordering::Relaxed);
        let skipped_rows = self.rows_skipped.load(Ordering::Relaxed);
        if run + skipped_rows > 0 {
            return skipped_rows as f64 / (run + skipped_rows) as f64;
        }
        let seen = self.modules_seen.load(Ordering::Relaxed);
        if seen == 0 {
            return 0.0;
        }
        self.modules_skipped.load(Ordering::Relaxed) as f64 / seen as f64
    }

    /// The SLO class this replica serves *right now*: the provisioned
    /// tier class unless a live retag ([`Self::slo_tag`]) overrode it.
    /// Everything that gates on compatibility — dispatch candidates,
    /// steal eligibility, migration placement, `STATS` — reads through
    /// here, so a retag takes effect atomically at every call site.
    pub fn live_slo(&self, fallback: Slo) -> Slo {
        match self.slo_tag.load(Ordering::Relaxed) {
            0 => fallback,
            t => Slo::ALL.get(t - 1).copied().unwrap_or(fallback),
        }
    }

    /// Snapshot used by the router's selection policies. The tier is
    /// static per-replica state the gauges don't own, so the caller
    /// supplies it — there is no "default" tier to fabricate (callers:
    /// [`ReplicaHandle::snapshot`] and the rebalancer's victim ranking,
    /// both of which hold the real provisioning). The snapshot's `slo`
    /// is the *live* class, so a retag re-routes from the next
    /// dispatch on.
    pub fn snapshot(&self, tier: &ReplicaTier) -> GaugeSnapshot {
        GaugeSnapshot {
            queued: self.queued.load(Ordering::Relaxed),
            pending_steps: self.pending_steps.load(Ordering::Relaxed),
            lazy_ratio: self.lazy_ratio(),
            finished: self.finished.load(Ordering::Acquire),
            breaker_open: self.breaker.load(Ordering::Relaxed)
                == BREAKER_OPEN
                || self.needs_respawn.load(Ordering::Acquire),
            slo: self.live_slo(tier.slo),
            max_batch: tier.max_batch,
            predicted_cost_milli: self
                .predicted_cost_milli
                .load(Ordering::Relaxed),
        }
    }

    /// Per-SLO completed counters (`Slo::index()` order).
    pub fn completed_by_slo(&self) -> [u64; Slo::COUNT] {
        let mut out = [0u64; Slo::COUNT];
        for (o, c) in out.iter_mut().zip(self.completed_by_slo.iter()) {
            *o = c.load(Ordering::Relaxed);
        }
        out
    }

    /// Feed one finished request's latency into its SLO class histogram
    /// (lock-free; the `STATS` reader folds these into per-tier
    /// quantiles while the pool runs).
    pub fn record_latency(&self, slo: Slo, latency: Duration) {
        self.lat_hist_by_slo[slo.index()]
            .record_us(latency.as_micros() as u64);
    }
}

/// Point-in-time view of one replica's load (plus its static tier
/// provisioning, so SLO-aware candidate ordering is a pure function of
/// the snapshot vector).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GaugeSnapshot {
    /// Requests admitted (dispatched) and not yet completed.
    pub queued: usize,
    /// Remaining denoise steps across queued + in-flight requests.
    pub pending_steps: usize,
    /// Observed lazy ratio Γ.
    pub lazy_ratio: f64,
    /// The worker has exited — the replica can never serve again.
    pub finished: bool,
    /// The replica is temporarily out of rotation: its circuit breaker
    /// is open, or its worker is down awaiting a supervisor respawn.
    /// Unlike `finished` this is recoverable — candidates exclude it,
    /// servability classification does not.
    pub breaker_open: bool,
    /// The replica's provisioned SLO class ([`ReplicaTier::slo`]).
    pub slo: Slo,
    /// The replica's batch width ([`ReplicaTier::max_batch`]) —
    /// throughput requests prefer wider replicas.
    pub max_batch: usize,
    /// Predicted milli-module-invocations across the replica's queued
    /// jobs ([`ReplicaGauges::predicted_cost_milli`]) — the calendar-
    /// priced backlog the router's cost ordering and the brownout
    /// pressure signal read.
    pub predicted_cost_milli: u64,
}

impl GaugeSnapshot {
    /// The shared admission predicate ([`tier_admits`]) over this
    /// snapshot's tier fields — used by the router's candidate filter.
    pub fn admits(&self, slo: Slo, lanes: usize) -> bool {
        tier_admits(self.slo, self.max_batch, slo, lanes)
    }
}

/// Final accounting exported by a replica at shutdown.
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    /// Replica id (stable pool index).
    pub id: usize,
    /// Skip-policy label the replica ran (A/B reporting).
    pub policy: String,
    /// Tier the replica was provisioned for.
    pub tier: ReplicaTier,
    /// Per-(layer,module) laziness counters.
    pub layer: LayerStats,
    /// Serving-level counters (completions, latencies, wall time).
    pub serve: ServeStats,
    /// Requests completed per SLO class (`Slo::index()` order).
    pub completed_by_slo: [u64; Slo::COUNT],
    /// Jobs this replica stole from siblings' queues.
    pub steals: u64,
    /// Jobs siblings stole out of this replica's queue.
    pub stolen: u64,
    /// Mid-flight trajectories this replica evicted to siblings.
    pub migrated_out: u64,
    /// Mid-flight trajectories this replica resumed from siblings.
    pub migrated_in: u64,
    /// Requests admitted warm-started from a pool-cache donor.
    pub warm_hits: u64,
    /// Times the supervisor respawned this slot's worker (accumulated
    /// across incarnations — the gauges survive the crash).
    pub restarts: u64,
    /// Times this replica's circuit breaker tripped open.
    pub breaker_trips: u64,
    /// Requests retired at or before their declared deadline.
    pub deadline_hits: u64,
    /// Requests retired after their declared deadline.
    pub deadline_misses: u64,
    /// Final buffer-arena counters, when the engine owns one (real
    /// engines do; the synthetic engine reports `None`). A healthy
    /// steady state shows `reused` ≫ `allocated` — see docs/PERF.md.
    pub arena: Option<crate::tensor::pool::PoolStats>,
    /// Set if the engine failed to construct or a round errored.
    pub error: Option<String>,
}

impl ReplicaReport {
    /// An empty report carrying only a failure message (construction
    /// failure, panic, or a worker that died without reporting).
    pub fn failed(id: usize, msg: impl Into<String>) -> ReplicaReport {
        ReplicaReport {
            id,
            policy: String::new(),
            tier: ReplicaTier::default(),
            layer: LayerStats::default(),
            serve: ServeStats::default(),
            completed_by_slo: [0; Slo::COUNT],
            steals: 0,
            stolen: 0,
            migrated_out: 0,
            migrated_in: 0,
            warm_hits: 0,
            restarts: 0,
            breaker_trips: 0,
            deadline_hits: 0,
            deadline_misses: 0,
            arena: None,
            error: Some(msg.into()),
        }
    }
}

/// Handle held by the router: input queue + gauges + join state.
pub struct ReplicaHandle {
    /// Replica id (stable pool index).
    pub id: usize,
    /// Live load gauges, shared with the worker (and thieves).
    pub gauges: Arc<ReplicaGauges>,
    /// The replica's provisioning (SLO class + batcher shape).
    pub tier: ReplicaTier,
    /// Telemetry tracer the worker (and its engine) record through;
    /// disabled unless the replica was spawned via
    /// [`spawn_traced`](Self::spawn_traced). The handle keeps a clone so
    /// the `TRACE` verb and the Chrome exporter can read the ring.
    pub tracer: Tracer,
    queue: BoundedQueue<PoolJob>,
    join: Mutex<Option<JoinHandle<()>>>,
    report: Arc<Mutex<Option<ReplicaReport>>>,
}

impl ReplicaHandle {
    /// Spawn the worker thread. `queue_cap` bounds this replica's input
    /// queue (admission shedding happens at the router on top of this).
    pub fn spawn(id: usize, queue_cap: usize, factory: EngineFactory)
                 -> Result<ReplicaHandle> {
        Self::spawn_with(id, queue_cap, factory, None)
    }

    /// Spawn with an optional pool [`Rebalancer`]: when present, the
    /// worker bounds in-engine admission to the rebalancer's window
    /// (excess jobs stay in the queue where siblings can steal them) and
    /// pulls work from overloaded siblings whenever it goes idle. The
    /// replica gets the default best-effort tier; heterogeneous pools
    /// use [`spawn_tiered`](Self::spawn_tiered).
    pub fn spawn_with(id: usize, queue_cap: usize, factory: EngineFactory,
                      steal: Option<Arc<Rebalancer>>) -> Result<ReplicaHandle> {
        let tier = match &steal {
            Some(rb) => ReplicaTier {
                steal_window: rb.admit_window(),
                ..ReplicaTier::default()
            },
            None => ReplicaTier::default(),
        };
        Self::spawn_tiered(id, queue_cap, factory, steal, tier)
    }

    /// Spawn a replica provisioned for a specific [`ReplicaTier`]: the
    /// worker bounds in-engine admission to the tier's window
    /// ([`ReplicaTier::engine_window`]), the router routes by the tier's
    /// SLO class, and thieves respect its compatibility constraint.
    pub fn spawn_tiered(id: usize, queue_cap: usize, factory: EngineFactory,
                        steal: Option<Arc<Rebalancer>>, tier: ReplicaTier)
                        -> Result<ReplicaHandle> {
        Self::spawn_traced(id, queue_cap, factory, steal, tier,
                           Tracer::disabled())
    }

    /// [`spawn_tiered`](Self::spawn_tiered) plus a telemetry [`Tracer`]:
    /// the worker records admission/queue-wait/steal/retire events, the
    /// engine gets the tracer installed for per-step module events, and
    /// the handle keeps a reader clone for `TRACE`/export. A disabled
    /// tracer makes this identical to `spawn_tiered`.
    pub fn spawn_traced(id: usize, queue_cap: usize, factory: EngineFactory,
                        steal: Option<Arc<Rebalancer>>, tier: ReplicaTier,
                        tracer: Tracer) -> Result<ReplicaHandle> {
        Self::spawn_cached(id, queue_cap, factory, steal, tier, tracer, None)
    }

    /// The fully-provisioned spawn: everything `spawn_traced` does plus
    /// an optional shared [`PoolCache`]. A cached worker (1) consults
    /// the warm-start donor store at admission and seeds the joiner's
    /// lane caches via [`PoolEngine::submit_warm`] on a near hit,
    /// (2) inserts every finished result into the exact-result tier
    /// *before* responding, and (3) offers boundary snapshots of its
    /// residents as donors while they are inside the warm horizon (and
    /// on eviction). `None` makes this identical to `spawn_traced`.
    pub fn spawn_cached(id: usize, queue_cap: usize, factory: EngineFactory,
                        steal: Option<Arc<Rebalancer>>, tier: ReplicaTier,
                        tracer: Tracer, cache: Option<Arc<PoolCache>>)
                        -> Result<ReplicaHandle> {
        let queue: BoundedQueue<PoolJob> = BoundedQueue::new(queue_cap.max(1));
        let gauges = Arc::new(ReplicaGauges::default());
        let report: Arc<Mutex<Option<ReplicaReport>>> =
            Arc::new(Mutex::new(None));
        let join = spawn_worker(id, factory, &queue, &gauges, &report,
                                steal, &tier, &tracer, cache, false)?;
        Ok(ReplicaHandle {
            id,
            gauges,
            tier,
            tracer,
            queue,
            join: Mutex::new(Some(join)),
            report,
        })
    }

    /// [`spawn_cached`](Self::spawn_cached) under supervision: the
    /// factory is *reusable*, so when this worker dies the
    /// [`crate::coordinator::pool::supervisor::Supervisor`] can respawn
    /// a fresh incarnation into the same slot — same queue, same
    /// gauges, same tier, same tracer ring. A supervised worker that
    /// panics leaves its queue OPEN, re-queues its residents' last
    /// boundary snapshots into its *own* queue (siblings are only the
    /// fallback), and raises [`ReplicaGauges::needs_respawn`] instead
    /// of finishing.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_supervised(id: usize, queue_cap: usize,
                            factory: &RespawnFactory,
                            steal: Option<Arc<Rebalancer>>,
                            tier: ReplicaTier, tracer: Tracer,
                            cache: Option<Arc<PoolCache>>)
                            -> Result<ReplicaHandle> {
        let queue: BoundedQueue<PoolJob> = BoundedQueue::new(queue_cap.max(1));
        let gauges = Arc::new(ReplicaGauges::default());
        let report: Arc<Mutex<Option<ReplicaReport>>> =
            Arc::new(Mutex::new(None));
        let f = factory.clone();
        let once: EngineFactory = Box::new(move || f());
        let join = spawn_worker(id, once, &queue, &gauges, &report,
                                steal, &tier, &tracer, cache, true)?;
        Ok(ReplicaHandle {
            id,
            gauges,
            tier,
            tracer,
            queue,
            join: Mutex::new(Some(join)),
            report,
        })
    }

    /// Spawn a fresh worker incarnation into this slot (supervisor
    /// respawn): reaps the dead thread, clears the respawn/poison
    /// flags, bumps the restart counter, and starts a new supervised
    /// worker over the SAME queue/gauges/tier/tracer — queued jobs and
    /// re-queued residents are served by the new incarnation, and every
    /// [`StealPeer`] registration stays valid because the queue
    /// identity never changes.
    pub fn respawn(&self, factory: &RespawnFactory,
                   steal: Option<Arc<Rebalancer>>,
                   cache: Option<Arc<PoolCache>>) -> Result<()> {
        if let Some(h) = self.join.lock().unwrap().take() {
            let _ = h.join(); // the old incarnation is dead by contract
        }
        self.gauges.poisoned.store(false, Ordering::Release);
        self.gauges.needs_respawn.store(false, Ordering::Release);
        self.gauges.restarts.fetch_add(1, Ordering::Relaxed);
        let f = factory.clone();
        let once: EngineFactory = Box::new(move || f());
        let join = spawn_worker(self.id, once, &self.queue, &self.gauges,
                                &self.report, steal, &self.tier,
                                &self.tracer, cache, true)?;
        *self.join.lock().unwrap() = Some(join);
        if self.tracer.is_enabled() {
            self.tracer.record(
                EventKind::Respawn, self.id as u64,
                self.gauges.restarts.load(Ordering::Relaxed));
        }
        Ok(())
    }

    /// Permanently retire a supervised slot whose restart budget is
    /// spent: close the queue, refuse whatever is still queued (forfeit
    /// accounting keeps the admission ledger balanced), post a failure
    /// report carrying the gauges' accumulated counters, and mark the
    /// replica finished so routing and the serve loop see a dead —
    /// not merely down — replica.
    pub fn give_up(&self, msg: impl Into<String>) {
        refuse_remaining(&self.queue, &self.gauges);
        if let Some(h) = self.join.lock().unwrap().take() {
            let _ = h.join();
        }
        let mut slot = self.report.lock().unwrap_or_else(|p| p.into_inner());
        if slot.is_none() {
            let mut rep = ReplicaReport::failed(self.id, msg);
            rep.tier = self.tier.clone();
            rep.steals = self.gauges.steals.load(Ordering::Relaxed);
            rep.stolen = self.gauges.stolen.load(Ordering::Relaxed);
            rep.migrated_out =
                self.gauges.migrated_out.load(Ordering::Relaxed);
            rep.migrated_in = self.gauges.migrated_in.load(Ordering::Relaxed);
            rep.restarts = self.gauges.restarts.load(Ordering::Relaxed);
            rep.breaker_trips =
                self.gauges.breaker_trips.load(Ordering::Relaxed);
            rep.deadline_hits =
                self.gauges.deadline_hits.load(Ordering::Relaxed);
            rep.deadline_misses =
                self.gauges.deadline_misses.load(Ordering::Relaxed);
            rep.completed_by_slo = self.gauges.completed_by_slo();
            *slot = Some(rep);
        }
        drop(slot);
        self.gauges.needs_respawn.store(false, Ordering::Release);
        self.gauges.finished.store(true, Ordering::Release);
    }

    /// True while this supervised slot's worker is down awaiting a
    /// respawn (the supervisor's poll signal).
    pub fn needs_respawn(&self) -> bool {
        self.gauges.needs_respawn.load(Ordering::Acquire)
    }
}

/// The worker-thread spawn shared by every `spawn_*` flavor and by
/// supervisor [`ReplicaHandle::respawn`]: construct the engine on the
/// new thread, run the replica loop, settle the admission ledger if it
/// unwinds. `supervised` selects the crash policy: an unsupervised
/// panic refuses the queue and finishes the replica for good; a
/// supervised one re-queues its residents into its own (still open)
/// queue and raises `needs_respawn` for the supervisor instead.
#[allow(clippy::too_many_arguments)]
fn spawn_worker(id: usize, factory: EngineFactory,
                queue: &BoundedQueue<PoolJob>,
                gauges: &Arc<ReplicaGauges>,
                report: &Arc<Mutex<Option<ReplicaReport>>>,
                steal: Option<Arc<Rebalancer>>, tier: &ReplicaTier,
                tracer: &Tracer, cache: Option<Arc<PoolCache>>,
                supervised: bool) -> Result<JoinHandle<()>> {
    let (q2, g2, r2) = (queue.clone(), gauges.clone(), report.clone());
    let t2 = tier.clone();
    let tr2 = tracer.clone();
    std::thread::Builder::new()
        .name(format!("lazydit-replica-{id}"))
        .spawn(move || {
                // a panicking engine (e.g. an assert deep in the sampler)
                // must not wedge the pool: post a failure report and close
                // the queue so waiting clients error out instead of
                // hanging. `responders` lives outside the unwind so the
                // handler knows exactly how many admitted requests died
                // with the engine; `engine_pending` mirrors the engine's
                // share of the pending_steps gauge so the handler can
                // subtract exactly that — an absolute `store(0)` here
                // would race a concurrent dispatch's optimistic
                // `fetch_add` (or a thief's gauge transfer) and leave a
                // dead replica with phantom backlog that permanently
                // skews jsq/lazy ordering.
                let mut responders: BTreeMap<u64, mpsc::Sender<RequestResult>> =
                    BTreeMap::new();
                // boundary snapshots of every resident, refreshed after
                // each completed round (stealing pools only): the crash-
                // resume source. Lives outside the unwind so the panic
                // handler can hand the last consistent state of each
                // resident to a sibling instead of forfeiting it.
                let mut stash: BTreeMap<u64, TrajectorySnapshot> =
                    BTreeMap::new();
                let engine_pending = AtomicUsize::new(0);
                let admitting = AtomicUsize::new(0);
                let result = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        run_replica(id, factory, &q2, &g2, &r2,
                                    &mut responders, &mut stash,
                                    steal.as_deref(),
                                    &engine_pending, &admitting, &t2, &tr2,
                                    cache.as_deref(), supervised)
                    }));
                if result.is_err() {
                    log::warn!("replica {id}: worker panicked");
                    if !supervised {
                        refuse_remaining(&q2, &g2);
                    }
                    // requests admitted into the unwound engine can never
                    // complete HERE — but their last boundary snapshots
                    // can resume. Recover what places; forfeit only the
                    // rest, and roll exactly the engine's known step
                    // backlog out of the gauge (an in-flight dispatch's
                    // optimistic increment is left for its own rollback,
                    // so nothing is double-resolved or wiped).
                    let lost = responders.len();
                    let mut recovered = 0u64;
                    let mut requeued = 0usize;
                    let mut requeued_steps = 0usize;
                    if supervised {
                        // the queue stays OPEN: the respawned incarnation
                        // inherits it. Residents resume in this same tier
                        // slot — own queue first (self-healing works even
                        // in a one-replica pool), siblings as fallback.
                        for (_, snap) in std::mem::take(&mut stash) {
                            let Some(tx) = responders.remove(&snap.req.id)
                            else { continue };
                            let steps = snap.pending_steps();
                            let job = PoolJob::resumed_restamped(snap, tx);
                            match q2.try_push(job) {
                                Ok(()) => {
                                    recovered += 1;
                                    requeued += 1;
                                    requeued_steps += steps;
                                }
                                Err(job) => {
                                    let placed = steal
                                        .as_deref()
                                        .map(|rb| {
                                            rb.place_from_dead(id, job)
                                              .is_ok()
                                        })
                                        .unwrap_or(false);
                                    if placed {
                                        recovered += 1;
                                        g2.migrated_out.fetch_add(
                                            1, Ordering::Relaxed);
                                    }
                                }
                            }
                        }
                    } else if let Some(rb) = steal.as_deref() {
                        for (_, snap) in std::mem::take(&mut stash) {
                            let Some(tx) = responders.remove(&snap.req.id)
                            else { continue };
                            let rid = snap.req.id;
                            let saved = snap.cursor;
                            let job = PoolJob::resumed_restamped(snap, tx);
                            // thief-side-only accounting: this side's
                            // ledger resolves wholesale below
                            if rb.place_from_dead(id, job).is_ok() {
                                recovered += 1;
                                g2.migrated_out.fetch_add(
                                    1, Ordering::Relaxed);
                                log::debug!(
                                    "replica {id}: resident {rid} \
                                     recovered to a sibling at step \
                                     {saved}");
                            }
                        }
                    }
                    g2.forfeited.fetch_add(lost as u64 - recovered,
                                           Ordering::Relaxed);
                    dec(&g2.queued, lost);
                    dec(&g2.pending_steps,
                        engine_pending.load(Ordering::Relaxed));
                    // self-requeued residents are queued again awaiting
                    // the next incarnation — re-credit exactly them
                    if requeued > 0 {
                        g2.queued.fetch_add(requeued, Ordering::Relaxed);
                        g2.pending_steps
                            .fetch_add(requeued_steps, Ordering::Relaxed);
                    }
                    // a job that died inside engine.submit left the queue
                    // but never reached `responders` — without this, each
                    // such panic would leak one admission-ledger slot
                    // (phantom queued + wire steps) forever
                    let adm = admitting.load(Ordering::Relaxed);
                    if adm > 0 {
                        g2.forfeited.fetch_add(1, Ordering::Relaxed);
                        dec(&g2.queued, 1);
                        dec(&g2.pending_steps, adm - 1);
                    }
                    if supervised {
                        g2.needs_respawn.store(true, Ordering::Release);
                    } else {
                        let mut slot =
                            r2.lock().unwrap_or_else(|p| p.into_inner());
                        if slot.is_none() {
                            let mut rep =
                                ReplicaReport::failed(id, "worker panicked");
                            rep.tier = t2.clone();
                            rep.steals = g2.steals.load(Ordering::Relaxed);
                            rep.stolen = g2.stolen.load(Ordering::Relaxed);
                            rep.migrated_out =
                                g2.migrated_out.load(Ordering::Relaxed);
                            rep.migrated_in =
                                g2.migrated_in.load(Ordering::Relaxed);
                            rep.restarts =
                                g2.restarts.load(Ordering::Relaxed);
                            rep.breaker_trips =
                                g2.breaker_trips.load(Ordering::Relaxed);
                            rep.deadline_hits =
                                g2.deadline_hits.load(Ordering::Relaxed);
                            rep.deadline_misses =
                                g2.deadline_misses.load(Ordering::Relaxed);
                            rep.completed_by_slo = g2.completed_by_slo();
                            *slot = Some(rep);
                        }
                    }
                }
                if g2.needs_respawn.load(Ordering::Acquire) {
                    // supervised: down, not dead — the slot awaits its
                    // next incarnation. `finished` stays false so the
                    // queue remains in the pool's servable ledger.
                    return;
                }
                // single exit point: the report (normal, error, or panic)
                // is posted by now, so the replica is observably finished
                g2.finished.store(true, Ordering::Release);
            })
            .with_context(|| format!("spawning replica {id}"))
}

impl ReplicaHandle {
    /// Snapshot for the router's selection policies, carrying this
    /// handle's tier provisioning (SLO class, batch width).
    pub fn snapshot(&self) -> GaugeSnapshot {
        self.gauges.snapshot(&self.tier)
    }

    /// This replica's stealable surface (input queue + gauges + tier),
    /// handed to the pool [`Rebalancer`] at registration.
    pub fn steal_peer(&self) -> StealPeer {
        StealPeer {
            id: self.id,
            queue: self.queue.clone(),
            gauges: self.gauges.clone(),
            tier: self.tier.clone(),
        }
    }

    /// Hand a job to this replica; `Err(job)` if its queue is full or
    /// closed (the router then tries the next candidate or sheds).
    pub fn try_send(&self, job: PoolJob) -> std::result::Result<(), PoolJob> {
        self.queue.try_push(job)
    }

    /// Stop accepting work. The worker finishes queued + in-flight
    /// trajectories, then exits (drain semantics).
    pub fn close(&self) {
        self.queue.close();
    }

    /// Ask the worker to evict every resident trajectory at its next
    /// step boundary and hand them to compatible siblings (drain-by-
    /// migration). Asynchronous: the flag lowers once the sweep ran;
    /// residents nobody can take resume locally, so nothing strands.
    /// A no-op without a pool rebalancer — there is nowhere to migrate.
    pub fn request_drain(&self) {
        self.gauges.drain.store(true, Ordering::Release);
    }

    /// True while a requested drain sweep has not yet run.
    pub fn draining(&self) -> bool {
        self.gauges.drain.load(Ordering::Acquire)
    }

    /// Retag this replica to serve `slo`, draining current residents by
    /// migration first: requests admitted under the old class move to
    /// compatible siblings (or finish here if nobody can take them),
    /// and every dispatch after this call routes by the new class.
    pub fn retag(&self, slo: Slo) {
        self.request_drain();
        self.gauges
            .slo_tag
            .store(slo.index() + 1, Ordering::Release);
    }

    /// The SLO class this replica serves right now (live retag aware).
    pub fn live_slo(&self) -> Slo {
        self.gauges.live_slo(self.tier.slo)
    }

    /// True once the worker has exported its final report — normal drain
    /// or failure. Used by the serve loop's liveness check.
    pub fn finished(&self) -> bool {
        self.report
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .is_some()
    }

    /// Close, wait for the worker, and return its final report.
    pub fn join_report(&self) -> ReplicaReport {
        self.close();
        if let Some(h) = self.join.lock().unwrap().take() {
            let _ = h.join();
        }
        // a down-awaiting-respawn slot that never got its respawn still
        // holds parked jobs: refuse them now so the admission ledger
        // settles at shutdown (a no-op after a normal drain)
        refuse_remaining(&self.queue, &self.gauges);
        self.report
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .take()
            .unwrap_or_else(|| {
                ReplicaReport::failed(self.id, "replica exited without a report")
            })
    }
}

/// How long an idle worker sleeps between probes. A stealing worker
/// polls fast right after going idle (a sibling's backlog is an
/// immediate opportunity), then backs off to the plain cadence once
/// `IDLE_BACKOFF_AFTER` consecutive probes found nothing — a genuinely
/// idle pool must not burn O(replicas²) lock traffic at 1 kHz. Any
/// admitted job (own queue or steal) resets the backoff.
const IDLE_WAIT_STEAL: Duration = Duration::from_millis(1);
const IDLE_WAIT_PLAIN: Duration = Duration::from_millis(50);
const IDLE_BACKOFF_AFTER: u32 = 64;

/// The worker loop: admit continuously (bounded by the rebalancer's
/// window when stealing is on), step the engine, keep gauges fresh,
/// steal from overloaded siblings when idle, drain on close.
/// `responders` (admitted-but-unfinished response channels) and
/// `engine_pending` (the engine's share of the pending_steps gauge) are
/// owned by the caller so the panic handler can account for requests
/// lost in an unwind by exact, known amounts.
#[allow(clippy::too_many_arguments)]
fn run_replica(id: usize, factory: EngineFactory,
               queue: &BoundedQueue<PoolJob>, gauges: &ReplicaGauges,
               report: &Mutex<Option<ReplicaReport>>,
               responders: &mut BTreeMap<u64, mpsc::Sender<RequestResult>>,
               stash: &mut BTreeMap<u64, TrajectorySnapshot>,
               steal: Option<&Rebalancer>, engine_pending: &AtomicUsize,
               admitting: &AtomicUsize, tier: &ReplicaTier,
               tracer: &Tracer, cache: Option<&PoolCache>,
               supervised: bool) {
    let mut engine: Box<dyn PoolEngine> = match factory() {
        Ok(e) => e,
        Err(e) => {
            let msg = format!("engine construction failed: {e:#}");
            log::warn!("replica {id}: {msg}");
            if supervised {
                // construction failures count against the restart
                // budget too: leave the queue open and let the
                // supervisor retry (or give up) — a transient artifact
                // hiccup should not permanently kill the slot
                gauges.needs_respawn.store(true, Ordering::Release);
                return;
            }
            refuse_remaining(queue, gauges);
            let mut rep = ReplicaReport::failed(id, msg);
            rep.tier = tier.clone();
            *report.lock().unwrap() = Some(rep);
            return;
        }
    };
    engine.install_tracer(tracer.clone());
    log::debug!("replica {id} up (policy {})", engine.policy_name());

    // The router optimistically added the *wire* step count to the
    // pending_steps gauge; the engine may admit fewer (its submit clamps
    // to the schedule). Reconcile at admission so the gauge tracks what
    // will actually be consumed — otherwise the residue accumulates and
    // biases jsq/lazy routing against this replica forever.
    #[allow(clippy::too_many_arguments)]
    fn admit(engine: &mut Box<dyn PoolEngine>,
             responders: &mut BTreeMap<u64, mpsc::Sender<RequestResult>>,
             gauges: &ReplicaGauges, engine_pending: &AtomicUsize,
             admitting: &AtomicUsize, tracer: &Tracer,
             cache: Option<&PoolCache>,
             result_keys: &mut BTreeMap<u64, RequestKey>,
             deadlines: &mut BTreeMap<u64, u64>, job: PoolJob) {
        let wire_steps = job.remaining_steps();
        let wire_id = job.id();
        // the job leaves the queued-work pool here: its priced backlog
        // contribution comes off the gauge whether or not submit
        // succeeds (a submit panic settles the rest of the ledger, and
        // re-queued residents re-enter at cost 0)
        dec_u64(&gauges.predicted_cost_milli, job.cost_milli);
        let deadline_us = job.deadline_us();
        if tracer.is_enabled() {
            let now = tracer.now_us();
            tracer.record_at(TraceEvent {
                kind: EventKind::Admit, ts_us: now, dur_us: 0,
                kind_id: wire_id, arg: wire_steps as u64,
            });
            if job.enqueued_us > 0 {
                tracer.record_at(TraceEvent {
                    kind: EventKind::QueueWait, ts_us: now,
                    dur_us: now.saturating_sub(job.enqueued_us),
                    kind_id: wire_id, arg: wire_steps as u64,
                });
            }
        }
        // mark the job in-admission (steps + 1 so 0 means "none"): if
        // submit panics, the handler must resolve exactly this job's
        // ledger entry — it left the queue but never reached responders
        admitting.store(wire_steps + 1, Ordering::Relaxed);
        let before = engine.pending_steps();
        let rid = match job.payload {
            JobPayload::Fresh(req) => match cache {
                Some(c) => {
                    // near-hit check: a same-family donor seeds the
                    // joiner's lane caches so its early would-skips
                    // skip instead of being cold-denied. submit_warm
                    // falls back to a cold admission on any mismatch.
                    let key = c.key_of(&req);
                    let (rid, rows) = match c.donate(&req) {
                        Some(donor) => engine.submit_warm(req, &donor),
                        None => (engine.submit(req), 0),
                    };
                    if rows > 0 {
                        gauges.warm_hits.fetch_add(1, Ordering::Relaxed);
                    }
                    result_keys.insert(rid, key);
                    rid
                }
                None => engine.submit(req),
            },
            JobPayload::Resumed(snap) => {
                gauges.migrated_in.fetch_add(1, Ordering::Relaxed);
                gauges.resumed.fetch_add(1, Ordering::Relaxed);
                gauges
                    .resume_steps_saved
                    .fetch_add(snap.cursor as u64, Ordering::Relaxed);
                if tracer.is_enabled() {
                    tracer.record_at(TraceEvent {
                        kind: EventKind::Migrate,
                        ts_us: tracer.now_us(),
                        dur_us: 0,
                        kind_id: wire_id,
                        arg: pack_pair(snap.cursor as u32,
                                       snap.pending_steps() as u32),
                    });
                }
                if let Some(c) = cache {
                    // a migrated trajectory's finished result is just
                    // as cacheable as a locally-admitted one
                    result_keys.insert(snap.req.id, c.key_of(&snap.req));
                }
                engine.admit_snapshot(snap)
            }
        };
        let actual = engine.pending_steps().saturating_sub(before);
        if actual < wire_steps {
            dec(&gauges.pending_steps, wire_steps - actual);
        }
        engine_pending.store(engine.pending_steps(), Ordering::Relaxed);
        admitting.store(0, Ordering::Relaxed);
        if deadline_us > 0 {
            deadlines.insert(rid, deadline_us);
        }
        responders.insert(rid, job.respond);
    }
    let mut error: Option<String> = None;
    let mut idle_misses = 0u32;
    // cache bookkeeping: the canonical key of every admitted request
    // (derived at admission, consumed when its result is inserted into
    // the exact tier) and the residents whose donor window has closed
    // (cursor past the warm horizon — stop snapshotting them).
    let mut result_keys: BTreeMap<u64, RequestKey> = BTreeMap::new();
    let mut donor_done: BTreeSet<u64> = BTreeSet::new();
    // declared deadline of every admitted-but-unfinished request,
    // captured at admission (the payload is consumed there) and settled
    // into the hit/miss gauges at retire. Residents that migrate away
    // retire elsewhere; their stale entries are dropped on removal
    // misses and die with the map — advisory accounting, never a leak.
    let mut deadlines: BTreeMap<u64, u64> = BTreeMap::new();
    // brownout stage-2 dial, applied only on change (the engine call may
    // recompute thresholds); 0 restores the configured target
    let mut boost_applied = 0usize;

    loop {
        // liveness heartbeat: the supervisor's stall detector watches
        // this counter — a wedged engine stops bumping it, a merely
        // slow one keeps a (long) cadence
        gauges.heartbeat.fetch_add(1, Ordering::Relaxed);
        gauges
            .heartbeat_us
            .store(crate::obs::epoch_us(), Ordering::Relaxed);
        let boost = gauges.gamma_boost.load(Ordering::Relaxed);
        if boost != boost_applied {
            engine.set_gamma_boost(boost as u32);
            boost_applied = boost;
        }
        // supervisor poison: a stalled-but-returning worker parks its
        // residents into its own (still open) queue and exits so a
        // fresh incarnation can take over
        if supervised && gauges.poisoned.swap(false, Ordering::AcqRel) {
            park_for_respawn(id, &mut engine, queue, gauges, responders,
                             engine_pending, cache);
            return;
        }
        // drain-by-migration: evict every resident at this step
        // boundary and hand them to compatible siblings (retag,
        // pre-shutdown). Unplaceable residents resume locally inside
        // the sweep, so the drain can never strand a trajectory.
        if gauges.drain.load(Ordering::Acquire) {
            if let Some(rb) = steal {
                migrate_residents(id, &mut engine, gauges, responders,
                                  rb, tracer, cache, None);
                engine_pending
                    .store(engine.pending_steps(), Ordering::Relaxed);
                stash.clear();
            }
            gauges.drain.store(false, Ordering::Release);
        }
        // mid-trajectory relief: an idle thief whose backlog we dwarf
        // asked for ONE resident ([`ReplicaGauges::evict_to`])
        let relief = gauges.evict_to.swap(0, Ordering::AcqRel);
        if relief > 0 {
            if let Some(rb) = steal {
                migrate_residents(id, &mut engine, gauges, responders,
                                  rb, tracer, cache, Some(relief - 1));
                engine_pending
                    .store(engine.pending_steps(), Ordering::Relaxed);
            }
        }
        // cap how many trajectories sit inside the engine: the tier's
        // steal window while stealing is on (everything beyond it stays
        // in the queue, where it remains migratable — an engine-admitted
        // trajectory can never move), the tier's batch width otherwise.
        // Re-read every iteration: the rebalancer narrows the window by
        // one step while sibling backlogs are overdispersed
        // (`Rebalancer::effective_window`), restoring it when balanced.
        let window = match steal {
            Some(rb) => rb.effective_window(tier),
            None => tier.engine_window(false),
        };
        // continuous batching: absorb whatever arrived, up to the
        // window. EDF tiers take the earliest effective deadline first
        // (exact FIFO when nothing declares one); the FIFO arm exists
        // for A/B measurement.
        while engine.active_count() < window {
            let popped = if tier.edf {
                queue.try_pop_min_by_key(|j| j.effective_deadline())
            } else {
                queue.try_pop()
            };
            match popped {
                Some(job) => {
                    idle_misses = 0;
                    admit(&mut engine, responders, gauges, engine_pending,
                          admitting, tracer, cache, &mut result_keys,
                          &mut deadlines, job);
                }
                None => break,
            }
        }
        if engine.active_count() == 0 {
            // idle: prefer pulling a queued job off an overloaded
            // sibling over waiting for the router to send one here
            if let Some(rb) = steal {
                if let Some(job) = rb.steal_for(id) {
                    idle_misses = 0;
                    if tracer.is_enabled() {
                        let now = tracer.now_us();
                        let queued = if job.enqueued_us > 0 {
                            now.saturating_sub(job.enqueued_us)
                        } else {
                            0
                        };
                        tracer.record_at(TraceEvent {
                            kind: EventKind::Steal, ts_us: now,
                            dur_us: queued, kind_id: job.id(),
                            arg: job.remaining_steps() as u64,
                        });
                    }
                    admit(&mut engine, responders, gauges, engine_pending,
                          admitting, tracer, cache, &mut result_keys,
                          &mut deadlines, job);
                    continue;
                }
            }
            idle_misses = idle_misses.saturating_add(1);
            let wait = if steal.is_some()
                && idle_misses < IDLE_BACKOFF_AFTER
            {
                IDLE_WAIT_STEAL
            } else {
                IDLE_WAIT_PLAIN
            };
            let popped = if tier.edf {
                queue.pop_timeout_min_by_key(wait,
                                             |j| j.effective_deadline())
            } else {
                queue.pop_timeout(wait)
            };
            match popped {
                Popped::Item(job) => {
                    idle_misses = 0;
                    admit(&mut engine, responders, gauges, engine_pending,
                          admitting, tracer, cache, &mut result_keys,
                          &mut deadlines, job);
                }
                Popped::Closed => break,
                Popped::TimedOut => continue,
            }
            continue; // absorb any burst before stepping
        }
        let before = engine.pending_steps();
        match engine.step_round() {
            Ok(finished) => {
                for res in finished {
                    gauges.completed.fetch_add(1, Ordering::Relaxed);
                    gauges.completed_by_slo[res.slo.index()]
                        .fetch_add(1, Ordering::Relaxed);
                    gauges.record_latency(res.slo, res.latency);
                    // deadline settlement: compare the retire instant
                    // against the declared deadline captured at
                    // admission (deadline-free requests skip both
                    // buckets, so hit-rate is over declared SLOs only)
                    if let Some(dl) = deadlines.remove(&res.id) {
                        if crate::obs::epoch_us() <= dl {
                            gauges
                                .deadline_hits
                                .fetch_add(1, Ordering::Relaxed);
                        } else {
                            gauges
                                .deadline_misses
                                .fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    if tracer.is_enabled() {
                        tracer.record_at(TraceEvent {
                            kind: EventKind::Retire,
                            ts_us: tracer.now_us(),
                            dur_us: res.latency.as_micros() as u64,
                            kind_id: res.id,
                            arg: pack_pair(res.slo.index() as u32,
                                           res.steps as u32),
                        });
                    }
                    dec(&gauges.queued, 1);
                    donor_done.remove(&res.id);
                    // cache the finished result BEFORE responding, so a
                    // client that immediately repeats the request is
                    // guaranteed to observe the hit
                    if let (Some(c), Some(key)) =
                        (cache, result_keys.remove(&res.id))
                    {
                        c.insert(key, &res);
                    }
                    if let Some(tx) = responders.remove(&res.id) {
                        let _ = tx.send(res);
                    }
                }
                let consumed = before.saturating_sub(engine.pending_steps());
                dec(&gauges.pending_steps, consumed);
                engine_pending
                    .store(engine.pending_steps(), Ordering::Relaxed);
                let ls = engine.layer_stats();
                gauges
                    .modules_seen
                    .store(ls.total.iter().sum(), Ordering::Relaxed);
                gauges
                    .modules_skipped
                    .store(ls.skips.iter().sum(), Ordering::Relaxed);
                gauges
                    .cold_denied
                    .store(ls.cold_denied_total(), Ordering::Relaxed);
                gauges
                    .rows_run
                    .store(ls.rows_run_total(), Ordering::Relaxed);
                gauges
                    .rows_skipped
                    .store(ls.rows_skipped_total(), Ordering::Relaxed);
                gauges
                    .rows_recovered
                    .store(ls.rows_recovered_total(), Ordering::Relaxed);
                gauges
                    .rows_warmed
                    .store(ls.rows_warmed_total(), Ordering::Relaxed);
                // donor harvesting: while a resident's cursor is inside
                // the warm horizon, offer its boundary snapshot to the
                // donor store (deeper boundaries replace shallower
                // ones). Once it crosses the horizon its donor window
                // is closed for good — stop snapshotting it.
                if let Some(c) = cache {
                    if c.warm_enabled() {
                        let horizon = c.warm_horizon();
                        for aid in engine.active_ids() {
                            if donor_done.contains(&aid) {
                                continue;
                            }
                            let Some(s) = engine.snapshot_request(aid)
                            else { continue };
                            if s.cursor > horizon {
                                donor_done.insert(aid);
                            } else if s.cursor > 0 {
                                c.offer_donor(&s);
                                if s.cursor == horizon {
                                    donor_done.insert(aid);
                                }
                            }
                        }
                    }
                }
                // refresh the crash-resume stash at this boundary: the
                // last consistent snapshot of every resident, so a
                // panic mid-round loses at most one round of work per
                // trajectory instead of the whole denoise. Supervised
                // workers stash even alone — their own next incarnation
                // is the resume target.
                if steal.is_some() || supervised {
                    stash.clear();
                    for aid in engine.active_ids() {
                        if let Some(s) = engine.snapshot_request(aid) {
                            stash.insert(aid, s);
                        }
                    }
                }
            }
            Err(e) => {
                error = Some(format!("step_round failed: {e:#}"));
                log::warn!("replica {id}: {}", error.as_deref().unwrap());
                if supervised {
                    // a step error counts against the restart budget
                    // like a panic: park what can resume, hand the slot
                    // to the supervisor, post no report
                    park_for_respawn(id, &mut engine, queue, gauges,
                                     responders, engine_pending, cache);
                    return;
                }
                break;
            }
        }
    }

    if error.is_some() {
        // forfeit whatever is left so pool-wide gauges stay sane; dropped
        // responders surface as "engine stopped" on the client side
        dec(&gauges.pending_steps, engine.pending_steps());
        dec(&gauges.queued, engine.active_count());
        gauges
            .forfeited
            .fetch_add(engine.active_count() as u64, Ordering::Relaxed);
        refuse_remaining(queue, gauges);
    }
    engine_pending.store(0, Ordering::Relaxed);
    // report the tier as *currently served*: a retagged replica's final
    // accounting belongs to its live class, not its birth provisioning
    let mut tier_now = tier.clone();
    tier_now.slo = gauges.live_slo(tier.slo);
    *report.lock().unwrap() = Some(ReplicaReport {
        id,
        policy: engine.policy_name(),
        tier: tier_now,
        layer: engine.layer_stats().clone(),
        serve: engine.serve_stats().clone(),
        completed_by_slo: gauges.completed_by_slo(),
        steals: gauges.steals.load(Ordering::Relaxed),
        stolen: gauges.stolen.load(Ordering::Relaxed),
        migrated_out: gauges.migrated_out.load(Ordering::Relaxed),
        migrated_in: gauges.migrated_in.load(Ordering::Relaxed),
        warm_hits: gauges.warm_hits.load(Ordering::Relaxed),
        restarts: gauges.restarts.load(Ordering::Relaxed),
        breaker_trips: gauges.breaker_trips.load(Ordering::Relaxed),
        deadline_hits: gauges.deadline_hits.load(Ordering::Relaxed),
        deadline_misses: gauges.deadline_misses.load(Ordering::Relaxed),
        arena: engine.arena_stats(),
        error,
    });
    log::debug!("replica {id} drained");
}

/// Saturating atomic decrement — gauge bookkeeping must never wrap even
/// when a matching increment was skipped (tests, error paths, a dispatch
/// rollback racing the panic handler's or a thief's own decrements).
pub(crate) fn dec(a: &AtomicUsize, n: usize) {
    let _ = a.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
        Some(v.saturating_sub(n))
    });
}

/// [`dec`] for the u64 gauges (predicted cost) — saturating for the
/// same reason: a missed increment (resumed job, test harness) must
/// never wrap the gauge into a pool-sized phantom backlog.
pub(crate) fn dec_u64(a: &AtomicU64, n: u64) {
    let _ = a.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
        Some(v.saturating_sub(n))
    });
}

/// Drop queued jobs (their responders close → clients see a structured
/// "engine stopped") and roll their load out of the gauges, marking each
/// as forfeited for the router's admission ledger.
fn refuse_remaining(queue: &BoundedQueue<PoolJob>, gauges: &ReplicaGauges) {
    queue.close();
    while let Some(job) = queue.try_pop() {
        dec(&gauges.queued, 1);
        dec(&gauges.pending_steps, job.remaining_steps());
        dec_u64(&gauges.predicted_cost_milli, job.cost_milli);
        gauges.forfeited.fetch_add(1, Ordering::Relaxed);
    }
}

/// A supervised worker's cooperative exit (poison, step error): evict
/// every resident at this boundary and push it — as a resumable
/// snapshot — into the replica's OWN still-open queue, where the next
/// incarnation picks it up. A successfully parked resident keeps its
/// admission-ledger entries (it is queued again, just as before);
/// residents that will not evict or will not fit forfeit with exact
/// decrements. Ends by raising `needs_respawn` for the supervisor.
fn park_for_respawn(id: usize, engine: &mut Box<dyn PoolEngine>,
                    queue: &BoundedQueue<PoolJob>, gauges: &ReplicaGauges,
                    responders: &mut BTreeMap<u64,
                                             mpsc::Sender<RequestResult>>,
                    engine_pending: &AtomicUsize,
                    cache: Option<&PoolCache>) {
    for rid in engine.active_ids() {
        let Some(tx) = responders.remove(&rid) else { continue };
        let Some(snap) = engine.evict_to_snapshot(rid) else {
            // un-evictable (e.g. a corrupting codec fault): it dies
            // with this incarnation, settled in the leftover pass below
            responders.insert(rid, tx);
            continue;
        };
        if let Some(c) = cache {
            c.offer_donor(&snap);
        }
        let steps = snap.pending_steps();
        let job = PoolJob::resumed_restamped(snap, tx);
        if queue.try_push(job).is_err() {
            // full or closed: the dropped responder surfaces a
            // structured error on the client; the ledger resolves here
            gauges.forfeited.fetch_add(1, Ordering::Relaxed);
            dec(&gauges.queued, 1);
            dec(&gauges.pending_steps, steps);
        }
    }
    // whatever still sits inside the engine dies with this incarnation
    let left = engine.active_count();
    if left > 0 {
        gauges.forfeited.fetch_add(left as u64, Ordering::Relaxed);
        dec(&gauges.queued, left);
        dec(&gauges.pending_steps, engine.pending_steps());
        for rid in engine.active_ids() {
            responders.remove(&rid);
        }
    }
    engine_pending.store(0, Ordering::Relaxed);
    gauges.needs_respawn.store(true, Ordering::Release);
    log::warn!("replica {id}: parked {} resident(s) for respawn",
               gauges.queued.load(Ordering::Relaxed));
}

/// Evict residents at the current step boundary and hand them to
/// siblings. `to == None` is the drain sweep: every resident, placed on
/// the compatible sibling with the lowest effective backlog. `to ==
/// Some(thief)` is mid-trajectory relief: the newest resident (largest
/// id — statistically the most remaining work and the coldest caches,
/// chosen without cloning every resident's caches just to rank them),
/// pushed to the requesting thief. Either way, a resident nobody can
/// take is re-admitted locally in the same pass: migration is an
/// optimization, never a way to lose work.
#[allow(clippy::too_many_arguments)]
fn migrate_residents(id: usize, engine: &mut Box<dyn PoolEngine>,
                     gauges: &ReplicaGauges,
                     responders: &mut BTreeMap<u64,
                                              mpsc::Sender<RequestResult>>,
                     rb: &Rebalancer, tracer: &Tracer,
                     cache: Option<&PoolCache>, to: Option<usize>) {
    let ids: Vec<u64> = if to.is_some() {
        engine.active_ids().into_iter().max().into_iter().collect()
    } else {
        engine.active_ids()
    };
    for rid in ids {
        let Some(tx) = responders.remove(&rid) else { continue };
        let Some(snap) = engine.evict_to_snapshot(rid) else {
            responders.insert(rid, tx);
            continue;
        };
        // an evicted boundary inside the warm horizon is donor-grade
        // state; retain it before the snapshot leaves this replica
        if let Some(c) = cache {
            c.offer_donor(&snap);
        }
        let steps = snap.pending_steps();
        let cursor = snap.cursor;
        let job = PoolJob::resumed_restamped(snap, tx);
        let placed = match to {
            Some(thief) => rb.push_to(id, thief, job),
            None => rb.place(id, job),
        };
        match placed {
            Ok(dest) => {
                gauges.migrated_out.fetch_add(1, Ordering::Relaxed);
                if tracer.is_enabled() {
                    tracer.record_at(TraceEvent {
                        kind: EventKind::Migrate,
                        ts_us: tracer.now_us(),
                        dur_us: 0,
                        kind_id: rid,
                        arg: pack_pair(cursor as u32, steps as u32),
                    });
                }
                log::debug!("replica {id}: resident {rid} migrated to \
                             replica {dest} at step {cursor}");
            }
            Err(job) => {
                let PoolJob { payload, respond, .. } = job;
                if let JobPayload::Resumed(snap) = payload {
                    let back = engine.admit_snapshot(snap);
                    responders.insert(back, respond);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::sim::{SimEngine, SimSpec};

    fn job(seed: u64, steps: usize)
           -> (PoolJob, mpsc::Receiver<RequestResult>) {
        let (tx, rx) = mpsc::channel();
        (PoolJob::fresh(Request::new(0, 3, steps, seed), tx, 0), rx)
    }

    #[test]
    fn replica_serves_and_reports() {
        let h = ReplicaHandle::spawn(0, 16, SimEngine::factory(SimSpec::fast()))
            .unwrap();
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (j, rx) = job(i, 4);
            h.gauges.queued.fetch_add(1, Ordering::Relaxed);
            h.gauges.pending_steps.fetch_add(4, Ordering::Relaxed);
            h.try_send(j).map_err(|_| "send").unwrap();
            rxs.push(rx);
        }
        for rx in rxs {
            let res = rx.recv().unwrap();
            assert_eq!(res.steps, 4);
        }
        let rep = h.join_report();
        assert!(rep.error.is_none(), "{:?}", rep.error);
        assert_eq!(rep.serve.completed, 5);
        assert_eq!(h.gauges.completed.load(Ordering::Relaxed), 5);
        assert_eq!(h.gauges.queued.load(Ordering::Relaxed), 0);
        assert_eq!(h.gauges.pending_steps.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn close_drains_in_flight() {
        let h = ReplicaHandle::spawn(1, 16, SimEngine::factory(SimSpec::fast()))
            .unwrap();
        let mut rxs = Vec::new();
        for i in 0..8 {
            let (j, rx) = job(100 + i, 6);
            h.gauges.queued.fetch_add(1, Ordering::Relaxed);
            h.gauges.pending_steps.fetch_add(6, Ordering::Relaxed);
            h.try_send(j).map_err(|_| "send").unwrap();
            rxs.push(rx);
        }
        // close immediately: every queued job must still complete
        let rep = h.join_report();
        for rx in rxs {
            assert!(rx.recv().is_ok(), "drain must finish in-flight work");
        }
        assert_eq!(rep.serve.completed, 8);
    }

    #[test]
    fn factory_failure_yields_error_report() {
        let factory: EngineFactory =
            Box::new(|| anyhow::bail!("no artifacts here"));
        let h = ReplicaHandle::spawn(2, 4, factory).unwrap();
        let (j, rx) = job(1, 4);
        let _ = h.try_send(j);
        let rep = h.join_report();
        assert!(rep.error.is_some());
        // responder dropped → receiver errors out rather than hanging
        assert!(rx.recv().is_err());
    }

    #[test]
    fn worker_panic_reports_and_releases_clients() {
        struct PanicEngine {
            layer: LayerStats,
            serve: ServeStats,
            active: usize,
        }
        impl PoolEngine for PanicEngine {
            fn submit(&mut self, req: Request) -> u64 {
                self.active += 1;
                req.id.max(1)
            }
            fn active_count(&self) -> usize {
                self.active
            }
            fn pending_steps(&self) -> usize {
                self.active
            }
            fn step_round(&mut self) -> Result<Vec<RequestResult>> {
                panic!("injected panic")
            }
            fn layer_stats(&self) -> &LayerStats {
                &self.layer
            }
            fn serve_stats(&self) -> &ServeStats {
                &self.serve
            }
            fn policy_name(&self) -> String {
                "panic".into()
            }
        }
        let factory: EngineFactory = Box::new(|| {
            Ok(Box::new(PanicEngine {
                layer: LayerStats::new(1),
                serve: ServeStats::default(),
                active: 0,
            }) as Box<dyn PoolEngine>)
        });
        let h = ReplicaHandle::spawn(9, 4, factory).unwrap();
        let (j, rx) = job(1, 4);
        h.try_send(j).map_err(|_| "send").unwrap();
        let rep = h.join_report();
        assert_eq!(rep.error.as_deref(), Some("worker panicked"));
        assert!(rx.recv().is_err(), "client must not hang on a panicked worker");
    }

    #[test]
    fn submit_panic_resolves_ledger_exactly() {
        // a job that dies inside engine.submit has left the queue but
        // never reached `responders` — its admission-ledger entry must
        // still resolve (forfeit) and its optimistic gauge contribution
        // must unwind, or the slot would leak from the pool cap forever
        struct SubmitPanicEngine {
            layer: LayerStats,
            serve: ServeStats,
        }
        impl PoolEngine for SubmitPanicEngine {
            fn submit(&mut self, _req: Request) -> u64 {
                panic!("injected submit panic")
            }
            fn active_count(&self) -> usize {
                0
            }
            fn pending_steps(&self) -> usize {
                0
            }
            fn step_round(&mut self) -> Result<Vec<RequestResult>> {
                Ok(Vec::new())
            }
            fn layer_stats(&self) -> &LayerStats {
                &self.layer
            }
            fn serve_stats(&self) -> &ServeStats {
                &self.serve
            }
            fn policy_name(&self) -> String {
                "submit-panic".into()
            }
        }
        let factory: EngineFactory = Box::new(|| {
            Ok(Box::new(SubmitPanicEngine {
                layer: LayerStats::new(1),
                serve: ServeStats::default(),
            }) as Box<dyn PoolEngine>)
        });
        let h = ReplicaHandle::spawn(7, 4, factory).unwrap();
        let (j, rx) = job(1, 5);
        // mirror the router's optimistic accounting at dispatch
        h.gauges.queued.fetch_add(1, Ordering::Relaxed);
        h.gauges.pending_steps.fetch_add(5, Ordering::Relaxed);
        h.try_send(j).map_err(|_| "send").unwrap();
        let rep = h.join_report();
        assert_eq!(rep.error.as_deref(), Some("worker panicked"));
        assert!(rx.recv().is_err(), "client must be released");
        assert_eq!(h.gauges.queued.load(Ordering::Relaxed), 0,
                   "no phantom queued entry");
        assert_eq!(h.gauges.pending_steps.load(Ordering::Relaxed), 0,
                   "no phantom step backlog");
        assert_eq!(h.gauges.forfeited.load(Ordering::Relaxed), 1,
                   "the admission ledger resolves the dead job");
    }

    #[test]
    fn tier_windows_and_bucket_sets() {
        let t = ReplicaTier::new(Slo::Latency, 1);
        assert_eq!(t.buckets, vec![1]);
        assert_eq!(t.engine_window(false), 1);
        assert_eq!(t.engine_window(true), 1);
        let t = ReplicaTier::new(Slo::Throughput, 8);
        assert_eq!(t.buckets, vec![1, 2, 4, 8]);
        assert_eq!(t.engine_window(false), 8);
        // non-power-of-two widths keep the exact cap as the top bucket
        let t = ReplicaTier::new(Slo::Besteffort, 6);
        assert_eq!(t.buckets, vec![1, 2, 4, 6]);
        assert_eq!(ReplicaTier::new(Slo::Latency, 0).max_batch, 1, "clamped");
        assert!(ReplicaTier::new(Slo::Latency, 1).can_serve(Slo::Besteffort));
        assert!(!ReplicaTier::new(Slo::Latency, 1).can_serve(Slo::Throughput));
    }

    #[test]
    fn tiered_replica_reports_tier_and_per_slo_completions() {
        let tier = ReplicaTier::new(Slo::Latency, 1);
        let h = ReplicaHandle::spawn_tiered(
            4, 16, SimEngine::factory(SimSpec::fast()), None, tier.clone())
            .unwrap();
        let mut rxs = Vec::new();
        for (i, slo) in [Slo::Latency, Slo::Latency, Slo::Besteffort]
            .iter()
            .enumerate()
        {
            let (tx, rx) = mpsc::channel();
            let req = Request::new(0, 1, 3, i as u64).with_slo(*slo);
            h.gauges.queued.fetch_add(1, Ordering::Relaxed);
            h.gauges.pending_steps.fetch_add(3, Ordering::Relaxed);
            h.try_send(PoolJob::fresh(req, tx, 0))
                .map_err(|_| "send")
                .unwrap();
            rxs.push(rx);
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        // the handle's snapshot carries the tier provisioning
        let s = h.snapshot();
        assert_eq!(s.slo, Slo::Latency);
        assert_eq!(s.max_batch, 1);
        let rep = h.join_report();
        assert!(rep.error.is_none(), "{:?}", rep.error);
        assert_eq!(rep.tier, tier);
        assert_eq!(rep.completed_by_slo[Slo::Latency.index()], 2);
        assert_eq!(rep.completed_by_slo[Slo::Besteffort.index()], 1);
        assert_eq!(rep.completed_by_slo[Slo::Throughput.index()], 0);
        assert_eq!(rep.completed_by_slo.iter().sum::<u64>(),
                   rep.serve.completed as u64,
                   "per-SLO counters partition the total");
    }

    #[test]
    fn cached_replica_warm_starts_and_populates_exact_tier() {
        use crate::coordinator::pool::cache::{CacheConfig, PoolCache};
        let spec = SimSpec { lazy_pct: 90, work_per_module: 0,
                             ..SimSpec::default() };
        let cache = Arc::new(PoolCache::new(
            CacheConfig::new(8, 2, spec.img_elems as u64)));
        let h = ReplicaHandle::spawn_cached(
            0, 16, SimEngine::factory(spec), None,
            ReplicaTier::default(), Tracer::disabled(),
            Some(cache.clone()))
            .unwrap();
        let send = |seed: u64| {
            let (tx, rx) = mpsc::channel();
            let req = Request::new(0, 3, 6, seed);
            h.gauges.queued.fetch_add(1, Ordering::Relaxed);
            h.gauges.pending_steps.fetch_add(6, Ordering::Relaxed);
            h.try_send(PoolJob::fresh(req, tx, 0))
                .map_err(|_| "send")
                .unwrap();
            rx
        };
        // first of the family runs cold and becomes a donor while its
        // cursor is inside the warm horizon (2)
        let first = send(500).recv().unwrap();
        // same family, different seed: warm-started from that donor
        let second = send(501).recv().unwrap();
        assert_ne!(first.image.data(), second.image.data(),
                   "different seeds must keep different images");
        assert_eq!(h.gauges.warm_hits.load(Ordering::Relaxed), 1,
                   "the near hit seeds the joiner");
        assert!(h.gauges.rows_warmed.load(Ordering::Relaxed) > 0,
                "step-0 would-skips convert under the seeded cache");
        let st = cache.stats();
        assert_eq!(st.inserted, 2, "both results cached before respond");
        assert!(st.donated >= 1, "the donor store served the near hit");
        // the exact tier now serves a repeat with zero engine work
        let hit = cache
            .lookup(&Request::new(0, 3, 6, 500))
            .expect("exact repeat must hit");
        assert_eq!(hit.image.data(), first.image.data(),
                   "the cached image is the engine's, bit-exact");
        let rep = h.join_report();
        assert!(rep.error.is_none(), "{:?}", rep.error);
        assert_eq!(rep.warm_hits, 1);
        assert_eq!(rep.layer.rows_warmed_total(),
                   h.gauges.rows_warmed.load(Ordering::Relaxed),
                   "gauge mirrors the engine's layer-stats total");
    }

    /// Poll until a supervised slot signals it needs a respawn (the
    /// worker dies asynchronously; tests must not race it).
    fn wait_needs_respawn(h: &ReplicaHandle) {
        for _ in 0..1000 {
            if h.needs_respawn() {
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        panic!("slot never raised needs_respawn");
    }

    #[test]
    fn supervised_construction_failure_respawns_and_serves() {
        // first incarnation fails to build (transient artifact hiccup);
        // the queued job survives in the still-open queue and the
        // respawned incarnation serves it
        let attempts = Arc::new(AtomicUsize::new(0));
        let a2 = attempts.clone();
        let factory: RespawnFactory = Arc::new(move || {
            if a2.fetch_add(1, Ordering::SeqCst) == 0 {
                anyhow::bail!("flaky artifacts");
            }
            (SimEngine::factory(SimSpec::fast()))()
        });
        let h = ReplicaHandle::spawn_supervised(
            0, 16, &factory, None, ReplicaTier::default(),
            Tracer::disabled(), None)
            .unwrap();
        let (j, rx) = job(7, 4);
        h.gauges.queued.fetch_add(1, Ordering::Relaxed);
        h.gauges.pending_steps.fetch_add(4, Ordering::Relaxed);
        h.try_send(j).map_err(|_| "send").unwrap();
        wait_needs_respawn(&h);
        assert!(!h.finished(), "down is not dead: no report posted");
        assert!(!h.gauges.finished.load(Ordering::Acquire));
        h.respawn(&factory, None, None).unwrap();
        let res = rx.recv().expect("respawned incarnation serves the job");
        assert_eq!(res.steps, 4);
        let rep = h.join_report();
        assert!(rep.error.is_none(), "{:?}", rep.error);
        assert_eq!(rep.restarts, 1, "the respawn is accounted");
        assert_eq!(h.gauges.queued.load(Ordering::Relaxed), 0);
        assert_eq!(h.gauges.pending_steps.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn supervised_panic_parks_slot_and_give_up_finishes_it() {
        struct AlwaysPanic {
            layer: LayerStats,
            serve: ServeStats,
            active: usize,
        }
        impl PoolEngine for AlwaysPanic {
            fn submit(&mut self, req: Request) -> u64 {
                self.active += 1;
                req.id.max(1)
            }
            fn active_count(&self) -> usize {
                self.active
            }
            fn pending_steps(&self) -> usize {
                self.active
            }
            fn step_round(&mut self) -> Result<Vec<RequestResult>> {
                panic!("injected panic")
            }
            fn layer_stats(&self) -> &LayerStats {
                &self.layer
            }
            fn serve_stats(&self) -> &ServeStats {
                &self.serve
            }
            fn policy_name(&self) -> String {
                "always-panic".into()
            }
        }
        let factory: RespawnFactory = Arc::new(|| {
            Ok(Box::new(AlwaysPanic {
                layer: LayerStats::new(1),
                serve: ServeStats::default(),
                active: 0,
            }) as Box<dyn PoolEngine>)
        });
        let h = ReplicaHandle::spawn_supervised(
            3, 8, &factory, None, ReplicaTier::default(),
            Tracer::disabled(), None)
            .unwrap();
        let (j, rx) = job(1, 4);
        h.gauges.queued.fetch_add(1, Ordering::Relaxed);
        h.gauges.pending_steps.fetch_add(4, Ordering::Relaxed);
        h.try_send(j).map_err(|_| "send").unwrap();
        wait_needs_respawn(&h);
        assert!(!h.finished(), "a supervised panic posts no report");
        // a down slot drops out of candidate rotation via breaker_open
        assert!(h.snapshot().breaker_open);
        assert!(!h.snapshot().finished);
        // restart budget exhausted: the supervisor retires the slot
        h.give_up("restart budget exhausted");
        assert!(h.finished());
        assert!(rx.recv().is_err(), "client released, not stranded");
        assert_eq!(h.gauges.queued.load(Ordering::Relaxed), 0);
        assert_eq!(h.gauges.pending_steps.load(Ordering::Relaxed), 0);
        assert!(h.gauges.forfeited.load(Ordering::Relaxed) >= 1,
                "the admission ledger resolves the dead job");
        let rep = h.join_report();
        assert_eq!(rep.error.as_deref(), Some("restart budget exhausted"));
    }

    #[test]
    fn gauges_track_lazy_ratio() {
        let g = ReplicaGauges::default();
        assert_eq!(g.lazy_ratio(), 0.0);
        g.modules_seen.store(100, Ordering::Relaxed);
        g.modules_skipped.store(25, Ordering::Relaxed);
        assert!((g.lazy_ratio() - 0.25).abs() < 1e-12);
        let tier = ReplicaTier::new(Slo::Latency, 2);
        let s = g.snapshot(&tier);
        assert_eq!(s.queued, 0);
        assert!((s.lazy_ratio - 0.25).abs() < 1e-12);
        assert_eq!(s.slo, Slo::Latency);
        assert_eq!(s.max_batch, 2);
    }

    fn deadline_job(id: u64, enqueued_us: u64, deadline_us: u64)
                    -> PoolJob {
        let (tx, _rx) = mpsc::channel();
        let mut req = Request::new(id, 1, 4, id);
        req.deadline_us = deadline_us;
        // _rx dropped: these jobs only exercise queue ordering
        PoolJob::fresh(req, tx, enqueued_us)
    }

    #[test]
    fn effective_deadline_orders_declared_before_legacy() {
        // declared deadlines pass through verbatim
        assert_eq!(deadline_job(1, 500, 9_000).effective_deadline(), 9_000);
        // legacy (no deadline): enqueue stamp pushed out by the fixed
        // offset, so relative FIFO order among legacy jobs is preserved
        assert_eq!(deadline_job(2, 500, 0).effective_deadline(),
                   500 + LEGACY_DEADLINE_US);
        assert_eq!(deadline_job(3, 900, 0).effective_deadline(),
                   900 + LEGACY_DEADLINE_US);
        // an untimed job (enqueued_us 0, test harnesses) still totals
        assert_eq!(deadline_job(4, 0, 0).effective_deadline(),
                   LEGACY_DEADLINE_US);
    }

    #[test]
    fn edf_queue_orders_deadlines_and_never_starves_legacy() {
        let q: BoundedQueue<PoolJob> = BoundedQueue::new(8);
        // arrival order: legacy, late deadline, early deadline, legacy
        q.try_push(deadline_job(0, 100, 0)).map_err(|_| "q").unwrap();
        q.try_push(deadline_job(1, 200, 50_000)).map_err(|_| "q").unwrap();
        q.try_push(deadline_job(2, 300, 10_000)).map_err(|_| "q").unwrap();
        q.try_push(deadline_job(3, 400, 0)).map_err(|_| "q").unwrap();
        let order: Vec<u64> = std::iter::from_fn(|| {
            q.try_pop_min_by_key(|j| j.effective_deadline())
        })
        .map(|j| j.id())
        .collect();
        // declared deadlines first (earliest wins), then the legacy
        // jobs in their original FIFO order — never dropped
        assert_eq!(order, vec![2, 1, 0, 3]);
    }

    #[test]
    fn restamped_resume_keeps_original_admission_instant() {
        // the queue-wait regression: a re-queued resident's job must be
        // stamped at the trajectory's ORIGINAL admission, so the wait
        // span measured at its next admission covers its whole queued
        // life — not just the slice since the re-queue
        let mut eng = SimEngine::new(SimSpec::fast());
        let mut req = Request::new(0, 1, 6, 42);
        req.deadline_us = 777_000;
        let rid = eng.submit(req);
        let _ = eng.step_round().unwrap();
        let snap = eng.evict_to_snapshot(rid).unwrap();
        let admitted = snap.admitted_us;
        assert!(admitted > 0, "sim stamps admission");
        let (tx, _rx) = mpsc::channel();
        let job = PoolJob::resumed_restamped(snap, tx);
        assert_eq!(job.enqueued_us, admitted);
        assert!(job.enqueued_us < crate::obs::epoch_us()
                || job.enqueued_us == admitted);
        // the declared deadline rides along too
        assert_eq!(job.deadline_us(), 777_000);
        assert_eq!(job.effective_deadline(), 777_000);
        // resumed jobs re-enter unpriced by design
        assert_eq!(job.cost_milli, 0);
    }

    #[test]
    fn deadline_hits_and_misses_settle_at_retire() {
        let h = ReplicaHandle::spawn(12, 16,
                                     SimEngine::factory(SimSpec::fast()))
            .unwrap();
        let mk = |deadline_us: u64| {
            let (tx, rx) = mpsc::channel();
            let mut req = Request::new(0, 1, 3, 7);
            req.deadline_us = deadline_us;
            h.gauges.queued.fetch_add(1, Ordering::Relaxed);
            h.gauges.pending_steps.fetch_add(3, Ordering::Relaxed);
            h.try_send(PoolJob::fresh(req, tx, crate::obs::epoch_us()))
                .map_err(|_| "send")
                .unwrap();
            rx
        };
        // generous deadline → hit; 1µs-past deadline → miss; none →
        // neither bucket
        let rx_hit = mk(crate::obs::epoch_us() + 60_000_000);
        let rx_miss = mk(1);
        let rx_none = mk(0);
        rx_hit.recv().unwrap();
        rx_miss.recv().unwrap();
        rx_none.recv().unwrap();
        let rep = h.join_report();
        assert_eq!(rep.deadline_hits, 1, "{rep:?}");
        assert_eq!(rep.deadline_misses, 1, "{rep:?}");
        assert_eq!(h.gauges.deadline_hits.load(Ordering::Relaxed), 1);
        assert_eq!(h.gauges.deadline_misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn predicted_cost_gauge_settles_at_admission_and_refusal() {
        let g = ReplicaGauges::default();
        // saturating: a decrement without a matching increment (resumed
        // job priced elsewhere) clamps at zero instead of wrapping
        dec_u64(&g.predicted_cost_milli, 5_000);
        assert_eq!(g.predicted_cost_milli.load(Ordering::Relaxed), 0);
        g.predicted_cost_milli.fetch_add(12_000, Ordering::Relaxed);
        dec_u64(&g.predicted_cost_milli, 4_000);
        assert_eq!(g.predicted_cost_milli.load(Ordering::Relaxed), 8_000);
        // the snapshot surfaces the live value for candidate ordering
        let s = g.snapshot(&ReplicaTier::default());
        assert_eq!(s.predicted_cost_milli, 8_000);
        // refusal drains a priced job's contribution with its slot
        let q: BoundedQueue<PoolJob> = BoundedQueue::new(4);
        let (tx, _rx) = mpsc::channel();
        let mut job = PoolJob::fresh(Request::new(0, 1, 4, 1), tx, 0);
        job.cost_milli = 3_000;
        g.queued.fetch_add(1, Ordering::Relaxed);
        g.pending_steps.fetch_add(4, Ordering::Relaxed);
        g.predicted_cost_milli.fetch_add(3_000, Ordering::Relaxed);
        q.try_push(job).map_err(|_| "q").unwrap();
        refuse_remaining(&q, &g);
        assert_eq!(g.predicted_cost_milli.load(Ordering::Relaxed), 8_000);
        assert_eq!(g.queued.load(Ordering::Relaxed), 0);
        assert_eq!(g.forfeited.load(Ordering::Relaxed), 1);
    }
}
