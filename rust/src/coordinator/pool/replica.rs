//! One replica: a worker thread owning its engine, fed through a bounded
//! queue, observable through lock-free gauges.
//!
//! Lifecycle: `spawn` → jobs via `try_send` → `close` (queue refuses new
//! work, worker finishes queued + in-flight trajectories) → `join_report`
//! (final per-replica stats). Engine construction happens on the worker
//! thread because PJRT types are `!Send`/`!Sync`.

use crate::coordinator::pool::steal::{Rebalancer, StealPeer};
use crate::coordinator::pool::{EngineFactory, PoolEngine};
use crate::coordinator::request::{Request, RequestResult};
use crate::coordinator::stats::{LayerStats, ServeStats};
use crate::util::threadpool::{BoundedQueue, Popped};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// A routed request plus its response channel.
pub struct PoolJob {
    pub req: Request,
    pub respond: mpsc::Sender<RequestResult>,
}

/// Live per-replica load/laziness gauges. The router reads these on every
/// dispatch; the worker updates them as rounds complete. All counters are
/// relaxed atomics — approximate-but-cheap is exactly what routing needs.
#[derive(Debug, Default)]
pub struct ReplicaGauges {
    /// Requests admitted (dispatched) and not yet completed.
    pub queued: AtomicUsize,
    /// Remaining denoise steps across queued + in-flight requests.
    /// Incremented by the router at dispatch, decremented by the worker
    /// as rounds consume steps.
    pub pending_steps: AtomicUsize,
    /// Requests completed by this replica.
    pub completed: AtomicU64,
    /// Requests this replica accepted but dropped without completing
    /// (engine failure, panic, refused queue backlog). The router's
    /// admission ledger needs these or dead replicas would pin
    /// "outstanding" work forever.
    pub forfeited: AtomicU64,
    /// Module invocations observed (engine layer-stats total).
    pub modules_seen: AtomicU64,
    /// Module invocations skipped (engine layer-stats skips).
    pub modules_skipped: AtomicU64,
    /// Jobs this replica pulled from a sibling's queue while idle.
    pub steals: AtomicU64,
    /// Jobs a sibling pulled out of this replica's queue.
    pub stolen: AtomicU64,
    /// Set once the worker thread has exited (report posted). Read by
    /// the router so finished/dead replicas drop out of candidate
    /// generation instead of winning the cost order with snapshot 0.
    pub finished: AtomicBool,
}

impl ReplicaGauges {
    /// Observed lazy ratio Γ (0 until the first round completes).
    pub fn lazy_ratio(&self) -> f64 {
        let seen = self.modules_seen.load(Ordering::Relaxed);
        if seen == 0 {
            return 0.0;
        }
        self.modules_skipped.load(Ordering::Relaxed) as f64 / seen as f64
    }

    /// Snapshot used by the router's selection policies.
    pub fn snapshot(&self) -> GaugeSnapshot {
        GaugeSnapshot {
            queued: self.queued.load(Ordering::Relaxed),
            pending_steps: self.pending_steps.load(Ordering::Relaxed),
            lazy_ratio: self.lazy_ratio(),
            finished: self.finished.load(Ordering::Acquire),
        }
    }
}

/// Point-in-time view of one replica's load.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GaugeSnapshot {
    pub queued: usize,
    pub pending_steps: usize,
    pub lazy_ratio: f64,
    /// The worker has exited — the replica can never serve again.
    pub finished: bool,
}

/// Final accounting exported by a replica at shutdown.
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    pub id: usize,
    /// Skip-policy label the replica ran (A/B reporting).
    pub policy: String,
    pub layer: LayerStats,
    pub serve: ServeStats,
    /// Jobs this replica stole from siblings' queues.
    pub steals: u64,
    /// Jobs siblings stole out of this replica's queue.
    pub stolen: u64,
    /// Set if the engine failed to construct or a round errored.
    pub error: Option<String>,
}

impl ReplicaReport {
    /// An empty report carrying only a failure message (construction
    /// failure, panic, or a worker that died without reporting).
    pub fn failed(id: usize, msg: impl Into<String>) -> ReplicaReport {
        ReplicaReport {
            id,
            policy: String::new(),
            layer: LayerStats::default(),
            serve: ServeStats::default(),
            steals: 0,
            stolen: 0,
            error: Some(msg.into()),
        }
    }
}

/// Handle held by the router: input queue + gauges + join state.
pub struct ReplicaHandle {
    pub id: usize,
    pub gauges: Arc<ReplicaGauges>,
    queue: BoundedQueue<PoolJob>,
    join: Mutex<Option<JoinHandle<()>>>,
    report: Arc<Mutex<Option<ReplicaReport>>>,
}

impl ReplicaHandle {
    /// Spawn the worker thread. `queue_cap` bounds this replica's input
    /// queue (admission shedding happens at the router on top of this).
    pub fn spawn(id: usize, queue_cap: usize, factory: EngineFactory)
                 -> Result<ReplicaHandle> {
        Self::spawn_with(id, queue_cap, factory, None)
    }

    /// Spawn with an optional pool [`Rebalancer`]: when present, the
    /// worker bounds in-engine admission to the rebalancer's window
    /// (excess jobs stay in the queue where siblings can steal them) and
    /// pulls work from overloaded siblings whenever it goes idle.
    pub fn spawn_with(id: usize, queue_cap: usize, factory: EngineFactory,
                      steal: Option<Arc<Rebalancer>>) -> Result<ReplicaHandle> {
        let queue: BoundedQueue<PoolJob> = BoundedQueue::new(queue_cap.max(1));
        let gauges = Arc::new(ReplicaGauges::default());
        let report: Arc<Mutex<Option<ReplicaReport>>> =
            Arc::new(Mutex::new(None));
        let (q2, g2, r2) = (queue.clone(), gauges.clone(), report.clone());
        let join = std::thread::Builder::new()
            .name(format!("lazydit-replica-{id}"))
            .spawn(move || {
                // a panicking engine (e.g. an assert deep in the sampler)
                // must not wedge the pool: post a failure report and close
                // the queue so waiting clients error out instead of
                // hanging. `responders` lives outside the unwind so the
                // handler knows exactly how many admitted requests died
                // with the engine; `engine_pending` mirrors the engine's
                // share of the pending_steps gauge so the handler can
                // subtract exactly that — an absolute `store(0)` here
                // would race a concurrent dispatch's optimistic
                // `fetch_add` (or a thief's gauge transfer) and leave a
                // dead replica with phantom backlog that permanently
                // skews jsq/lazy ordering.
                let mut responders: BTreeMap<u64, mpsc::Sender<RequestResult>> =
                    BTreeMap::new();
                let engine_pending = AtomicUsize::new(0);
                let admitting = AtomicUsize::new(0);
                let result = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        run_replica(id, factory, &q2, &g2, &r2,
                                    &mut responders, steal.as_deref(),
                                    &engine_pending, &admitting)
                    }));
                if result.is_err() {
                    log::warn!("replica {id}: worker panicked");
                    refuse_remaining(&q2, &g2);
                    // requests admitted into the unwound engine can never
                    // complete — forfeit exactly those, and roll exactly
                    // the engine's known step backlog out of the gauge
                    // (an in-flight dispatch's optimistic increment is
                    // left for its own rollback, so nothing is
                    // double-resolved or wiped)
                    let lost = responders.len();
                    g2.forfeited.fetch_add(lost as u64, Ordering::Relaxed);
                    dec(&g2.queued, lost);
                    dec(&g2.pending_steps,
                        engine_pending.load(Ordering::Relaxed));
                    // a job that died inside engine.submit left the queue
                    // but never reached `responders` — without this, each
                    // such panic would leak one admission-ledger slot
                    // (phantom queued + wire steps) forever
                    let adm = admitting.load(Ordering::Relaxed);
                    if adm > 0 {
                        g2.forfeited.fetch_add(1, Ordering::Relaxed);
                        dec(&g2.queued, 1);
                        dec(&g2.pending_steps, adm - 1);
                    }
                    let mut slot =
                        r2.lock().unwrap_or_else(|p| p.into_inner());
                    if slot.is_none() {
                        let mut rep =
                            ReplicaReport::failed(id, "worker panicked");
                        rep.steals = g2.steals.load(Ordering::Relaxed);
                        rep.stolen = g2.stolen.load(Ordering::Relaxed);
                        *slot = Some(rep);
                    }
                }
                // single exit point: the report (normal, error, or panic)
                // is posted by now, so the replica is observably finished
                g2.finished.store(true, Ordering::Release);
            })
            .with_context(|| format!("spawning replica {id}"))?;
        Ok(ReplicaHandle {
            id,
            gauges,
            queue,
            join: Mutex::new(Some(join)),
            report,
        })
    }

    /// This replica's stealable surface (input queue + gauges), handed
    /// to the pool [`Rebalancer`] at registration.
    pub fn steal_peer(&self) -> StealPeer {
        StealPeer {
            id: self.id,
            queue: self.queue.clone(),
            gauges: self.gauges.clone(),
        }
    }

    /// Hand a job to this replica; `Err(job)` if its queue is full or
    /// closed (the router then tries the next candidate or sheds).
    pub fn try_send(&self, job: PoolJob) -> std::result::Result<(), PoolJob> {
        self.queue.try_push(job)
    }

    /// Stop accepting work. The worker finishes queued + in-flight
    /// trajectories, then exits (drain semantics).
    pub fn close(&self) {
        self.queue.close();
    }

    /// True once the worker has exported its final report — normal drain
    /// or failure. Used by the serve loop's liveness check.
    pub fn finished(&self) -> bool {
        self.report
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .is_some()
    }

    /// Close, wait for the worker, and return its final report.
    pub fn join_report(&self) -> ReplicaReport {
        self.close();
        if let Some(h) = self.join.lock().unwrap().take() {
            let _ = h.join();
        }
        self.report
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .take()
            .unwrap_or_else(|| {
                ReplicaReport::failed(self.id, "replica exited without a report")
            })
    }
}

/// How long an idle worker sleeps between probes. A stealing worker
/// polls fast right after going idle (a sibling's backlog is an
/// immediate opportunity), then backs off to the plain cadence once
/// `IDLE_BACKOFF_AFTER` consecutive probes found nothing — a genuinely
/// idle pool must not burn O(replicas²) lock traffic at 1 kHz. Any
/// admitted job (own queue or steal) resets the backoff.
const IDLE_WAIT_STEAL: Duration = Duration::from_millis(1);
const IDLE_WAIT_PLAIN: Duration = Duration::from_millis(50);
const IDLE_BACKOFF_AFTER: u32 = 64;

/// The worker loop: admit continuously (bounded by the rebalancer's
/// window when stealing is on), step the engine, keep gauges fresh,
/// steal from overloaded siblings when idle, drain on close.
/// `responders` (admitted-but-unfinished response channels) and
/// `engine_pending` (the engine's share of the pending_steps gauge) are
/// owned by the caller so the panic handler can account for requests
/// lost in an unwind by exact, known amounts.
fn run_replica(id: usize, factory: EngineFactory,
               queue: &BoundedQueue<PoolJob>, gauges: &ReplicaGauges,
               report: &Mutex<Option<ReplicaReport>>,
               responders: &mut BTreeMap<u64, mpsc::Sender<RequestResult>>,
               steal: Option<&Rebalancer>, engine_pending: &AtomicUsize,
               admitting: &AtomicUsize) {
    let mut engine: Box<dyn PoolEngine> = match factory() {
        Ok(e) => e,
        Err(e) => {
            let msg = format!("engine construction failed: {e:#}");
            log::warn!("replica {id}: {msg}");
            refuse_remaining(queue, gauges);
            *report.lock().unwrap() = Some(ReplicaReport::failed(id, msg));
            return;
        }
    };
    log::debug!("replica {id} up (policy {})", engine.policy_name());

    // The router optimistically added the *wire* step count to the
    // pending_steps gauge; the engine may admit fewer (its submit clamps
    // to the schedule). Reconcile at admission so the gauge tracks what
    // will actually be consumed — otherwise the residue accumulates and
    // biases jsq/lazy routing against this replica forever.
    fn admit(engine: &mut Box<dyn PoolEngine>,
             responders: &mut BTreeMap<u64, mpsc::Sender<RequestResult>>,
             gauges: &ReplicaGauges, engine_pending: &AtomicUsize,
             admitting: &AtomicUsize, job: PoolJob) {
        let wire_steps = job.req.steps;
        // mark the job in-admission (steps + 1 so 0 means "none"): if
        // submit panics, the handler must resolve exactly this job's
        // ledger entry — it left the queue but never reached responders
        admitting.store(wire_steps + 1, Ordering::Relaxed);
        let before = engine.pending_steps();
        let rid = engine.submit(job.req);
        let actual = engine.pending_steps().saturating_sub(before);
        if actual < wire_steps {
            dec(&gauges.pending_steps, wire_steps - actual);
        }
        engine_pending.store(engine.pending_steps(), Ordering::Relaxed);
        admitting.store(0, Ordering::Relaxed);
        responders.insert(rid, job.respond);
    }
    let mut error: Option<String> = None;
    // with stealing on, cap how many trajectories sit inside the engine:
    // everything beyond the window stays in the queue, where it remains
    // migratable — an engine-admitted trajectory can never move
    let window = match steal {
        Some(rb) => rb.admit_window().max(1),
        None => usize::MAX,
    };
    let mut idle_misses = 0u32;

    loop {
        // continuous batching: absorb whatever arrived, up to the window
        while engine.active_count() < window {
            match queue.try_pop() {
                Some(job) => {
                    idle_misses = 0;
                    admit(&mut engine, responders, gauges, engine_pending,
                          admitting, job);
                }
                None => break,
            }
        }
        if engine.active_count() == 0 {
            // idle: prefer pulling a queued job off an overloaded
            // sibling over waiting for the router to send one here
            if let Some(rb) = steal {
                if let Some(job) = rb.steal_for(id) {
                    idle_misses = 0;
                    admit(&mut engine, responders, gauges, engine_pending,
                          admitting, job);
                    continue;
                }
            }
            idle_misses = idle_misses.saturating_add(1);
            let wait = if steal.is_some()
                && idle_misses < IDLE_BACKOFF_AFTER
            {
                IDLE_WAIT_STEAL
            } else {
                IDLE_WAIT_PLAIN
            };
            match queue.pop_timeout(wait) {
                Popped::Item(job) => {
                    idle_misses = 0;
                    admit(&mut engine, responders, gauges, engine_pending,
                          admitting, job);
                }
                Popped::Closed => break,
                Popped::TimedOut => continue,
            }
            continue; // absorb any burst before stepping
        }
        let before = engine.pending_steps();
        match engine.step_round() {
            Ok(finished) => {
                for res in finished {
                    gauges.completed.fetch_add(1, Ordering::Relaxed);
                    dec(&gauges.queued, 1);
                    if let Some(tx) = responders.remove(&res.id) {
                        let _ = tx.send(res);
                    }
                }
                let consumed = before.saturating_sub(engine.pending_steps());
                dec(&gauges.pending_steps, consumed);
                engine_pending
                    .store(engine.pending_steps(), Ordering::Relaxed);
                let ls = engine.layer_stats();
                gauges
                    .modules_seen
                    .store(ls.total.iter().sum(), Ordering::Relaxed);
                gauges
                    .modules_skipped
                    .store(ls.skips.iter().sum(), Ordering::Relaxed);
            }
            Err(e) => {
                error = Some(format!("step_round failed: {e:#}"));
                log::warn!("replica {id}: {}", error.as_deref().unwrap());
                break;
            }
        }
    }

    if error.is_some() {
        // forfeit whatever is left so pool-wide gauges stay sane; dropped
        // responders surface as "engine stopped" on the client side
        dec(&gauges.pending_steps, engine.pending_steps());
        dec(&gauges.queued, engine.active_count());
        gauges
            .forfeited
            .fetch_add(engine.active_count() as u64, Ordering::Relaxed);
        refuse_remaining(queue, gauges);
    }
    engine_pending.store(0, Ordering::Relaxed);
    *report.lock().unwrap() = Some(ReplicaReport {
        id,
        policy: engine.policy_name(),
        layer: engine.layer_stats().clone(),
        serve: engine.serve_stats().clone(),
        steals: gauges.steals.load(Ordering::Relaxed),
        stolen: gauges.stolen.load(Ordering::Relaxed),
        error,
    });
    log::debug!("replica {id} drained");
}

/// Saturating atomic decrement — gauge bookkeeping must never wrap even
/// when a matching increment was skipped (tests, error paths, a dispatch
/// rollback racing the panic handler's or a thief's own decrements).
pub(crate) fn dec(a: &AtomicUsize, n: usize) {
    let _ = a.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
        Some(v.saturating_sub(n))
    });
}

/// Drop queued jobs (their responders close → clients see a structured
/// "engine stopped") and roll their load out of the gauges, marking each
/// as forfeited for the router's admission ledger.
fn refuse_remaining(queue: &BoundedQueue<PoolJob>, gauges: &ReplicaGauges) {
    queue.close();
    while let Some(job) = queue.try_pop() {
        dec(&gauges.queued, 1);
        dec(&gauges.pending_steps, job.req.steps);
        gauges.forfeited.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::sim::{SimEngine, SimSpec};

    fn job(seed: u64, steps: usize)
           -> (PoolJob, mpsc::Receiver<RequestResult>) {
        let (tx, rx) = mpsc::channel();
        (PoolJob { req: Request::new(0, 3, steps, seed), respond: tx }, rx)
    }

    #[test]
    fn replica_serves_and_reports() {
        let h = ReplicaHandle::spawn(0, 16, SimEngine::factory(SimSpec::fast()))
            .unwrap();
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (j, rx) = job(i, 4);
            h.gauges.queued.fetch_add(1, Ordering::Relaxed);
            h.gauges.pending_steps.fetch_add(4, Ordering::Relaxed);
            h.try_send(j).map_err(|_| "send").unwrap();
            rxs.push(rx);
        }
        for rx in rxs {
            let res = rx.recv().unwrap();
            assert_eq!(res.steps, 4);
        }
        let rep = h.join_report();
        assert!(rep.error.is_none(), "{:?}", rep.error);
        assert_eq!(rep.serve.completed, 5);
        assert_eq!(h.gauges.completed.load(Ordering::Relaxed), 5);
        assert_eq!(h.gauges.queued.load(Ordering::Relaxed), 0);
        assert_eq!(h.gauges.pending_steps.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn close_drains_in_flight() {
        let h = ReplicaHandle::spawn(1, 16, SimEngine::factory(SimSpec::fast()))
            .unwrap();
        let mut rxs = Vec::new();
        for i in 0..8 {
            let (j, rx) = job(100 + i, 6);
            h.gauges.queued.fetch_add(1, Ordering::Relaxed);
            h.gauges.pending_steps.fetch_add(6, Ordering::Relaxed);
            h.try_send(j).map_err(|_| "send").unwrap();
            rxs.push(rx);
        }
        // close immediately: every queued job must still complete
        let rep = h.join_report();
        for rx in rxs {
            assert!(rx.recv().is_ok(), "drain must finish in-flight work");
        }
        assert_eq!(rep.serve.completed, 8);
    }

    #[test]
    fn factory_failure_yields_error_report() {
        let factory: EngineFactory =
            Box::new(|| anyhow::bail!("no artifacts here"));
        let h = ReplicaHandle::spawn(2, 4, factory).unwrap();
        let (j, rx) = job(1, 4);
        let _ = h.try_send(j);
        let rep = h.join_report();
        assert!(rep.error.is_some());
        // responder dropped → receiver errors out rather than hanging
        assert!(rx.recv().is_err());
    }

    #[test]
    fn worker_panic_reports_and_releases_clients() {
        struct PanicEngine {
            layer: LayerStats,
            serve: ServeStats,
            active: usize,
        }
        impl PoolEngine for PanicEngine {
            fn submit(&mut self, req: Request) -> u64 {
                self.active += 1;
                req.id.max(1)
            }
            fn active_count(&self) -> usize {
                self.active
            }
            fn pending_steps(&self) -> usize {
                self.active
            }
            fn step_round(&mut self) -> Result<Vec<RequestResult>> {
                panic!("injected panic")
            }
            fn layer_stats(&self) -> &LayerStats {
                &self.layer
            }
            fn serve_stats(&self) -> &ServeStats {
                &self.serve
            }
            fn policy_name(&self) -> String {
                "panic".into()
            }
        }
        let factory: EngineFactory = Box::new(|| {
            Ok(Box::new(PanicEngine {
                layer: LayerStats::new(1),
                serve: ServeStats::default(),
                active: 0,
            }) as Box<dyn PoolEngine>)
        });
        let h = ReplicaHandle::spawn(9, 4, factory).unwrap();
        let (j, rx) = job(1, 4);
        h.try_send(j).map_err(|_| "send").unwrap();
        let rep = h.join_report();
        assert_eq!(rep.error.as_deref(), Some("worker panicked"));
        assert!(rx.recv().is_err(), "client must not hang on a panicked worker");
    }

    #[test]
    fn submit_panic_resolves_ledger_exactly() {
        // a job that dies inside engine.submit has left the queue but
        // never reached `responders` — its admission-ledger entry must
        // still resolve (forfeit) and its optimistic gauge contribution
        // must unwind, or the slot would leak from the pool cap forever
        struct SubmitPanicEngine {
            layer: LayerStats,
            serve: ServeStats,
        }
        impl PoolEngine for SubmitPanicEngine {
            fn submit(&mut self, _req: Request) -> u64 {
                panic!("injected submit panic")
            }
            fn active_count(&self) -> usize {
                0
            }
            fn pending_steps(&self) -> usize {
                0
            }
            fn step_round(&mut self) -> Result<Vec<RequestResult>> {
                Ok(Vec::new())
            }
            fn layer_stats(&self) -> &LayerStats {
                &self.layer
            }
            fn serve_stats(&self) -> &ServeStats {
                &self.serve
            }
            fn policy_name(&self) -> String {
                "submit-panic".into()
            }
        }
        let factory: EngineFactory = Box::new(|| {
            Ok(Box::new(SubmitPanicEngine {
                layer: LayerStats::new(1),
                serve: ServeStats::default(),
            }) as Box<dyn PoolEngine>)
        });
        let h = ReplicaHandle::spawn(7, 4, factory).unwrap();
        let (j, rx) = job(1, 5);
        // mirror the router's optimistic accounting at dispatch
        h.gauges.queued.fetch_add(1, Ordering::Relaxed);
        h.gauges.pending_steps.fetch_add(5, Ordering::Relaxed);
        h.try_send(j).map_err(|_| "send").unwrap();
        let rep = h.join_report();
        assert_eq!(rep.error.as_deref(), Some("worker panicked"));
        assert!(rx.recv().is_err(), "client must be released");
        assert_eq!(h.gauges.queued.load(Ordering::Relaxed), 0,
                   "no phantom queued entry");
        assert_eq!(h.gauges.pending_steps.load(Ordering::Relaxed), 0,
                   "no phantom step backlog");
        assert_eq!(h.gauges.forfeited.load(Ordering::Relaxed), 1,
                   "the admission ledger resolves the dead job");
    }

    #[test]
    fn gauges_track_lazy_ratio() {
        let g = ReplicaGauges::default();
        assert_eq!(g.lazy_ratio(), 0.0);
        g.modules_seen.store(100, Ordering::Relaxed);
        g.modules_skipped.store(25, Ordering::Relaxed);
        assert!((g.lazy_ratio() - 0.25).abs() < 1e-12);
        let s = g.snapshot();
        assert_eq!(s.queued, 0);
        assert!((s.lazy_ratio - 0.25).abs() < 1e-12);
    }
}
