//! Deterministic fault injection: the chaos substrate every
//! self-healing path is tested against.
//!
//! A [`FaultPlan`] is a seeded, human-writable schedule of faults to
//! inject into a serving run (`lazydit serve --fault-plan SPEC`, or
//! directly in benches/tests). The plan compiles to one
//! [`FaultSchedule`] per replica; the schedule is consulted at engine
//! round boundaries, so every fault fires at a *deterministic* point in
//! the replica's own timeline — rerunning the same plan against the
//! same workload reproduces the same crash, stall, or corruption.
//!
//! Spec grammar (comma-separated items, whitespace ignored):
//!
//! ```text
//! plan   := item ("," item)*
//! item   := ["r" REPLICA ":"] fault | "seed=" N
//! fault  := "panic@" ROUND            worker panics entering ROUND
//!         | "panic~" PCT              seeded PCT% panic chance per round
//!         | "stall@" ROUND "=" MS     worker sleeps MS ms at ROUND
//!         | "burst@" ROUND "=" K      K rounds of zero progress (queue
//!                                     backpressure builds)
//!         | "corrupt@" ROUND          from ROUND on, every snapshot is
//!                                     pushed through the wire codec
//!                                     with a flipped byte (strict
//!                                     decode rejects it), so the
//!                                     crash-resume stash goes stale
//!         | "sock@" I "=" MS          self-drive client stalls MS ms
//!                                     before reading response I (slow
//!                                     reader; exercises the bounded
//!                                     response write)
//! ```
//!
//! Without an `rK:` prefix a fault targets replica 0. `sock@` faults
//! are client-side and ignore the replica prefix. Rounds are 1-based:
//! `panic@1` fires on the engine's first `step_round`.
//!
//! Injection has two equivalent homes: [`crate::coordinator::pool::sim::SimEngine`]
//! consults its schedule natively (so synthetic chaos costs nothing
//! when the schedule is empty), and [`FaultEngine`] wraps any other
//! [`PoolEngine`] (the real engine) with the same semantics.

use crate::coordinator::pool::{EngineFactory, PoolEngine};
use crate::coordinator::request::{Request, RequestResult, TrajectorySnapshot};
use crate::coordinator::stats::{LayerStats, ServeStats};
use anyhow::{bail, Context, Result};

/// One parsed fault item (replica-scoped; see module docs for grammar).
#[derive(Debug, Clone, PartialEq, Eq)]
enum FaultKind {
    /// Panic entering round `.0`.
    PanicAt(u64),
    /// Seeded per-round panic probability in percent.
    PanicRate(u32),
    /// Sleep `.1` ms entering round `.0`.
    StallAt(u64, u64),
    /// `.1` rounds of zero progress starting at round `.0`.
    BurstAt(u64, u64),
    /// From round `.0` on, snapshots decode-corrupt.
    CorruptFrom(u64),
}

/// A seeded, replica-addressed schedule of injectable faults.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    seed: u64,
    items: Vec<(usize, FaultKind)>,
    socks: Vec<(u64, u64)>,
}

impl FaultPlan {
    /// Parse a plan from the spec grammar (see module docs). Empty
    /// specs parse to an empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for raw in spec.split(',') {
            let item = raw.trim();
            if item.is_empty() {
                continue;
            }
            if let Some(n) = item.strip_prefix("seed=") {
                plan.seed = n
                    .parse()
                    .with_context(|| format!("bad fault seed {n:?}"))?;
                continue;
            }
            let (replica, body) = match item.strip_prefix('r') {
                Some(rest) if rest.contains(':') => {
                    let (r, body) = rest.split_once(':').unwrap();
                    let r: usize = r.parse().with_context(|| {
                        format!("bad replica prefix in {item:?}")
                    })?;
                    (r, body)
                }
                _ => (0, item),
            };
            let kind = parse_fault(body)
                .with_context(|| format!("bad fault item {item:?}"))?;
            if let Parsed::Sock(i, ms) = kind {
                plan.socks.push((i, ms));
            } else if let Parsed::Fault(k) = kind {
                plan.items.push((replica, k));
            }
        }
        Ok(plan)
    }

    /// True when the plan injects nothing anywhere.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty() && self.socks.is_empty()
    }

    /// Compile the engine-side schedule for one replica. Replicas the
    /// plan never names get an empty (free) schedule.
    pub fn for_replica(&self, replica: usize) -> FaultSchedule {
        let mut s = FaultSchedule {
            seed: self.seed,
            replica: replica as u64,
            ..FaultSchedule::default()
        };
        for (r, kind) in &self.items {
            if *r != replica {
                continue;
            }
            match kind {
                FaultKind::PanicAt(round) => s.panic_rounds.push(*round),
                FaultKind::PanicRate(pct) => {
                    s.panic_rate_pct = s.panic_rate_pct.max(*pct);
                }
                FaultKind::StallAt(round, ms) => s.stalls.push((*round, *ms)),
                FaultKind::BurstAt(round, k) => s.bursts.push((*round, *k)),
                FaultKind::CorruptFrom(round) => {
                    s.corrupt_from = Some(
                        s.corrupt_from.map_or(*round, |c| c.min(*round)),
                    );
                }
            }
        }
        s
    }

    /// Client-side slow-reader stalls: `(response index, ms)` pairs,
    /// 0-based over the self-drive client's request sequence.
    pub fn sock_stalls(&self) -> &[(u64, u64)] {
        &self.socks
    }
}

/// Intermediate parse result: engine faults vs client-side sock items.
enum Parsed {
    Fault(FaultKind),
    Sock(u64, u64),
}

fn parse_fault(body: &str) -> Result<Parsed> {
    let num = |s: &str| -> Result<u64> {
        s.parse::<u64>()
            .with_context(|| format!("expected a number, got {s:?}"))
    };
    let pair = |s: &str, what: &str| -> Result<(u64, u64)> {
        let Some((a, b)) = s.split_once('=') else {
            bail!("{what} needs ROUND=VALUE, got {s:?}");
        };
        Ok((num(a)?, num(b)?))
    };
    if let Some(rest) = body.strip_prefix("panic@") {
        let round = num(rest)?;
        if round == 0 {
            bail!("rounds are 1-based; panic@0 never fires");
        }
        return Ok(Parsed::Fault(FaultKind::PanicAt(round)));
    }
    if let Some(rest) = body.strip_prefix("panic~") {
        let pct = num(rest)?;
        if pct > 100 {
            bail!("panic rate must be 0..=100, got {pct}");
        }
        return Ok(Parsed::Fault(FaultKind::PanicRate(pct as u32)));
    }
    if let Some(rest) = body.strip_prefix("stall@") {
        let (round, ms) = pair(rest, "stall")?;
        return Ok(Parsed::Fault(FaultKind::StallAt(round, ms)));
    }
    if let Some(rest) = body.strip_prefix("burst@") {
        let (round, k) = pair(rest, "burst")?;
        return Ok(Parsed::Fault(FaultKind::BurstAt(round, k.max(1))));
    }
    if let Some(rest) = body.strip_prefix("corrupt@") {
        return Ok(Parsed::Fault(FaultKind::CorruptFrom(num(rest)?)));
    }
    if let Some(rest) = body.strip_prefix("sock@") {
        let (i, ms) = pair(rest, "sock")?;
        return Ok(Parsed::Sock(i, ms));
    }
    bail!(
        "unknown fault (expected panic@R, panic~PCT, stall@R=MS, \
         burst@R=K, corrupt@R, sock@I=MS, or seed=N)"
    );
}

/// What one engine round should suffer. Applied in order: stall
/// (sleep), then panic, then burst (return without progress).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RoundFaults {
    /// Sleep this long before doing anything else.
    pub stall_ms: u64,
    /// Panic (the worker's `catch_unwind` + supervisor take over).
    pub panic: bool,
    /// Make zero progress this round (backpressure builds upstream).
    pub burst: bool,
}

/// One replica's compiled fault timeline. The engine (or its
/// [`FaultEngine`] wrapper) calls [`FaultSchedule::begin_round`] once
/// per `step_round`; the schedule advances its own 1-based round
/// counter, so a respawned engine built from the same plan relives the
/// same timeline — exactly what makes flapping reproducible.
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    seed: u64,
    replica: u64,
    panic_rounds: Vec<u64>,
    panic_rate_pct: u32,
    stalls: Vec<(u64, u64)>,
    bursts: Vec<(u64, u64)>,
    corrupt_from: Option<u64>,
    round: u64,
}

impl FaultSchedule {
    /// True when nothing is ever injected (the common fast path: one
    /// branch per round, no allocation).
    pub fn is_empty(&self) -> bool {
        self.panic_rounds.is_empty()
            && self.panic_rate_pct == 0
            && self.stalls.is_empty()
            && self.bursts.is_empty()
            && self.corrupt_from.is_none()
    }

    /// Advance to the next round and report what it should suffer.
    pub fn begin_round(&mut self) -> RoundFaults {
        self.round += 1;
        if self.is_empty() {
            return RoundFaults::default();
        }
        let r = self.round;
        let mut out = RoundFaults::default();
        for (round, ms) in &self.stalls {
            if *round == r {
                out.stall_ms = out.stall_ms.max(*ms);
            }
        }
        out.panic = self.panic_rounds.contains(&r)
            || (self.panic_rate_pct > 0
                && fault_mix(self.seed ^ self.replica.rotate_left(17), r)
                    % 100
                    < self.panic_rate_pct as u64);
        out.burst = self
            .bursts
            .iter()
            .any(|(start, k)| r >= *start && r < start + k);
        out
    }

    /// Is the snapshot path corrupting as of the current round?
    pub fn corrupting(&self) -> bool {
        matches!(self.corrupt_from, Some(c) if self.round >= c)
    }

    /// Rounds this schedule has begun (1-based; 0 before the first).
    pub fn round(&self) -> u64 {
        self.round
    }
}

/// SplitMix64-style stateless mixer for the seeded panic-rate draw.
fn fault_mix(a: u64, b: u64) -> u64 {
    let mut z = a
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(b.rotate_left(31))
        .wrapping_add(0xC2B2_AE3D);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Model snapshot-decode corruption honestly: round-trip the snapshot
/// through the real wire codec with one flipped header byte. The
/// strict decoder rejects it, so the caller sees `None` — the same
/// observable as a torn write on a real transport — and the rejection
/// path itself gets exercised on every corrupt round.
pub fn corrupt_snapshot(snap: &TrajectorySnapshot)
                        -> Option<TrajectorySnapshot> {
    let mut bytes = snap.encode();
    if let Some(b0) = bytes.first_mut() {
        *b0 ^= 0x40; // break the magic: decode must reject
    }
    TrajectorySnapshot::decode(&bytes).ok()
}

/// A [`PoolEngine`] decorator injecting a [`FaultSchedule`] into any
/// inner engine — how the real [`crate::coordinator::engine::Engine`]
/// gets chaos without knowing about it. The synthetic engine consults
/// its schedule natively instead (zero wrapper cost on the bench's
/// clean runs), with identical semantics.
pub struct FaultEngine {
    inner: Box<dyn PoolEngine>,
    faults: FaultSchedule,
}

impl FaultEngine {
    /// Wrap `inner` with the given schedule.
    pub fn new(inner: Box<dyn PoolEngine>, faults: FaultSchedule)
               -> FaultEngine {
        FaultEngine { inner, faults }
    }

    /// Decorate an engine factory so every engine it builds (including
    /// supervisor respawns) starts the schedule from round 0.
    pub fn wrap_factory(factory: EngineFactory, faults: FaultSchedule)
                        -> EngineFactory {
        Box::new(move || {
            Ok(Box::new(FaultEngine::new(factory()?, faults))
               as Box<dyn PoolEngine>)
        })
    }
}

impl PoolEngine for FaultEngine {
    fn submit(&mut self, req: Request) -> u64 {
        self.inner.submit(req)
    }

    fn active_count(&self) -> usize {
        self.inner.active_count()
    }

    fn pending_steps(&self) -> usize {
        self.inner.pending_steps()
    }

    fn step_round(&mut self) -> Result<Vec<RequestResult>> {
        let rf = self.faults.begin_round();
        if rf.stall_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(rf.stall_ms));
        }
        if rf.panic {
            panic!("injected fault: panic at round {}", self.faults.round());
        }
        if rf.burst {
            return Ok(Vec::new());
        }
        self.inner.step_round()
    }

    fn layer_stats(&self) -> &LayerStats {
        self.inner.layer_stats()
    }

    fn serve_stats(&self) -> &ServeStats {
        self.inner.serve_stats()
    }

    fn policy_name(&self) -> String {
        self.inner.policy_name()
    }

    fn arena_stats(&self) -> Option<crate::tensor::pool::PoolStats> {
        self.inner.arena_stats()
    }

    fn install_tracer(&mut self, tracer: crate::obs::Tracer) {
        self.inner.install_tracer(tracer);
    }

    fn active_ids(&self) -> Vec<u64> {
        self.inner.active_ids()
    }

    fn evict_to_snapshot(&mut self, id: u64) -> Option<TrajectorySnapshot> {
        if self.faults.corrupting() {
            // refuse *before* evicting: a corrupting transport must not
            // silently drop a live trajectory out of the engine
            return None;
        }
        self.inner.evict_to_snapshot(id)
    }

    fn admit_snapshot(&mut self, snap: TrajectorySnapshot) -> u64 {
        self.inner.admit_snapshot(snap)
    }

    fn snapshot_request(&self, id: u64) -> Option<TrajectorySnapshot> {
        let snap = self.inner.snapshot_request(id)?;
        if self.faults.corrupting() {
            return corrupt_snapshot(&snap);
        }
        Some(snap)
    }

    fn submit_warm(&mut self, req: Request, donor: &TrajectorySnapshot)
                   -> (u64, u64) {
        self.inner.submit_warm(req, donor)
    }

    fn set_gamma_boost(&mut self, boost: u32) {
        self.inner.set_gamma_boost(boost);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::sim::{SimEngine, SimSpec};

    #[test]
    fn plan_grammar_round_trips_every_item() {
        let plan = FaultPlan::parse(
            "panic@3, r1:stall@2=40, r2:burst@5=3, corrupt@4, \
             sock@1=25, seed=99, panic~10",
        )
        .unwrap();
        assert!(!plan.is_empty());
        assert_eq!(plan.sock_stalls(), &[(1, 25)]);

        let mut r0 = plan.for_replica(0);
        assert!(!r0.is_empty());
        assert!(r0.corrupt_from.is_some());
        assert_eq!(r0.panic_rounds, vec![3]);
        assert_eq!(r0.panic_rate_pct, 10);
        // rounds 1..2 are clean-ish, round 3 panics (rate seeded off)
        let mut clean = FaultPlan::parse("panic@3").unwrap().for_replica(0);
        assert!(!clean.begin_round().panic);
        assert!(!clean.begin_round().panic);
        assert!(clean.begin_round().panic);

        let mut r1 = plan.for_replica(1);
        assert_eq!(r1.begin_round().stall_ms, 0);
        assert_eq!(r1.begin_round().stall_ms, 40);

        let mut r2 = plan.for_replica(2);
        for _ in 0..4 {
            assert!(!r2.begin_round().burst);
        }
        for _ in 0..3 {
            assert!(r2.begin_round().burst, "burst spans rounds 5..8");
        }
        assert!(!r2.begin_round().burst);

        // corruption engages at its round and stays engaged
        for round in 1..=6 {
            assert_eq!(r0.corrupting(), round > 3, "round {round}");
            r0.begin_round();
        }

        // unnamed replicas get a free schedule
        assert!(plan.for_replica(7).is_empty());
    }

    #[test]
    fn plan_rejects_malformed_specs() {
        for bad in [
            "explode@3", "panic@", "panic@0", "panic~101", "stall@5",
            "burst@2=x", "rX:panic@1", "seed=zzz", "sock@3",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
        // empty and whitespace specs are the no-op plan
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" , ").unwrap().is_empty());
    }

    #[test]
    fn panic_rate_is_seeded_and_deterministic() {
        let draw = |seed: u64| {
            let plan =
                FaultPlan::parse(&format!("panic~30,seed={seed}")).unwrap();
            let mut s = plan.for_replica(0);
            (0..64).map(|_| s.begin_round().panic).collect::<Vec<_>>()
        };
        assert_eq!(draw(7), draw(7), "same seed, same timeline");
        assert_ne!(draw(7), draw(8), "different seed, different timeline");
        let hits = draw(7).iter().filter(|p| **p).count();
        assert!(hits > 5 && hits < 40, "~30% of 64 rounds, got {hits}");
        // distinct replicas fault at distinct rounds under one seed
        let plan = FaultPlan::parse("r0:panic~30,r1:panic~30").unwrap();
        let per = |r: usize| {
            let mut s = plan.for_replica(r);
            (0..64).map(|_| s.begin_round().panic).collect::<Vec<_>>()
        };
        assert_ne!(per(0), per(1));
    }

    #[test]
    fn corrupt_snapshot_always_fails_strict_decode() {
        let mut e = SimEngine::new(SimSpec::fast());
        e.submit(Request::new(5, 1, 4, 9));
        e.step_round().unwrap();
        let snap = e.snapshot_request(5).unwrap();
        assert!(corrupt_snapshot(&snap).is_none(),
                "flipped magic must be rejected by the codec");
    }

    #[test]
    fn fault_engine_injects_panic_stall_and_burst() {
        let wrap = |spec: &str| {
            let faults = FaultPlan::parse(spec).unwrap().for_replica(0);
            let mut e = FaultEngine::new(
                Box::new(SimEngine::new(SimSpec::fast())), faults);
            e.submit(Request::new(0, 1, 3, 4));
            e
        };
        // burst: no progress, no retire, request stays active
        let mut burst = wrap("burst@1=2");
        assert!(burst.step_round().unwrap().is_empty());
        assert_eq!(burst.pending_steps(), 3, "burst makes zero progress");
        assert!(burst.step_round().unwrap().is_empty());
        assert_eq!(burst.pending_steps(), 3);
        for _ in 0..3 {
            burst.step_round().unwrap();
        }
        assert_eq!(burst.active_count(), 0, "drains once the burst ends");

        // panic: unwinds out of step_round at its round
        let mut boom = wrap("panic@2");
        boom.step_round().unwrap();
        let caught = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| boom.step_round()));
        assert!(caught.is_err(), "round 2 must panic");

        // stall: wall time visibly longer on the stalled round
        let mut slow = wrap("stall@1=30");
        let t0 = std::time::Instant::now();
        slow.step_round().unwrap();
        assert!(t0.elapsed() >= std::time::Duration::from_millis(25));
    }

    #[test]
    fn corrupting_fault_engine_stales_the_stash_and_refuses_evict() {
        let faults = FaultPlan::parse("corrupt@2").unwrap().for_replica(0);
        let mut e = FaultEngine::new(
            Box::new(SimEngine::new(SimSpec::fast())), faults);
        e.submit(Request::new(9, 1, 5, 2));
        e.step_round().unwrap();
        // round 1: still clean
        assert!(e.snapshot_request(9).is_some());
        e.step_round().unwrap();
        // round 2+: stash refresh sees decode failures, evict refuses
        assert!(e.snapshot_request(9).is_none());
        assert!(e.evict_to_snapshot(9).is_none());
        assert_eq!(e.active_count(), 1,
                   "a refused evict must not lose the trajectory");
    }
}
