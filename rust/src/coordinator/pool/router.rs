//! Admission control + dispatch across the replica pool.
//!
//! Three policies (config::RoutePolicy):
//! * `rr`   — rotate, ignoring load;
//! * `jsq`  — join-shortest-queue on admitted-but-unfinished requests;
//! * `lazy` — cost-based: a replica's backlog is its queued remaining
//!   denoise steps discounted by its observed lazy ratio Γ — a replica
//!   skipping Γ of its module invocations clears a step in ≈(1−Γ) of the
//!   full-step time, so its *effective* backlog is `steps · (1 − Γ)`.
//!
//! Admission control is pool-wide: when the total of per-replica queues
//! reaches `queue_cap`, new requests are shed immediately (the client
//! gets a structured `queue full` line, never silence).

use crate::config::RoutePolicy;
use crate::coordinator::pool::agg::PoolReport;
use crate::coordinator::pool::replica::{GaugeSnapshot, PoolJob, ReplicaHandle};
use crate::coordinator::pool::steal::Rebalancer;
use crate::coordinator::request::{Request, RequestResult};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

/// The pool front-door. All methods take `&self`; the router is shared
/// across acceptor threads behind an `Arc`.
pub struct Router {
    replicas: Vec<ReplicaHandle>,
    route: RoutePolicy,
    queue_cap: usize,
    rr: AtomicUsize,
    shed: AtomicU64,
    /// Admission ledger: dispatch attempts (tickets). Outstanding work is
    /// `dispatched − shed − Σ(completed + forfeited)`; because the ticket
    /// is taken *before* the bound check, N concurrent dispatches get N
    /// distinct ticket numbers and the cap cannot be overrun by a
    /// check-then-act race across connection threads.
    dispatched: AtomicU64,
    /// Wire-protocol id allocator: replica engines each number from 1,
    /// so the router assigns pool-unique ids before dispatch.
    next_id: AtomicU64,
    /// Present when pool work stealing is on; the router registers the
    /// replicas' stealable surfaces with it at construction.
    rebalancer: Option<Arc<Rebalancer>>,
}

impl Router {
    pub fn new(replicas: Vec<ReplicaHandle>, route: RoutePolicy,
               queue_cap: usize) -> Router {
        Self::with_rebalancer(replicas, route, queue_cap, None)
    }

    /// Construct with pool work stealing. The `rebalancer` must be the
    /// same instance the replicas were spawned with
    /// ([`ReplicaHandle::spawn_with`]); this registers every replica's
    /// queue + gauges as the steal peer set, which arms `steal_for`.
    pub fn with_rebalancer(replicas: Vec<ReplicaHandle>, route: RoutePolicy,
                           queue_cap: usize,
                           rebalancer: Option<Arc<Rebalancer>>) -> Router {
        assert!(!replicas.is_empty(), "router needs at least one replica");
        if let Some(rb) = &rebalancer {
            rb.register(replicas.iter().map(|r| r.steal_peer()).collect());
        }
        Router {
            replicas,
            route,
            queue_cap: queue_cap.max(1),
            rr: AtomicUsize::new(0),
            shed: AtomicU64::new(0),
            dispatched: AtomicU64::new(0),
            next_id: AtomicU64::new(1),
            rebalancer,
        }
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    pub fn route(&self) -> RoutePolicy {
        self.route
    }

    /// Admitted-but-unfinished requests across the pool.
    pub fn total_queued(&self) -> usize {
        self.replicas
            .iter()
            .map(|r| r.gauges.queued.load(Ordering::Relaxed))
            .sum()
    }

    /// Requests completed across the pool.
    pub fn total_completed(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.completed.load(Ordering::Relaxed))
            .sum()
    }

    /// Requests shed by admission control.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Jobs migrated between replicas so far (0 when stealing is off).
    pub fn total_steals(&self) -> u64 {
        self.rebalancer.as_ref().map_or(0, |rb| rb.total_steals())
    }

    /// True when pool work stealing is armed.
    pub fn stealing(&self) -> bool {
        self.rebalancer.is_some()
    }

    /// Live pool-wide lazy ratio Γ from the gauges.
    pub fn overall_lazy(&self) -> f64 {
        let (mut seen, mut skipped) = (0u64, 0u64);
        for r in &self.replicas {
            seen += r.gauges.modules_seen.load(Ordering::Relaxed);
            skipped += r.gauges.modules_skipped.load(Ordering::Relaxed);
        }
        if seen == 0 {
            0.0
        } else {
            skipped as f64 / seen as f64
        }
    }

    /// True when every replica worker has exited (drained or failed) —
    /// the serve loop uses this to stop instead of waiting forever.
    pub fn all_replicas_finished(&self) -> bool {
        self.replicas.iter().all(|r| r.finished())
    }

    /// Resolved (no longer outstanding) ledger entries: sheds plus every
    /// request a replica completed or forfeited. Monotone, so a stale
    /// read can only over-estimate outstanding work — which sheds
    /// conservatively, never overruns the cap.
    fn resolved(&self) -> u64 {
        let done: u64 = self
            .replicas
            .iter()
            .map(|r| {
                r.gauges.completed.load(Ordering::Relaxed)
                    + r.gauges.forfeited.load(Ordering::Relaxed)
            })
            .sum();
        done + self.shed.load(Ordering::Relaxed)
    }

    /// Route one request. Returns `false` if it was shed (admission bound
    /// hit, or every replica refused). Requests arriving with `id == 0`
    /// get a pool-unique id (replica engines each number from 1, so
    /// engine-assigned ids would collide across replicas on the wire).
    pub fn dispatch(&self, mut req: Request,
                    respond: mpsc::Sender<RequestResult>) -> bool {
        // take a ticket first, then check the bound: the shed below
        // returns the ticket via the shed counter inside resolved()
        let resolved = self.resolved();
        let ticket = self.dispatched.fetch_add(1, Ordering::Relaxed) + 1;
        if ticket.saturating_sub(resolved) > self.queue_cap as u64 {
            self.shed.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        if req.id == 0 {
            req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        let snaps: Vec<GaugeSnapshot> =
            self.replicas.iter().map(|r| r.gauges.snapshot()).collect();
        let rr = self.rr.fetch_add(1, Ordering::Relaxed);
        let order = candidate_order(self.route, &snaps, rr);
        let steps = req.steps;
        let mut job = PoolJob { req, respond };
        for idx in order {
            let h = &self.replicas[idx];
            // optimistic accounting: visible to concurrent dispatches
            // before the worker even sees the job
            h.gauges.queued.fetch_add(1, Ordering::Relaxed);
            h.gauges.pending_steps.fetch_add(steps, Ordering::Relaxed);
            match h.try_send(job) {
                Ok(()) => return true,
                Err(j) => {
                    // saturating rollback: a panicked worker's cleanup
                    // decrements may race ours between the add and here,
                    // and a raw fetch_sub would wrap to usize::MAX
                    crate::coordinator::pool::replica::dec(&h.gauges.queued, 1);
                    crate::coordinator::pool::replica::dec(
                        &h.gauges.pending_steps, steps);
                    job = j;
                }
            }
        }
        self.shed.fetch_add(1, Ordering::Relaxed);
        false
    }

    /// Drain and stop every replica, returning the aggregated report.
    /// In-flight and queued trajectories finish first (drain semantics).
    pub fn shutdown(&self) -> PoolReport {
        for r in &self.replicas {
            r.close();
        }
        let mut reports: Vec<_> =
            self.replicas.iter().map(|r| r.join_report()).collect();
        // steal counters settle only once EVERY worker thread has exited
        // (gauge transfers run on thief worker threads, so a victim's own
        // exit can race the final `stolen` increment). All threads are
        // joined now — re-read the gauges so the reports can never miss
        // a migration and the steals==stolen conservation stays exact.
        for (rep, h) in reports.iter_mut().zip(&self.replicas) {
            rep.steals = h.gauges.steals.load(Ordering::Relaxed);
            rep.stolen = h.gauges.stolen.load(Ordering::Relaxed);
        }
        PoolReport { replicas: reports, shed: self.shed_count() }
    }
}

/// Effective-backlog cost of one replica under the lazy-aware policy.
pub fn lazy_cost(snap: &GaugeSnapshot) -> f64 {
    // clamp Γ: a replica that skipped everything so far still pays the
    // apply/embed/final overhead, so never discount below 5%
    snap.pending_steps as f64 * (1.0 - snap.lazy_ratio.clamp(0.0, 0.95))
}

/// Best-first replica order for one dispatch. Pure so policies are unit
/// testable without threads. Finished (drained or dead) replicas are
/// excluded up front: their snapshot cost of 0 would otherwise rank them
/// *first* under jsq/lazy, making every dispatch pay a futile `try_send`
/// against a closed queue before reaching a live replica.
pub fn candidate_order(route: RoutePolicy, snaps: &[GaugeSnapshot],
                       rr: usize) -> Vec<usize> {
    let n = snaps.len();
    let mut idx: Vec<usize> = (0..n).filter(|&i| !snaps[i].finished).collect();
    match route {
        RoutePolicy::RoundRobin => {
            // rotate over the live set (identical to the old full-pool
            // rotation when nothing has finished)
            let k = idx.len();
            if k > 0 {
                idx.rotate_left(rr % k);
            }
        }
        RoutePolicy::Jsq => {
            idx.sort_by_key(|&i| (snaps[i].queued, i));
        }
        RoutePolicy::Lazy => {
            idx.sort_by(|&a, &b| {
                lazy_cost(&snaps[a])
                    .partial_cmp(&lazy_cost(&snaps[b]))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then_with(|| snaps[a].queued.cmp(&snaps[b].queued))
                    .then_with(|| a.cmp(&b))
            });
        }
    }
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(queued: usize, steps: usize, lazy: f64) -> GaugeSnapshot {
        GaugeSnapshot {
            queued,
            pending_steps: steps,
            lazy_ratio: lazy,
            finished: false,
        }
    }

    #[test]
    fn rr_rotates() {
        let s = vec![snap(0, 0, 0.0); 3];
        assert_eq!(candidate_order(RoutePolicy::RoundRobin, &s, 0), vec![0, 1, 2]);
        assert_eq!(candidate_order(RoutePolicy::RoundRobin, &s, 1), vec![1, 2, 0]);
        assert_eq!(candidate_order(RoutePolicy::RoundRobin, &s, 4), vec![1, 2, 0]);
    }

    #[test]
    fn jsq_picks_shortest() {
        let s = vec![snap(4, 80, 0.0), snap(1, 20, 0.0), snap(2, 40, 0.0)];
        assert_eq!(candidate_order(RoutePolicy::Jsq, &s, 0)[0], 1);
        // tie → lowest index (replicas 0 and 1 both queue 2), and the
        // rr cursor must not perturb jsq ordering
        let t = vec![snap(2, 0, 0.0), snap(2, 0, 0.0), snap(1, 0, 0.0)];
        assert_eq!(candidate_order(RoutePolicy::Jsq, &t, 7), vec![2, 0, 1]);
        assert_eq!(candidate_order(RoutePolicy::Jsq, &t, 0), vec![2, 0, 1]);
    }

    #[test]
    fn finished_replicas_are_excluded_from_candidates() {
        let mut s = vec![snap(0, 0, 0.0), snap(3, 60, 0.0), snap(1, 20, 0.0)];
        s[0].finished = true; // dead replica: snapshot cost 0 would
                              // otherwise win jsq/lazy outright
        assert_eq!(candidate_order(RoutePolicy::Jsq, &s, 0), vec![2, 1]);
        assert_eq!(candidate_order(RoutePolicy::Lazy, &s, 0), vec![2, 1]);
        // rr rotates over the live set only
        assert_eq!(candidate_order(RoutePolicy::RoundRobin, &s, 0), vec![1, 2]);
        assert_eq!(candidate_order(RoutePolicy::RoundRobin, &s, 1), vec![2, 1]);
        assert_eq!(candidate_order(RoutePolicy::RoundRobin, &s, 2), vec![1, 2]);
        // a fully-finished pool yields no candidates at all
        s[1].finished = true;
        s[2].finished = true;
        assert!(candidate_order(RoutePolicy::Jsq, &s, 0).is_empty());
        assert!(candidate_order(RoutePolicy::RoundRobin, &s, 3).is_empty());
    }

    #[test]
    fn lazy_discounts_backlog_by_gamma() {
        // replica 0: 100 steps at Γ=0.6 → cost 40
        // replica 1:  60 steps at Γ=0.0 → cost 60
        let s = vec![snap(5, 100, 0.6), snap(3, 60, 0.0)];
        assert_eq!(candidate_order(RoutePolicy::Lazy, &s, 0)[0], 0);
        // without laziness the same backlogs invert the choice
        let s = vec![snap(5, 100, 0.0), snap(3, 60, 0.0)];
        assert_eq!(candidate_order(RoutePolicy::Lazy, &s, 0)[0], 1);
    }

    #[test]
    fn lazy_cost_clamps_gamma() {
        let c = lazy_cost(&snap(1, 100, 1.0));
        assert!((c - 5.0).abs() < 1e-9, "Γ clamped to 0.95 → cost 5, got {c}");
    }
}
