//! Admission control + dispatch across the replica pool.
//!
//! Three policies (config::RoutePolicy) order the candidates for
//! best-effort traffic:
//! * `rr`   — rotate, ignoring load;
//! * `jsq`  — join-shortest-queue on admitted-but-unfinished requests;
//! * `lazy` — cost-based: a replica's backlog is its queued remaining
//!   denoise steps discounted by its observed lazy ratio Γ — a replica
//!   skipping Γ of its module invocations clears a step in ≈(1−Γ) of the
//!   full-step time, so its *effective* backlog is `steps · (1 − Γ)`.
//!
//! SLO-tagged requests route by tier instead: candidates are restricted
//! to compatible replicas ([`crate::config::Slo::serves`]), with
//! matching-tier replicas ahead of best-effort spill. Latency requests
//! order by lazy-discounted backlog (narrowest batch first on ties);
//! throughput requests prefer the widest batch. A request whose SLO no
//! live replica can honor sheds immediately — by design, a latency
//! budget is never silently parked on a deep-batch replica.
//!
//! Admission control is pool-wide: when the total of per-replica queues
//! reaches `queue_cap`, new requests are shed immediately (the client
//! gets a structured `queue full` line, never silence). Sheds are also
//! counted per SLO class for the `STATS` wire verb and the final report.
//!
//! When constructed [`with_cache`](Router::with_cache), the router
//! fronts the dispatch path with the content-addressable
//! [`PoolCache`]: an exact [`crate::coordinator::request::RequestKey`]
//! hit answers on the response channel immediately — zero engine work,
//! no queue capacity consumed — and settles its own ledger term
//! (`cache_hits`), so the conservation law becomes
//! `dispatched == completed + cache_hits + shed + forfeited`. Cache
//! hits never touch the latency histograms: quantiles keep describing
//! engine-served requests only.
//!
//! Invariants (pinned by unit + integration tests):
//! * **Gauge conservation** — pool-wide `queued`/`pending_steps` totals
//!   are preserved by dispatch rollback, steal migration, and dead-
//!   replica cleanup; completed + cache_hits + forfeited + shed
//!   resolves every admission ticket exactly once.
//! * **Admission-ledger bound** — tickets are taken *before* the bound
//!   check, so concurrent dispatches can never overrun `queue_cap`.
//! * **Candidate soundness** — finished replicas and SLO-incompatible
//!   tiers never appear in a dispatch order.

use crate::config::{RoutePolicy, Slo};
use crate::coordinator::pool::agg::PoolReport;
use crate::coordinator::pool::brownout::Brownout;
use crate::coordinator::pool::cache::PoolCache;
use crate::coordinator::pool::calendar::PoolCalendar;
use crate::coordinator::pool::replica::{breaker_name, GaugeSnapshot,
                                        PoolJob, ReplicaHandle};
use crate::coordinator::pool::steal::Rebalancer;
use crate::coordinator::request::{Request, RequestResult};
use crate::obs::{EventKind, LatencyHist};
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

/// Why one dispatch attempt succeeded or shed — the wire front-end
/// maps the two shed reasons to distinct error lines so clients can
/// tell transient overload from a permanent pool-shape mismatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchOutcome {
    /// Admitted to a replica; the response channel will deliver.
    Admitted,
    /// Transient: the pool-wide admission bound (or every compatible
    /// replica's queue) is full. Backing off and retrying can succeed.
    ShedCapacity,
    /// Permanent for this pool shape: no live replica is compatible
    /// with the request's SLO class and lane count. Retrying the same
    /// request is futile until the pool is re-provisioned.
    ShedUnservable,
    /// Served straight from the exact-result cache: the finished
    /// response was already delivered on the caller's channel with zero
    /// engine work and zero queue capacity consumed. Settled by the
    /// ledger's `cache_hits` term, and deliberately absent from the
    /// latency histograms (a 0-step hit must not deflate p50).
    CacheHit,
    /// Shed at admission because the request's deadline cannot be met:
    /// on every candidate replica, predicted queue delay (calendar-
    /// priced backlog × µs-per-row) plus the request's own predicted
    /// service time already overruns the deadline. Admitting it would
    /// burn engine time on a result the client has declared worthless —
    /// shedding now frees that capacity for requests that can still
    /// hit. Counted inside `shed` (the conservation ledger is
    /// unchanged) and additionally under `slack_sheds`.
    ShedNoSlack,
}

/// The pool front-door. All methods take `&self`; the router is shared
/// across acceptor threads behind an `Arc`.
pub struct Router {
    replicas: Vec<ReplicaHandle>,
    route: RoutePolicy,
    queue_cap: usize,
    rr: AtomicUsize,
    shed: AtomicU64,
    /// Sheds per SLO class (`Slo::index()` order) — surfaced by the
    /// `STATS` verb and the final report's tier breakdown.
    shed_by_slo: [AtomicU64; Slo::COUNT],
    /// Admission ledger: dispatch attempts (tickets). Outstanding work is
    /// `dispatched − shed − Σ(completed + forfeited)`; because the ticket
    /// is taken *before* the bound check, N concurrent dispatches get N
    /// distinct ticket numbers and the cap cannot be overrun by a
    /// check-then-act race across connection threads.
    dispatched: AtomicU64,
    /// Wire-protocol id allocator: replica engines each number from 1,
    /// so the router assigns pool-unique ids before dispatch.
    next_id: AtomicU64,
    /// Present when pool work stealing is on; the router registers the
    /// replicas' stealable surfaces with it at construction.
    rebalancer: Option<Arc<Rebalancer>>,
    /// Present when the router fronts dispatch with the
    /// content-addressable cache ([`with_cache`](Self::with_cache)).
    /// Exact hits answer here; the same `Arc` lives in the replicas so
    /// completions populate the exact tier and admissions warm-start.
    cache: Option<Arc<PoolCache>>,
    /// Requests resolved by the exact-result cache — its own ledger
    /// term: `dispatched == completed + cache_hits + shed + forfeited`.
    cache_hits: AtomicU64,
    /// Response writes the wire front-end abandoned because the client
    /// stopped reading (slow-client guard; see `serve_lines`). Counted
    /// here so the pool report and `STATS` can surface them.
    write_timeouts: AtomicU64,
    /// The pool-wide overload controller, when armed
    /// ([`with_brownout_controller`](Self::with_brownout_controller)):
    /// dispatch caps best-effort steps by its stage, `STATS` and
    /// responses echo the stage.
    brownout: Option<Arc<Brownout>>,
    /// The skip-calendar pricing oracle, when armed
    /// ([`with_calendar`](Self::with_calendar)): every dispatch is
    /// priced in predicted module rows, latency-tier requests without a
    /// deadline get one defaulted from predicted service time, and
    /// requests whose deadline no candidate can meet shed by negative
    /// slack. The serve loop ticks its EWMA fallback.
    calendar: Option<Arc<PoolCalendar>>,
    /// Requests shed by the negative-slack check — a subset of `shed`
    /// (the ledger counts them there; this counter only attributes the
    /// reason).
    slack_sheds: AtomicU64,
}

impl Router {
    /// Construct without work stealing (see
    /// [`with_rebalancer`](Self::with_rebalancer)).
    pub fn new(replicas: Vec<ReplicaHandle>, route: RoutePolicy,
               queue_cap: usize) -> Router {
        Self::with_rebalancer(replicas, route, queue_cap, None)
    }

    /// Construct with pool work stealing. The `rebalancer` must be the
    /// same instance the replicas were spawned with
    /// ([`ReplicaHandle::spawn_with`]); this registers every replica's
    /// queue + gauges as the steal peer set, which arms `steal_for`.
    pub fn with_rebalancer(replicas: Vec<ReplicaHandle>, route: RoutePolicy,
                           queue_cap: usize,
                           rebalancer: Option<Arc<Rebalancer>>) -> Router {
        Self::with_cache(replicas, route, queue_cap, rebalancer, None)
    }

    /// Construct with an optional content-addressable cache fronting
    /// the dispatch path (decorator: cache-check before delegating to
    /// the routed dispatch). Pass the SAME `Arc` the replicas were
    /// spawned with ([`ReplicaHandle::spawn_cached`]) — the replicas
    /// write completions into the exact tier and harvest warm-start
    /// donors; the router reads exact hits here. `None` behaves exactly
    /// like [`with_rebalancer`](Self::with_rebalancer).
    pub fn with_cache(replicas: Vec<ReplicaHandle>, route: RoutePolicy,
                      queue_cap: usize,
                      rebalancer: Option<Arc<Rebalancer>>,
                      cache: Option<Arc<PoolCache>>) -> Router {
        assert!(!replicas.is_empty(), "router needs at least one replica");
        if let Some(rb) = &rebalancer {
            rb.register(replicas.iter().map(|r| r.steal_peer()).collect());
        }
        Router {
            replicas,
            route,
            queue_cap: queue_cap.max(1),
            rr: AtomicUsize::new(0),
            shed: AtomicU64::new(0),
            shed_by_slo: Default::default(),
            dispatched: AtomicU64::new(0),
            next_id: AtomicU64::new(1),
            rebalancer,
            cache,
            cache_hits: AtomicU64::new(0),
            write_timeouts: AtomicU64::new(0),
            brownout: None,
            calendar: None,
            slack_sheds: AtomicU64::new(0),
        }
    }

    /// Arm the skip-calendar pricing oracle (builder, called before the
    /// router is shared). Dispatch prices every request through it,
    /// latency-tier requests get calendar-defaulted deadlines, the
    /// negative-slack shed check activates once the oracle can price in
    /// time units, and the brownout pressure signal reads the priced
    /// backlog. The serve loop is expected to call
    /// [`tick_calendar`](Self::tick_calendar) periodically so the EWMA
    /// fallback self-calibrates.
    pub fn with_calendar(mut self, cal: Arc<PoolCalendar>) -> Router {
        self.calendar = Some(cal);
        self
    }

    /// The armed calendar oracle, if any.
    pub fn calendar(&self) -> Option<&Arc<PoolCalendar>> {
        self.calendar.as_ref()
    }

    /// Feed the calendar oracle's EWMA fallback from the live pool
    /// gauges (cheap: a handful of relaxed loads; the serve loop calls
    /// this on its housekeeping cadence). No-op when no calendar is
    /// armed.
    pub fn tick_calendar(&self) {
        let Some(cal) = &self.calendar else { return };
        let rows_run = self.total_rows_run();
        let rows_seen = rows_run + self.total_rows_skipped();
        let live = self.replicas.len() - self.dead_replicas();
        cal.tick(rows_run, rows_seen, self.total_completed(), live,
                 crate::obs::epoch_us());
    }

    /// Arm the pool-wide brownout controller (builder, called before
    /// the router is shared). The serve loop ticks the controller; the
    /// router consults it at dispatch (best-effort step cap) and echoes
    /// its stage through `STATS` and the response formatter.
    pub fn with_brownout_controller(mut self, b: Arc<Brownout>) -> Router {
        self.brownout = Some(b);
        self
    }

    /// The brownout controller's current degradation stage (0 = full
    /// fidelity; 0 when no controller is armed).
    pub fn brownout_stage(&self) -> usize {
        self.brownout.as_ref().map_or(0, |b| b.stage())
    }

    /// The armed brownout controller, if any (the serve loop's tick
    /// target).
    pub fn brownout(&self) -> Option<&Arc<Brownout>> {
        self.brownout.as_ref()
    }

    /// Borrow replica `idx`'s handle (supervisor access: respawn,
    /// give-up, breaker state live on the handle/gauges).
    pub fn replica(&self, idx: usize) -> Option<&ReplicaHandle> {
        self.replicas.get(idx)
    }

    /// Ask every replica worker to raise its engine's target laziness
    /// by `boost` percentage points at its next loop boundary (brownout
    /// stage 2; 0 restores the configured target).
    pub fn set_gamma_boost(&self, boost: u32) {
        for r in &self.replicas {
            r.gauges
                .gamma_boost
                .store(boost as usize, Ordering::Relaxed);
        }
    }

    /// Count one abandoned response write (slow-client guard).
    pub fn note_write_timeout(&self) {
        self.write_timeouts.fetch_add(1, Ordering::Relaxed);
    }

    /// Response writes abandoned on stalled clients pool-wide.
    pub fn total_write_timeouts(&self) -> u64 {
        self.write_timeouts.load(Ordering::Relaxed)
    }

    /// Supervisor respawns pool-wide (gauges survive incarnations).
    pub fn total_restarts(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.restarts.load(Ordering::Relaxed))
            .sum()
    }

    /// Circuit-breaker trips pool-wide.
    pub fn total_breaker_trips(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.breaker_trips.load(Ordering::Relaxed))
            .sum()
    }

    /// Replicas whose worker has exited for good (drained or dead).
    /// `provisioned − dead` is the pool's live capacity — without a
    /// supervisor a panicked replica lands here permanently, and
    /// `STATS` reports the shrinkage instead of hiding it.
    pub fn dead_replicas(&self) -> usize {
        self.replicas
            .iter()
            .filter(|r| r.gauges.finished.load(Ordering::Acquire))
            .count()
    }

    /// Record a pool-level trace event (brownout transitions, breaker
    /// trips). The router owns no ring; pool events land on replica 0's
    /// tracer, like cache hits.
    pub fn record_pool_event(&self, kind: EventKind, kind_id: u64,
                             arg: u64) {
        if let Some(r) = self.replicas.first() {
            r.tracer.record(kind, kind_id, arg);
        }
    }

    /// Number of replicas in the pool (live or finished).
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// The configured dispatch policy for best-effort traffic.
    pub fn route(&self) -> RoutePolicy {
        self.route
    }

    /// Per-replica admission bound (the brownout controller's pressure
    /// denominator is `queue_cap × replica_count`).
    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    /// Admitted-but-unfinished requests across the pool.
    pub fn total_queued(&self) -> usize {
        self.replicas
            .iter()
            .map(|r| r.gauges.queued.load(Ordering::Relaxed))
            .sum()
    }

    /// Requests completed across the pool.
    pub fn total_completed(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.completed.load(Ordering::Relaxed))
            .sum()
    }

    /// Requests retired on or before their declared/defaulted deadline,
    /// pool-wide. Requests without a deadline count in neither bucket.
    pub fn total_deadline_hits(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.deadline_hits.load(Ordering::Relaxed))
            .sum()
    }

    /// Requests retired after their deadline, pool-wide.
    pub fn total_deadline_misses(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.deadline_misses.load(Ordering::Relaxed))
            .sum()
    }

    /// Requests shed at admission because no candidate replica could
    /// meet their deadline (a strict subset of `shed_count`).
    pub fn slack_shed_count(&self) -> u64 {
        self.slack_sheds.load(Ordering::Relaxed)
    }

    /// Calendar-priced queued backlog pool-wide, in milli-rows of
    /// predicted executed module invocations. Zero until a calendar is
    /// armed and dispatches have been priced.
    pub fn total_predicted_cost_milli(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| {
                r.gauges.predicted_cost_milli.load(Ordering::Relaxed)
            })
            .sum()
    }

    /// Backlog pressure for brownout control: the raw queued-request
    /// count, raised (never lowered) by the calendar-priced backlog
    /// expressed in request-equivalents. With no calendar — or before
    /// it can estimate request shape — this is exactly the legacy
    /// queue-length signal; once pricing is live, a queue of few-but-
    /// enormous requests registers the pressure its row count hides.
    pub fn backlog_pressure(&self) -> usize {
        let queued = self.total_queued();
        let Some(cal) = &self.calendar else { return queued };
        match cal.queue_equivalent(self.total_predicted_cost_milli()) {
            Some(eq) => queued.max(eq.ceil() as usize),
            None => queued,
        }
    }

    /// Test hook: register one shed without a wire request (brownout
    /// pressure-path tests).
    #[cfg(test)]
    pub(crate) fn record_shed_for_test(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests shed by admission control.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Sheds per SLO class (`Slo::index()` order).
    pub fn shed_by_slo(&self) -> [u64; Slo::COUNT] {
        let mut out = [0u64; Slo::COUNT];
        for (o, c) in out.iter_mut().zip(self.shed_by_slo.iter()) {
            *o = c.load(Ordering::Relaxed);
        }
        out
    }

    /// Jobs migrated between replicas so far (0 when stealing is off).
    pub fn total_steals(&self) -> u64 {
        self.rebalancer.as_ref().map_or(0, |rb| rb.total_steals())
    }

    /// True when pool work stealing is armed.
    pub fn stealing(&self) -> bool {
        self.rebalancer.is_some()
    }

    /// Module invocations pool-wide whose skip was denied by a cold
    /// (freshly-joined) row — the live view of laziness lost to
    /// all-or-nothing batch skip coupling.
    pub fn total_cold_denied(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.cold_denied.load(Ordering::Relaxed))
            .sum()
    }

    /// Live rows run pool-wide (row-weighted work).
    pub fn total_rows_run(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.rows_run.load(Ordering::Relaxed))
            .sum()
    }

    /// Live rows served from cache pool-wide.
    pub fn total_rows_skipped(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.rows_skipped.load(Ordering::Relaxed))
            .sum()
    }

    /// Rows pool-wide that only row-granular gating could skip (their
    /// module still ran for other rows).
    pub fn total_rows_recovered(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.rows_recovered.load(Ordering::Relaxed))
            .sum()
    }

    /// Live pool-wide lazy ratio Γ from the gauges: row-weighted
    /// (skipped rows over live rows seen), falling back to the
    /// module-weighted ratio when no row accounting exists yet.
    pub fn overall_lazy(&self) -> f64 {
        let (run, skipped_rows) =
            (self.total_rows_run(), self.total_rows_skipped());
        if run + skipped_rows > 0 {
            return skipped_rows as f64 / (run + skipped_rows) as f64;
        }
        let (mut seen, mut skipped) = (0u64, 0u64);
        for r in &self.replicas {
            seen += r.gauges.modules_seen.load(Ordering::Relaxed);
            skipped += r.gauges.modules_skipped.load(Ordering::Relaxed);
        }
        if seen == 0 {
            0.0
        } else {
            skipped as f64 / seen as f64
        }
    }

    /// True when every replica worker has exited (drained or failed) —
    /// the serve loop uses this to stop instead of waiting forever.
    pub fn all_replicas_finished(&self) -> bool {
        self.replicas.iter().all(|r| r.finished())
    }

    /// Resolved (no longer outstanding) ledger entries: sheds, cache
    /// hits, and every request a replica completed or forfeited.
    /// Monotone, so a stale read can only over-estimate outstanding
    /// work — which sheds conservatively, never overruns the cap.
    fn resolved(&self) -> u64 {
        let done: u64 = self
            .replicas
            .iter()
            .map(|r| {
                r.gauges.completed.load(Ordering::Relaxed)
                    + r.gauges.forfeited.load(Ordering::Relaxed)
            })
            .sum();
        done + self.shed.load(Ordering::Relaxed)
            + self.cache_hits.load(Ordering::Relaxed)
    }

    /// Route one request. Returns `false` if it was shed — see
    /// [`dispatch_outcome`](Self::dispatch_outcome) for the
    /// reason-bearing variant the wire front-end uses. A cache hit
    /// counts as success: the response channel has already delivered.
    pub fn dispatch(&self, req: Request,
                    respond: mpsc::Sender<RequestResult>) -> bool {
        matches!(self.dispatch_outcome(req, respond),
                 DispatchOutcome::Admitted | DispatchOutcome::CacheHit)
    }

    /// Route one request, reporting *why* when it sheds: a capacity shed
    /// is transient (back off and retry), an unservable shed is
    /// permanent for this pool shape (no live replica matches the
    /// request's SLO class and lane count) and retrying is futile —
    /// the wire front-end surfaces the two differently. Requests
    /// arriving with `id == 0` get a pool-unique id (replica engines
    /// each number from 1, so engine-assigned ids would collide across
    /// replicas on the wire).
    pub fn dispatch_outcome(&self, mut req: Request,
                            respond: mpsc::Sender<RequestResult>)
                            -> DispatchOutcome {
        let slo = req.slo;
        let lanes = req.lanes().max(1);
        // brownout stage 3: cap best-effort step schedules BEFORE the
        // cache lookup, so a degraded request's key matches other
        // degraded requests (and a full-fidelity cached result never
        // masquerades as the degraded one, or vice versa)
        if let Some(b) = &self.brownout {
            req.steps = b.cap_steps(slo, req.steps);
        }
        // cache-check before delegating to the routed path: an exact
        // hit answers immediately and never consumes queue capacity.
        // The hit is counted BEFORE its dispatch ticket, so a
        // concurrent resolved() read can never observe the ticket
        // without its resolution — outstanding work is never
        // over-estimated by a hit in flight, and the bound check stays
        // exact for real dispatches racing it.
        if let Some(c) = &self.cache {
            if let Some(mut res) = c.lookup(&req) {
                self.cache_hits.fetch_add(1, Ordering::Relaxed);
                self.dispatched.fetch_add(1, Ordering::Relaxed);
                let id = if req.id == 0 {
                    self.next_id.fetch_add(1, Ordering::Relaxed)
                } else {
                    req.id
                };
                // re-stamp the wire identity: the cached payload came
                // from a different request (same key, other id/SLO tag)
                // and a hit costs no engine time
                res.id = id;
                res.slo = slo;
                res.latency = std::time::Duration::ZERO;
                // the router owns no trace ring; hits land on replica
                // 0's so TRACE consumers see them (arg = steps saved)
                if let Some(r) = self.replicas.first() {
                    r.tracer.record(EventKind::CacheHit, id,
                                    res.steps as u64);
                }
                // a dropped receiver just discards the hit — same as a
                // completion racing a disconnected client
                let _ = respond.send(res);
                return DispatchOutcome::CacheHit;
            }
        }
        // take a ticket first, then check the bound: the sheds below
        // return the ticket via the shed counter inside resolved()
        let resolved = self.resolved();
        let ticket = self.dispatched.fetch_add(1, Ordering::Relaxed) + 1;
        if ticket.saturating_sub(resolved) > self.queue_cap as u64 {
            // classify the shed even at the bound: an unservable
            // request must report as unservable, or the reason would
            // flip-flop with load and well-behaved clients would retry
            // a condition that can never clear. The probe (one atomic
            // per replica, no allocation) runs only on shed paths —
            // admitted requests never pay it.
            self.count_shed(slo);
            return if self.any_compatible(slo, lanes) {
                DispatchOutcome::ShedCapacity
            } else {
                DispatchOutcome::ShedUnservable
            };
        }
        if req.id == 0 {
            req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        // calendar pricing: predicted executed module rows for this
        // request's whole schedule (milli-units; 0 = oracle not yet
        // calibrated and no artifact entry covers this step count)
        let mut cost_milli = 0u64;
        if let Some(cal) = &self.calendar {
            cal.observe_dispatch(req.steps);
            cost_milli = cal.price_milli(req.steps, 0);
            // latency-tier requests that declared no deadline get one
            // defaulted from predicted service time — the tier's SLO
            // becomes an explicit, enforceable instant instead of an
            // implicit "soon"
            if req.deadline_us == 0 && slo == Slo::Latency {
                if let Some(d) = cal
                    .default_deadline_us(crate::obs::epoch_us(), req.steps)
                {
                    req.deadline_us = d;
                }
            }
        }
        let snaps: Vec<GaugeSnapshot> =
            self.replicas.iter().map(|r| r.snapshot()).collect();
        let rr = self.rr.fetch_add(1, Ordering::Relaxed);
        let order = candidate_order(self.route, slo, lanes, &snaps, rr);
        if order.is_empty() {
            self.count_shed(slo);
            // distinguish "no compatible tier exists" (permanent) from
            // "every compatible replica is breaker-open / awaiting
            // respawn" (transient — the supervisor may revive them)
            return if self.any_compatible(slo, lanes) {
                DispatchOutcome::ShedCapacity
            } else {
                DispatchOutcome::ShedUnservable
            };
        }
        // negative-slack shed: if on EVERY candidate the predicted
        // queue delay (priced queued backlog × µs-per-row) plus this
        // request's own predicted service time already overruns its
        // deadline, admitting it would spend engine time on a result
        // the client has declared worthless. Admission-time only —
        // jobs already queued are never evicted by this check — and
        // inactive until the oracle can price in time units, so an
        // uncalibrated pool never sheds work it might have served.
        if req.deadline_us > 0 && cost_milli > 0 {
            if let Some(cal) = &self.calendar {
                if let Some(svc) = cal.service_us(cost_milli) {
                    let now = crate::obs::epoch_us();
                    let feasible = order.iter().any(|&i| {
                        let delay = cal
                            .service_us(snaps[i].predicted_cost_milli)
                            .unwrap_or(0);
                        now.saturating_add(delay).saturating_add(svc)
                            <= req.deadline_us
                    });
                    if !feasible {
                        self.count_shed(slo);
                        self.slack_sheds.fetch_add(1, Ordering::Relaxed);
                        return DispatchOutcome::ShedNoSlack;
                    }
                }
            }
        }
        let steps = req.steps;
        // stamp the admission instant once (one clock read, off the
        // engine hot path) so replicas can report queue-wait spans;
        // 0 means "untimed" to the consumer, which epoch_us never is
        // after the first microsecond of process life
        let mut job = PoolJob::fresh(req, respond, crate::obs::epoch_us());
        job.cost_milli = cost_milli;
        for idx in order {
            let h = &self.replicas[idx];
            // optimistic accounting: visible to concurrent dispatches
            // before the worker even sees the job
            h.gauges.queued.fetch_add(1, Ordering::Relaxed);
            h.gauges.pending_steps.fetch_add(steps, Ordering::Relaxed);
            h.gauges
                .predicted_cost_milli
                .fetch_add(cost_milli, Ordering::Relaxed);
            match h.try_send(job) {
                Ok(()) => return DispatchOutcome::Admitted,
                Err(j) => {
                    // saturating rollback: a panicked worker's cleanup
                    // decrements may race ours between the add and here,
                    // and a raw fetch_sub would wrap to usize::MAX
                    crate::coordinator::pool::replica::dec(&h.gauges.queued, 1);
                    crate::coordinator::pool::replica::dec(
                        &h.gauges.pending_steps, steps);
                    crate::coordinator::pool::replica::dec_u64(
                        &h.gauges.predicted_cost_milli, cost_milli);
                    job = j;
                }
            }
        }
        self.count_shed(slo);
        DispatchOutcome::ShedCapacity
    }

    /// Resolve a shed ticket, total + per-SLO-class.
    fn count_shed(&self, slo: Slo) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        self.shed_by_slo[slo.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Is any live replica's tier compatible with `(slo, lanes)`? The
    /// shed-path classifier behind unservable-vs-capacity reporting
    /// (shares [`crate::coordinator::pool::replica::tier_admits`] with
    /// the candidate filter and steal eligibility). Judged over each
    /// replica's LIVE SLO class, so a retag immediately changes what
    /// the pool reports as servable.
    fn any_compatible(&self, slo: Slo, lanes: usize) -> bool {
        self.replicas.iter().any(|r| {
            !r.gauges.finished.load(Ordering::Acquire)
                && crate::coordinator::pool::replica::tier_admits(
                    r.gauges.live_slo(r.tier.slo), r.tier.max_batch,
                    slo, lanes)
        })
    }

    /// Trajectories that crossed a replica boundary as portable
    /// snapshots (drain, relief, crash resume) — counted on the way out.
    pub fn total_migrated(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.migrated_out.load(Ordering::Relaxed))
            .sum()
    }

    /// Snapshots admitted back into an engine pool-wide.
    pub fn total_resumed(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.resumed.load(Ordering::Relaxed))
            .sum()
    }

    /// Denoise steps resumed trajectories did NOT redo because their
    /// snapshot carried the cursor (steps saved vs restart-from-zero).
    pub fn total_resume_steps_saved(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| {
                r.gauges.resume_steps_saved.load(Ordering::Relaxed)
            })
            .sum()
    }

    /// Retag replica `idx` to serve `slo` from now on: the worker drains
    /// its current residents to compatible siblings (drain-by-migration)
    /// at its next step boundary and the live class flips immediately
    /// for dispatch, stealing, and placement. The provisioned tier is
    /// untouched — a later `retag` can flip it back. No-op on a bad
    /// index. Typical use: an idle throughput replica turns into a
    /// latency server when `shed_by_slo.latency` starts growing.
    pub fn retag_replica(&self, idx: usize, slo: Slo) {
        if let Some(r) = self.replicas.get(idx) {
            r.retag(slo);
        }
    }

    /// Ask replica `idx` to evict every resident trajectory to
    /// compatible siblings at its next step boundary, WITHOUT changing
    /// its SLO class — a pure drain-by-migration sweep. Residents with
    /// no live compatible sibling re-admit locally, so nothing strands.
    /// No-op on a bad index.
    pub fn drain_replica(&self, idx: usize) {
        if let Some(r) = self.replicas.get(idx) {
            r.request_drain();
        }
    }

    /// Total requests ever handed to [`dispatch`](Self::dispatch) —
    /// admitted, cache-served, or shed. The pool-wide conservation law
    /// is `dispatched == completed + cache_hits + shed + forfeited`
    /// once drained (`cache_hits` is 0 without a cache).
    pub fn total_dispatched(&self) -> u64 {
        self.dispatched.load(Ordering::Relaxed)
    }

    /// Requests served straight from the exact-result cache — counted
    /// separately from `completed` (hits do zero engine work and are
    /// deliberately absent from the latency histograms).
    pub fn total_cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    /// Requests admitted warm-started pool-wide: a same-family donor
    /// trajectory actually seeded lane-cache rows at admission.
    pub fn total_warm_hits(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.warm_hits.load(Ordering::Relaxed))
            .sum()
    }

    /// Lane-cache rows seeded from warm-start donors pool-wide — each
    /// one a `rows_denied_cold` the joiner will not pay.
    pub fn total_rows_warmed(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.rows_warmed.load(Ordering::Relaxed))
            .sum()
    }

    /// Live counter snapshot of the fronting cache, when one is armed.
    pub fn cache_stats(&self)
                       -> Option<crate::coordinator::pool::cache::CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// Requests lost to replica panics pool-wide (admitted but neither
    /// completed nor recoverable from a boundary snapshot).
    pub fn total_forfeited(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.gauges.forfeited.load(Ordering::Relaxed))
            .sum()
    }

    /// Each replica's LIVE SLO class (provisioned tier unless retagged).
    pub fn live_slos(&self) -> Vec<Slo> {
        self.replicas
            .iter()
            .map(|r| r.gauges.live_slo(r.tier.slo))
            .collect()
    }

    /// One-line JSON snapshot of the live pool gauges — the payload of
    /// the `STATS` wire verb (see docs/SERVING.md). Per replica: tier,
    /// batch width, queued, pending steps, observed Γ (row-weighted),
    /// row-work gauges (`rows_run`/`rows_skipped`/`rows_recovered`),
    /// completions (total and per SLO class), latency quantiles from
    /// the replica's merged log-bucketed histogram, steal counters,
    /// liveness. Pool-wide: route, stealing, totals, row-work plus the
    /// recovered-work ratio, sheds per SLO class, and a `tiers` object
    /// with per-SLO-class p50/p95/p99 from histograms merged across
    /// every replica that served that class.
    pub fn stats_json(&self) -> String {
        let replicas: Vec<Json> = self
            .replicas
            .iter()
            .map(|r| {
                let s = r.snapshot();
                let by = r.gauges.completed_by_slo();
                let by_slo = Json::obj(
                    Slo::ALL
                        .iter()
                        .map(|c| (c.name(), Json::num(by[c.index()] as f64)))
                        .collect(),
                );
                let mut lh = LatencyHist::new();
                for h in r.gauges.lat_hist_by_slo.iter() {
                    lh.merge_from(h);
                }
                Json::obj(vec![
                    ("id", Json::num(r.id as f64)),
                    // the LIVE class: retags show up here immediately
                    ("tier", Json::str(
                        r.gauges.live_slo(r.tier.slo).name())),
                    ("provisioned", Json::str(r.tier.slo.name())),
                    ("latency_ms", hist_ms_json(&lh)),
                    ("max_batch", Json::num(r.tier.max_batch as f64)),
                    ("queued", Json::num(s.queued as f64)),
                    ("pending_steps", Json::num(s.pending_steps as f64)),
                    ("lazy_ratio", Json::num(s.lazy_ratio)),
                    ("cold_denied",
                     Json::num(r.gauges.cold_denied.load(Ordering::Relaxed)
                               as f64)),
                    ("rows_run",
                     Json::num(r.gauges.rows_run.load(Ordering::Relaxed)
                               as f64)),
                    ("rows_skipped",
                     Json::num(r.gauges.rows_skipped.load(Ordering::Relaxed)
                               as f64)),
                    ("rows_recovered",
                     Json::num(r.gauges.rows_recovered
                               .load(Ordering::Relaxed)
                               as f64)),
                    ("warm_hits",
                     Json::num(r.gauges.warm_hits.load(Ordering::Relaxed)
                               as f64)),
                    ("rows_warmed",
                     Json::num(r.gauges.rows_warmed.load(Ordering::Relaxed)
                               as f64)),
                    ("completed",
                     Json::num(r.gauges.completed.load(Ordering::Relaxed)
                               as f64)),
                    ("completed_by_slo", by_slo),
                    ("steals",
                     Json::num(r.gauges.steals.load(Ordering::Relaxed)
                               as f64)),
                    ("stolen",
                     Json::num(r.gauges.stolen.load(Ordering::Relaxed)
                               as f64)),
                    ("migrated_out",
                     Json::num(r.gauges.migrated_out
                               .load(Ordering::Relaxed) as f64)),
                    ("migrated_in",
                     Json::num(r.gauges.migrated_in
                               .load(Ordering::Relaxed) as f64)),
                    ("resumed",
                     Json::num(r.gauges.resumed.load(Ordering::Relaxed)
                               as f64)),
                    ("resume_steps_saved",
                     Json::num(r.gauges.resume_steps_saved
                               .load(Ordering::Relaxed) as f64)),
                    ("restarts",
                     Json::num(r.gauges.restarts.load(Ordering::Relaxed)
                               as f64)),
                    ("breaker", Json::str(breaker_name(
                        r.gauges.breaker.load(Ordering::Relaxed)))),
                    ("heartbeat_us",
                     Json::num(r.gauges.heartbeat_us
                               .load(Ordering::Relaxed) as f64)),
                    ("predicted_cost_milli",
                     Json::num(s.predicted_cost_milli as f64)),
                    ("deadline_hits",
                     Json::num(r.gauges.deadline_hits
                               .load(Ordering::Relaxed) as f64)),
                    ("deadline_misses",
                     Json::num(r.gauges.deadline_misses
                               .load(Ordering::Relaxed) as f64)),
                    ("finished", Json::Bool(s.finished)),
                ])
            })
            .collect();
        let sheds = self.shed_by_slo();
        let shed_by_slo = Json::obj(
            Slo::ALL
                .iter()
                .map(|c| (c.name(), Json::num(sheds[c.index()] as f64)))
                .collect(),
        );
        let tiers = Json::obj(
            Slo::ALL
                .iter()
                .map(|c| {
                    let mut lh = LatencyHist::new();
                    for r in &self.replicas {
                        lh.merge_from(&r.gauges.lat_hist_by_slo[c.index()]);
                    }
                    (c.name(), hist_ms_json(&lh))
                })
                .collect(),
        );
        let mut pool = vec![
            ("replicas", Json::arr(replicas)),
            ("route", Json::str(self.route.name())),
            ("stealing", Json::Bool(self.stealing())),
            ("queued", Json::num(self.total_queued() as f64)),
            ("completed", Json::num(self.total_completed() as f64)),
            ("shed", Json::num(self.shed_count() as f64)),
            ("shed_by_slo", shed_by_slo),
            ("steals", Json::num(self.total_steals() as f64)),
            ("migrated", Json::num(self.total_migrated() as f64)),
            ("resumed", Json::num(self.total_resumed() as f64)),
            ("resume_steps_saved",
             Json::num(self.total_resume_steps_saved() as f64)),
            ("lazy_ratio", Json::num(self.overall_lazy())),
            ("cold_denied", Json::num(self.total_cold_denied() as f64)),
            ("rows_run", Json::num(self.total_rows_run() as f64)),
            ("rows_skipped", Json::num(self.total_rows_skipped() as f64)),
            ("rows_recovered",
             Json::num(self.total_rows_recovered() as f64)),
            // share of the pool's skipped rows the coupled gate would
            // not have skipped (the per-slot counterfactual)
            ("recovered_ratio",
             Json::num(self.total_rows_recovered() as f64
                       / self.total_rows_skipped().max(1) as f64)),
            // cache-served completions, counted apart from `completed`
            // so latency quantiles keep describing engine work only
            ("cache_hits", Json::num(self.total_cache_hits() as f64)),
            ("warm_hits", Json::num(self.total_warm_hits() as f64)),
            ("rows_warmed", Json::num(self.total_rows_warmed() as f64)),
            // capacity truthfulness: a panicked replica without a
            // supervisor shrinks the pool — report it, don't hide it
            ("provisioned", Json::num(self.replicas.len() as f64)),
            ("live_replicas",
             Json::num((self.replicas.len() - self.dead_replicas())
                       as f64)),
            ("dead_replicas", Json::num(self.dead_replicas() as f64)),
            ("restarts", Json::num(self.total_restarts() as f64)),
            ("breaker_trips",
             Json::num(self.total_breaker_trips() as f64)),
            ("write_timeouts",
             Json::num(self.total_write_timeouts() as f64)),
            ("brownout_stage",
             Json::num(self.brownout_stage() as f64)),
            ("deadline_hits",
             Json::num(self.total_deadline_hits() as f64)),
            ("deadline_misses",
             Json::num(self.total_deadline_misses() as f64)),
            ("slack_sheds", Json::num(self.slack_shed_count() as f64)),
            // priced queued backlog (milli-rows); the brownout signal
            // is max(total_queued, queue_equivalent(this))
            ("predicted_backlog",
             Json::num(self.total_predicted_cost_milli() as f64)),
            ("tiers", tiers),
        ];
        if let Some(cs) = self.cache_stats() {
            pool.push(("cache", Json::obj(vec![
                ("hits", Json::num(cs.hits as f64)),
                ("misses", Json::num(cs.misses as f64)),
                ("entries", Json::num(cs.entries as f64)),
                ("inserted", Json::num(cs.inserted as f64)),
                ("evicted", Json::num(cs.evicted as f64)),
                ("donors", Json::num(cs.donors as f64)),
                ("donated", Json::num(cs.donated as f64)),
                ("donor_rejected", Json::num(cs.donor_rejected as f64)),
            ])));
        }
        Json::obj(pool).to_string()
    }

    /// One-line JSON payload of the `TRACE` wire verb: the newest ring
    /// events per replica (up to `max_per_replica` each), decoded to
    /// named kinds. `recorded` is the replica's all-time event count —
    /// strictly larger than `events.len()` once the ring has wrapped,
    /// so a consumer can tell "quiet" from "overwritten". `enabled` is
    /// false (and every `events` empty) when the server runs without
    /// `--trace-out`/`--trace`.
    pub fn trace_json(&self, max_per_replica: usize) -> String {
        let replicas: Vec<Json> = self
            .replicas
            .iter()
            .map(|r| {
                let events: Vec<Json> = r
                    .tracer
                    .ring()
                    .map(|ring| ring.snapshot(max_per_replica))
                    .unwrap_or_default()
                    .into_iter()
                    .map(|ev| {
                        Json::obj(vec![
                            ("kind", Json::str(ev.kind.name())),
                            ("ts_us", Json::num(ev.ts_us as f64)),
                            ("dur_us", Json::num(ev.dur_us as f64)),
                            ("id", Json::num(ev.kind_id as f64)),
                            ("arg", Json::num(ev.arg as f64)),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("id", Json::num(r.id as f64)),
                    ("recorded",
                     Json::num(r.tracer.ring().map_or(0, |g| g.recorded())
                               as f64)),
                    ("events", Json::arr(events)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("enabled",
             Json::Bool(self.replicas.iter()
                 .any(|r| r.tracer.is_enabled()))),
            ("replicas", Json::arr(replicas)),
        ])
        .to_string()
    }

    /// Drain and stop every replica, returning the aggregated report.
    /// In-flight and queued trajectories finish first (drain semantics).
    ///
    /// With stealing armed and ≥2 replicas, shutdown drains *by
    /// migration*: all but the last replica are asked to evict their
    /// residents as snapshots (placed on still-open siblings) before
    /// their queues close, concentrating the tail of the run on fewer
    /// replicas instead of waiting for the slowest straggler — and
    /// exercising the same evict/admit path a crash or retag uses. A
    /// replica whose residents have nowhere to go re-admits them
    /// locally and finishes them itself; nothing is ever stranded.
    pub fn shutdown(&self) -> PoolReport {
        if self.rebalancer.is_some() && self.replicas.len() > 1 {
            let deadline = std::time::Instant::now()
                + std::time::Duration::from_millis(250);
            for r in &self.replicas[..self.replicas.len() - 1] {
                r.request_drain();
                // bounded wait: the worker clears the flag once the
                // sweep ran (a dead worker never does — don't hang)
                while r.draining()
                    && !r.finished()
                    && std::time::Instant::now() < deadline
                {
                    std::thread::sleep(
                        std::time::Duration::from_millis(1));
                }
                r.close();
            }
        }
        for r in &self.replicas {
            r.close();
        }
        let mut reports: Vec<_> =
            self.replicas.iter().map(|r| r.join_report()).collect();
        // steal/migration counters settle only once EVERY worker thread
        // has exited (gauge transfers run on thief worker threads, so a
        // victim's own exit can race the final `stolen` increment). All
        // threads are joined now — re-read the gauges so the reports can
        // never miss a migration and conservation stays exact.
        for (rep, h) in reports.iter_mut().zip(&self.replicas) {
            rep.steals = h.gauges.steals.load(Ordering::Relaxed);
            rep.stolen = h.gauges.stolen.load(Ordering::Relaxed);
            rep.migrated_out =
                h.gauges.migrated_out.load(Ordering::Relaxed);
            rep.migrated_in =
                h.gauges.migrated_in.load(Ordering::Relaxed);
            rep.restarts = h.gauges.restarts.load(Ordering::Relaxed);
            rep.breaker_trips =
                h.gauges.breaker_trips.load(Ordering::Relaxed);
            rep.deadline_hits =
                h.gauges.deadline_hits.load(Ordering::Relaxed);
            rep.deadline_misses =
                h.gauges.deadline_misses.load(Ordering::Relaxed);
        }
        PoolReport {
            replicas: reports,
            shed: self.shed_count(),
            shed_by_slo: self.shed_by_slo(),
            cache_hits: self.total_cache_hits(),
            slack_sheds: self.slack_shed_count(),
        }
    }
}

/// Quantile summary of one latency histogram, in milliseconds — the
/// shape shared by the per-replica `latency_ms` field and the pool
/// `tiers` breakdown of the `STATS` payload.
fn hist_ms_json(lh: &LatencyHist) -> Json {
    Json::obj(vec![
        ("count", Json::num(lh.count() as f64)),
        ("mean_ms", Json::num(lh.mean_us() / 1e3)),
        ("p50", Json::num(lh.quantile_ms(0.50))),
        ("p95", Json::num(lh.quantile_ms(0.95))),
        ("p99", Json::num(lh.quantile_ms(0.99))),
    ])
}

/// Effective-backlog cost of one replica under the lazy-aware policy.
pub fn lazy_cost(snap: &GaugeSnapshot) -> f64 {
    // clamp Γ: a replica that skipped everything so far still pays the
    // apply/embed/final overhead, so never discount below 5%
    snap.pending_steps as f64 * (1.0 - snap.lazy_ratio.clamp(0.0, 0.95))
}

/// Best-first replica order for one dispatch. Pure so policies are unit
/// testable without threads. Finished (drained or dead) replicas are
/// excluded up front: their snapshot cost of 0 would otherwise rank them
/// *first* under jsq/lazy, making every dispatch pay a futile `try_send`
/// against a closed queue before reaching a live replica. So are
/// replicas whose tier cannot honor the request's SLO class.
///
/// A replica also has to physically *fit* the request: `lanes` is the
/// request's lane count (2 under CFG), and a replica whose batch width
/// is narrower can never plan a round containing it — admitting it
/// anyway would wedge the worker in a no-progress spin (the request can
/// never be scheduled), so such replicas are filtered here and the
/// request sheds with a structured error instead. In particular a
/// `lat:b1` tier only serves `cfg_scale: 1.0` (single-lane) requests.
///
/// Best-effort requests use the configured route policy over every
/// eligible replica. SLO-tagged requests use tier preference instead:
/// matching-tier replicas first, then best-effort spill, each group
/// internally ordered by the SLO's own cost model (lazy-discounted
/// backlog for latency, batch width for throughput). An empty return
/// means no live replica can honor the request — the dispatcher sheds.
pub fn candidate_order(route: RoutePolicy, slo: Slo, lanes: usize,
                       snaps: &[GaugeSnapshot], rr: usize) -> Vec<usize> {
    let n = snaps.len();
    // breaker-open (or down-awaiting-respawn) replicas are excluded
    // like finished ones, but only here: the servability classifier
    // still counts them, so their sheds report as transient capacity
    // pressure rather than a permanent pool-shape mismatch
    let live: Vec<usize> = (0..n)
        .filter(|&i| {
            !snaps[i].finished
                && !snaps[i].breaker_open
                && snaps[i].admits(slo, lanes)
        })
        .collect();
    if slo == Slo::Besteffort {
        let mut idx = live;
        order_group_by_route(route, snaps, rr, &mut idx);
        return idx;
    }
    let (mut pref, mut spill): (Vec<usize>, Vec<usize>) =
        live.into_iter().partition(|&i| snaps[i].slo == slo);
    order_group_by_slo(slo, snaps, &mut pref);
    order_group_by_slo(slo, snaps, &mut spill);
    pref.extend(spill);
    pref
}

/// Order one candidate group under the configured route policy
/// (best-effort traffic).
fn order_group_by_route(route: RoutePolicy, snaps: &[GaugeSnapshot],
                        rr: usize, idx: &mut Vec<usize>) {
    match route {
        RoutePolicy::RoundRobin => {
            // rotate over the live set (identical to the old full-pool
            // rotation when nothing has finished)
            let k = idx.len();
            if k > 0 {
                idx.rotate_left(rr % k);
            }
        }
        RoutePolicy::Jsq => {
            idx.sort_by_key(|&i| (snaps[i].queued, i));
        }
        RoutePolicy::Lazy => {
            idx.sort_by(|&a, &b| {
                lazy_cost(&snaps[a])
                    .partial_cmp(&lazy_cost(&snaps[b]))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    // priced tie-break: when the step-count heuristic
                    // can't separate two replicas, the calendar-priced
                    // backlog (predicted rows actually to be executed,
                    // skip-adjusted per schedule position) can
                    .then_with(|| {
                        snaps[a]
                            .predicted_cost_milli
                            .cmp(&snaps[b].predicted_cost_milli)
                    })
                    .then_with(|| snaps[a].queued.cmp(&snaps[b].queued))
                    .then_with(|| a.cmp(&b))
            });
        }
    }
}

/// Order one candidate group by an SLO class's own cost model,
/// independent of the pool's route policy:
/// * latency — lowest lazy-discounted backlog first (the replica that
///   will start the request soonest), narrowest batch on ties (less
///   co-batched interference), then fewest queued, then index;
/// * throughput — widest batch first (most lanes per invocation), then
///   lowest lazy-discounted backlog, then index.
fn order_group_by_slo(slo: Slo, snaps: &[GaugeSnapshot],
                      idx: &mut Vec<usize>) {
    match slo {
        Slo::Latency => idx.sort_by(|&a, &b| {
            lazy_cost(&snaps[a])
                .partial_cmp(&lazy_cost(&snaps[b]))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| snaps[a].max_batch.cmp(&snaps[b].max_batch))
                // calendar-priced backlog separates replicas the step
                // heuristic and batch width both tie on
                .then_with(|| {
                    snaps[a]
                        .predicted_cost_milli
                        .cmp(&snaps[b].predicted_cost_milli)
                })
                .then_with(|| snaps[a].queued.cmp(&snaps[b].queued))
                .then_with(|| a.cmp(&b))
        }),
        Slo::Throughput => idx.sort_by(|&a, &b| {
            snaps[b]
                .max_batch
                .cmp(&snaps[a].max_batch)
                .then_with(|| {
                    lazy_cost(&snaps[a])
                        .partial_cmp(&lazy_cost(&snaps[b]))
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .then_with(|| a.cmp(&b))
        }),
        Slo::Besteffort => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(queued: usize, steps: usize, lazy: f64) -> GaugeSnapshot {
        GaugeSnapshot {
            queued,
            pending_steps: steps,
            lazy_ratio: lazy,
            finished: false,
            breaker_open: false,
            slo: Slo::Besteffort,
            max_batch: 8,
            predicted_cost_milli: 0,
        }
    }

    fn tiered(mut s: GaugeSnapshot, slo: Slo, max_batch: usize)
              -> GaugeSnapshot {
        s.slo = slo;
        s.max_batch = max_batch;
        s
    }

    /// Shorthand: single-lane best-effort request under the given route.
    fn order_be(route: RoutePolicy, snaps: &[GaugeSnapshot], rr: usize)
                -> Vec<usize> {
        candidate_order(route, Slo::Besteffort, 1, snaps, rr)
    }

    #[test]
    fn rr_rotates() {
        let s = vec![snap(0, 0, 0.0); 3];
        assert_eq!(order_be(RoutePolicy::RoundRobin, &s, 0), vec![0, 1, 2]);
        assert_eq!(order_be(RoutePolicy::RoundRobin, &s, 1), vec![1, 2, 0]);
        assert_eq!(order_be(RoutePolicy::RoundRobin, &s, 4), vec![1, 2, 0]);
    }

    #[test]
    fn jsq_picks_shortest() {
        let s = vec![snap(4, 80, 0.0), snap(1, 20, 0.0), snap(2, 40, 0.0)];
        assert_eq!(order_be(RoutePolicy::Jsq, &s, 0)[0], 1);
        // tie → lowest index (replicas 0 and 1 both queue 2), and the
        // rr cursor must not perturb jsq ordering
        let t = vec![snap(2, 0, 0.0), snap(2, 0, 0.0), snap(1, 0, 0.0)];
        assert_eq!(order_be(RoutePolicy::Jsq, &t, 7), vec![2, 0, 1]);
        assert_eq!(order_be(RoutePolicy::Jsq, &t, 0), vec![2, 0, 1]);
    }

    #[test]
    fn finished_replicas_are_excluded_from_candidates() {
        let mut s = vec![snap(0, 0, 0.0), snap(3, 60, 0.0), snap(1, 20, 0.0)];
        s[0].finished = true; // dead replica: snapshot cost 0 would
                              // otherwise win jsq/lazy outright
        assert_eq!(order_be(RoutePolicy::Jsq, &s, 0), vec![2, 1]);
        assert_eq!(order_be(RoutePolicy::Lazy, &s, 0), vec![2, 1]);
        // rr rotates over the live set only
        assert_eq!(order_be(RoutePolicy::RoundRobin, &s, 0), vec![1, 2]);
        assert_eq!(order_be(RoutePolicy::RoundRobin, &s, 1), vec![2, 1]);
        assert_eq!(order_be(RoutePolicy::RoundRobin, &s, 2), vec![1, 2]);
        // a fully-finished pool yields no candidates at all
        s[1].finished = true;
        s[2].finished = true;
        assert!(order_be(RoutePolicy::Jsq, &s, 0).is_empty());
        assert!(order_be(RoutePolicy::RoundRobin, &s, 3).is_empty());
    }

    #[test]
    fn lazy_discounts_backlog_by_gamma() {
        // replica 0: 100 steps at Γ=0.6 → cost 40
        // replica 1:  60 steps at Γ=0.0 → cost 60
        let s = vec![snap(5, 100, 0.6), snap(3, 60, 0.0)];
        assert_eq!(order_be(RoutePolicy::Lazy, &s, 0)[0], 0);
        // without laziness the same backlogs invert the choice
        let s = vec![snap(5, 100, 0.0), snap(3, 60, 0.0)];
        assert_eq!(order_be(RoutePolicy::Lazy, &s, 0)[0], 1);
    }

    #[test]
    fn slo_requests_prefer_matching_tier_then_spill() {
        // pool: 0 = latency B1, 1 = throughput B8, 2 = best-effort B4
        let s = vec![
            tiered(snap(0, 0, 0.0), Slo::Latency, 1),
            tiered(snap(0, 0, 0.0), Slo::Throughput, 8),
            tiered(snap(0, 0, 0.0), Slo::Besteffort, 4),
        ];
        // latency request: its own tier first, best-effort spill second,
        // the throughput replica excluded outright — regardless of route
        for route in [RoutePolicy::RoundRobin, RoutePolicy::Jsq,
                      RoutePolicy::Lazy] {
            assert_eq!(candidate_order(route, Slo::Latency, 1, &s, 3),
                       vec![0, 2], "route {}", route.name());
            assert_eq!(candidate_order(route, Slo::Throughput, 1, &s, 3),
                       vec![1, 2], "route {}", route.name());
        }
        // best-effort requests see every live replica
        assert_eq!(order_be(RoutePolicy::Jsq, &s, 0).len(), 3);
    }

    #[test]
    fn slo_spill_keeps_tier_preference_under_load() {
        // the latency replica is BUSIER than the best-effort spill
        // target, but tier preference is a hard ordering: spill is the
        // fallback, not a cost competitor (keeping latency traffic off
        // shared replicas while its tier can still absorb it)
        let s = vec![
            tiered(snap(3, 60, 0.0), Slo::Latency, 1),
            tiered(snap(0, 0, 0.0), Slo::Besteffort, 4),
        ];
        assert_eq!(candidate_order(RoutePolicy::Jsq, Slo::Latency, 1, &s, 0),
                   vec![0, 1]);
    }

    #[test]
    fn latency_tier_orders_by_lazy_discounted_backlog() {
        // two latency replicas: 0 has more raw steps but Γ=0.8 → cost
        // 20; 1 has fewer steps at Γ=0 → cost 40. The lazier one wins.
        let s = vec![
            tiered(snap(4, 100, 0.8), Slo::Latency, 1),
            tiered(snap(2, 40, 0.0), Slo::Latency, 1),
        ];
        assert_eq!(candidate_order(RoutePolicy::Jsq, Slo::Latency, 1, &s, 0),
                   vec![0, 1]);
    }

    #[test]
    fn throughput_tier_prefers_widest_batch() {
        let s = vec![
            tiered(snap(0, 0, 0.0), Slo::Throughput, 4),
            tiered(snap(0, 0, 0.0), Slo::Throughput, 16),
            tiered(snap(0, 0, 0.0), Slo::Throughput, 8),
        ];
        assert_eq!(
            candidate_order(RoutePolicy::Jsq, Slo::Throughput, 1, &s, 0),
            vec![1, 2, 0]
        );
        // equal widths fall back to lazy-discounted backlog
        let s = vec![
            tiered(snap(2, 80, 0.0), Slo::Throughput, 8),
            tiered(snap(2, 80, 0.9), Slo::Throughput, 8),
        ];
        assert_eq!(
            candidate_order(RoutePolicy::Jsq, Slo::Throughput, 1, &s, 0),
            vec![1, 0]
        );
    }

    #[test]
    fn requests_wider_than_a_replicas_batch_are_filtered() {
        // a CFG request occupies 2 lanes; a B1 replica can never plan a
        // round containing it — admitting it anyway would wedge the
        // worker in a no-progress spin, so it must not be a candidate
        let s = vec![
            tiered(snap(0, 0, 0.0), Slo::Latency, 1),
            tiered(snap(0, 0, 0.0), Slo::Besteffort, 4),
        ];
        // single-lane latency request: B1 tier first, spill second
        assert_eq!(candidate_order(RoutePolicy::Jsq, Slo::Latency, 1, &s, 0),
                   vec![0, 1]);
        // 2-lane latency request: only the B4 spill replica fits
        assert_eq!(candidate_order(RoutePolicy::Jsq, Slo::Latency, 2, &s, 0),
                   vec![1]);
        // 2-lane latency request against a B1-only pool: shed, not hang
        let only_b1 = vec![tiered(snap(0, 0, 0.0), Slo::Latency, 1)];
        assert!(candidate_order(RoutePolicy::Jsq, Slo::Latency, 2,
                                &only_b1, 0).is_empty());
        // best-effort traffic obeys the same fit rule
        assert_eq!(order_be(RoutePolicy::Jsq, &s, 0), vec![0, 1]);
        assert_eq!(candidate_order(RoutePolicy::Jsq, Slo::Besteffort, 2,
                                   &s, 0),
                   vec![1]);
    }

    #[test]
    fn incompatible_pool_yields_no_candidates() {
        // a latency request against a throughput-only pool sheds rather
        // than silently parking on a deep-batch replica
        let s = vec![
            tiered(snap(0, 0, 0.0), Slo::Throughput, 8),
            tiered(snap(0, 0, 0.0), Slo::Throughput, 8),
        ];
        assert!(candidate_order(RoutePolicy::Jsq, Slo::Latency, 1, &s, 0)
            .is_empty());
        // ...and dead matching-tier replicas don't resurrect routing
        let mut s = vec![tiered(snap(0, 0, 0.0), Slo::Latency, 1)];
        s[0].finished = true;
        assert!(candidate_order(RoutePolicy::Jsq, Slo::Latency, 1, &s, 0)
            .is_empty());
    }

    #[test]
    fn breaker_open_replicas_leave_the_rotation_but_stay_servable() {
        // an open breaker (or a down-awaiting-respawn slot) is excluded
        // from candidates exactly like `finished` — its snapshot cost of
        // 0 must not win jsq/lazy — but unlike `finished` the condition
        // is transient, so the filter is a separate flag
        let mut s =
            vec![snap(0, 0, 0.0), snap(3, 60, 0.0), snap(1, 20, 0.0)];
        s[0].breaker_open = true;
        assert_eq!(order_be(RoutePolicy::Jsq, &s, 0), vec![2, 1]);
        assert_eq!(order_be(RoutePolicy::RoundRobin, &s, 0), vec![1, 2]);
        // every compatible replica tripped → no candidates at all (the
        // dispatcher then sheds as CAPACITY, not unservable)
        s[1].breaker_open = true;
        s[2].breaker_open = true;
        assert!(order_be(RoutePolicy::Lazy, &s, 0).is_empty());
        // half-open probes are NOT excluded: the snapshot only raises
        // the flag for the fully-open state
        let g = super::super::replica::ReplicaGauges::default();
        g.breaker.store(super::super::replica::BREAKER_HALF_OPEN,
                        Ordering::Relaxed);
        let tier = crate::coordinator::pool::replica::ReplicaTier::default();
        assert!(!g.snapshot(&tier).breaker_open);
        g.breaker.store(super::super::replica::BREAKER_OPEN,
                        Ordering::Relaxed);
        assert!(g.snapshot(&tier).breaker_open);
    }

    #[test]
    fn lazy_cost_clamps_gamma() {
        let c = lazy_cost(&snap(1, 100, 1.0));
        assert!((c - 5.0).abs() < 1e-9, "Γ clamped to 0.95 → cost 5, got {c}");
    }

    #[test]
    fn priced_backlog_breaks_lazy_and_latency_ties() {
        // identical step-count heuristics: the calendar-priced backlog
        // decides, lower predicted cost first
        let mut s = vec![snap(2, 40, 0.5), snap(2, 40, 0.5)];
        s[0].predicted_cost_milli = 9_000;
        s[1].predicted_cost_milli = 4_000;
        assert_eq!(order_be(RoutePolicy::Lazy, &s, 0), vec![1, 0]);
        // ...but a genuine lazy_cost difference still dominates any
        // price gap: the refinement is strictly a tie-break
        s[1].pending_steps = 400;
        assert_eq!(order_be(RoutePolicy::Lazy, &s, 0), vec![0, 1]);
        // the latency SLO cost model refines the same way
        let mut t = vec![
            tiered(snap(1, 10, 0.0), Slo::Latency, 1),
            tiered(snap(1, 10, 0.0), Slo::Latency, 1),
        ];
        t[0].predicted_cost_milli = 5_000;
        assert_eq!(
            candidate_order(RoutePolicy::Jsq, Slo::Latency, 1, &t, 0),
            vec![1, 0]
        );
    }

    /// A PoolCalendar whose artifact prices a `steps`-step request at
    /// exactly `steps` rows (one row per step, nothing skipped).
    fn priced_calendar(steps: usize) -> super::super::PoolCalendar {
        use crate::coordinator::pool::calendar::{SkipCalendar, StepProfile};
        let mut prof = StepProfile::new();
        for s in 0..steps {
            prof.record(s, 1, 1);
        }
        let mut cal = SkipCalendar::new(0xfeed, "test");
        cal.insert_profile(steps, &prof, 1);
        super::super::PoolCalendar::new(Some(cal))
    }

    fn one_replica_router(cal: Arc<super::super::PoolCalendar>) -> Router {
        use crate::coordinator::pool::sim::{SimEngine, SimSpec};
        let h = crate::coordinator::pool::ReplicaHandle::spawn(
            0, 16, SimEngine::factory(SimSpec::fast()))
            .unwrap();
        Router::new(vec![h], RoutePolicy::Jsq, 16).with_calendar(cal)
    }

    #[test]
    fn no_slack_shed_attributes_reason_and_stays_inside_the_ledger() {
        use crate::coordinator::request::Request;
        let cal = Arc::new(priced_calendar(4));
        cal.set_us_per_inv(1_000.0); // 1ms per row → 4ms predicted
        let router = one_replica_router(cal);
        let (tx, rx) = mpsc::channel();
        let mut r = Request::new(0, 0, 4, 1);
        r.cfg_scale = 1.0;
        r.deadline_us = 1; // unmeetable: already in the past
        assert!(matches!(router.dispatch_outcome(r, tx),
                         DispatchOutcome::ShedNoSlack));
        assert_eq!(router.slack_shed_count(), 1);
        assert_eq!(router.shed_count(), 1, "slack sheds live inside shed");
        assert!(rx.recv().is_err(), "shed request must get no result");
        // uncalibrated time units disarm the check: the same hopeless
        // request is admitted rather than guessed at
        router.calendar().unwrap().set_us_per_inv(0.0);
        let (tx, rx) = mpsc::channel();
        let mut r = Request::new(0, 0, 4, 2);
        r.cfg_scale = 1.0;
        r.deadline_us = 1;
        assert!(matches!(router.dispatch_outcome(r, tx),
                         DispatchOutcome::Admitted));
        assert!(rx.recv().is_ok());
        assert_eq!(router.slack_shed_count(), 1);
        let rep = router.shutdown();
        // conservation: dispatched == completed + cache_hits + shed
        assert_eq!(router.total_dispatched(), 2);
        assert_eq!(rep.slack_sheds, 1);
        assert_eq!(
            router.total_dispatched(),
            router.total_completed() + router.total_cache_hits()
                + rep.shed + router.total_forfeited()
        );
    }

    #[test]
    fn latency_deadlines_default_from_the_calendar_and_settle() {
        use crate::coordinator::request::Request;
        let cal = Arc::new(priced_calendar(4));
        // 40ms predicted service → 320ms defaulted deadline: roomy
        // enough that a SimSpec::fast() request always hits it
        cal.set_us_per_inv(10_000.0);
        let router = one_replica_router(cal);
        let (tx, rx) = mpsc::channel();
        let mut r = Request::new(0, 0, 4, 3);
        r.cfg_scale = 1.0;
        r.slo = Slo::Latency; // best-effort replica admits as spill
        assert_eq!(r.deadline_us, 0, "wire default: no declared deadline");
        assert!(matches!(router.dispatch_outcome(r, tx),
                         DispatchOutcome::Admitted));
        assert!(rx.recv().is_ok());
        router.shutdown();
        // the defaulted deadline comfortably covers a SimSpec::fast()
        // request → settles as a hit, not "no deadline"
        assert_eq!(router.total_deadline_hits(), 1);
        assert_eq!(router.total_deadline_misses(), 0);
    }

    #[test]
    fn backlog_pressure_never_drops_below_queue_length() {
        let cal = Arc::new(super::super::PoolCalendar::online());
        let router = one_replica_router(cal.clone());
        // uncalibrated: exactly the legacy queue-length signal
        let g = &router.replica(0).unwrap().gauges;
        g.queued.fetch_add(7, Ordering::Relaxed);
        assert_eq!(router.backlog_pressure(), 7);
        // calibrate the shape EWMAs (4-step requests, 1 row/step, Γ=0),
        // then inflate the priced gauge: 80 predicted rows ÷ 4 rows per
        // request = 20 request-equivalents > 7 queued
        cal.observe_dispatch(4);
        cal.tick(0, 0, 0, 1, 1_000);
        cal.tick(400, 400, 100, 1, 2_000);
        g.predicted_cost_milli.fetch_add(80_000, Ordering::Relaxed);
        assert!(router.backlog_pressure() >= 20,
                "priced backlog must raise pressure, got {}",
                router.backlog_pressure());
        g.queued.fetch_add(93, Ordering::Relaxed); // 100 queued now
        assert_eq!(router.backlog_pressure(), 100,
                   "pressure is max(queued, priced), never less");
        router.shutdown();
    }
}
