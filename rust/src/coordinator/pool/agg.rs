//! Pool-wide aggregation: fold per-replica `LayerStats` / `ServeStats`
//! into one view for `cmd_serve` / `cmd_profile` reporting.
//!
//! The invariant the integration tests pin down: every pool-wide counter
//! is exactly the sum of the per-replica counters (Γ is the ratio of the
//! summed numerators/denominators, never an average of averages).

use crate::config::Slo;
use crate::coordinator::pool::replica::ReplicaReport;
use crate::coordinator::stats::{LayerStats, ServeStats};

/// Merge `b`'s per-(layer,module) counters into `a`, growing `a` if the
/// replicas ran different depths (possible under per-replica configs).
pub fn merge_layer_stats(a: &mut LayerStats, b: &LayerStats) {
    if b.skips.len() > a.skips.len() {
        a.skips.resize(b.skips.len(), 0);
        a.total.resize(b.total.len(), 0);
        a.s_sum.resize(b.s_sum.len(), 0.0);
    }
    if b.cold_denied.len() > a.cold_denied.len() {
        a.cold_denied.resize(b.cold_denied.len(), 0);
    }
    if b.rows_run.len() > a.rows_run.len() {
        a.rows_run.resize(b.rows_run.len(), 0);
        a.rows_skipped.resize(b.rows_skipped.len(), 0);
        a.rows_recovered.resize(b.rows_recovered.len(), 0);
    }
    if b.rows_warmed.len() > a.rows_warmed.len() {
        a.rows_warmed.resize(b.rows_warmed.len(), 0);
    }
    for k in 0..b.skips.len() {
        a.skips[k] += b.skips[k];
        a.total[k] += b.total[k];
        a.s_sum[k] += b.s_sum[k];
    }
    for k in 0..b.cold_denied.len() {
        a.cold_denied[k] += b.cold_denied[k];
    }
    for k in 0..b.rows_run.len() {
        a.rows_run[k] += b.rows_run[k];
        a.rows_skipped[k] += b.rows_skipped[k];
        a.rows_recovered[k] += b.rows_recovered[k];
    }
    for k in 0..b.rows_warmed.len() {
        a.rows_warmed[k] += b.rows_warmed[k];
    }
}

/// Merge `b`'s serving counters into `a`. Latency samples concatenate
/// and the histograms fold bucket-wise (so merged quantiles stay
/// histogram-backed); wall time takes the max (replicas run
/// concurrently, so summing walls would overstate elapsed time).
pub fn merge_serve_stats(a: &mut ServeStats, b: &ServeStats) {
    a.completed += b.completed;
    a.shed += b.shed;
    a.latencies_s.extend_from_slice(&b.latencies_s);
    a.hist.merge_from(&b.hist);
    a.wall_s = a.wall_s.max(b.wall_s);
    a.module_invocations += b.module_invocations;
    a.module_skips += b.module_skips;
    a.rows_retained += b.rows_retained;
    a.rows_migrated += b.rows_migrated;
    a.resumed += b.resumed;
    a.resume_steps_saved += b.resume_steps_saved;
}

/// Final pool-wide accounting returned by `Router::shutdown`.
#[derive(Debug, Clone)]
pub struct PoolReport {
    /// Per-replica final reports, in pool-index order.
    pub replicas: Vec<ReplicaReport>,
    /// Requests shed by router admission control.
    pub shed: u64,
    /// Sheds per SLO class (`Slo::index()` order; sums to `shed`).
    pub shed_by_slo: [u64; Slo::COUNT],
    /// Requests the router answered straight from the exact-result
    /// cache (zero engine work — counted apart from `completed`, and
    /// a ledger term of the conservation law:
    /// `dispatched == completed + cache_hits + shed + forfeited`).
    pub cache_hits: u64,
    /// Requests shed at admission because no candidate replica could
    /// meet their deadline. A strict subset of `shed` — the
    /// conservation ledger already counts these there; this figure
    /// only attributes the reason.
    pub slack_sheds: u64,
}

impl PoolReport {
    /// Pool-wide per-(layer,module) laziness (sum of replica counters).
    pub fn merged_layer(&self) -> LayerStats {
        let mut out = LayerStats::default();
        for r in &self.replicas {
            merge_layer_stats(&mut out, &r.layer);
        }
        out
    }

    /// Pool-wide serving stats; `shed` includes router-level sheds.
    pub fn merged_serve(&self) -> ServeStats {
        let mut out = ServeStats::default();
        for r in &self.replicas {
            merge_serve_stats(&mut out, &r.serve);
        }
        out.shed += self.shed as usize;
        out
    }

    /// Pool-wide lazy ratio Γ: row-weighted when any replica recorded
    /// row-work, module-weighted otherwise (ratio of sums either way).
    pub fn overall_lazy(&self) -> f64 {
        self.merged_layer().row_overall_ratio()
    }

    /// Total completed requests.
    pub fn completed(&self) -> usize {
        self.replicas.iter().map(|r| r.serve.completed).sum()
    }

    /// Replicas that died (construction or round failure).
    pub fn failed(&self) -> usize {
        self.replicas.iter().filter(|r| r.error.is_some()).count()
    }

    /// Jobs replicas pulled from siblings (work stealing), pool-wide.
    pub fn total_steals(&self) -> u64 {
        self.replicas.iter().map(|r| r.steals).sum()
    }

    /// Jobs pulled *out of* replicas' queues, pool-wide. Conservation:
    /// every migration increments exactly one replica's `steals` and one
    /// replica's `stolen`, so the two totals are always equal.
    pub fn total_stolen(&self) -> u64 {
        self.replicas.iter().map(|r| r.stolen).sum()
    }

    /// Mid-flight trajectories evicted to siblings as snapshots,
    /// pool-wide (drain, relief, crash resume).
    pub fn total_migrated_out(&self) -> u64 {
        self.replicas.iter().map(|r| r.migrated_out).sum()
    }

    /// Snapshots received from siblings, pool-wide. Equals
    /// `total_migrated_out` unless a replica died before admitting a
    /// snapshot already pushed to its queue.
    pub fn total_migrated_in(&self) -> u64 {
        self.replicas.iter().map(|r| r.migrated_in).sum()
    }

    /// Trajectories resumed from a snapshot, pool-wide (includes local
    /// re-admissions when a drain found no taker).
    pub fn total_resumed(&self) -> u64 {
        self.replicas.iter().map(|r| r.serve.resumed).sum()
    }

    /// Denoise steps resuming saved vs. restarting from step 0,
    /// pool-wide.
    pub fn total_resume_steps_saved(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.serve.resume_steps_saved)
            .sum()
    }

    /// Module invocations pool-wide whose skip was denied by a cold
    /// (freshly-joined) row — inherent cold work under row-granular
    /// gating (the coupled gate additionally dragged whole batches).
    pub fn total_cold_denied(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.layer.cold_denied_total())
            .sum()
    }

    /// Live rows run pool-wide (row-weighted work).
    pub fn total_rows_run(&self) -> u64 {
        self.replicas.iter().map(|r| r.layer.rows_run_total()).sum()
    }

    /// Live rows served from cache pool-wide.
    pub fn total_rows_skipped(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.layer.rows_skipped_total())
            .sum()
    }

    /// Rows only row-granular gating could skip, pool-wide.
    pub fn total_rows_recovered(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.layer.rows_recovered_total())
            .sum()
    }

    /// Requests admitted warm-started pool-wide (a donor trajectory
    /// actually seeded lane-cache rows at admission).
    pub fn total_warm_hits(&self) -> u64 {
        self.replicas.iter().map(|r| r.warm_hits).sum()
    }

    /// Lane-cache rows seeded from warm-start donors pool-wide — each
    /// one a cold denial the joiner did not pay.
    pub fn total_rows_warmed(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.layer.rows_warmed_total())
            .sum()
    }

    /// Worker respawns pool-wide (supervised slots only).
    pub fn total_restarts(&self) -> u64 {
        self.replicas.iter().map(|r| r.restarts).sum()
    }

    /// Circuit-breaker trips pool-wide.
    pub fn total_breaker_trips(&self) -> u64 {
        self.replicas.iter().map(|r| r.breaker_trips).sum()
    }

    /// Requests retired on or before their deadline, pool-wide.
    /// Requests without a deadline count in neither bucket.
    pub fn total_deadline_hits(&self) -> u64 {
        self.replicas.iter().map(|r| r.deadline_hits).sum()
    }

    /// Requests retired after their deadline, pool-wide.
    pub fn total_deadline_misses(&self) -> u64 {
        self.replicas.iter().map(|r| r.deadline_misses).sum()
    }

    /// Completions per SLO class (`Slo::index()` order): the sum of the
    /// per-replica counters, like every other pool-wide figure.
    pub fn completed_by_slo(&self) -> [u64; Slo::COUNT] {
        let mut out = [0u64; Slo::COUNT];
        for r in &self.replicas {
            for (o, c) in out.iter_mut().zip(r.completed_by_slo.iter()) {
                *o += c;
            }
        }
        out
    }

    /// Multi-line human summary: one line per replica (the A/B + tier
    /// view), the pool-wide roll-up, and a per-SLO-tier breakdown.
    pub fn render(&self) -> String {
        let mut out = String::from(
            "replica  tier        b   policy        served   Γ(lazy)   \
             mean lat   p99 lat   stole  lost\n",
        );
        for r in &self.replicas {
            let line = match &r.error {
                Some(e) => format!(
                    "  {:>2}     {:<10} {:>2}   {:<12}  FAILED: {e}\n",
                    r.id, r.tier.slo.name(), r.tier.max_batch, r.policy),
                None => format!(
                    "  {:>2}     {:<10} {:>2}   {:<12}  {:>6}   {:>6.1}%   \
                     {:>7.3}s  {:>7.3}s   {:>5}  {:>4}\n",
                    r.id,
                    r.tier.slo.name(),
                    r.tier.max_batch,
                    r.policy,
                    r.serve.completed,
                    100.0 * r.layer.row_overall_ratio(),
                    r.serve.mean_latency(),
                    r.serve.p99_latency(),
                    r.steals,
                    r.stolen,
                ),
            };
            out.push_str(&line);
        }
        let serve = self.merged_serve();
        out.push_str(&format!(
            "  pool                   {:>6}   {:>6.1}%   {:>7.3}s  {:>7.3}s   \
             ({} shed, {} stolen, {} cold-denied, rows {}/{} skipped, \
             {} recovered)\n",
            serve.completed,
            100.0 * self.overall_lazy(),
            serve.mean_latency(),
            serve.p99_latency(),
            serve.shed,
            self.total_steals(),
            self.total_cold_denied(),
            self.total_rows_skipped(),
            self.total_rows_skipped() + self.total_rows_run(),
            self.total_rows_recovered(),
        ));
        out.push_str(&format!(
            "  migration: {} out / {} in, {} resumed, {} steps saved\n",
            self.total_migrated_out(),
            self.total_migrated_in(),
            self.total_resumed(),
            self.total_resume_steps_saved(),
        ));
        // only when the cache did something: cache-less runs keep the
        // exact report shape older tooling parses
        if self.cache_hits > 0 || self.total_warm_hits() > 0 {
            out.push_str(&format!(
                "  cache: {} exact hits, {} warm starts, {} rows warmed\n",
                self.cache_hits,
                self.total_warm_hits(),
                self.total_rows_warmed(),
            ));
        }
        // only when deadlines were actually in play: deadline-free runs
        // keep the exact report shape older tooling parses
        let (dl_hits, dl_misses) =
            (self.total_deadline_hits(), self.total_deadline_misses());
        if dl_hits > 0 || dl_misses > 0 || self.slack_sheds > 0 {
            out.push_str(&format!(
                "  deadlines: {} hit, {} missed, {} slack-shed\n",
                dl_hits, dl_misses, self.slack_sheds,
            ));
        }
        // only when the supervisor actually intervened: clean runs keep
        // the exact report shape older tooling parses
        if self.total_restarts() > 0 || self.total_breaker_trips() > 0 {
            out.push_str(&format!(
                "  supervisor: {} restarts, {} breaker trips, {} dead\n",
                self.total_restarts(),
                self.total_breaker_trips(),
                self.failed(),
            ));
        }
        let done = self.completed_by_slo();
        out.push_str("  tiers (completed/shed):");
        for slo in Slo::ALL {
            out.push_str(&format!(
                "  {} {}/{}",
                slo.name(),
                done[slo.index()],
                self.shed_by_slo[slo.index()],
            ));
        }
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(depth: usize, skips: u64, total: u64) -> LayerStats {
        let mut l = LayerStats::new(depth);
        for k in 0..2 * depth {
            l.skips[k] = skips;
            l.total[k] = total;
            l.s_sum[k] = 0.5 * total as f64;
        }
        l
    }

    fn report(id: usize, depth: usize, skips: u64, total: u64,
              completed: usize) -> ReplicaReport {
        ReplicaReport {
            id,
            policy: "mean".to_string(),
            tier: crate::coordinator::pool::replica::ReplicaTier::default(),
            layer: layer(depth, skips, total),
            serve: ServeStats {
                completed,
                shed: 0,
                latencies_s: vec![0.1; completed],
                wall_s: 1.0 + id as f64,
                module_invocations: 2 * depth as u64 * total,
                module_skips: 2 * depth as u64 * skips,
                ..Default::default()
            },
            completed_by_slo: [0, 0, completed as u64],
            steals: 0,
            stolen: 0,
            migrated_out: 0,
            migrated_in: 0,
            warm_hits: 0,
            restarts: 0,
            breaker_trips: 0,
            deadline_hits: 0,
            deadline_misses: 0,
            arena: None,
            error: None,
        }
    }

    #[test]
    fn merged_counters_are_sums() {
        let pr = PoolReport {
            replicas: vec![report(0, 3, 10, 40, 4), report(1, 3, 30, 40, 6)],
            shed: 2,
            shed_by_slo: [0, 0, 2],
            cache_hits: 0,
            slack_sheds: 0,
        };
        let l = pr.merged_layer();
        assert_eq!(l.skips[0], 40);
        assert_eq!(l.total[0], 80);
        // Γ = (10+30)/(40+40) per slot = 0.5 — NOT avg(0.25, 0.75) by luck:
        // verify with asymmetric totals too
        assert!((pr.overall_lazy() - 0.5).abs() < 1e-12);
        let s = pr.merged_serve();
        assert_eq!(s.completed, pr.completed());
        assert_eq!(s.shed, 2);
        assert_eq!(s.latencies_s.len(), s.completed);
        assert!((s.wall_s - 2.0).abs() < 1e-12, "wall is max, not sum");
    }

    #[test]
    fn merged_histograms_back_the_pool_quantiles() {
        // two replicas with disjoint latency bands: the merged p99 must
        // come from the slow replica's band (bucket-wise hist fold), and
        // the merged count equals the sum
        let mut fast = report(0, 1, 0, 4, 100);
        fast.serve.latencies_s.clear();
        for _ in 0..100 {
            fast.serve.record_latency(0.010);
        }
        let mut slow = report(1, 1, 0, 4, 100);
        slow.serve.latencies_s.clear();
        for _ in 0..100 {
            slow.serve.record_latency(1.0);
        }
        let pr = PoolReport { replicas: vec![fast, slow], shed: 0,
                              shed_by_slo: [0; Slo::COUNT],
                              cache_hits: 0, slack_sheds: 0 };
        let s = pr.merged_serve();
        assert_eq!(s.hist.count(), 200);
        let p99 = s.p99_latency();
        assert!((p99 - 1.0).abs() / 1.0 <= 0.125, "merged p99 {p99}");
        let p50 = s.quantile_latency(0.5);
        assert!(p50 < 0.012, "merged p50 sits in the fast band: {p50}");
    }

    #[test]
    fn gamma_is_ratio_of_sums_not_average_of_ratios() {
        // replica 0: 9/10 skipped (Γ=0.9), replica 1: 0/90 (Γ=0.0)
        let pr = PoolReport {
            replicas: vec![report(0, 1, 9, 10, 1), report(1, 1, 0, 90, 9)],
            shed: 0,
            shed_by_slo: [0; Slo::COUNT],
            cache_hits: 0,
            slack_sheds: 0,
        };
        // ratio of sums: 18/200 per-pool = 0.09; average of averages 0.45
        assert!((pr.overall_lazy() - 0.09).abs() < 1e-12);
    }

    #[test]
    fn merge_grows_to_deeper_replica() {
        let mut a = LayerStats::new(1);
        a.record(0, true, 0.9);
        let b = layer(3, 2, 4);
        merge_layer_stats(&mut a, &b);
        assert_eq!(a.skips.len(), 6);
        assert_eq!(a.skips[0], 3);
        assert_eq!(a.skips[5], 2);
    }

    #[test]
    fn render_mentions_every_replica_and_pool() {
        let mut a = report(0, 2, 1, 4, 3);
        a.steals = 3;
        let mut b = report(1, 2, 3, 4, 5);
        b.stolen = 3;
        let pr = PoolReport { replicas: vec![a, b], shed: 1,
                              shed_by_slo: [0, 0, 1], cache_hits: 0, slack_sheds: 0 };
        let s = pr.render();
        assert!(s.contains("pool"));
        assert!(s.contains("mean"));
        assert!(s.contains(
            "(1 shed, 3 stolen, 0 cold-denied, rows 0/0 skipped, \
             0 recovered)"
        ), "{s}");
        assert!(s.contains("stole"), "steal column present: {s}");
        assert_eq!(pr.failed(), 0);
    }

    #[test]
    fn row_work_merges_as_sums_and_renders() {
        let mut a = report(0, 1, 0, 4, 1);
        a.layer.record_rows(0, 3, 5, 2);
        let mut b = report(1, 1, 0, 4, 1);
        b.layer.record_rows(1, 1, 3, 1);
        let pr = PoolReport { replicas: vec![a, b], shed: 0,
                              shed_by_slo: [0; Slo::COUNT],
                              cache_hits: 0, slack_sheds: 0 };
        assert_eq!(pr.total_rows_run(), 4);
        assert_eq!(pr.total_rows_skipped(), 8);
        assert_eq!(pr.total_rows_recovered(), 3);
        let merged = pr.merged_layer();
        assert_eq!(merged.rows_run, vec![3, 1]);
        assert_eq!(merged.rows_skipped, vec![5, 3]);
        assert_eq!(merged.rows_recovered, vec![2, 1]);
        // once rows exist, pool Γ is the row-weighted ratio of sums
        assert!((pr.overall_lazy() - 8.0 / 12.0).abs() < 1e-12);
        assert!(pr.render().contains("rows 8/12 skipped, 3 recovered"),
                "{}", pr.render());
    }

    #[test]
    fn cold_denied_aggregates_as_a_sum() {
        let mut a = report(0, 1, 0, 4, 1);
        a.layer.record_cold_denied(0);
        a.layer.record_cold_denied(1);
        let mut b = report(1, 1, 0, 4, 1);
        b.layer.record_cold_denied(1);
        let pr = PoolReport { replicas: vec![a, b], shed: 0,
                              shed_by_slo: [0; Slo::COUNT],
                              cache_hits: 0, slack_sheds: 0 };
        assert_eq!(pr.total_cold_denied(), 3);
        let merged = pr.merged_layer();
        assert_eq!(merged.cold_denied, vec![1, 2]);
        assert!(pr.render().contains("3 cold-denied"), "{}", pr.render());
    }

    #[test]
    fn per_tier_counters_sum_and_render() {
        use crate::coordinator::pool::replica::ReplicaTier;
        let mut a = report(0, 1, 0, 4, 5);
        a.tier = ReplicaTier::new(Slo::Latency, 1);
        a.completed_by_slo = [4, 0, 1];
        let mut b = report(1, 1, 0, 4, 7);
        b.tier = ReplicaTier::new(Slo::Throughput, 8);
        b.completed_by_slo = [0, 6, 1];
        let pr = PoolReport {
            replicas: vec![a, b],
            shed: 3,
            shed_by_slo: [1, 2, 0],
            cache_hits: 0,
            slack_sheds: 0,
        };
        assert_eq!(pr.completed_by_slo(), [4, 6, 2]);
        assert_eq!(pr.shed_by_slo.iter().sum::<u64>(), pr.shed);
        let s = pr.render();
        assert!(s.contains("latency"), "tier column present: {s}");
        assert!(s.contains("throughput"), "{s}");
        assert!(s.contains("tiers (completed/shed)"), "{s}");
        assert!(s.contains("latency 4/1"), "{s}");
        assert!(s.contains("throughput 6/2"), "{s}");
        assert!(s.contains("besteffort 2/0"), "{s}");
    }

    #[test]
    fn steal_totals_are_sums_and_conserved() {
        // steals/stolen aggregate exactly like every other pool counter:
        // the pool-wide value is the sum of the per-replica counters,
        // and migration conservation makes the two totals equal
        let mut a = report(0, 1, 0, 4, 4);
        a.steals = 2;
        a.stolen = 1;
        let mut b = report(1, 1, 0, 4, 4);
        b.steals = 1;
        b.stolen = 2;
        let pr = PoolReport { replicas: vec![a, b], shed: 0,
                              shed_by_slo: [0; Slo::COUNT],
                              cache_hits: 0, slack_sheds: 0 };
        assert_eq!(pr.total_steals(), 3);
        assert_eq!(pr.total_stolen(), 3);
        assert_eq!(pr.total_steals(), pr.total_stolen(),
                   "every migration has exactly one thief and one victim");
    }

    #[test]
    fn migration_totals_are_sums_and_render() {
        let mut a = report(0, 1, 0, 4, 4);
        a.migrated_out = 2;
        a.serve.resumed = 1;
        a.serve.resume_steps_saved = 3;
        let mut b = report(1, 1, 0, 4, 4);
        b.migrated_in = 2;
        b.serve.resumed = 2;
        b.serve.resume_steps_saved = 6;
        let pr = PoolReport { replicas: vec![a, b], shed: 0,
                              shed_by_slo: [0; Slo::COUNT],
                              cache_hits: 0, slack_sheds: 0 };
        assert_eq!(pr.total_migrated_out(), 2);
        assert_eq!(pr.total_migrated_in(), 2);
        assert_eq!(pr.total_resumed(), 3);
        assert_eq!(pr.total_resume_steps_saved(), 9);
        let s = pr.merged_serve();
        assert_eq!(s.resumed, 3);
        assert_eq!(s.resume_steps_saved, 9);
        assert!(pr.render().contains(
            "migration: 2 out / 2 in, 3 resumed, 9 steps saved"),
            "{}", pr.render());
    }

    #[test]
    fn supervisor_line_renders_only_after_interventions() {
        let mut a = report(0, 1, 0, 4, 4);
        a.restarts = 2;
        a.breaker_trips = 1;
        let mut b = report(1, 1, 0, 4, 0);
        b.error = Some("restart budget exhausted".to_string());
        b.restarts = 3;
        let pr = PoolReport { replicas: vec![a, b], shed: 0,
                              shed_by_slo: [0; Slo::COUNT],
                              cache_hits: 0, slack_sheds: 0 };
        assert_eq!(pr.total_restarts(), 5);
        assert_eq!(pr.total_breaker_trips(), 1);
        assert!(pr.render().contains(
            "supervisor: 5 restarts, 1 breaker trips, 1 dead"),
            "{}", pr.render());
        // an intervention-free run keeps the exact legacy report shape
        let quiet = PoolReport { replicas: vec![report(0, 1, 0, 4, 4)],
                                 shed: 0, shed_by_slo: [0; Slo::COUNT],
                                 cache_hits: 0, slack_sheds: 0 };
        assert!(!quiet.render().contains("supervisor:"),
                "{}", quiet.render());
    }

    #[test]
    fn deadline_line_renders_only_with_deadline_activity() {
        let mut a = report(0, 1, 0, 4, 4);
        a.deadline_hits = 3;
        a.deadline_misses = 1;
        let mut b = report(1, 1, 0, 4, 4);
        b.deadline_hits = 2;
        let pr = PoolReport { replicas: vec![a, b], shed: 2,
                              shed_by_slo: [0, 0, 2],
                              cache_hits: 0, slack_sheds: 1 };
        assert_eq!(pr.total_deadline_hits(), 5);
        assert_eq!(pr.total_deadline_misses(), 1);
        assert!(pr.render().contains(
            "deadlines: 5 hit, 1 missed, 1 slack-shed"),
            "{}", pr.render());
        // slack sheds stay inside the shed ledger term: the render
        // attributes, it never adds a new conservation bucket
        assert!(pr.slack_sheds <= pr.shed);
        // a deadline-free run keeps the exact legacy report shape
        let quiet = PoolReport { replicas: vec![report(0, 1, 0, 4, 4)],
                                 shed: 0, shed_by_slo: [0; Slo::COUNT],
                                 cache_hits: 0, slack_sheds: 0 };
        assert!(!quiet.render().contains("deadlines:"),
                "{}", quiet.render());
    }

    #[test]
    fn cache_line_renders_only_when_the_cache_did_something() {
        let mut a = report(0, 1, 0, 4, 4);
        a.warm_hits = 2;
        a.layer.record_rows_warmed(0, 3);
        let b = report(1, 1, 0, 4, 4);
        let pr = PoolReport { replicas: vec![a, b], shed: 0,
                              shed_by_slo: [0; Slo::COUNT],
                              cache_hits: 5, slack_sheds: 0 };
        assert_eq!(pr.total_warm_hits(), 2);
        assert_eq!(pr.total_rows_warmed(), 3);
        assert!(pr.render().contains(
            "cache: 5 exact hits, 2 warm starts, 3 rows warmed"),
            "{}", pr.render());
        // rows_warmed merges slot-wise like every other layer counter
        assert_eq!(pr.merged_layer().rows_warmed_total(), 3);
        // a cache-less run keeps the exact legacy report shape
        let quiet = PoolReport { replicas: vec![report(0, 1, 0, 4, 4)],
                                 shed: 0, shed_by_slo: [0; Slo::COUNT],
                                 cache_hits: 0, slack_sheds: 0 };
        assert!(!quiet.render().contains("cache:"), "{}", quiet.render());
    }
}
