//! Pool supervision: heartbeat-watched replica respawn with a restart
//! budget, plus the per-replica circuit breaker.
//!
//! The serve loop ticks a [`Supervisor`] every few milliseconds. Each
//! tick it walks the slots and drives two state machines off the live
//! gauges:
//!
//! * **Respawn** — a supervised worker that dies (panic, step error,
//!   engine-construction failure, poisoned stall) raises
//!   `needs_respawn` and leaves its queue OPEN. The supervisor waits
//!   out an exponential backoff (`backoff_base_ms · 2^restarts`), then
//!   calls [`ReplicaHandle::respawn`] — same queue, same gauges, same
//!   tier slot, so [`crate::coordinator::pool::steal::StealPeer`]
//!   registrations and router candidate order stay valid without any
//!   re-registration. Once `restart_budget` respawns are spent, the
//!   next fault retires the slot for good
//!   ([`ReplicaHandle::give_up`]), and the pool reports dead capacity
//!   instead of flapping forever.
//!
//! * **Breaker** — `breaker_open_after` consecutive faults trip the
//!   slot's breaker open (closed→open), removing it from the router's
//!   candidate rotation while servability classification still counts
//!   it (sheds report as transient capacity, not pool-shape mismatch).
//!   After `breaker_probe_ms` the breaker half-opens (probe traffic
//!   allowed); a fault while probing re-opens it, a healthy
//!   `breaker_close_after_ms` closes it and clears the fault streak.
//!   Every trip records a [`EventKind::BreakerTrip`] trace event and
//!   bumps the `breaker_trips` gauge.
//!
//! Stalls are detected by the [`StallDetector`]: the worker bumps a
//! heartbeat at every loop boundary, so a *busy* replica whose
//! heartbeat stops advancing is wedged — but a legitimately long batch
//! also goes quiet, so the threshold adapts to the largest
//! inter-heartbeat gap observed while healthy (3× that gap, floored at
//! `stall_after_ms`). A detected stall trips the breaker and poisons
//! the worker ([`crate::coordinator::pool::ReplicaGauges::poisoned`]):
//! threads cannot be killed, so the worker parks its residents into
//! its own queue and exits for respawn at its next loop boundary.
//!
//! Everything here takes `&ReplicaHandle` through the router — the
//! supervisor owns no replica state beyond its per-slot counters, so
//! it composes with stealing, tiering, caching, and tracing untouched.

use crate::coordinator::pool::cache::PoolCache;
use crate::coordinator::pool::replica::{ReplicaHandle, BREAKER_CLOSED,
                                        BREAKER_HALF_OPEN, BREAKER_OPEN};
use crate::coordinator::pool::router::Router;
use crate::coordinator::pool::steal::Rebalancer;
use crate::coordinator::pool::RespawnFactory;
use crate::obs::EventKind;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Supervision knobs (`lazydit serve --supervise on` uses the
/// defaults; see docs/SERVING.md for the failure-modes cookbook).
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Respawns allowed per slot before the supervisor gives up and
    /// retires it (dead capacity, reported — not hidden).
    pub restart_budget: u32,
    /// Backoff before the first respawn, in ms; doubles per respawn
    /// already spent on the slot.
    pub backoff_base_ms: u64,
    /// Heartbeat-silence floor (ms) before a busy replica counts as
    /// stalled. The effective threshold is `max(stall_after_ms, 3 ×
    /// largest healthy inter-heartbeat gap)` so long batches don't
    /// false-positive.
    pub stall_after_ms: u64,
    /// Consecutive faults that trip the circuit breaker open.
    pub breaker_open_after: u32,
    /// Open → half-open cooldown (ms): how long a tripped slot sits
    /// fully out of rotation before probe traffic is allowed.
    pub breaker_probe_ms: u64,
    /// Healthy half-open interval (ms) that closes the breaker and
    /// clears the consecutive-fault streak.
    pub breaker_close_after_ms: u64,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            restart_budget: 3,
            backoff_base_ms: 50,
            stall_after_ms: 500,
            breaker_open_after: 2,
            breaker_probe_ms: 250,
            breaker_close_after_ms: 500,
        }
    }
}

/// Heartbeat-stall detection for one replica, separable from the
/// supervisor so the stall-vs-long-batch distinction is unit testable
/// with manual clock ticks. Feed it `(heartbeat, busy, now_us)` every
/// supervisor tick; it answers "is this replica wedged?".
#[derive(Debug, Clone)]
pub struct StallDetector {
    stall_after_us: u64,
    last_hb: u64,
    last_advance_us: u64,
    max_gap_us: u64,
    primed: bool,
}

impl StallDetector {
    /// A detector with the given silence floor (ms).
    pub fn new(stall_after_ms: u64) -> StallDetector {
        StallDetector {
            stall_after_us: stall_after_ms.max(1) * 1000,
            last_hb: 0,
            last_advance_us: 0,
            max_gap_us: 0,
            primed: false,
        }
    }

    /// Observe one sample. Returns `true` when the replica is busy but
    /// its heartbeat has been silent for longer than the adaptive
    /// threshold — `max(stall_after, 3 × largest healthy gap)` — so a
    /// replica whose batches legitimately take 200 ms is not declared
    /// dead after 500 ms of one more long batch.
    pub fn observe(&mut self, hb: u64, busy: bool, now_us: u64) -> bool {
        if !self.primed {
            self.primed = true;
            self.last_hb = hb;
            self.last_advance_us = now_us;
            return false;
        }
        if hb != self.last_hb {
            let gap = now_us.saturating_sub(self.last_advance_us);
            if gap > self.max_gap_us {
                self.max_gap_us = gap;
            }
            self.last_hb = hb;
            self.last_advance_us = now_us;
            return false;
        }
        if !busy {
            // an idle worker still heartbeats every poll; a quiet one
            // with nothing admitted has nothing to be wedged ON
            return false;
        }
        let threshold = self.stall_after_us.max(3 * self.max_gap_us);
        now_us.saturating_sub(self.last_advance_us) > threshold
    }

    /// Re-arm after a respawn or a detected stall: the silence clock
    /// restarts now, the learned gap history is kept.
    pub fn reset(&mut self, now_us: u64) {
        self.last_advance_us = now_us;
        self.primed = true;
    }

    /// The adaptive stall threshold currently in effect (µs).
    pub fn threshold_us(&self) -> u64 {
        self.stall_after_us.max(3 * self.max_gap_us)
    }
}

/// Per-slot supervision state (counters the gauges don't own).
#[derive(Debug)]
struct Slot {
    restarts_used: u32,
    consec_faults: u32,
    /// Epoch-µs of the pending respawn; 0 = none scheduled.
    retry_at_us: u64,
    stall: StallDetector,
    breaker_since_us: u64,
    half_open_since_us: u64,
    gave_up: bool,
}

/// The pool supervisor. Owns one [`RespawnFactory`] and one [`Slot`]
/// per replica; the serve loop calls [`tick`](Self::tick) on a short
/// cadence with the current epoch-µs clock.
pub struct Supervisor {
    router: Arc<Router>,
    factories: Vec<RespawnFactory>,
    steal: Option<Arc<Rebalancer>>,
    cache: Option<Arc<PoolCache>>,
    cfg: SupervisorConfig,
    slots: Vec<Slot>,
}

impl Supervisor {
    /// Supervise `router`'s pool. `factories[i]` rebuilds replica `i`'s
    /// engine on respawn — pass the SAME rebalancer/cache the replicas
    /// were spawned with, so a respawned incarnation steals and caches
    /// exactly like its predecessor.
    pub fn new(router: Arc<Router>, factories: Vec<RespawnFactory>,
               steal: Option<Arc<Rebalancer>>,
               cache: Option<Arc<PoolCache>>,
               cfg: SupervisorConfig) -> Supervisor {
        assert_eq!(factories.len(), router.replica_count(),
                   "one respawn factory per replica");
        let slots = (0..factories.len())
            .map(|_| Slot {
                restarts_used: 0,
                consec_faults: 0,
                retry_at_us: 0,
                stall: StallDetector::new(cfg.stall_after_ms),
                breaker_since_us: 0,
                half_open_since_us: 0,
                gave_up: false,
            })
            .collect();
        Supervisor { router, factories, steal, cache, cfg, slots }
    }

    /// The supervised router (serve-loop convenience).
    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }

    /// Slots permanently retired (budget exhausted or respawn failed).
    pub fn given_up(&self) -> usize {
        self.slots.iter().filter(|s| s.gave_up).count()
    }

    /// One supervision pass at `now_us` (epoch µs). Walks every slot:
    /// schedules/executes respawns with exponential backoff, retires
    /// budget-exhausted slots, detects stalls, and drives the breaker
    /// open → half-open → closed recovery.
    pub fn tick(&mut self, now_us: u64) {
        for i in 0..self.slots.len() {
            let Some(h) = self.router.replica(i) else { continue };
            let slot = &mut self.slots[i];
            if slot.gave_up || h.gauges.finished.load(Ordering::Acquire) {
                continue;
            }
            if h.needs_respawn() {
                if slot.retry_at_us == 0 {
                    // a fresh fault: count it, maybe trip the breaker,
                    // and either schedule the backed-off respawn or
                    // retire the slot if the budget is spent
                    slot.consec_faults += 1;
                    if slot.consec_faults >= self.cfg.breaker_open_after {
                        trip_open(&self.router, h, slot, now_us);
                    }
                    if slot.restarts_used >= self.cfg.restart_budget {
                        log::warn!("replica {i}: restart budget \
                                    exhausted, retiring the slot");
                        h.give_up("restart budget exhausted");
                        slot.gave_up = true;
                        continue;
                    }
                    let backoff_ms = self.cfg.backoff_base_ms
                        << slot.restarts_used.min(10);
                    slot.retry_at_us = now_us + backoff_ms * 1000;
                } else if now_us >= slot.retry_at_us {
                    slot.retry_at_us = 0;
                    slot.restarts_used += 1;
                    if h.respawn(&self.factories[i], self.steal.clone(),
                                 self.cache.clone())
                        .is_err()
                    {
                        h.give_up("respawn failed");
                        slot.gave_up = true;
                        continue;
                    }
                    // a respawned flapper rejoins as a half-open probe,
                    // not at full dispatch weight
                    if h.gauges.breaker.load(Ordering::Relaxed)
                        == BREAKER_OPEN
                    {
                        h.gauges
                            .breaker
                            .store(BREAKER_HALF_OPEN, Ordering::Relaxed);
                        slot.half_open_since_us = now_us;
                    }
                    slot.stall.reset(now_us);
                }
                continue;
            }
            // alive: watch the heartbeat
            let busy = h.gauges.queued.load(Ordering::Relaxed) > 0;
            let hb = h.gauges.heartbeat.load(Ordering::Relaxed);
            if slot.stall.observe(hb, busy, now_us) {
                log::warn!("replica {i}: heartbeat stalled \
                            (threshold {} ms), poisoning",
                           slot.stall.threshold_us() / 1000);
                slot.consec_faults += 1;
                trip_open(&self.router, h, slot, now_us);
                // cooperative escape hatch: the worker parks its
                // residents and exits for respawn when (if) its engine
                // returns from the wedged round
                h.gauges.poisoned.store(true, Ordering::Release);
                slot.stall.reset(now_us);
                continue;
            }
            // breaker recovery: open → half-open probe → closed
            match h.gauges.breaker.load(Ordering::Relaxed) {
                s if s == BREAKER_OPEN => {
                    if now_us.saturating_sub(slot.breaker_since_us)
                        >= self.cfg.breaker_probe_ms * 1000
                    {
                        h.gauges
                            .breaker
                            .store(BREAKER_HALF_OPEN, Ordering::Relaxed);
                        slot.half_open_since_us = now_us;
                    }
                }
                s if s == BREAKER_HALF_OPEN => {
                    if now_us.saturating_sub(slot.half_open_since_us)
                        >= self.cfg.breaker_close_after_ms * 1000
                    {
                        h.gauges
                            .breaker
                            .store(BREAKER_CLOSED, Ordering::Relaxed);
                        slot.consec_faults = 0;
                    }
                }
                _ => {}
            }
        }
    }
}

/// Trip `h`'s breaker open (idempotent): gauge state, trip counter,
/// trace event, transition stamp.
fn trip_open(router: &Router, h: &ReplicaHandle, slot: &mut Slot,
             now_us: u64) {
    if h.gauges.breaker.load(Ordering::Relaxed) == BREAKER_OPEN {
        return;
    }
    h.gauges.breaker.store(BREAKER_OPEN, Ordering::Relaxed);
    let trips = h.gauges.breaker_trips.fetch_add(1, Ordering::Relaxed) + 1;
    slot.breaker_since_us = now_us;
    router.record_pool_event(EventKind::BreakerTrip, h.id as u64, trips);
    log::warn!("replica {}: circuit breaker OPEN (trip {trips})", h.id);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RoutePolicy;
    use crate::coordinator::pool::replica::ReplicaTier;
    use crate::coordinator::pool::sim::{SimEngine, SimSpec};
    use crate::coordinator::pool::{PoolEngine, PoolJob};
    use crate::coordinator::request::{Request, RequestResult};
    use crate::obs::{epoch_us, Tracer};
    use std::sync::atomic::AtomicUsize;
    use std::sync::mpsc;

    #[test]
    fn stall_detector_distinguishes_wedge_from_long_batch() {
        let mut d = StallDetector::new(500);
        let ms = |m: u64| m * 1000;
        // healthy history: heartbeats 200 ms apart while busy — the
        // detector learns this replica legitimately runs long batches
        let mut now = ms(1000);
        for hb in 1..=5u64 {
            assert!(!d.observe(hb, true, now));
            now += ms(200);
        }
        assert_eq!(d.threshold_us(), ms(600), "3 × observed 200 ms gap");
        // one more long batch: 550 ms of silence is within 3× history —
        // a fixed 500 ms cutoff would have false-positived here
        assert!(!d.observe(5, true, now + ms(550) - ms(200)));
        // genuine wedge: silence past the adaptive threshold
        assert!(d.observe(5, true, now + ms(700) - ms(200)));
        // idle silence is never a stall, no matter how long
        let mut quiet = StallDetector::new(500);
        quiet.observe(1, false, ms(0));
        assert!(!quiet.observe(1, false, ms(60_000)));
        // with no long-batch history the floor applies
        let mut fresh = StallDetector::new(500);
        fresh.observe(1, true, ms(0));
        assert!(!fresh.observe(1, true, ms(400)));
        assert!(fresh.observe(1, true, ms(600)));
    }

    /// One-replica supervised pool whose factory is scripted: the first
    /// `fail_first` constructions fail, the rest are healthy SimEngines.
    fn flaky_pool(fail_first: usize, cfg: SupervisorConfig)
                  -> (Arc<Router>, Supervisor) {
        let attempts = Arc::new(AtomicUsize::new(0));
        let factory: RespawnFactory = Arc::new(move || {
            if attempts.fetch_add(1, Ordering::SeqCst) < fail_first {
                anyhow::bail!("flaky artifacts");
            }
            (SimEngine::factory(SimSpec::fast()))()
        });
        let h = crate::coordinator::pool::ReplicaHandle::spawn_supervised(
            0, 16, &factory, None, ReplicaTier::default(),
            Tracer::disabled(), None)
            .unwrap();
        let router = Arc::new(Router::new(vec![h], RoutePolicy::Jsq, 64));
        let sup = Supervisor::new(router.clone(), vec![factory], None,
                                  None, cfg);
        (router, sup)
    }

    /// Tick the supervisor on the real clock until `done` or timeout.
    fn tick_until(sup: &mut Supervisor,
                  mut done: impl FnMut(&Router) -> bool) {
        let deadline = std::time::Instant::now()
            + std::time::Duration::from_secs(10);
        while !done(sup.router()) {
            assert!(std::time::Instant::now() < deadline,
                    "supervisor never converged");
            sup.tick(epoch_us());
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }

    #[test]
    fn restart_budget_exhaustion_retires_the_slot() {
        let cfg = SupervisorConfig {
            restart_budget: 2,
            backoff_base_ms: 1,
            breaker_open_after: 2,
            ..SupervisorConfig::default()
        };
        // the factory NEVER recovers: every incarnation dies at build
        let (router, mut sup) = flaky_pool(usize::MAX, cfg);
        tick_until(&mut sup, |r| r.dead_replicas() == 1);
        assert_eq!(sup.given_up(), 1);
        let h = router.replica(0).unwrap();
        assert_eq!(h.gauges.restarts.load(Ordering::Relaxed), 2,
                   "exactly the budget was spent");
        assert!(h.gauges.breaker_trips.load(Ordering::Relaxed) >= 1,
                "two consecutive faults tripped the breaker");
        let rep = h.join_report();
        assert_eq!(rep.error.as_deref(), Some("restart budget exhausted"));
        assert_eq!(rep.restarts, 2);
    }

    #[test]
    fn breaker_round_trips_closed_open_half_open_closed() {
        let cfg = SupervisorConfig {
            restart_budget: 5,
            backoff_base_ms: 1,
            breaker_open_after: 2,
            breaker_probe_ms: 5,
            breaker_close_after_ms: 5,
            ..SupervisorConfig::default()
        };
        // two construction failures, then healthy forever
        let (router, mut sup) = flaky_pool(2, cfg);
        let g = &router.replica(0).unwrap().gauges;
        assert_eq!(g.breaker.load(Ordering::Relaxed), BREAKER_CLOSED);
        // converge: the breaker must trip open on the second fault...
        tick_until(&mut sup, |r| {
            r.replica(0).unwrap()
                .gauges.breaker_trips.load(Ordering::Relaxed) >= 1
        });
        // ...and eventually close again once the slot turns healthy
        tick_until(&mut sup, |r| {
            let g = &r.replica(0).unwrap().gauges;
            !r.replica(0).unwrap().needs_respawn()
                && g.breaker.load(Ordering::Relaxed) == BREAKER_CLOSED
                && g.restarts.load(Ordering::Relaxed) == 2
        });
        // the recovered slot actually serves
        let h = router.replica(0).unwrap();
        let (tx, rx) = mpsc::channel();
        h.gauges.queued.fetch_add(1, Ordering::Relaxed);
        h.gauges.pending_steps.fetch_add(4, Ordering::Relaxed);
        h.try_send(PoolJob::fresh(Request::new(0, 3, 4, 9), tx, 0))
            .map_err(|_| "send")
            .unwrap();
        let res: RequestResult = rx.recv().unwrap();
        assert_eq!(res.steps, 4);
        assert_eq!(sup.given_up(), 0);
    }

    #[test]
    fn respawned_replica_resumes_bit_identically() {
        // the PR 7 crash-resume propcheck, extended across respawns: a
        // supervised 1-replica pool whose engine panics every 3rd round
        // finishes the trajectory over several incarnations (own-queue
        // re-queue → respawn → resume at cursor), and the image must be
        // bit-identical to an uninterrupted run — laziness decisions,
        // latent, lane caches all carried by the snapshots
        let spec = SimSpec::fast();
        let reference = {
            let mut e = SimEngine::new(spec.clone());
            let (tx, rx) = mpsc::channel();
            e.submit(Request::new(1, 3, 6, 42));
            loop {
                let done = e.step_round().unwrap();
                if let Some(r) = done.into_iter().next() {
                    tx.send(r).unwrap();
                    break;
                }
            }
            rx.recv().unwrap()
        };
        let panicky = SimSpec {
            faults: crate::coordinator::pool::FaultPlan::parse("panic@3")
                .unwrap()
                .for_replica(0),
            ..spec
        };
        let factory: RespawnFactory = Arc::new(move || {
            // every incarnation gets a FRESH schedule: it panics at its
            // own 3rd round, so the trajectory advances 2 steps per life
            Ok(Box::new(SimEngine::new(panicky.clone()))
               as Box<dyn PoolEngine>)
        });
        let h = crate::coordinator::pool::ReplicaHandle::spawn_supervised(
            0, 16, &factory, None, ReplicaTier::default(),
            Tracer::disabled(), None)
            .unwrap();
        let router = Arc::new(Router::new(vec![h], RoutePolicy::Jsq, 64));
        let cfg = SupervisorConfig {
            restart_budget: 10,
            backoff_base_ms: 1,
            ..SupervisorConfig::default()
        };
        let mut sup = Supervisor::new(router.clone(), vec![factory],
                                      None, None, cfg);
        let (tx, rx) = mpsc::channel();
        assert!(router.dispatch(Request::new(0, 3, 6, 42), tx));
        let res = loop {
            match rx.try_recv() {
                Ok(r) => break r,
                Err(mpsc::TryRecvError::Empty) => {
                    sup.tick(epoch_us());
                    std::thread::sleep(
                        std::time::Duration::from_millis(2));
                }
                Err(e) => panic!("trajectory lost across respawns: {e}"),
            }
        };
        let g = &router.replica(0).unwrap().gauges;
        assert!(g.restarts.load(Ordering::Relaxed) >= 1,
                "the engine must actually have died at least once");
        assert_eq!(res.steps, 6);
        assert_eq!(res.image.data(), reference.image.data(),
                   "resume across respawns must be bit-identical");
        assert_eq!(res.per_module_skip, reference.per_module_skip,
                   "per-boundary skip decisions must survive respawns");
        assert_eq!(res.lazy_ratio, reference.lazy_ratio);
        assert_eq!(router.total_forfeited(), 0, "nothing forfeited");
    }
}
