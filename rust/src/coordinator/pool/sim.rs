//! Deterministic synthetic engine: the pool's test/bench substrate.
//!
//! `SimEngine` mimics the real engine's serving contract — per-request
//! multi-step trajectories, per-module skip accounting, `LayerStats` /
//! `ServeStats` bookkeeping — without artifacts or the XLA runtime.
//! Executed modules burn a calibrated amount of CPU, so pool scaling and
//! lazy-aware routing are *measurable*; skipped modules cost nothing,
//! so a replica's lazy ratio shows up in wall-clock exactly as in the
//! real system.
//!
//! Determinism contract (pinned by `tests/integration_pool.rs`): the
//! output image is a pure function of `(seed, label, steps)` — identical
//! bytes regardless of replica count, routing policy, or co-batched
//! requests. Skip decisions are a pure function of `(step, module slot)`
//! per trajectory (the row-granular default). The opt-in
//! [`SimSpec::coupled`] mode models the legacy all-or-nothing batch
//! gate instead — there skip decisions depend on who is co-batched
//! (that is the waste being measured) while images stay deterministic.

use crate::coordinator::pool::calendar::StepProfile;
use crate::coordinator::pool::fault::{corrupt_snapshot, FaultSchedule};
use crate::coordinator::pool::{EngineFactory, PoolEngine};
use crate::coordinator::request::{Request, RequestResult, TrajectorySnapshot};
use crate::coordinator::stats::{LayerStats, ServeStats};
use crate::obs::ring::{pack_module_arg, pack_pair};
use crate::obs::{EventKind, TraceEvent, Tracer};
use crate::tensor::Tensor;
use crate::util::prng::Rng;
use anyhow::Result;
use std::time::Instant;

/// Synthetic-engine parameters.
#[derive(Debug, Clone)]
pub struct SimSpec {
    /// Transformer depth analog (2·depth module slots).
    pub depth: usize,
    /// Output image elements.
    pub img_elems: usize,
    /// Target lazy ratio in percent (0 = never skip).
    pub lazy_pct: u32,
    /// Spin iterations per *executed* module (per request, per step).
    pub work_per_module: u64,
    /// Policy label reported for pool A/B views.
    pub policy: String,
    /// Model the legacy all-or-nothing batch gate: a slot skips only
    /// when *every* active trajectory is warm and wants the skip — one
    /// cold joiner denies the whole batch. `false` (the default)
    /// mirrors the real engine's row-granular gate: each trajectory
    /// skips on its own, and skips taken while the batch was not
    /// uniformly skippable count as recovered rows.
    pub coupled: bool,
    /// Fault schedule this engine consults natively at every round
    /// boundary (empty = the default no-op fast path). Compiled from a
    /// [`crate::coordinator::pool::fault::FaultPlan`]; a respawned
    /// engine built from the same spec relives the same timeline.
    pub faults: FaultSchedule,
}

impl Default for SimSpec {
    fn default() -> Self {
        SimSpec {
            depth: 4,
            img_elems: 48,
            lazy_pct: 50,
            work_per_module: 4_000,
            policy: "sim".to_string(),
            coupled: false,
            faults: FaultSchedule::default(),
        }
    }
}

impl SimSpec {
    /// Cheap variant for unit tests.
    pub fn fast() -> SimSpec {
        SimSpec { work_per_module: 50, ..Default::default() }
    }

    /// Variant with a pinned lazy target and module cost — the building
    /// block of skewed-Γ pools (bench/tests): replicas sharing a
    /// workload but diverging in observed laziness, the regime where
    /// lazy-discounted work stealing beats admission-time placement.
    pub fn with_lazy(lazy_pct: u32, work_per_module: u64) -> SimSpec {
        SimSpec {
            lazy_pct,
            work_per_module,
            policy: format!("sim-g{lazy_pct}"),
            ..Default::default()
        }
    }
}

/// One in-flight synthetic trajectory.
struct SimActive {
    req: Request,
    cursor: usize,
    skip_counts: Vec<u32>,
    modules_seen: Vec<u32>,
    /// Admission stamp on the shared [`crate::obs::epoch_us`] clock —
    /// portable across replicas, so a migrated trajectory's end-to-end
    /// latency is attributed (once, in full) to the finishing replica.
    admitted_us: u64,
    /// Warm-start horizon: steps `< warm_until` are treated as warm
    /// even at cursor 0, modeling lane caches seeded from a donor
    /// trajectory (`submit_warm`). 0 = admitted cold, the default.
    warm_until: usize,
}

/// The synthetic engine. Single-threaded like the real one; a pool
/// replica owns exactly one.
pub struct SimEngine {
    /// The parameters this engine was built with.
    pub spec: SimSpec,
    /// Per-(layer,module) laziness accounting.
    pub layer_stats: LayerStats,
    /// Serving-level accounting.
    pub serve_stats: ServeStats,
    active: Vec<SimActive>,
    next_id: u64,
    /// Telemetry sink (disabled by default; a traced replica installs
    /// its own via [`PoolEngine::install_tracer`]).
    tracer: Tracer,
    /// Brownout Γ boost in percentage points (stacked on
    /// `spec.lazy_pct`, saturated at 95 so step 0's cold gate and a
    /// sliver of executed rows always remain).
    gamma_boost: u32,
    /// Per-step-index run/seen row counters — the calibration feed for
    /// `lazydit calibrate` ([`PoolEngine::step_profile`]).
    step_profile: StepProfile,
}

impl SimEngine {
    /// Build an engine with the given parameters.
    pub fn new(spec: SimSpec) -> SimEngine {
        let depth = spec.depth;
        SimEngine {
            spec,
            layer_stats: LayerStats::new(depth),
            serve_stats: ServeStats::default(),
            active: Vec::new(),
            next_id: 1,
            tracer: Tracer::disabled(),
            gamma_boost: 0,
            step_profile: StepProfile::new(),
        }
    }

    /// A `Send` factory for `ReplicaHandle::spawn`.
    pub fn factory(spec: SimSpec) -> EngineFactory {
        Box::new(move || Ok(Box::new(SimEngine::new(spec)) as Box<dyn PoolEngine>))
    }

    /// The lazy target currently in force: the configured percentage
    /// plus any brownout boost, saturated at 95.
    fn effective_lazy_pct(&self) -> u32 {
        (self.spec.lazy_pct + self.gamma_boost).min(95)
    }

    /// Would the gates skip (step, module slot)? Pure lazy-target draw,
    /// before the cache gate.
    fn would_skip(&self, step: usize, k: usize) -> bool {
        mix(step as u64, k as u64) % 100 < self.effective_lazy_pct() as u64
    }

    /// Deterministic skip decision for (step, module slot). Step 0 never
    /// skips (no cache yet), mirroring the real engine's cache gate; a
    /// step-0 would-skip counts as a cold-row denial in `LayerStats`.
    fn wants_skip(&self, step: usize, k: usize) -> bool {
        step > 0 && self.would_skip(step, k)
    }
}

/// The synthetic output image: a pure function of (seed, label, steps).
pub fn sim_image(req: &Request, img_elems: usize) -> Tensor {
    let stream = req
        .seed
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (req.class_label as u64).rotate_left(17)
        ^ (req.steps as u64).rotate_left(41);
    let mut rng = Rng::new(stream);
    let mut v = vec![0.0f32; img_elems];
    rng.fill_normal(&mut v);
    Tensor::from_vec(&[img_elems], v).expect("sim image shape")
}

/// A synthetic trajectory as a portable snapshot. The simulator keeps
/// no latent or lane caches — its skip gate is a pure function of
/// (step, slot) — so the snapshot carries empty `z`/`caches` payloads
/// (explicitly tolerated by the codec) and a placeholder timestep
/// schedule whose *length* preserves `pending_steps()` semantics.
/// Counters and the admission stamp travel verbatim, which is exactly
/// what makes a resumed run indistinguishable from an uninterrupted
/// one: the gate re-derives every decision from the cursor.
fn sim_snapshot(a: &SimActive) -> TrajectorySnapshot {
    TrajectorySnapshot {
        req: a.req.clone(),
        timesteps: vec![0; a.req.steps],
        cursor: a.cursor,
        z: Vec::new(),
        caches: Vec::new(),
        skip_counts: a.skip_counts.clone(),
        modules_seen: a.modules_seen.clone(),
        admitted_us: a.admitted_us,
        steps_done: a.cursor,
    }
}

/// SplitMix64-style stateless mixer for skip decisions.
fn mix(a: u64, b: u64) -> u64 {
    let mut z = a
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(b.rotate_left(31))
        .wrapping_add(0xD1FF_051F);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Burn a deterministic amount of CPU (an executed module's cost).
fn spin(iters: u64) -> u64 {
    let mut acc = 0x9E37u64;
    for i in 0..iters {
        acc = acc.rotate_left(5).wrapping_add(i ^ 0xA5A5_A5A5);
    }
    std::hint::black_box(acc)
}

impl PoolEngine for SimEngine {
    fn submit(&mut self, mut req: Request) -> u64 {
        if req.id == 0 {
            req.id = self.next_id;
            self.next_id += 1;
        } else {
            self.next_id = self.next_id.max(req.id + 1);
        }
        let id = req.id;
        let slots = 2 * self.spec.depth;
        self.active.push(SimActive {
            req,
            cursor: 0,
            skip_counts: vec![0; slots],
            modules_seen: vec![0; slots],
            admitted_us: crate::obs::epoch_us(),
            warm_until: 0,
        });
        id
    }

    fn submit_warm(&mut self, req: Request, donor: &TrajectorySnapshot)
                   -> (u64, u64) {
        // family + boundary validation mirrors the real engine: a donor
        // that does not match the joiner admits it cold (always safe)
        let family_ok = donor.req.class_label == req.class_label
            && donor.req.steps == req.steps
            && donor.req.cfg_scale.to_bits() == req.cfg_scale.to_bits()
            && donor.lanes() == req.lanes();
        if !family_ok || donor.cursor == 0 {
            return (self.submit(req), 0);
        }
        let warm_until = donor.cursor.min(req.steps);
        let lanes = req.lanes() as u64;
        let id = self.submit(req);
        if let Some(a) = self.active.last_mut() {
            a.warm_until = warm_until;
        }
        // the simulator keeps no materialized caches — its gate is
        // (step, slot)-pure — so the seeded surface is modeled as one
        // row per (module slot, lane), same shape the real engine copies
        (id, (2 * self.spec.depth) as u64 * lanes)
    }

    fn active_ids(&self) -> Vec<u64> {
        self.active.iter().map(|a| a.req.id).collect()
    }

    fn evict_to_snapshot(&mut self, id: u64) -> Option<TrajectorySnapshot> {
        if self.spec.faults.corrupting() {
            // refuse *before* evicting: a corrupting transport must not
            // silently drop a live trajectory out of the engine
            return None;
        }
        let idx = self.active.iter().position(|a| a.req.id == id)?;
        let a = self.active.remove(idx);
        Some(sim_snapshot(&a))
    }

    fn admit_snapshot(&mut self, snap: TrajectorySnapshot) -> u64 {
        let id = snap.req.id;
        self.next_id = self.next_id.max(id.saturating_add(1));
        let slots = 2 * self.spec.depth;
        // counters travel with the trajectory; a depth-mismatched pool
        // (never built in practice) degrades to fresh counters rather
        // than corrupt indexing
        let fit = |mut v: Vec<u32>| {
            if v.len() != slots { v = vec![0; slots]; }
            v
        };
        self.serve_stats.resumed += 1;
        self.serve_stats.resume_steps_saved += snap.cursor as u64;
        self.active.push(SimActive {
            req: snap.req,
            cursor: snap.cursor,
            skip_counts: fit(snap.skip_counts),
            modules_seen: fit(snap.modules_seen),
            admitted_us: snap.admitted_us,
            warm_until: 0,
        });
        id
    }

    fn snapshot_request(&self, id: u64) -> Option<TrajectorySnapshot> {
        let snap = self.active
            .iter()
            .find(|a| a.req.id == id)
            .map(sim_snapshot)?;
        if self.spec.faults.corrupting() {
            // the stash refresh sees honest decode failures from here on
            return corrupt_snapshot(&snap);
        }
        Some(snap)
    }

    fn active_count(&self) -> usize {
        self.active.len()
    }

    fn pending_steps(&self) -> usize {
        self.active
            .iter()
            .map(|a| a.req.steps.saturating_sub(a.cursor))
            .sum()
    }

    fn step_round(&mut self) -> Result<Vec<RequestResult>> {
        // native fault injection, same semantics (and ordering: stall,
        // panic, burst) as the FaultEngine wrapper — one branch per
        // round when the schedule is empty
        let rf = self.spec.faults.begin_round();
        if rf.stall_ms > 0 {
            std::thread::sleep(
                std::time::Duration::from_millis(rf.stall_ms));
        }
        if rf.panic {
            panic!("injected fault: panic at round {}",
                   self.spec.faults.round());
        }
        if rf.burst {
            return Ok(Vec::new());
        }
        let t0 = Instant::now();
        let depth = self.spec.depth;
        let gamma = self.effective_lazy_pct() as f64 / 100.0;
        // a warm-started joiner (warm_until > 0) is not cold at step 0:
        // its lane caches were seeded at admission
        let any_cold = self
            .active
            .iter()
            .any(|a| a.cursor == 0 && a.warm_until == 0);
        let traced = self.tracer.is_enabled() && !self.active.is_empty();
        if traced {
            self.tracer.record_at(TraceEvent {
                kind: EventKind::BatchBuild,
                ts_us: self.tracer.now_us(),
                dur_us: 0,
                kind_id: 0,
                arg: pack_pair(self.active.len() as u32, 0),
            });
        }
        for k in 0..2 * depth {
            let slot_start = if traced { self.tracer.now_us() } else { 0 };
            let (mut t_run, mut t_skip) = (0u32, 0u32);
            // did every trajectory's gate want this skip? The coupled
            // gate skips only when that consensus holds AND nobody is
            // cold; the row-granular gate uses the same pair to count
            // recovered rows and to attribute coupled denials honestly
            // (a run caused by a *gate* disagreement is not cold waste)
            let all_want = !self.active.is_empty()
                && self.active.iter().all(|a| self.would_skip(a.cursor, k));
            let batch_skip = all_want && !any_cold;
            for ai in 0..self.active.len() {
                let step = self.active[ai].cursor;
                let want = self.would_skip(step, k);
                let warm = step > 0 || step < self.active[ai].warm_until;
                let skip = if self.spec.coupled {
                    batch_skip
                } else {
                    warm && want // own gate, behind the cache gate
                };
                self.active[ai].modules_seen[k] += 1;
                self.layer_stats.record(k, skip, gamma);
                self.serve_stats.module_invocations += 1;
                self.step_profile.record(step, (!skip) as u64, 1);
                if skip {
                    t_skip += 1;
                    self.active[ai].skip_counts[k] += 1;
                    self.serve_stats.module_skips += 1;
                    let recovered = !self.spec.coupled && !batch_skip;
                    self.layer_stats.record_rows(k, 0, 1, recovered as u64);
                    if step == 0 {
                        // this skip exists only because the trajectory
                        // was warm-started: a cold denial converted
                        self.layer_stats.record_rows_warmed(k, 1);
                    }
                } else {
                    t_run += 1;
                    self.layer_stats.record_rows(k, 1, 0, 0);
                    if want
                        && (!warm
                            || (self.spec.coupled && all_want && any_cold))
                    {
                        // the gates wanted to skip; a cold cache said
                        // run — this row's own on a fresh join, or (in
                        // coupled mode) a freshly-joined sibling's that
                        // dragged a batch whose gates all agreed
                        self.layer_stats.record_cold_denied(k);
                    }
                    spin(self.spec.work_per_module);
                }
            }
            if traced {
                // the slot is a run span if any row executed, a skip
                // span when every row came from cache
                self.tracer.record_at(TraceEvent {
                    kind: if t_run > 0 {
                        EventKind::ModuleRun
                    } else {
                        EventKind::ModuleSkip
                    },
                    ts_us: slot_start,
                    dur_us: self.tracer.now_us()
                        .saturating_sub(slot_start),
                    kind_id: k as u64,
                    arg: pack_module_arg(gamma, t_run, t_skip),
                });
            }
        }
        for a in &mut self.active {
            a.cursor += 1;
        }
        // retire finished trajectories
        let img_elems = self.spec.img_elems;
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].cursor >= self.active[i].req.steps {
                let a = self.active.remove(i);
                let latency = std::time::Duration::from_micros(
                    crate::obs::epoch_us().saturating_sub(a.admitted_us));
                let seen: u32 = a.modules_seen.iter().sum();
                let skipped: u32 = a.skip_counts.iter().sum();
                let attn_seen: u32 =
                    (0..depth).map(|l| a.modules_seen[2 * l]).sum();
                let attn_skip: u32 =
                    (0..depth).map(|l| a.skip_counts[2 * l]).sum();
                let ffn_seen: u32 =
                    (0..depth).map(|l| a.modules_seen[2 * l + 1]).sum();
                let ffn_skip: u32 =
                    (0..depth).map(|l| a.skip_counts[2 * l + 1]).sum();
                self.serve_stats.completed += 1;
                self.serve_stats.record_latency(latency.as_secs_f64());
                out.push(RequestResult {
                    id: a.req.id,
                    class_label: a.req.class_label,
                    steps: a.req.steps,
                    slo: a.req.slo,
                    image: sim_image(&a.req, img_elems),
                    lazy_ratio: skipped as f64 / seen.max(1) as f64,
                    attn_lazy_ratio: attn_skip as f64 / attn_seen.max(1) as f64,
                    ffn_lazy_ratio: ffn_skip as f64 / ffn_seen.max(1) as f64,
                    latency,
                    per_module_skip: (0..2 * depth)
                        .map(|k| a.skip_counts[k] as f64
                             / a.modules_seen[k].max(1) as f64)
                        .collect(),
                });
            } else {
                i += 1;
            }
        }
        self.serve_stats.wall_s += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    fn step_profile(&self) -> Option<&StepProfile> {
        Some(&self.step_profile)
    }

    fn layer_stats(&self) -> &LayerStats {
        &self.layer_stats
    }

    fn serve_stats(&self) -> &ServeStats {
        &self.serve_stats
    }

    fn policy_name(&self) -> String {
        self.spec.policy.clone()
    }

    fn install_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    fn set_gamma_boost(&mut self, boost: u32) {
        self.gamma_boost = boost;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_all(e: &mut SimEngine) -> Vec<RequestResult> {
        let mut out = Vec::new();
        while e.active_count() > 0 {
            out.extend(e.step_round().unwrap());
        }
        out
    }

    #[test]
    fn images_are_pure_functions_of_request() {
        let a = sim_image(&Request::new(1, 3, 10, 42), 32);
        let b = sim_image(&Request::new(99, 3, 10, 42), 32);
        assert_eq!(a.data(), b.data(), "id must not affect the image");
        let c = sim_image(&Request::new(1, 4, 10, 42), 32);
        assert_ne!(a.data(), c.data(), "label must affect the image");
        let d = sim_image(&Request::new(1, 3, 10, 43), 32);
        assert_ne!(a.data(), d.data(), "seed must affect the image");
    }

    #[test]
    fn trajectories_complete_with_expected_accounting() {
        let mut e = SimEngine::new(SimSpec::fast());
        e.submit(Request::new(0, 1, 6, 7));
        e.submit(Request::new(0, 2, 3, 8));
        assert_eq!(e.pending_steps(), 9);
        let res = run_all(&mut e);
        assert_eq!(res.len(), 2);
        assert_eq!(e.serve_stats.completed, 2);
        assert_eq!(e.pending_steps(), 0);
        // 9 request-steps × 8 module slots
        assert_eq!(e.serve_stats.module_invocations, 72);
        let total: u64 = e.layer_stats.total.iter().sum();
        assert_eq!(total, 72);
    }

    #[test]
    fn lazy_ratio_tracks_target() {
        let mut e = SimEngine::new(SimSpec {
            lazy_pct: 50,
            work_per_module: 0,
            ..SimSpec::default()
        });
        for s in 0..8 {
            e.submit(Request::new(0, s % 4, 40, s as u64));
        }
        run_all(&mut e);
        let gamma = e.layer_stats.overall_ratio();
        assert!((gamma - 0.5).abs() < 0.12,
                "Γ {gamma} should approximate 50% target");
        // zero-lazy engine never skips
        let mut never = SimEngine::new(SimSpec {
            lazy_pct: 0,
            work_per_module: 0,
            ..SimSpec::default()
        });
        never.submit(Request::new(0, 1, 10, 3));
        run_all(&mut never);
        assert_eq!(never.layer_stats.overall_ratio(), 0.0);
    }

    #[test]
    fn coupled_gate_denies_what_row_granularity_recovers() {
        // identical arrival schedule, both gate modes: a warm resident
        // plus a cold joiner every round. The coupled gate runs the
        // resident's modules whenever the joiner is cold; the
        // row-granular gate serves the resident from cache and counts
        // those skips as recovered.
        let run = |coupled: bool| {
            let mut e = SimEngine::new(SimSpec {
                lazy_pct: 90,
                work_per_module: 0,
                coupled,
                ..SimSpec::default()
            });
            e.submit(Request::new(0, 1, 6, 77));
            for round in 0..4 {
                e.submit(Request::new(0, 2, 1, 200 + round));
                e.step_round().unwrap();
            }
            while e.active_count() > 0 {
                e.step_round().unwrap();
            }
            e
        };
        let coupled = run(true);
        let rowg = run(false);
        let total = |e: &SimEngine| {
            e.layer_stats.rows_run_total() + e.layer_stats.rows_skipped_total()
        };
        assert_eq!(total(&coupled), total(&rowg),
                   "same schedule, same row-weighted work offered");
        assert!(rowg.layer_stats.rows_run_total()
                    < coupled.layer_stats.rows_run_total(),
                "row granularity must run strictly fewer rows ({} vs {})",
                rowg.layer_stats.rows_run_total(),
                coupled.layer_stats.rows_run_total());
        assert!(rowg.layer_stats.rows_recovered_total() > 0,
                "resident skips during cold rounds count as recovered");
        assert_eq!(coupled.layer_stats.rows_recovered_total(), 0,
                   "the coupled gate can never recover rows");
        // rows partition module invocations exactly (one row per
        // trajectory per invocation in the simulator)
        assert_eq!(total(&rowg), rowg.serve_stats.module_invocations);
    }

    #[test]
    fn skip_decisions_are_step_slot_deterministic() {
        let e = SimEngine::new(SimSpec::default());
        for step in 0..20 {
            for k in 0..8 {
                assert_eq!(e.wants_skip(step, k), e.wants_skip(step, k));
            }
            assert!(!e.wants_skip(0, step % 8), "step 0 never skips");
        }
    }

    #[test]
    fn traced_sim_records_batch_and_module_spans() {
        let mut e = SimEngine::new(SimSpec::fast());
        let tr = Tracer::enabled(0, 256);
        e.install_tracer(tr.clone());
        e.submit(Request::new(0, 1, 3, 9));
        run_all(&mut e);
        let evs = tr.ring().unwrap().snapshot(256);
        let count = |k: EventKind| {
            evs.iter().filter(|v| v.kind == k).count() as u64
        };
        // one BatchBuild per round, one module span per slot per round
        assert_eq!(count(EventKind::BatchBuild), 3);
        assert_eq!(count(EventKind::ModuleRun)
                       + count(EventKind::ModuleSkip),
                   e.serve_stats.module_invocations);
        // with a single trajectory a slot skip IS a row skip, so the
        // span kinds must partition exactly like the skip accounting
        assert_eq!(count(EventKind::ModuleSkip),
                   e.serve_stats.module_skips);
        assert!(count(EventKind::ModuleRun) > 0, "step 0 never skips");
        // an untraced engine is the default and records nothing
        let mut quiet = SimEngine::new(SimSpec::fast());
        quiet.submit(Request::new(0, 1, 2, 4));
        run_all(&mut quiet);
        assert!(!quiet.tracer.is_enabled());
    }

    #[test]
    fn resumed_trajectory_matches_uninterrupted_run() {
        // same request, two lives: one denoised start-to-finish on a
        // single engine, one evicted at a mid-flight step boundary,
        // pushed through the wire encoding, and resumed on a DIFFERENT
        // engine that also carries a cold co-batched joiner (so the
        // recovered-row gate is exercised on the resumed side too).
        // Results must be indistinguishable.
        let spec = || SimSpec { lazy_pct: 60, work_per_module: 0,
                                ..SimSpec::default() };
        let req = || Request::new(7, 3, 9, 0xC0FFEE);
        let mut solo = SimEngine::new(spec());
        solo.submit(req());
        let baseline = run_all(&mut solo).pop().unwrap();

        let mut victim = SimEngine::new(spec());
        victim.submit(req());
        for _ in 0..4 {
            victim.step_round().unwrap();
        }
        let snap = victim.evict_to_snapshot(7).expect("id 7 active");
        assert_eq!(victim.active_count(), 0);
        assert_eq!(snap.pending_steps(), 5);
        let bytes = snap.encode();
        let snap = TrajectorySnapshot::decode(&bytes).unwrap();

        let mut thief = SimEngine::new(spec());
        thief.submit(Request::new(0, 1, 2, 5)); // cold joiner
        assert_eq!(thief.admit_snapshot(snap), 7);
        assert_eq!(thief.serve_stats.resumed, 1);
        assert_eq!(thief.serve_stats.resume_steps_saved, 4);
        let resumed = run_all(&mut thief)
            .into_iter()
            .find(|r| r.id == 7)
            .unwrap();

        assert_eq!(baseline.image.data(), resumed.image.data(),
                   "image must be a pure function of the request");
        assert_eq!(baseline.lazy_ratio, resumed.lazy_ratio,
                   "skip decisions are (step, slot)-pure, so the \
                    resumed half must re-derive the identical gates");
        assert_eq!(baseline.per_module_skip, resumed.per_module_skip);
        // the resumed trajectory is warm while its co-batch is cold:
        // its skips count as recovered rows, same as any resident
        assert!(thief.layer_stats.rows_recovered_total() > 0,
                "warm resumed rows skipping beside a cold joiner must \
                 be accounted as recovered");
        // unknown ids evict nothing; eviction does not disturb others
        assert!(thief.evict_to_snapshot(999).is_none());
    }

    #[test]
    fn warm_start_converts_cold_denials_into_skips() {
        let spec = || SimSpec { lazy_pct: 80, work_per_module: 0,
                                ..SimSpec::default() };
        // donor: same family (label, steps, cfg), different seed,
        // evicted at step boundary 3
        let mut d = SimEngine::new(spec());
        let donor_id = d.submit(Request::new(0, 5, 8, 111));
        for _ in 0..3 {
            d.step_round().unwrap();
        }
        let donor = d.evict_to_snapshot(donor_id).unwrap();

        let run = |warm: Option<&TrajectorySnapshot>| {
            let mut e = SimEngine::new(spec());
            let req = Request::new(0, 5, 8, 222);
            match warm {
                Some(dn) => {
                    let (_, rows) = e.submit_warm(req, dn);
                    assert!(rows > 0, "valid donor must seed rows");
                }
                None => {
                    e.submit(req);
                }
            }
            let img = run_all(&mut e).pop().unwrap().image;
            (e, img)
        };
        let (cold, cold_img) = run(None);
        let (warm, warm_img) = run(Some(&donor));
        assert_eq!(cold_img.data(), warm_img.data(),
                   "warm start must never change the output");
        assert!(warm.layer_stats.rows_warmed_total() > 0,
                "step-0 would-skips convert under a seeded cache");
        assert!(warm.layer_stats.cold_denied_total()
                    < cold.layer_stats.cold_denied_total());
        assert_eq!(warm.layer_stats.cold_denied_total()
                       + warm.layer_stats.rows_warmed_total(),
                   cold.layer_stats.cold_denied_total(),
                   "every warmed row is exactly one converted denial");

        // rejected donors admit cold: family mismatch and no boundary
        let mut e = SimEngine::new(spec());
        let mut wrong = donor.clone();
        wrong.req.steps = 9;
        let (_, rows) = e.submit_warm(Request::new(0, 5, 8, 333), &wrong);
        assert_eq!(rows, 0, "family mismatch admits cold");
        let mut fresh = donor.clone();
        fresh.cursor = 0;
        let (_, rows) = e.submit_warm(Request::new(0, 5, 8, 334), &fresh);
        assert_eq!(rows, 0, "boundary-free donor admits cold");
        run_all(&mut e);
        assert_eq!(e.layer_stats.rows_warmed_total(), 0);
    }

    /// Warm-start fidelity: for any family, horizon, and lazy target,
    /// a warm-started run produces bit-identical output to the cold
    /// run; at horizon 0 the *entire* run (skip accounting included) is
    /// identical; and warmed rows exactly partition the cold run's
    /// denials.
    #[test]
    fn propcheck_warm_start_is_output_invariant_across_horizons() {
        use crate::util::propcheck::propcheck;
        propcheck(40, |g| {
            let steps = g.usize_in(1, 6);
            let spec = SimSpec {
                lazy_pct: g.usize_in(0, 95) as u32,
                work_per_module: 0,
                ..SimSpec::default()
            };
            let label = g.usize_in(0, 4);
            let donor_seed = g.u64();
            let joiner_seed = donor_seed.wrapping_add(1);
            let horizon = g.usize_in(0, steps);
            // the donor trajectory, evicted at the horizon boundary
            let mut d = SimEngine::new(spec.clone());
            let donor_id = d.submit(Request::new(0, label, steps,
                                                 donor_seed));
            for _ in 0..horizon {
                d.step_round().expect("donor step");
            }
            let donor = d.evict_to_snapshot(donor_id).unwrap();
            let drain = |e: &mut SimEngine| {
                let mut out = Vec::new();
                while e.active_count() > 0 {
                    out.extend(e.step_round().expect("sim step"));
                }
                out.pop().unwrap()
            };
            // cold reference vs warm-started joiner, same request
            let mut cold = SimEngine::new(spec.clone());
            cold.submit(Request::new(0, label, steps, joiner_seed));
            let cold_res = drain(&mut cold);
            let mut warm = SimEngine::new(spec.clone());
            let (_, rows) = warm.submit_warm(
                Request::new(0, label, steps, joiner_seed), &donor);
            let warm_res = drain(&mut warm);
            let bits = |t: &crate::tensor::Tensor| {
                t.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            };
            crate::prop_assert!(
                bits(&cold_res.image) == bits(&warm_res.image),
                "warm start changed the output (horizon {horizon})");
            if horizon == 0 {
                crate::prop_assert!(rows == 0,
                    "a boundary-free donor must be refused");
                crate::prop_assert!(
                    cold_res.per_module_skip == warm_res.per_module_skip,
                    "horizon 0 must be bit-identical to cold, \
                     skip accounting included");
                crate::prop_assert!(
                    warm.layer_stats.rows_warmed_total() == 0,
                    "horizon 0 warms nothing");
            }
            crate::prop_assert!(
                warm.layer_stats.cold_denied_total()
                    + warm.layer_stats.rows_warmed_total()
                    == cold.layer_stats.cold_denied_total(),
                "warmed rows must exactly partition the cold denials");
        });
    }

    #[test]
    fn native_faults_match_wrapper_semantics() {
        use crate::coordinator::pool::FaultPlan;
        let with_faults = |spec: &str| {
            let mut e = SimEngine::new(SimSpec {
                faults: FaultPlan::parse(spec).unwrap().for_replica(0),
                ..SimSpec::fast()
            });
            e.submit(Request::new(6, 1, 3, 4));
            e
        };
        // burst: zero progress, trajectory intact
        let mut burst = with_faults("burst@1=2");
        assert!(burst.step_round().unwrap().is_empty());
        assert!(burst.step_round().unwrap().is_empty());
        assert_eq!(burst.pending_steps(), 3, "burst makes zero progress");
        for _ in 0..3 {
            burst.step_round().unwrap();
        }
        assert_eq!(burst.active_count(), 0, "drains once the burst ends");
        // panic: unwinds out of step_round at its round
        let mut boom = with_faults("panic@2");
        boom.step_round().unwrap();
        assert!(std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| boom.step_round())).is_err());
        // corruption: stash goes stale, evict refuses without loss
        let mut rot = with_faults("corrupt@2");
        rot.step_round().unwrap();
        assert!(rot.snapshot_request(6).is_some(), "round 1 still clean");
        rot.step_round().unwrap();
        assert!(rot.snapshot_request(6).is_none());
        assert!(rot.evict_to_snapshot(6).is_none());
        assert_eq!(rot.active_count(), 1,
                   "a refused evict must not lose the trajectory");
    }

    #[test]
    fn gamma_boost_raises_observed_laziness_and_saturates() {
        let run_with_boost = |boost: u32| {
            let mut e = SimEngine::new(SimSpec {
                lazy_pct: 40,
                work_per_module: 0,
                ..SimSpec::default()
            });
            e.set_gamma_boost(boost);
            for s in 0..4 {
                e.submit(Request::new(0, s, 30, s as u64));
            }
            run_all(&mut e);
            e.layer_stats.overall_ratio()
        };
        let base = run_with_boost(0);
        let boosted = run_with_boost(30);
        assert!(boosted > base + 0.15,
                "a 30-point boost must visibly raise Γ ({base} → {boosted})");
        // the boost saturates: 90 + 50 caps at 95, never 100
        let e = {
            let mut e = SimEngine::new(SimSpec {
                lazy_pct: 90,
                ..SimSpec::fast()
            });
            e.set_gamma_boost(50);
            e
        };
        assert_eq!(e.effective_lazy_pct(), 95);
        // boost 0 restores the configured target exactly
        let mut back = SimEngine::new(SimSpec::fast());
        back.set_gamma_boost(20);
        back.set_gamma_boost(0);
        assert_eq!(back.effective_lazy_pct(), back.spec.lazy_pct);
    }

    #[test]
    fn snapshot_request_is_non_destructive() {
        let mut e = SimEngine::new(SimSpec::fast());
        e.submit(Request::new(11, 2, 5, 42));
        e.step_round().unwrap();
        let peek = e.snapshot_request(11).expect("active");
        assert_eq!(peek.cursor, 1);
        assert_eq!(e.active_count(), 1, "peeking must not evict");
        assert_eq!(e.active_ids(), vec![11]);
        assert!(e.snapshot_request(404).is_none());
        // the stash snapshot round-trips the codec like any other
        let back = TrajectorySnapshot::decode(&peek.encode()).unwrap();
        assert_eq!(back, peek);
    }

    #[test]
    fn ids_assigned_and_preserved() {
        let mut e = SimEngine::new(SimSpec::fast());
        let a = e.submit(Request::new(0, 0, 1, 0));
        let b = e.submit(Request::new(0, 0, 1, 1));
        assert!(b > a);
        let c = e.submit(Request::new(77, 0, 1, 2));
        assert_eq!(c, 77);
        let d = e.submit(Request::new(0, 0, 1, 3));
        assert_eq!(d, 78);
    }
}
