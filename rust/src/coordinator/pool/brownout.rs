//! Laziness-brownout: the pool-wide overload controller.
//!
//! Under sustained backlog or shed pressure the pool does not have to
//! choose between "full fidelity" and "drop the request" — LazyDiT's
//! own fidelity/compute dial gives it a middle path. The [`Brownout`]
//! controller walks a ladder of *declared* degradation stages, each
//! trading a little output quality for a lot of admission capacity:
//!
//! | stage | dial                                   | effect |
//! |-------|----------------------------------------|--------|
//! | 0     | none                                   | configured behavior |
//! | 1     | widen the warm-start horizon           | deeper donors admitted → more early steps skipped |
//! | 2     | raise target Γ (`set_gamma_boost`)     | engines skip more aggressively |
//! | 3     | cap best-effort request steps          | best-effort work shrinks at admission |
//!
//! Stages are cumulative (stage 3 keeps the stage-1/2 dials engaged)
//! and reversible: the controller steps **up one stage at a time**
//! after `engage_ticks` consecutive pressured ticks, and back **down
//! one stage** after `recover_ticks` consecutive calm ticks, with a
//! hold band between the two watermarks so it never flaps at the
//! boundary. Pressure is measured each tick as pool backlog relative
//! to capacity (`total_queued / (queue_cap × live replicas)` against
//! `hi_pct`/`lo_pct`) OR any shed since the previous tick — a pool
//! that is actively turning clients away is pressured regardless of
//! how its queue happens to look at sampling time.
//!
//! Degradation is *honest*: every transition records an
//! [`EventKind::Brownout`] trace event (arg = packed `(from, to)`),
//! the current stage is surfaced in `STATS` and echoed on every wire
//! response while non-zero, and the stage-3 step cap is applied at
//! dispatch **before** the result-cache lookup, so a degraded request
//! is keyed — and cached — as the degraded computation it actually
//! ran. Nothing silently pretends full fidelity.
//!
//! The controller is interior-atomic and shared (`Arc`): the serve
//! loop ticks it, the router consults [`Brownout::cap_steps`] inline
//! at dispatch, and `STATS` reads the gauges — no locks anywhere.

use crate::config::Slo;
use crate::coordinator::pool::cache::PoolCache;
use crate::coordinator::pool::router::Router;
use crate::obs::ring::pack_pair;
use crate::obs::EventKind;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// The stage at which the warm-start horizon widens.
pub const STAGE_HORIZON: usize = 1;
/// The stage at which the Γ boost engages.
pub const STAGE_GAMMA: usize = 2;
/// The stage at which best-effort steps are capped at admission.
pub const STAGE_STEP_CAP: usize = 3;

/// Brownout knobs (`lazydit serve --brownout on` uses the defaults;
/// docs/SERVING.md walks the ladder).
#[derive(Debug, Clone)]
pub struct BrownoutConfig {
    /// Engage watermark: a tick is *pressured* when pool backlog is at
    /// least this percent of total queue capacity (or anything shed
    /// since the last tick).
    pub hi_pct: usize,
    /// Recover watermark: a tick is *calm* when backlog is at most
    /// this percent and nothing shed. Between the watermarks the
    /// controller holds its stage.
    pub lo_pct: usize,
    /// Consecutive pressured ticks before stepping up one stage.
    pub engage_ticks: u32,
    /// Consecutive calm ticks before stepping down one stage.
    pub recover_ticks: u32,
    /// Stage-1 warm-horizon override (engaged when it exceeds the
    /// configured horizon; restored on recovery).
    pub horizon_widen: usize,
    /// Stage-2 Γ boost, in laziness percentage points.
    pub gamma_boost: u32,
    /// Stage-3 cap on best-effort request steps (≥ 1).
    pub besteffort_step_cap: usize,
    /// Highest stage the controller may reach (≤ 3).
    pub max_stage: usize,
}

impl Default for BrownoutConfig {
    fn default() -> Self {
        BrownoutConfig {
            hi_pct: 80,
            lo_pct: 30,
            engage_ticks: 3,
            recover_ticks: 10,
            horizon_widen: 4,
            gamma_boost: 5,
            besteffort_step_cap: 8,
            max_stage: STAGE_STEP_CAP,
        }
    }
}

/// The overload controller. Construct once, share via `Arc`, register
/// on the router with
/// [`Router::with_brownout_controller`], and tick from the serve loop.
pub struct Brownout {
    cfg: BrownoutConfig,
    cache: Option<Arc<PoolCache>>,
    /// The configured horizon stage 0 restores (captured at build so
    /// recovery never depends on reading back an overridden value).
    base_horizon: usize,
    stage: AtomicUsize,
    pressured_ticks: AtomicUsize,
    calm_ticks: AtomicUsize,
    transitions: AtomicU64,
    peak_stage: AtomicUsize,
    last_shed: AtomicU64,
}

impl Brownout {
    /// A controller at stage 0. Pass the pool's cache when one exists
    /// so stage 1 can widen its warm horizon; `None` leaves stage 1 a
    /// declared-but-inert step on the ladder.
    pub fn new(cfg: BrownoutConfig, cache: Option<Arc<PoolCache>>)
               -> Brownout {
        let base_horizon = cache
            .as_ref()
            .map_or(0, |c| c.config().warm_horizon);
        Brownout {
            cfg,
            cache,
            base_horizon,
            stage: AtomicUsize::new(0),
            pressured_ticks: AtomicUsize::new(0),
            calm_ticks: AtomicUsize::new(0),
            transitions: AtomicU64::new(0),
            peak_stage: AtomicUsize::new(0),
            last_shed: AtomicU64::new(0),
        }
    }

    /// The degradation stage currently in force (0 = none).
    pub fn stage(&self) -> usize {
        self.stage.load(Ordering::Relaxed)
    }

    /// Stage transitions taken so far (up and down).
    pub fn transitions(&self) -> u64 {
        self.transitions.load(Ordering::Relaxed)
    }

    /// Deepest stage reached over the controller's lifetime.
    pub fn peak_stage(&self) -> usize {
        self.peak_stage.load(Ordering::Relaxed)
    }

    /// The admission-time step budget for a request of class `slo`:
    /// unchanged below [`STAGE_STEP_CAP`] and for guaranteed classes,
    /// capped at `besteffort_step_cap` for best-effort work while the
    /// pool is at stage 3. The router applies this *before* the cache
    /// lookup so degraded requests are cached under degraded keys.
    pub fn cap_steps(&self, slo: Slo, steps: usize) -> usize {
        if slo == Slo::Besteffort && self.stage() >= STAGE_STEP_CAP {
            steps.min(self.cfg.besteffort_step_cap.max(1))
        } else {
            steps
        }
    }

    /// One controller pass: classify the tick (pressured / calm /
    /// hold), advance the hysteresis counters, and step the stage when
    /// a streak completes. Call on the serve-loop cadence.
    pub fn tick(&self, router: &Router) {
        let live = router
            .replica_count()
            .saturating_sub(router.dead_replicas());
        let capacity = router.queue_cap() * live;
        // calendar-aware pressure: raw queue length, raised (never
        // lowered) to the priced backlog in request-equivalents — a
        // queue of few-but-enormous requests registers the load its
        // item count hides. Identical to `total_queued()` when no
        // calendar is armed, so uncalendared pools are unaffected.
        let queued = router.backlog_pressure();
        let shed = router.shed_count();
        let shed_delta =
            shed.saturating_sub(self.last_shed.swap(shed, Ordering::Relaxed));
        let pressured = capacity == 0
            || shed_delta > 0
            || queued * 100 >= self.cfg.hi_pct * capacity;
        let calm = !pressured
            && queued * 100 <= self.cfg.lo_pct * capacity;
        let stage = self.stage();
        if pressured {
            self.calm_ticks.store(0, Ordering::Relaxed);
            let streak =
                self.pressured_ticks.fetch_add(1, Ordering::Relaxed) + 1;
            if streak >= self.cfg.engage_ticks as usize
                && stage < self.cfg.max_stage.min(STAGE_STEP_CAP)
            {
                self.pressured_ticks.store(0, Ordering::Relaxed);
                self.transition(stage + 1, router);
            }
        } else if calm {
            self.pressured_ticks.store(0, Ordering::Relaxed);
            let streak =
                self.calm_ticks.fetch_add(1, Ordering::Relaxed) + 1;
            if streak >= self.cfg.recover_ticks as usize && stage > 0 {
                self.calm_ticks.store(0, Ordering::Relaxed);
                self.transition(stage - 1, router);
            }
        } else {
            // the hold band: neither streak may carry across it
            self.pressured_ticks.store(0, Ordering::Relaxed);
            self.calm_ticks.store(0, Ordering::Relaxed);
        }
    }

    /// Jump straight to `stage` (clamped to the configured maximum),
    /// applying every dial and recording the transition — the bench's
    /// per-stage sweep and operator overrides use this; production
    /// traffic goes through [`tick`](Self::tick).
    pub fn force_stage(&self, stage: usize, router: &Router) {
        self.transition(stage.min(self.cfg.max_stage.min(STAGE_STEP_CAP)),
                        router);
    }

    /// Move to `to`, re-apply every stage dial, and record the
    /// transition (trace event + counters). Idempotent on `to == from`.
    fn transition(&self, to: usize, router: &Router) {
        let from = self.stage.swap(to, Ordering::Relaxed);
        if from == to {
            return;
        }
        if let Some(c) = &self.cache {
            c.set_warm_horizon(if to >= STAGE_HORIZON {
                self.base_horizon.max(self.cfg.horizon_widen)
            } else {
                self.base_horizon
            });
        }
        router.set_gamma_boost(if to >= STAGE_GAMMA {
            self.cfg.gamma_boost
        } else {
            0
        });
        self.transitions.fetch_add(1, Ordering::Relaxed);
        self.peak_stage.fetch_max(to, Ordering::Relaxed);
        router.record_pool_event(EventKind::Brownout, to as u64,
                                 pack_pair(from as u32, to as u32));
        log::warn!("brownout: stage {from} -> {to}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RoutePolicy;
    use crate::coordinator::pool::cache::CacheConfig;
    use crate::coordinator::pool::replica::ReplicaHandle;
    use crate::coordinator::pool::sim::{SimEngine, SimSpec};

    /// An idle 1-replica pool with queue_cap 10 whose pressure we dial
    /// by hand through the queued gauge — the controller only ever
    /// reads gauges, so this exercises the real decision path.
    fn idle_pool() -> Arc<Router> {
        let h = ReplicaHandle::spawn(0, 16,
                                     SimEngine::factory(SimSpec::fast()))
            .unwrap();
        Arc::new(Router::new(vec![h], RoutePolicy::Jsq, 10))
    }

    fn set_backlog(router: &Router, queued: usize) {
        let g = &router.replica(0).unwrap().gauges;
        let cur = g.queued.load(Ordering::Relaxed);
        if queued > cur {
            g.queued.fetch_add(queued - cur, Ordering::Relaxed);
        } else {
            g.queued.fetch_sub(cur - queued, Ordering::Relaxed);
        }
    }

    #[test]
    fn ladder_engages_and_recovers_with_hysteresis() {
        let router = idle_pool();
        let cache = Arc::new(PoolCache::new(CacheConfig::new(8, 2, 48)));
        let cfg = BrownoutConfig {
            engage_ticks: 3,
            recover_ticks: 4,
            horizon_widen: 6,
            gamma_boost: 5,
            besteffort_step_cap: 2,
            ..BrownoutConfig::default()
        };
        let b = Brownout::new(cfg, Some(cache.clone()));
        // sustained pressure: 9/10 queued ≥ 80% watermark
        set_backlog(&router, 9);
        b.tick(&router);
        b.tick(&router);
        assert_eq!(b.stage(), 0, "one tick short of the engage streak");
        b.tick(&router);
        assert_eq!(b.stage(), 1);
        assert_eq!(cache.warm_horizon(), 6, "stage 1 widened the horizon");
        for _ in 0..3 {
            b.tick(&router);
        }
        assert_eq!(b.stage(), 2);
        let g = &router.replica(0).unwrap().gauges;
        assert_eq!(g.gamma_boost.load(Ordering::Relaxed), 5,
                   "stage 2 raised target gamma on every replica");
        for _ in 0..3 {
            b.tick(&router);
        }
        assert_eq!(b.stage(), 3, "ladder tops out at the step-cap stage");
        for _ in 0..20 {
            b.tick(&router);
        }
        assert_eq!(b.stage(), 3, "max_stage is a ceiling");
        assert_eq!(b.cap_steps(Slo::Besteffort, 50), 2);
        assert_eq!(b.cap_steps(Slo::Latency, 50), 50,
                   "guaranteed classes are never degraded");
        // the hold band (between lo 30% and hi 80%) freezes the stage
        // and resets both streaks
        set_backlog(&router, 5);
        for _ in 0..50 {
            b.tick(&router);
        }
        assert_eq!(b.stage(), 3, "hold band never recovers");
        // calm: 0/10 backlog, no sheds → step DOWN one stage per streak
        set_backlog(&router, 0);
        for _ in 0..4 {
            b.tick(&router);
        }
        assert_eq!(b.stage(), 2);
        assert_eq!(b.cap_steps(Slo::Besteffort, 50), 50,
                   "the step cap lifts below stage 3");
        for _ in 0..8 {
            b.tick(&router);
        }
        assert_eq!(b.stage(), 0, "full recovery, one stage at a time");
        assert_eq!(g.gamma_boost.load(Ordering::Relaxed), 0,
                   "recovery restores the configured gamma");
        assert_eq!(cache.warm_horizon(), 2,
                   "recovery restores the configured horizon");
        assert_eq!(b.peak_stage(), 3);
        assert_eq!(b.transitions(), 6, "3 up + 3 down");
        router.shutdown();
    }

    #[test]
    fn shed_pressure_engages_even_with_an_empty_queue() {
        let router = idle_pool();
        let b = Brownout::new(BrownoutConfig {
            engage_ticks: 1,
            ..BrownoutConfig::default()
        }, None);
        // a shed burst between ticks is pressure regardless of backlog
        b.tick(&router); // baseline: records last_shed = 0
        assert_eq!(b.stage(), 0, "calm pool stays at stage 0");
        for _ in 0..3 {
            router.record_shed_for_test();
            b.tick(&router);
        }
        assert_eq!(b.stage(), 3, "every shedding tick escalated");
        router.shutdown();
    }

    #[test]
    fn force_stage_applies_dials_and_clamps() {
        let router = idle_pool();
        let cache = Arc::new(PoolCache::new(CacheConfig::new(8, 0, 48)));
        let b = Brownout::new(BrownoutConfig {
            max_stage: 2,
            horizon_widen: 3,
            ..BrownoutConfig::default()
        }, Some(cache.clone()));
        assert!(!cache.warm_enabled(), "horizon 0: warm tier off");
        b.force_stage(3, &router);
        assert_eq!(b.stage(), 2, "clamped to max_stage");
        assert_eq!(cache.warm_horizon(), 3,
                   "widening from 0 turns the warm tier on");
        assert!(cache.warm_enabled());
        assert_eq!(b.cap_steps(Slo::Besteffort, 50), 50,
                   "a pool capped at stage 2 never clips steps");
        b.force_stage(0, &router);
        assert_eq!(cache.warm_horizon(), 0, "configured horizon restored");
        assert_eq!(b.transitions(), 2);
        router.shutdown();
    }
}
