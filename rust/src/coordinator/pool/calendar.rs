//! Skip calendars: calibrated per-(model, steps, policy) predictions of
//! how many module-row invocations a request will actually execute.
//!
//! LazyDiT's laziness is *predictable*: the per-step skip pattern is a
//! near-deterministic function of the model, the step schedule, and the
//! decision policy, not of the individual request (SmoothCache makes
//! the same observation and precomputes its schedules offline). This
//! module turns that predictability into an admission-time price.
//!
//! Three layers:
//!
//! - [`StepProfile`] — raw per-step-index run/seen row counters,
//!   recorded by an engine while it serves (both [`SimEngine`] and the
//!   real engine implement [`PoolEngine::step_profile`]). `lazydit
//!   calibrate` aggregates one over a trace.
//! - [`SkipCalendar`] — the versioned, strictly-decoded JSON artifact:
//!   a map from step count to the *expected executed module-row
//!   invocations per step* for one request (the per-step vector already
//!   folds the skip ratio in). [`SkipCalendar::cost_from`] sums the
//!   tail from a step cursor — the predicted remaining work, monotone
//!   non-increasing as the cursor advances. Serialization goes through
//!   [`crate::util::json::Json`] with `BTreeMap`-sorted keys, so the
//!   same trace always produces a byte-identical artifact.
//! - [`PoolCalendar`] — the router-held pricing oracle: the optional
//!   loaded artifact plus online EWMA fallbacks (observed Γ, rows per
//!   step, wall-µs per executed row) that self-calibrate from the pool
//!   gauges when no artifact is given. Everything downstream — EDF
//!   deadlines, shed-by-slack, steal victim ranking, brownout pressure
//!   — reads one number from here.
//!
//! The artifact deliberately carries **no wall-clock data**: service
//! time depends on the machine, so `us_per_inv` is always an online
//! estimate, while the invocation counts are a property of the model ×
//! policy and are portable between hosts.
//!
//! [`SimEngine`]: crate::coordinator::pool::sim::SimEngine
//! [`PoolEngine::step_profile`]: crate::coordinator::pool::PoolEngine::step_profile

use crate::util::json::{Json, JsonError};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Calendar artifact schema version ([`SkipCalendar::decode`] rejects
/// any other value — the codec never guesses at unknown layouts).
pub const CALENDAR_VERSION: u64 = 1;

/// Magic tag in the artifact's `"calendar"` field, so a stray JSON file
/// can never be mistaken for a calendar.
pub const CALENDAR_MAGIC: &str = "lazydit/skip-calendar";

/// Default headroom multiplier when deriving a latency-tier deadline
/// from the calendar's predicted service time: `deadline = now +
/// headroom × predicted_service`. Generous, because the prediction is
/// service time only — queueing delay is what the slack check charges
/// separately.
pub const DEADLINE_HEADROOM: f64 = 8.0;

/// Floor on a calendar-derived default deadline, so a near-zero service
/// prediction (tiny synthetic requests) never produces an unmeetable
/// sub-millisecond deadline.
pub const DEADLINE_FLOOR_US: u64 = 25_000;

// ------------------------------------------------------------ profile

/// Per-step-index run/seen module-row counters, recorded by an engine
/// while it serves. Step index is the request's own cursor (0-based),
/// so requests with different step counts can share a profile — the
/// calibrator is expected to feed it a single-step-count trace when it
/// wants an exact calendar entry.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StepProfile {
    /// Executed module rows at each step index.
    rows_run: Vec<u64>,
    /// Module rows decided (run + skipped) at each step index.
    rows_seen: Vec<u64>,
}

impl StepProfile {
    /// An empty profile.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one module-row decision batch at `step`: `run` rows
    /// executed out of `seen` decided. Grows the vectors on demand.
    pub fn record(&mut self, step: usize, run: u64, seen: u64) {
        if self.rows_run.len() <= step {
            self.rows_run.resize(step + 1, 0);
            self.rows_seen.resize(step + 1, 0);
        }
        self.rows_run[step] += run;
        self.rows_seen[step] += seen;
    }

    /// Number of step indices with any observation.
    pub fn len(&self) -> usize {
        self.rows_seen.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.rows_seen.is_empty()
    }

    /// Executed rows recorded at `step` (0 beyond the observed range).
    pub fn run_rows(&self, step: usize) -> u64 {
        self.rows_run.get(step).copied().unwrap_or(0)
    }

    /// Decided rows recorded at `step` (0 beyond the observed range).
    pub fn seen_rows(&self, step: usize) -> u64 {
        self.rows_seen.get(step).copied().unwrap_or(0)
    }

    /// Fraction of decided rows that executed at `step`; `None` when
    /// the step was never observed.
    pub fn run_ratio(&self, step: usize) -> Option<f64> {
        let seen = self.seen_rows(step);
        (seen > 0).then(|| self.run_rows(step) as f64 / seen as f64)
    }

    /// Total executed rows across all steps.
    pub fn total_run(&self) -> u64 {
        self.rows_run.iter().sum()
    }

    /// Total decided rows across all steps.
    pub fn total_seen(&self) -> u64 {
        self.rows_seen.iter().sum()
    }

    /// Fold another profile in (index-wise sums) — how the calibrator
    /// merges per-replica profiles into one trace-wide aggregate.
    pub fn merge(&mut self, other: &StepProfile) {
        for s in 0..other.len() {
            self.record(s, other.run_rows(s), other.seen_rows(s));
        }
    }
}

// ----------------------------------------------------------- calendar

/// The calibrated artifact: expected executed module-row invocations
/// per step, per step count, for one (model params, policy) pair.
///
/// JSON schema (all five top-level keys required, nothing else
/// accepted):
///
/// ```json
/// {
///   "calendar": "lazydit/skip-calendar",
///   "entries": {"10": [16.0, 8.25, 8.25, ...]},
///   "model_params": "00a1b2c3d4e5f607",
///   "policy": "sim:lazy=50:work=4000:coupled=false",
///   "version": 1
/// }
/// ```
///
/// `model_params` is the engine's parameter fingerprint (the same value
/// [`crate::coordinator::request::Request::key`] folds into a
/// `RequestKey`), hex-encoded because JSON numbers cannot carry a full
/// u64 exactly. Each `entries` value has exactly `steps` elements, all
/// finite and non-negative — the expected executed rows for a single
/// request at that step index (skip ratio already folded in).
#[derive(Debug, Clone, PartialEq)]
pub struct SkipCalendar {
    /// Model-parameter fingerprint this calendar was profiled on.
    pub model_params: u64,
    /// Decision policy / engine descriptor the profile ran under.
    pub policy: String,
    /// step count → expected executed rows per step (len == steps).
    pub entries: BTreeMap<u64, Vec<f64>>,
}

impl SkipCalendar {
    /// An empty calendar for `(model_params, policy)`.
    pub fn new(model_params: u64, policy: &str) -> Self {
        SkipCalendar {
            model_params,
            policy: policy.to_string(),
            entries: BTreeMap::new(),
        }
    }

    /// Insert the entry for `steps` from a trace-wide [`StepProfile`]
    /// over `requests` same-step-count requests: expected executed rows
    /// at step `s` = profiled executed rows at `s` / requests.
    pub fn insert_profile(&mut self, steps: usize, profile: &StepProfile,
                          requests: u64) {
        let n = requests.max(1) as f64;
        let entry: Vec<f64> =
            (0..steps).map(|s| profile.run_rows(s) as f64 / n).collect();
        self.entries.insert(steps as u64, entry);
    }

    /// Predicted remaining executed rows for a `steps`-step request at
    /// step `cursor`: the sum of the entry's tail. `None` when no entry
    /// covers this step count. Monotone non-increasing in `cursor`
    /// (entries are non-negative), which is what makes it a sound
    /// admission price: work only ever burns down.
    pub fn cost_from(&self, steps: usize, cursor: usize) -> Option<f64> {
        let entry = self.entries.get(&(steps as u64))?;
        let from = cursor.min(entry.len());
        Some(entry[from..].iter().sum())
    }

    /// Implied skip ratio Γ for `steps`-step requests: 1 − executed /
    /// decided, where decided is taken as the max per-step expectation
    /// times the step count (a lower bound on Γ; exact when the row
    /// count per step is constant, as in the synthetic engine).
    pub fn implied_gamma(&self, steps: usize) -> Option<f64> {
        let entry = self.entries.get(&(steps as u64))?;
        let peak = entry.iter().cloned().fold(0.0f64, f64::max);
        if peak <= 0.0 {
            return None;
        }
        let total: f64 = entry.iter().sum();
        Some(1.0 - total / (peak * entry.len() as f64))
    }

    /// Serialize to the canonical artifact text (sorted keys via
    /// `BTreeMap`, trailing newline): the same calendar value always
    /// produces byte-identical output.
    pub fn encode(&self) -> String {
        let entries = Json::Obj(
            self.entries
                .iter()
                .map(|(steps, v)| {
                    (steps.to_string(),
                     Json::arr(v.iter().map(|x| Json::num(*x))))
                })
                .collect(),
        );
        let doc = Json::obj(vec![
            ("calendar", Json::str(CALENDAR_MAGIC)),
            ("entries", entries),
            ("model_params",
             Json::str(&format!("{:016x}", self.model_params))),
            ("policy", Json::str(&self.policy)),
            ("version", Json::num(CALENDAR_VERSION as f64)),
        ]);
        format!("{doc}\n")
    }

    /// Strict decode: rejects non-objects, unknown or missing top-level
    /// keys, a wrong magic or version, a malformed fingerprint, entry
    /// keys that aren't positive integers, entry vectors whose length
    /// disagrees with their step count, and any negative or non-finite
    /// element. Mirrors the `LZTS` snapshot codec's posture: never
    /// guess at a layout you don't recognize.
    pub fn decode(text: &str) -> Result<SkipCalendar, JsonError> {
        let doc = Json::parse(text)?;
        let obj = doc
            .as_obj()
            .ok_or_else(|| JsonError("calendar: not an object".into()))?;
        for key in obj.keys() {
            if !matches!(key.as_str(),
                         "calendar" | "entries" | "model_params"
                         | "policy" | "version") {
                return Err(JsonError(format!(
                    "calendar: unknown key '{key}'")));
            }
        }
        let magic = doc.req("calendar")?.as_str().ok_or_else(|| {
            JsonError("calendar: magic must be a string".into())
        })?;
        if magic != CALENDAR_MAGIC {
            return Err(JsonError(format!(
                "calendar: bad magic '{magic}'")));
        }
        let version = doc.req("version")?.as_u64().ok_or_else(|| {
            JsonError("calendar: version must be an integer".into())
        })?;
        if version != CALENDAR_VERSION {
            return Err(JsonError(format!(
                "calendar: unsupported version {version} (expected \
                 {CALENDAR_VERSION})")));
        }
        let fp = doc.req("model_params")?.as_str().ok_or_else(|| {
            JsonError("calendar: model_params must be a hex string".into())
        })?;
        if fp.is_empty() || fp.len() > 16 {
            return Err(JsonError(
                "calendar: model_params must be 1..=16 hex digits".into()));
        }
        let model_params = u64::from_str_radix(fp, 16).map_err(|_| {
            JsonError(format!("calendar: bad model_params '{fp}'"))
        })?;
        let policy = doc.req("policy")?.as_str().ok_or_else(|| {
            JsonError("calendar: policy must be a string".into())
        })?;
        let raw = doc.req("entries")?.as_obj().ok_or_else(|| {
            JsonError("calendar: entries must be an object".into())
        })?;
        let mut entries = BTreeMap::new();
        for (k, v) in raw {
            let steps: u64 = k.parse().map_err(|_| {
                JsonError(format!("calendar: bad step count key '{k}'"))
            })?;
            if steps == 0 {
                return Err(JsonError(
                    "calendar: step count 0 is not a schedule".into()));
            }
            let arr = v.as_arr().ok_or_else(|| {
                JsonError(format!("calendar: entry {steps} must be an \
                                   array"))
            })?;
            if arr.len() as u64 != steps {
                return Err(JsonError(format!(
                    "calendar: entry {steps} has {} elements (expected \
                     {steps})",
                    arr.len())));
            }
            let mut entry = Vec::with_capacity(arr.len());
            for x in arr {
                let n = x.as_f64().ok_or_else(|| {
                    JsonError(format!(
                        "calendar: entry {steps} has a non-number"))
                })?;
                if !n.is_finite() || n < 0.0 {
                    return Err(JsonError(format!(
                        "calendar: entry {steps} has a negative or \
                         non-finite element")));
                }
                entry.push(n);
            }
            entries.insert(steps, entry);
        }
        Ok(SkipCalendar { model_params, policy: policy.to_string(), entries })
    }
}

// ------------------------------------------------------------- oracle

/// EWMA smoothing factor for the online fallbacks: slow enough to ride
/// out per-tick noise, fast enough to track a Γ drift within a few
/// hundred ticks.
const EWMA_ALPHA: f64 = 0.2;

/// Γ clamp when pricing with the fallback, mirroring
/// [`crate::coordinator::pool::router::lazy_cost`]: even a saturated
/// observed Γ must never price work at zero.
const GAMMA_CLAMP: f64 = 0.95;

/// The router-held pricing oracle: an optional calibrated
/// [`SkipCalendar`] plus online EWMA estimates that self-calibrate from
/// the pool gauges when no artifact (or no matching entry) is
/// available. All state is atomic — priced reads happen on the
/// dispatch path, ticks happen on the serve loop.
#[derive(Debug)]
pub struct PoolCalendar {
    calendar: Option<SkipCalendar>,
    /// EWMA of pool-wide observed skip ratio Γ (f64 bits).
    gamma_bits: AtomicU64,
    /// EWMA of decided module rows per step per request (f64 bits).
    inv_per_step_bits: AtomicU64,
    /// EWMA of wall microseconds per *executed* row (f64 bits); 0 means
    /// "unknown" — slack checks and deadline defaulting stay off.
    us_per_inv_bits: AtomicU64,
    /// EWMA of wire step count per dispatched request (f64 bits).
    steps_per_req_bits: AtomicU64,
    // cumulative counters at the previous tick
    last_rows_run: AtomicU64,
    last_rows_seen: AtomicU64,
    last_completed: AtomicU64,
    last_us: AtomicU64,
}

fn load_f64(a: &AtomicU64) -> f64 {
    f64::from_bits(a.load(Ordering::Relaxed))
}

fn store_f64(a: &AtomicU64, v: f64) {
    a.store(v.to_bits(), Ordering::Relaxed);
}

/// One EWMA step: first sample seeds the estimate, later samples blend
/// at [`EWMA_ALPHA`]. Non-finite samples are dropped.
fn ewma(a: &AtomicU64, sample: f64) {
    if !sample.is_finite() {
        return;
    }
    let cur = load_f64(a);
    let next = if cur == 0.0 {
        sample
    } else {
        cur + EWMA_ALPHA * (sample - cur)
    };
    store_f64(a, next);
}

impl PoolCalendar {
    /// An oracle around an optional loaded artifact.
    pub fn new(calendar: Option<SkipCalendar>) -> Self {
        PoolCalendar {
            calendar,
            gamma_bits: AtomicU64::new(0),
            inv_per_step_bits: AtomicU64::new(0),
            us_per_inv_bits: AtomicU64::new(0),
            steps_per_req_bits: AtomicU64::new(0),
            last_rows_run: AtomicU64::new(0),
            last_rows_seen: AtomicU64::new(0),
            last_completed: AtomicU64::new(0),
            last_us: AtomicU64::new(0),
        }
    }

    /// Oracle with no artifact: pure EWMA self-calibration.
    pub fn online() -> Self {
        Self::new(None)
    }

    /// True when a calibrated artifact is loaded.
    pub fn armed(&self) -> bool {
        self.calendar.is_some()
    }

    /// The loaded artifact, if any.
    pub fn calendar(&self) -> Option<&SkipCalendar> {
        self.calendar.as_ref()
    }

    /// Record a dispatched request's wire step count (EWMA input for
    /// the fallback's rows-per-step estimate).
    pub fn observe_dispatch(&self, steps: usize) {
        ewma(&self.steps_per_req_bits, steps as f64);
    }

    /// Periodic self-calibration from cumulative pool counters
    /// (`rows_run` / `rows_seen` executed/decided row totals,
    /// `completed` request total, `live` live replicas, `now_us` shared
    /// epoch). Deltas since the previous tick feed the Γ, rows-per-step
    /// and µs-per-row EWMAs; ticks with no progress are no-ops.
    pub fn tick(&self, rows_run: u64, rows_seen: u64, completed: u64,
                live: usize, now_us: u64) {
        let d_run =
            rows_run.saturating_sub(self.last_rows_run.swap(rows_run,
                                                            Ordering::Relaxed));
        let d_seen =
            rows_seen.saturating_sub(self.last_rows_seen
                                         .swap(rows_seen, Ordering::Relaxed));
        let d_done =
            completed.saturating_sub(self.last_completed
                                         .swap(completed, Ordering::Relaxed));
        let prev_us = self.last_us.swap(now_us, Ordering::Relaxed);
        let d_us = now_us.saturating_sub(prev_us);
        if d_seen > 0 {
            ewma(&self.gamma_bits, 1.0 - d_run as f64 / d_seen as f64);
        }
        if d_done > 0 {
            let steps = load_f64(&self.steps_per_req_bits);
            if steps > 0.0 {
                // decided rows per completed request, spread over its
                // steps — the shape factor the fallback price needs
                ewma(&self.inv_per_step_bits,
                     d_seen as f64 / d_done as f64 / steps);
            }
        }
        if d_run > 0 && d_us > 0 && prev_us > 0 {
            // wall time × live replicas approximates busy compute time
            // under load; idle ticks contribute no executed rows and
            // are skipped by the d_run guard, and the first tick (whose
            // window stretches back to the epoch) by the prev_us guard
            ewma(&self.us_per_inv_bits,
                 d_us as f64 * live.max(1) as f64 / d_run as f64);
        }
    }

    /// Observed-Γ EWMA (0 until the first tick with row progress).
    pub fn gamma(&self) -> f64 {
        load_f64(&self.gamma_bits)
    }

    /// Wall-µs-per-executed-row estimate; `None` until calibrated.
    pub fn us_per_inv(&self) -> Option<f64> {
        let v = load_f64(&self.us_per_inv_bits);
        (v > 0.0).then_some(v)
    }

    /// Force the µs-per-row estimate (tests and the calibrate verb's
    /// serve-side seeding).
    pub fn set_us_per_inv(&self, v: f64) {
        store_f64(&self.us_per_inv_bits, v.max(0.0));
    }

    /// Price a request: predicted remaining executed module rows for a
    /// `steps`-step request at `cursor`, in milli-rows. Calendar entry
    /// when one covers the step count, EWMA fallback `remaining ×
    /// rows_per_step × (1 − Γ)` otherwise; 0 ("unpriced") when neither
    /// knows anything yet.
    pub fn price_milli(&self, steps: usize, cursor: usize) -> u64 {
        if let Some(cost) =
            self.calendar.as_ref().and_then(|c| c.cost_from(steps, cursor))
        {
            return (cost * 1e3).round() as u64;
        }
        let per_step = load_f64(&self.inv_per_step_bits);
        if per_step <= 0.0 {
            return 0;
        }
        let gamma = self.gamma().clamp(0.0, GAMMA_CLAMP);
        let remaining = steps.saturating_sub(cursor) as f64;
        (remaining * per_step * (1.0 - gamma) * 1e3).round() as u64
    }

    /// Predicted service time for `cost_milli` milli-rows of work;
    /// `None` until the µs-per-row EWMA has calibrated.
    pub fn service_us(&self, cost_milli: u64) -> Option<u64> {
        let per = self.us_per_inv()?;
        Some((cost_milli as f64 / 1e3 * per).round() as u64)
    }

    /// Calendar-derived default deadline for a latency-tier request
    /// admitted at `now_us`: predicted service × [`DEADLINE_HEADROOM`],
    /// floored at [`DEADLINE_FLOOR_US`]. `None` while the request can't
    /// be priced in time units yet.
    pub fn default_deadline_us(&self, now_us: u64, steps: usize)
                               -> Option<u64> {
        let cost = self.price_milli(steps, 0);
        if cost == 0 {
            return None;
        }
        let svc = self.service_us(cost)?;
        let lead = ((svc as f64 * DEADLINE_HEADROOM) as u64)
            .max(DEADLINE_FLOOR_US);
        Some(now_us + lead)
    }

    /// Convert a predicted-cost backlog (milli-rows) into
    /// request-equivalents — the unit brownout thresholds are tuned in.
    /// `None` until the fallback shape estimates exist.
    pub fn queue_equivalent(&self, backlog_milli: u64) -> Option<f64> {
        let per_step = load_f64(&self.inv_per_step_bits);
        let steps = load_f64(&self.steps_per_req_bits);
        if per_step <= 0.0 || steps <= 0.0 {
            return None;
        }
        let gamma = self.gamma().clamp(0.0, GAMMA_CLAMP);
        let per_req = per_step * steps * (1.0 - gamma);
        (per_req > 0.0)
            .then(|| backlog_milli as f64 / 1e3 / per_req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    fn sample() -> SkipCalendar {
        let mut c = SkipCalendar::new(0xDEAD_BEEF_F00D_CAFE, "sim:lazy=50");
        c.entries.insert(4, vec![16.0, 8.0, 8.25, 7.75]);
        c.entries.insert(10, (0..10).map(|s| 16.0 / (1 + s) as f64)
                                    .collect());
        c
    }

    #[test]
    fn codec_round_trips() {
        let c = sample();
        let text = c.encode();
        let back = SkipCalendar::decode(&text).expect("decode");
        assert_eq!(back, c);
        // and the canonical form is a fixed point: byte-identical
        assert_eq!(back.encode(), text);
    }

    #[test]
    fn encode_is_deterministic() {
        // insertion order must not leak into the artifact bytes
        let a = sample();
        let mut b = SkipCalendar::new(0xDEAD_BEEF_F00D_CAFE, "sim:lazy=50");
        let mut entries: Vec<_> = a.entries.clone().into_iter().collect();
        entries.reverse();
        for (k, v) in entries {
            b.entries.insert(k, v);
        }
        assert_eq!(a.encode(), b.encode());
    }

    #[test]
    fn strict_decode_rejects() {
        let good = sample().encode();
        assert!(SkipCalendar::decode(&good).is_ok());
        let cases: &[(&str, &str)] = &[
            ("not json", "calendar"),
            ("[1,2]", "not an object"),
            // missing each required key
            (r#"{"entries":{},"model_params":"ab","policy":"p","version":1}"#,
             "missing magic"),
            (r#"{"calendar":"lazydit/skip-calendar","model_params":"ab","policy":"p","version":1}"#,
             "missing entries"),
            (r#"{"calendar":"lazydit/skip-calendar","entries":{},"policy":"p","version":1}"#,
             "missing model_params"),
            (r#"{"calendar":"lazydit/skip-calendar","entries":{},"model_params":"ab","version":1}"#,
             "missing policy"),
            (r#"{"calendar":"lazydit/skip-calendar","entries":{},"model_params":"ab","policy":"p"}"#,
             "missing version"),
            // wrong magic / version
            (r#"{"calendar":"other","entries":{},"model_params":"ab","policy":"p","version":1}"#,
             "bad magic"),
            (r#"{"calendar":"lazydit/skip-calendar","entries":{},"model_params":"ab","policy":"p","version":2}"#,
             "future version"),
            // unknown key
            (r#"{"calendar":"lazydit/skip-calendar","entries":{},"model_params":"ab","policy":"p","version":1,"extra":0}"#,
             "unknown key"),
            // fingerprint malformed
            (r#"{"calendar":"lazydit/skip-calendar","entries":{},"model_params":"xyz","policy":"p","version":1}"#,
             "non-hex fingerprint"),
            (r#"{"calendar":"lazydit/skip-calendar","entries":{},"model_params":"00112233445566778899","policy":"p","version":1}"#,
             "overlong fingerprint"),
            (r#"{"calendar":"lazydit/skip-calendar","entries":{},"model_params":7,"policy":"p","version":1}"#,
             "numeric fingerprint"),
            // entry shape violations
            (r#"{"calendar":"lazydit/skip-calendar","entries":{"x":[1]},"model_params":"ab","policy":"p","version":1}"#,
             "non-numeric step key"),
            (r#"{"calendar":"lazydit/skip-calendar","entries":{"0":[]},"model_params":"ab","policy":"p","version":1}"#,
             "zero steps"),
            (r#"{"calendar":"lazydit/skip-calendar","entries":{"3":[1,2]},"model_params":"ab","policy":"p","version":1}"#,
             "length mismatch"),
            (r#"{"calendar":"lazydit/skip-calendar","entries":{"2":[1,-0.5]},"model_params":"ab","policy":"p","version":1}"#,
             "negative element"),
            (r#"{"calendar":"lazydit/skip-calendar","entries":{"2":[1,"x"]},"model_params":"ab","policy":"p","version":1}"#,
             "non-number element"),
            (r#"{"calendar":"lazydit/skip-calendar","entries":[1],"model_params":"ab","policy":"p","version":1}"#,
             "entries not an object"),
        ];
        for (text, why) in cases {
            assert!(SkipCalendar::decode(text).is_err(),
                    "decode must reject: {why}");
        }
    }

    #[test]
    fn cost_from_is_monotone_non_increasing() {
        propcheck(200, |g| {
            let steps = g.usize_in(1, 64);
            let mut c = SkipCalendar::new(g.u64(), "prop");
            let entry: Vec<f64> = (0..steps)
                .map(|_| g.f32_in(0.0, 32.0) as f64)
                .collect();
            c.entries.insert(steps as u64, entry);
            let mut prev = f64::INFINITY;
            for cursor in 0..=steps + 2 {
                let cost = c.cost_from(steps, cursor).expect("entry");
                assert!(cost <= prev + 1e-9,
                        "cost rose as the cursor advanced: {cost} > {prev} \
                         at cursor {cursor}");
                assert!(cost >= -0.0, "cost must be non-negative");
                prev = cost;
            }
            assert_eq!(c.cost_from(steps, steps).unwrap(), 0.0,
                       "a finished request costs nothing");
            assert!(c.cost_from(steps + 1, 0).is_none(),
                    "unknown step counts have no calendar price");
        });
    }

    #[test]
    fn profile_records_and_merges() {
        let mut a = StepProfile::new();
        a.record(0, 10, 16);
        a.record(2, 4, 16);
        assert_eq!(a.len(), 3);
        assert_eq!(a.seen_rows(1), 0);
        assert_eq!(a.run_ratio(1), None);
        assert_eq!(a.run_ratio(0), Some(10.0 / 16.0));
        let mut b = StepProfile::new();
        b.record(0, 6, 16);
        b.merge(&a);
        assert_eq!(b.run_rows(0), 16);
        assert_eq!(b.seen_rows(0), 32);
        assert_eq!(b.run_rows(2), 4);
        assert_eq!(b.total_run(), 20);
        assert_eq!(b.total_seen(), 48);
    }

    #[test]
    fn insert_profile_normalizes_per_request() {
        let mut p = StepProfile::new();
        // 4 requests × 2 steps, 8 slots each: all run at step 0, half
        // skipped at step 1
        p.record(0, 32, 32);
        p.record(1, 16, 32);
        let mut c = SkipCalendar::new(1, "t");
        c.insert_profile(2, &p, 4);
        assert_eq!(c.entries[&2], vec![8.0, 4.0]);
        assert_eq!(c.cost_from(2, 0), Some(12.0));
        assert_eq!(c.cost_from(2, 1), Some(4.0));
    }

    #[test]
    fn oracle_prefers_calendar_and_falls_back() {
        let mut cal = SkipCalendar::new(1, "t");
        cal.entries.insert(4, vec![8.0, 4.0, 2.0, 1.0]);
        let oracle = PoolCalendar::new(Some(cal));
        assert_eq!(oracle.price_milli(4, 0), 15_000);
        assert_eq!(oracle.price_milli(4, 2), 3_000);
        // no entry for 7 steps and no EWMA yet → unpriced
        assert_eq!(oracle.price_milli(7, 0), 0);
        // calibrate the fallback: 2 requests completed, 7 steps each,
        // 8 rows/step decided, half skipped
        oracle.observe_dispatch(7);
        oracle.observe_dispatch(7);
        oracle.tick(0, 0, 0, 1, 1_000);
        oracle.tick(56, 112, 2, 1, 2_000);
        let priced = oracle.price_milli(7, 0);
        assert!(priced > 0, "fallback must price once calibrated");
        // remaining 7 × 8 rows/step × (1 − 0.5) = 28 rows
        assert!((priced as i64 - 28_000).abs() < 2_000,
                "fallback price off: {priced}");
        assert!(oracle.price_milli(7, 6) < priced,
                "fallback price must shrink with the cursor");
    }

    #[test]
    fn oracle_service_time_gates_on_calibration() {
        let oracle = PoolCalendar::online();
        assert_eq!(oracle.service_us(10_000), None);
        assert_eq!(oracle.default_deadline_us(0, 4), None);
        oracle.set_us_per_inv(100.0);
        assert_eq!(oracle.service_us(10_000), Some(1_000));
        // still no price → still no default deadline
        assert_eq!(oracle.default_deadline_us(0, 4), None);
        let mut cal = SkipCalendar::new(1, "t");
        cal.entries.insert(4, vec![8.0, 4.0, 2.0, 1.0]);
        let oracle = PoolCalendar::new(Some(cal));
        oracle.set_us_per_inv(100.0);
        // 15 rows × 100 µs = 1.5 ms service; headroom-floored deadline
        let dl = oracle.default_deadline_us(5_000, 4).expect("deadline");
        assert!(dl >= 5_000 + DEADLINE_FLOOR_US);
    }

    #[test]
    fn queue_equivalent_inverts_per_request_cost() {
        let oracle = PoolCalendar::online();
        assert_eq!(oracle.queue_equivalent(1_000), None);
        oracle.observe_dispatch(10);
        oracle.tick(0, 0, 0, 1, 0);
        oracle.tick(40, 80, 1, 1, 1_000); // 80 rows seen, Γ=0.5, 10 steps
        // per request ≈ 8 rows/step × 10 steps × 0.5 = 40 rows
        let q = oracle.queue_equivalent(80_000).expect("calibrated");
        assert!((q - 2.0).abs() < 0.25, "queue equivalent off: {q}");
    }
}
