//! Replica pool: the multi-engine serving runtime (DESIGN.md §7 extended).
//!
//! The single-threaded [`crate::coordinator::engine::Engine`] caps
//! throughput at one denoise loop. This subsystem lifts that: N worker
//! threads each own a private engine (PJRT types are `!Send`/`!Sync`, so
//! every replica *constructs* its engine on its own thread from a `Send`
//! factory) and a router places requests across them.
//!
//! * [`replica`] — the worker thread: bounded input queue, continuous
//!   admission, per-replica load gauges, drain-on-close;
//! * [`router`] — admission control + dispatch policies (round-robin,
//!   join-shortest-queue, lazy-aware cost);
//! * [`steal`] — pool-level work stealing: an idle replica pulls queued
//!   (not-yet-started) jobs from the sibling with the highest
//!   lazy-discounted effective backlog, moving the gauge accounting
//!   with the job so routing stays truthful;
//! * [`agg`] — pool-wide aggregation of per-replica `LayerStats` /
//!   `ServeStats` into one report;
//! * [`cache`] — the content-addressable result + warm-start cache the
//!   router fronts dispatch with: exact [`crate::coordinator::request::RequestKey`]
//!   hits return a finished output with zero engine work, near hits
//!   (same family, different seed) seed a joiner's lane caches from a
//!   donor trajectory;
//! * [`calendar`] — calibrated skip calendars: per-(model, steps,
//!   policy) predictions of executed module rows per remaining step,
//!   profiled by `lazydit calibrate` (with an online EWMA fallback),
//!   that price every request at admission and anchor the latency
//!   tier's deadlines;
//! * [`sim`] — a deterministic synthetic engine: exercises the whole pool
//!   (and the scaling bench) without artifacts or the XLA runtime;
//! * [`fault`] — deterministic fault injection: a seeded [`fault::FaultPlan`]
//!   compiles to per-replica schedules (panic/stall/burst/corrupt) the
//!   synthetic engine honors natively and [`fault::FaultEngine`] wraps
//!   around the real one;
//! * [`supervisor`] — watches per-replica heartbeats, respawns dead
//!   workers into the same tier slot (restart budget + exponential
//!   backoff) and trips a per-replica circuit breaker so routing stops
//!   feeding a flapping replica;
//! * [`brownout`] — the pool-wide overload controller: under sustained
//!   backlog/shed pressure it trades fidelity for availability through
//!   declared degradation stages (wider warm horizon → higher target Γ
//!   → capped best-effort steps) and steps back down on recovery.
//!
//! Replicas may run different skip policies side-by-side (per-replica
//! override in `lazydit serve --replica-policy`), turning the server into
//! an online A/B harness for the baselines. They may also be provisioned
//! heterogeneously ([`replica::ReplicaTier`], `--replica-spec`): each
//! replica carries its own SLO class and batcher shape, and the router
//! places each request on the tier that matches its `"slo"` tag — the
//! serving analogue of allocating LazyDiT's compute budget where it pays.
//!
//! Cross-module invariants (each module's docs state its own):
//! * **gauge conservation** — every `queued`/`pending_steps` increment
//!   has exactly one matching decrement across dispatch rollback, steal
//!   migration, completion, and dead-replica cleanup, so pool-wide sums
//!   stay truthful while the system runs;
//! * **thief-first locking order** — a migration updates the thief's
//!   gauges before the victim's, inside the rebalancer's peer lock, so
//!   concurrent readers never under-count the pool total;
//! * **admission-window bound** — a stealing worker keeps at most its
//!   tier's window of trajectories inside the engine; the queue tail
//!   stays migratable and SLO-compatible thieves can always help.
#![deny(missing_docs)]

pub mod agg;
pub mod brownout;
pub mod cache;
pub mod calendar;
pub mod fault;
pub mod replica;
pub mod router;
pub mod sim;
pub mod steal;
pub mod supervisor;

pub use agg::PoolReport;
pub use brownout::{Brownout, BrownoutConfig};
pub use cache::{CacheConfig, CacheStats, PoolCache};
pub use calendar::{PoolCalendar, SkipCalendar, StepProfile};
pub use fault::{FaultEngine, FaultPlan, FaultSchedule};
pub use replica::{PoolJob, ReplicaGauges, ReplicaHandle, ReplicaReport,
                  ReplicaTier};
pub use router::{DispatchOutcome, Router};
pub use sim::{SimEngine, SimSpec};
pub use steal::{Rebalancer, StealPeer};
pub use supervisor::{Supervisor, SupervisorConfig};

use crate::coordinator::request::{Request, RequestResult};
use crate::coordinator::stats::{LayerStats, ServeStats};
use anyhow::Result;

/// The engine surface a replica worker drives. Implemented by the real
/// [`crate::coordinator::engine::Engine`] and by [`sim::SimEngine`].
/// Implementations are thread-local to their replica — the trait
/// deliberately has no `Send` bound.
pub trait PoolEngine {
    /// Admit a request into the active set; returns the assigned id.
    fn submit(&mut self, req: Request) -> u64;

    /// Requests admitted and not yet finished.
    fn active_count(&self) -> usize;

    /// Total remaining denoise steps across the active set (the router's
    /// backlog unit).
    fn pending_steps(&self) -> usize;

    /// Run one scheduling round; returns finished requests.
    fn step_round(&mut self) -> Result<Vec<RequestResult>>;

    /// Per-(layer,module) laziness accounting so far.
    fn layer_stats(&self) -> &LayerStats;

    /// Serving-level accounting so far.
    fn serve_stats(&self) -> &ServeStats;

    /// Human-readable skip-policy label (pool A/B reporting).
    fn policy_name(&self) -> String;

    /// This engine's buffer-arena counters, when it owns one (the real
    /// engine's per-replica [`crate::tensor::pool::TensorPool`]; the
    /// synthetic engine has no tensors and returns `None`). Surfaced in
    /// the final [`ReplicaReport`] so a serving run can verify the
    /// steady state stopped allocating.
    fn arena_stats(&self) -> Option<crate::tensor::pool::PoolStats> {
        None
    }

    /// Hand the engine a telemetry tracer to record per-step span events
    /// through (see [`crate::obs`]). Default: ignore it — engines that
    /// predate tracing (and test doubles) stay correct, they just emit
    /// no engine-side events.
    fn install_tracer(&mut self, _tracer: crate::obs::Tracer) {}

    /// Ids of every trajectory currently active on this engine, in
    /// admission order. Drives eviction sweeps (drain-by-migration) and
    /// the crash-resume stash. Default: none — engines without snapshot
    /// support simply have nothing to migrate.
    fn active_ids(&self) -> Vec<u64> {
        Vec::new()
    }

    /// Evict an active trajectory at the current step boundary and
    /// return it as a portable snapshot: batch residency is flushed so
    /// the snapshot's lane caches are current, the request leaves the
    /// active set, and resuming the snapshot anywhere is bit-identical
    /// to never having evicted. `None` when the id is unknown or the
    /// engine does not support snapshots (the default).
    fn evict_to_snapshot(&mut self, _id: u64)
                         -> Option<crate::coordinator::request::TrajectorySnapshot> {
        None
    }

    /// Admit a previously evicted trajectory, resuming at its cursor;
    /// returns the id it runs under (snapshot ids are pool-unique, so
    /// implementations keep them). Engines without snapshot support
    /// return 0 (and must not be offered snapshots — the pool layer
    /// gates on eviction having succeeded somewhere first).
    fn admit_snapshot(&mut self,
                      _snap: crate::coordinator::request::TrajectorySnapshot)
                      -> u64 {
        0
    }

    /// Copy (without evicting) an active trajectory's state as of the
    /// last completed step boundary — the crash-resume stash the worker
    /// refreshes between rounds. Unlike [`Self::evict_to_snapshot`]
    /// this must not disturb residency; `None` when unsupported (the
    /// default) or the id is unknown.
    fn snapshot_request(&self, _id: u64)
                        -> Option<crate::coordinator::request::TrajectorySnapshot> {
        None
    }

    /// Admit `req` warm-started from a same-family donor trajectory:
    /// seed the joiner's lane caches from the donor's so its early
    /// would-skip steps skip instead of being cold-denied. Returns the
    /// assigned id plus the number of lane-cache rows actually seeded —
    /// 0 means the donor was rejected (shape mismatch, empty) and the
    /// request was admitted cold, which is always a safe fallback and
    /// the default for engines without warm-start support.
    fn submit_warm(&mut self, req: Request,
                   _donor: &crate::coordinator::request::TrajectorySnapshot)
                   -> (u64, u64) {
        (self.submit(req), 0)
    }

    /// Per-step-index run/seen row counters recorded while serving —
    /// the raw material `lazydit calibrate` aggregates into a
    /// [`calendar::SkipCalendar`]. `None` (the default) for engines
    /// that don't profile per step.
    fn step_profile(&self) -> Option<&calendar::StepProfile> {
        None
    }

    /// Raise the engine's target laziness by `boost` percentage points
    /// — the brownout controller's stage-2 dial (LazyDiT's fidelity/
    /// compute trade turned into an overload valve). 0 restores the
    /// configured target. Engines without a tunable gate ignore it
    /// (the default): degradation is best-effort by design.
    fn set_gamma_boost(&mut self, _boost: u32) {}
}

/// Constructs a replica's engine *on the replica thread*. The factory is
/// `Send`; the engine it builds does not have to be.
pub type EngineFactory =
    Box<dyn FnOnce() -> Result<Box<dyn PoolEngine>> + Send + 'static>;

/// A *reusable* engine factory for supervised slots: unlike
/// [`EngineFactory`] it can be invoked again after a crash, so the
/// [`supervisor::Supervisor`] can respawn a replacement worker into the
/// same tier slot. Shared (`Arc`) because the supervisor keeps one per
/// slot for the whole pool lifetime.
pub type RespawnFactory =
    std::sync::Arc<dyn Fn() -> Result<Box<dyn PoolEngine>> + Send + Sync
                   + 'static>;
