//! Pool-level work stealing: idle replicas pull queued (not-yet-started)
//! jobs from the sibling with the highest *lazy-discounted* effective
//! backlog.
//!
//! Why lazy-discounted: LazyDiT makes per-trajectory cost dynamic — a
//! replica's backlog shrinks at a rate set by its observed lazy ratio Γ,
//! so admission-time placement systematically strands work on replicas
//! whose laziness collapsed mid-trajectory (prompts that defeat the skip
//! predictor). The victim choice therefore ranks siblings by
//! `pending_steps · (1 − Γ)` — the same cost the lazy routing policy
//! uses — so the thief relieves the replica that will take *longest* to
//! clear its queue, not merely the one with the most items.
//!
//! Gauge-transfer invariant: a stolen job's accounting (`queued` 1,
//! `pending_steps` wire steps, `predicted_cost_milli` its calendar
//! price) moves with the job, thief first, then victim, inside the
//! rebalancer's peer lock. Pool-wide sums (the
//! router's jsq/lazy inputs and the admission ledger) therefore never
//! under-count during a migration, and each side's counters are adjusted
//! by exact, known amounts — never stored absolutely — so concurrent
//! dispatch rollbacks and the panic handler compose with migration.

use crate::coordinator::pool::replica::{dec, dec_u64, tier_admits, PoolJob,
                                        ReplicaGauges, ReplicaTier};
use crate::coordinator::pool::router::lazy_cost;
use crate::util::threadpool::BoundedQueue;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One replica's stealable surface: its input queue (thieves take from
/// the back; the owner keeps popping the front), its load gauges, and
/// its tier (the SLO-compatibility constraint on what it may steal).
pub struct StealPeer {
    /// Replica id (stable pool index).
    pub id: usize,
    /// The replica's input queue; thieves take from the back.
    pub queue: BoundedQueue<PoolJob>,
    /// The replica's live gauges (migration moves accounting here).
    pub gauges: Arc<ReplicaGauges>,
    /// The replica's provisioning: a thief only pulls jobs whose SLO
    /// class its own tier can honor ([`ReplicaTier::can_serve`]).
    pub tier: ReplicaTier,
}

impl StealPeer {
    /// The peer's full admission predicate over its LIVE SLO class — a
    /// retagged replica ([`ReplicaGauges::slo_tag`]) is judged by what
    /// it serves now, not its birth provisioning.
    fn admits(&self, slo: crate::config::Slo, lanes: usize) -> bool {
        tier_admits(self.gauges.live_slo(self.tier.slo),
                    self.tier.max_batch, slo, lanes)
    }
}

/// Pool-level rebalancer shared by every replica worker. Constructed
/// before the replicas (workers hold it from birth), populated with the
/// peer set once all replicas exist; `steal_for` is a no-op until then.
pub struct Rebalancer {
    peers: Mutex<Vec<StealPeer>>,
    /// Max trajectories a worker admits into its engine at once; jobs
    /// beyond the window wait in the queue, where they remain
    /// migratable (an engine-admitted trajectory can never move).
    admit_window: usize,
    /// Total successful migrations (monotone; for reporting).
    total_steals: AtomicU64,
    /// Raised while any tier group's step-backlogs are *overdispersed*
    /// (variance exceeding twice the mean — load clumping on few
    /// same-tier siblings): every stealing worker narrows its in-engine
    /// admission window by one step, keeping one more job in the
    /// migratable queue tail. Cleared as soon as every group looks
    /// balanced. Recomputed inside [`Self::steal_for`]'s existing peer
    /// scan (whenever any worker idles) and, while raised, refreshed
    /// rate-limited from [`Self::effective_window`] so a fully-busy
    /// pool cannot freeze it on (the ROADMAP "steal-aware admission
    /// window" heuristic).
    window_shrunk: AtomicBool,
    /// Last time the raised signal was re-validated from the busy path
    /// (see [`Self::effective_window`]).
    refreshed_at: Mutex<std::time::Instant>,
}

/// While the dispersion signal is raised, busy workers re-validate it
/// from `effective_window` at most this often — cheap enough to sit on
/// the admission path, frequent enough that a signal raised during a
/// transient can't outlive the imbalance just because nobody idles.
const SHRINK_REFRESH: std::time::Duration =
    std::time::Duration::from_millis(10);

impl Rebalancer {
    /// Construct with the pool-default in-engine admission window
    /// (tiered replicas override it per replica via
    /// [`ReplicaTier::steal_window`]).
    pub fn new(admit_window: usize) -> Arc<Rebalancer> {
        Arc::new(Rebalancer {
            peers: Mutex::new(Vec::new()),
            admit_window: admit_window.max(1),
            total_steals: AtomicU64::new(0),
            window_shrunk: AtomicBool::new(false),
            refreshed_at: Mutex::new(std::time::Instant::now()),
        })
    }

    /// In-engine admission bound for stealing workers.
    pub fn admit_window(&self) -> usize {
        self.admit_window
    }

    /// The *adaptive* in-engine admission bound for a stealing worker
    /// of `tier`: the tier's steal window, narrowed by one step (never
    /// below 1) while the backlog-dispersion signal is raised, restored
    /// to the constant as soon as every tier group is balanced.
    ///
    /// While the signal is raised it is re-validated here, rate-limited
    /// (every ~10ms) and contention-free (`try_lock`, skipped on
    /// conflict): the scan otherwise lives only in the idle steal
    /// probe, and a saturated pool — where nobody ever idles — must
    /// not keep running on a frozen stale signal.
    pub fn effective_window(&self, tier: &ReplicaTier) -> usize {
        let w = tier.engine_window(true);
        if !self.window_shrunk.load(Ordering::Relaxed) {
            return w;
        }
        if let Ok(mut last) = self.refreshed_at.try_lock() {
            if last.elapsed() >= SHRINK_REFRESH {
                *last = std::time::Instant::now();
                if let Ok(peers) = self.peers.try_lock() {
                    self.note_backlogs(&peers);
                }
            }
        }
        if self.window_shrunk.load(Ordering::Relaxed) {
            w.saturating_sub(1).max(1)
        } else {
            w
        }
    }

    /// Is the dispersion signal currently narrowing windows? (tests,
    /// reporting)
    pub fn window_shrunk(&self) -> bool {
        self.window_shrunk.load(Ordering::Relaxed)
    }

    /// Recompute the dispersion signal: within each *tier group* (same
    /// SLO class and batch width — only same-tier siblings are
    /// comparable), raise it when the group's step-backlog population
    /// variance exceeds twice its mean (index of dispersion ≫ 1 — far
    /// spikier than a balanced group), clear it when every group is
    /// balanced, idle, or trivially small. Grouping matters: a B1
    /// latency replica's inherently tiny backlog next to B8 throughput
    /// replicas' deep ones is healthy heterogeneity, not clumping, and
    /// must never narrow anyone's window.
    fn note_backlogs(&self, peers: &[StealPeer]) {
        let mut shrunk = false;
        let mut seen: Vec<(crate::config::Slo, usize)> = Vec::new();
        for p in peers {
            let key = (p.tier.slo, p.tier.max_batch);
            if seen.contains(&key) {
                continue;
            }
            seen.push(key);
            let group: Vec<f64> = peers
                .iter()
                .filter(|q| q.tier.slo == key.0
                            && q.tier.max_batch == key.1)
                .map(|q| {
                    q.gauges.pending_steps.load(Ordering::Relaxed) as f64
                })
                .collect();
            if group.len() < 2 {
                continue;
            }
            let n = group.len() as f64;
            let mean = group.iter().sum::<f64>() / n;
            let var = group
                .iter()
                .map(|&b| (b - mean) * (b - mean))
                .sum::<f64>()
                / n;
            if mean > 0.0 && var > 2.0 * mean {
                shrunk = true;
                break;
            }
        }
        self.window_shrunk.store(shrunk, Ordering::Relaxed);
    }

    /// Successful migrations so far, pool-wide.
    pub fn total_steals(&self) -> u64 {
        self.total_steals.load(Ordering::Relaxed)
    }

    /// Hand the rebalancer the full peer set (router construction).
    /// Replaces any previous registration.
    pub fn register(&self, peers: Vec<StealPeer>) {
        *self.peers.lock().unwrap_or_else(|p| p.into_inner()) = peers;
    }

    /// Steal one queued job for replica `thief`, from the sibling with
    /// the highest lazy-discounted effective backlog that actually has a
    /// queued (not-yet-started) job the thief's tier can honor — a B1
    /// latency replica never pulls a throughput job off a B8 sibling
    /// (and vice versa), nor any job whose lane count exceeds its batch
    /// width; ineligible jobs are skipped in place, not reordered.
    /// Returns `None` when nothing is stealable. On success the job's
    /// gauge accounting has already moved to the thief — the caller
    /// admits the job as if the router had dispatched it here.
    pub fn steal_for(&self, thief: usize) -> Option<PoolJob> {
        let peers = self.peers.lock().unwrap_or_else(|p| p.into_inner());
        // refresh the adaptive-window signal on the scan we already pay
        // for: one gauge read per peer, grouped by tier
        self.note_backlogs(&peers);
        let me = peers.iter().find(|p| p.id == thief)?;
        // rank victims by effective backlog, costliest first — ties
        // broken by the calendar-priced backlog (predicted rows the
        // victim actually has left to execute), so of two siblings the
        // step heuristic can't separate, the thief relieves the one
        // whose queue really holds more work; only siblings with jobs
        // physically in their queue are candidates
        let mut victims: Vec<(f64, u64, usize)> = peers
            .iter()
            .enumerate()
            .filter(|(_, p)| p.id != thief && !p.queue.is_empty())
            .map(|(i, p)| {
                let s = p.gauges.snapshot(&p.tier);
                (lazy_cost(&s), s.predicted_cost_milli, i)
            })
            .collect();
        victims.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| b.1.cmp(&a.1))
                .then_with(|| a.2.cmp(&b.2))
        });
        for (_, _, vi) in victims {
            let victim = &peers[vi];
            // eligibility is the router's candidate predicate
            // (`tier_admits`): the thief's tier must honor the job's
            // SLO class AND physically fit its lane count — a B1
            // replica admitting a 2-lane CFG job could never plan a
            // round containing it. Judged by the thief's LIVE class so
            // a retag changes what it may pull immediately.
            if let Some(job) = victim.queue.steal_back_matching(|j| {
                me.admits(j.slo(), j.lanes())
            }) {
                let steps = job.remaining_steps();
                // gauge transfer, thief first: pool totals never
                // under-count mid-migration, and the victim side uses
                // saturating known-amount decrements so a racing panic
                // handler or dispatch rollback cannot wrap the gauge
                me.gauges.queued.fetch_add(1, Ordering::Relaxed);
                me.gauges.pending_steps.fetch_add(steps, Ordering::Relaxed);
                me.gauges
                    .predicted_cost_milli
                    .fetch_add(job.cost_milli, Ordering::Relaxed);
                me.gauges.steals.fetch_add(1, Ordering::Relaxed);
                dec(&victim.gauges.queued, 1);
                dec(&victim.gauges.pending_steps, steps);
                dec_u64(&victim.gauges.predicted_cost_milli, job.cost_milli);
                victim.gauges.stolen.fetch_add(1, Ordering::Relaxed);
                self.total_steals.fetch_add(1, Ordering::Relaxed);
                log::debug!("replica {thief} stole a {steps}-step job \
                             from replica {}", victim.id);
                return Some(job);
            }
        }
        // nothing queued anywhere the thief may take. Consider asking a
        // RUNNING victim for mid-trajectory relief: when a sibling's
        // lazy-discounted resident backlog dwarfs the (idle) thief's,
        // ask it to evict one resident at its next step boundary and
        // push the snapshot here ([`ReplicaGauges::evict_to`]). The
        // request is asymptotically free for the victim (one relaxed
        // load per boundary) and raced with compare_exchange so only
        // one thief at a time asks.
        let my_cost = lazy_cost(&me.gauges.snapshot(&me.tier));
        let mut best: Option<(f64, usize)> = None;
        for (i, p) in peers.iter().enumerate() {
            if p.id == thief || p.gauges.finished.load(Ordering::Acquire) {
                continue;
            }
            // at least two residents: relieving a lone trajectory just
            // moves latency around (and could ping-pong it forever)
            if p.gauges.queued.load(Ordering::Relaxed) < 2 {
                continue;
            }
            let cost = lazy_cost(&p.gauges.snapshot(&p.tier));
            if cost >= MID_RELIEF_MIN_COST
                && cost >= MID_RELIEF_FACTOR * my_cost.max(1.0)
                && best.map_or(true, |(c, _)| cost > c)
            {
                best = Some((cost, i));
            }
        }
        if let Some((_, vi)) = best {
            let _ = peers[vi].gauges.evict_to.compare_exchange(
                0, thief + 1, Ordering::AcqRel, Ordering::Relaxed);
        }
        None
    }

    /// Hand `job` to the compatible, open sibling of `from` with the
    /// lowest lazy-discounted backlog (drain-by-migration and the
    /// graceful half of crash recovery). Full gauge transfer moves with
    /// the job — destination first, then the `from` side — exactly like
    /// a queued-job steal. Returns the destination replica id, or the
    /// job back when no sibling can take it (the caller re-admits it
    /// locally: placement is an optimization, never a place work can
    /// be lost).
    pub fn place(&self, from: usize, job: PoolJob)
                 -> Result<usize, PoolJob> {
        let peers = self.peers.lock().unwrap_or_else(|p| p.into_inner());
        let mut order: Vec<(f64, u64, usize)> = peers
            .iter()
            .enumerate()
            .filter(|(_, p)| {
                p.id != from
                    && !p.gauges.finished.load(Ordering::Acquire)
                    && p.admits(job.slo(), job.lanes())
            })
            .map(|(i, p)| {
                let s = p.gauges.snapshot(&p.tier);
                (lazy_cost(&s), s.predicted_cost_milli, i)
            })
            .collect();
        // least-loaded first; priced backlog breaks step-heuristic ties
        order.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.1.cmp(&b.1))
                .then_with(|| a.2.cmp(&b.2))
        });
        let mut job = job;
        for (_, _, i) in order {
            match transfer(&peers, from, i, job, true) {
                Ok(dest) => return Ok(dest),
                Err(j) => job = j,
            }
        }
        Err(job)
    }

    /// Push `job` to the specific replica `to` (mid-trajectory relief:
    /// the victim answers the thief that asked). Validates the thief's
    /// live compatibility and queue state; on failure the job comes
    /// back and the caller re-admits locally.
    pub fn push_to(&self, from: usize, to: usize, job: PoolJob)
                   -> Result<usize, PoolJob> {
        let peers = self.peers.lock().unwrap_or_else(|p| p.into_inner());
        let Some(idx) = peers.iter().position(|p| p.id == to) else {
            return Err(job);
        };
        let p = &peers[idx];
        if p.gauges.finished.load(Ordering::Acquire)
            || !p.admits(job.slo(), job.lanes())
        {
            return Err(job);
        }
        transfer(&peers, from, idx, job, true)
    }

    /// [`Self::place`] for a replica whose worker is already dead
    /// (crash resume): only the destination's gauges are credited — the
    /// panic handler resolves the dead side's whole ledger wholesale,
    /// so per-job decrements here would double-count.
    pub fn place_from_dead(&self, from: usize, job: PoolJob)
                           -> Result<usize, PoolJob> {
        let peers = self.peers.lock().unwrap_or_else(|p| p.into_inner());
        let mut order: Vec<(f64, u64, usize)> = peers
            .iter()
            .enumerate()
            .filter(|(_, p)| {
                p.id != from
                    && !p.gauges.finished.load(Ordering::Acquire)
                    && p.admits(job.slo(), job.lanes())
            })
            .map(|(i, p)| {
                let s = p.gauges.snapshot(&p.tier);
                (lazy_cost(&s), s.predicted_cost_milli, i)
            })
            .collect();
        order.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.1.cmp(&b.1))
                .then_with(|| a.2.cmp(&b.2))
        });
        let mut job = job;
        for (_, _, i) in order {
            match transfer(&peers, from, i, job, false) {
                Ok(dest) => return Ok(dest),
                Err(j) => job = j,
            }
        }
        Err(job)
    }
}

/// Ask for mid-trajectory relief only from victims whose effective
/// backlog is at least this many full-cost steps…
const MID_RELIEF_MIN_COST: f64 = 8.0;
/// …and at least this multiple of the thief's own effective backlog
/// ("dwarfs", not "exceeds" — eviction costs a flush + re-sync, so the
/// imbalance must be worth it).
const MID_RELIEF_FACTOR: f64 = 4.0;

/// Move one job into `peers[to_idx]`'s queue with gauge transfer,
/// destination first. When `from_side` is set, the `from` replica's
/// gauges give the accounting up (live migration); when clear, the dead
/// side is settled elsewhere (crash resume). On a full/closed queue the
/// destination's optimistic credit unwinds and the job returns.
fn transfer(peers: &[StealPeer], from: usize, to_idx: usize, job: PoolJob,
            from_side: bool) -> Result<usize, PoolJob> {
    let dest = &peers[to_idx];
    let steps = job.remaining_steps();
    let cost = job.cost_milli;
    dest.gauges.queued.fetch_add(1, Ordering::Relaxed);
    dest.gauges.pending_steps.fetch_add(steps, Ordering::Relaxed);
    dest.gauges
        .predicted_cost_milli
        .fetch_add(cost, Ordering::Relaxed);
    match dest.queue.try_push(job) {
        Ok(()) => {
            if from_side {
                if let Some(v) = peers.iter().find(|p| p.id == from) {
                    dec(&v.gauges.queued, 1);
                    dec(&v.gauges.pending_steps, steps);
                    dec_u64(&v.gauges.predicted_cost_milli, cost);
                }
            }
            Ok(dest.id)
        }
        Err(j) => {
            dec(&dest.gauges.queued, 1);
            dec(&dest.gauges.pending_steps, steps);
            dec_u64(&dest.gauges.predicted_cost_milli, cost);
            Err(j)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Slo;
    use crate::coordinator::request::{Request, RequestResult};
    use std::sync::mpsc;

    /// A peer with no worker thread behind it — gauges and queue are
    /// driven by hand so migrations are fully deterministic.
    fn peer(id: usize) -> StealPeer {
        peer_tiered(id, ReplicaTier::default())
    }

    fn peer_tiered(id: usize, tier: ReplicaTier) -> StealPeer {
        StealPeer {
            id,
            queue: BoundedQueue::new(64),
            gauges: Arc::new(ReplicaGauges::default()),
            tier,
        }
    }

    fn enqueue(p: &StealPeer, steps: usize, seed: u64)
               -> mpsc::Receiver<RequestResult> {
        enqueue_slo(p, steps, seed, Slo::Besteffort)
    }

    fn enqueue_slo(p: &StealPeer, steps: usize, seed: u64, slo: Slo)
                   -> mpsc::Receiver<RequestResult> {
        let (tx, rx) = mpsc::channel();
        // mirror the router's optimistic accounting at dispatch;
        // single-lane (no CFG) so B1 thieves are lane-eligible and the
        // tests exercise the SLO constraint in isolation
        let mut req = Request::new(0, 1, steps, seed).with_slo(slo);
        req.cfg_scale = 1.0;
        p.gauges.queued.fetch_add(1, Ordering::Relaxed);
        p.gauges.pending_steps.fetch_add(steps, Ordering::Relaxed);
        p.queue
            .try_push(PoolJob::fresh(req, tx, 0))
            .map_err(|_| "push")
            .unwrap();
        rx
    }

    fn seed_of(job: &PoolJob) -> u64 {
        match &job.payload {
            crate::coordinator::pool::replica::JobPayload::Fresh(r) => r.seed,
            crate::coordinator::pool::replica::JobPayload::Resumed(s) => {
                s.req.seed
            }
        }
    }

    #[test]
    fn steal_transfers_job_and_gauges_exactly_once() {
        let rb = Rebalancer::new(2);
        rb.register(vec![peer(0), peer(1)]);
        let peers = rb.peers.lock().unwrap();
        let _rx = enqueue(&peers[0], 7, 1);
        drop(peers);

        let job = rb.steal_for(1).expect("job should migrate");
        assert_eq!(job.remaining_steps(), 7);
        let peers = rb.peers.lock().unwrap();
        // victim fully relieved…
        assert_eq!(peers[0].gauges.queued.load(Ordering::Relaxed), 0);
        assert_eq!(peers[0].gauges.pending_steps.load(Ordering::Relaxed), 0);
        assert_eq!(peers[0].gauges.stolen.load(Ordering::Relaxed), 1);
        // …thief owns exactly the migrated amounts…
        assert_eq!(peers[1].gauges.queued.load(Ordering::Relaxed), 1);
        assert_eq!(peers[1].gauges.pending_steps.load(Ordering::Relaxed), 7);
        assert_eq!(peers[1].gauges.steals.load(Ordering::Relaxed), 1);
        // …and the queue is empty: the job exists in exactly one place
        assert!(peers[0].queue.is_empty());
        drop(peers);
        assert_eq!(rb.total_steals(), 1);
        assert!(rb.steal_for(1).is_none(), "nothing left to steal");
    }

    #[test]
    fn victim_choice_follows_lazy_discounted_backlog() {
        // peer 0: big raw backlog but Γ=0.9 → effective cost small
        // peer 2: smaller raw backlog at Γ=0 → effective cost largest
        let rb = Rebalancer::new(2);
        rb.register(vec![peer(0), peer(1), peer(2)]);
        let peers = rb.peers.lock().unwrap();
        let _rx0 = enqueue(&peers[0], 100, 1);
        peers[0].gauges.modules_seen.store(100, Ordering::Relaxed);
        peers[0].gauges.modules_skipped.store(90, Ordering::Relaxed);
        let _rx2 = enqueue(&peers[2], 60, 2);
        drop(peers);

        // cost(0) = 100·(1−0.9) = 10, cost(2) = 60·(1−0) = 60 → steal
        // from peer 2 even though peer 0 queues more raw steps
        let job = rb.steal_for(1).expect("steal");
        assert_eq!(job.remaining_steps(), 60);
        let peers = rb.peers.lock().unwrap();
        assert_eq!(peers[2].gauges.stolen.load(Ordering::Relaxed), 1);
        assert_eq!(peers[0].gauges.stolen.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn thief_never_steals_from_itself_or_unregistered_pool() {
        let rb = Rebalancer::new(1);
        assert!(rb.steal_for(0).is_none(), "no peers registered yet");
        rb.register(vec![peer(0)]);
        let peers = rb.peers.lock().unwrap();
        let _rx = enqueue(&peers[0], 5, 1);
        drop(peers);
        assert!(rb.steal_for(0).is_none(), "own queue is not a victim");
        let peers = rb.peers.lock().unwrap();
        assert_eq!(peers[0].gauges.queued.load(Ordering::Relaxed), 1,
                   "gauges untouched when nothing migrates");
    }

    #[test]
    fn latency_thief_never_steals_a_throughput_job() {
        // victim: B8 throughput replica holding one throughput job;
        // thief: B1 latency replica — its tier cannot honor the job's
        // SLO, so the steal must not happen (the satellite's "a B1
        // latency replica never steals a B8-only throughput job")
        let rb = Rebalancer::new(1);
        rb.register(vec![
            peer_tiered(0, ReplicaTier::new(Slo::Throughput, 8)),
            peer_tiered(1, ReplicaTier::new(Slo::Latency, 1)),
        ]);
        let peers = rb.peers.lock().unwrap();
        let _rx = enqueue_slo(&peers[0], 9, 1, Slo::Throughput);
        drop(peers);
        assert!(rb.steal_for(1).is_none(),
                "latency tier must not migrate a throughput job");
        let peers = rb.peers.lock().unwrap();
        assert_eq!(peers[0].gauges.queued.load(Ordering::Relaxed), 1,
                   "job and gauges stay with the victim");
        assert_eq!(peers[0].gauges.stolen.load(Ordering::Relaxed), 0);
        drop(peers);
        assert_eq!(rb.total_steals(), 0);
        // the throughput sibling CAN take it
        rb.register(vec![
            peer_tiered(0, ReplicaTier::new(Slo::Throughput, 8)),
            peer_tiered(1, ReplicaTier::new(Slo::Throughput, 8)),
        ]);
        let peers = rb.peers.lock().unwrap();
        let _rx = enqueue_slo(&peers[0], 9, 1, Slo::Throughput);
        drop(peers);
        assert!(rb.steal_for(1).is_some());
    }

    #[test]
    fn constrained_thief_skips_over_ineligible_tail() {
        // victim queue (front→back): [besteffort, throughput] — the
        // newest job is off-limits to a latency thief, but the older
        // best-effort one is fair game and must migrate without
        // disturbing the throughput job
        let rb = Rebalancer::new(1);
        rb.register(vec![
            peer_tiered(0, ReplicaTier::new(Slo::Throughput, 8)),
            peer_tiered(1, ReplicaTier::new(Slo::Latency, 1)),
        ]);
        let peers = rb.peers.lock().unwrap();
        let _rx1 = enqueue_slo(&peers[0], 3, 10, Slo::Besteffort);
        let _rx2 = enqueue_slo(&peers[0], 4, 20, Slo::Throughput);
        drop(peers);
        let job = rb.steal_for(1).expect("best-effort job migrates");
        assert_eq!(seed_of(&job), 10, "the eligible (older) job was taken");
        let peers = rb.peers.lock().unwrap();
        assert_eq!(peers[0].queue.len(), 1, "throughput job left in place");
        assert_eq!(peers[0].gauges.pending_steps.load(Ordering::Relaxed), 4);
        assert_eq!(peers[1].gauges.pending_steps.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn narrow_thief_never_steals_a_job_wider_than_its_batch() {
        // a 2-lane CFG best-effort job is SLO-compatible with a latency
        // thief, but a B1 replica could never plan a round containing
        // it — the lane-fit check must block the migration
        let rb = Rebalancer::new(1);
        rb.register(vec![
            peer_tiered(0, ReplicaTier::new(Slo::Throughput, 8)),
            peer_tiered(1, ReplicaTier::new(Slo::Latency, 1)),
        ]);
        let peers = rb.peers.lock().unwrap();
        let (tx, _rx) = mpsc::channel();
        let req = Request::new(0, 1, 5, 77); // cfg_scale 1.5 → 2 lanes
        assert_eq!(req.lanes(), 2);
        peers[0].gauges.queued.fetch_add(1, Ordering::Relaxed);
        peers[0].gauges.pending_steps.fetch_add(5, Ordering::Relaxed);
        peers[0]
            .queue
            .try_push(PoolJob::fresh(req, tx, 0))
            .map_err(|_| "push")
            .unwrap();
        drop(peers);
        assert!(rb.steal_for(1).is_none(),
                "B1 thief must not take a 2-lane job");
        // a wide sibling can take it
        rb.register(vec![
            peer_tiered(0, ReplicaTier::new(Slo::Throughput, 8)),
            peer_tiered(1, ReplicaTier::new(Slo::Besteffort, 8)),
        ]);
        let peers = rb.peers.lock().unwrap();
        let (tx, _rx2) = mpsc::channel();
        peers[0].gauges.queued.fetch_add(1, Ordering::Relaxed);
        peers[0].gauges.pending_steps.fetch_add(5, Ordering::Relaxed);
        peers[0]
            .queue
            .try_push(PoolJob::fresh(Request::new(0, 1, 5, 78), tx, 0))
            .map_err(|_| "push")
            .unwrap();
        drop(peers);
        assert!(rb.steal_for(1).is_some());
    }

    #[test]
    fn admission_window_adapts_to_backlog_dispersion() {
        let rb = Rebalancer::new(4);
        let tier = ReplicaTier::new(Slo::Besteffort, 4);
        assert_eq!(rb.effective_window(&tier), 4, "balanced at birth");
        rb.register(vec![peer(0), peer(1), peer(2)]);
        // one replica hoards the backlog: mean 20, variance 800 ≫ 2·mean
        {
            let peers = rb.peers.lock().unwrap();
            peers[0].gauges.pending_steps.store(60, Ordering::Relaxed);
        }
        assert!(rb.steal_for(1).is_none(), "nothing queued to migrate");
        assert!(rb.window_shrunk(), "overdispersion must raise the signal");
        assert_eq!(rb.effective_window(&tier), 3, "window narrows one step");
        // a B1 tier never narrows below one trajectory
        assert_eq!(
            rb.effective_window(&ReplicaTier::new(Slo::Latency, 1)),
            1
        );
        // balance restored ⇒ the constant window comes back — via the
        // BUSY path: no steal_for (nobody idles), effective_window's
        // rate-limited refresh must clear the stale signal by itself
        {
            let peers = rb.peers.lock().unwrap();
            for p in peers.iter() {
                p.gauges.pending_steps.store(20, Ordering::Relaxed);
            }
        }
        std::thread::sleep(SHRINK_REFRESH + SHRINK_REFRESH);
        assert_eq!(rb.effective_window(&tier), 4,
                   "a saturated pool must not run on a frozen signal");
        assert!(!rb.window_shrunk(), "balanced pool clears the signal");
        // an idle pool (all zero) is balanced too
        {
            let peers = rb.peers.lock().unwrap();
            for p in peers.iter() {
                p.gauges.pending_steps.store(0, Ordering::Relaxed);
            }
        }
        assert!(rb.steal_for(1).is_none());
        assert!(!rb.window_shrunk());
    }

    #[test]
    fn healthy_heterogeneous_pool_never_shrinks_the_window() {
        // the documented tiered shape lat:b1x1 + thr:b8x3 under steady
        // balanced load: the latency replica's backlog is inherently
        // tiny next to the throughput replicas' deep ones. Dispersion
        // is judged within tier groups, so this must NOT read as
        // overdispersion (pool-wide variance would trip it forever)
        let rb = Rebalancer::new(8);
        rb.register(vec![
            peer_tiered(0, ReplicaTier::new(Slo::Latency, 1)),
            peer_tiered(1, ReplicaTier::new(Slo::Throughput, 8)),
            peer_tiered(2, ReplicaTier::new(Slo::Throughput, 8)),
            peer_tiered(3, ReplicaTier::new(Slo::Throughput, 8)),
        ]);
        {
            let peers = rb.peers.lock().unwrap();
            peers[0].gauges.pending_steps.store(8, Ordering::Relaxed);
            for p in peers.iter().skip(1) {
                p.gauges.pending_steps.store(160, Ordering::Relaxed);
            }
        }
        assert!(rb.steal_for(0).is_none(), "nothing queued");
        assert!(!rb.window_shrunk(),
                "healthy tier heterogeneity is not clumping");
        // but clumping WITHIN the throughput group still trips it
        {
            let peers = rb.peers.lock().unwrap();
            peers[1].gauges.pending_steps.store(480, Ordering::Relaxed);
            peers[2].gauges.pending_steps.store(0, Ordering::Relaxed);
            peers[3].gauges.pending_steps.store(0, Ordering::Relaxed);
        }
        assert!(rb.steal_for(0).is_none());
        assert!(rb.window_shrunk(),
                "same-tier imbalance must raise the signal");
    }

    #[test]
    fn steals_newest_job_first() {
        // thieves take the back of the deque — the job the owner would
        // reach last — so FIFO fairness on the victim is preserved
        let rb = Rebalancer::new(1);
        rb.register(vec![peer(0), peer(1)]);
        let peers = rb.peers.lock().unwrap();
        let _rx1 = enqueue(&peers[0], 3, 11);
        let _rx2 = enqueue(&peers[0], 4, 22);
        drop(peers);
        let job = rb.steal_for(1).expect("steal");
        assert_eq!(seed_of(&job), 22, "back of the queue migrates first");
    }

    fn resumed_job(id: u64, steps: usize, cursor: usize, slo: Slo)
                   -> (PoolJob, mpsc::Receiver<RequestResult>) {
        use crate::coordinator::request::TrajectorySnapshot;
        let (tx, rx) = mpsc::channel();
        let mut req = Request::new(id, 1, steps, id).with_slo(slo);
        req.cfg_scale = 1.0;
        let snap = TrajectorySnapshot {
            req,
            timesteps: vec![0; steps],
            cursor,
            z: Vec::new(),
            caches: Vec::new(),
            skip_counts: Vec::new(),
            modules_seen: Vec::new(),
            admitted_us: 1,
            steps_done: cursor,
        };
        (PoolJob::resumed(snap, tx, 0), rx)
    }

    #[test]
    fn place_moves_snapshot_and_gauges_to_least_loaded_sibling() {
        let rb = Rebalancer::new(2);
        rb.register(vec![peer(0), peer(1), peer(2)]);
        {
            let peers = rb.peers.lock().unwrap();
            // the evicting replica owns the trajectory's ledger entry
            peers[0].gauges.queued.fetch_add(1, Ordering::Relaxed);
            peers[0].gauges.pending_steps.fetch_add(6, Ordering::Relaxed);
            // sibling 1 is busier than sibling 2
            peers[1].gauges.pending_steps.fetch_add(40, Ordering::Relaxed);
        }
        let (job, _rx) = resumed_job(9, 10, 4, Slo::Besteffort);
        assert_eq!(job.remaining_steps(), 6, "pending = steps − cursor");
        let dest = rb.place(0, job).map_err(|_| "place").unwrap();
        assert_eq!(dest, 2, "lowest effective backlog wins");
        let peers = rb.peers.lock().unwrap();
        assert_eq!(peers[0].gauges.queued.load(Ordering::Relaxed), 0);
        assert_eq!(peers[0].gauges.pending_steps.load(Ordering::Relaxed), 0);
        assert_eq!(peers[2].gauges.queued.load(Ordering::Relaxed), 1);
        assert_eq!(peers[2].gauges.pending_steps.load(Ordering::Relaxed), 6,
                   "only the REMAINING steps migrate");
        assert_eq!(peers[2].queue.len(), 1);
    }

    #[test]
    fn place_respects_live_retag_compatibility() {
        // sibling 1 was provisioned throughput but retagged latency:
        // a throughput snapshot must NOT land there, and with no other
        // sibling the job comes back for local resumption
        let rb = Rebalancer::new(2);
        rb.register(vec![
            peer_tiered(0, ReplicaTier::new(Slo::Throughput, 8)),
            peer_tiered(1, ReplicaTier::new(Slo::Throughput, 8)),
        ]);
        {
            let peers = rb.peers.lock().unwrap();
            peers[1].gauges.slo_tag.store(
                Slo::Latency.index() + 1, Ordering::Release);
        }
        let (job, _rx) = resumed_job(5, 8, 2, Slo::Throughput);
        assert!(rb.place(0, job).is_err(),
                "retagged sibling no longer serves throughput");
        // the reverse retag opens it up
        {
            let peers = rb.peers.lock().unwrap();
            peers[1].gauges.slo_tag.store(0, Ordering::Release);
        }
        let (job, _rx) = resumed_job(6, 8, 2, Slo::Throughput);
        assert_eq!(rb.place(0, job).map_err(|_| "place").unwrap(), 1);
    }

    #[test]
    fn push_to_validates_target_and_returns_job_on_mismatch() {
        let rb = Rebalancer::new(2);
        rb.register(vec![
            peer_tiered(0, ReplicaTier::new(Slo::Besteffort, 8)),
            peer_tiered(1, ReplicaTier::new(Slo::Latency, 1)),
        ]);
        let (job, _rx) = resumed_job(3, 6, 1, Slo::Throughput);
        let back = rb.push_to(0, 1, job)
            .err()
            .expect("latency thief cannot take a throughput snapshot");
        assert_eq!(back.remaining_steps(), 5, "job intact for local resume");
        let (job, _rx) = resumed_job(4, 6, 1, Slo::Latency);
        assert_eq!(rb.push_to(0, 1, job).map_err(|_| "push").unwrap(), 1);
        let peers = rb.peers.lock().unwrap();
        assert_eq!(peers[1].gauges.pending_steps.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn place_from_dead_credits_only_the_destination() {
        let rb = Rebalancer::new(2);
        rb.register(vec![peer(0), peer(1)]);
        {
            // the dead replica's ledger is settled by the panic
            // handler, not per-job — seed it to prove it is untouched
            let peers = rb.peers.lock().unwrap();
            peers[0].gauges.queued.fetch_add(1, Ordering::Relaxed);
            peers[0].gauges.pending_steps.fetch_add(7, Ordering::Relaxed);
        }
        let (job, _rx) = resumed_job(8, 9, 2, Slo::Besteffort);
        assert_eq!(rb.place_from_dead(0, job).map_err(|_| "p").unwrap(), 1);
        let peers = rb.peers.lock().unwrap();
        assert_eq!(peers[0].gauges.queued.load(Ordering::Relaxed), 1,
                   "dead side untouched (handler settles it wholesale)");
        assert_eq!(peers[0].gauges.pending_steps.load(Ordering::Relaxed), 7);
        assert_eq!(peers[1].gauges.queued.load(Ordering::Relaxed), 1);
        assert_eq!(peers[1].gauges.pending_steps.load(Ordering::Relaxed), 7);
    }

    /// Enqueue a calendar-priced single-lane job, mirroring the
    /// router's optimistic accounting including the priced gauge.
    fn enqueue_priced(p: &StealPeer, steps: usize, seed: u64, cost: u64)
                      -> mpsc::Receiver<RequestResult> {
        let (tx, rx) = mpsc::channel();
        let mut req = Request::new(0, 1, steps, seed);
        req.cfg_scale = 1.0;
        let mut job = PoolJob::fresh(req, tx, 0);
        job.cost_milli = cost;
        p.gauges.queued.fetch_add(1, Ordering::Relaxed);
        p.gauges.pending_steps.fetch_add(steps, Ordering::Relaxed);
        p.gauges.predicted_cost_milli.fetch_add(cost, Ordering::Relaxed);
        p.queue.try_push(job).map_err(|_| "push").unwrap();
        rx
    }

    #[test]
    fn priced_backlog_breaks_victim_ties_and_rides_with_the_steal() {
        // victims 0 and 2 tie exactly on the step heuristic (same
        // backlog, Γ=0); the calendar-priced gauge must decide, and the
        // price must migrate with the job like the other gauges
        let rb = Rebalancer::new(2);
        rb.register(vec![peer(0), peer(1), peer(2)]);
        let peers = rb.peers.lock().unwrap();
        let _rx0 = enqueue_priced(&peers[0], 10, 40, 2_000);
        let _rx2 = enqueue_priced(&peers[2], 10, 41, 9_000);
        drop(peers);
        let job = rb.steal_for(1).expect("steal");
        assert_eq!(seed_of(&job), 41, "pricier victim is relieved first");
        assert_eq!(job.cost_milli, 9_000, "price rides with the job");
        let peers = rb.peers.lock().unwrap();
        assert_eq!(
            peers[2].gauges.predicted_cost_milli.load(Ordering::Relaxed),
            0, "victim gives the priced accounting up"
        );
        assert_eq!(
            peers[1].gauges.predicted_cost_milli.load(Ordering::Relaxed),
            9_000, "thief owns exactly the migrated price"
        );
        assert_eq!(
            peers[0].gauges.predicted_cost_milli.load(Ordering::Relaxed),
            2_000, "bystander untouched"
        );
    }

    #[test]
    fn idle_thief_requests_mid_trajectory_relief_from_dwarfing_victim() {
        let rb = Rebalancer::new(2);
        rb.register(vec![peer(0), peer(1)]);
        {
            // victim 0: two residents, deep engine backlog, empty queue
            let peers = rb.peers.lock().unwrap();
            peers[0].gauges.queued.store(2, Ordering::Relaxed);
            peers[0].gauges.pending_steps.store(50, Ordering::Relaxed);
        }
        assert!(rb.steal_for(1).is_none(), "nothing queued to steal");
        let peers = rb.peers.lock().unwrap();
        assert_eq!(peers[0].gauges.evict_to.load(Ordering::Relaxed), 2,
                   "victim asked to evict one resident to thief 1");
        drop(peers);
        // a lone-resident victim is never asked, however deep
        rb.register(vec![peer(0), peer(1)]);
        {
            let peers = rb.peers.lock().unwrap();
            peers[0].gauges.queued.store(1, Ordering::Relaxed);
            peers[0].gauges.pending_steps.store(500, Ordering::Relaxed);
        }
        assert!(rb.steal_for(1).is_none());
        let peers = rb.peers.lock().unwrap();
        assert_eq!(peers[0].gauges.evict_to.load(Ordering::Relaxed), 0,
                   "never ping-pong a lone trajectory");
    }
}
