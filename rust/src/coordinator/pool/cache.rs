//! Content-addressable result + warm-start cache fronting the router.
//!
//! Two tiers, both keyed off the canonical
//! [`RequestKey`](crate::coordinator::request::RequestKey):
//!
//! * **exact-result cache** — `(label, cfg, steps, seed, model)` →
//!   finished [`RequestResult`], bounded LRU. A hit returns the stored
//!   output with zero engine work; the router still settles the
//!   admission ledger (the conservation law grows a `cache_hits` term).
//! * **warm-start donor store** — per
//!   [`FamilyKey`](crate::coordinator::request::FamilyKey) (the exact
//!   key minus the seed), an early-step boundary
//!   [`TrajectorySnapshot`] trimmed to its lane caches. On a near hit
//!   (same family, different seed) the joiner's `LaneCaches` are seeded
//!   from the donor so it enters the batch with valid rows instead of
//!   cold ones — converting `rows_denied_cold` into skips.
//!
//! Safety model: the exact tier is sound because equal keys imply
//! bit-identical outputs (the key covers every output-affecting request
//! field — propcheck-asserted below against the SimEngine). The warm
//! tier is an approximation bounded by `warm_horizon`: only donors
//! captured at a step boundary **within** the horizon are admitted
//! (Δ-DiT: trajectory deviations concentrate in late steps, so
//! early-step caches are safe to share), and a donor whose lane shapes
//! do not match the joiner is rejected at admission — the joiner then
//! runs cold, which is always correct.
//!
//! Concurrency: both tiers sit behind plain mutexes — the cache is
//! touched once per dispatch/completion, never inside the per-step hot
//! path — while the observability counters are relaxed atomics readable
//! without the locks.

use crate::coordinator::request::{FamilyKey, Request, RequestKey,
                                  RequestResult, TrajectorySnapshot};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Pool-cache provisioning: capacities, the warm-start horizon, and the
/// model identity baked into every key.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Exact-result LRU bound (entries). 0 disables the exact tier.
    pub result_capacity: usize,
    /// Donor-store bound (families). 0 disables the warm tier together
    /// with `warm_horizon`.
    pub donor_capacity: usize,
    /// Step horizon for warm starts: only donors whose boundary cursor
    /// is in `1..=warm_horizon` may seed a joiner. 0 disables
    /// warm-starting entirely (nothing is ever transferred — a
    /// horizon-0 admission is bit-identical to a cold run).
    pub warm_horizon: usize,
    /// Serving model / resolution discriminator mixed into every key
    /// (see [`RequestKey::model_params`]).
    pub model_params: u64,
}

impl CacheConfig {
    /// A config with both tiers sized `capacity` and the given horizon.
    pub fn new(capacity: usize, warm_horizon: usize,
               model_params: u64) -> CacheConfig {
        CacheConfig {
            result_capacity: capacity,
            donor_capacity: capacity,
            warm_horizon,
            model_params,
        }
    }
}

/// Point-in-time cache counters (`STATS`, pool report, benches).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Exact-tier lookups that returned a finished result.
    pub hits: u64,
    /// Exact-tier lookups that found nothing (engine work follows).
    pub misses: u64,
    /// Results inserted into the exact tier.
    pub inserted: u64,
    /// Results evicted by the LRU bound.
    pub evicted: u64,
    /// Live exact-tier entries.
    pub entries: u64,
    /// Donors handed out to warm-start a joiner.
    pub donated: u64,
    /// Donor offers rejected (past the horizon, no boundary yet, or
    /// inconsistent lane shapes).
    pub donor_rejected: u64,
    /// Live donor families.
    pub donors: u64,
}

struct ResultEntry {
    last_used: u64,
    res: RequestResult,
}

#[derive(Default)]
struct ResultLru {
    map: BTreeMap<RequestKey, ResultEntry>,
    tick: u64,
}

struct DonorEntry {
    inserted: u64,
    snap: TrajectorySnapshot,
}

#[derive(Default)]
struct DonorStore {
    map: BTreeMap<FamilyKey, DonorEntry>,
    tick: u64,
}

/// The two-tier content-addressable cache. One instance is shared
/// (`Arc`) between the router (exact-hit check at dispatch) and every
/// replica worker (result insertion + donor offers at step boundaries,
/// donor lookup at admission).
pub struct PoolCache {
    cfg: CacheConfig,
    /// The warm-start horizon currently in force. Seeded from
    /// [`CacheConfig::warm_horizon`]; the brownout controller widens it
    /// under overload (stage 1) and restores it on recovery, so it is
    /// an atomic rather than plain config.
    effective_horizon: AtomicUsize,
    results: Mutex<ResultLru>,
    donors: Mutex<DonorStore>,
    hits: AtomicU64,
    misses: AtomicU64,
    inserted: AtomicU64,
    evicted: AtomicU64,
    donated: AtomicU64,
    donor_rejected: AtomicU64,
}

impl PoolCache {
    /// An empty cache with the given provisioning.
    pub fn new(cfg: CacheConfig) -> PoolCache {
        PoolCache {
            effective_horizon: AtomicUsize::new(cfg.warm_horizon),
            cfg,
            results: Mutex::new(ResultLru::default()),
            donors: Mutex::new(DonorStore::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserted: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            donated: AtomicU64::new(0),
            donor_rejected: AtomicU64::new(0),
        }
    }

    /// The provisioning this cache runs under.
    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// True when the exact-result tier is live.
    pub fn exact_enabled(&self) -> bool {
        self.cfg.result_capacity > 0
    }

    /// True when the warm-start tier is live (under the *effective*
    /// horizon, so a brownout widening from 0 turns the tier on).
    pub fn warm_enabled(&self) -> bool {
        self.warm_horizon() > 0 && self.cfg.donor_capacity > 0
    }

    /// The warm-start horizon currently in force (the configured value
    /// unless the brownout controller has overridden it).
    pub fn warm_horizon(&self) -> usize {
        self.effective_horizon.load(Ordering::Relaxed)
    }

    /// Override the effective warm-start horizon. Widening trades
    /// fidelity for availability (deeper donors admitted); callers
    /// restore the configured value on recovery.
    pub fn set_warm_horizon(&self, horizon: usize) {
        self.effective_horizon.store(horizon, Ordering::Relaxed);
    }

    /// The canonical key of `req` under this cache's model identity.
    pub fn key_of(&self, req: &Request) -> RequestKey {
        req.key(self.cfg.model_params)
    }

    /// Exact-tier lookup: a completed result for `req`'s key, or `None`
    /// (counted as a miss) when engine work is needed. The returned
    /// result still carries the *original* run's accounting; the caller
    /// re-stamps wire identity (`id`, `slo`, latency) for this request.
    pub fn lookup(&self, req: &Request) -> Option<RequestResult> {
        if !self.exact_enabled() {
            return None;
        }
        let key = self.key_of(req);
        let mut lru = self.results.lock().unwrap_or_else(|p| p.into_inner());
        lru.tick += 1;
        let tick = lru.tick;
        match lru.map.get_mut(&key) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.res.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a finished result under `key`, evicting the
    /// least-recently-used entry past the bound. Called by the replica
    /// worker at completion, *before* the response is sent, so a client
    /// that immediately repeats the request observes the hit.
    pub fn insert(&self, key: RequestKey, res: &RequestResult) {
        if !self.exact_enabled() {
            return;
        }
        let mut lru = self.results.lock().unwrap_or_else(|p| p.into_inner());
        lru.tick += 1;
        let tick = lru.tick;
        let fresh = lru
            .map
            .insert(key, ResultEntry { last_used: tick, res: res.clone() })
            .is_none();
        if fresh {
            self.inserted.fetch_add(1, Ordering::Relaxed);
        }
        while lru.map.len() > self.cfg.result_capacity {
            let Some(oldest) = lru
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
            else {
                break;
            };
            lru.map.remove(&oldest);
            self.evicted.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Offer a boundary snapshot as a warm-start donor for its family.
    /// Rejected (returns `false`, counted) when warm-starting is off,
    /// the snapshot has no completed boundary (`cursor == 0`), its
    /// cursor is **past the step horizon** (stale — late-step caches
    /// are not safe to share), or its lane-cache shapes are internally
    /// inconsistent. Accepted donors are stored trimmed
    /// ([`TrajectorySnapshot::donor_trim`]); an existing family donor
    /// is replaced only by one with a deeper (still in-horizon) cursor.
    pub fn offer_donor(&self, snap: &TrajectorySnapshot) -> bool {
        if !self.warm_enabled()
            || snap.cursor == 0
            || snap.cursor > self.warm_horizon()
            || !lane_shapes_consistent(snap)
        {
            self.donor_rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let family = snap.req.key(self.cfg.model_params).family();
        let mut store = self.donors.lock().unwrap_or_else(|p| p.into_inner());
        store.tick += 1;
        let tick = store.tick;
        if let Some(existing) = store.map.get(&family) {
            if existing.snap.cursor >= snap.cursor {
                return true; // the deeper donor already on file wins
            }
        }
        store.map.insert(family, DonorEntry {
            inserted: tick,
            snap: snap.donor_trim(),
        });
        while store.map.len() > self.cfg.donor_capacity {
            let Some(oldest) = store
                .map
                .iter()
                .min_by_key(|(_, e)| e.inserted)
                .map(|(k, _)| *k)
            else {
                break;
            };
            store.map.remove(&oldest);
        }
        true
    }

    /// Near-hit lookup: a donor for `req`'s family, validated against
    /// the joiner — the donor's lane count must match `req.lanes()` and
    /// its cache shapes must be consistent, otherwise the donor is
    /// refused (counted) and the joiner runs cold. An exact-seed match
    /// is also refused: warm-starting a request from *its own* family
    /// donor with the same seed would be pointless (the exact tier owns
    /// that case).
    pub fn donate(&self, req: &Request) -> Option<TrajectorySnapshot> {
        if !self.warm_enabled() {
            return None;
        }
        let family = self.key_of(req).family();
        let store = self.donors.lock().unwrap_or_else(|p| p.into_inner());
        let entry = store.map.get(&family)?;
        let snap = &entry.snap;
        if snap.lanes() != req.lanes()
            || !lane_shapes_consistent(snap)
            || snap.cursor == 0
            || snap.cursor > self.warm_horizon()
        {
            self.donor_rejected.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        self.donated.fetch_add(1, Ordering::Relaxed);
        Some(snap.clone())
    }

    /// Point-in-time counter snapshot.
    pub fn stats(&self) -> CacheStats {
        let entries = self
            .results
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .map
            .len() as u64;
        let donors = self
            .donors
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .map
            .len() as u64;
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserted: self.inserted.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
            entries,
            donated: self.donated.load(Ordering::Relaxed),
            donor_rejected: self.donor_rejected.load(Ordering::Relaxed),
            donors,
        }
    }
}

/// A donor's lane caches are usable only when non-degenerate and
/// internally consistent: lane count matches the request's CFG shape
/// (when caches are materialized at all — the synthetic engine's
/// snapshots carry none and model warmth analytically), and every lane
/// has matching `values`/`valid` lengths with uniform row widths.
fn lane_shapes_consistent(snap: &TrajectorySnapshot) -> bool {
    if snap.caches.is_empty() {
        return true; // synthetic-engine donors: warmth is modeled
    }
    if snap.caches.len() != snap.lanes() {
        return false;
    }
    let nslots = snap.caches[0].values.len();
    let nd = snap.caches[0].values.first().map(Vec::len).unwrap_or(0);
    snap.caches.iter().all(|lane| {
        lane.values.len() == nslots
            && lane.valid.len() == nslots
            && lane.values.iter().all(|row| row.len() == nd)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Slo;
    use crate::coordinator::pool::sim::{SimEngine, SimSpec};
    use crate::coordinator::pool::PoolEngine;
    use crate::coordinator::request::{ActiveRequest, LaneCaches};
    use crate::prop_assert;
    use crate::util::propcheck::propcheck;

    fn result_for(req: &Request) -> RequestResult {
        RequestResult {
            id: req.id,
            class_label: req.class_label,
            steps: req.steps,
            slo: req.slo,
            image: crate::coordinator::pool::sim::sim_image(req, 16),
            lazy_ratio: 0.5,
            attn_lazy_ratio: 0.5,
            ffn_lazy_ratio: 0.5,
            latency: std::time::Duration::from_millis(3),
            per_module_skip: vec![0.5; 4],
        }
    }

    fn boundary_snapshot(req: Request, cursor: usize, depth: usize,
                         nd: usize) -> TrajectorySnapshot {
        let ts: Vec<usize> = (0..req.steps).rev().map(|i| i * 100 + 1)
            .collect();
        let mut ar = ActiveRequest::new(req, ts, depth, nd, 8);
        ar.cursor = cursor;
        ar.steps_done = cursor;
        for lc in ar.caches.iter_mut() {
            for k in 0..lc.valid.len() {
                lc.valid[k] = true;
            }
        }
        ar.into_snapshot()
    }

    #[test]
    fn exact_tier_is_a_bounded_lru() {
        let cache = PoolCache::new(CacheConfig::new(2, 0, 48));
        let reqs: Vec<Request> =
            (0..3).map(|i| Request::new(0, i, 4, 100 + i as u64)).collect();
        for r in &reqs {
            assert!(cache.lookup(r).is_none(), "cold cache");
            cache.insert(cache.key_of(r), &result_for(r));
        }
        // capacity 2: inserting the 3rd evicted the least recently used
        // (req 0 — req 1 and 2 were touched later)
        let st = cache.stats();
        assert_eq!(st.entries, 2);
        assert_eq!(st.inserted, 3);
        assert_eq!(st.evicted, 1);
        assert!(cache.lookup(&reqs[0]).is_none(), "LRU victim gone");
        let hit = cache.lookup(&reqs[2]).expect("resident entry");
        assert_eq!(hit.image.data(),
                   result_for(&reqs[2]).image.data(),
                   "the hit returns the stored image bit-exactly");
        // touch req 1 so req 2 becomes the LRU victim of the next insert
        assert!(cache.lookup(&reqs[1]).is_some());
        cache.insert(cache.key_of(&reqs[0]), &result_for(&reqs[0]));
        assert!(cache.lookup(&reqs[2]).is_none(), "recency order enforced");
        assert!(cache.lookup(&reqs[1]).is_some());
        // id / slo never partition the cache
        let mut alias = reqs[1].clone();
        alias.id = 999;
        alias.slo = Slo::Latency;
        assert!(cache.lookup(&alias).is_some(), "id/slo are not key fields");
    }

    #[test]
    fn zero_capacity_disables_the_exact_tier() {
        let cache = PoolCache::new(CacheConfig::new(0, 0, 48));
        let req = Request::new(0, 1, 4, 7);
        cache.insert(cache.key_of(&req), &result_for(&req));
        assert!(cache.lookup(&req).is_none());
        assert_eq!(cache.stats(), CacheStats::default(),
                   "a disabled tier counts nothing");
    }

    #[test]
    fn donor_store_rejects_stale_and_boundary_free_offers() {
        let cache = PoolCache::new(CacheConfig::new(4, 2, 8));
        let req = Request::new(0, 3, 6, 42);
        // no completed boundary yet: nothing valid to share
        assert!(!cache.offer_donor(
            &boundary_snapshot(req.clone(), 0, 2, 4)));
        // past the horizon (cursor 3 > horizon 2): stale, rejected
        assert!(!cache.offer_donor(
            &boundary_snapshot(req.clone(), 3, 2, 4)));
        assert_eq!(cache.stats().donor_rejected, 2);
        assert_eq!(cache.stats().donors, 0);
        // within the horizon: accepted
        assert!(cache.offer_donor(&boundary_snapshot(req.clone(), 1, 2, 4)));
        assert_eq!(cache.stats().donors, 1);
        // a deeper in-horizon donor replaces it; a shallower one doesn't
        assert!(cache.offer_donor(&boundary_snapshot(req.clone(), 2, 2, 4)));
        let mut probe = req.clone();
        probe.seed = 43; // near hit: same family, different seed
        assert_eq!(cache.donate(&probe).unwrap().cursor, 2);
        assert!(cache.offer_donor(&boundary_snapshot(req, 1, 2, 4)));
        assert_eq!(cache.donate(&probe).unwrap().cursor, 2,
                   "deeper donor retained");
    }

    #[test]
    fn donor_store_rejects_mismatched_lane_shapes_at_admission() {
        let cache = PoolCache::new(CacheConfig::new(4, 3, 8));
        let req = Request::new(0, 5, 6, 77); // cfg 1.5 → 2 lanes
        // a donor whose lane count contradicts its own CFG shape
        let mut bad = boundary_snapshot(req.clone(), 2, 2, 4);
        bad.caches.pop(); // 1 lane of caches on a 2-lane request
        assert!(!cache.offer_donor(&bad), "lane-count mismatch rejected");
        // a donor with ragged per-lane shapes
        let mut ragged = boundary_snapshot(req.clone(), 2, 2, 4);
        ragged.caches[1].valid.pop();
        assert!(!cache.offer_donor(&ragged), "ragged valid len rejected");
        let mut ragged = boundary_snapshot(req.clone(), 2, 2, 4);
        ragged.caches[0].values[1] = vec![0.0; 99];
        assert!(!cache.offer_donor(&ragged), "ragged row width rejected");
        assert_eq!(cache.stats().donors, 0);
        // a well-formed donor whose stored shape no longer matches the
        // joiner's lane count is refused at donate time too
        assert!(cache.offer_donor(&boundary_snapshot(req.clone(), 2, 2, 4)));
        let mut store = cache.donors.lock().unwrap();
        for e in store.map.values_mut() {
            e.snap.caches = vec![LaneCaches::empty(2, 4); 1];
        }
        drop(store);
        let mut probe = req;
        probe.seed = 78;
        assert!(cache.donate(&probe).is_none(),
                "doctored donor refused at admission");
        assert!(cache.stats().donor_rejected >= 4);
    }

    #[test]
    fn donor_families_are_bounded() {
        let mut cfg = CacheConfig::new(8, 2, 8);
        cfg.donor_capacity = 2;
        let cache = PoolCache::new(cfg);
        for label in 0..3 {
            let req = Request::new(0, label, 6, label as u64);
            assert!(cache.offer_donor(&boundary_snapshot(req, 1, 2, 4)));
        }
        assert_eq!(cache.stats().donors, 2, "oldest family evicted");
    }

    /// Key soundness, the property the exact tier's correctness rests
    /// on: two requests with equal `RequestKey`s produce bit-identical
    /// SimEngine outputs (so a cached result can never be wrong for the
    /// request it hits), and any single output-affecting field
    /// perturbation changes the key (so a different computation can
    /// never hit the entry).
    #[test]
    fn propcheck_equal_keys_imply_bit_identical_engine_outputs() {
        propcheck(40, |g| {
            let steps = g.usize_in(1, 5);
            let mut a = Request::new(0, g.usize_in(0, 9), steps, g.u64());
            a.cfg_scale = *g.choose(&[1.0f32, 1.5, 2.0]);
            // same key fields, different wire identity + SLO class
            let mut b = a.clone();
            b.id = 0;
            b.slo = *g.choose(&[Slo::Latency, Slo::Throughput,
                                Slo::Besteffort]);
            let spec = SimSpec {
                lazy_pct: g.usize_in(0, 90) as u32,
                ..SimSpec::fast()
            };
            prop_assert!(a.key(spec.img_elems as u64)
                         == b.key(spec.img_elems as u64),
                         "identity fields leaked into the key");
            let run = |req: Request, spec: &SimSpec| {
                let mut e = SimEngine::new(spec.clone());
                e.submit(req);
                let mut out = Vec::new();
                while e.active_count() > 0 {
                    out.extend(e.step_round().expect("sim step"));
                }
                out.remove(0).image.data().to_vec()
            };
            let img_a = run(a.clone(), &spec);
            let img_b = run(b, &spec);
            prop_assert!(
                img_a.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                    == img_b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "equal keys must mean bit-identical outputs");
            // every output-affecting perturbation must change the key
            let k = a.key(spec.img_elems as u64);
            let mut p = a.clone();
            p.class_label += 1;
            prop_assert!(p.key(spec.img_elems as u64) != k, "label");
            let mut p = a.clone();
            p.steps += 1;
            prop_assert!(p.key(spec.img_elems as u64) != k, "steps");
            let mut p = a.clone();
            p.seed = p.seed.wrapping_add(1);
            prop_assert!(p.key(spec.img_elems as u64) != k, "seed");
            let mut p = a.clone();
            p.cfg_scale += 0.25;
            prop_assert!(p.key(spec.img_elems as u64) != k, "cfg");
            prop_assert!(a.key(spec.img_elems as u64 + 1) != k,
                         "resolution/model params");
        });
    }
}
