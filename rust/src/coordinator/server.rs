//! TCP JSON-lines serving front-end with admission control.
//!
//! Protocol (one JSON object per line):
//!   request : {"label": 3, "steps": 20, "seed": 1, "cfg_scale": 1.5}
//!   response: {"id": 7, "latency_ms": 123.4, "lazy_ratio": 0.31,
//!              "attn_lazy": 0.35, "ffn_lazy": 0.27, "steps": 20}
//!   shed    : {"error": "queue full"}
//!
//! The engine is single-threaded (PJRT types are not Sync); acceptor
//! threads feed a bounded queue — backpressure is the queue bound, and
//! over-bound requests are shed immediately (admission control).

use crate::coordinator::engine::Engine;
use crate::coordinator::request::{Request, RequestResult};
use crate::util::json::Json;
use crate::util::threadpool::BoundedQueue;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;

/// A queued request with its response channel.
pub struct Pending {
    pub req: Request,
    pub respond: mpsc::Sender<RequestResult>,
}

/// Parse one request line into a Request (id assigned later).
pub fn parse_request_line(line: &str) -> Result<Request> {
    let j = Json::parse(line).context("request json")?;
    let label = j.req("label")?.as_usize().context("label")?;
    let steps = j.get("steps").and_then(|v| v.as_usize()).unwrap_or(20);
    let seed = j.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    let cfg_scale = j
        .get("cfg_scale")
        .and_then(|v| v.as_f64())
        .unwrap_or(1.5) as f32;
    let mut r = Request::new(0, label, steps, seed);
    r.cfg_scale = cfg_scale;
    Ok(r)
}

/// Format a response line.
pub fn format_response(res: &RequestResult) -> String {
    Json::obj(vec![
        ("id", Json::num(res.id as f64)),
        ("steps", Json::num(res.steps as f64)),
        ("label", Json::num(res.class_label as f64)),
        ("latency_ms", Json::num(res.latency.as_secs_f64() * 1e3)),
        ("lazy_ratio", Json::num(res.lazy_ratio)),
        ("attn_lazy", Json::num(res.attn_lazy_ratio)),
        ("ffn_lazy", Json::num(res.ffn_lazy_ratio)),
    ])
    .to_string()
}

fn handle_conn(stream: TcpStream, queue: BoundedQueue<Pending>) {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply = match parse_request_line(&line) {
            Ok(req) => {
                let (tx, rx) = mpsc::channel();
                match queue.try_push(Pending { req, respond: tx }) {
                    Ok(()) => match rx.recv() {
                        Ok(res) => format_response(&res),
                        Err(_) => r#"{"error":"engine stopped"}"#.to_string(),
                    },
                    Err(_) => r#"{"error":"queue full"}"#.to_string(),
                }
            }
            Err(e) => format!(r#"{{"error":"{e}"}}"#),
        };
        if writer.write_all(reply.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
        let _ = writer.flush();
    }
    log::debug!("connection from {peer:?} closed");
}

/// Run the serving loop: accept on `addr`, drive the engine until
/// `max_requests` have completed (0 = forever).
pub fn serve(mut engine: Engine, addr: &str, max_requests: usize) -> Result<()> {
    let queue: BoundedQueue<Pending> = BoundedQueue::new(engine.serve.queue_cap);
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    log::info!("serving on {addr} (config {})", engine.serve.config_name);

    let q2 = queue.clone();
    let acceptor = std::thread::Builder::new()
        .name("lazydit-acceptor".into())
        .spawn(move || loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    let q3 = q2.clone();
                    std::thread::spawn(move || handle_conn(stream, q3));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => {
                    log::warn!("accept error: {e}");
                    break;
                }
            }
        })?;

    let mut responders: std::collections::BTreeMap<u64, mpsc::Sender<RequestResult>> =
        Default::default();
    let mut served = 0usize;
    loop {
        // admit everything currently queued (bounded by queue cap)
        for p in queue.drain_up_to(engine.serve.queue_cap) {
            let id = engine.submit(p.req);
            responders.insert(id, p.respond);
        }
        if engine.active_count() == 0 {
            std::thread::sleep(std::time::Duration::from_millis(2));
            continue;
        }
        for res in engine.step_round()? {
            if let Some(tx) = responders.remove(&res.id) {
                let _ = tx.send(res);
            }
            served += 1;
        }
        if max_requests > 0 && served >= max_requests {
            break;
        }
    }
    queue.close();
    drop(acceptor); // detached; process exit reaps it
    log::info!("served {served} requests; lazy ratio {:.3}",
               engine.layer_stats.overall_ratio());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use std::time::Duration;

    #[test]
    fn parses_request_lines() {
        let r = parse_request_line(r#"{"label": 3, "steps": 10, "seed": 7}"#).unwrap();
        assert_eq!(r.class_label, 3);
        assert_eq!(r.steps, 10);
        assert_eq!(r.seed, 7);
        assert!((r.cfg_scale - 1.5).abs() < 1e-6);
    }

    #[test]
    fn defaults_apply() {
        let r = parse_request_line(r#"{"label": 0}"#).unwrap();
        assert_eq!(r.steps, 20);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse_request_line("not json").is_err());
        assert!(parse_request_line(r#"{"steps": 10}"#).is_err());
    }

    #[test]
    fn formats_responses() {
        let res = RequestResult {
            id: 7,
            class_label: 3,
            steps: 20,
            image: Tensor::zeros(&[1]),
            lazy_ratio: 0.5,
            attn_lazy_ratio: 0.6,
            ffn_lazy_ratio: 0.4,
            latency: Duration::from_millis(120),
            per_module_skip: vec![],
        };
        let s = format_response(&res);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.req("id").unwrap().as_usize().unwrap(), 7);
        assert!((j.req("lazy_ratio").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-9);
    }
}
