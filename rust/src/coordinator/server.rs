//! TCP JSON-lines serving front-end with admission control.
//!
//! Protocol (one JSON object per line; see docs/SERVING.md):
//!   request : {"label": 3, "steps": 20, "seed": 1, "cfg_scale": 1.5,
//!              "slo": "latency", "deadline_ms": 250}
//!   response: {"id": 7, "latency_ms": 123.4, "lazy_ratio": 0.31,
//!              "attn_lazy": 0.35, "ffn_lazy": 0.27, "steps": 20,
//!              "slo": "latency"}
//!   shed    : {"error": "queue full", "shed": "queue_full"} — the
//!             "shed" tag is machine-readable: "no_slack" (deadline
//!             unmeetable at admission), "queue_full" (transient
//!             overload), or "unservable" (permanent shape mismatch)
//!   stats   : the bare verb line `STATS` returns one JSON object with
//!             the live pool gauges, including per-replica and per-tier
//!             latency quantiles (replica-pool back-end only)
//!   trace   : the bare verb line `TRACE` returns one JSON object with
//!             the newest telemetry ring events per replica (empty when
//!             the server runs untraced; pool back-end only)
//!
//! `steps` must be a positive integer and `seed` a non-negative integer
//! below 2^53; malformed fields get a structured `{"error": ...}` line.
//! `slo` is optional ("latency"|"throughput"|"besteffort"); legacy lines
//! without it default to best-effort, so pre-SLO clients keep working
//! unchanged.
//!
//! Two back-ends share this front-end:
//! * [`serve`] — the legacy single-engine loop (one denoise loop total);
//! * [`serve_pool`] — the replica pool: acceptor threads feed the
//!   [`Router`], which places each request on one of N replica engines
//!   (round-robin / join-shortest-queue / lazy-aware for best-effort
//!   traffic, tier-preference for SLO-tagged requests). Shutdown drains:
//!   replicas finish in-flight trajectories before exit.

use crate::config::Slo;
use crate::coordinator::engine::Engine;
use crate::coordinator::pool::{Brownout, PoolReport, Router, Supervisor};
use crate::coordinator::request::{Request, RequestResult};
use crate::obs::epoch_us;
use crate::util::json::Json;
use crate::util::threadpool::BoundedQueue;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// A queued request with its response channel.
pub struct Pending {
    pub req: Request,
    pub respond: mpsc::Sender<RequestResult>,
}

/// Most denoise steps a request may ask for: the diffusion grid length
/// (`DiffusionConfig::timesteps` is 1000 for every exported config).
/// Enforced at the protocol edge because `Schedule::ddim_timesteps`
/// asserts it — an unchecked value would panic a replica worker.
pub const MAX_STEPS: usize = 1000;

/// Parse one request line into a Request (id assigned later).
///
/// Strictness (wire-protocol contract): every integer field is parsed
/// as a strict integer — fields used to be silently truncated through
/// `as u64`/`as usize` casts, mangling large, negative, and fractional
/// values. `steps` must be in `1..=MAX_STEPS`.
pub fn parse_request_line(line: &str) -> Result<Request> {
    let j = Json::parse(line).context("request json")?;
    let label = j
        .req("label")?
        .as_u64()
        .context("label must be a non-negative integer")?;
    // labels cross the PJRT boundary as i32 — reject anything that the
    // downstream cast would wrap instead of serving the wrong class
    if label > i32::MAX as u64 {
        bail!("label must be below 2^31");
    }
    let label = label as usize;
    let steps = match j.get("steps") {
        None => 20,
        Some(v) => v
            .as_u64()
            .context("steps must be a positive integer")? as usize,
    };
    if steps == 0 {
        bail!("steps must be >= 1");
    }
    if steps > MAX_STEPS {
        bail!("steps must be <= {MAX_STEPS}");
    }
    let seed = match j.get("seed") {
        None => 0,
        Some(v) => v
            .as_u64()
            .context("seed must be a non-negative integer below 2^53")?,
    };
    let cfg_scale = match j.get("cfg_scale") {
        None => 1.5,
        Some(v) => v.as_f64().context("cfg_scale must be a number")? as f32,
    };
    // optional, backward-compatible: legacy lines have no "slo" field
    let slo = match j.get("slo") {
        None => Slo::Besteffort,
        Some(v) => Slo::parse(v.as_str().context(
            "slo must be a string: latency|throughput|besteffort")?)?,
    };
    // optional, backward-compatible: a relative deadline in
    // milliseconds, stamped to an absolute shared-epoch instant at parse
    // time so every later comparison (EDF ordering, slack checks, hit/
    // miss accounting) is a plain integer compare. Absent or 0 means "no
    // deadline" — the router may still default one for the latency tier.
    let deadline_us = match j.get("deadline_ms") {
        None => 0,
        Some(v) => {
            let ms = v
                .as_u64()
                .context("deadline_ms must be a non-negative integer")?;
            if ms == 0 {
                0
            } else {
                crate::obs::epoch_us().saturating_add(ms.saturating_mul(1000))
            }
        }
    };
    let mut r = Request::new(0, label, steps, seed);
    r.cfg_scale = cfg_scale;
    r.slo = slo;
    r.deadline_us = deadline_us;
    Ok(r)
}

/// Format a response line.
pub fn format_response(res: &RequestResult) -> String {
    format_response_staged(res, 0)
}

/// [`format_response`] with the pool's brownout stage echoed. Stage 0
/// (normal operation) emits no extra field, so healthy-pool responses
/// are byte-identical to the pre-brownout wire format and legacy
/// clients never see the key; degraded responses carry
/// `"brownout_stage"` so clients know their result may have been
/// produced under widened warm-horizon / boosted-laziness dials.
pub fn format_response_staged(res: &RequestResult, stage: usize) -> String {
    let mut fields = vec![
        ("id", Json::num(res.id as f64)),
        ("steps", Json::num(res.steps as f64)),
        ("label", Json::num(res.class_label as f64)),
        ("latency_ms", Json::num(res.latency.as_secs_f64() * 1e3)),
        ("lazy_ratio", Json::num(res.lazy_ratio)),
        ("attn_lazy", Json::num(res.attn_lazy_ratio)),
        ("ffn_lazy", Json::num(res.ffn_lazy_ratio)),
        ("slo", Json::str(res.slo.name())),
    ];
    if stage > 0 {
        fields.push(("brownout_stage", Json::num(stage as f64)));
    }
    Json::obj(fields).to_string()
}

/// Structured error line (escaping-safe: built through the serializer,
/// never by string interpolation).
pub fn error_line(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

/// Structured shed line: the human-readable `"error"` message plus a
/// machine-readable `"shed"` reason (`"no_slack"` / `"queue_full"` /
/// `"unservable"`), so load generators and admission clients can branch
/// on the reason without parsing prose. Additive: every field of the
/// plain error line is still present.
pub fn shed_line(msg: &str, reason: &str) -> String {
    Json::obj(vec![
        ("error", Json::str(msg)),
        ("shed", Json::str(reason)),
    ])
    .to_string()
}

/// Shed reason for a request whose deadline no candidate replica can
/// meet even before it queues — retrying with the same deadline under
/// the same load is futile; retrying with a looser one may succeed.
pub const NO_SLACK_MSG: &str =
    "no slack: predicted queue delay plus service time overruns this \
     request's deadline on every candidate replica";

/// Shed reason for a request no replica in the pool can ever serve
/// (SLO class / lane-count mismatch) — distinct from `queue full` so
/// clients don't retry a condition that cannot clear.
pub const UNSERVABLE_MSG: &str =
    "unservable: no live replica matches this request's SLO class and \
     lane count";

/// Most ring events the `TRACE` verb returns per replica in one reply —
/// bounds the response line (the full ring is still exported to the
/// Chrome trace file at shutdown).
pub const TRACE_MAX_EVENTS: usize = 512;

/// Slow-client guard: the most time one response write may block the
/// connection thread. A client that opens a connection, submits a
/// request, and then never drains its socket would otherwise pin the
/// thread in `write_all` forever once the kernel send buffer fills —
/// with the completed result already consumed from the channel, that
/// stalls nothing pool-side, but it leaks a thread per such client.
/// Timed-out writes drop the connection and bump
/// [`Router::total_write_timeouts`].
pub const RESPONSE_WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// How one non-empty inbound line is interpreted, resolved before any
/// back-end work. Bare verbs are exact matches (post-trim), so they
/// can never collide with a JSON request object.
#[derive(Debug, PartialEq, Eq)]
enum LineVerb<'a> {
    /// `STATS` — reply with the live pool gauges.
    Stats,
    /// `TRACE` — reply with recent telemetry ring events.
    Trace,
    /// Anything else: a candidate request object for
    /// [`parse_request_line`].
    Request(&'a str),
}

/// Resolve a trimmed, non-empty line to its verb. Total over arbitrary
/// input — fuzzed below along with [`parse_request_line`], because a
/// panic here would take a connection thread down with a client-chosen
/// payload.
fn classify_line(trimmed: &str) -> LineVerb<'_> {
    match trimmed {
        "STATS" => LineVerb::Stats,
        "TRACE" => LineVerb::Trace,
        other => LineVerb::Request(other),
    }
}

/// Shared per-connection read loop. `submit` hands an admitted request
/// plus its response channel to a back-end; `Err((msg, reason))` means
/// shed, with `msg` telling the client why in prose (`queue full` for
/// transient overload, [`UNSERVABLE_MSG`] for a permanent pool-shape
/// mismatch) and `reason` the machine-readable `"shed"` tag
/// ([`shed_line`]). `respond`
/// formats a completed result (the pool back-end stamps the live
/// brownout stage here). `stats` answers the `STATS` verb and `trace`
/// the `TRACE` verb — bare non-JSON lines, so they can never collide
/// with a request object — each with one JSON line (live gauges /
/// recent ring events). `write_timeout` bounds each response write
/// (slow-client guard); a timed-out write calls `on_write_timeout` and
/// drops the connection.
fn serve_lines<F, R, S, T, W>(stream: TcpStream,
                              write_timeout: Option<Duration>, submit: F,
                              respond: R, stats: S, trace: T,
                              on_write_timeout: W)
where
    F: Fn(Request, mpsc::Sender<RequestResult>)
        -> Result<(), (&'static str, &'static str)>,
    R: Fn(&RequestResult) -> String,
    S: Fn() -> String,
    T: Fn() -> String,
    W: Fn(),
{
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = stream;
    if write_timeout.is_some() {
        // a failed setsockopt leaves the write unbounded — log loudly
        // rather than pretending the guard is armed
        if let Err(e) = writer.set_write_timeout(write_timeout) {
            log::warn!("slow-client guard disarmed for {peer:?}: {e}");
        }
    }
    for line in reader.lines() {
        let Ok(line) = line else { break };
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = match classify_line(trimmed) {
            LineVerb::Stats => stats(),
            LineVerb::Trace => trace(),
            LineVerb::Request(raw) => match parse_request_line(raw) {
                Ok(req) => {
                    let (tx, rx) = mpsc::channel();
                    match submit(req, tx) {
                        Ok(()) => match rx.recv() {
                            Ok(res) => respond(&res),
                            Err(_) => error_line("engine stopped"),
                        },
                        Err((msg, reason)) => shed_line(msg, reason),
                    }
                }
                Err(e) => error_line(&format!("{e:#}")),
            },
        };
        let wrote = writer
            .write_all(reply.as_bytes())
            .and_then(|()| writer.write_all(b"\n"));
        if let Err(e) = wrote {
            // SO_SNDTIMEO surfaces as TimedOut or WouldBlock depending
            // on platform; both mean the client stopped draining
            if matches!(e.kind(), std::io::ErrorKind::TimedOut
                                  | std::io::ErrorKind::WouldBlock)
            {
                log::warn!("response write to {peer:?} timed out — \
                            dropping slow client");
                on_write_timeout();
            }
            break;
        }
        let _ = writer.flush();
    }
    log::debug!("connection from {peer:?} closed");
}

/// Run the legacy single-engine serving loop: accept on `addr`, drive the
/// engine until `max_requests` have completed (0 = forever).
pub fn serve(mut engine: Engine, addr: &str, max_requests: usize) -> Result<()> {
    let queue: BoundedQueue<Pending> = BoundedQueue::new(engine.serve.queue_cap);
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    log::info!("serving on {addr} (config {})", engine.serve.config_name);

    let q2 = queue.clone();
    let acceptor = std::thread::Builder::new()
        .name("lazydit-acceptor".into())
        .spawn(move || loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    let q3 = q2.clone();
                    std::thread::spawn(move || {
                        serve_lines(
                            stream,
                            Some(RESPONSE_WRITE_TIMEOUT),
                            move |req, tx| {
                                q3.try_push(Pending { req, respond: tx })
                                    .map_err(|_| ("queue full",
                                                  "queue_full"))
                            },
                            format_response,
                            // live gauges and trace rings need the pool
                            // router; this legacy single-engine loop
                            // (library use — the CLI always runs the
                            // pool) has none
                            || error_line(
                                "STATS needs the replica-pool back-end"),
                            || error_line(
                                "TRACE needs the replica-pool back-end"),
                            // no router, so timeouts are log-only here
                            || {},
                        )
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => {
                    log::warn!("accept error: {e}");
                    break;
                }
            }
        })?;

    let mut responders: std::collections::BTreeMap<u64, mpsc::Sender<RequestResult>> =
        Default::default();
    let mut served = 0usize;
    loop {
        // admit everything currently queued (bounded by queue cap)
        for p in queue.drain_up_to(engine.serve.queue_cap) {
            let id = engine.submit(p.req);
            responders.insert(id, p.respond);
        }
        if engine.active_count() == 0 {
            std::thread::sleep(std::time::Duration::from_millis(2));
            continue;
        }
        for res in engine.step_round()? {
            if let Some(tx) = responders.remove(&res.id) {
                let _ = tx.send(res);
            }
            served += 1;
        }
        if max_requests > 0 && served >= max_requests {
            break;
        }
    }
    queue.close();
    drop(acceptor); // detached; process exit reaps it
    log::info!("served {served} requests; lazy ratio {:.3}",
               engine.layer_stats.row_overall_ratio());
    Ok(())
}

/// Run the replica-pool serving loop: accept on `addr`, feed the router,
/// stop once `max_requests` have completed (0 = forever), then drain the
/// pool and return the aggregated report. `max_requests` is a lower
/// bound, not an exact count: requests admitted before the stop is
/// observed still drain to completion (the pool never abandons admitted
/// work), so the report may show more than `max_requests` served. Also
/// stops — instead of hanging — if the acceptor dies or every replica
/// has exited (e.g. all engine constructions failed); the per-replica
/// errors are in the returned report.
pub fn serve_pool(router: Router, addr: &str,
                  max_requests: usize) -> Result<PoolReport> {
    serve_pool_shared(Arc::new(router), addr, max_requests, 0, None, None)
}

/// [`serve_pool`] over a shared router, with an optional forced
/// drain-by-migration: once `drain_after > 0` requests have completed,
/// replica 0 is asked to evict its residents to siblings at its next
/// step boundary, and the ask is re-armed every poll tick until at
/// least one trajectory actually migrates (a sweep that catches an
/// empty engine migrates nothing). Exercises the mid-flight snapshot
/// path end-to-end under real traffic; requires pool stealing and at
/// least two replicas, else the trigger is ignored. The caller keeps
/// its own `Arc` clone, so post-shutdown ledger counters
/// ([`Router::total_dispatched`] etc.) stay readable after the report
/// is returned.
///
/// When a [`Supervisor`] is passed it is ticked every poll interval:
/// panicked or wedged replicas are respawned into their slots (same
/// queue identity, so steal registrations stay valid) under an
/// exponential-backoff restart budget. When a [`Brownout`] controller
/// is passed it is ticked on the same cadence, stepping the pool
/// through degradation stages under sustained backlog or shed
/// pressure; the live stage is stamped on every response line
/// (`"brownout_stage"`, stage > 0 only).
pub fn serve_pool_shared(router: Arc<Router>, addr: &str,
                         max_requests: usize, drain_after: usize,
                         mut supervisor: Option<Supervisor>,
                         brownout: Option<Arc<Brownout>>)
                         -> Result<PoolReport> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    log::info!("serving on {addr} — {} replicas, route {}",
               router.replica_count(), router.route().name());

    let stop = Arc::new(AtomicBool::new(false));
    let (r2, s2) = (router.clone(), stop.clone());
    let acceptor = std::thread::Builder::new()
        .name("lazydit-pool-acceptor".into())
        .spawn(move || loop {
            if s2.load(Ordering::Relaxed) {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    let r3 = r2.clone();
                    let r4 = r2.clone();
                    let r5 = r2.clone();
                    let r6 = r2.clone();
                    let r7 = r2.clone();
                    std::thread::spawn(move || {
                        serve_lines(
                            stream,
                            Some(RESPONSE_WRITE_TIMEOUT),
                            move |req, tx| {
                                use crate::coordinator::pool::DispatchOutcome;
                                match r3.dispatch_outcome(req, tx) {
                                    DispatchOutcome::Admitted => Ok(()),
                                    // the cached response is already in
                                    // the channel; recv() below returns
                                    // it without blocking
                                    DispatchOutcome::CacheHit => Ok(()),
                                    DispatchOutcome::ShedCapacity => {
                                        Err(("queue full", "queue_full"))
                                    }
                                    DispatchOutcome::ShedUnservable => {
                                        Err((UNSERVABLE_MSG, "unservable"))
                                    }
                                    DispatchOutcome::ShedNoSlack => {
                                        Err((NO_SLACK_MSG, "no_slack"))
                                    }
                                }
                            },
                            // stamp the stage at response time, not
                            // admission time: the client learns the
                            // conditions its result was produced under
                            move |res| format_response_staged(
                                res, r6.brownout_stage()),
                            move || r4.stats_json(),
                            move || r5.trace_json(TRACE_MAX_EVENTS),
                            move || r7.note_write_timeout(),
                        )
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => {
                    // a dead acceptor makes the server permanently deaf —
                    // propagate via the stop flag instead of hanging
                    log::warn!("accept error, stopping pool: {e}");
                    s2.store(true, Ordering::Relaxed);
                    break;
                }
            }
        })?;

    let force_drain = drain_after > 0
        && router.stealing()
        && router.replica_count() > 1;
    loop {
        if stop.load(Ordering::Relaxed) {
            break; // acceptor hit a fatal error
        }
        if let Some(sup) = supervisor.as_mut() {
            sup.tick(epoch_us());
        }
        if let Some(b) = &brownout {
            b.tick(&router);
        }
        // feed the calendar oracle's EWMA fallback from the cumulative
        // pool counters (no-op when no calendar is armed)
        router.tick_calendar();
        // cache hits count toward the stop bound: each one answered a
        // client even though no replica completed anything for it.
        // Forfeits count too — a forfeited request's client got an
        // "engine stopped" error, so that ledger entry is resolved and
        // will never become a completion; without this term a panic
        // that forfeits in-flight work leaves the bound unreachable
        // and the loop hangs forever
        if max_requests > 0
            && router.total_completed() + router.total_cache_hits()
                + router.total_forfeited()
                >= max_requests as u64
        {
            break;
        }
        if router.all_replicas_finished() {
            log::warn!("every replica has exited — stopping pool");
            break;
        }
        if force_drain
            && router.total_completed() >= drain_after as u64
            && router.total_migrated() == 0
        {
            // re-arm until a sweep lands on a resident trajectory
            router.drain_replica(0);
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    stop.store(true, Ordering::Relaxed);
    drop(acceptor); // detached; exits on its next poll tick

    let report = router.shutdown();
    log::info!(
        "pool served {} requests ({} shed); lazy ratio {:.3}",
        report.completed(),
        report.shed,
        report.overall_lazy()
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use std::time::Duration;

    #[test]
    fn parses_request_lines() {
        let r = parse_request_line(r#"{"label": 3, "steps": 10, "seed": 7}"#).unwrap();
        assert_eq!(r.class_label, 3);
        assert_eq!(r.steps, 10);
        assert_eq!(r.seed, 7);
        assert!((r.cfg_scale - 1.5).abs() < 1e-6);
    }

    #[test]
    fn defaults_apply() {
        let r = parse_request_line(r#"{"label": 0}"#).unwrap();
        assert_eq!(r.steps, 20);
        assert_eq!(r.seed, 0);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse_request_line("not json").is_err());
        assert!(parse_request_line(r#"{"steps": 10}"#).is_err());
    }

    #[test]
    fn rejects_zero_steps() {
        let e = parse_request_line(r#"{"label": 1, "steps": 0}"#).unwrap_err();
        assert!(format!("{e:#}").contains("steps must be >= 1"), "{e:#}");
    }

    #[test]
    fn rejects_out_of_grid_steps() {
        // values past the diffusion grid would panic the replica worker
        // in Schedule::ddim_timesteps — the protocol edge must stop them
        assert!(parse_request_line(r#"{"label": 1, "steps": 1000}"#).is_ok());
        let e =
            parse_request_line(r#"{"label": 1, "steps": 1001}"#).unwrap_err();
        assert!(format!("{e:#}").contains("steps must be <= 1000"), "{e:#}");
        assert!(parse_request_line(r#"{"label": 1, "steps": 100000}"#).is_err());
    }

    #[test]
    fn rejects_mangled_label_and_cfg_scale() {
        // label used to saturate/truncate through `as usize`
        assert!(parse_request_line(r#"{"label": -1}"#).is_err());
        assert!(parse_request_line(r#"{"label": 3.9}"#).is_err());
        // 2^32 would wrap to class 0 through the downstream i32 cast
        assert!(parse_request_line(r#"{"label": 4294967296}"#).is_err());
        // cfg_scale of the wrong type used to silently become 1.5
        assert!(parse_request_line(r#"{"label": 1, "cfg_scale": "x"}"#).is_err());
        let r = parse_request_line(r#"{"label": 1, "cfg_scale": 1.0}"#).unwrap();
        assert!((r.cfg_scale - 1.0).abs() < 1e-6);
    }

    #[test]
    fn seeds_parse_as_strict_integers() {
        // large integers survive exactly up to 2^53 - 1
        let r = parse_request_line(
            r#"{"label": 1, "seed": 9007199254740991}"#).unwrap();
        assert_eq!(r.seed, 9_007_199_254_740_991);
        // negative, fractional, and oversized seeds are rejected, not
        // silently mangled through `as u64` — including 2^53 and 2^53+1,
        // which collide as f64
        for bad in [
            r#"{"label": 1, "seed": -3}"#,
            r#"{"label": 1, "seed": 1.5}"#,
            r#"{"label": 1, "seed": 9007199254740992}"#,
            r#"{"label": 1, "seed": 9007199254740993}"#,
            r#"{"label": 1, "seed": 1e300}"#,
        ] {
            let e = parse_request_line(bad).unwrap_err();
            assert!(format!("{e:#}").contains("seed"), "{bad}: {e:#}");
        }
        // steps has the same strictness
        assert!(parse_request_line(r#"{"label": 1, "steps": 2.5}"#).is_err());
    }

    #[test]
    fn error_lines_are_valid_json() {
        let s = error_line("bad \"quoted\" thing\nwith newline");
        let j = Json::parse(&s).unwrap();
        assert_eq!(
            j.req("error").unwrap().as_str().unwrap(),
            "bad \"quoted\" thing\nwith newline"
        );
    }

    #[test]
    fn shed_lines_carry_a_machine_readable_reason() {
        for (msg, reason) in [
            ("queue full", "queue_full"),
            (UNSERVABLE_MSG, "unservable"),
            (NO_SLACK_MSG, "no_slack"),
        ] {
            let s = shed_line(msg, reason);
            let j = Json::parse(&s).unwrap();
            // additive: the legacy "error" field is still present, so
            // pre-existing clients that only look there keep working
            assert_eq!(j.req("error").unwrap().as_str().unwrap(), msg);
            assert_eq!(j.req("shed").unwrap().as_str().unwrap(), reason);
        }
    }

    #[test]
    fn formats_responses() {
        let res = RequestResult {
            id: 7,
            class_label: 3,
            steps: 20,
            slo: Slo::Latency,
            image: Tensor::zeros(&[1]),
            lazy_ratio: 0.5,
            attn_lazy_ratio: 0.6,
            ffn_lazy_ratio: 0.4,
            latency: Duration::from_millis(120),
            per_module_skip: vec![],
        };
        let s = format_response(&res);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.req("id").unwrap().as_usize().unwrap(), 7);
        assert!((j.req("lazy_ratio").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-9);
        // the SLO class is echoed so clients can verify tier handling
        assert_eq!(j.req("slo").unwrap().as_str().unwrap(), "latency");
    }

    #[test]
    fn brownout_stage_is_stamped_only_when_degraded() {
        let res = RequestResult {
            id: 9,
            class_label: 1,
            steps: 8,
            slo: Slo::Besteffort,
            image: Tensor::zeros(&[1]),
            lazy_ratio: 0.2,
            attn_lazy_ratio: 0.2,
            ffn_lazy_ratio: 0.2,
            latency: Duration::from_millis(5),
            per_module_skip: vec![],
        };
        // stage 0 is byte-identical to the legacy wire format
        assert_eq!(format_response_staged(&res, 0), format_response(&res));
        assert!(!format_response(&res).contains("brownout_stage"));
        let degraded = format_response_staged(&res, 2);
        let j = Json::parse(&degraded).unwrap();
        assert_eq!(j.req("brownout_stage").unwrap().as_usize().unwrap(), 2);
        // the rest of the payload is unchanged by the stamp
        assert_eq!(j.req("id").unwrap().as_usize().unwrap(), 9);
    }

    #[test]
    fn verbs_resolve_exactly_and_only_exactly() {
        assert_eq!(classify_line("STATS"), LineVerb::Stats);
        assert_eq!(classify_line("TRACE"), LineVerb::Trace);
        // near-misses are requests (and then structured parse errors),
        // never silently treated as verbs
        for miss in ["stats", "STATSS", "STATS X", "TRACE{", "TRACERT",
                     "", "S", "статистика"] {
            assert!(matches!(classify_line(miss), LineVerb::Request(_)),
                    "{miss:?}");
        }
    }

    #[test]
    fn wire_front_end_never_panics_on_arbitrary_bytes() {
        use crate::util::propcheck::propcheck;
        // drive the exact per-line path a connection thread runs (verb
        // resolution, then request parse) over adversarial input; the
        // property is totality — a panic here would let a client kill
        // connection threads with a chosen payload
        let drive = |line: &str| {
            let trimmed = line.trim();
            if trimmed.is_empty() {
                return;
            }
            match classify_line(trimmed) {
                LineVerb::Stats | LineVerb::Trace => {}
                LineVerb::Request(raw) => {
                    if let Err(e) = parse_request_line(raw) {
                        // the error must also format into a valid
                        // structured line (it goes on the wire)
                        let s = error_line(&format!("{e:#}"));
                        assert!(Json::parse(&s).is_ok(), "{s}");
                    }
                }
            }
        };
        const VALID: &str = r#"{"label": 3, "steps": 12, "seed": 9, "cfg_scale": 1.5, "slo": "latency"}"#;
        propcheck(150, |g| {
            // raw garbage: random bytes, decoded the way a reader
            // would have to before reaching the parser
            let n = g.usize_in(0, 80);
            let bytes: Vec<u8> = (0..n).map(|_| g.u64() as u8).collect();
            drive(&String::from_utf8_lossy(&bytes));
            // mutations of a well-formed request line: single byte
            // stomp, truncation at a random cut, and a spliced
            // duplicate region — shapes that stay "almost JSON"
            let good = VALID.as_bytes();
            let mut m = good.to_vec();
            let i = g.usize_in(0, m.len() - 1);
            m[i] = g.u64() as u8;
            drive(&String::from_utf8_lossy(&m));
            drive(&String::from_utf8_lossy(
                &good[..g.usize_in(0, good.len())]));
            let (a, b) = (g.usize_in(0, good.len() - 1),
                          g.usize_in(0, good.len() - 1));
            let (lo, hi) = (a.min(b), a.max(b));
            let mut m = good.to_vec();
            m.extend_from_slice(&good[lo..hi]);
            drive(&String::from_utf8_lossy(&m));
            // verb-adjacent lines: prefixes/suffixes of the bare verbs
            let verb = *g.choose(&["STATS", "TRACE"]);
            let cut = g.usize_in(0, verb.len());
            drive(&verb[..cut]);
            drive(&format!("{verb}{}", g.u64()));
            drive(&format!("  {verb}\t"));
        });
    }

    #[test]
    fn slo_round_trips_and_legacy_lines_default() {
        // legacy line (no slo field): best-effort, exactly as before
        let r = parse_request_line(r#"{"label": 1, "steps": 4}"#).unwrap();
        assert_eq!(r.slo, Slo::Besteffort);
        // full spellings and short aliases round-trip through the parser
        for (wire, want) in [
            ("latency", Slo::Latency),
            ("lat", Slo::Latency),
            ("throughput", Slo::Throughput),
            ("thr", Slo::Throughput),
            ("besteffort", Slo::Besteffort),
            ("be", Slo::Besteffort),
        ] {
            let line = format!(r#"{{"label": 1, "slo": "{wire}"}}"#);
            assert_eq!(parse_request_line(&line).unwrap().slo, want,
                       "{wire}");
        }
        // wrong type and unknown class get structured errors, never a
        // silent best-effort downgrade
        let e = parse_request_line(r#"{"label": 1, "slo": 3}"#).unwrap_err();
        assert!(format!("{e:#}").contains("slo"), "{e:#}");
        let e =
            parse_request_line(r#"{"label": 1, "slo": "gold"}"#).unwrap_err();
        assert!(format!("{e:#}").contains("unknown SLO"), "{e:#}");
    }

    #[test]
    fn deadline_ms_parses_strictly_and_stamps_absolute() {
        // legacy lines (no field) and an explicit 0 both mean "no
        // deadline" — the sentinel the rest of the pool keys off
        let r = parse_request_line(r#"{"label": 1}"#).unwrap();
        assert_eq!(r.deadline_us, 0);
        let r =
            parse_request_line(r#"{"label": 1, "deadline_ms": 0}"#).unwrap();
        assert_eq!(r.deadline_us, 0);
        // a relative deadline becomes an absolute shared-epoch instant
        // ~ms*1000 past "now"
        let before = crate::obs::epoch_us();
        let r = parse_request_line(r#"{"label": 1, "deadline_ms": 250}"#)
            .unwrap();
        let after = crate::obs::epoch_us();
        assert!(r.deadline_us >= before + 250_000, "{}", r.deadline_us);
        assert!(r.deadline_us <= after + 250_000, "{}", r.deadline_us);
        // strict integer: negative, fractional, and oversized values are
        // rejected, never silently truncated into a bogus deadline
        for bad in [
            r#"{"label": 1, "deadline_ms": -5}"#,
            r#"{"label": 1, "deadline_ms": 1.5}"#,
            r#"{"label": 1, "deadline_ms": "soon"}"#,
            r#"{"label": 1, "deadline_ms": 9007199254740992}"#,
        ] {
            let e = parse_request_line(bad).unwrap_err();
            assert!(format!("{e:#}").contains("deadline_ms"),
                    "{bad}: {e:#}");
        }
    }
}
