//! `lazydit` — leader entrypoint + CLI (DESIGN.md §5).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = lazydit::cli::dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
