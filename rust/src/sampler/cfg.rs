//! Classifier-free guidance (Ho & Salimans 2022) combination, as in the
//! paper's Sec. 3.1:  ε̂ = w·ε(c) − (w−1)·ε(∅).
//!
//! The coordinator runs cond/uncond as adjacent batch rows; `combine`
//! folds row pairs back into one guided prediction per request.

use crate::tensor::Tensor;

/// Combine a [2B, ...] eps tensor (rows ordered cond_0..cond_{B-1},
/// uncond_0..uncond_{B-1}) into guided [B, ...] predictions.
pub fn combine_stacked(eps: &Tensor, scale: f32) -> Tensor {
    let b2 = eps.dim0();
    assert!(b2 % 2 == 0, "CFG tensor must have even batch");
    let b = b2 / 2;
    let mut shape = eps.shape().to_vec();
    shape[0] = b;
    let mut out = Tensor::zeros(&shape);
    let r = eps.row_len();
    for i in 0..b {
        let cond = eps.row(i);
        let unc = eps.row(b + i);
        let dst = out.row_mut(i);
        for k in 0..r {
            dst[k] = scale * cond[k] - (scale - 1.0) * unc[k];
        }
    }
    out
}

/// Combine a pair of per-request tensors.
pub fn combine_pair(cond: &Tensor, uncond: &Tensor, scale: f32) -> Tensor {
    let mut out = Tensor::zeros(cond.shape());
    out.axpby_from(scale, cond, -(scale - 1.0), uncond);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_one_is_conditional() {
        let cond = Tensor::from_vec(&[2], vec![1.0, 2.0]).unwrap();
        let unc = Tensor::from_vec(&[2], vec![-3.0, 7.0]).unwrap();
        let out = combine_pair(&cond, &unc, 1.0);
        assert_eq!(out, cond);
    }

    #[test]
    fn linearity() {
        let cond = Tensor::from_vec(&[2], vec![1.0, 0.0]).unwrap();
        let unc = Tensor::from_vec(&[2], vec![0.0, 1.0]).unwrap();
        let out = combine_pair(&cond, &unc, 1.5);
        assert_eq!(out.data(), &[1.5, -0.5]);
    }

    #[test]
    fn stacked_matches_pairwise() {
        let eps = Tensor::from_vec(&[4, 2], vec![
            1., 2., 3., 4.,      // cond rows
            10., 20., 30., 40.,  // uncond rows
        ]).unwrap();
        let out = combine_stacked(&eps, 1.5);
        assert_eq!(out.shape(), &[2, 2]);
        // row0: 1.5*[1,2] - 0.5*[10,20] = [-3.5, -7]
        assert_eq!(out.row(0), &[-3.5, -7.0]);
        assert_eq!(out.row(1), &[-10.5, -14.0]);
    }
}
