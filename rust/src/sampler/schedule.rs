//! Linear-β diffusion schedule and the DDIM timestep subset.

/// Precomputed schedule tables for T training timesteps.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub timesteps: usize,
    pub betas: Vec<f32>,
    pub alphas_bar: Vec<f32>,
}

impl Schedule {
    /// Linear betas in f32, matching `jnp.linspace(beta_start, beta_end, T)`
    /// followed by `cumprod(1 - betas)`.
    pub fn linear(timesteps: usize, beta_start: f32, beta_end: f32) -> Schedule {
        assert!(timesteps >= 2);
        let mut betas = Vec::with_capacity(timesteps);
        let step = (beta_end - beta_start) / (timesteps - 1) as f32;
        for i in 0..timesteps {
            betas.push(beta_start + step * i as f32);
        }
        let mut alphas_bar = Vec::with_capacity(timesteps);
        let mut prod = 1.0f32;
        for &b in &betas {
            prod *= 1.0 - b;
            alphas_bar.push(prod);
        }
        Schedule { timesteps, betas, alphas_bar }
    }

    /// ᾱ at integer timestep t; ᾱ_{-1} ≡ 1 (the clean-data boundary).
    pub fn alpha_bar(&self, t: isize) -> f32 {
        if t < 0 {
            1.0
        } else {
            self.alphas_bar[(t as usize).min(self.timesteps - 1)]
        }
    }

    /// The DDIM sub-sequence of timesteps for `steps` sampling steps,
    /// descending (t_K .. t_1), matching the DiT/DDIM "uniform spacing"
    /// convention: t_i = round(i * T / steps) - 1 walked downward.
    pub fn ddim_timesteps(&self, steps: usize) -> Vec<usize> {
        assert!(steps >= 1 && steps <= self.timesteps);
        let mut ts: Vec<usize> = (1..=steps)
            .map(|i| (i * self.timesteps) / steps - 1)
            .collect();
        ts.dedup();
        ts.reverse(); // descending: start at the noisiest step
        ts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_closed_form() {
        let s = Schedule::linear(1000, 1e-4, 2e-2);
        assert_eq!(s.betas.len(), 1000);
        assert!((s.betas[0] - 1e-4).abs() < 1e-9);
        assert!((s.betas[999] - 2e-2).abs() < 1e-7);
        // ᾱ decreasing in (0, 1]
        for w in s.alphas_bar.windows(2) {
            assert!(w[1] < w[0]);
            assert!(w[1] > 0.0 && w[0] <= 1.0);
        }
        // hand-check ᾱ_1 = (1-β0)(1-β1)
        let expect = (1.0 - s.betas[0]) * (1.0 - s.betas[1]);
        assert!((s.alphas_bar[1] - expect).abs() < 1e-7);
    }

    #[test]
    fn boundary_alpha_bar() {
        let s = Schedule::linear(100, 1e-4, 2e-2);
        assert_eq!(s.alpha_bar(-1), 1.0);
        assert_eq!(s.alpha_bar(0), s.alphas_bar[0]);
        assert_eq!(s.alpha_bar(1_000_000), s.alphas_bar[99]);
    }

    #[test]
    fn ddim_subset_properties() {
        let s = Schedule::linear(1000, 1e-4, 2e-2);
        for steps in [1, 5, 10, 25, 50, 1000] {
            let ts = s.ddim_timesteps(steps);
            assert_eq!(ts.len(), steps, "steps {steps}");
            assert_eq!(ts[0], 999, "must start at T-1");
            for w in ts.windows(2) {
                assert!(w[1] < w[0], "descending");
            }
        }
        assert_eq!(s.ddim_timesteps(4), vec![999, 749, 499, 249]);
    }
}
