//! The DDIM update rule (Song, Meng & Ermon 2020), η = 0 (deterministic),
//! as used by the paper for all comparisons:
//!
//!   z_{t'} = √ᾱ_{t'} · (z_t − √(1−ᾱ_t)·ε̂) / √ᾱ_t  +  √(1−ᾱ_{t'}) · ε̂

use crate::sampler::schedule::Schedule;
use crate::tensor::Tensor;

/// Stateless DDIM stepper over a schedule.
#[derive(Debug, Clone)]
pub struct DdimSampler {
    pub schedule: Schedule,
}

impl DdimSampler {
    pub fn new(schedule: Schedule) -> Self {
        DdimSampler { schedule }
    }

    /// One deterministic DDIM step from timestep `t` to `t_prev`
    /// (`t_prev < t`; pass -1 for the final step to x0).
    /// Updates `z` in place given the model's ε̂ prediction.
    pub fn step(&self, z: &mut Tensor, eps: &Tensor, t: isize, t_prev: isize) {
        let ab_t = self.schedule.alpha_bar(t);
        let ab_p = self.schedule.alpha_bar(t_prev);
        let (a, b) = ddim_coeffs(ab_t, ab_p);
        let zc = z.clone();
        z.axpby_from(a, &zc, b, eps);
    }

    /// Predicted clean sample x̂0 from (z_t, ε̂) — used for preview decode.
    pub fn predict_x0(&self, z: &Tensor, eps: &Tensor, t: isize) -> Tensor {
        let ab_t = self.schedule.alpha_bar(t);
        let mut out = Tensor::zeros(z.shape());
        out.axpby_from(
            1.0 / ab_t.sqrt(),
            z,
            -((1.0 - ab_t).sqrt()) / ab_t.sqrt(),
            eps,
        );
        out
    }
}

/// The (a, b) such that z' = a·z + b·ε̂ for the η=0 DDIM update.
pub fn ddim_coeffs(ab_t: f32, ab_prev: f32) -> (f32, f32) {
    let sa_t = ab_t.sqrt();
    let sa_p = ab_prev.sqrt();
    let a = sa_p / sa_t;
    let b = (1.0 - ab_prev).sqrt() - sa_p * (1.0 - ab_t).sqrt() / sa_t;
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    fn sampler() -> DdimSampler {
        DdimSampler::new(Schedule::linear(1000, 1e-4, 2e-2))
    }

    #[test]
    fn identity_step() {
        // t' == t must be the identity map (a=1, b=0).
        let s = sampler();
        let ab = s.schedule.alpha_bar(500);
        let (a, b) = ddim_coeffs(ab, ab);
        assert!((a - 1.0).abs() < 1e-6);
        assert!(b.abs() < 1e-6);
    }

    #[test]
    fn perfect_eps_recovers_x0() {
        // If ε̂ equals the true noise used by q_sample, stepping t -> -1
        // recovers x0 exactly (η = 0 determinism).
        propcheck(50, |g| {
            let s = sampler();
            let n = g.usize_in(2, 32);
            let t = g.usize_in(1, 999) as isize;
            let x0 = Tensor::from_vec(&[n], g.vec_normal(n)).unwrap();
            let noise = Tensor::from_vec(&[n], g.vec_normal(n)).unwrap();
            let ab = s.schedule.alpha_bar(t);
            let mut z = Tensor::zeros(&[n]);
            z.axpby_from(ab.sqrt(), &x0, (1.0 - ab).sqrt(), &noise);
            s.step(&mut z, &noise, t, -1);
            let err = z.sub(&x0).max_abs();
            assert!(err < 2e-4, "err {err} at t {t}");
        });
    }

    #[test]
    fn step_is_deterministic() {
        let s = sampler();
        let z0 = Tensor::from_vec(&[4], vec![0.1, -0.2, 0.3, 1.0]).unwrap();
        let eps = Tensor::from_vec(&[4], vec![0.5, 0.5, -0.5, 0.0]).unwrap();
        let mut a = z0.clone();
        let mut b = z0.clone();
        s.step(&mut a, &eps, 999, 749);
        s.step(&mut b, &eps, 999, 749);
        assert_eq!(a, b);
    }

    #[test]
    fn predict_x0_inverts_qsample() {
        let s = sampler();
        let x0 = Tensor::from_vec(&[3], vec![0.2, -0.7, 1.1]).unwrap();
        let noise = Tensor::from_vec(&[3], vec![1.0, -1.0, 0.5]).unwrap();
        let t = 300isize;
        let ab = s.schedule.alpha_bar(t);
        let mut z = Tensor::zeros(&[3]);
        z.axpby_from(ab.sqrt(), &x0, (1.0 - ab).sqrt(), &noise);
        let xhat = s.predict_x0(&z, &noise, t);
        assert!(xhat.sub(&x0).max_abs() < 1e-4);
    }
}
