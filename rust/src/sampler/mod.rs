//! Diffusion sampling owned by L3: β/ᾱ schedules, timestep subset
//! selection, the DDIM update rule, and classifier-free guidance.
//!
//! The schedule must match `python/compile/diffusion.py` bit-for-bit in
//! spirit (float32 linear betas, cumulative product); the integration test
//! `golden_numerics` compares against `artifacts/alphas_bar.npy`.

pub mod schedule;
pub mod ddim;
pub mod cfg;

pub use ddim::DdimSampler;
pub use schedule::Schedule;
