//! Analytic compute-cost model — regenerates the paper's TMACs columns
//! (Tables 3/6/7). Counts multiply-accumulates per module per denoising
//! step from the architecture, including the lazy-gate overhead the paper
//! notes as its limitation.

use crate::config::ModelConfig;

/// MACs for one MHSA module invocation at batch 1.
pub fn attn_macs(cfg: &ModelConfig) -> u64 {
    let (n, d) = (cfg.tokens() as u64, cfg.dim as u64);
    // qkv projection + output projection + QK^T + AV
    n * d * 3 * d + n * d * d + 2 * n * n * d
}

/// MACs for one Feedforward module invocation at batch 1.
pub fn ffn_macs(cfg: &ModelConfig) -> u64 {
    let (n, d, h) = (cfg.tokens() as u64, cfg.dim as u64, cfg.hidden() as u64);
    n * d * h + n * h * d
}

/// MACs for the modulation (adaLN shift/scale projections) of one module.
pub fn modulate_macs(cfg: &ModelConfig) -> u64 {
    let d = cfg.dim as u64;
    // two D×D matvecs on the conditioning vector + alpha projection
    3 * d * d
}

/// Extra MACs of the lazy-gate linear layer (paper's added layers).
pub fn gate_macs(cfg: &ModelConfig) -> u64 {
    (cfg.tokens() * cfg.dim) as u64
}

/// MACs for embed + final layers per step at batch 1.
pub fn peripheral_macs(cfg: &ModelConfig) -> u64 {
    let (n, d) = (cfg.tokens() as u64, cfg.dim as u64);
    let pd = cfg.patch_dim() as u64;
    let f = cfg.freq_dim as u64;
    let patch = n * pd * d;
    let temb = f * d + d * d;
    let fin = 2 * d * d + n * d * pd;
    patch + temb + fin
}

/// MACs of one full (no-skip) denoise step at batch 1, gates included
/// when `with_gates`.
pub fn step_macs(cfg: &ModelConfig, with_gates: bool) -> u64 {
    let l = cfg.depth as u64;
    let per_block =
        attn_macs(cfg) + ffn_macs(cfg) + 2 * modulate_macs(cfg)
        + if with_gates { 2 * gate_macs(cfg) } else { 0 };
    peripheral_macs(cfg) + l * per_block
}

/// Total MACs of a full sampling run (per generated image, CFG doubling
/// included) with a fraction `lazy_ratio` of module invocations skipped.
///
/// Skipped modules still pay modulation+gate+apply (the paper keeps
/// scale/shift/residual); only the MHSA/FFN body is elided.
pub fn run_macs(cfg: &ModelConfig, steps: usize, lazy_ratio: f64,
                cfg_guidance: bool, with_gates: bool) -> u64 {
    let l = cfg.depth as u64;
    let body = (attn_macs(cfg) + ffn_macs(cfg)) as f64;
    let keep = body * (1.0 - lazy_ratio);
    let overhead = 2.0 * modulate_macs(cfg) as f64
        + if with_gates { 2.0 * gate_macs(cfg) as f64 } else { 0.0 };
    let per_step = peripheral_macs(cfg) as f64 + l as f64 * (keep + overhead);
    let mult = if cfg_guidance { 2.0 } else { 1.0 };
    (per_step * steps as f64 * mult) as u64
}

/// Pretty TMACs (1e12 MACs) for table printing.
pub fn as_tmacs(macs: u64) -> f64 {
    macs as f64 / 1e12
}

/// Giga-MACs for toy-scale tables (our models are small; the *ratios*
/// are what reproduce the paper's columns).
pub fn as_gmacs(macs: u64) -> f64 {
    macs as f64 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(), paper_analog: "".into(),
            img_size: 8, channels: 3, patch: 2, dim: 96, depth: 6, heads: 6,
            num_classes: 10, mlp_ratio: 4, freq_dim: 128,
        }
    }

    #[test]
    fn hand_counted_attn() {
        let c = cfg();
        // N=16, D=96: qkv 16*96*288=442368; proj 16*96*96=147456;
        // qk^t + av: 2*16*16*96=49152
        assert_eq!(attn_macs(&c), 442_368 + 147_456 + 49_152);
    }

    #[test]
    fn hand_counted_ffn() {
        let c = cfg();
        // N=16, D=96, H=384: 2*16*96*384
        assert_eq!(ffn_macs(&c), 2 * 16 * 96 * 384);
    }

    #[test]
    fn lazy_ratio_scales_body_only() {
        let c = cfg();
        let full = run_macs(&c, 50, 0.0, true, true);
        let half = run_macs(&c, 50, 0.5, true, true);
        let none = run_macs(&c, 50, 1.0, true, true);
        assert!(half < full && none < half);
        // body at ratio 1.0 fully gone; difference full-none == body
        let body = (attn_macs(&c) + ffn_macs(&c)) * c.depth as u64 * 50 * 2;
        assert_eq!(full - none, body);
        // 50% ratio removes exactly half the body
        assert_eq!(full - half, body / 2);
    }

    #[test]
    fn gate_overhead_is_small() {
        let c = cfg();
        let with = run_macs(&c, 50, 0.0, true, true);
        let without = run_macs(&c, 50, 0.0, true, false);
        let overhead = (with - without) as f64 / without as f64;
        assert!(overhead < 0.01, "gate overhead {overhead} must be <1%");
    }

    #[test]
    fn cfg_doubles() {
        let c = cfg();
        assert_eq!(
            run_macs(&c, 10, 0.0, true, true),
            2 * run_macs(&c, 10, 0.0, false, true)
        );
    }
}
