//! Benchmark substrate (criterion is not in the offline vendor set):
//! timing harness + the shared quality-evaluation pipeline used by the
//! paper-table regenerators.

pub mod harness;
pub mod quality;

pub use harness::{bench, BenchResult, BenchSpec};
pub use quality::{FeatureExtractor, MetricContext, QualityRow};
