//! Micro/e2e timing harness: warmup + measured iterations with
//! mean/p50/p95/min reporting — the criterion stand-in for `cargo bench`.

use crate::metrics::stats::{mean, quantile};
use std::time::Instant;

/// Iteration plan.
#[derive(Debug, Clone, Copy)]
pub struct BenchSpec {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for BenchSpec {
    fn default() -> Self {
        BenchSpec { warmup: 2, iters: 10 }
    }
}

/// One benchmark's timing summary (seconds).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<44} mean {:>9.4}s  p50 {:>9.4}s  p95 {:>9.4}s  min {:>9.4}s  ({} iters)",
            self.name, self.mean_s, self.p50_s, self.p95_s, self.min_s, self.iters
        )
    }
}

/// Time a closure `spec.iters` times after `spec.warmup` warmups.
pub fn bench<F: FnMut()>(name: &str, spec: BenchSpec, mut f: F) -> BenchResult {
    for _ in 0..spec.warmup {
        f();
    }
    let mut samples = Vec::with_capacity(spec.iters);
    for _ in 0..spec.iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters: spec.iters,
        mean_s: mean(&samples),
        p50_s: quantile(&samples, 0.5),
        p95_s: quantile(&samples, 0.95),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("spin", BenchSpec { warmup: 1, iters: 5 }, || {
            std::hint::black_box((0..10_000).sum::<u64>());
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s + 1e-12);
        assert!(r.p50_s <= r.p95_s + 1e-12);
    }

    #[test]
    fn summary_contains_name() {
        let r = bench("xyz", BenchSpec { warmup: 0, iters: 1 }, || {});
        assert!(r.summary().contains("xyz"));
    }
}
