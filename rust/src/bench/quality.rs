//! Shared quality-evaluation pipeline: real-reference features, the
//! centroid classifier for the IS analog, and the FID/sFID/IS/P/R row
//! computation every paper-table harness uses.

use crate::coordinator::request::RequestResult;
use crate::data::synth::SynthBlobs;
use crate::metrics::fid::frechet_distance;
use crate::metrics::inception::{inception_score, CentroidClassifier};
use crate::metrics::prec_recall::precision_recall;
use crate::runtime::engine_rt::{Executable, Runtime};
use crate::runtime::manifest::ManifestConfig;
use crate::runtime::value::HostValue;
use crate::tensor::Tensor;
use crate::util::prng::Rng;
use anyhow::{Context, Result};
use std::rc::Rc;

/// Batched driver over the exported `feature_b{B}` graphs.
pub struct FeatureExtractor {
    exes: Vec<(usize, Rc<Executable>)>, // (bucket, exe), descending bucket
    img_shape: Vec<usize>,
    pub dim: usize,
}

impl FeatureExtractor {
    pub fn new(rt: &Rc<Runtime>, cfg: &ManifestConfig, dim: usize)
               -> Result<FeatureExtractor> {
        let mut buckets = cfg.buckets.clone();
        buckets.sort_unstable();
        buckets.reverse();
        let mut exes = Vec::new();
        for b in buckets {
            exes.push((b, rt.load(cfg, &format!("feature_b{b}"))?));
        }
        Ok(FeatureExtractor {
            exes,
            img_shape: vec![cfg.model.channels, cfg.model.img_size,
                            cfg.model.img_size],
            dim,
        })
    }

    /// Extract (feat, sfeat) rows for images [B, C, S, S].
    pub fn extract(&self, images: &Tensor) -> Result<(Vec<f32>, Vec<f32>)> {
        let n = images.dim0();
        let row = images.row_len();
        let mut feats = Vec::with_capacity(n * self.dim);
        let mut sfeats = Vec::with_capacity(n * self.dim);
        let mut i = 0;
        while i < n {
            let remaining = n - i;
            // largest bucket ≤ remaining, else smallest (pad last chunk)
            let (b, exe) = self
                .exes
                .iter()
                .find(|(b, _)| *b <= remaining)
                .or_else(|| self.exes.last().map(|x| x))
                .context("no feature buckets")?;
            let take = remaining.min(*b);
            let mut chunk =
                Tensor::zeros(&[*b, self.img_shape[0], self.img_shape[1],
                                self.img_shape[2]]);
            for k in 0..take {
                chunk.row_mut(k).copy_from_slice(
                    &images.data()[(i + k) * row..(i + k + 1) * row]);
            }
            let mut out = exe.call(&[HostValue::F32(chunk)])?;
            let sf = out.pop().context("sfeat")?.as_f32()?;
            let f = out.pop().context("feat")?.as_f32()?;
            for k in 0..take {
                feats.extend_from_slice(f.row(k));
                sfeats.extend_from_slice(sf.row(k));
            }
            i += take;
        }
        Ok((feats, sfeats))
    }
}

/// Reference statistics over real SynthBlobs samples + the IS classifier.
pub struct MetricContext {
    pub real_feats: Vec<f32>,
    pub real_sfeats: Vec<f32>,
    pub n_real: usize,
    pub clf: CentroidClassifier,
    pub clf_accuracy: f64,
    pub dim: usize,
    pub threads: usize,
}

impl MetricContext {
    /// Build from `n_real` freshly sampled real images.
    pub fn build(extractor: &FeatureExtractor, img_size: usize, n_real: usize,
                 seed: u64, threads: usize) -> Result<MetricContext> {
        let ds = SynthBlobs::new(img_size);
        let mut rng = Rng::new(seed ^ 0x4EA1);
        let (imgs, labels) = ds.sample_batch(&mut rng, n_real);
        let (feats, sfeats) = extractor.extract(&imgs)?;
        let clf = CentroidClassifier::fit(&feats, &labels, extractor.dim,
                                          ds.num_classes, 0.05);
        let clf_accuracy = clf.accuracy(&feats, &labels, extractor.dim);
        Ok(MetricContext {
            real_feats: feats,
            real_sfeats: sfeats,
            n_real,
            clf,
            clf_accuracy,
            dim: extractor.dim,
            threads,
        })
    }

    /// Full quality row for a generated image set.
    pub fn evaluate(&self, extractor: &FeatureExtractor, images: &Tensor)
                    -> Result<QualityRow> {
        let n = images.dim0();
        let (feats, sfeats) = extractor.extract(images)?;
        let fid = frechet_distance(&self.real_feats, self.n_real, &feats, n,
                                   self.dim);
        let sfid = frechet_distance(&self.real_sfeats, self.n_real, &sfeats, n,
                                    self.dim);
        let is = inception_score(&self.clf, &feats, n, self.dim);
        let (prec, rec) = precision_recall(&self.real_feats, self.n_real,
                                           &feats, n, self.dim, 3,
                                           self.threads);
        Ok(QualityRow { fid, sfid, is, precision: prec, recall: rec })
    }
}

/// One metrics row (the paper's five quality columns).
#[derive(Debug, Clone)]
pub struct QualityRow {
    pub fid: f64,
    pub sfid: f64,
    pub is: f64,
    pub precision: f64,
    pub recall: f64,
}

/// Stack result images [B, C, S, S] from engine results.
pub fn stack_images(results: &[RequestResult]) -> Result<Tensor> {
    let n = results.len();
    anyhow::ensure!(n > 0, "no results");
    let shape = results[0].image.shape().to_vec();
    let mut full = vec![n];
    full.extend_from_slice(&shape);
    let mut out = Tensor::zeros(&full);
    for (i, r) in results.iter().enumerate() {
        out.row_mut(i).copy_from_slice(r.image.data());
    }
    Ok(out)
}

/// Round-robin labels for an eval trial.
pub fn eval_labels(n: usize, num_classes: usize) -> Vec<usize> {
    (0..n).map(|i| i % num_classes).collect()
}
