//! Serving workload generation: Poisson-arrival request traces with
//! configurable step counts, class mixes and lazy settings — the input to
//! the latency/throughput benches (Tables 3/6) and the serve example.

use crate::util::prng::Rng;

/// One request in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Arrival offset from trace start, seconds.
    pub at: f64,
    pub class_label: usize,
    pub steps: usize,
    pub seed: u64,
}

/// A generated trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

/// Trace-generation parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub requests: usize,
    /// Mean arrival rate (req/s). 0 ⇒ all arrive at t=0 (closed-loop batch).
    pub rate: f64,
    pub steps_choices: Vec<usize>,
    pub num_classes: usize,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            requests: 32,
            rate: 0.0,
            steps_choices: vec![20],
            num_classes: 10,
            seed: 0,
        }
    }
}

impl WorkloadSpec {
    pub fn generate(&self) -> Trace {
        let mut rng = Rng::new(self.seed ^ 0x77C0_11AD);
        let mut t = 0.0f64;
        let mut events = Vec::with_capacity(self.requests);
        for i in 0..self.requests {
            if self.rate > 0.0 {
                t += rng.exponential(self.rate);
            }
            let steps = self.steps_choices[rng.below(self.steps_choices.len())];
            events.push(TraceEvent {
                at: t,
                class_label: rng.below(self.num_classes),
                steps,
                seed: self.seed.wrapping_mul(0x9E37).wrapping_add(i as u64),
            });
        }
        Trace { events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_loop_all_at_zero() {
        let spec = WorkloadSpec { requests: 10, rate: 0.0, ..Default::default() };
        let tr = spec.generate();
        assert_eq!(tr.events.len(), 10);
        assert!(tr.events.iter().all(|e| e.at == 0.0));
    }

    #[test]
    fn poisson_arrivals_monotone() {
        let spec = WorkloadSpec { requests: 100, rate: 50.0, ..Default::default() };
        let tr = spec.generate();
        for w in tr.events.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        // mean inter-arrival ≈ 1/rate
        let total = tr.events.last().unwrap().at;
        let mean = total / 99.0;
        assert!((mean - 0.02).abs() < 0.01, "mean inter-arrival {mean}");
    }

    #[test]
    fn deterministic() {
        let spec = WorkloadSpec { requests: 20, rate: 10.0, seed: 5, ..Default::default() };
        assert_eq!(spec.generate().events, spec.generate().events);
    }

    #[test]
    fn respects_step_choices() {
        let spec = WorkloadSpec {
            requests: 50,
            steps_choices: vec![10, 20],
            ..Default::default()
        };
        let tr = spec.generate();
        assert!(tr.events.iter().all(|e| e.steps == 10 || e.steps == 20));
    }
}
