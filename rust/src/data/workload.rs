//! Serving workload generation: Poisson-arrival request traces with
//! configurable step counts, class mixes and lazy settings — the input to
//! the latency/throughput benches (Tables 3/6) and the serve example.

use crate::config::Slo;
use crate::util::prng::Rng;

/// One request in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Arrival offset from trace start, seconds.
    pub at: f64,
    pub class_label: usize,
    pub steps: usize,
    pub seed: u64,
    /// SLO class drawn from [`WorkloadSpec::slo_mix`] (best-effort when
    /// the mix is empty).
    pub slo: Slo,
}

/// A generated trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

/// Trace-generation parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub requests: usize,
    /// Mean arrival rate (req/s). 0 ⇒ all arrive at t=0 (closed-loop batch).
    pub rate: f64,
    pub steps_choices: Vec<usize>,
    pub num_classes: usize,
    pub seed: u64,
    /// SLO-class mix as (class, weight) pairs; weights need not sum
    /// to 1. Empty ⇒ every request is best-effort (and the RNG stream
    /// is identical to pre-SLO traces, keeping old seeds reproducible).
    pub slo_mix: Vec<(Slo, f64)>,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            requests: 32,
            rate: 0.0,
            steps_choices: vec![20],
            num_classes: 10,
            seed: 0,
            slo_mix: Vec::new(),
        }
    }
}

/// Weighted draw from an SLO mix (negative weights count as zero; an
/// all-zero mix degrades to best-effort). Zero-weight entries are
/// skipped outright: with the draw landing exactly on 0.0, a `x -= 0`
/// no-op followed by `x <= 0` would otherwise select a class the spec
/// explicitly weighted to zero.
fn draw_slo(rng: &mut Rng, mix: &[(Slo, f64)]) -> Slo {
    let total: f64 = mix.iter().map(|(_, w)| w.max(0.0)).sum();
    if total <= 0.0 {
        return Slo::Besteffort;
    }
    let mut x = rng.uniform() as f64 * total;
    for (slo, w) in mix {
        if *w <= 0.0 {
            continue;
        }
        x -= w;
        if x <= 0.0 {
            return *slo;
        }
    }
    // float residue: fall back to the last positively weighted class
    mix.iter()
        .rev()
        .find(|(_, w)| *w > 0.0)
        .map(|(s, _)| *s)
        .unwrap_or(Slo::Besteffort)
}

impl WorkloadSpec {
    pub fn generate(&self) -> Trace {
        let mut rng = Rng::new(self.seed ^ 0x77C0_11AD);
        let mut t = 0.0f64;
        let mut events = Vec::with_capacity(self.requests);
        for i in 0..self.requests {
            if self.rate > 0.0 {
                t += rng.exponential(self.rate);
            }
            let steps = self.steps_choices[rng.below(self.steps_choices.len())];
            let slo = if self.slo_mix.is_empty() {
                Slo::Besteffort
            } else {
                draw_slo(&mut rng, &self.slo_mix)
            };
            events.push(TraceEvent {
                at: t,
                class_label: rng.below(self.num_classes),
                steps,
                seed: self.seed.wrapping_mul(0x9E37).wrapping_add(i as u64),
                slo,
            });
        }
        Trace { events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_loop_all_at_zero() {
        let spec = WorkloadSpec { requests: 10, rate: 0.0, ..Default::default() };
        let tr = spec.generate();
        assert_eq!(tr.events.len(), 10);
        assert!(tr.events.iter().all(|e| e.at == 0.0));
    }

    #[test]
    fn poisson_arrivals_monotone() {
        let spec = WorkloadSpec { requests: 100, rate: 50.0, ..Default::default() };
        let tr = spec.generate();
        for w in tr.events.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        // mean inter-arrival ≈ 1/rate
        let total = tr.events.last().unwrap().at;
        let mean = total / 99.0;
        assert!((mean - 0.02).abs() < 0.01, "mean inter-arrival {mean}");
    }

    #[test]
    fn deterministic() {
        let spec = WorkloadSpec { requests: 20, rate: 10.0, seed: 5, ..Default::default() };
        assert_eq!(spec.generate().events, spec.generate().events);
    }

    #[test]
    fn empty_mix_is_besteffort_and_stream_compatible() {
        let legacy = WorkloadSpec { requests: 16, rate: 5.0, seed: 9,
                                    ..Default::default() };
        let tr = legacy.generate();
        assert!(tr.events.iter().all(|e| e.slo == Slo::Besteffort));
        // the per-event (at, label, steps, seed) tuple stream must not
        // change just because the SLO field exists
        assert_eq!(legacy.generate().events, tr.events);
    }

    #[test]
    fn slo_mix_draws_every_class_deterministically() {
        let spec = WorkloadSpec {
            requests: 300,
            slo_mix: vec![(Slo::Latency, 0.3), (Slo::Throughput, 0.5),
                          (Slo::Besteffort, 0.2)],
            seed: 11,
            ..Default::default()
        };
        let tr = spec.generate();
        let count = |s: Slo| tr.events.iter().filter(|e| e.slo == s).count();
        for slo in Slo::ALL {
            assert!(count(slo) > 0, "{} never drawn", slo.name());
        }
        // weights steer the mix (rough bounds, deterministic seed)
        assert!(count(Slo::Throughput) > count(Slo::Besteffort));
        assert_eq!(spec.generate().events, tr.events, "deterministic");
    }

    #[test]
    fn zero_weight_classes_are_never_drawn() {
        let spec = WorkloadSpec {
            requests: 500,
            slo_mix: vec![(Slo::Latency, 0.0), (Slo::Throughput, 1.0)],
            seed: 3,
            ..Default::default()
        };
        assert!(spec.generate().events.iter()
            .all(|e| e.slo == Slo::Throughput));
    }

    #[test]
    fn degenerate_mixes_fall_back_to_besteffort() {
        let spec = WorkloadSpec {
            requests: 8,
            slo_mix: vec![(Slo::Latency, 0.0), (Slo::Throughput, -1.0)],
            ..Default::default()
        };
        assert!(spec.generate().events.iter()
            .all(|e| e.slo == Slo::Besteffort));
    }

    #[test]
    fn respects_step_choices() {
        let spec = WorkloadSpec {
            requests: 50,
            steps_choices: vec![10, 20],
            ..Default::default()
        };
        let tr = spec.generate();
        assert!(tr.events.iter().all(|e| e.steps == 10 || e.steps == 20));
    }
}
