//! SynthBlobs-10: a procedural, class-conditional image distribution used
//! as the ImageNet substitute (DESIGN.md §4 substitution table).
//!
//! Each of the 10 classes is a deterministic template of two colored
//! Gaussian blobs (class-specific positions, colors, widths) over a
//! class-tinted background. Samples jitter blob positions, colors and
//! background and add pixel noise — multi-modal, learnable in minutes,
//! and discriminative enough for the FID/IS analogs to rank methods.
//!
//! Values are in [-1, 1], layout NCHW, C = 3.

use crate::tensor::Tensor;
use crate::util::prng::Rng;

/// Dataset generator for one image size.
#[derive(Debug, Clone)]
pub struct SynthBlobs {
    pub img_size: usize,
    pub num_classes: usize,
}

/// Deterministic per-class template.
#[derive(Debug, Clone)]
pub struct ClassTemplate {
    pub centers: [(f32, f32); 2],
    pub colors: [[f32; 3]; 2],
    pub sigma: f32,
    pub background: [f32; 3],
}

impl SynthBlobs {
    pub fn new(img_size: usize) -> SynthBlobs {
        SynthBlobs { img_size, num_classes: 10 }
    }

    /// The fixed template of class `k` (independent of sampling RNG).
    pub fn template(&self, k: usize) -> ClassTemplate {
        assert!(k < self.num_classes);
        // derive all constants from a per-class PRNG stream so templates
        // are reproducible everywhere (python never needs them)
        let mut rng = Rng::new(0x5EED_0000 + k as u64);
        let angle = 2.0 * std::f32::consts::PI * (k as f32) / self.num_classes as f32;
        let r = 0.28;
        let c1 = (0.5 + r * angle.cos(), 0.5 + r * angle.sin());
        let c2 = (0.5 - r * angle.cos(), 0.5 - r * angle.sin());
        let mut color = || {
            [
                rng.uniform_in(-0.9, 0.9),
                rng.uniform_in(-0.9, 0.9),
                rng.uniform_in(-0.9, 0.9),
            ]
        };
        let colors = [color(), color()];
        let background = [
            rng.uniform_in(-0.25, 0.25),
            rng.uniform_in(-0.25, 0.25),
            rng.uniform_in(-0.25, 0.25),
        ];
        let sigma = 0.10 + 0.05 * ((k % 3) as f32);
        ClassTemplate { centers: [c1, c2], colors, sigma, background }
    }

    /// Render one sample of class `k` into `out` ([3, S, S] slice).
    pub fn render_into(&self, k: usize, rng: &mut Rng, out: &mut [f32]) {
        let s = self.img_size;
        debug_assert_eq!(out.len(), 3 * s * s);
        let t = self.template(k);
        // per-sample jitter
        let jitter = 0.06;
        let centers: Vec<(f32, f32)> = t
            .centers
            .iter()
            .map(|&(cx, cy)| {
                (
                    cx + rng.uniform_in(-jitter, jitter),
                    cy + rng.uniform_in(-jitter, jitter),
                )
            })
            .collect();
        let cscale = rng.uniform_in(0.85, 1.15);
        let bg_jit = rng.uniform_in(-0.08, 0.08);
        let sigma = t.sigma * rng.uniform_in(0.9, 1.1);
        let inv2s2 = 1.0 / (2.0 * sigma * sigma);
        let noise_amp = 0.05;

        for c in 0..3 {
            for y in 0..s {
                for x in 0..s {
                    let fx = (x as f32 + 0.5) / s as f32;
                    let fy = (y as f32 + 0.5) / s as f32;
                    let mut v = t.background[c] + bg_jit;
                    for (bi, &(cx, cy)) in centers.iter().enumerate() {
                        let d2 = (fx - cx) * (fx - cx) + (fy - cy) * (fy - cy);
                        v += cscale * t.colors[bi][c] * (-d2 * inv2s2).exp();
                    }
                    v += noise_amp * rng.normal();
                    out[c * s * s + y * s + x] = v.clamp(-1.0, 1.0);
                }
            }
        }
    }

    /// Sample a batch: images [B, 3, S, S] and labels [B].
    pub fn sample_batch(&self, rng: &mut Rng, batch: usize) -> (Tensor, Vec<usize>) {
        let s = self.img_size;
        let mut imgs = Tensor::zeros(&[batch, 3, s, s]);
        let mut labels = Vec::with_capacity(batch);
        let row = 3 * s * s;
        for b in 0..batch {
            let k = rng.below(self.num_classes);
            labels.push(k);
            self.render_into(k, rng, &mut imgs.data_mut()[b * row..(b + 1) * row]);
        }
        (imgs, labels)
    }

    /// Sample a batch with the given labels.
    pub fn sample_batch_labeled(&self, rng: &mut Rng, labels: &[usize]) -> Tensor {
        let s = self.img_size;
        let mut imgs = Tensor::zeros(&[labels.len(), 3, s, s]);
        let row = 3 * s * s;
        for (b, &k) in labels.iter().enumerate() {
            self.render_into(k, rng, &mut imgs.data_mut()[b * row..(b + 1) * row]);
        }
        imgs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn values_in_range() {
        let ds = SynthBlobs::new(8);
        let mut rng = Rng::new(1);
        let (imgs, labels) = ds.sample_batch(&mut rng, 16);
        assert_eq!(imgs.shape(), &[16, 3, 8, 8]);
        assert_eq!(labels.len(), 16);
        for &v in imgs.data() {
            assert!((-1.0..=1.0).contains(&v));
        }
        assert!(labels.iter().all(|&k| k < 10));
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = SynthBlobs::new(8);
        let (a, la) = ds.sample_batch(&mut Rng::new(7), 4);
        let (b, lb) = ds.sample_batch(&mut Rng::new(7), 4);
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn classes_are_distinguishable() {
        // mean intra-class distance should be well below inter-class
        let ds = SynthBlobs::new(8);
        let mut rng = Rng::new(3);
        let a1 = ds.sample_batch_labeled(&mut rng, &[0; 8]);
        let a2 = ds.sample_batch_labeled(&mut rng, &[0; 8]);
        let b = ds.sample_batch_labeled(&mut rng, &[5; 8]);
        let intra = a1.sub(&a2).l2_norm();
        let inter = a1.sub(&b).l2_norm();
        assert!(
            inter > 1.5 * intra,
            "inter {inter} should dominate intra {intra}"
        );
    }

    #[test]
    fn templates_fixed() {
        let ds = SynthBlobs::new(16);
        let t1 = ds.template(3);
        let t2 = ds.template(3);
        assert_eq!(t1.centers, t2.centers);
        assert_eq!(t1.colors, t2.colors);
    }
}
