//! Data substrates: the SynthBlobs-10 dataset (ImageNet stand-in, see
//! DESIGN.md §4) and serving workload/trace generation.

pub mod synth;
pub mod workload;

pub use synth::SynthBlobs;
pub use workload::{Trace, TraceEvent, WorkloadSpec};
