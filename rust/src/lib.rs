//! # LazyDiT — lazy learning for the acceleration of diffusion transformers
//!
//! Rust + JAX + Pallas reproduction of Shen et al., AAAI 2025 (see
//! `DESIGN.md`). This crate is the L3 layer: the serving coordinator,
//! sampler, training drivers, metrics, benchmarks, and every substrate
//! they need. Model compute runs through AOT-compiled XLA executables
//! (`artifacts/*.hlo.txt`) loaded via the PJRT C API — Python is never on
//! the request path.
//!
//! Module map (DESIGN.md §5):
//! * [`util`] — substrates: JSON, PRNG, npy, argparse, thread pool,
//!   property-testing mini-framework, logging.
//! * [`config`] — model/serve/train configuration.
//! * [`tensor`] — host tensors and the small host-side math.
//! * [`runtime`] — PJRT client, manifest, executable registry.
//! * [`model`] — parameter store, checkpoints, the lazy block runner.
//! * [`sampler`] — diffusion schedules, DDIM, classifier-free guidance.
//! * [`coordinator`] — the paper's system contribution: continuous
//!   batcher, denoise scheduler (per-request caches live in the engine's
//!   request state), replica pool with lazy-aware routing, skip
//!   policies, server.
//! * [`train`] — pretraining + lazy-learning drivers (AOT train steps).
//! * [`data`] — SynthBlobs-10 dataset and workload generators.
//! * [`metrics`] — FID/sFID/IS/precision-recall analogs + linalg.
//! * [`baselines`] — DDIM step-reduction, Learn2Cache-analog, DeepCache-analog.
//! * [`tmacs`] — analytic compute-cost model (TMACs columns).
//! * [`obs`] — serving telemetry: shared epoch, log-bucketed latency
//!   histograms, per-replica trace rings, Chrome-trace export.
//! * [`io`] — PNG/CSV/markdown writers.
//! * [`bench`] — benchmark harness (criterion is unavailable offline).

pub mod util;
pub mod config;
pub mod tensor;
pub mod runtime;
pub mod model;
pub mod sampler;
pub mod coordinator;
pub mod train;
pub mod data;
pub mod metrics;
pub mod baselines;
pub mod tmacs;
pub mod obs;
pub mod io;
pub mod bench;
pub mod cli;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
