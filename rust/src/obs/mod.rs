//! Serving telemetry substrate: one shared monotonic epoch, log-bucketed
//! mergeable latency histograms, per-replica lock-free trace ring
//! buffers, and Chrome-trace export (see `docs/OBSERVABILITY.md`).
//!
//! Everything here is allocation-free on the hot path: histograms record
//! into fixed atomic arrays, rings overwrite fixed slots, and a disabled
//! [`Tracer`] is a `None` check. Readers (STATS/TRACE/export) pay the
//! allocations instead.

pub mod chrome;
pub mod epoch;
pub mod hist;
pub mod ring;

pub use epoch::{epoch, epoch_us};
pub use hist::LatencyHist;
pub use ring::{EventKind, TraceEvent, TraceRing, Tracer};
