//! Log-bucketed latency histogram (HDR-style): a fixed atomic array of
//! 496 buckets covering every `u64` microsecond value with ≤ 12.5%
//! relative error, mergeable across replicas and SLO tiers.
//!
//! Layout (log-linear, 8 sub-buckets per octave): values 0–7 get exact
//! unit buckets; a value `v ≥ 8` with most-significant bit `m` lands in
//! octave `o = m − 2`, sub-bucket `(v >> (m−3)) − 8`, i.e. index
//! `o·8 + sub`. Bucket `i ≥ 8` spans `[(8+i%8) << (i/8 − 1), …)` with
//! width `1 << (i/8 − 1)`, so width/lower-bound ≤ 1/8 everywhere.
//! Recording is two relaxed `fetch_add`s — no locks, no allocation —
//! which is what lets the serving hot path keep per-SLO histograms live.

use std::sync::atomic::{AtomicU64, Ordering};

/// Total bucket count: 8 unit buckets + 61 octaves × 8 sub-buckets.
pub const BUCKETS: usize = 496;

/// A mergeable log-bucketed latency histogram over microsecond values.
#[derive(Debug)]
pub struct LatencyHist {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl LatencyHist {
    /// An empty histogram (one ~4 KB allocation; recording never
    /// allocates again).
    pub fn new() -> LatencyHist {
        LatencyHist {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Bucket index for a microsecond value (see module docs).
    pub fn index(v_us: u64) -> usize {
        if v_us < 8 {
            return v_us as usize;
        }
        let m = 63 - v_us.leading_zeros() as u64; // msb position, >= 3
        let octave = m - 2;
        let sub = (v_us >> (m - 3)) - 8; // 0..8
        (octave * 8 + sub) as usize
    }

    /// Inclusive lower bound of bucket `i`.
    pub fn bucket_low(i: usize) -> u64 {
        if i < 8 {
            return i as u64;
        }
        let (octave, sub) = (i as u64 / 8, i as u64 % 8);
        (8 + sub) << (octave - 1)
    }

    /// Representative (midpoint) value of bucket `i` — what quantiles
    /// report.
    pub fn bucket_mid(i: usize) -> u64 {
        if i < 8 {
            return i as u64;
        }
        let width = 1u64 << (i as u64 / 8 - 1);
        Self::bucket_low(i) + width / 2
    }

    /// Record one microsecond sample (lock-free, allocation-free).
    pub fn record_us(&self, v_us: u64) {
        self.buckets[Self::index(v_us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(v_us, Ordering::Relaxed);
    }

    /// Record a sample given in milliseconds.
    pub fn record_ms(&self, ms: f64) {
        self.record_us((ms.max(0.0) * 1e3) as u64);
    }

    /// Record a sample given in seconds.
    pub fn record_secs(&self, s: f64) {
        self.record_us((s.max(0.0) * 1e6) as u64);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean in microseconds (0.0 when empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Quantile `q ∈ [0, 1]` in microseconds: the midpoint of the bucket
    /// holding the ceil(q·count)-th smallest sample (0 when empty).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= rank {
                return Self::bucket_mid(i);
            }
        }
        Self::bucket_mid(BUCKETS - 1)
    }

    /// Quantile in milliseconds (convenience for wire/report surfaces).
    pub fn quantile_ms(&self, q: f64) -> f64 {
        self.quantile_us(q) as f64 / 1e3
    }

    /// Fold another histogram into this one (bucket-wise add — the merge
    /// of two histograms is exactly the histogram of the union).
    pub fn merge_from(&self, other: &LatencyHist) {
        for (a, b) in self.buckets.iter().zip(other.buckets.iter()) {
            let v = b.load(Ordering::Relaxed);
            if v != 0 {
                a.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_us
            .fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

impl Default for LatencyHist {
    fn default() -> LatencyHist {
        LatencyHist::new()
    }
}

impl Clone for LatencyHist {
    fn clone(&self) -> LatencyHist {
        let h = LatencyHist::new();
        h.merge_from(self);
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_bounds_agree() {
        // every probe value lands in a bucket whose [low, next-low)
        // range contains it, and bucket lows are strictly increasing
        for i in 1..BUCKETS {
            assert!(LatencyHist::bucket_low(i) > LatencyHist::bucket_low(i - 1),
                    "bucket lows must increase at {i}");
        }
        let mut probes: Vec<u64> = (0..64).map(|s| 1u64 << s).collect();
        probes.extend((0..64).map(|s| (1u64 << s) - 1));
        probes.extend([0, 3, 7, 8, 9, 100, 999, 12_345, u64::MAX]);
        for v in probes {
            let i = LatencyHist::index(v);
            assert!(i < BUCKETS, "index {i} out of range for {v}");
            assert!(LatencyHist::bucket_low(i) <= v,
                    "low({i}) > {v}");
            if i + 1 < BUCKETS {
                assert!(v < LatencyHist::bucket_low(i + 1),
                        "{v} belongs to a later bucket than {i}");
            }
        }
    }

    #[test]
    fn relative_error_is_bounded() {
        // the midpoint never misrepresents a sample by more than 12.5%
        let mut x = 0x9e3779b97f4a7c15u64;
        for _ in 0..10_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let v = x % 100_000_000; // up to 100 s in µs
            let mid = LatencyHist::bucket_mid(LatencyHist::index(v)) as f64;
            let err = (mid - v as f64).abs() / (v as f64).max(1.0);
            assert!(err <= 0.125, "value {v} -> mid {mid} (err {err})");
        }
    }

    #[test]
    fn quantiles_track_sorted_samples() {
        // deterministic sample set; histogram quantiles must agree with
        // the exact sorted quantiles within the bucket error bound
        let h = LatencyHist::new();
        let mut samples: Vec<u64> = Vec::new();
        let mut x = 42u64;
        for _ in 0..5000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = 100 + (x >> 33) % 1_000_000; // 100 µs .. ~1 s
            samples.push(v);
            h.record_us(v);
        }
        samples.sort_unstable();
        for q in [0.5, 0.95, 0.99] {
            let rank = ((q * samples.len() as f64).ceil() as usize)
                .clamp(1, samples.len());
            let exact = samples[rank - 1] as f64;
            let est = h.quantile_us(q) as f64;
            assert!((est - exact).abs() / exact <= 0.125,
                    "q{q}: est {est} vs exact {exact}");
        }
        assert_eq!(h.count(), 5000);
        let exact_mean =
            samples.iter().sum::<u64>() as f64 / samples.len() as f64;
        assert!((h.mean_us() - exact_mean).abs() < 1e-6,
                "mean is exact (sum is kept outside the buckets)");
    }

    #[test]
    fn merge_equals_union() {
        let a = LatencyHist::new();
        let b = LatencyHist::new();
        let u = LatencyHist::new();
        for v in [10u64, 20, 30, 40_000] {
            a.record_us(v);
            u.record_us(v);
        }
        for v in [15u64, 1_000_000, 7] {
            b.record_us(v);
            u.record_us(v);
        }
        a.merge_from(&b);
        assert_eq!(a.count(), u.count());
        for q in [0.25, 0.5, 0.9, 1.0] {
            assert_eq!(a.quantile_us(q), u.quantile_us(q), "q{q}");
        }
        let c = a.clone();
        assert_eq!(c.count(), a.count());
        assert_eq!(c.quantile_us(0.5), a.quantile_us(0.5));
    }

    #[test]
    fn empty_hist_reports_zero() {
        let h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }
}
