//! Chrome-trace-format export: turns ring-buffer events into a JSON
//! file `chrome://tracing` and Perfetto load directly — one track (tid)
//! per replica, duration slices for engine spans (module run/skip
//! colored apart), instant markers for admission/steal/retire — plus a
//! pure-Rust structural validator the CI smoke gate and tests share.

use crate::obs::ring::{unpack_module_arg, unpack_pair, EventKind,
                       TraceEvent, Tracer};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// What a validated trace contains (enough for tests and the tier-1
/// smoke gate to assert on without re-parsing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChromeSummary {
    /// Total entries in `traceEvents` (metadata included).
    pub events: usize,
    /// `ph:"X"` duration slices.
    pub slices: usize,
    /// `ph:"i"` instant events.
    pub instants: usize,
    /// Distinct tids carrying non-metadata events (≈ replicas).
    pub tracks: usize,
}

/// Gather `(replica, events)` groups from live tracers (disabled ones
/// contribute nothing), newest `max_per` events per replica.
pub fn collect_tracers(tracers: &[Tracer], max_per: usize)
                       -> Vec<(usize, Vec<TraceEvent>)> {
    tracers
        .iter()
        .filter_map(|t| t.ring().map(|r| (t.replica(), r.snapshot(max_per))))
        .collect()
}

fn event_args(ev: &TraceEvent) -> Json {
    match ev.kind {
        EventKind::Admit => Json::obj(vec![
            ("id", Json::num(ev.kind_id as f64)),
            ("steps", Json::num(ev.arg as f64)),
        ]),
        EventKind::QueueWait => Json::obj(vec![
            ("id", Json::num(ev.kind_id as f64)),
            ("wait_us", Json::num(ev.dur_us as f64)),
        ]),
        EventKind::BatchBuild => {
            let (lanes, bucket) = unpack_pair(ev.arg);
            Json::obj(vec![
                ("lanes", Json::num(lanes as f64)),
                ("bucket", Json::num(bucket as f64)),
            ])
        }
        EventKind::ModuleRun | EventKind::ModuleSkip => {
            let (gate, rows_run, rows_skipped) = unpack_module_arg(ev.arg);
            Json::obj(vec![
                ("slot", Json::num(ev.kind_id as f64)),
                ("gate", Json::num(gate)),
                ("rows_run", Json::num(rows_run as f64)),
                ("rows_skipped", Json::num(rows_skipped as f64)),
            ])
        }
        EventKind::Scatter => {
            let (retained, migrated) = unpack_pair(ev.arg);
            Json::obj(vec![
                ("rows_retained", Json::num(retained as f64)),
                ("rows_migrated", Json::num(migrated as f64)),
            ])
        }
        EventKind::Steal => Json::obj(vec![
            ("id", Json::num(ev.kind_id as f64)),
            ("steps", Json::num(ev.arg as f64)),
            ("queued_us", Json::num(ev.dur_us as f64)),
        ]),
        EventKind::Retire => {
            let (slo, steps) = unpack_pair(ev.arg);
            Json::obj(vec![
                ("id", Json::num(ev.kind_id as f64)),
                ("latency_ms", Json::num(ev.dur_us as f64 / 1e3)),
                ("slo", Json::num(slo as f64)),
                ("steps", Json::num(steps as f64)),
            ])
        }
        EventKind::Migrate => {
            let (cursor, remaining) = unpack_pair(ev.arg);
            Json::obj(vec![
                ("id", Json::num(ev.kind_id as f64)),
                ("cursor", Json::num(cursor as f64)),
                ("remaining_steps", Json::num(remaining as f64)),
            ])
        }
        EventKind::CacheHit => Json::obj(vec![
            ("id", Json::num(ev.kind_id as f64)),
            ("steps_saved", Json::num(ev.arg as f64)),
        ]),
        EventKind::Brownout => {
            let (from, to) = unpack_pair(ev.arg);
            Json::obj(vec![
                ("from_stage", Json::num(from as f64)),
                ("to_stage", Json::num(to as f64)),
            ])
        }
        EventKind::Respawn => Json::obj(vec![
            ("replica", Json::num(ev.kind_id as f64)),
            ("restarts", Json::num(ev.arg as f64)),
        ]),
        EventKind::BreakerTrip => Json::obj(vec![
            ("replica", Json::num(ev.kind_id as f64)),
            ("trips", Json::num(ev.arg as f64)),
        ]),
    }
}

fn event_json(replica: usize, ev: &TraceEvent) -> Json {
    let mut pairs = vec![
        ("name", Json::str(ev.kind.name())),
        ("pid", Json::num(0.0)),
        ("tid", Json::num(replica as f64)),
        ("ts", Json::num(ev.ts_us as f64)),
        ("args", event_args(ev)),
    ];
    if ev.kind.is_slice() {
        pairs.push(("ph", Json::str("X")));
        pairs.push(("dur", Json::num(ev.dur_us as f64)));
        // color run vs skip apart in the viewer (reserved palette names)
        match ev.kind {
            EventKind::ModuleRun => {
                pairs.push(("cname", Json::str("thread_state_running")));
            }
            EventKind::ModuleSkip => pairs.push(("cname", Json::str("good"))),
            _ => {}
        }
    } else {
        pairs.push(("ph", Json::str("i")));
        pairs.push(("s", Json::str("t"))); // thread-scoped instant
    }
    Json::obj(pairs)
}

/// Build the full Chrome-trace JSON document for `(replica, events)`
/// groups: per-replica `thread_name` metadata plus every event.
pub fn chrome_trace_json(groups: &[(usize, Vec<TraceEvent>)]) -> Json {
    let mut events: Vec<Json> = vec![Json::obj(vec![
        ("name", Json::str("process_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(0.0)),
        ("args", Json::obj(vec![("name", Json::str("lazydit pool"))])),
    ])];
    for (replica, evs) in groups {
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(*replica as f64)),
            ("args",
             Json::obj(vec![("name",
                             Json::str(&format!("replica {replica}")))])),
        ]));
        for ev in evs {
            events.push(event_json(*replica, ev));
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Serialize + validate + write a Chrome trace. The self-validation
/// means a written file is structurally loadable by construction; the
/// summary comes back for logging/asserting.
pub fn write_chrome_trace(path: &Path, groups: &[(usize, Vec<TraceEvent>)])
                          -> Result<ChromeSummary> {
    let text = chrome_trace_json(groups).to_string();
    let summary = validate_chrome_trace(&text)
        .context("generated trace failed self-validation")?;
    std::fs::write(path, &text)
        .with_context(|| format!("writing trace to {}", path.display()))?;
    Ok(summary)
}

/// Structural validator for Chrome-trace JSON (the tier-1 smoke gate's
/// no-jq check): top-level `traceEvents` array; every entry an object
/// with a known `ph`, a non-empty `name`, and a numeric `pid`; duration
/// slices additionally need numeric `ts`/`dur`/`tid`, instants need
/// `ts`/`tid`.
pub fn validate_chrome_trace(text: &str) -> Result<ChromeSummary> {
    let root = Json::parse(text)
        .map_err(|e| anyhow::anyhow!("not valid JSON: {e}"))?;
    let Some(events) = root.get("traceEvents").and_then(|v| v.as_arr()) else {
        bail!("missing top-level traceEvents array");
    };
    let mut summary = ChromeSummary { events: events.len(), slices: 0,
                                      instants: 0, tracks: 0 };
    let mut tids: Vec<u64> = Vec::new();
    for (i, ev) in events.iter().enumerate() {
        let Some(obj) = ev.as_obj() else {
            bail!("traceEvents[{i}] is not an object");
        };
        let ph = obj.get("ph").and_then(|v| v.as_str()).unwrap_or("");
        let name = obj.get("name").and_then(|v| v.as_str()).unwrap_or("");
        if name.is_empty() {
            bail!("traceEvents[{i}] has no name");
        }
        if obj.get("pid").and_then(|v| v.as_f64()).is_none() {
            bail!("traceEvents[{i}] ({name}) has no numeric pid");
        }
        match ph {
            "M" => {}
            "X" | "i" => {
                for key in ["ts", "tid"] {
                    if obj.get(key).and_then(|v| v.as_f64()).is_none() {
                        bail!("traceEvents[{i}] ({name}) missing numeric \
                               {key}");
                    }
                }
                if ph == "X" {
                    if obj.get("dur").and_then(|v| v.as_f64()).is_none() {
                        bail!("traceEvents[{i}] ({name}) slice missing dur");
                    }
                    summary.slices += 1;
                } else {
                    summary.instants += 1;
                }
                let tid = obj.get("tid").and_then(|v| v.as_u64()).unwrap_or(0);
                if !tids.contains(&tid) {
                    tids.push(tid);
                }
            }
            other => bail!("traceEvents[{i}] ({name}) has unknown ph {other:?}"),
        }
    }
    summary.tracks = tids.len();
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::ring::{pack_module_arg, pack_pair};

    fn sample_groups() -> Vec<(usize, Vec<TraceEvent>)> {
        let mk = |kind, ts, dur, id, arg| TraceEvent {
            kind, ts_us: ts, dur_us: dur, kind_id: id, arg,
        };
        vec![
            (0, vec![
                mk(EventKind::Admit, 10, 0, 1, 4),
                mk(EventKind::BatchBuild, 20, 5, 0, pack_pair(2, 4)),
                mk(EventKind::ModuleRun, 21, 3, 0, pack_module_arg(0.2, 2, 0)),
                mk(EventKind::ModuleSkip, 24, 1, 1, pack_module_arg(0.9, 0, 2)),
                mk(EventKind::Retire, 40, 30, 1, pack_pair(1, 4)),
            ]),
            (1, vec![
                mk(EventKind::Steal, 15, 0, 0, 4),
                mk(EventKind::Scatter, 25, 2, 0, pack_pair(3, 1)),
                mk(EventKind::QueueWait, 12, 8, 2, 0),
            ]),
        ]
    }

    #[test]
    fn written_trace_validates_and_summarizes() {
        let dir = std::env::temp_dir().join("lazydit_obs_chrome_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let summary = write_chrome_trace(&path, &sample_groups()).unwrap();
        // 8 events + process_name + 2 thread_name metadata
        assert_eq!(summary.events, 11);
        assert_eq!(summary.slices, 4, "batch_build/run/skip/scatter");
        assert_eq!(summary.instants, 4, "admit/retire/steal/queue_wait");
        assert_eq!(summary.tracks, 2);
        // independently re-validate what landed on disk
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(validate_chrome_trace(&text).unwrap(), summary);
        // run vs skip are visually distinct
        assert!(text.contains("thread_state_running"));
        assert!(text.contains("\"good\""));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn validator_rejects_malformed_traces() {
        for (bad, why) in [
            ("{}", "no traceEvents"),
            ("[1,2]", "array root"),
            ("{\"traceEvents\": [42]}", "non-object event"),
            ("{\"traceEvents\": [{\"ph\":\"X\",\"pid\":0}]}", "no name"),
            ("{\"traceEvents\": [{\"name\":\"a\",\"ph\":\"Z\",\"pid\":0}]}",
             "unknown ph"),
            ("{\"traceEvents\": [{\"name\":\"a\",\"ph\":\"X\",\"pid\":0,\
              \"tid\":0,\"ts\":1}]}", "slice without dur"),
            ("not json at all", "unparsable"),
        ] {
            assert!(validate_chrome_trace(bad).is_err(), "accepted: {why}");
        }
    }

    #[test]
    fn collect_skips_disabled_tracers() {
        let on = Tracer::enabled(2, 8);
        on.record(EventKind::Admit, 1, 1);
        let groups = collect_tracers(&[Tracer::disabled(), on], 100);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].0, 2);
        assert_eq!(groups[0].1.len(), 1);
    }
}
