//! Per-replica trace ring buffer: a fixed-capacity, lock-free record of
//! typed span events, overwritten oldest-first and readable from any
//! thread without stopping the writer.
//!
//! Concurrency model: each ring has exactly ONE writer (the replica
//! worker thread that owns the engine) and any number of readers (the
//! STATS/TRACE connection threads, the post-serve Chrome exporter).
//! Every slot is a tiny seqlock: the writer stamps `seq = 2·h + 1`
//! (release) before the payload words and `seq = 2·h + 2` (release)
//! after, where `h` is the event's all-time sequence number. A reader
//! accepts a slot only when `seq == 2·h + 2` before AND after copying
//! the words, so torn or overwritten slots are skipped, never surfaced.
//! The monotone `head` counter is the all-time total: overwriting drops
//! old *payloads*, never the count.

use crate::obs::epoch::epoch_us;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Typed trace event kinds (the wire/export taxonomy; see
/// `docs/OBSERVABILITY.md` for the field meaning per kind).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u64)]
pub enum EventKind {
    /// A request entered a replica's queue (`id` = request id,
    /// `arg` = wire steps).
    Admit = 1,
    /// Time between enqueue and engine admission (`dur_us` = the wait).
    QueueWait = 2,
    /// One engine scheduling round (`arg` = packed (lanes, bucket)).
    BatchBuild = 3,
    /// A module slot executed (`id` = slot index, `arg` = packed gate
    /// value + rows run/skipped).
    ModuleRun = 4,
    /// A module slot was lazily skipped (same packing as ModuleRun).
    ModuleSkip = 5,
    /// Batch residency churn this round (`arg` = packed
    /// (rows retained, rows migrated)).
    Scatter = 6,
    /// A queued job migrated to this replica via work stealing
    /// (`id` = request id, `dur_us` = time the job sat queued before
    /// the theft, `arg` = wire steps).
    Steal = 7,
    /// A request finished (`id` = request id, `dur_us` = latency,
    /// `arg` = packed (slo index, steps)).
    Retire = 8,
    /// A trajectory crossed a replica boundary as a portable snapshot:
    /// evicted out (drain / mid-trajectory relief) or admitted back in
    /// (`id` = request id, `arg` = packed (cursor, remaining steps)).
    Migrate = 9,
    /// A request was served straight from the pool result cache — zero
    /// engine work (`id` = request id, `arg` = wire steps the cache
    /// saved). Recorded on replica 0's ring: the router, which fronts
    /// the cache, owns no ring of its own.
    CacheHit = 10,
    /// The brownout controller changed degradation stage (`id` = the
    /// new stage, `arg` = packed (from, to)). Recorded on replica 0's
    /// ring — the controller, like the cache, is pool-wide.
    Brownout = 11,
    /// The supervisor respawned a dead worker into this slot (`id` =
    /// replica id, `arg` = restarts so far including this one).
    Respawn = 12,
    /// A replica's circuit breaker tripped open (`id` = replica id,
    /// `arg` = trips so far including this one).
    BreakerTrip = 13,
}

impl EventKind {
    /// Decode the on-ring representation (None for a corrupt word —
    /// readers drop such slots).
    pub fn from_u64(v: u64) -> Option<EventKind> {
        Some(match v {
            1 => EventKind::Admit,
            2 => EventKind::QueueWait,
            3 => EventKind::BatchBuild,
            4 => EventKind::ModuleRun,
            5 => EventKind::ModuleSkip,
            6 => EventKind::Scatter,
            7 => EventKind::Steal,
            8 => EventKind::Retire,
            9 => EventKind::Migrate,
            10 => EventKind::CacheHit,
            11 => EventKind::Brownout,
            12 => EventKind::Respawn,
            13 => EventKind::BreakerTrip,
            _ => return None,
        })
    }

    /// Stable snake_case name used in TRACE JSON and Chrome traces.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Admit => "admit",
            EventKind::QueueWait => "queue_wait",
            EventKind::BatchBuild => "batch_build",
            EventKind::ModuleRun => "module_run",
            EventKind::ModuleSkip => "module_skip",
            EventKind::Scatter => "scatter",
            EventKind::Steal => "steal",
            EventKind::Retire => "retire",
            EventKind::Migrate => "migrate",
            EventKind::CacheHit => "cache_hit",
            EventKind::Brownout => "brownout",
            EventKind::Respawn => "respawn",
            EventKind::BreakerTrip => "breaker_trip",
        }
    }

    /// True for kinds exported as duration slices (`ph:"X"`); the rest
    /// become instant events (`ph:"i"`).
    pub fn is_slice(self) -> bool {
        matches!(self,
                 EventKind::BatchBuild | EventKind::ModuleRun
                 | EventKind::ModuleSkip | EventKind::Scatter)
    }
}

/// One decoded trace event (five u64 words on the ring).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// What happened.
    pub kind: EventKind,
    /// Start time, µs since the shared epoch.
    pub ts_us: u64,
    /// Span duration in µs (0 for instants).
    pub dur_us: u64,
    /// Kind-specific identifier (request id or module slot index).
    pub kind_id: u64,
    /// Kind-specific packed payload (see the packing helpers).
    pub arg: u64,
}

/// Pack a module event payload: gate value (clamped to [0,1], stored in
/// millionths) plus rows run/skipped (saturated to 16 bits each).
pub fn pack_module_arg(gate: f64, rows_run: u32, rows_skipped: u32) -> u64 {
    let g = (gate.clamp(0.0, 1.0) * 1e6) as u64;
    g | ((rows_run.min(0xFFFF) as u64) << 32)
        | ((rows_skipped.min(0xFFFF) as u64) << 48)
}

/// Decode [`pack_module_arg`].
pub fn unpack_module_arg(arg: u64) -> (f64, u32, u32) {
    let gate = (arg & 0xFFFF_FFFF) as f64 / 1e6;
    let rows_run = ((arg >> 32) & 0xFFFF) as u32;
    let rows_skipped = ((arg >> 48) & 0xFFFF) as u32;
    (gate, rows_run, rows_skipped)
}

/// Pack two 32-bit counters into one payload word.
pub fn pack_pair(a: u32, b: u32) -> u64 {
    (a as u64) | ((b as u64) << 32)
}

/// Decode [`pack_pair`].
pub fn unpack_pair(arg: u64) -> (u32, u32) {
    ((arg & 0xFFFF_FFFF) as u32, (arg >> 32) as u32)
}

const WORDS: usize = 5;

struct Slot {
    seq: AtomicU64,
    w: [AtomicU64; WORDS],
}

impl Slot {
    fn empty() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            w: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0),
                AtomicU64::new(0), AtomicU64::new(0)],
        }
    }
}

/// The fixed-capacity ring itself. Built once per replica; shared via
/// `Arc` between the writer (inside [`Tracer`]) and readers.
pub struct TraceRing {
    slots: Box<[Slot]>,
    mask: usize,
    head: AtomicU64,
}

impl TraceRing {
    /// A ring with capacity `cap` rounded up to a power of two (min 2).
    pub fn new(cap: usize) -> TraceRing {
        let cap = cap.max(2).next_power_of_two();
        TraceRing {
            slots: (0..cap).map(|_| Slot::empty()).collect(),
            mask: cap - 1,
            head: AtomicU64::new(0),
        }
    }

    /// Slot capacity (how many recent events survive).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// All-time recorded count — monotone, never reduced by overwrite.
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Record one event. Single-writer only; allocation-free.
    pub fn record(&self, ev: TraceEvent) {
        let h = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(h as usize) & self.mask];
        // odd seq marks the payload as in-flight for concurrent readers
        slot.seq.store(2 * h + 1, Ordering::Release);
        slot.w[0].store(ev.kind as u64, Ordering::Relaxed);
        slot.w[1].store(ev.ts_us, Ordering::Relaxed);
        slot.w[2].store(ev.dur_us, Ordering::Relaxed);
        slot.w[3].store(ev.kind_id, Ordering::Relaxed);
        slot.w[4].store(ev.arg, Ordering::Relaxed);
        slot.seq.store(2 * h + 2, Ordering::Release);
        self.head.store(h + 1, Ordering::Release);
    }

    /// Copy out up to `max` of the most recent events, oldest first.
    /// Slots the writer is overwriting mid-copy are skipped (the seqlock
    /// check), so the result is always a set of whole events.
    pub fn snapshot(&self, max: usize) -> Vec<TraceEvent> {
        let head = self.recorded();
        let window = (self.slots.len() as u64).min(max as u64);
        let lo = head.saturating_sub(window);
        let mut out = Vec::with_capacity((head - lo) as usize);
        for i in lo..head {
            let slot = &self.slots[(i as usize) & self.mask];
            if slot.seq.load(Ordering::Acquire) != 2 * i + 2 {
                continue; // being overwritten (or torn): not event i anymore
            }
            let w: [u64; WORDS] =
                std::array::from_fn(|j| slot.w[j].load(Ordering::Acquire));
            if slot.seq.load(Ordering::Acquire) != 2 * i + 2 {
                continue; // overwritten while copying
            }
            if let Some(kind) = EventKind::from_u64(w[0]) {
                out.push(TraceEvent {
                    kind,
                    ts_us: w[1],
                    dur_us: w[2],
                    kind_id: w[3],
                    arg: w[4],
                });
            }
        }
        out
    }
}

impl std::fmt::Debug for TraceRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRing")
            .field("capacity", &self.capacity())
            .field("recorded", &self.recorded())
            .finish()
    }
}

/// The handle engines and replica workers record through. Cloning is
/// cheap (an `Arc` bump); the disabled form is a `None` and every record
/// call degrades to one branch — no clock read, no atomics, no
/// allocation.
#[derive(Debug, Clone, Default)]
pub struct Tracer {
    inner: Option<Arc<TraceRing>>,
    replica: usize,
}

impl Tracer {
    /// The no-op tracer (telemetry off — the default everywhere).
    pub fn disabled() -> Tracer {
        Tracer::default()
    }

    /// A live tracer over a fresh ring of `cap` slots for `replica`.
    pub fn enabled(replica: usize, cap: usize) -> Tracer {
        Tracer { inner: Some(Arc::new(TraceRing::new(cap))), replica }
    }

    /// Whether events are being collected.
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// The replica this tracer stamps (Chrome track / TRACE grouping).
    pub fn replica(&self) -> usize {
        self.replica
    }

    /// The underlying ring, for readers (None when disabled).
    pub fn ring(&self) -> Option<&Arc<TraceRing>> {
        self.inner.as_ref()
    }

    /// Epoch-µs now — or 0 without touching the clock when disabled, so
    /// hot paths can bracket spans with no disabled-mode overhead.
    pub fn now_us(&self) -> u64 {
        if self.inner.is_some() { epoch_us() } else { 0 }
    }

    /// Record an instant event stamped now.
    pub fn record(&self, kind: EventKind, kind_id: u64, arg: u64) {
        if let Some(ring) = &self.inner {
            ring.record(TraceEvent {
                kind, ts_us: epoch_us(), dur_us: 0, kind_id, arg,
            });
        }
    }

    /// Record a span that started at `start_us` (from [`Tracer::now_us`])
    /// and ends now.
    pub fn record_span(&self, kind: EventKind, start_us: u64, kind_id: u64,
                       arg: u64) {
        if let Some(ring) = &self.inner {
            let now = epoch_us();
            ring.record(TraceEvent {
                kind,
                ts_us: start_us,
                dur_us: now.saturating_sub(start_us),
                kind_id,
                arg,
            });
        }
    }

    /// Record a fully-specified event (timestamps already in hand).
    pub fn record_at(&self, ev: TraceEvent) {
        if let Some(ring) = &self.inner {
            ring.record(ev);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, ts: u64) -> TraceEvent {
        TraceEvent { kind, ts_us: ts, dur_us: 1, kind_id: ts, arg: 0 }
    }

    #[test]
    fn ring_keeps_newest_and_counts_everything() {
        // capacity rounds to 8; record 20 → the last 8 survive, but the
        // all-time counter says 20 (overwrite drops payloads, not counts)
        let r = TraceRing::new(5);
        assert_eq!(r.capacity(), 8);
        for i in 0..20u64 {
            r.record(ev(EventKind::Admit, i));
        }
        assert_eq!(r.recorded(), 20);
        let snap = r.snapshot(usize::MAX);
        assert_eq!(snap.len(), 8);
        let ts: Vec<u64> = snap.iter().map(|e| e.ts_us).collect();
        assert_eq!(ts, (12..20).collect::<Vec<u64>>(),
                   "oldest dropped, newest kept, order preserved");
        // a bounded snapshot returns the newest suffix
        let tail = r.snapshot(3);
        assert_eq!(tail.iter().map(|e| e.ts_us).collect::<Vec<_>>(),
                   vec![17, 18, 19]);
    }

    #[test]
    fn events_roundtrip_all_fields() {
        let r = TraceRing::new(4);
        let e = TraceEvent {
            kind: EventKind::ModuleSkip,
            ts_us: 123,
            dur_us: 45,
            kind_id: 6,
            arg: pack_module_arg(0.75, 3, 5),
        };
        r.record(e);
        let snap = r.snapshot(16);
        assert_eq!(snap, vec![e]);
        let (gate, run, skip) = unpack_module_arg(snap[0].arg);
        assert!((gate - 0.75).abs() < 1e-5);
        assert_eq!((run, skip), (3, 5));
    }

    #[test]
    fn pack_helpers_roundtrip() {
        assert_eq!(unpack_pair(pack_pair(7, 9)), (7, 9));
        assert_eq!(unpack_pair(pack_pair(u32::MAX, 0)), (u32::MAX, 0));
        let (g, r, s) = unpack_module_arg(pack_module_arg(1.5, 70_000, 2));
        assert_eq!(g, 1.0, "gate clamps to [0,1]");
        assert_eq!((r, s), (0xFFFF, 2), "row counts saturate at 16 bits");
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::disabled();
        assert!(!t.is_enabled());
        assert_eq!(t.now_us(), 0, "no clock read when disabled");
        t.record(EventKind::Admit, 1, 2);
        t.record_span(EventKind::BatchBuild, 0, 0, 0);
        assert!(t.ring().is_none());
    }

    #[test]
    fn enabled_tracer_feeds_its_ring() {
        let t = Tracer::enabled(3, 16);
        assert_eq!(t.replica(), 3);
        t.record(EventKind::Admit, 11, 4);
        let t0 = t.now_us();
        t.record_span(EventKind::BatchBuild, t0, 0, pack_pair(2, 4));
        let ring = t.ring().unwrap();
        assert_eq!(ring.recorded(), 2);
        let snap = ring.snapshot(16);
        assert_eq!(snap[0].kind, EventKind::Admit);
        assert_eq!(snap[1].kind, EventKind::BatchBuild);
        assert!(snap[1].ts_us >= snap[0].ts_us, "shared epoch orders events");
    }

    #[test]
    fn concurrent_reader_sees_only_whole_events() {
        // hammer the ring from one writer while a reader snapshots: every
        // surfaced event must be internally consistent (we encode a
        // checksum relation between the words)
        let ring = Arc::new(TraceRing::new(64));
        let w = ring.clone();
        let writer = std::thread::spawn(move || {
            for i in 0..50_000u64 {
                w.record(TraceEvent {
                    kind: EventKind::Retire,
                    ts_us: i,
                    dur_us: i.wrapping_mul(3),
                    kind_id: i ^ 0xABCD,
                    arg: i.wrapping_add(7),
                });
            }
        });
        let mut seen = 0u64;
        for _ in 0..200 {
            for e in ring.snapshot(64) {
                let i = e.ts_us;
                assert_eq!(e.dur_us, i.wrapping_mul(3), "torn event surfaced");
                assert_eq!(e.kind_id, i ^ 0xABCD);
                assert_eq!(e.arg, i.wrapping_add(7));
                seen += 1;
            }
        }
        writer.join().unwrap();
        assert_eq!(ring.recorded(), 50_000);
        assert!(seen > 0, "reader observed events mid-write");
    }
}
