//! The process-wide telemetry epoch: a single monotonic origin shared by
//! trace events, histograms, and the stderr logger, so timestamps from
//! different subsystems land on one comparable axis.

use std::sync::OnceLock;
use std::time::Instant;

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The shared monotonic epoch. The first caller pins it; every later
/// call returns the same instant, so two timestamps taken anywhere in
/// the process are directly subtractable.
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds elapsed since the shared epoch (the unit every trace
/// event and Chrome-trace `ts` field uses).
pub fn epoch_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_is_pinned_and_monotonic() {
        let a = epoch();
        let t1 = epoch_us();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let t2 = epoch_us();
        assert_eq!(a, epoch(), "epoch must not move once pinned");
        assert!(t2 > t1, "epoch_us must be monotonic ({t1} -> {t2})");
    }
}
