//! Fréchet distance between two feature sets — the FID/sFID analog
//! (Heusel et al. 2017 formula over our fixed random feature net):
//!
//!   FD = ‖μ₁−μ₂‖² + tr(Σ₁ + Σ₂ − 2·(Σ₁Σ₂)^{1/2})
//!
//! The cross term uses the symmetric form (Σ₁^{1/2} Σ₂ Σ₁^{1/2})^{1/2}
//! so every square root is of a PSD matrix.

use crate::metrics::linalg::{mean_cov, sqrtm_psd, Mat};

/// Fréchet distance between row-major feature sets a: [na, d], b: [nb, d].
pub fn frechet_distance(a: &[f32], na: usize, b: &[f32], nb: usize, d: usize) -> f64 {
    let (mu1, s1) = mean_cov(a, na, d);
    let (mu2, s2) = mean_cov(b, nb, d);
    frechet_from_moments(&mu1, &s1, &mu2, &s2)
}

/// Fréchet distance from precomputed moments.
pub fn frechet_from_moments(mu1: &[f64], s1: &Mat, mu2: &[f64], s2: &Mat) -> f64 {
    let d = mu1.len();
    assert_eq!(mu2.len(), d);
    let mean_term: f64 = (0..d).map(|i| (mu1[i] - mu2[i]).powi(2)).sum();
    // tr((Σ1 Σ2)^{1/2}) via the PSD-symmetric equivalent
    let r1 = sqrtm_psd(s1);
    let inner = r1.matmul(s2).matmul(&r1).symmetrize();
    let cross = sqrtm_psd(&inner);
    let cov_term = s1.trace() + s2.trace() - 2.0 * cross.trace();
    (mean_term + cov_term).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn gauss_rows(rng: &mut Rng, n: usize, d: usize, mean: f32, sd: f32) -> Vec<f32> {
        (0..n * d).map(|_| mean + sd * rng.normal()).collect()
    }

    #[test]
    fn identical_sets_near_zero() {
        let mut rng = Rng::new(1);
        let a = gauss_rows(&mut rng, 500, 8, 0.0, 1.0);
        let fd = frechet_distance(&a, 500, &a, 500, 8);
        assert!(fd < 1e-9, "fd {fd}");
    }

    #[test]
    fn same_distribution_small() {
        let mut rng = Rng::new(2);
        let a = gauss_rows(&mut rng, 2000, 4, 0.0, 1.0);
        let b = gauss_rows(&mut rng, 2000, 4, 0.0, 1.0);
        let fd = frechet_distance(&a, 2000, &b, 2000, 4);
        assert!(fd < 0.05, "fd {fd}");
    }

    #[test]
    fn mean_shift_detected() {
        // two isotropic gaussians d=4 shifted by 2 per dim: FD ≈ 4*2² = 16
        let mut rng = Rng::new(3);
        let a = gauss_rows(&mut rng, 4000, 4, 0.0, 1.0);
        let b = gauss_rows(&mut rng, 4000, 4, 2.0, 1.0);
        let fd = frechet_distance(&a, 4000, &b, 4000, 4);
        assert!((fd - 16.0).abs() < 1.0, "fd {fd}");
    }

    #[test]
    fn variance_shift_detected() {
        // N(0,1) vs N(0,4) per dim, d=2: FD = 2*(1+4-2*2) = 2
        let mut rng = Rng::new(4);
        let a = gauss_rows(&mut rng, 4000, 2, 0.0, 1.0);
        let b = gauss_rows(&mut rng, 4000, 2, 0.0, 2.0);
        let fd = frechet_distance(&a, 4000, &b, 4000, 2);
        assert!((fd - 2.0).abs() < 0.4, "fd {fd}");
    }

    #[test]
    fn monotone_in_shift() {
        let mut rng = Rng::new(5);
        let a = gauss_rows(&mut rng, 2000, 4, 0.0, 1.0);
        let mut last = -1.0;
        for shift in [0.0f32, 0.5, 1.0, 2.0] {
            let b = gauss_rows(&mut rng, 2000, 4, shift, 1.0);
            let fd = frechet_distance(&a, 2000, &b, 2000, 4);
            assert!(fd > last, "fd {fd} at shift {shift} not > {last}");
            last = fd;
        }
    }
}
