//! Summary statistics used by the bench harness and tables.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// q-quantile (0..=1) by linear interpolation on a sorted copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = pos - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.118033988).abs() < 1e-6);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
    }
}
