//! Improved Precision & Recall for generative models (Kynkäänniemi et al.
//! 2019) — the same k-NN manifold estimator the paper reports, computed in
//! our fixed feature space.
//!
//! precision = fraction of generated samples inside the real manifold;
//! recall    = fraction of real samples inside the generated manifold;
//! manifold(X) = ∪_i Ball(x_i, dist_to_kth_neighbour(x_i, X)).

use crate::util::threadpool::parallel_map;

fn dist2(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Squared distance from each row of xs to its k-th nearest neighbour
/// within xs (excluding itself).
fn knn_radii2(xs: &[f32], n: usize, d: usize, k: usize, threads: usize) -> Vec<f32> {
    assert!(k >= 1 && n > k, "need n > k");
    let idx: Vec<usize> = (0..n).collect();
    parallel_map(idx, threads, |i| {
        let xi = &xs[i * d..(i + 1) * d];
        // partial selection of k smallest distances
        let mut best = vec![f32::INFINITY; k];
        for j in 0..n {
            if j == i {
                continue;
            }
            let dj = dist2(xi, &xs[j * d..(j + 1) * d]);
            // insert into the sorted top-k buffer
            if dj < best[k - 1] {
                let mut p = k - 1;
                while p > 0 && best[p - 1] > dj {
                    best[p] = best[p - 1];
                    p -= 1;
                }
                best[p] = dj;
            }
        }
        best[k - 1]
    })
}

/// Fraction of query rows that fall inside the manifold of `support`.
fn coverage(query: &[f32], nq: usize, support: &[f32], ns: usize, d: usize,
            radii2: &[f32], threads: usize) -> f64 {
    let idx: Vec<usize> = (0..nq).collect();
    let hits: Vec<u32> = parallel_map(idx, threads, |i| {
        let q = &query[i * d..(i + 1) * d];
        for j in 0..ns {
            if dist2(q, &support[j * d..(j + 1) * d]) <= radii2[j] {
                return 1u32;
            }
        }
        0u32
    });
    hits.iter().sum::<u32>() as f64 / nq.max(1) as f64
}

/// (precision, recall) with neighbourhood size k (paper uses k=3).
pub fn precision_recall(real: &[f32], n_real: usize, fake: &[f32],
                        n_fake: usize, d: usize, k: usize,
                        threads: usize) -> (f64, f64) {
    let r_real = knn_radii2(real, n_real, d, k, threads);
    let r_fake = knn_radii2(fake, n_fake, d, k, threads);
    let precision = coverage(fake, n_fake, real, n_real, d, &r_real, threads);
    let recall = coverage(real, n_real, fake, n_fake, d, &r_fake, threads);
    (precision, recall)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn gauss(rng: &mut Rng, n: usize, d: usize, mean: f32) -> Vec<f32> {
        (0..n * d).map(|_| mean + rng.normal()).collect()
    }

    #[test]
    fn same_distribution_high_both() {
        let mut rng = Rng::new(1);
        let real = gauss(&mut rng, 300, 4, 0.0);
        let fake = gauss(&mut rng, 300, 4, 0.0);
        let (p, r) = precision_recall(&real, 300, &fake, 300, 4, 3, 4);
        assert!(p > 0.85, "precision {p}");
        assert!(r > 0.85, "recall {r}");
    }

    #[test]
    fn distant_fake_zero_precision() {
        let mut rng = Rng::new(2);
        let real = gauss(&mut rng, 200, 4, 0.0);
        let fake = gauss(&mut rng, 200, 4, 50.0);
        let (p, r) = precision_recall(&real, 200, &fake, 200, 4, 3, 4);
        assert!(p < 0.02, "precision {p}");
        assert!(r < 0.02, "recall {r}");
    }

    #[test]
    fn mode_collapse_high_precision_low_recall() {
        // fake concentrated on a tiny region of the real manifold
        let mut rng = Rng::new(3);
        let real = gauss(&mut rng, 400, 4, 0.0);
        let fake: Vec<f32> = (0..400 * 4).map(|_| 0.02 * rng.normal()).collect();
        let (p, r) = precision_recall(&real, 400, &fake, 400, 4, 3, 4);
        assert!(p > 0.9, "precision {p}");
        assert!(r < 0.5, "recall {r}");
    }

    #[test]
    fn knn_radius_hand_check() {
        // 3 colinear points at 0, 1, 10: k=1 radii² = 1, 1, 81
        let xs = [0.0f32, 1.0, 10.0];
        let r = knn_radii2(&xs, 3, 1, 1, 1);
        assert_eq!(r, vec![1.0, 1.0, 81.0]);
    }
}
