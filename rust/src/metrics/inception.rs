//! Inception-Score analog (Salimans et al. 2016 functional form):
//!
//!   IS = exp( E_x KL( p(y|x) ‖ p(y) ) )
//!
//! The paper scores with an ImageNet InceptionV3; our substitute classifier
//! is a nearest-centroid softmax in the fixed feature space: class
//! centroids are estimated from real SynthBlobs samples, and
//! p(y|x) = softmax(−τ·‖f(x) − μ_y‖²). This keeps the same quality ×
//! diversity semantics: confident, class-diverse samples score high.

/// A centroid-softmax classifier over feature space.
#[derive(Debug, Clone)]
pub struct CentroidClassifier {
    pub centroids: Vec<Vec<f32>>, // [K][d]
    pub tau: f32,
}

impl CentroidClassifier {
    /// Fit centroids from labeled real features ([n, d] rows).
    pub fn fit(feats: &[f32], labels: &[usize], d: usize, num_classes: usize,
               tau: f32) -> CentroidClassifier {
        let n = labels.len();
        assert_eq!(feats.len(), n * d);
        let mut centroids = vec![vec![0.0f32; d]; num_classes];
        let mut counts = vec![0usize; num_classes];
        for (i, &k) in labels.iter().enumerate() {
            counts[k] += 1;
            for j in 0..d {
                centroids[k][j] += feats[i * d + j];
            }
        }
        for k in 0..num_classes {
            if counts[k] > 0 {
                for j in 0..d {
                    centroids[k][j] /= counts[k] as f32;
                }
            }
        }
        CentroidClassifier { centroids, tau }
    }

    /// p(y|x) for one feature row.
    pub fn predict(&self, feat: &[f32]) -> Vec<f64> {
        let k = self.centroids.len();
        let mut logits = vec![0.0f64; k];
        for (c, cen) in self.centroids.iter().enumerate() {
            let d2: f32 = feat
                .iter()
                .zip(cen)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            logits[c] = -(self.tau * d2) as f64;
        }
        softmax(&logits)
    }

    /// Top-1 classification accuracy on labeled features (sanity metric).
    pub fn accuracy(&self, feats: &[f32], labels: &[usize], d: usize) -> f64 {
        let mut hits = 0usize;
        for (i, &k) in labels.iter().enumerate() {
            let p = self.predict(&feats[i * d..(i + 1) * d]);
            let arg = p
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if arg == k {
                hits += 1;
            }
        }
        hits as f64 / labels.len().max(1) as f64
    }
}

fn softmax(logits: &[f64]) -> Vec<f64> {
    let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = logits.iter().map(|l| (l - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.iter().map(|e| e / z).collect()
}

/// IS over generated features ([n, d] rows) with the given classifier.
pub fn inception_score(clf: &CentroidClassifier, feats: &[f32], n: usize,
                       d: usize) -> f64 {
    assert!(n > 0);
    let k = clf.centroids.len();
    let mut marginal = vec![0.0f64; k];
    let mut conds = Vec::with_capacity(n);
    for i in 0..n {
        let p = clf.predict(&feats[i * d..(i + 1) * d]);
        for (m, pi) in marginal.iter_mut().zip(&p) {
            *m += pi;
        }
        conds.push(p);
    }
    for m in marginal.iter_mut() {
        *m /= n as f64;
    }
    let mut kl_sum = 0.0;
    for p in &conds {
        for (pi, mi) in p.iter().zip(&marginal) {
            if *pi > 1e-12 && *mi > 1e-12 {
                kl_sum += pi * (pi / mi).ln();
            }
        }
    }
    (kl_sum / n as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// Synthetic feature world: K well-separated centroids.
    fn world(k: usize, d: usize) -> CentroidClassifier {
        let mut cents = vec![vec![0.0f32; d]; k];
        for (i, c) in cents.iter_mut().enumerate() {
            c[i % d] = 5.0 * (1.0 + (i / d) as f32);
        }
        CentroidClassifier { centroids: cents, tau: 1.0 }
    }

    #[test]
    fn perfect_diverse_samples_score_k() {
        // one noiseless sample exactly at each centroid: IS -> K
        let k = 5;
        let d = 8;
        let clf = world(k, d);
        let feats: Vec<f32> = clf.centroids.iter().flatten().cloned().collect();
        let is = inception_score(&clf, &feats, k, d);
        assert!((is - k as f64).abs() < 0.2, "IS {is}");
    }

    #[test]
    fn mode_collapse_scores_one() {
        // all samples at one centroid: marginal == conditional ⇒ IS = 1
        let k = 5;
        let d = 8;
        let clf = world(k, d);
        let one = &clf.centroids[2];
        let n = 50;
        let feats: Vec<f32> = (0..n).flat_map(|_| one.clone()).collect();
        let is = inception_score(&clf, &feats, n, d);
        assert!((is - 1.0).abs() < 1e-6, "IS {is}");
    }

    #[test]
    fn garbage_scores_low() {
        // far-away noise: conditionals ≈ uniform ⇒ IS ≈ 1
        let k = 5;
        let d = 8;
        let clf = world(k, d);
        let mut rng = Rng::new(7);
        let n = 100;
        let feats: Vec<f32> = (0..n * d).map(|_| 100.0 + 0.01 * rng.normal()).collect();
        let is = inception_score(&clf, &feats, n, d);
        assert!(is < 1.5, "IS {is}");
    }

    #[test]
    fn fit_recovers_centroids_and_classifies() {
        let mut rng = Rng::new(9);
        let k = 3;
        let d = 4;
        let true_c = world(k, d);
        let n = 300;
        let mut feats = Vec::with_capacity(n * d);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let y = i % k;
            labels.push(y);
            for j in 0..d {
                feats.push(true_c.centroids[y][j] + 0.3 * rng.normal());
            }
        }
        let clf = CentroidClassifier::fit(&feats, &labels, d, k, 1.0);
        let acc = clf.accuracy(&feats, &labels, d);
        assert!(acc > 0.95, "acc {acc}");
    }
}
