//! Evaluation metrics: Fréchet-distance (FID/sFID analog), Inception-Score
//! analog, and Kynkäänniemi precision/recall — over the fixed random
//! feature net exported as `feature_b{B}.hlo.txt` (DESIGN.md §4).

pub mod linalg;
pub mod fid;
pub mod inception;
pub mod prec_recall;
pub mod stats;

pub use fid::frechet_distance;
pub use inception::inception_score;
pub use prec_recall::precision_recall;
