//! Small dense linear algebra substrate: symmetric Jacobi eigensolver and
//! PSD matrix square root — all the FID computation needs at feature
//! dimension 64.

/// Row-major square matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub n: usize,
    pub a: Vec<f64>,
}

impl Mat {
    pub fn zeros(n: usize) -> Mat {
        Mat { n, a: vec![0.0; n * n] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n);
        for i in 0..n {
            m.a[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let n = rows.len();
        let mut m = Mat::zeros(n);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), n);
            m.a[i * n..(i + 1) * n].copy_from_slice(r);
        }
        m
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.n + j] = v;
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.n, other.n);
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let aik = self.a[i * n + k];
                if aik == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out.a[i * n + j] += aik * other.a[k * n + j];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                out.a[j * n + i] = self.a[i * n + j];
            }
        }
        out
    }

    pub fn trace(&self) -> f64 {
        (0..self.n).map(|i| self.a[i * self.n + i]).sum()
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!(self.n, other.n);
        Mat {
            n: self.n,
            a: self.a.iter().zip(&other.a).map(|(x, y)| x + y).collect(),
        }
    }

    pub fn scale(&self, k: f64) -> Mat {
        Mat { n: self.n, a: self.a.iter().map(|x| x * k).collect() }
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        self.a
            .iter()
            .zip(&other.a)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f64::max)
    }

    /// Symmetrize: 0.5(A + Aᵀ) — guards numerical asymmetry.
    pub fn symmetrize(&self) -> Mat {
        self.add(&self.transpose()).scale(0.5)
    }
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
/// Returns (eigenvalues, eigenvectors as columns of V) with A = V Λ Vᵀ.
pub fn sym_eigen(m: &Mat) -> (Vec<f64>, Mat) {
    let n = m.n;
    let mut a = m.symmetrize();
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a.get(i, j) * a.get(i, j);
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a.get(p, p);
                let aqq = a.get(q, q);
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of A
                for k in 0..n {
                    let akp = a.get(k, p);
                    let akq = a.get(k, q);
                    a.set(k, p, c * akp - s * akq);
                    a.set(k, q, s * akp + c * akq);
                }
                for k in 0..n {
                    let apk = a.get(p, k);
                    let aqk = a.get(q, k);
                    a.set(p, k, c * apk - s * aqk);
                    a.set(q, k, s * apk + c * aqk);
                }
                // accumulate V
                for k in 0..n {
                    let vkp = v.get(k, p);
                    let vkq = v.get(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    let eig = (0..n).map(|i| a.get(i, i)).collect();
    (eig, v)
}

/// PSD matrix square root via eigendecomposition; negative eigenvalues
/// (numerical noise) are clamped to zero.
pub fn sqrtm_psd(m: &Mat) -> Mat {
    let n = m.n;
    let (eig, v) = sym_eigen(m);
    let mut s = Mat::zeros(n);
    for i in 0..n {
        s.set(i, i, eig[i].max(0.0).sqrt());
    }
    v.matmul(&s).matmul(&v.transpose())
}

/// Sample mean and covariance of row-major feature rows [n, d].
pub fn mean_cov(rows: &[f32], n: usize, d: usize) -> (Vec<f64>, Mat) {
    assert_eq!(rows.len(), n * d);
    assert!(n >= 2, "need at least 2 samples for covariance");
    let mut mean = vec![0.0f64; d];
    for i in 0..n {
        for j in 0..d {
            mean[j] += rows[i * d + j] as f64;
        }
    }
    for v in mean.iter_mut() {
        *v /= n as f64;
    }
    let mut cov = Mat::zeros(d);
    for i in 0..n {
        for j in 0..d {
            let xj = rows[i * d + j] as f64 - mean[j];
            for k in j..d {
                let xk = rows[i * d + k] as f64 - mean[k];
                cov.a[j * d + k] += xj * xk;
            }
        }
    }
    let denom = (n - 1) as f64;
    for j in 0..d {
        for k in j..d {
            let v = cov.a[j * d + k] / denom;
            cov.a[j * d + k] = v;
            cov.a[k * d + j] = v;
        }
    }
    (mean, cov)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn eigen_of_diagonal() {
        let m = Mat::from_rows(&[&[3.0, 0.0], &[0.0, 1.0]]);
        let (mut eig, _) = sym_eigen(&m);
        eig.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((eig[0] - 1.0).abs() < 1e-10);
        assert!((eig[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn eigen_reconstructs() {
        // random symmetric 8x8: V Λ Vᵀ == A
        let mut rng = Rng::new(2);
        let n = 8;
        let mut m = Mat::zeros(n);
        for i in 0..n {
            for j in i..n {
                let v = rng.normal() as f64;
                m.set(i, j, v);
                m.set(j, i, v);
            }
        }
        let (eig, v) = sym_eigen(&m);
        let mut lam = Mat::zeros(n);
        for i in 0..n {
            lam.set(i, i, eig[i]);
        }
        let rec = v.matmul(&lam).matmul(&v.transpose());
        assert!(rec.max_abs_diff(&m) < 1e-8, "diff {}", rec.max_abs_diff(&m));
    }

    #[test]
    fn sqrtm_squares_back() {
        // random PSD: B = XᵀX; sqrtm(B)² == B
        let mut rng = Rng::new(3);
        let n = 6;
        let mut x = Mat::zeros(n);
        for i in 0..n * n {
            x.a[i] = rng.normal() as f64;
        }
        let b = x.transpose().matmul(&x);
        let s = sqrtm_psd(&b);
        let s2 = s.matmul(&s);
        assert!(s2.max_abs_diff(&b) < 1e-7, "diff {}", s2.max_abs_diff(&b));
    }

    #[test]
    fn sqrtm_identity() {
        let i4 = Mat::eye(4);
        assert!(sqrtm_psd(&i4).max_abs_diff(&i4) < 1e-12);
    }

    #[test]
    fn mean_cov_hand_check() {
        // two points (0,0) and (2,2): mean (1,1), cov [[2,2],[2,2]]
        let rows = [0.0f32, 0.0, 2.0, 2.0];
        let (mean, cov) = mean_cov(&rows, 2, 2);
        assert_eq!(mean, vec![1.0, 1.0]);
        for v in &cov.a {
            assert!((v - 2.0).abs() < 1e-12);
        }
    }
}
