//! DeepCache-flavoured heuristic baseline: cache every module on every
//! k-th step uniformly (input- and layer-independent). The weakest
//! baseline; included as the ablation floor for Table 7 discussion.

/// Build a uniform schedule: skip all modules on steps where
/// `step % period != 0` (step 0 always computes).
pub fn uniform_schedule(steps: usize, slots: usize, period: usize) -> Vec<Vec<bool>> {
    (0..steps)
        .map(|s| {
            let skip = s != 0 && s % period.max(1) != 0;
            vec![skip; slots]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::learn2cache::schedule_ratio;

    #[test]
    fn period_two_skips_half() {
        let s = uniform_schedule(10, 4, 2);
        assert!(!s[0][0]);
        assert!(s[1][0] && !s[2][0] && s[3][0]);
        assert!((schedule_ratio(&s) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn period_one_never_skips() {
        let s = uniform_schedule(10, 4, 1);
        assert_eq!(schedule_ratio(&s), 0.0);
    }
}
