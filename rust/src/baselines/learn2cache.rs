//! Learn2Cache-analog baseline (Ma et al. 2024, "Learning-to-Cache").
//!
//! The defining property vs LazyDiT: ONE static, input-independent cache
//! schedule per sampling-step count — a binary mask over (step, layer,
//! module) — versus our per-input dynamic gates. We learn the mask the
//! honest cheap way the router relaxation converges to: profile the cosine
//! similarity of consecutive-step module outputs on training inputs and
//! cache the most-similar (step, slot) pairs up to the compute budget.
//! (The paper notes L2C needs a full ImageNet epoch; the profiling pass
//! here is the toy-scale equivalent, see DESIGN.md §4.)

/// Accumulated similarity profile: mean cosine of module output at
/// (step_idx, slot) vs the previous step's output. Indexed [step][2L].
#[derive(Debug, Clone)]
pub struct SimProfile {
    pub sums: Vec<Vec<f64>>,
    pub counts: Vec<Vec<u64>>,
}

impl SimProfile {
    pub fn new(steps: usize, slots: usize) -> SimProfile {
        SimProfile {
            sums: vec![vec![0.0; slots]; steps],
            counts: vec![vec![0; slots]; steps],
        }
    }

    pub fn record(&mut self, step_idx: usize, slot: usize, cos: f64) {
        if step_idx < self.sums.len() && slot < self.sums[0].len() {
            self.sums[step_idx][slot] += cos;
            self.counts[step_idx][slot] += 1;
        }
    }

    pub fn mean(&self, step_idx: usize, slot: usize) -> f64 {
        let c = self.counts[step_idx][slot];
        if c == 0 {
            0.0
        } else {
            self.sums[step_idx][slot] / c as f64
        }
    }

    pub fn steps(&self) -> usize {
        self.sums.len()
    }

    pub fn slots(&self) -> usize {
        self.sums.first().map(|s| s.len()).unwrap_or(0)
    }
}

/// Build the static schedule: skip the `target_ratio` fraction of
/// (step, slot) pairs with the highest profiled similarity. Step 0 is
/// never skipped (no cache exists yet).
pub fn build_schedule(profile: &SimProfile, target_ratio: f64) -> Vec<Vec<bool>> {
    let steps = profile.steps();
    let slots = profile.slots();
    let mut sched = vec![vec![false; slots]; steps];
    if steps <= 1 {
        return sched;
    }
    // candidates exclude step 0
    let mut cands: Vec<(f64, usize, usize)> = Vec::new();
    for s in 1..steps {
        for k in 0..slots {
            cands.push((profile.mean(s, k), s, k));
        }
    }
    cands.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let budget = ((steps * slots) as f64 * target_ratio).round() as usize;
    for &(_, s, k) in cands.iter().take(budget.min(cands.len())) {
        sched[s][k] = true;
    }
    sched
}

/// Achieved skip fraction of a schedule.
pub fn schedule_ratio(sched: &[Vec<bool>]) -> f64 {
    let total: usize = sched.iter().map(|r| r.len()).sum();
    let skips: usize = sched
        .iter()
        .map(|r| r.iter().filter(|&&b| b).count())
        .sum();
    skips as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> SimProfile {
        // 4 steps × 2 slots; similarity grows with step, slot 1 > slot 0
        let mut p = SimProfile::new(4, 2);
        for s in 0..4 {
            for k in 0..2 {
                p.record(s, k, 0.2 * s as f64 + 0.1 * k as f64);
                p.record(s, k, 0.2 * s as f64 + 0.1 * k as f64);
            }
        }
        p
    }

    #[test]
    fn mean_accumulates() {
        let p = profile();
        assert!((p.mean(3, 1) - 0.7).abs() < 1e-12);
        assert_eq!(p.mean(0, 0), 0.0);
    }

    #[test]
    fn schedule_hits_budget_and_prefers_similar() {
        let p = profile();
        let sched = build_schedule(&p, 0.5);
        // budget = 4 of 8; step 0 excluded
        assert!((schedule_ratio(&sched) - 0.5).abs() < 1e-9);
        assert!(!sched[0][0] && !sched[0][1], "step 0 never skipped");
        // the most similar pairs (steps 3 and 2) get picked first
        assert!(sched[3][1] && sched[3][0]);
    }

    #[test]
    fn zero_ratio_schedule_empty() {
        let sched = build_schedule(&profile(), 0.0);
        assert_eq!(schedule_ratio(&sched), 0.0);
    }

    #[test]
    fn full_ratio_caps_at_non_first_steps() {
        let sched = build_schedule(&profile(), 1.0);
        // 6 of 8 possible (step 0 excluded)
        assert!((schedule_ratio(&sched) - 0.75).abs() < 1e-9);
    }
}
