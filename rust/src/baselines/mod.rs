//! Baselines the paper compares against:
//! * DDIM step-reduction — the same engine with gates disabled and fewer
//!   sampling steps (every "DDIM, # of Step s" row);
//! * [`learn2cache`] — an input-INDEPENDENT static cache schedule learned
//!   offline from profiled inter-step similarities (Ma et al. 2024 analog,
//!   Table 7);
//! * [`deepcache`] — a heuristic uniform skip-every-other-step schedule
//!   (DeepCache-flavoured ablation).

pub mod learn2cache;
pub mod deepcache;

pub use learn2cache::{build_schedule, SimProfile};
