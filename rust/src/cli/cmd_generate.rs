//! `lazydit generate` — sample images with DDIM or the lazy engine and
//! optionally write a PNG grid (regenerates Figures 1/3/7 visuals).

use crate::bench::quality::stack_images;
use crate::cli::common::{merge_specs, serve_config, EvalContext};
use crate::config::LazyScope;
use crate::coordinator::engine::{generate_batch, EngineOptions};
use crate::util::argparse::{Args, OptSpec};
use anyhow::Result;
use std::path::PathBuf;

pub fn specs() -> Vec<OptSpec> {
    merge_specs(&[
        OptSpec { name: "steps", help: "DDIM sampling steps", default: Some("20"), is_flag: false },
        OptSpec { name: "lazy", help: "target lazy ratio % (0 = DDIM baseline)", default: Some("0"), is_flag: false },
        OptSpec { name: "count", help: "images to generate", default: Some("16"), is_flag: false },
        OptSpec { name: "seed", help: "rng seed", default: Some("0"), is_flag: false },
        OptSpec { name: "out", help: "output PNG grid path", default: None, is_flag: false },
        OptSpec { name: "cfg-scale", help: "guidance scale", default: Some("1.5"), is_flag: false },
        OptSpec { name: "policy", help: "skip policy", default: Some("mean"), is_flag: false },
        OptSpec { name: "scope", help: "both|attn|ffn|none", default: Some("both"), is_flag: false },
        OptSpec { name: "max-batch", help: "max lanes per round", default: Some("8"), is_flag: false },
        OptSpec { name: "threshold", help: "gate threshold", default: Some("0.5"), is_flag: false },
        OptSpec { name: "queue-cap", help: "admission queue bound", default: Some("256"), is_flag: false },
        OptSpec { name: "train-steps", help: "gate training steps if needed", default: Some("200"), is_flag: false },
        OptSpec { name: "train-lr", help: "gate training lr", default: Some("5e-3"), is_flag: false },
        OptSpec { name: "pretrain-steps", help: "base steps if needed", default: Some("1500"), is_flag: false },
        OptSpec { name: "pretrain-lr", help: "base lr if needed", default: Some("2e-3"), is_flag: false },
    ])
}

pub fn run(a: Args) -> Result<()> {
    let ctx = EvalContext::open(&a, 64)?;
    let steps = a.get_usize("steps", 20)?;
    let lazy_pct = a.get_usize("lazy", 0)?;
    let count = a.get_usize("count", 16)?;
    let seed = a.get_u64("seed", 0)?;
    let serve = serve_config(&a, &ctx.cfg.model.name)?;

    let mut engine = if lazy_pct == 0 {
        ctx.engine(serve, EngineOptions { disable_gates: true, ..Default::default() }, None)?
    } else {
        let gamma = ctx.ensure_gates(&a, steps, lazy_pct, LazyScope::Both)?;
        ctx.engine(serve, EngineOptions::default(), Some(&gamma))?
    };

    let labels: Vec<usize> = (0..count).map(|i| i % ctx.cfg.model.num_classes).collect();
    let t0 = std::time::Instant::now();
    let cfg_scale = engine.serve.cfg_scale;
    let results = generate_batch(&mut engine, &labels, steps, seed,
                                 cfg_scale)?;
    let wall = t0.elapsed().as_secs_f64();

    let lazy: f64 = results.iter().map(|r| r.lazy_ratio).sum::<f64>()
        / results.len() as f64;
    println!(
        "generated {count} images in {wall:.2}s ({:.2} img/s); steps {steps}, \
         achieved lazy ratio {:.1}%",
        count as f64 / wall,
        100.0 * lazy
    );

    let images = stack_images(&results)?;
    let q = ctx.metrics.evaluate(&ctx.extractor, &images)?;
    println!(
        "quality: FID-a {:.3}  sFID-a {:.3}  IS-a {:.3}  Prec {:.3}  Rec {:.3}",
        q.fid, q.sfid, q.is, q.precision, q.recall
    );

    if let Some(out) = a.get("out") {
        let path = PathBuf::from(out);
        let cols = (count as f64).sqrt().ceil() as usize;
        crate::io::png::write_grid(&path, &images, cols.max(1), 16)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}
