//! `lazydit eval` — full quality + compute row for one configuration.

use crate::bench::quality::{eval_labels, stack_images};
use crate::cli::common::{merge_specs, serve_config, EvalContext};
use crate::config::LazyScope;
use crate::coordinator::engine::{generate_batch, EngineOptions};
use crate::util::argparse::{Args, OptSpec};
use anyhow::Result;

pub fn specs() -> Vec<OptSpec> {
    merge_specs(&[
        OptSpec { name: "steps", help: "DDIM sampling steps", default: Some("20"), is_flag: false },
        OptSpec { name: "lazy", help: "lazy ratio % (0 = DDIM)", default: Some("0"), is_flag: false },
        OptSpec { name: "n-eval", help: "images per trial", default: Some("128"), is_flag: false },
        OptSpec { name: "n-real", help: "real reference samples", default: Some("256"), is_flag: false },
        OptSpec { name: "seed", help: "rng seed", default: Some("0"), is_flag: false },
        OptSpec { name: "policy", help: "skip policy", default: Some("mean"), is_flag: false },
        OptSpec { name: "scope", help: "both|attn|ffn|none", default: Some("both"), is_flag: false },
        OptSpec { name: "max-batch", help: "max lanes", default: Some("8"), is_flag: false },
        OptSpec { name: "cfg-scale", help: "guidance", default: Some("1.5"), is_flag: false },
        OptSpec { name: "threshold", help: "gate threshold", default: Some("0.5"), is_flag: false },
        OptSpec { name: "queue-cap", help: "queue bound", default: Some("256"), is_flag: false },
        OptSpec { name: "train-steps", help: "gate train steps if needed", default: Some("200"), is_flag: false },
        OptSpec { name: "train-lr", help: "gate train lr", default: Some("5e-3"), is_flag: false },
        OptSpec { name: "pretrain-steps", help: "base steps if needed", default: Some("1500"), is_flag: false },
        OptSpec { name: "pretrain-lr", help: "base lr if needed", default: Some("2e-3"), is_flag: false },
    ])
}

pub fn run(a: Args) -> Result<()> {
    let n_real = a.get_usize("n-real", 256)?;
    let ctx = EvalContext::open(&a, n_real)?;
    let steps = a.get_usize("steps", 20)?;
    let lazy_pct = a.get_usize("lazy", 0)?;
    let n_eval = a.get_usize("n-eval", 128)?;
    let serve = serve_config(&a, &ctx.cfg.model.name)?;
    let cfg_scale = serve.cfg_scale;

    let mut engine = if lazy_pct == 0 {
        ctx.engine(serve, EngineOptions { disable_gates: true, ..Default::default() }, None)?
    } else {
        let gamma = ctx.ensure_gates(&a, steps, lazy_pct, LazyScope::Both)?;
        ctx.engine(serve, EngineOptions::default(), Some(&gamma))?
    };

    let labels = eval_labels(n_eval, ctx.cfg.model.num_classes);
    let t0 = std::time::Instant::now();
    let results = generate_batch(&mut engine, &labels, steps,
                                 a.get_u64("seed", 0)?, cfg_scale)?;
    let wall = t0.elapsed().as_secs_f64();
    let images = stack_images(&results)?;
    let q = ctx.metrics.evaluate(&ctx.extractor, &images)?;
    let lazy: f64 = results.iter().map(|r| r.lazy_ratio).sum::<f64>()
        / results.len() as f64;
    let macs = crate::tmacs::run_macs(&ctx.cfg.model, steps, lazy, true,
                                      lazy_pct > 0);

    println!(
        "\nconfig {} steps {steps} lazy {:.1}% ({} images, {wall:.1}s, \
         {:.2} img/s)",
        ctx.cfg.model.name, 100.0 * lazy, n_eval, n_eval as f64 / wall
    );
    println!(
        "  FID-a {:.3}  sFID-a {:.3}  IS-a {:.3}  Prec {:.3}  Rec {:.3}  \
         GMACs/img {:.3}",
        q.fid, q.sfid, q.is, q.precision, q.recall,
        crate::tmacs::as_gmacs(macs)
    );
    println!("{}", engine.layer_stats.render_fig4());
    Ok(())
}
